"""Paper §6.3 / §3 — sampling complexity: alias (MHW) vs full-conditional.

The paper's core algorithmic claim: the exact sampler costs O(K) per token
while MHW costs amortized O(k_d + 1), so exact slows down with the topic
count while MHW stays ~flat.  We time one jitted sweep per method across K
and report per-token cost plus the MH acceptance rate (the approximation-
quality diagnostic of §3.3 — it must stay high or the chain mixes slowly).

Also reports:

* alias-table build throughput (tables/s) — the producer side of the
  paper's producer/consumer thread-pool design (§5.1) — fused
  (in-kernel dense term) vs. materialize-then-build, and the incremental
  partial-rebuild cost, which must scale with the changed rows, not V;
* the round engine: rounds/s of the compiled whole-round program
  (engine.round, donated buffers, async dispatch) vs. the PR-2 Python
  reference loop, plus a blocking per-phase breakdown of one round
  (sample / filter+push / project / alias-rebuild) — the dispatch-overhead
  win tracked in BENCH_throughput.json.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import alias as alias_mod
from repro.core import family as family_mod
from repro.core import lda, ps
from repro.data.synthetic import CorpusConfig, make_topic_corpus
from repro.engine import Trainer, TrainerConfig
from repro.engine import round as round_mod
from repro.kernels import ops as kernel_ops

from benchmarks import common

# sampler name -> (lda.sweep method, layout)
SAMPLERS = {
    "exact": ("exact", "scan"),
    "mhw": ("mhw", "scan"),
    "mhw_sorted": ("mhw", "sorted"),
}


def time_sweeps(cfg, tokens, mask, samplers, n_iter=5):
    """Median per-sweep wall time for each sampler, measured interleaved.

    Round-robin across samplers within each iteration so machine load
    drift (shared CI boxes wander by 2-3× over minutes) hits every
    sampler equally — the *relative* numbers are what the artifact
    tracks.  Medians, not means, for the same reason.
    """
    states = {}
    for sampler in samplers:
        method, layout = SAMPLERS[sampler]
        lays = None
        if layout == "sorted":
            # Production path: the token stream never changes between
            # sweeps, so the per-chunk sorts are hoisted out of the loop.
            lays = lda.build_sorted_layouts(cfg, tokens, mask)
        local, shared = lda.init_state(cfg, tokens, mask,
                                       jax.random.PRNGKey(0))
        tables, stale = lda.build_alias(cfg, shared)
        # warmup/compile
        out = lda.sweep(cfg, local, shared, tables, stale, tokens, mask,
                        jax.random.PRNGKey(1), method=method, layout=layout,
                        sorted_layouts=lays)
        jax.block_until_ready(out[1])
        states[sampler] = [local, shared, tables, stale, lays, []]
    for i in range(n_iter):
        for sampler in samplers:
            method, layout = SAMPLERS[sampler]
            st = states[sampler]
            local, shared, tables, stale, lays, times = st
            t0 = time.perf_counter()
            local, dwk, dk = lda.sweep(
                cfg, local, shared, tables, stale, tokens, mask,
                jax.random.fold_in(jax.random.PRNGKey(2), i),
                method=method, layout=layout, sorted_layouts=lays)
            shared = lda.apply_delta(shared, dwk, dk)
            jax.block_until_ready(shared.n_wk)
            times.append(time.perf_counter() - t0)
            st[0], st[1] = local, shared
    return {s: sorted(states[s][5])[n_iter // 2] for s in samplers}


def time_round_engine(cfg, tokens, mask, n_rounds=6, n_clients=8, tau=2):
    """Rounds/s: compiled whole-round program vs. the Python reference
    loop, same config and RNG (the two produce bit-identical counts, so
    this isolates dispatch overhead + per-round host sync)."""
    out = {}
    for compiled in (False, True):
        trainer = Trainer(cfg, tokens, mask, config=TrainerConfig(
            n_clients=n_clients, tau=tau, compiled=compiled))
        trainer.step()                  # warmup/compile
        trainer._sync()
        t0 = time.perf_counter()
        for _ in range(n_rounds):
            trainer.step()
        trainer._sync()
        out["compiled" if compiled else "python_loop"] = \
            (time.perf_counter() - t0) / n_rounds
    out["speedup"] = out["python_loop"] / out["compiled"]
    return out


def round_phase_breakdown(cfg, tokens, mask, n_rounds=3, n_clients=2):
    """Blocking per-phase wall-clock of one sync round, built from the
    shared round body (engine.round) the way the reference loop dispatches
    it: alias-rebuild → sample (tau sweeps/client) → filter+push →
    project(+auxiliaries).  Phases are synced individually, so the numbers
    over-count overlap on purpose — they bound each phase's share."""
    spec = ps.FilterSpec(kind="topk", k_rows=cfg.vocab_size // 8,
                         random_rows=cfg.vocab_size // 16)
    trainer = Trainer(cfg, tokens, mask, config=TrainerConfig(
        n_clients=n_clients, compiled=False, filter=spec))
    fam = trainer.family

    @jax.jit
    def sample_fn(local, snapshot, tables, stale, t, m, keys):
        return round_mod.tau_sweeps(cfg, fam, local, snapshot, tables,
                                    stale, t, m, keys)

    @jax.jit
    def filter_push_fn(accs, snapshot, residuals, kfs):
        total, res = None, []
        for c, acc in enumerate(accs):
            sent, r2 = round_mod.filter_push(fam, acc, spec, kfs[c],
                                             residuals[c])
            res.append(r2)
            total = sent if total is None else {
                n: total[n] + sent[n] for n in sent}
        return fam.apply_delta(snapshot, total), tuple(res)

    @jax.jit
    def project_fn(locals_, shared, key):
        return fam.post_round(cfg, list(locals_), fam.project(shared), key)

    trainer.step()                      # warmup the trainer state
    phases = {"alias_rebuild": 0.0, "sample": 0.0, "filter_push": 0.0,
              "project": 0.0}
    for r in range(1, 2 + n_rounds):    # round 1 warms the phase jits
        t0 = time.perf_counter()
        tables, stale = fam.build_alias(cfg, trainer.shared)
        jax.block_until_ready(tables.prob)
        phases["alias_rebuild"] += time.perf_counter() - t0

        snapshot = trainer.shared
        accs = []
        t0 = time.perf_counter()
        for c, (t, m) in enumerate(trainer.shards):
            keys = jax.vmap(lambda s, c=c: jax.random.fold_in(
                trainer.key, r * 131 + c * 17 + s))(jnp.arange(1))
            trainer.locals_[c], acc = sample_fn(
                trainer.locals_[c], snapshot, tables, stale, t, m, keys)
            accs.append(acc)
        jax.block_until_ready(accs[-1])
        phases["sample"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        kfs = [jax.random.fold_in(trainer.key, 7000 + r * 131 + c)
               for c in range(len(accs))]
        trainer.shared, res = filter_push_fn(tuple(accs), snapshot,
                                             tuple(trainer.residuals), kfs)
        trainer.residuals = list(res)
        jax.block_until_ready(fam.stats_dict(trainer.shared)[
            fam.delta_names[0]])
        phases["filter_push"] += time.perf_counter() - t0

        t0 = time.perf_counter()
        trainer.locals_, trainer.shared = project_fn(
            tuple(trainer.locals_), trainer.shared,
            jax.random.fold_in(trainer.key, 9000 + r))
        trainer.locals_ = list(trainer.locals_)
        trainer._sync()
        phases["project"] += time.perf_counter() - t0
        if r == 1:                      # drop the compile round
            phases = {k: 0.0 for k in phases}
    return {k: v / n_rounds for k, v in phases.items()}


def time_partial_rebuild(cfg, shared, tables, stale, row_counts):
    """Incremental alias producer cost vs. number of changed rows — must
    scale with R, not V (the full-rebuild baseline)."""
    fam = family_mod.family_of(cfg)
    out = {}
    for n_rows in row_counts:
        rows = jnp.arange(n_rows, dtype=jnp.int32)
        valid = jnp.ones((n_rows,), bool)
        fn = jax.jit(lambda sh, tb, st, rw, vl: fam.rebuild_alias_rows(
            cfg, sh, tb, st, rw, vl))
        t2, s2 = fn(shared, tables, stale, rows, valid)   # warmup/compile
        jax.block_until_ready(t2.prob)
        t0 = time.perf_counter()
        for _ in range(3):
            t2, s2 = fn(shared, tables, stale, rows, valid)
        jax.block_until_ready(t2.prob)
        out[str(n_rows)] = (time.perf_counter() - t0) / 3
    return out


def run(quick: bool = True) -> None:
    vocab = 300 if quick else 1000
    ccfg = CorpusConfig(n_topics=8, vocab_size=vocab,
                        n_docs=64 if quick else 128,
                        doc_len=48 if quick else 64, seed=5)
    tokens, mask, _ = make_topic_corpus(ccfg)
    tokens, mask = jnp.asarray(tokens), jnp.asarray(mask)
    n_tok = int(mask.sum())

    artifact = {"quick": quick, "vocab": vocab, "n_tokens": n_tok,
                "us_per_token": {}, "speedup_sorted_vs_mhw": {}}
    ks = (16, 64) if quick else (16, 64, 256, 1024)
    per_token = {}
    for k in ks:
        cfg = lda.LDAConfig(n_topics=k, vocab_size=vocab, mh_steps=2)
        dts = time_sweeps(cfg, tokens, mask, tuple(SAMPLERS),
                          n_iter=7 if quick else 9)
        for sampler, dt in dts.items():
            per_token[(sampler, k)] = dt / n_tok
            artifact["us_per_token"].setdefault(sampler, {})[str(k)] = \
                dt / n_tok * 1e6
            common.emit("throughput_scaling", sampler=sampler, n_topics=k,
                        us_per_token=dt / n_tok * 1e6,
                        tokens_per_s=n_tok / dt)
        speedup = per_token[("mhw", k)] / per_token[("mhw_sorted", k)]
        artifact["speedup_sorted_vs_mhw"][str(k)] = speedup
        common.emit("throughput_sorted_speedup", n_topics=k,
                    sorted_vs_mhw=speedup)
    # Scaling exponent proxy: cost growth exact vs mhw from smallest to
    # largest K (paper: exact grows ~linearly, alias ~flat on CPU clusters;
    # on TPU both are dense K-lane ops, so the ratio narrows — DESIGN.md §2).
    k0, k1 = ks[0], ks[-1]
    common.emit("throughput_summary",
                exact_growth=per_token[("exact", k1)] / per_token[("exact", k0)],
                mhw_growth=per_token[("mhw", k1)] / per_token[("mhw", k0)],
                k_ratio=k1 / k0)
    artifact["growth"] = {
        s: per_token[(s, k1)] / per_token[(s, k0)] for s in SAMPLERS}

    # Correctness cross-check for the artifact: scan vs sorted perplexity
    # after 5 sweeps (the sorted relaxation must not trade correctness).
    # Averaged over 3 paired sweep-RNG seeds: single-seed 5-sweep
    # perplexity has ~±1.5% MC noise on this corpus, which would swamp the
    # ~1% systematic relaxation effect being measured.
    cfg = lda.LDAConfig(n_topics=64, vocab_size=vocab, mh_steps=2)
    ppl = {"mhw": [], "mhw_sorted": []}
    for sampler in ("mhw", "mhw_sorted"):
        _, layout = SAMPLERS[sampler]
        for seed in (2, 3, 4):
            ppl[sampler].append(common.lda_sweep_perplexity(
                cfg, tokens, mask, layout, seed))
    mean_ppl = {s: sum(v) / len(v) for s, v in ppl.items()}
    rel = abs(mean_ppl["mhw_sorted"] - mean_ppl["mhw"]) / mean_ppl["mhw"]
    artifact["perplexity_5_sweeps"] = {
        **{s: {"per_seed": v, "mean": mean_ppl[s]} for s, v in ppl.items()},
        "rel_diff": rel}
    common.emit("throughput_ppl_check", mhw=mean_ppl["mhw"],
                mhw_sorted=mean_ppl["mhw_sorted"], rel_diff=rel)

    # Alias build throughput (producer pool, §5.1): materialize-then-build
    # (dense (V, K) term in HBM, then the table builder) vs. the fused
    # kernel (dense term computed in-register from the raw statistics —
    # saves the V×K round trip; see kernels/alias_build.py).
    cfg = lda.LDAConfig(n_topics=64, vocab_size=vocab)
    _, shared = lda.init_state(cfg, tokens, mask, jax.random.PRNGKey(0))
    t, _ = lda.build_alias(cfg, shared)
    jax.block_until_ready(t.prob)
    t0 = time.perf_counter()
    for _ in range(3):
        t, _ = lda.build_alias(cfg, shared)
    jax.block_until_ready(t.prob)
    dt = (time.perf_counter() - t0) / 3
    tile_r = max(t for t in (8, 4, 2, 1) if vocab % t == 0)
    tf, _ = kernel_ops.build_tables_fused_lda(
        shared.n_wk, shared.n_k, alpha=cfg.alpha, beta=cfg.beta,
        vocab_size=vocab, tile_r=tile_r)
    jax.block_until_ready(tf.prob)
    t0 = time.perf_counter()
    for _ in range(3):
        tf, _ = kernel_ops.build_tables_fused_lda(
            shared.n_wk, shared.n_k, alpha=cfg.alpha, beta=cfg.beta,
            vocab_size=vocab, tile_r=tile_r)
    jax.block_until_ready(tf.prob)
    dt_fused = (time.perf_counter() - t0) / 3
    common.emit("alias_build", vocab=vocab, n_topics=64,
                tables_per_s=vocab / dt, s_per_build=dt,
                s_per_build_fused=dt_fused)
    artifact["alias_build"] = {"tables_per_s": vocab / dt,
                               "s_per_build": dt,
                               "s_per_build_fused": dt_fused}

    # Incremental (delta-driven) partial rebuild: cost scales with the
    # number of changed rows, not V — vs. s_per_build above as the
    # full-rebuild baseline.
    tables, stale = lda.build_alias(cfg, shared)
    partial = time_partial_rebuild(cfg, shared, tables, stale,
                                   (8, 32, 128) if quick
                                   else (8, 32, 128, 512))
    for n_rows, s in partial.items():
        common.emit("alias_partial_rebuild", changed_rows=int(n_rows),
                    s_per_rebuild=s)
    artifact["alias_partial_rebuild"] = {
        "s_full_rebuild": dt, "s_per_changed_rows": partial}

    # Round engine: the compiled whole-round program vs. the PR-2 Python
    # loop (one dispatch per op + a device sync every round), plus the
    # blocking per-phase breakdown of the reference round.  Measured on a
    # small shard so per-round dispatch + host-sync overhead — what fusion
    # removes — is not drowned by kernel compute (the production regime:
    # many clients, modest per-client shards).
    rcfg = CorpusConfig(n_topics=8, vocab_size=vocab, n_docs=32,
                        doc_len=16, seed=11)
    rtokens, rmask, _ = make_topic_corpus(rcfg)
    rtokens, rmask = jnp.asarray(rtokens), jnp.asarray(rmask)
    cfg_round = lda.LDAConfig(n_topics=16 if quick else 64,
                              vocab_size=vocab)
    engine = time_round_engine(cfg_round, rtokens, rmask,
                               n_rounds=10 if quick else 16)
    common.emit("round_engine", s_per_round_python=engine["python_loop"],
                s_per_round_compiled=engine["compiled"],
                rounds_per_s_python=1.0 / engine["python_loop"],
                rounds_per_s_compiled=1.0 / engine["compiled"],
                speedup=engine["speedup"])
    phases = round_phase_breakdown(cfg_round, rtokens, rmask)
    for ph, s in phases.items():
        common.emit("round_phase", phase=ph, s_per_round=s)
    artifact["round_engine"] = {
        "s_per_round": {"python_loop": engine["python_loop"],
                        "compiled": engine["compiled"]},
        "rounds_per_s": {"python_loop": 1.0 / engine["python_loop"],
                         "compiled": 1.0 / engine["compiled"]},
        "compiled_speedup": engine["speedup"],
        "phase_breakdown_s": phases}

    # MH acceptance rate vs staleness (§3.3): how far can the alias table
    # lag before the chain stops moving?  This is the napkin math behind the
    # `alias_refresh_every` knob — the paper rebuilds after l/n draws.
    from repro.core import mhw as mhw_mod
    cfg = lda.LDAConfig(n_topics=64, vocab_size=vocab, mh_steps=4)
    local, shared = lda.init_state(cfg, tokens, mask, jax.random.PRNGKey(0))
    # Burn in so the state is not pure noise (drift then measures sweeps
    # *between refreshes*, the operational staleness).
    for i in range(5):
        tables, stale = lda.build_alias(cfg, shared)
        local, dwk, dk = lda.sweep(cfg, local, shared, tables, stale, tokens,
                                   mask, jax.random.fold_in(jax.random.PRNGKey(3), i))
        shared = lda.apply_delta(shared, dwk, dk)

    w = tokens.reshape(-1)[:512]
    docs0 = jnp.zeros_like(w)
    for drift_sweeps in (0, 1, 2, 4):
        tables, stale = lda.build_alias(cfg, shared)   # fresh table
        drift = shared
        for i in range(drift_sweeps):
            local, dwk, dk = lda.sweep(
                cfg, local, drift, tables, stale, tokens, mask,
                jax.random.fold_in(jax.random.PRNGKey(31), i))
            drift = lda.apply_delta(drift, dwk, dk)
        n_dk_rows = local.n_dk[docs0]
        lm = lda.language_model(cfg, drift)
        # Exactly the sweep's proposal (eq. 4): exact sparse term
        # n_dk·lm_fresh + stale dense term α·lm_stale via the alias table.
        sparse_w = n_dk_rows * lm[w]
        prop = mhw_mod.MixtureProposal(sparse_weights=sparse_w,
                                       dense_tables=tables, dense_rows=w)

        def log_p(t, lm=lm, n_dk_rows=n_dk_rows):
            rows = jnp.arange(w.shape[0])
            return jnp.log((n_dk_rows[rows, t] + cfg.alpha)
                           * lm[w, t] + 1e-30)

        z_init = jax.random.randint(jax.random.PRNGKey(8), w.shape, 0,
                                    cfg.n_topics)
        _, rate = mhw_mod.mh_chain_with_stats(
            jax.random.PRNGKey(9), z_init, prop, stale, log_p, 4)
        common.emit("mh_acceptance", sweeps_of_drift=drift_sweeps,
                    acceptance=float(rate))
        artifact.setdefault("mh_acceptance", {})[str(drift_sweeps)] = \
            float(rate)

    common.write_artifact("throughput", artifact)


if __name__ == "__main__":
    run(quick=False)
