"""Consistency-policy frontier (paper §5.2-§5.3; Yuan et al. 2014 §4).

The parameter server's relaxed consistency model is the paper's central
scaling lever: bulk-synchronous rounds (BSP) pay a full pull — snapshot
refresh + alias-proposal rebuild — every round, while stale-synchronous
clients (SSP, bound s) amortize that work over s+1 rounds and async
clients never block on it at all.  The staleness is not free: clients
sample against older statistics, so mixing can slow down.

This bench records that trade as the staleness-vs-throughput-vs-perplexity
frontier over the multi-client quick config: rounds/s and final held-out
perplexity for BSP vs SSP(1) vs SSP(2) vs SSP(4) vs async, written to
``BENCH_consistency.json``.  The acceptance contract (tracked by
tools/ci.sh):

* all policy entries present (bsp, ssp1, ssp2, ssp4, async);
* SSP(bound ≥ 2) strictly faster (rounds/s) than BSP;
* SSP (bounds 1-2) and async within 5% relative perplexity of BSP at
  equal rounds.  SSP(4) is recorded as the deep-staleness frontier point
  without a ppl gate: at quick-CI corpus sizes a refresh period of 5
  rounds is a large fraction of the whole transient, so its gap
  (~10-20%) reflects the tiny-corpus regime, not the production one the
  paper targets (where per-round relative drift is orders of magnitude
  smaller) — the artifact tracks it so the trade stays visible.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import lda
from repro.data.synthetic import CorpusConfig, make_topic_corpus
from repro.engine import Trainer, TrainerConfig

from benchmarks import common

# Artifact keys, in the order reported.
POLICIES = {
    "bsp": "bsp",
    "ssp1": "ssp:1",
    "ssp2": "ssp:2",
    "ssp4": "ssp:4",
    "async": "async",
}


def time_policy(cfg, tokens, mask, consistency: str, *, n_clients: int,
                n_rounds: int, seeds=(0, 1)) -> dict:
    """Min-of-seeds s/round (timed segment excludes the compile/warmup
    rounds; min because shared-box load only ever adds time) and
    seed-averaged final perplexity for one consistency policy."""
    times, ppls = [], []
    for seed in seeds:
        trainer = Trainer(cfg, tokens, mask, config=TrainerConfig(
            n_clients=n_clients, consistency=consistency),
            key=jax.random.PRNGKey(seed))
        # Warmup: compile the round and settle the alias/pull schedule
        # past the first refresh so every policy is timed steady-state.
        for _ in range(2):
            trainer.step()
        trainer._sync()
        t0 = time.perf_counter()
        for _ in range(n_rounds):
            trainer.step()
        trainer._sync()
        times.append((time.perf_counter() - t0) / n_rounds)
        ppls.append(trainer.perplexity(tokens[:64], mask[:64]))
        assert trainer.consistency_error() == 0.0, consistency
    return {
        "s_per_round": min(times),
        "rounds_per_s": 1.0 / min(times),
        "perplexity_final": sum(ppls) / len(ppls),
    }


def run(quick: bool = True) -> None:
    # The regime the policies differentiate in: several clients with
    # modest per-client shards, a vocabulary large enough that the
    # per-round pull work (snapshot + alias-proposal rebuild over V rows)
    # is a visible fraction of the round — exactly the work SSP amortizes
    # over its staleness window — and a corpus large enough that
    # per-round relative drift does not drown the stale clients.
    ccfg = CorpusConfig(n_topics=8, vocab_size=2048 if quick else 8192,
                        n_docs=256 if quick else 512,
                        doc_len=48 if quick else 64, seed=13)
    tokens, mask, _ = make_topic_corpus(ccfg)
    tokens, mask = jnp.asarray(tokens), jnp.asarray(mask)
    cfg = lda.LDAConfig(n_topics=16 if quick else 32,
                        vocab_size=ccfg.vocab_size, mh_steps=2)
    n_clients = 4
    n_rounds = 30 if quick else 48

    artifact = {"quick": quick, "vocab": ccfg.vocab_size,
                "n_clients": n_clients, "n_rounds": n_rounds,
                "policies": {}}
    from repro.core.server import make_consistency
    from repro.engine import round as round_mod
    traces0 = {name: round_mod.trace_count(
        "lda", "scan", make_consistency(c).key)
        for name, c in POLICIES.items()}
    results = {}
    for name, consistency in POLICIES.items():
        results[name] = time_policy(cfg, tokens, mask, consistency,
                                    n_clients=n_clients, n_rounds=n_rounds,
                                    seeds=(0, 1) if quick else (0, 1, 2))
        common.emit("consistency", policy=name, **results[name])

    bsp = results["bsp"]
    for name, res in results.items():
        res["speedup_vs_bsp"] = bsp["s_per_round"] / res["s_per_round"]
        res["ppl_rel_vs_bsp"] = (abs(res["perplexity_final"]
                                     - bsp["perplexity_final"])
                                 / bsp["perplexity_final"])
        # SSP(4) is the deep-staleness frontier point: recorded, but not
        # gated on perplexity (module docstring).  The artifact carries
        # the flag explicitly so downstream checks can assert the gate
        # coverage instead of inferring it from the bound.
        res["unguarded"] = name == "ssp4"
        artifact["policies"][name] = res
    common.emit("consistency_summary",
                ssp2_speedup_vs_bsp=results["ssp2"]["speedup_vs_bsp"],
                ssp4_speedup_vs_bsp=results["ssp4"]["speedup_vs_bsp"],
                async_ppl_rel_vs_bsp=results["async"]["ppl_rel_vs_bsp"])

    # The acceptance contract, asserted here so a nightly/CI run fails
    # loudly instead of silently shipping a regressed artifact.  SSP(4)
    # carries no ppl gate — it is the deep-staleness frontier point (see
    # module docstring).
    for name in ("ssp2", "ssp4"):
        assert results[name]["s_per_round"] < bsp["s_per_round"], (
            f"{name} not strictly faster than BSP: {results[name]} vs {bsp}")
    for name in ("ssp1", "ssp2", "async"):
        assert results[name]["ppl_rel_vs_bsp"] <= 0.05, (name, results[name])

    # Trace-count guard (must run in-process — jit caches die with the
    # interpreter): per policy, this bench's Trainers (all seeds share one
    # static signature) cost exactly one new trace, and nothing that
    # varies per round (refresh flag, projection cadence, failure mask)
    # may have retraced it.
    for name, consistency in POLICIES.items():
        pkey = make_consistency(consistency).key
        n = round_mod.trace_count("lda", "scan", pkey) - traces0[name]
        assert n == 1, (
            f"compiled round traced {n}x for (lda, scan, {pkey}) in this "
            "bench — steady-state rounds must not retrace")
    common.emit("consistency_trace_guard", traces_per_policy=1)

    common.write_artifact("consistency", artifact)


if __name__ == "__main__":
    run(quick=False)
