"""Paper Fig 7 — HDP-LDA convergence at two client-group sizes (paper: 200
and 500 clients; CPU-scaled to 2 and 8), driven by ``engine.Trainer``.
The hierarchical DP resamples CRT table counts and the root topic
distribution θ0 every round (the family's ``post_round`` hook).

Also benchmarks the token-sorted tile-skipping layout — with HDP's dense
term b1·θ0_t as the per-topic prior vector — against the scan oracle
(``--layout sorted`` equivalent: both layouts always run) and writes the
``BENCH_hdp.json`` artifact so the sorted-path speedup for this family is
diffable across PRs, mirroring ``BENCH_throughput.json`` for LDA.
"""

from __future__ import annotations

from repro.core import hdp

from benchmarks import common


def run(quick: bool = True) -> None:
    tokens, mask, _, ccfg = common.default_corpus(quick, seed=2)
    cfg = hdp.HDPConfig(n_topics=ccfg.n_topics * 2,
                        vocab_size=ccfg.vocab_size, b0=1.0, b1=2.0,
                        mh_steps=4)
    n_rounds = 10 if quick else 25
    artifact: dict = {"quick": quick, "n_topics": cfg.n_topics,
                      "vocab": ccfg.vocab_size}

    for n_clients in ((2, 8) if not quick else (2, 4)):
        res = common.run_multiclient(
            cfg, tokens, mask, n_clients=n_clients, n_rounds=n_rounds,
            method="mhw", eval_every=max(1, n_rounds // 4))
        common.emit(
            "hdp_fig7", sampler="alias_hdp", clients=n_clients,
            perplexity_first=res.perplexities[0],
            perplexity_final=res.perplexities[-1],
            topics_per_word_final=res.topics_per_word[-1],
            s_per_iter=sum(res.iter_times[1:]) / max(len(res.iter_times) - 1, 1),
            tokens_per_s=res.tokens_per_s)

    # Sorted fast path vs scan oracle (single client).
    common.layout_speedup_artifact("hdp", cfg, tokens, mask,
                                   artifact=artifact,
                                   n_rounds=6 if quick else 10)


if __name__ == "__main__":
    run(quick=False)
