"""Paper Fig 7 — HDP-LDA convergence at two client-group sizes (paper: 200
and 500 clients; CPU-scaled to 2 and 8).  The hierarchical DP resamples CRT
table counts and the root topic distribution θ0 every round."""

from __future__ import annotations

from repro.core import hdp

from benchmarks import common


def run(quick: bool = True) -> None:
    tokens, mask, _, ccfg = common.default_corpus(quick, seed=2)
    cfg = hdp.HDPConfig(n_topics=ccfg.n_topics * 2,
                        vocab_size=ccfg.vocab_size, b0=1.0, b1=2.0,
                        mh_steps=4)
    n_rounds = 10 if quick else 25
    for n_clients in ((2, 8) if not quick else (2, 4)):
        hooks = common.hdp_hooks(cfg, project=True)
        res = common.run_multiclient(
            hooks, tokens, mask, n_clients=n_clients, n_rounds=n_rounds,
            method="mhw", eval_every=max(1, n_rounds // 4))
        common.emit(
            "hdp_fig7", sampler="alias_hdp", clients=n_clients,
            perplexity_first=res.perplexities[0],
            perplexity_final=res.perplexities[-1],
            topics_per_word_final=res.topics_per_word[-1],
            s_per_iter=sum(res.iter_times[1:]) / max(len(res.iter_times) - 1, 1),
            tokens_per_s=res.tokens_per_s)


if __name__ == "__main__":
    run(quick=False)
