"""(V, K) scale benchmark — makes the vocabulary/topic scale axis real.

Sweeps a ladder of (vocab, n_topics) points on the zipf synthetic corpus
(``make_topic_corpus`` draws word frequencies from a power law) and, per
point, reports the numbers that gate the scale story:

* **tokens/s** of the K-tiled sorted mhw sweep (``tile_k`` staging keeps
  per-table VMEM residency at ``tile_v × tile_k`` instead of
  ``tile_v × K``; the grid is capped via an explicit ``tile_v`` because
  interpret mode unrolls every grid program at trace time),
* **alias-build ms/row** via the incremental row builder
  (``kernels.alias_build_rows`` — the production cadence: only drifted
  rows are rebuilt, so this is the cost that matters at scale),
* **bytes/round** for the same sweep's deltas encoded as a dense PUSH
  frame vs a sparse PUSH_SPARSE frame (DESIGN.md §12), plus a parity bit
  asserting the sparse frame densifies back bit-exactly.

The largest quick point is (V=65536, K=256).  At that size the full
dense alias build (vmapped ``core.alias.build``) costs minutes on the
CPU CI container, so points above ``_FULL_BUILD_MAX_V`` substitute
synthetic uniform proposal tables (prob=1, alias=self — a valid alias
table) over the real ``dense_probs`` staleness snapshot; throughput is
unaffected because the sweep's cost does not depend on table *values*.

Artifact: ``BENCH_scale.json`` — gated for completeness by tools/ci.sh.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import alias as alias_mod
from repro.core import family as fam_mod
from repro.core import lda
from repro.core import ps
from repro.data.synthetic import CorpusConfig, make_topic_corpus
from repro.kernels import alias_build as ab
from repro.net import protocol

from benchmarks import common

# (vocab, n_topics, tile_k); the ladder ends at the §6.3 scale target.
QUICK_POINTS = ((1024, 64, 16), (8192, 128, 32), (65536, 256, 64))
FULL_POINTS = ((4096, 128, 32), (32768, 256, 64), (131072, 512, 64))

# Above this vocab the full dense alias build is replaced by synthetic
# uniform tables (see module docstring); the incremental row builder is
# still measured for real at every point.
_FULL_BUILD_MAX_V = 8192


def _uniform_tables(v: int, k: int) -> alias_mod.AliasTable:
    """A valid alias table encoding the uniform distribution per row."""
    return alias_mod.AliasTable(
        prob=jnp.ones((v, k), jnp.float32),
        alias=jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32), (v, k)),
        mass=jnp.full((v,), float(k), jnp.float32))


def _delta_frames(deltas: dict[str, np.ndarray], n_rows: int
                  ) -> tuple[int, int, bool]:
    """Encode one client's sweep deltas as dense PUSH vs sparse
    PUSH_SPARSE frames; return (dense_bytes, sparse_bytes, parity)."""
    meta = {"round": 0, "client": 0}
    dense = protocol.pack_frame(protocol.MsgType.PUSH, meta, deltas)

    sp = ps.to_sparse_delta(deltas)
    rows = np.asarray(sp.rows).astype(np.uint32)
    arrays = {"rows": rows}
    arrays.update({n: np.ascontiguousarray(np.asarray(v))
                   for n, v in sp.values.items()})
    sparse = protocol.pack_frame(
        protocol.MsgType.PUSH_SPARSE,
        {**meta, "n_rows": n_rows, "sparse": sorted(sp.values)}, arrays)

    parity = True
    for n, v in deltas.items():
        densified = np.zeros_like(v)
        densified[rows] = np.asarray(sp.values[n])
        parity = parity and bool(np.array_equal(densified, v))
    return len(dense), len(sparse), parity


def _measure_point(vocab: int, n_topics: int, tile_k: int, *,
                   n_docs: int, doc_len: int) -> dict:
    tokens, mask, _ = make_topic_corpus(CorpusConfig(
        n_topics=8, vocab_size=vocab, n_docs=n_docs, doc_len=doc_len,
        seed=5))
    tokens, mask = jnp.asarray(tokens), jnp.asarray(mask)
    n_tokens = float(np.asarray(mask).sum())

    # Cap the grid explicitly: interpret mode unrolls every grid program
    # at trace time, so tile_v must not be allowed to collapse to the
    # VMEM-budget default at large V (which would mean hundreds of
    # programs and a trace-bound measurement).
    tile_v = max(vocab // 8, 128)
    bp = tokens.size
    cfg = lda.LDAConfig(n_topics=n_topics, vocab_size=vocab,
                        tile_k=tile_k, tile_v=tile_v,
                        tile_b=min(1024, bp), sorted_chunks=1)
    fam = fam_mod.family_of(cfg)
    key = jax.random.PRNGKey(0)
    local, shared = fam.init_state(cfg, tokens, mask, key)
    lays = fam.build_sorted_layouts(cfg, tokens, mask)

    if vocab <= _FULL_BUILD_MAX_V:
        with common.Timer() as t_build:
            tables, stale = fam.build_alias(cfg, shared)
            jax.block_until_ready(tables.prob)
        full_build_s = t_build.elapsed
    else:
        tables = _uniform_tables(vocab, n_topics)
        stale = lda.dense_probs(cfg, shared)
        jax.block_until_ready(stale)
        full_build_s = None

    # Two reps: the first compiles, the second is the warm number.
    sweep_key = jax.random.fold_in(key, 1)
    deltas = None
    for _ in range(2):
        with common.Timer() as t_sweep:
            _, deltas = fam.sweep_sorted(cfg, local, shared, tables, stale,
                                         tokens, mask, sweep_key, lays)
            jax.block_until_ready(deltas["n_wk"])
    tokens_per_s = n_tokens / max(t_sweep.elapsed, 1e-9)

    # Incremental alias rebuild over a batch of drifted rows — the
    # production producer cost (kernels.alias_build_rows, K-tiled).
    n_rows = min(vocab, 256)
    p_rows = jax.random.uniform(key, (n_rows, n_topics)) + 1e-3
    for _ in range(2):
        with common.Timer() as t_rows:
            prob, _, _ = ab.alias_build_rows(p_rows, tile_r=8, tile_k=tile_k)
            jax.block_until_ready(prob)
    alias_ms_per_row = t_rows.elapsed * 1e3 / n_rows

    np_deltas = {n: np.asarray(v) for n, v in deltas.items()
                 if np.asarray(v).ndim >= 1 and
                 np.asarray(v).shape[0] == vocab}
    dense_b, sparse_b, parity = _delta_frames(np_deltas, vocab)

    nb = -(-bp // cfg.tile_b)
    return {
        "vocab": vocab, "n_topics": n_topics,
        "tile_v": tile_v, "tile_k": tile_k, "tile_b": cfg.tile_b,
        "grid": [nb, vocab // tile_v, n_topics // tile_k],
        "table_tile_elems": tile_v * tile_k,
        "table_tile_elems_untiled": tile_v * n_topics,
        "n_tokens": n_tokens,
        "tokens_per_s": tokens_per_s,
        "sweep_s": t_sweep.elapsed,
        "full_alias_build_s": full_build_s,
        "alias_build_ms_per_row": alias_ms_per_row,
        "alias_rows_batch": n_rows,
        "bytes_per_round": {
            "dense": dense_b, "sparse": sparse_b,
            "ratio": dense_b / max(sparse_b, 1),
        },
        "sparse_parity": parity,
    }


def run(quick: bool = True) -> None:
    points = QUICK_POINTS if quick else FULL_POINTS
    n_docs, doc_len = (48, 16) if quick else (256, 32)
    artifact: dict = {"quick": quick, "n_docs": n_docs, "doc_len": doc_len,
                      "points": []}
    for vocab, n_topics, tile_k in points:
        t0 = time.perf_counter()
        entry = _measure_point(vocab, n_topics, tile_k,
                               n_docs=n_docs, doc_len=doc_len)
        entry["point_s"] = time.perf_counter() - t0
        artifact["points"].append(entry)
        if not entry["sparse_parity"]:
            raise AssertionError(
                f"sparse delta frame at V={vocab} did not densify "
                "back bit-exactly")
        common.emit("scale", vocab=vocab, n_topics=n_topics,
                    tile_k=tile_k,
                    tokens_per_s=entry["tokens_per_s"],
                    alias_build_ms_per_row=entry["alias_build_ms_per_row"],
                    bytes_dense=entry["bytes_per_round"]["dense"],
                    bytes_sparse=entry["bytes_per_round"]["sparse"],
                    bytes_ratio=entry["bytes_per_round"]["ratio"])

    artifact["max_point"] = {"vocab": max(p["vocab"] for p in
                                          artifact["points"]),
                             "n_topics": max(p["n_topics"] for p in
                                             artifact["points"])}
    common.write_artifact("scale", artifact)


if __name__ == "__main__":
    run(quick=True)
