"""Paper Fig 6 — scaling with the client-group size (paper: 6000 clients /
5B documents; CPU-scaled).  AliasLDA runs the same corpus sharded over 1, 2,
4, 8 clients and reports document log-likelihood convergence and aggregate
token throughput.  The paper's observation to reproduce: the relaxed
consistency model keeps convergence nearly independent of the client count
(small variance across clients), while throughput scales with clients."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lda

from benchmarks import common


def doc_loglik(cfg, shared, tokens, mask, key) -> float:
    """Per-token document log-likelihood (Fig 6's y-axis)."""
    ppl = lda.perplexity(cfg, shared, tokens, mask, key)
    return -float(jnp.log(ppl))


def run(quick: bool = True) -> None:
    tokens, mask, _, ccfg = common.default_corpus(quick, seed=4)
    cfg = lda.LDAConfig(n_topics=ccfg.n_topics, vocab_size=ccfg.vocab_size,
                        alpha=0.1, beta=0.01, mh_steps=2)
    n_rounds = 10 if quick else 20
    finals = {}
    for n_clients in ((1, 4) if quick else (1, 2, 4, 8)):
        res = common.run_multiclient(
            cfg, tokens, mask, n_clients=n_clients, n_rounds=n_rounds,
            method="mhw", eval_every=max(1, n_rounds // 4))
        ll = -float(jnp.log(jnp.asarray(res.perplexities[-1])))
        finals[n_clients] = ll
        # Aggregate throughput: each client sweeps its shard concurrently in
        # production — wall-time there is the per-client time, so aggregate
        # tokens/s multiplies by the client count.
        per_client_t = (sum(res.iter_times[1:])
                        / max(len(res.iter_times) - 1, 1)) / n_clients
        common.emit("lda_fig6_scaling", clients=n_clients,
                    doc_loglik_final=ll,
                    agg_tokens_per_s=res.tokens / max(per_client_t, 1e-9),
                    perplexity_final=res.perplexities[-1])
    lls = list(finals.values())
    common.emit("lda_fig6_summary",
                loglik_spread=max(lls) - min(lls),
                consistent=int(max(lls) - min(lls) < 0.35))


if __name__ == "__main__":
    run(quick=False)
