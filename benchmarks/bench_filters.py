"""Paper §5.3 — communication filters: bytes on the wire vs convergence.

LDA runs with the dense push, the magnitude-priority top-k filter (+ uniform
anti-starvation rows), and a threshold filter.  Reported: estimated sync
bytes per round per client, final perplexity, and the compression ratio.
The paper's claim: filtered synchronization preserves convergence at a
fraction of the traffic."""

from __future__ import annotations

from repro.core import lda, ps

from benchmarks import common


def run(quick: bool = True) -> None:
    tokens, mask, _, ccfg = common.default_corpus(quick, seed=6)
    cfg = lda.LDAConfig(n_topics=ccfg.n_topics, vocab_size=ccfg.vocab_size,
                        alpha=0.1, beta=0.01, mh_steps=2)
    n_rounds = 10 if quick else 20
    dense_bytes = ccfg.vocab_size * ccfg.n_topics * 4

    variants = [
        ("dense", ps.FilterSpec()),
        ("topk", ps.FilterSpec(kind="topk", k_rows=ccfg.vocab_size // 8,
                               random_rows=ccfg.vocab_size // 32)),
        ("topk_small", ps.FilterSpec(kind="topk",
                                     k_rows=ccfg.vocab_size // 32,
                                     random_rows=ccfg.vocab_size // 64)),
        ("threshold", ps.FilterSpec(kind="threshold", threshold=2.0)),
    ]
    base_ppl = None
    for label, spec in variants:
        res = common.run_multiclient(
            cfg, tokens, mask, n_clients=4, n_rounds=n_rounds,
            method="mhw", filter_spec=spec,
            eval_every=max(1, n_rounds // 4))
        if spec.kind == "topk":
            rows = spec.k_rows + spec.random_rows
            wire = rows * (ccfg.n_topics * 4 + 4)
        else:
            wire = dense_bytes
        ppl = res.perplexities[-1]
        if label == "dense":
            base_ppl = ppl
        common.emit("filters_53", filter=label,
                    wire_bytes_per_round=wire,
                    compression_x=dense_bytes / wire,
                    perplexity_final=ppl,
                    ppl_vs_dense=ppl / base_ppl)


if __name__ == "__main__":
    run(quick=False)
