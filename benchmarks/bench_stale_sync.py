"""Beyond-paper transfer — the PS communication pattern applied to LM
training (train/sync.py): stale-synchronous gradient sync with top-k
magnitude filtering + error feedback, vs fully-synchronous SGD.

A small transformer trains on a learnable synthetic stream under three sync
regimes; reported: final loss and estimated sync traffic.  The claim being
quantified: bounded staleness + filtered deltas (the paper's eventual-
consistency design) trades a small convergence delay for a large traffic
cut — on gradients, exactly as it does on sufficient statistics."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import reduced
from repro.configs.registry import ARCHITECTURES
from repro.core import ps
from repro.data.synthetic import lm_batches
from repro.models import model as model_lib
from repro.optim import adamw
from repro.train import sync as sync_lib
from repro.train.train_step import TrainConfig, loss_fn

from benchmarks import common


def run(quick: bool = True) -> None:
    cfg = reduced(ARCHITECTURES["qwen2-1.5b"]).replace(vocab_size=256)
    tcfg = TrainConfig(peak_lr=1e-3, warmup=5, total_steps=200,
                       loss_chunk=32)
    n_steps = 20 if quick else 60
    n_clients = 2
    batch, seq = 8, 32

    grad_fn = jax.jit(jax.value_and_grad(
        lambda p, b: loss_fn(cfg, tcfg, p, b)[0]))

    variants = [
        ("sync_dense", sync_lib.SyncConfig(sync_every=1, filter=ps.FilterSpec())),
        ("stale2_topk", sync_lib.SyncConfig(
            sync_every=2, filter=ps.FilterSpec(kind="topk", k_rows=64,
                                               random_rows=16))),
        ("stale4_topk", sync_lib.SyncConfig(
            sync_every=4, filter=ps.FilterSpec(kind="topk", k_rows=64,
                                               random_rows=16))),
    ]

    for label, scfg in variants:
        key = jax.random.PRNGKey(0)
        params = model_lib.init_params(cfg, key)
        opt = adamw.init(params)
        residuals = [jax.tree.map(jnp.zeros_like, params)
                     for _ in range(n_clients)]
        data = lm_batches(cfg.vocab_size, batch * n_clients, seq,
                          n_steps, seed=11, kind="affine")
        losses = []
        for step, full_batch in enumerate(data):
            toks = full_batch["tokens"]
            shard = toks.shape[0] // n_clients
            grads_sum = None
            for c in range(n_clients):
                b = {"tokens": jnp.asarray(toks[c * shard:(c + 1) * shard])}
                l, g = grad_fn(params, b)
                losses.append(float(l))
                residuals[c] = jax.tree.map(jnp.add, residuals[c], g)
            if (step + 1) % scfg.sync_every == 0:
                # filtered push from every client; psum == sum here
                for c in range(n_clients):
                    kf = jax.random.fold_in(key, step * 31 + c)
                    sent = sync_lib.filter_tree(residuals[c], scfg.filter, kf)
                    residuals[c] = jax.tree.map(
                        lambda r, s: r - s, residuals[c], sent)
                    grads_sum = sent if grads_sum is None else jax.tree.map(
                        jnp.add, grads_sum, sent)
                grads = jax.tree.map(
                    lambda g: g / (n_clients * scfg.sync_every), grads_sum)
                lr = adamw.cosine_schedule(
                    opt.step, peak_lr=tcfg.peak_lr, warmup=tcfg.warmup,
                    total=tcfg.total_steps)
                params, opt = adamw.update(
                    params, grads, opt, lr=lr,
                    weight_decay=tcfg.weight_decay, grad_clip=tcfg.grad_clip)
        dense_b, filt_b = sync_lib.sync_bytes_estimate(params, scfg.filter)
        per_step_traffic = filt_b / scfg.sync_every
        common.emit("stale_sync", variant=label,
                    loss_first=float(np.mean(losses[:n_clients * 2])),
                    loss_final=float(np.mean(losses[-n_clients * 2:])),
                    sync_bytes_per_step=per_step_traffic,
                    traffic_vs_dense=per_step_traffic / dense_b)


if __name__ == "__main__":
    run(quick=False)
