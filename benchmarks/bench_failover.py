"""Paper §5.4 — failure and recovery robustness.

One client of four "fails" (its pushes are lost) for a window of rounds and
then recovers, continuing from its snapshot against the freshly-pulled
shared state — the client-failover protocol.  The run must converge to a
perplexity comparable with the no-failure run (the paper's production
requirement: pre-emption is routine on the shared cluster)."""

from __future__ import annotations

from repro.core import lda

from benchmarks import common


def run(quick: bool = True) -> None:
    tokens, mask, _, ccfg = common.default_corpus(quick, seed=7)
    cfg = lda.LDAConfig(n_topics=ccfg.n_topics, vocab_size=ccfg.vocab_size,
                        alpha=0.1, beta=0.01, mh_steps=2)
    n_rounds = 12 if quick else 24

    baseline = common.run_multiclient(
        cfg, tokens, mask, n_clients=4, n_rounds=n_rounds,
        method="mhw", eval_every=max(1, n_rounds // 4))
    failed = common.run_multiclient(
        cfg, tokens, mask, n_clients=4, n_rounds=n_rounds,
        method="mhw", eval_every=max(1, n_rounds // 4),
        drop_client=(1, n_rounds // 4, n_rounds // 2))

    common.emit("failover_54", variant="baseline",
                perplexity_final=baseline.perplexities[-1])
    common.emit("failover_54", variant="client1_fails",
                perplexity_final=failed.perplexities[-1],
                degradation=failed.perplexities[-1]
                / baseline.perplexities[-1])


if __name__ == "__main__":
    run(quick=False)
