"""Paper §5.4 — failure and recovery robustness (kill-and-rejoin).

One client of four crashes for a window of rounds and rejoins from its
last periodic snapshot (locals restored, read-my-writes lag reset, forced
fresh pull — the ``core.fault`` + ``Trainer.restore`` machinery).  The
run must recover: after the rejoin the perplexity trajectory re-converges
onto the no-failure baseline and the final perplexity degrades by at most
5% — the paper's production requirement, since pre-emption is routine on
the shared cluster.

Measured per consistency policy (BSP, SSP(2), async):

* ``recovery_rounds`` — rounds after the rejoin until held-out perplexity
  is back within 2% of the baseline trajectory at the same round;
* ``degradation`` — relative final-perplexity gap vs the baseline run.

The ``tcp`` section repeats the exercise over the wire (DESIGN.md §13)
with *process*-level kills: a BSP loopback run through chaos proxies
(connection drop on the push path) in which one shard-server process is
killed and restarted from its snapshot and one worker process is killed
and relaunched with ``--restore``.  Recorded there:

* ``bsp_bitexact`` — the parity bit: final per-stat checksums of the
  disturbed tcp run equal the undisturbed in-process run's;
* ``recovery_rounds`` — rounds the restarted worker re-executed beyond
  its kill point (0 = resumed at exactly the snapshotted round);
* ``degradation`` — relative final-perplexity gap vs the in-process
  baseline (0 when bit-exact, by construction).

Artifact: ``BENCH_failover.json``.
"""

from __future__ import annotations

import tempfile

from repro.core import lda
from repro.core.fault import FaultPlan
from repro.engine import Trainer, TrainerConfig

from benchmarks import common

POLICIES = {"bsp": "bsp", "ssp2": "ssp:2", "async": "async"}

N_CLIENTS = 4
KILLED = 1
RECOVERY_BAND = 0.02
MAX_DEGRADATION = 0.05


def _run(cfg, tokens, mask, consistency: str, n_rounds: int, *,
         fault_plan=None, snapshot_dir=None) -> tuple[list[float], Trainer]:
    tcfg = TrainerConfig(
        n_clients=N_CLIENTS, method="mhw", consistency=consistency,
        fault_plan=fault_plan,
        snapshot_every=2 if snapshot_dir else 0,
        snapshot_dir=snapshot_dir)
    trainer = Trainer(cfg, tokens, mask, config=tcfg)
    res = trainer.run(n_rounds, eval_every=1, eval_docs=32)
    return res.perplexities, trainer


def _recovery_rounds(base: list[float], killed: list[float],
                     rejoin_round: int) -> int:
    """Rounds after the rejoin until the killed run's per-round perplexity
    re-enters a ±RECOVERY_BAND band around the baseline trajectory (and
    stays there for the remainder, so a single lucky round doesn't count
    as recovered)."""
    n = len(base)
    for r in range(rejoin_round, n):
        if all(killed[s] <= base[s] * (1.0 + RECOVERY_BAND)
               for s in range(r, n)):
            return r - rejoin_round
    return n - rejoin_round


def _tcp_failover(quick: bool) -> dict:
    """Kill-and-rejoin over the wire: real processes, chaos proxy,
    shard restart from snapshot, worker restart from snapshot."""
    from repro.core.fault import FaultEvent, FaultPlan
    from repro.launch.loopback import _reference_run, launch_failover

    n_rounds = 6 if quick else 10
    kill_client_round, kill_server_round = 2, 3
    # Connection ordinal 0 loses its round-1 push on the wire (frame 5)
    # and recovers through idempotent replay.
    plan = FaultPlan.scripted(
        FaultEvent("conn_drop", client=0, start=5, stop=6, period=1))
    res = launch_failover(
        client_sets=((0,), (1,)), n_rounds=n_rounds,
        kill_server_round=kill_server_round,
        kill_client=1, kill_client_round=kill_client_round,
        chaos_plan=plan, timeout=420.0)
    assert res.ok, \
        f"tcp failover run failed: {[(p.name, p.returncode) for p in res.failures()]}" \
        f" diagnostics={res.diagnostics}"
    assert res.restarts == {"server": 1, "client": 1}, \
        f"expected one shard and one worker restart, got {res.restarts}"

    finals = [p for p in res.clients if p.returncode == 0 and p.result]
    ref = _reference_run(n_rounds)
    sums = [p.result["checksums"] for p in finals]
    bitexact = bool(sums) and all(s == ref["checksums"] for s in sums)
    victim = next(p for p in finals if p.result["restored"])
    # Rounds re-executed beyond the kill point: 0 means the restarted
    # worker resumed at exactly the round its snapshot recorded.
    recovery = (kill_client_round + victim.result["rounds_done"]) - n_rounds
    degradation = victim.result["perplexity"] / ref["perplexity"] - 1.0
    assert bitexact, \
        f"disturbed tcp run diverged from in-process: {sums} vs " \
        f"{ref['checksums']}"
    assert degradation <= MAX_DEGRADATION, \
        f"tcp: final perplexity degraded {degradation:.3f}"

    section = {
        "n_rounds": n_rounds,
        "kill_client_round": kill_client_round,
        "kill_server_round": kill_server_round,
        "restarts": res.restarts,
        "conn_drops": sum(p["actions"]["conn_drop"] for p in res.proxies),
        "retries": sum(p.result["counters"]["retries"] for p in finals),
        "bsp_bitexact": bitexact,
        "recovery_rounds": recovery,
        "degradation": degradation,
        "perplexity_final": victim.result["perplexity"],
        "perplexity_baseline": ref["perplexity"],
    }
    common.emit("failover_54", policy="bsp", variant="tcp_kill_rejoin",
                perplexity_final=victim.result["perplexity"],
                recovery_rounds=recovery, degradation=degradation,
                bsp_bitexact=int(bitexact),
                restarts_server=res.restarts["server"],
                restarts_client=res.restarts["client"],
                conn_drops=section["conn_drops"])
    return section


def run(quick: bool = True) -> None:
    tokens, mask, _, ccfg = common.default_corpus(quick, seed=7)
    cfg = lda.LDAConfig(n_topics=ccfg.n_topics, vocab_size=ccfg.vocab_size,
                        alpha=0.1, beta=0.01, mh_steps=2)
    n_rounds = 12 if quick else 24
    crash_start, crash_stop = n_rounds // 4, n_rounds // 2
    plan = FaultPlan.crash(KILLED, crash_start, crash_stop)

    artifact: dict = {
        "n_clients": N_CLIENTS, "n_rounds": n_rounds,
        "killed_client": KILLED,
        "crash_window": [crash_start, crash_stop],
        "policies": {},
    }

    for label, consistency in POLICIES.items():
        base_ppl, _ = _run(cfg, tokens, mask, consistency, n_rounds)
        with tempfile.TemporaryDirectory() as snap_dir:
            kill_ppl, trainer = _run(cfg, tokens, mask, consistency,
                                     n_rounds, fault_plan=plan,
                                     snapshot_dir=snap_dir)
        assert trainer.rejoins == 1, \
            f"{label}: expected exactly one rejoin, got {trainer.rejoins}"

        degradation = kill_ppl[-1] / base_ppl[-1] - 1.0
        recovery = _recovery_rounds(base_ppl, kill_ppl, crash_stop)
        assert degradation <= MAX_DEGRADATION, \
            f"{label}: final perplexity degraded {degradation:.3f} " \
            f"(> {MAX_DEGRADATION}) after kill-and-rejoin"

        artifact["policies"][label] = {
            "baseline": {"perplexity_final": base_ppl[-1],
                         "perplexity_per_round": base_ppl},
            "kill_rejoin": {"perplexity_final": kill_ppl[-1],
                            "perplexity_per_round": kill_ppl,
                            "rejoin_round": crash_stop,
                            "recovery_rounds": recovery,
                            "degradation": degradation},
        }
        common.emit("failover_54", policy=label, variant="baseline",
                    perplexity_final=base_ppl[-1])
        common.emit("failover_54", policy=label, variant="kill_rejoin",
                    perplexity_final=kill_ppl[-1],
                    recovery_rounds=recovery, degradation=degradation)

    artifact["tcp"] = _tcp_failover(quick)

    common.write_artifact("failover", artifact)


if __name__ == "__main__":
    run(quick=False)
