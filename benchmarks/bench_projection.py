"""Paper Fig 8 — effect of parameter projection under relaxed consistency.

The PDP runs with multiple clients and τ=2 local sweeps between syncs (the
bounded-staleness regime where per-client replicas drift and pushed deltas
violate the polytope constraints, exactly paper Fig 3's scenario), once
WITH the distributed projection (Algorithm 2) and once WITHOUT.  Without
projection the violation count grows and perplexity degrades/diverges —
the paper's headline robustness result."""

from __future__ import annotations

import math

from repro.core import pdp

from benchmarks import common


def run(quick: bool = True) -> None:
    tokens, mask, _, ccfg = common.default_corpus(quick, seed=3)
    cfg = pdp.PDPConfig(n_topics=ccfg.n_topics, vocab_size=ccfg.vocab_size,
                        alpha=0.1, discount=0.1, concentration=5.0,
                        mh_steps=4, stirling_n_max=256)
    n_rounds = 10 if quick else 24

    final = {}
    for project in (True, False):
        res = common.run_multiclient(
            cfg, tokens, mask, n_clients=4, n_rounds=n_rounds, tau=2,
            method="mhw", eval_every=max(1, n_rounds // 4),
            project_every=1 if project else 0)
        label = "with_projection" if project else "no_projection"
        ppl = res.perplexities[-1]
        final[label] = ppl
        common.emit(
            "projection_fig8", variant=label,
            perplexity_first=res.perplexities[0],
            perplexity_final=ppl if math.isfinite(ppl) else float("inf"),
            violations_final=res.violations[-1],
            diverged=int(not math.isfinite(ppl)))
    better = (not math.isfinite(final["no_projection"])
              or final["with_projection"] <= final["no_projection"] * 1.02)
    common.emit("projection_fig8_summary",
                projection_helps=int(better))


if __name__ == "__main__":
    run(quick=False)
