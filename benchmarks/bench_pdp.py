"""Paper Fig 5 — PDP (Pitman-Yor topic model) convergence on the client
group, with the constraint projection active (the paper's production
configuration).  Reports perplexity, topics/word, iteration time, and the
constraint-violation count *before* each projection (it must be driven to
zero by the projector, not absent by construction)."""

from __future__ import annotations

from repro.core import pdp

from benchmarks import common


def run(quick: bool = True) -> None:
    tokens, mask, _, ccfg = common.default_corpus(quick, seed=1)
    cfg = pdp.PDPConfig(n_topics=ccfg.n_topics, vocab_size=ccfg.vocab_size,
                        alpha=0.1, discount=0.1, concentration=5.0,
                        mh_steps=4, stirling_n_max=256)
    n_clients = 4
    n_rounds = 10 if quick else 25

    for method in ("mhw", "exact"):
        hooks = common.pdp_hooks(cfg, project=True)
        res = common.run_multiclient(
            hooks, tokens, mask, n_clients=n_clients, n_rounds=n_rounds,
            method=method, eval_every=max(1, n_rounds // 4))
        common.emit(
            "pdp_fig5", sampler=f"alias_pdp[{method}]", clients=n_clients,
            perplexity_first=res.perplexities[0],
            perplexity_final=res.perplexities[-1],
            topics_per_word_final=res.topics_per_word[-1],
            violations_final=res.violations[-1],
            s_per_iter=sum(res.iter_times[1:]) / max(len(res.iter_times) - 1, 1),
            tokens_per_s=res.tokens_per_s)


if __name__ == "__main__":
    run(quick=False)
