"""Paper Fig 5 — PDP (Pitman-Yor topic model) convergence on the client
group, with the constraint projection active (the paper's production
configuration), driven by ``engine.Trainer``.  Reports perplexity,
topics/word, iteration time, and the constraint-violation count.

Also benchmarks the token-sorted tile-skipping layout against the scan
oracle for PDP's 2K joint outcome space (``--layout sorted`` equivalent:
both layouts always run) and writes the ``BENCH_pdp.json`` artifact so the
sorted-path speedup for this family is diffable across PRs, mirroring
``BENCH_throughput.json`` for LDA.
"""

from __future__ import annotations

from repro.core import pdp

from benchmarks import common


def _model_cfg(ccfg) -> pdp.PDPConfig:
    return pdp.PDPConfig(n_topics=ccfg.n_topics, vocab_size=ccfg.vocab_size,
                         alpha=0.1, discount=0.1, concentration=5.0,
                         mh_steps=4, stirling_n_max=256)


def run(quick: bool = True) -> None:
    tokens, mask, _, ccfg = common.default_corpus(quick, seed=1)
    cfg = _model_cfg(ccfg)
    n_clients = 4
    n_rounds = 10 if quick else 25
    artifact: dict = {"quick": quick, "n_topics": ccfg.n_topics,
                      "vocab": ccfg.vocab_size}

    for method in ("mhw", "exact"):
        res = common.run_multiclient(
            cfg, tokens, mask, n_clients=n_clients, n_rounds=n_rounds,
            method=method, eval_every=max(1, n_rounds // 4))
        common.emit(
            "pdp_fig5", sampler=f"alias_pdp[{method}]", clients=n_clients,
            perplexity_first=res.perplexities[0],
            perplexity_final=res.perplexities[-1],
            topics_per_word_final=res.topics_per_word[-1],
            violations_final=res.violations[-1],
            s_per_iter=sum(res.iter_times[1:]) / max(len(res.iter_times) - 1, 1),
            tokens_per_s=res.tokens_per_s)

    # Sorted fast path vs scan oracle (single client: the per-sweep layout
    # comparison; multi-client convergence numbers above are the fig).
    common.layout_speedup_artifact("pdp", cfg, tokens, mask,
                                   artifact=artifact,
                                   n_rounds=6 if quick else 10)


if __name__ == "__main__":
    run(quick=False)
