"""Wire-transport benchmark (DESIGN.md §11): in-process vs loopback TCP.

For each consistency policy, runs the same Trainer configuration over the
in-process ParameterServer and over ``transport="tcp"`` against threaded
:class:`repro.net.server.ShardServer` shards on loopback, and reports:

* rounds/s for both transports (the cost of crossing the socket),
* bytes moved per round (both directions, summed over shard servers),
* RPC latency percentiles (p50/p99) from the client-side counters,
* a BSP bit-exactness parity bit (checksum equality with in-process —
  the §11 acceptance criterion, re-verified on every bench run).

Artifact: ``BENCH_wire.json`` — gated for completeness by tools/ci.sh.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import family as fam_mod
from repro.core.lda import LDAConfig
from repro.engine import Trainer, TrainerConfig
from repro.net.client import _checksum
from repro.net.server import serve_shards

from benchmarks import common

POLICIES = {"bsp": "bsp", "ssp2": "ssp:2"}


def _stats_checksums(trainer) -> dict[str, str]:
    fam = fam_mod.get("lda")
    return {n: _checksum(np.asarray(v))
            for n, v in fam.stats_dict(trainer.shared).items()}


def _time_rounds(trainer, rounds: int) -> float:
    trainer.step()          # warm-up: compile + first alias build
    trainer._sync()
    t0 = time.perf_counter()
    for _ in range(rounds):
        trainer.step()
    trainer._sync()
    return rounds / (time.perf_counter() - t0)


def run(quick: bool = True) -> None:
    vocab, n_topics = (64, 4) if quick else (2048, 64)
    n_docs, doc_len = (16, 12) if quick else (256, 64)
    rounds = 4 if quick else 16
    n_clients, n_shards = 2, 2

    from repro.data.synthetic import CorpusConfig, make_topic_corpus
    tokens, mask, _ = make_topic_corpus(CorpusConfig(
        n_topics=n_topics, vocab_size=vocab, n_docs=n_docs,
        doc_len=doc_len, seed=3))
    cfg = LDAConfig(n_topics=n_topics, vocab_size=vocab)
    key = jax.random.PRNGKey(0)

    artifact: dict = {"quick": quick, "vocab": vocab, "n_topics": n_topics,
                      "n_clients": n_clients, "n_shards": n_shards,
                      "rounds": rounds, "policies": {}, "parity": {}}

    for label, policy in POLICIES.items():
        inproc = Trainer(cfg, tokens, mask, key=key,
                         config=TrainerConfig(n_clients=n_clients, tau=1,
                                              consistency=policy))
        rps_inproc = _time_rounds(inproc, rounds)
        inproc_sums = _stats_checksums(inproc)

        servers = serve_shards("lda", vocab_size=vocab,
                               n_clients=n_clients, n_shards=n_shards,
                               consistency=policy, barrier_timeout=120.0)
        addrs = tuple("%s:%d" % s.address for s in servers)
        try:
            tcp = Trainer(cfg, tokens, mask, key=key,
                          config=TrainerConfig(n_clients=n_clients, tau=1,
                                               consistency=policy,
                                               transport="tcp",
                                               server_addrs=addrs))
            rps_tcp = _time_rounds(tcp, rounds)
            tcp_sums = _stats_checksums(tcp)
            counters = tcp.remote.counters()
            tcp.close()
        finally:
            for s in servers:
                s.close()

        total_rounds = rounds + 1  # incl. warm-up
        bytes_per_round = ((counters["bytes_in"] + counters["bytes_out"])
                           / total_rounds)
        entry = {
            "rounds_per_s": {"inproc": rps_inproc, "tcp": rps_tcp},
            "bytes_per_round": bytes_per_round,
            "rpc_latency_ms": {"p50": counters["rpc_p50_ms"],
                               "p99": counters["rpc_p99_ms"]},
            "rpc_count": counters["rpc_count"],
        }
        artifact["policies"][label] = entry
        if label == "bsp":
            artifact["parity"]["bsp_bitexact"] = inproc_sums == tcp_sums
        common.emit("wire", policy=label,
                    rounds_per_s_inproc=rps_inproc,
                    rounds_per_s_tcp=rps_tcp,
                    bytes_per_round=bytes_per_round,
                    rpc_p50_ms=counters["rpc_p50_ms"],
                    rpc_p99_ms=counters["rpc_p99_ms"])

    if not artifact["parity"]["bsp_bitexact"]:
        raise AssertionError(
            "BSP over loopback TCP diverged from the in-process result")
    common.write_artifact("wire", artifact)


if __name__ == "__main__":
    run(quick=True)
