"""Wire-transport benchmark (DESIGN.md §11-12): in-process vs loopback TCP.

For each consistency policy, runs the same Trainer configuration over the
in-process ParameterServer and over ``transport="tcp"`` against threaded
:class:`repro.net.server.ShardServer` shards on loopback, and reports:

* rounds/s for both transports (the cost of crossing the socket),
* bytes moved per round, split into **encoded** bytes (full frames,
  headers included — what the socket carries) and **payload** bytes
  (meta+npz sections only — what the application ships), both directions
  summed over shard servers,
* RPC latency percentiles (p50/p99) from the client-side counters,
* a BSP bit-exactness parity bit (checksum equality with in-process —
  the §11 acceptance criterion, re-verified on every bench run).

A second section measures the **sparse delta exchange** (DESIGN.md §12)
on a zipf corpus whose vocabulary dwarfs the per-round touched rows:
the same BSP run with ``sparse_push`` on vs off, reporting the
client→server payload bytes/round for both and the reduction ratio
(the §12 acceptance criterion: ≥ 5×), plus a checksum parity bit
(sparse BSP must land bit-exactly on the dense result).

Artifact: ``BENCH_wire.json`` — gated for completeness by tools/ci.sh.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import family as fam_mod
from repro.core.lda import LDAConfig
from repro.engine import Trainer, TrainerConfig
from repro.net.client import _checksum
from repro.net.server import serve_shards

from benchmarks import common

POLICIES = {"bsp": "bsp", "ssp2": "ssp:2"}


def _stats_checksums(trainer) -> dict[str, str]:
    fam = fam_mod.get("lda")
    return {n: _checksum(np.asarray(v))
            for n, v in fam.stats_dict(trainer.shared).items()}


def _time_rounds(trainer, rounds: int) -> float:
    trainer.step()          # warm-up: compile + first alias build
    trainer._sync()
    t0 = time.perf_counter()
    for _ in range(rounds):
        trainer.step()
    trainer._sync()
    return rounds / (time.perf_counter() - t0)


def run(quick: bool = True) -> None:
    vocab, n_topics = (64, 4) if quick else (2048, 64)
    n_docs, doc_len = (16, 12) if quick else (256, 64)
    rounds = 4 if quick else 16
    n_clients, n_shards = 2, 2

    from repro.data.synthetic import CorpusConfig, make_topic_corpus
    tokens, mask, _ = make_topic_corpus(CorpusConfig(
        n_topics=n_topics, vocab_size=vocab, n_docs=n_docs,
        doc_len=doc_len, seed=3))
    cfg = LDAConfig(n_topics=n_topics, vocab_size=vocab)
    key = jax.random.PRNGKey(0)

    artifact: dict = {"quick": quick, "vocab": vocab, "n_topics": n_topics,
                      "n_clients": n_clients, "n_shards": n_shards,
                      "rounds": rounds, "policies": {}, "parity": {}}

    for label, policy in POLICIES.items():
        inproc = Trainer(cfg, tokens, mask, key=key,
                         config=TrainerConfig(n_clients=n_clients, tau=1,
                                              consistency=policy))
        rps_inproc = _time_rounds(inproc, rounds)
        inproc_sums = _stats_checksums(inproc)

        servers = serve_shards("lda", vocab_size=vocab,
                               n_clients=n_clients, n_shards=n_shards,
                               consistency=policy, barrier_timeout=120.0)
        addrs = tuple("%s:%d" % s.address for s in servers)
        try:
            tcp = Trainer(cfg, tokens, mask, key=key,
                          config=TrainerConfig(n_clients=n_clients, tau=1,
                                               consistency=policy,
                                               transport="tcp",
                                               server_addrs=addrs))
            rps_tcp = _time_rounds(tcp, rounds)
            tcp_sums = _stats_checksums(tcp)
            counters = tcp.remote.counters()
            tcp.close()
        finally:
            for s in servers:
                s.close()

        total_rounds = rounds + 1  # incl. warm-up
        encoded = (counters["bytes_in"] + counters["bytes_out"]) \
            / total_rounds
        payload = (counters["payload_in"] + counters["payload_out"]) \
            / total_rounds
        entry = {
            "rounds_per_s": {"inproc": rps_inproc, "tcp": rps_tcp},
            "bytes_per_round": {"encoded": encoded, "payload": payload},
            "rpc_latency_ms": {"p50": counters["rpc_p50_ms"],
                               "p99": counters["rpc_p99_ms"]},
            "rpc_count": counters["rpc_count"],
        }
        artifact["policies"][label] = entry
        if label == "bsp":
            artifact["parity"]["bsp_bitexact"] = inproc_sums == tcp_sums
        common.emit("wire", policy=label,
                    rounds_per_s_inproc=rps_inproc,
                    rounds_per_s_tcp=rps_tcp,
                    encoded_bytes_per_round=encoded,
                    payload_bytes_per_round=payload,
                    rpc_p50_ms=counters["rpc_p50_ms"],
                    rpc_p99_ms=counters["rpc_p99_ms"])

    if not artifact["parity"]["bsp_bitexact"]:
        raise AssertionError(
            "BSP over loopback TCP diverged from the in-process result")

    # --- sparse delta exchange (DESIGN.md §12) --------------------------
    # A vocabulary much larger than the per-round touched row set — the
    # regime the COO frames exist for.  zipf word frequencies mean each
    # client's sweep touches a few dozen of the 2048 rows, so a dense
    # PUSH ships mostly zeros.
    sv, sk = (2048, 8) if quick else (16384, 64)
    s_docs, s_len = (12, 8) if quick else (128, 32)
    s_rounds = 3 if quick else 8
    s_tokens, s_mask, _ = make_topic_corpus(CorpusConfig(
        n_topics=4, vocab_size=sv, n_docs=s_docs, doc_len=s_len, seed=7))
    s_cfg = LDAConfig(n_topics=sk, vocab_size=sv)

    sums, push_payload, modes = {}, {}, ("dense", "sparse")
    for mode in modes:
        servers = serve_shards("lda", vocab_size=sv, n_clients=n_clients,
                               n_shards=n_shards, consistency="bsp",
                               barrier_timeout=120.0)
        addrs = tuple("%s:%d" % s.address for s in servers)
        try:
            tcp = Trainer(s_cfg, s_tokens, s_mask, key=key,
                          config=TrainerConfig(
                              n_clients=n_clients, tau=1,
                              consistency="bsp", transport="tcp",
                              server_addrs=addrs,
                              sparse_push=(mode == "sparse")))
            # Warm up first so the INIT push (full dense state, one-off)
            # and compile round stay out of the steady-state counters.
            tcp.step()
            tcp._sync()
            before = tcp.remote.counters()["payload_out"]
            for _ in range(s_rounds):
                tcp.step()
            tcp._sync()
            after = tcp.remote.counters()["payload_out"]
            sums[mode] = _stats_checksums(tcp)
            tcp.close()
        finally:
            for s in servers:
                s.close()
        # client→server payload: dominated by the PUSH/PUSH_SPARSE frames
        # (pull requests are O(100) bytes).
        push_payload[mode] = (after - before) / s_rounds

    ratio = push_payload["dense"] / max(push_payload["sparse"], 1e-9)
    parity = sums["dense"] == sums["sparse"]
    artifact["sparse"] = {
        "vocab": sv, "n_topics": sk, "rounds": s_rounds,
        "push_payload_bytes_per_round": dict(push_payload),
        "reduction_ratio": ratio,
    }
    artifact["parity"]["sparse_bitexact"] = parity
    common.emit("wire_sparse", vocab=sv, n_topics=sk,
                dense_push_payload=push_payload["dense"],
                sparse_push_payload=push_payload["sparse"],
                reduction_ratio=ratio)
    if not parity:
        raise AssertionError(
            "sparse_push BSP diverged from the dense-push result")
    if ratio < 5.0:
        raise AssertionError(
            f"sparse push reduced payload only {ratio:.2f}x (< 5x) at "
            f"V={sv}")
    common.write_artifact("wire", artifact)


if __name__ == "__main__":
    run(quick=True)
