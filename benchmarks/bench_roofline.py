"""§Roofline reporting — renders the dry-run JSON (produced by
``repro.launch.dryrun --json``) as the per-(arch × shape × mesh) roofline
table: three terms in seconds, dominant bottleneck, MODEL_FLOPS/HLO_FLOPs
useful-compute ratio.  This benchmark only *reads* compiled artifacts; it
never compiles (the dry-run is a separate, slow, 512-device process)."""

from __future__ import annotations

import json
import os

from benchmarks import common

DEFAULT_PATHS = ("dryrun_single.json", "dryrun_multi.json")


def run(quick: bool = True, paths: tuple[str, ...] = DEFAULT_PATHS) -> None:
    records = []
    for p in paths:
        if os.path.exists(p):
            records.extend(json.load(open(p)))
    if not records:
        common.emit("roofline", status="no dry-run JSON found — run "
                    "PYTHONPATH=src python -m repro.launch.dryrun --json ...")
        return
    n_ok = n_fail = 0
    for r in records:
        if r["status"] == "skip":
            continue
        if r["status"] == "fail":
            n_fail += 1
            common.emit("roofline_fail", arch=r["arch"], shape=r["shape"],
                        mesh=r["mesh"], error=r.get("error", "?")[:80])
            continue
        n_ok += 1
        common.emit(
            "roofline", arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
            t_compute_s=r["t_compute_s"], t_memory_s=r["t_memory_s"],
            t_collective_s=r["t_collective_s"], bottleneck=r["bottleneck"],
            useful_ratio=r["useful_ratio"])
    common.emit("roofline_summary", ok=n_ok, fail=n_fail)


if __name__ == "__main__":
    run(quick=False)
