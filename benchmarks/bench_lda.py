"""Paper Fig 4 — AliasLDA vs YahooLDA across client counts.

For each client count the two samplers (method="exact" ≙ YahooLDA's
full-conditional sparse sampler; method="mhw" ≙ AliasLDA) run the same
number of rounds on the same sharded corpus through ``engine.Trainer``.
Reported per run: perplexity convergence, average topics/word,
per-iteration wall time and token throughput — the four panels of Fig 4
(CPU-scaled).
"""

from __future__ import annotations

from repro.core import lda

from benchmarks import common


def run(quick: bool = True) -> None:
    tokens, mask, _, ccfg = common.default_corpus(quick)
    cfg = lda.LDAConfig(n_topics=ccfg.n_topics, vocab_size=ccfg.vocab_size,
                        alpha=0.1, beta=0.01, mh_steps=2)
    client_counts = (2, 4) if quick else (2, 4, 8)
    n_rounds = 12 if quick else 30

    for n_clients in client_counts:
        results = {}
        for method, label in (("exact", "yahoo_lda"), ("mhw", "alias_lda")):
            res = common.run_multiclient(
                cfg, tokens, mask, n_clients=n_clients, n_rounds=n_rounds,
                method=method, eval_every=max(1, n_rounds // 4))
            results[label] = res
            common.emit(
                "lda_fig4", sampler=label, clients=n_clients,
                perplexity_first=res.perplexities[0],
                perplexity_final=res.perplexities[-1],
                topics_per_word_final=res.topics_per_word[-1],
                s_per_iter=sum(res.iter_times[1:]) / max(len(res.iter_times) - 1, 1),
                tokens_per_s=res.tokens_per_s)
        speedup = (sum(results["yahoo_lda"].iter_times[1:])
                   / max(sum(results["alias_lda"].iter_times[1:]), 1e-9))
        ppl_ratio = (results["alias_lda"].perplexities[-1]
                     / results["yahoo_lda"].perplexities[-1])
        common.emit("lda_fig4_summary", clients=n_clients,
                    alias_speedup_x=speedup, alias_ppl_ratio=ppl_ratio)


if __name__ == "__main__":
    run(quick=False)
