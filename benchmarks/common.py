"""Shared benchmark harness.

``simulate_clients`` reproduces the distributed round of
``repro.core.distributed`` semantically on a single device: C clients each
sweep τ times against a frozen snapshot of the shared statistics (applying
their *own* deltas locally between sweeps), their filtered deltas are summed
(the psum), applied, and optionally projected.  This is bit-compatible with
the shard_map driver modulo client RNG streams, and it is what lets the
paper's multi-client staleness/consistency experiments (Figs 4-8) run on the
CPU container.
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hdp, lda, pdp, projection, ps
from repro.data.synthetic import CorpusConfig, make_topic_corpus, shard_corpus

Array = jax.Array


# ---------------------------------------------------------------------------
# CSV / reporting helpers
# ---------------------------------------------------------------------------

_ROWS: list[dict] = []


def emit(bench: str, **fields) -> None:
    row = {"bench": bench, **fields}
    _ROWS.append(row)
    parts = [f"{k}={v:.6g}" if isinstance(v, float) else f"{k}={v}"
             for k, v in fields.items()]
    print(f"[{bench}] " + " ".join(parts), flush=True)


def rows() -> list[dict]:
    return _ROWS


def write_csv(path: str) -> None:
    keys: list[str] = []
    for r in _ROWS:
        for k in r:
            if k not in keys:
                keys.append(k)
    with open(path, "w") as f:
        f.write(",".join(keys) + "\n")
        for r in _ROWS:
            f.write(",".join(str(r.get(k, "")) for k in keys) + "\n")


def lda_sweep_perplexity(cfg, tokens, mask, layout: str, seed: int,
                         n_sweeps: int = 5) -> float:
    """Held-out perplexity after ``n_sweeps`` mhw sweeps with ``layout``.

    Single source of truth for the scan-vs-sorted equivalence number:
    bench_throughput's artifact cross-check and
    tests/test_sorted_sweep.py::test_sorted_matches_scan_perplexity both
    call this, so the measurement protocol cannot drift between them.
    Deterministic given (corpus, cfg, seed).
    """
    lays = lda.build_sorted_layouts(cfg, tokens, mask) \
        if layout == "sorted" else None
    local, shared = lda.init_state(cfg, tokens, mask, jax.random.PRNGKey(0))
    for i in range(n_sweeps):
        tables, stale = lda.build_alias(cfg, shared)
        local, dwk, dk = lda.sweep(
            cfg, local, shared, tables, stale, tokens, mask,
            jax.random.fold_in(jax.random.PRNGKey(seed), i),
            method="mhw", layout=layout, sorted_layouts=lays)
        shared = lda.apply_delta(shared, dwk, dk)
    return float(lda.perplexity(cfg, shared, tokens, mask,
                                jax.random.PRNGKey(9)))


def write_artifact(name: str, payload: dict) -> str:
    """Write a machine-readable benchmark artifact ``BENCH_<name>.json``.

    These are the cross-PR perf trajectory: each benchmark module dumps its
    headline numbers here so regressions are diffable without parsing
    stdout or the CSV.  Returns the path written.
    """
    path = f"BENCH_{name}.json"
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[artifact] wrote {path}", flush=True)
    return path


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.elapsed = time.perf_counter() - self.t0


# ---------------------------------------------------------------------------
# Model adapters (same shape as repro.core.distributed.ADAPTERS, plus the
# per-model eval + alias hooks the benchmark loop needs)
# ---------------------------------------------------------------------------

@dataclass
class ModelHooks:
    name: str
    init: Callable          # (tokens, mask, key) -> (local, shared)
    build_alias: Callable   # shared -> (tables, stale_dense)
    sweep: Callable         # (local, shared, tables, stale, tok, mask, key, method)
    apply: Callable         # (shared, deltas) -> shared
    delta_zero: Callable    # shared -> zero-deltas pytree
    perplexity: Callable    # (shared, tokens, mask, key) -> scalar
    topics_per_word: Callable | None = None
    project: Callable | None = None       # shared -> shared (Alg 1/2)
    count_violations: Callable | None = None
    post_round: Callable | None = None    # (local, shared, key) -> (local, shared)


def lda_hooks(cfg: lda.LDAConfig) -> ModelHooks:
    def sweep(local, shared, tables, stale, tok, mask, key, method):
        local2, dwk, dk = lda.sweep(cfg, local, shared, tables, stale, tok,
                                    mask, key, method=method)
        return local2, {"n_wk": dwk}

    def apply(shared, d):
        n_wk = shared.n_wk + d["n_wk"]
        return lda.SharedStats(n_wk=n_wk, n_k=n_wk.sum(0))

    return ModelHooks(
        name="lda",
        init=lambda t, m, k: lda.init_state(cfg, t, m, k),
        build_alias=lambda s: lda.build_alias(cfg, s),
        sweep=sweep, apply=apply,
        delta_zero=lambda s: {"n_wk": jnp.zeros_like(s.n_wk)},
        perplexity=lambda s, t, m, k: lda.perplexity(cfg, s, t, m, k),
        topics_per_word=lambda s: lda.topics_per_word(s),
    )


def pdp_hooks(cfg: pdp.PDPConfig, project: bool = True) -> ModelHooks:
    def sweep(local, shared, tables, stale, tok, mask, key, method):
        local2, dm, ds = pdp.sweep(cfg, local, shared, tables, stale, tok,
                                   mask, key, method=method)
        return local2, {"m_wk": dm, "s_wk": ds}

    def apply(shared, d):
        m_wk = shared.m_wk + d["m_wk"]
        s_wk = shared.s_wk + d["s_wk"]
        return pdp.SharedStats(m_wk=m_wk, s_wk=s_wk, m_k=m_wk.sum(0),
                               s_k=s_wk.sum(0))

    def proj(shared):
        stats = projection.project(
            {"m_wk": shared.m_wk, "s_wk": shared.s_wk,
             "m_k": shared.m_k, "s_k": shared.s_k},
            projection.PDP_RULES, projection.PDP_AGGREGATES)
        return pdp.SharedStats(**stats)

    return ModelHooks(
        name="pdp",
        init=lambda t, m, k: pdp.init_state(cfg, t, m, k),
        build_alias=lambda s: pdp.build_alias(cfg, s),
        sweep=sweep, apply=apply,
        delta_zero=lambda s: {"m_wk": jnp.zeros_like(s.m_wk),
                              "s_wk": jnp.zeros_like(s.s_wk)},
        perplexity=lambda s, t, m, k: pdp.perplexity(cfg, s, t, m, k),
        topics_per_word=lambda s: lda.topics_per_word(
            lda.SharedStats(n_wk=s.m_wk, n_k=s.m_k)),
        project=proj if project else None,
        count_violations=lambda s: projection.count_violations(
            {"m_wk": s.m_wk, "s_wk": s.s_wk}, projection.PDP_RULES),
    )


def hdp_hooks(cfg: hdp.HDPConfig, project: bool = True) -> ModelHooks:
    def sweep(local, shared, tables, stale, tok, mask, key, method):
        local2, dwk, dk = hdp.sweep(cfg, local, shared, tables, stale, tok,
                                    mask, key, method=method)
        return local2, {"n_wk": dwk}

    def apply(shared, d):
        n_wk = shared.n_wk + d["n_wk"]
        return hdp.SharedStats(n_wk=n_wk, n_k=n_wk.sum(0),
                               m_k=shared.m_k, theta0=shared.theta0)

    def proj(shared):
        n_wk = jnp.maximum(shared.n_wk, 0.0)       # nonneg rule
        return hdp.SharedStats(n_wk=n_wk, n_k=n_wk.sum(0),
                               m_k=shared.m_k, theta0=shared.theta0)

    def post_round(locals_, shared, key):
        """CRT table resampling per client; m_k sums across clients (it is a
        shared aggregation parameter, paper §5.2), then theta0 | m_k."""
        m_k_total = None
        for c in range(len(locals_)):
            locals_[c], m_k = hdp.resample_tables(
                cfg, locals_[c], shared, jax.random.fold_in(key, c))
            m_k_total = m_k if m_k_total is None else m_k_total + m_k
        theta0 = hdp.resample_theta0(cfg, m_k_total,
                                     jax.random.fold_in(key, 101))
        shared = hdp.SharedStats(n_wk=shared.n_wk, n_k=shared.n_k,
                                 m_k=m_k_total, theta0=theta0)
        return locals_, shared

    return ModelHooks(
        name="hdp",
        init=lambda t, m, k: hdp.init_state(cfg, t, m, k),
        build_alias=lambda s: hdp.build_alias(cfg, s),
        sweep=sweep, apply=apply,
        delta_zero=lambda s: {"n_wk": jnp.zeros_like(s.n_wk)},
        perplexity=lambda s, t, m, k: hdp.perplexity(cfg, s, t, m, k),
        topics_per_word=lambda s: lda.topics_per_word(
            lda.SharedStats(n_wk=s.n_wk, n_k=s.n_k)),
        project=proj if project else None,
        count_violations=lambda s: projection.count_violations(
            {"n_wk": s.n_wk}, (projection.Rule("nonneg", "n_wk"),)),
        post_round=post_round,
    )


# ---------------------------------------------------------------------------
# The multi-client simulated round
# ---------------------------------------------------------------------------

@dataclass
class RunResult:
    perplexities: list[float] = field(default_factory=list)
    topics_per_word: list[float] = field(default_factory=list)
    iter_times: list[float] = field(default_factory=list)
    violations: list[float] = field(default_factory=list)
    tokens: int = 0

    @property
    def tokens_per_s(self) -> float:
        t = float(np.mean(self.iter_times)) if self.iter_times else 1.0
        return self.tokens / max(t, 1e-9)


def run_multiclient(hooks: ModelHooks, tokens, mask, *, n_clients: int,
                    n_rounds: int, tau: int = 1, method: str = "mhw",
                    alias_refresh_every: int = 1,
                    filter_spec: ps.FilterSpec | None = None,
                    eval_every: int = 5, eval_docs: int = 32,
                    drop_client: tuple[int, int, int] | None = None,
                    key=None, project_every: int = 1) -> RunResult:
    """The paper's distributed round, simulated client-by-client.

    drop_client: (client_id, from_round, to_round) — failure injection
    (paper §5.4): that client's deltas are lost for those rounds; on
    recovery it re-pulls the shared state (its local z/n_dk survive in
    practice since snapshots are per-client — we keep them, matching the
    client-failover protocol of re-reading its shard from the snapshot).
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    shards = shard_corpus(np.asarray(tokens), np.asarray(mask), n_clients)
    shards = [(jnp.asarray(t), jnp.asarray(m)) for t, m in shards]

    # init() builds per-shard stats; the canonical shared state is their sum.
    locals_ = [hooks.init(t, m, jax.random.fold_in(key, c))[0]
               for c, (t, m) in enumerate(shards)]
    shared = _sum_shared(hooks, shards, locals_, key)

    eval_t, eval_m = tokens[:eval_docs], mask[:eval_docs]
    res = RunResult(tokens=int(np.asarray(mask).sum()))
    tables = stale = None
    # Error-feedback residuals (ps.residual_update): what a communication
    # filter withholds is carried to the next round, never dropped — count
    # mass must be conserved or the statistics drift negative (paper §5.3's
    # eventual-consistency contract).
    residuals = [None] * n_clients

    for r in range(n_rounds):
        with Timer() as tm:
            if tables is None or r % alias_refresh_every == 0:
                tables, stale = hooks.build_alias(shared)
            snapshot = shared
            total_delta = None
            for c in range(n_clients):
                if drop_client and c == drop_client[0] and \
                        drop_client[1] <= r < drop_client[2]:
                    continue  # failed client: contributes nothing this round
                t, m = shards[c]
                local_shared = snapshot
                acc = None
                for s in range(tau):
                    k = jax.random.fold_in(key, r * 131 + c * 17 + s)
                    locals_[c], d = hooks.sweep(locals_[c], local_shared,
                                                tables, stale, t, m, k, method)
                    local_shared = hooks.apply(local_shared, d)
                    acc = d if acc is None else {
                        n: acc[n] + d[n] for n in d}
                if filter_spec is not None and filter_spec.kind != "dense":
                    kf = jax.random.fold_in(key, 7000 + r * 131 + c)
                    if residuals[c] is not None:
                        acc = {n: acc[n] + residuals[c][n] for n in acc}
                    sent = {n: ps.filter_delta(v, filter_spec,
                                               jax.random.fold_in(kf, i))
                            for i, (n, v) in enumerate(acc.items())}
                    residuals[c] = {n: acc[n] - sent[n] for n in acc}
                    acc = sent
                total_delta = acc if total_delta is None else {
                    n: total_delta[n] + acc[n] for n in acc}
            if total_delta is not None:
                shared = hooks.apply(shared, total_delta)
            if hooks.project is not None and project_every and \
                    r % project_every == 0:
                shared = hooks.project(shared)
            if hooks.post_round is not None:
                locals_, shared = hooks.post_round(
                    locals_, shared, jax.random.fold_in(key, 9000 + r))
            jax.block_until_ready(jax.tree.leaves(_stats_dict(shared))[0])
        res.iter_times.append(tm.elapsed)

        if r % eval_every == 0 or r == n_rounds - 1:
            pp = float(hooks.perplexity(shared, eval_t, eval_m,
                                        jax.random.PRNGKey(42)))
            res.perplexities.append(pp)
            if hooks.topics_per_word:
                res.topics_per_word.append(float(hooks.topics_per_word(shared)))
            if hooks.count_violations:
                res.violations.append(float(hooks.count_violations(shared)))
    return res


def _stats_dict(shared) -> dict:
    return shared._asdict() if hasattr(shared, "_asdict") else dict(shared)


def _sum_shared(hooks: ModelHooks, shards, locals_, key):
    """Canonical shared stats = sum over client shards (re-init per shard)."""
    shared = None
    for c, (t, m) in enumerate(shards):
        _, sh = hooks.init(t, m, jax.random.fold_in(key, c))
        if shared is None:
            shared = sh
        else:
            d = _stats_dict(sh)
            cur = _stats_dict(shared)
            merged = {}
            for n in cur:
                if cur[n].shape == () or n == "theta0":
                    merged[n] = cur[n]
                else:
                    merged[n] = cur[n] + d[n]
            shared = type(shared)(**merged)
    return shared


def default_corpus(quick: bool, seed: int = 0):
    cfg = CorpusConfig(
        n_topics=8 if quick else 16,
        vocab_size=400 if quick else 1200,
        n_docs=128 if quick else 512,
        doc_len=48 if quick else 96,
        seed=seed)
    tokens, mask, phi = make_topic_corpus(cfg)
    return jnp.asarray(tokens), jnp.asarray(mask), phi, cfg
