"""Shared benchmark harness.

The multi-client round loop lives in ``repro.engine.Trainer`` (one driver
for every ModelFamily); this module keeps the reporting helpers, the
default corpus, the shared scan-vs-sorted measurement protocol and a thin
``run_multiclient`` wrapper so benchmark modules stay one-call simple.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.core import family as family_mod
from repro.core import ps
from repro.data.synthetic import CorpusConfig, make_topic_corpus
from repro.engine import RunResult, Trainer, TrainerConfig

Array = jax.Array

__all__ = ["emit", "rows", "write_csv", "write_artifact", "Timer",
           "RunResult", "run_multiclient", "default_corpus",
           "lda_sweep_perplexity", "family_sweep_perplexity",
           "time_trainer_rounds", "layout_speedup_artifact"]


# ---------------------------------------------------------------------------
# CSV / reporting helpers
# ---------------------------------------------------------------------------

_ROWS: list[dict] = []


def emit(bench: str, **fields) -> None:
    row = {"bench": bench, **fields}
    _ROWS.append(row)
    parts = [f"{k}={v:.6g}" if isinstance(v, float) else f"{k}={v}"
             for k, v in fields.items()]
    print(f"[{bench}] " + " ".join(parts), flush=True)


def rows() -> list[dict]:
    return _ROWS


def write_csv(path: str) -> None:
    """Write the collected rows in long format: ``bench,row,metric,value``.

    ``row`` is the ordinal of the emit() call within its bench, so the
    fields of one emit stay joinable.  Long format means the schema does
    not change when a bench adds a metric — downstream diffing selects
    by (bench, metric) instead of chasing a union-of-columns header.
    """
    ordinal: dict[str, int] = {}
    with open(path, "w") as f:
        f.write("bench,row,metric,value\n")
        for r in _ROWS:
            bench = r["bench"]
            i = ordinal.get(bench, 0)
            ordinal[bench] = i + 1
            for k, v in r.items():
                if k == "bench":
                    continue
                f.write(f"{bench},{i},{k},{v}\n")


def family_sweep_perplexity(cfg, tokens, mask, layout: str, seed: int,
                            n_sweeps: int = 5) -> float:
    """Held-out perplexity after ``n_sweeps`` single-client mhw sweeps with
    ``layout``, for any registered family.

    Single source of truth for the scan-vs-sorted equivalence number:
    the benchmark artifact cross-checks and the parity tests
    (tests/test_sorted_sweep.py) both call this, so the measurement
    protocol cannot drift between them.  Deterministic given
    (corpus, cfg, seed).
    """
    fam = family_mod.family_of(cfg)
    lays = fam.build_sorted_layouts(cfg, tokens, mask) \
        if layout == "sorted" else None
    local, shared = fam.init_state(cfg, tokens, mask, jax.random.PRNGKey(0))
    for i in range(n_sweeps):
        tables, stale = fam.build_alias(cfg, shared)
        local, deltas = fam.sweep(
            cfg, local, shared, tables, stale, tokens, mask,
            jax.random.fold_in(jax.random.PRNGKey(seed), i),
            method="mhw", layout=layout, sorted_layouts=lays)
        shared = fam.apply_delta(shared, deltas)
    return float(fam.perplexity(cfg, shared, tokens, mask,
                                jax.random.PRNGKey(9)))


def lda_sweep_perplexity(cfg, tokens, mask, layout: str, seed: int,
                         n_sweeps: int = 5) -> float:
    """LDA-named alias of :func:`family_sweep_perplexity` (kept for the
    historical artifact/test call sites)."""
    return family_sweep_perplexity(cfg, tokens, mask, layout, seed,
                                   n_sweeps=n_sweeps)


def write_artifact(name: str, payload: dict) -> str:
    """Write a machine-readable benchmark artifact ``BENCH_<name>.json``.

    These are the cross-PR perf trajectory: each benchmark module dumps its
    headline numbers here so regressions are diffable without parsing
    stdout or the CSV.  Returns the path written.
    """
    path = f"BENCH_{name}.json"
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[artifact] wrote {path}", flush=True)
    return path


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.elapsed = time.perf_counter() - self.t0


# ---------------------------------------------------------------------------
# The multi-client simulated round — engine.Trainer under a thin wrapper
# ---------------------------------------------------------------------------

def run_multiclient(model_cfg, tokens, mask, *, n_clients: int,
                    n_rounds: int, tau: int = 1, method: str = "mhw",
                    layout: str = "scan", alias_refresh_every: int = 1,
                    filter_spec: ps.FilterSpec | None = None,
                    eval_every: int = 5, eval_docs: int = 32,
                    drop_client: tuple[int, int, int] | None = None,
                    fault_plan=None, snapshot_every: int = 0,
                    snapshot_dir: str | None = None,
                    key=None, project_every: int = 1,
                    consistency: str = "bsp",
                    n_server_shards: int = 1) -> RunResult:
    """The paper's distributed round, simulated client-by-client — see
    ``repro.engine.Trainer`` for the lifecycle.  The model family is
    resolved from ``model_cfg``'s type via the registry.

    Fault injection goes through ``fault_plan`` (a ``core.fault.FaultPlan``);
    ``drop_client`` remains as the deprecated single-crash shim and is
    forwarded so callers still see the DeprecationWarning."""
    tcfg = TrainerConfig(
        layout=layout, method=method, n_clients=n_clients, tau=tau,
        alias_refresh_every=alias_refresh_every,
        project_every=project_every,
        consistency=consistency, n_server_shards=n_server_shards,
        filter=filter_spec if filter_spec is not None else ps.FilterSpec(),
        drop_client=drop_client, fault_plan=fault_plan,
        snapshot_every=snapshot_every, snapshot_dir=snapshot_dir)
    trainer = Trainer(model_cfg, tokens, mask, config=tcfg, key=key)
    return trainer.run(n_rounds, eval_every=eval_every, eval_docs=eval_docs)


def time_trainer_rounds(model_cfg, tokens, mask, *, layouts=("scan", "sorted"),
                        n_clients: int = 1, n_rounds: int = 5,
                        eval_every: int = 10**9, key=None
                        ) -> dict[str, RunResult]:
    """Run the same corpus through one Trainer per layout, interleaving is
    unnecessary here because each layout runs its own jitted program; the
    first (compile) round is excluded by callers via ``iter_times[1:]``."""
    out = {}
    for layout in layouts:
        tcfg = TrainerConfig(layout=layout, n_clients=n_clients)
        trainer = Trainer(model_cfg, tokens, mask, config=tcfg, key=key)
        out[layout] = trainer.run(n_rounds, eval_every=eval_every)
    return out


def layout_speedup_artifact(name: str, model_cfg, tokens, mask, *,
                            artifact: dict, n_rounds: int) -> None:
    """The shared scan-vs-sorted measurement + artifact protocol: run one
    single-client Trainer per layout, record median round time (first
    compile round excluded) and final perplexity into ``artifact``, emit
    the per-layout rows and the speedup summary, and write
    ``BENCH_<name>.json``.  One implementation for every family so the
    cross-PR speedup numbers cannot drift between benches."""
    per_layout = time_trainer_rounds(model_cfg, tokens, mask, n_clients=1,
                                     n_rounds=n_rounds)
    secs = {}
    for layout, res in per_layout.items():
        # Exclude the compile round when there is more than one.
        times = res.iter_times[1:] or res.iter_times
        secs[layout] = sorted(times)[len(times) // 2]
        artifact.setdefault("s_per_round", {})[layout] = secs[layout]
        artifact.setdefault("perplexity_final", {})[layout] = \
            res.perplexities[-1]
        emit(f"{name}_layout", layout=layout, s_per_round=secs[layout],
             perplexity_final=res.perplexities[-1])
    speedup = secs["scan"] / max(secs["sorted"], 1e-9)
    ppl_rel = abs(per_layout["sorted"].perplexities[-1]
                  - per_layout["scan"].perplexities[-1]) \
        / per_layout["scan"].perplexities[-1]
    artifact["speedup_sorted_vs_scan"] = speedup
    artifact["ppl_rel_diff_sorted_vs_scan"] = ppl_rel
    emit(f"{name}_layout_summary", speedup_sorted_vs_scan=speedup,
         ppl_rel_diff=ppl_rel)
    write_artifact(name, artifact)


def default_corpus(quick: bool, seed: int = 0):
    cfg = CorpusConfig(
        n_topics=8 if quick else 16,
        vocab_size=400 if quick else 1200,
        n_docs=128 if quick else 512,
        doc_len=48 if quick else 96,
        seed=seed)
    tokens, mask, phi = make_topic_corpus(cfg)
    return jnp.asarray(tokens), jnp.asarray(mask), phi, cfg
