"""Serving benchmark (DESIGN.md §14): fold-in latency/throughput + quality.

Trains a small LDA model in-process, freezes it into an
:class:`repro.serve.InferenceSnapshot`, then measures the online
inference service end to end:

* **latency / throughput** — a real :class:`repro.serve.server.
  InferenceServer` on loopback under ≥ 2 concurrent client connections
  (each its own thread + socket, documents batched into shared fused
  sweeps by the server's batcher); reports client-observed p50/p99
  request latency, aggregate docs/s, and the server's load-shed count,
* **parity** — a sample of the concurrently-served results is re-derived
  through :func:`reference_fold_in` (the training ``family.sweep`` path
  with pushes dropped) and must match bit-for-bit — the §14 determinism
  contract re-verified on every bench run,
* **quality gate** — held-out documents folded in through the engine
  must score a perplexity within ``QUALITY_TOL`` of the training-time
  evaluator (``family.perplexity``) on the same documents.  A fold-in
  chain that silently diverged from the model would fail here even if
  it stayed deterministic.

Artifact: ``BENCH_serve.json`` — gated for completeness by tools/ci.sh.
"""

from __future__ import annotations

import threading
import time

import jax
import numpy as np

from repro.core import family as fam_mod
from repro.data.synthetic import CorpusConfig, make_topic_corpus
from repro.engine import Trainer, TrainerConfig
from repro.serve import (FoldInEngine, InferRequest, ServeConfig,
                         fold_in_perplexity, from_trainer)
from repro.serve.client import InferenceClient, requests_for
from repro.serve.engine import InferResult, reference_fold_in, \
    result_checksum
from repro.serve.server import InferenceServer

from benchmarks import common

# Fold-in perplexity may not exceed the training-time evaluator's by more
# than this factor on the same held-out documents (it is usually *lower*:
# the harvested theta is a fitted point estimate, family.perplexity
# averages over its own short internal chains).
QUALITY_TOL = 1.25
PARITY_DOCS = 3


def _serve_concurrent(addr: str, *, n_clients: int, n_docs: int,
                      vocab_size: int, max_len: int
                      ) -> tuple[dict[int, InferResult], list[float], float]:
    """Drive ``n_clients`` concurrent client connections; returns
    (results by uid, per-request client latencies ms, wall seconds)."""
    results: dict[int, InferResult] = {}
    latencies: list[float] = []
    errors: list[BaseException] = []
    lock = threading.Lock()

    def client_main(cid: int) -> None:
        try:
            reqs = requests_for(cid, vocab_size=vocab_size, n_docs=n_docs,
                                max_len=max_len, corpus_seed=7,
                                seed_base=1000)
            with InferenceClient(addr, timeout=300.0) as cli:
                for req in reqs:
                    t0 = time.perf_counter()
                    res = cli.infer(req.uid, req.tokens, seed=req.seed)
                    dt = (time.perf_counter() - t0) * 1e3
                    with lock:
                        results[res.uid] = res
                        latencies.append(dt)
        except BaseException as e:  # surfaced after join
            with lock:
                errors.append(e)

    threads = [threading.Thread(target=client_main, args=(c,),
                                name=f"bench-serve-client-{c}")
               for c in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    return results, latencies, wall


def run(quick: bool = True) -> None:
    if quick:
        vocab, n_topics, n_train, doc_len = 400, 8, 64, 48
        rounds, n_clients, docs_per_client = 3, 2, 5
        n_sweeps, max_slots, held_out = 4, 4, 12
    else:
        vocab, n_topics, n_train, doc_len = 1200, 16, 256, 96
        rounds, n_clients, docs_per_client = 6, 3, 12
        n_sweeps, max_slots, held_out = 8, 8, 32

    # --- train + freeze -------------------------------------------------
    tokens, mask, _ = make_topic_corpus(CorpusConfig(
        n_topics=n_topics, vocab_size=vocab, n_docs=n_train + held_out,
        doc_len=doc_len, seed=0))
    fam = fam_mod.get("lda")
    cfg = fam.config_cls(n_topics=n_topics, vocab_size=vocab)
    trainer = Trainer(cfg, tokens[:n_train], mask[:n_train],
                      config=TrainerConfig(n_clients=1),
                      key=jax.random.PRNGKey(0))
    with common.Timer() as t_train:
        trainer.run(rounds, eval_every=rounds + 1)
    snap = from_trainer(trainer)
    ho_tokens = np.asarray(tokens[n_train:])
    ho_mask = np.asarray(mask[n_train:], bool)

    scfg = ServeConfig(max_slots=max_slots, max_len=doc_len,
                       n_sweeps=n_sweeps)

    # --- concurrent service on loopback ---------------------------------
    server = InferenceServer(snap, scfg, max_queue=2 * max_slots,
                             max_batch_delay=0.005).start()
    addr = "%s:%d" % server.address
    try:
        results, lat_ms, wall = _serve_concurrent(
            addr, n_clients=n_clients, n_docs=docs_per_client,
            vocab_size=vocab, max_len=doc_len)
        sstats = server.stats()
    finally:
        server.close()
    total_docs = n_clients * docs_per_client
    assert len(results) == total_docs, \
        f"served {len(results)} of {total_docs} docs"
    lat = sorted(lat_ms)

    def pct(p: float) -> float:
        return lat[min(len(lat) - 1, int(round(p * (len(lat) - 1))))]

    docs_per_s = total_docs / wall

    # --- parity: a sample of the served results vs the training path ----
    sample = requests_for(0, vocab_size=vocab, n_docs=docs_per_client,
                          max_len=doc_len, corpus_seed=7,
                          seed_base=1000)[:PARITY_DOCS]
    bit_exact = True
    for req in sample:
        _, theta, z = reference_fold_in(snap, req.tokens, req.seed,
                                        n_sweeps=n_sweeps,
                                        max_len=doc_len)
        ref = InferResult(uid=req.uid, theta=theta, assignments=z,
                          n_sweeps=n_sweeps)
        bit_exact &= (result_checksum(ref)
                      == result_checksum(results[req.uid]))

    # --- quality gate: fold-in perplexity vs training-time eval ---------
    ho_lens = ho_mask.sum(axis=1).astype(int)
    ho_reqs = [InferRequest(uid=i, tokens=ho_tokens[i, :ho_lens[i]],
                            seed=5000 + i)
               for i in range(ho_tokens.shape[0])]
    eng = FoldInEngine(snap, scfg)
    ho_results = eng.run(ho_reqs)
    thetas = np.stack([ho_results[i].theta
                       for i in range(len(ho_reqs))])
    fold_ppl = fold_in_perplexity(snap, thetas, ho_tokens, ho_mask)
    eval_ppl = float(fam.perplexity(cfg, snap.shared, ho_tokens, ho_mask,
                                    jax.random.PRNGKey(123)))
    ratio = fold_ppl / eval_ppl
    within = bool(ratio <= QUALITY_TOL)

    artifact = {
        "quick": quick,
        "vocab": vocab, "n_topics": n_topics, "doc_len": doc_len,
        "train_docs": n_train, "train_rounds": rounds,
        "train_s": t_train.elapsed,
        "serve": {
            "n_clients": n_clients,
            "docs": total_docs,
            "n_sweeps": n_sweeps,
            "max_slots": max_slots,
            "docs_per_s": docs_per_s,
            "latency_ms": {"p50": pct(0.50), "p99": pct(0.99)},
            "server_latency_ms": {"p50": sstats["latency_p50_ms"],
                                  "p99": sstats["latency_p99_ms"]},
            "shed": sstats["shed"],
            "sweeps_run": sstats["sweeps_run"],
        },
        "parity": {"bit_exact": bool(bit_exact),
                   "docs_checked": len(sample)},
        "quality": {
            "held_out_docs": int(ho_tokens.shape[0]),
            "fold_in_ppl": float(fold_ppl),
            "train_eval_ppl": eval_ppl,
            "ratio": float(ratio),
            "tolerance": QUALITY_TOL,
            "within_tolerance": within,
        },
    }
    common.emit("serve", n_clients=n_clients, docs=total_docs,
                docs_per_s=docs_per_s, p50_ms=pct(0.50),
                p99_ms=pct(0.99), shed=sstats["shed"],
                fold_in_ppl=float(fold_ppl), train_eval_ppl=eval_ppl,
                ppl_ratio=float(ratio))
    common.write_artifact("serve", artifact)

    if not bit_exact:
        raise AssertionError(
            "concurrently-served fold-in diverged from the "
            "reference_fold_in training path")
    if not within:
        raise AssertionError(
            f"fold-in perplexity {fold_ppl:.2f} exceeds training-time "
            f"eval {eval_ppl:.2f} by {ratio:.3f}x (> {QUALITY_TOL}x)")


if __name__ == "__main__":
    run(quick=True)
