"""Benchmark driver — one module per paper table/figure.

  python -m benchmarks.run             # quick sizes (CI / CPU container)
  python -m benchmarks.run --full      # paper-scaled (slow)
  python -m benchmarks.run --only lda,projection

Mapping to the paper:
  bench_lda         Fig 4  AliasLDA vs YahooLDA (ppl, topics/word, time)
  bench_pdp         Fig 5  PDP convergence with projection
  bench_hdp         Fig 7  HDP at two client-group sizes
  bench_projection  Fig 8  projection vs no projection
  bench_scaling     Fig 6  client-count scaling (doc log-likelihood)
  bench_throughput  §3/§6.3 sampler complexity vs K + alias build + MH rate
  bench_filters     §5.3   communication-filter traffic/quality trade
  bench_consistency §5.2-3 staleness-vs-throughput-vs-perplexity frontier
                           (BSP vs SSP(1,2,4) vs async parameter server)
  bench_failover    §5.4   client failure + recovery robustness
  bench_stale_sync  beyond-paper: PS pattern on LM gradient sync
  bench_roofline    §Roofline table from the dry-run artifacts
  bench_wire        §11     in-process vs loopback-TCP transport (rounds/s,
                           bytes/round, RPC latency, BSP parity bit)
  bench_scale       §6.3   (V, K) scale ladder — K-tiled sweep tokens/s,
                           incremental alias-build ms/row, dense-vs-sparse
                           bytes/round (reaches V=65536, K=256 in quick)
  bench_serve       §14    online fold-in serving — p50/p99 latency +
                           docs/s under concurrent clients, load-shed
                           count, reference-path parity bit, and the
                           fold-in-vs-training perplexity quality gate

Besides the CSV, benchmark modules write machine-readable
``BENCH_<name>.json`` artifacts (``common.write_artifact``) so the perf
trajectory is diffable across PRs — e.g. ``BENCH_throughput.json`` carries
per-token µs for exact / mhw / mhw_sorted and the sorted-path speedup.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import common

MODULES = ("lda", "pdp", "hdp", "projection", "scaling", "throughput",
           "filters", "consistency", "failover", "stale_sync", "roofline",
           "wire", "scale", "serve")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="paper-scaled sizes")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized quick mode (the default; --full flips)")
    ap.add_argument("--only", default=None,
                    help="comma-separated module subset")
    ap.add_argument("--csv", default="bench_results.csv")
    args = ap.parse_args(argv)
    if args.quick and args.full:
        ap.error("--quick and --full are mutually exclusive")

    only = set(args.only.split(",")) if args.only else set(MODULES)
    unknown = only - set(MODULES)
    if unknown:
        ap.error(f"unknown benchmark module(s) {sorted(unknown)}; "
                 f"choose from {MODULES}")
    failures = []
    for name in MODULES:
        if name not in only:
            continue
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        print(f"\n=== bench_{name} ===", flush=True)
        t0 = time.time()
        try:
            mod.run(quick=not args.full)
        except Exception:
            traceback.print_exc()
            failures.append(name)
        print(f"=== bench_{name} done in {time.time() - t0:.1f}s ===",
              flush=True)

    if args.csv:
        common.write_csv(args.csv)
        print(f"\nwrote {args.csv} ({len(common.rows())} rows)")
    if failures:
        print(f"FAILED benchmarks: {failures}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
