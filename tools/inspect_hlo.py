"""Dump + summarize the optimized HLO for one (arch, shape, mesh):
collective ops by computation with shapes, trip counts, and byte totals —
the profiling tool for §Perf (we reason from lowered IR, not wall-clock).

    PYTHONPATH=src python tools/inspect_hlo.py --arch smollm-360m \
        --shape train_4k [--multi-pod] [--dump /tmp/x.hlo]
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
from collections import defaultdict

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ARCHITECTURES
from repro.launch import roofline as rl
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--dump", default=None)
    ap.add_argument("--top", type=int, default=25)
    args = ap.parse_args()

    cfg = ARCHITECTURES[args.arch]
    shape = INPUT_SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    with mesh:
        spec = specs_lib.make_lowering_spec(cfg, shape, mesh)
        lowered = specs_lib.lower(spec)
        compiled = lowered.compile()
    hlo = compiled.as_text()
    if args.dump:
        with open(args.dump, "w") as f:
            f.write(hlo)
        print(f"dumped {len(hlo) / 1e6:.1f}MB HLO to {args.dump}")

    comps = rl._parse_computations(hlo)
    body_trip, called_by = {}, {}
    for name, lines in comps.items():
        for line in lines:
            m = rl._WHILE_RE.search(line)
            if m:
                cond, body = m.groups()
                body_trip[body] = rl._trip_count(comps.get(cond, []))
                called_by[body] = name
                called_by[cond] = name

    def multiplier(comp):
        mult, seen = 1, set()
        while comp in called_by and comp not in seen:
            seen.add(comp)
            mult *= body_trip.get(comp, 1)
            comp = called_by[comp]
        return mult

    print("while loops (body -> trip):")
    for b, t in sorted(body_trip.items(), key=lambda x: -x[1])[:15]:
        print(f"  {b:60s} trip={t:8d} nested_mult={multiplier(b)}")

    rows = []
    for name, lines in comps.items():
        mult = multiplier(name)
        for line in lines:
            m = rl._INSTR_RE.match(line)
            if not m:
                continue
            shape_str, op = m.groups()
            for kind in rl._COLLECTIVES:
                if op == kind or op == kind + "-start":
                    b = rl._shape_bytes(shape_str)
                    rows.append((b * mult, b, mult, kind, name,
                                 shape_str[:60]))
                    break
    rows.sort(reverse=True)
    total = sum(r[0] for r in rows)
    print(f"\ntotal collective bytes/device/step: {total / 2**30:.2f} GiB "
          f"({len(rows)} collective ops)")
    by_kind = defaultdict(int)
    for r in rows:
        by_kind[r[3]] += r[0]
    for k, v in sorted(by_kind.items(), key=lambda x: -x[1]):
        print(f"  {k:20s} {v / 2**30:9.3f} GiB")
    print(f"\ntop {args.top} collectives (bytes×mult, bytes, mult, kind, "
          f"computation, shape):")
    for r in rows[:args.top]:
        print(f"  {r[0] / 2**20:10.1f}MiB = {r[1] / 2**20:8.2f}MiB x{r[2]:<6d} "
              f"{r[3]:18s} {r[4][:40]:40s} {r[5]}")


if __name__ == "__main__":
    main()
