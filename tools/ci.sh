#!/usr/bin/env bash
# CI entry point: tier-1 tests + quick-mode throughput benchmark.
#
# Runs entirely on CPU — the Pallas kernels execute in interpret mode
# (repro.kernels.ops.INTERPRET defaults to True), so this validates kernel
# semantics and the benchmark pipeline without TPU hardware.
#
# Usage: tools/ci.sh  (from the repo root)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1 tests ==="
python -m pytest -x -q

echo "=== quick benchmarks: throughput + families + consistency + failover ==="
# One invocation so bench_results.csv keeps every module's rows.  The
# lda/pdp/hdp modules drive all three model families through
# engine.Trainer and both layouts (writing BENCH_{pdp,hdp}.json), so API
# drift between families breaks CI, not just the nightly benchmarks.
# The throughput module's round_engine / alias_partial_rebuild sections
# track the compiled-round dispatch-overhead win and the incremental
# alias rebuild cost as BENCH_throughput.json artifacts (DESIGN.md §8).
# The consistency module is the parameter-server policy bench
# (DESIGN.md §9): BENCH_consistency.json must carry rounds/s +
# perplexity for every policy with SSP(>=2) strictly faster than BSP,
# and it asserts in-process that the compiled round still traces once
# per (family, layout, policy) — it fails if a policy's per-round
# cadence (refresh flag, projection, failure mask) started retracing.
# The failover module is the kill-and-rejoin robustness bench
# (DESIGN.md §10): one client crashes mid-run and rejoins from its
# periodic snapshot under each consistency policy; BENCH_failover.json
# must carry the recovery-rounds and final-perplexity-degradation
# numbers with degradation <= 5%.
# The wire module is the out-of-process transport bench (DESIGN.md §11):
# the same Trainer config over the in-process server and over loopback
# TCP shard servers; BENCH_wire.json must carry rounds/s for both
# transports, bytes/round (encoded vs payload), and RPC latency
# percentiles per policy, and the module itself hard-fails if
# BSP-over-TCP is not bit-exact with in-process or if the sparse delta
# exchange (DESIGN.md §12) reduces push payload by less than 5x.
# The scale module is the (V, K) ladder (DESIGN.md §12): K-tiled sorted
# sweep tokens/s, incremental alias-build ms/row and dense-vs-sparse
# frame bytes up to (V=65536, K=256) in quick mode.
# The serve module is the online fold-in serving bench (DESIGN.md §14):
# a real InferenceServer under concurrent client connections;
# BENCH_serve.json must carry p50/p99 latency, docs/s, the shed count
# and the fold-in-vs-training perplexity quality gate, and the module
# itself hard-fails if the served results are not bit-exact with the
# reference_fold_in training path or the gate is exceeded.
python -m benchmarks.run --only throughput,lda,pdp,hdp,consistency,failover,wire,scale,serve --quick
python - <<'EOF'
import json
art = json.load(open("BENCH_consistency.json"))
pols = art["policies"]
missing = {"bsp", "ssp1", "ssp2", "ssp4", "async"} - set(pols)
assert not missing, f"BENCH_consistency.json missing policies: {missing}"
for name, res in pols.items():
    assert res["rounds_per_s"] > 0, (name, res)
# Every policy must declare its perplexity-gate coverage, and exactly
# SSP(4) — the deep-staleness frontier point — may ride ungated.
for name, res in pols.items():
    assert res.get("unguarded") is (name == "ssp4"), (name, res)
assert pols["ssp4"].get("unguarded") is True, pols["ssp4"]
print("consistency artifact OK:", ", ".join(
    f"{n}={pols[n]['rounds_per_s']:.2f} r/s" for n in sorted(pols)))
EOF
python - <<'EOF'
import json
art = json.load(open("BENCH_failover.json"))
pols = art["policies"]
missing = {"bsp", "ssp2", "async"} - set(pols)
assert not missing, f"BENCH_failover.json missing policies: {missing}"
for name, res in pols.items():
    for variant in ("baseline", "kill_rejoin"):
        assert variant in res, (name, sorted(res))
        assert res[variant]["perplexity_final"] > 0, (name, variant, res)
    kr = res["kill_rejoin"]
    assert "recovery_rounds" in kr and "degradation" in kr, (name, kr)
    assert kr["degradation"] <= 0.05, (name, kr)
# The tcp section (DESIGN.md §13) is the process-level kill-and-rejoin:
# shard restarted from its snapshot + worker relaunched with --restore,
# through chaos proxies.  BSP must come back bit-exact.
tcp = art["tcp"]
assert tcp["bsp_bitexact"] is True, tcp
assert tcp["degradation"] <= 0.05, tcp
assert tcp["restarts"] == {"server": 1, "client": 1}, tcp
assert tcp["conn_drops"] >= 1, tcp
print("failover artifact OK:", ", ".join(
    f"{n}: +{pols[n]['kill_rejoin']['degradation']*100:.1f}% ppl, "
    f"{pols[n]['kill_rejoin']['recovery_rounds']} rounds to recover"
    for n in sorted(pols))
    + f"; tcp: bit-exact, {tcp['recovery_rounds']} rounds re-executed, "
    f"{tcp['conn_drops']} wire drops survived")
EOF
python - <<'EOF'
import json
art = json.load(open("BENCH_wire.json"))
pols = art["policies"]
missing = {"bsp", "ssp2"} - set(pols)
assert not missing, f"BENCH_wire.json missing policies: {missing}"
for name, res in pols.items():
    for transport in ("inproc", "tcp"):
        assert res["rounds_per_s"][transport] > 0, (name, transport, res)
    bpr = res["bytes_per_round"]
    assert bpr["encoded"] >= bpr["payload"] > 0, (name, bpr)
    lat = res["rpc_latency_ms"]
    assert lat["p50"] > 0 and lat["p99"] >= lat["p50"], (name, lat)
# Bytes/round regression guard: the quick-mode BSP geometry is fixed
# (V=64, K=4, 2 clients, 2 shards, tau=1), so encoded bytes/round is
# deterministic modulo JSON meta jitter.  7523 B is the PR-8 baseline;
# a frame-format or push-cadence regression shows up here.
assert pols["bsp"]["bytes_per_round"]["encoded"] <= 7523 * 1.10, \
    ("bytes/round regression vs 7523 B baseline", pols["bsp"])
sparse = art["sparse"]
assert sparse["reduction_ratio"] >= 5.0, sparse
assert art["parity"]["bsp_bitexact"] is True, art["parity"]
assert art["parity"]["sparse_bitexact"] is True, art["parity"]
print("wire artifact OK:", ", ".join(
    f"{n}: {pols[n]['rounds_per_s']['tcp']:.1f} r/s tcp "
    f"({pols[n]['bytes_per_round']['encoded']/1024:.1f} KiB/round, "
    f"p99 {pols[n]['rpc_latency_ms']['p99']:.1f} ms)"
    for n in sorted(pols))
    + f"; sparse push {sparse['reduction_ratio']:.1f}x smaller")
EOF
python - <<'EOF'
import json
art = json.load(open("BENCH_scale.json"))
pts = art["points"]
assert pts, "BENCH_scale.json has no points"
assert art["max_point"]["vocab"] >= 65536, art["max_point"]
assert art["max_point"]["n_topics"] >= 256, art["max_point"]
for p in pts:
    assert p["tokens_per_s"] > 0, p
    assert p["alias_build_ms_per_row"] > 0, p
    assert p["sparse_parity"] is True, p
    assert p["bytes_per_round"]["ratio"] > 1.0, p
print("scale artifact OK:", ", ".join(
    f"V={p['vocab']} K={p['n_topics']}: {p['tokens_per_s']:.0f} tok/s, "
    f"sparse {p['bytes_per_round']['ratio']:.0f}x" for p in pts))
EOF
python - <<'EOF'
import json
art = json.load(open("BENCH_serve.json"))
srv = art["serve"]
assert srv["n_clients"] >= 2, srv
assert srv["docs"] > 0 and srv["docs_per_s"] > 0, srv
lat = srv["latency_ms"]
assert lat["p50"] > 0 and lat["p99"] >= lat["p50"], lat
assert srv["shed"] >= 0, srv
assert art["parity"]["bit_exact"] is True, art["parity"]
q = art["quality"]
for k in ("fold_in_ppl", "train_eval_ppl", "ratio", "tolerance"):
    assert q[k] > 0, (k, q)
assert q["within_tolerance"] is True, q
print(f"serve artifact OK: {srv['docs_per_s']:.2f} docs/s over "
      f"{srv['n_clients']} clients (p50 {lat['p50']:.0f} ms, "
      f"p99 {lat['p99']:.0f} ms, shed {srv['shed']}); "
      f"fold-in ppl {q['fold_in_ppl']:.1f} vs eval "
      f"{q['train_eval_ppl']:.1f} ({q['ratio']:.2f}x <= "
      f"{q['tolerance']}x)")
EOF

echo "=== loopback e2e smoke: 1 shard server + 2 client processes ==="
# Real processes over 127.0.0.1 speaking the framed protocol end to end;
# the smoke asserts both client processes and an in-process reference
# agree on the final shared-statistics checksums (BSP bit-exactness
# across the socket).  timeout(1) guards against a hung server — a
# protocol bug must fail CI, not wedge it.
timeout 540 python -m repro.launch.loopback --smoke

echo "=== tcp kill-and-rejoin smoke: chaos proxy + shard restart + worker rejoin ==="
# The DESIGN.md §13 acceptance run as a process-level smoke: a BSP
# loopback run through chaos proxies (connection drop on the push path)
# in which one shard-server process is killed at its round barrier and
# restarted from its snapshot (--restore --ports, same addresses) and
# one worker process is killed mid-run and relaunched with --restore.
# The smoke asserts exactly one restart of each, that the scheduled
# drop fired, and that the final checksums are bit-exact with the
# undisturbed in-process run.  timeout(1) again guards against hangs.
timeout 540 python -m repro.launch.loopback --failover-smoke

echo "=== serve e2e smoke: 1 inference server + 2 concurrent client processes ==="
# The DESIGN.md §14 acceptance as a process-level smoke: train a small
# model, snapshot it, boot an inference-server process from the
# checkpoint and two concurrent client processes over 127.0.0.1, and
# require every served result checksum to be bit-identical to an
# in-process FoldInEngine replay of the same requests (the determinism
# contract across process + socket boundaries).  timeout(1) again
# guards against a hung batcher.
timeout 540 python -m repro.launch.serve --smoke

echo "=== artifacts ==="
ls -l BENCH_*.json bench_results.csv
