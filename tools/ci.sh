#!/usr/bin/env bash
# CI entry point: tier-1 tests + quick-mode throughput benchmark.
#
# Runs entirely on CPU — the Pallas kernels execute in interpret mode
# (repro.kernels.ops.INTERPRET defaults to True), so this validates kernel
# semantics and the benchmark pipeline without TPU hardware.
#
# Usage: tools/ci.sh  (from the repo root)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1 tests ==="
python -m pytest -x -q

echo "=== quick throughput benchmark (interpret/CPU) ==="
python -m benchmarks.run --only throughput

echo "=== artifacts ==="
ls -l BENCH_*.json bench_results.csv
