#!/usr/bin/env bash
# CI entry point: tier-1 tests + quick-mode throughput benchmark.
#
# Runs entirely on CPU — the Pallas kernels execute in interpret mode
# (repro.kernels.ops.INTERPRET defaults to True), so this validates kernel
# semantics and the benchmark pipeline without TPU hardware.
#
# Usage: tools/ci.sh  (from the repo root)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== tier-1 tests ==="
python -m pytest -x -q

echo "=== quick benchmarks: throughput + Trainer smoke (interpret/CPU) ==="
# One invocation so bench_results.csv keeps every module's rows.  The
# lda/pdp/hdp modules drive all three model families through
# engine.Trainer and both layouts (writing BENCH_{pdp,hdp}.json), so API
# drift between families breaks CI, not just the nightly benchmarks.
# The throughput module's round_engine / alias_partial_rebuild sections
# track the compiled-round dispatch-overhead win and the incremental
# alias rebuild cost as BENCH_throughput.json artifacts (DESIGN.md §8).
python -m benchmarks.run --only throughput,lda,pdp,hdp --quick

echo "=== artifacts ==="
ls -l BENCH_*.json bench_results.csv
