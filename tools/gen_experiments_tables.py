"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dry-run JSON.

    python tools/gen_experiments_tables.py \
        --base dryrun_single.json dryrun_multi.json \
        --zero dryrun_single_zero.json dryrun_multi_zero.json

Prints markdown to stdout; EXPERIMENTS.md holds the committed copy.
"""

from __future__ import annotations

import argparse
import json


def fmt_b(b: float) -> str:
    if b >= 2**30:
        return f"{b / 2**30:.2f}GiB"
    if b >= 2**20:
        return f"{b / 2**20:.1f}MiB"
    return f"{b / 2**10:.0f}KiB"


def load(paths):
    recs = []
    for p in paths:
        recs.extend(json.load(open(p)))
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--base", nargs="+", default=["dryrun_single.json",
                                                  "dryrun_multi.json"])
    ap.add_argument("--zero", nargs="*", default=[])
    args = ap.parse_args()
    base = load(args.base)
    zero = load(args.zero) if args.zero else []

    print("### §Dry-run\n")
    print("| arch | shape | mesh | kind | compile | HBM/dev | "
          "top collectives (bytes/dev/step) |")
    print("|---|---|---|---|---|---|---|")
    for r in base:
        if r["status"] == "skip":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | skip | "
                  f"— | long-context needs sub-quadratic attention |")
            continue
        if r["status"] == "fail":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | "
                  f"**FAIL** | — | {r.get('error', '?')[:60]} |")
            continue
        ma = r["memory_analysis"]
        hbm = ma["argument_bytes"] + ma["output_bytes"] + ma["temp_bytes"]
        coll = ", ".join(
            f"{k.replace('collective-', 'c-')}:{fmt_b(v)}"
            for k, v in sorted(r["coll_by_kind"].items(),
                               key=lambda x: -x[1])[:3])
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['kind']} | "
              f"{r['compile_s']}s | {fmt_b(hbm)} | {coll or 'none'} |")

    print("\n### §Roofline — baseline\n")
    print("| arch | shape | mesh | t_compute | t_memory | t_collective | "
          "bottleneck | MODEL/analytic | MFU bound |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in base:
        if r["status"] != "ok":
            continue
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
              f"{r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} | "
              f"{r['t_collective_s']:.2e} | {r['bottleneck']} | "
              f"{r['useful_ratio']:.2f} | {r['mfu_bound'] * 100:.2f}% |")

    if zero:
        bmap = {(r["arch"], r["shape"], r["mesh"]): r
                for r in base if r["status"] == "ok"}
        print("\n### §Roofline — optimized (zero modes) vs baseline\n")
        print("| arch | shape | mesh | t_collective base → zero | Δ | "
              "MFU bound base → zero |")
        print("|---|---|---|---|---|---|")
        for r in zero:
            if r["status"] != "ok":
                continue
            b = bmap[(r["arch"], r["shape"], r["mesh"])]
            x = b["t_collective_s"] / max(r["t_collective_s"], 1e-12)
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                  f"{b['t_collective_s']:.2e} → {r['t_collective_s']:.2e} | "
                  f"{x:,.0f}× | {b['mfu_bound'] * 100:.2f}% → "
                  f"{r['mfu_bound'] * 100:.2f}% |")


if __name__ == "__main__":
    main()
