"""Synthetic data: power-law topic-model corpora and LM token streams.

The topic-model generator follows the paper's data regime: Zipf-distributed
word frequencies inside each topic (the power-law the PDP models), Dirichlet
document-topic mixtures, shardable into per-client document shards.  Word
draws use our own (numpy) alias tables, so corpus generation is O(1) per
token even at millions of tokens — the paper's method eating its own tail.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class CorpusConfig:
    n_topics: int = 16
    vocab_size: int = 2048
    n_docs: int = 1024
    doc_len: int = 128          # padded length; actual lengths vary
    theta_conc: float = 0.2     # document Dirichlet
    zipf_a: float = 1.2         # within-topic word-frequency power law
    min_len_frac: float = 0.5
    seed: int = 0


def _np_alias_build(p: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    k = p.shape[0]
    p = p / p.sum()
    scaled = p * k
    prob = np.ones(k)
    alias = np.arange(k)
    small = [i for i in range(k) if scaled[i] < 1.0]
    large = [i for i in range(k) if scaled[i] >= 1.0]
    while small and large:
        i = small.pop()
        j = large.pop()
        prob[i] = scaled[i]
        alias[i] = j
        scaled[j] -= 1.0 - scaled[i]
        (small if scaled[j] < 1.0 else large).append(j)
    return prob, alias


def _np_alias_sample(prob, alias, n, rng):
    slot = rng.integers(0, prob.shape[0], size=n)
    coin = rng.random(n)
    return np.where(coin < prob[slot], slot, alias[slot])


def make_topic_corpus(cfg: CorpusConfig):
    """Returns (tokens (D, L) int32, mask (D, L) bool, true_phi (K, V))."""
    rng = np.random.default_rng(cfg.seed)
    k, v = cfg.n_topics, cfg.vocab_size

    # Power-law topics: each topic permutes a Zipf profile over a random
    # subset ordering of the vocabulary (overlapping supports).
    ranks = np.arange(1, v + 1, dtype=np.float64)
    zipf = ranks ** (-cfg.zipf_a)
    phi = np.zeros((k, v))
    for t in range(k):
        perm = rng.permutation(v)
        phi[t, perm] = zipf / zipf.sum()

    tables = [_np_alias_build(phi[t]) for t in range(k)]
    tokens = np.zeros((cfg.n_docs, cfg.doc_len), np.int32)
    mask = np.zeros((cfg.n_docs, cfg.doc_len), bool)
    min_len = max(1, int(cfg.doc_len * cfg.min_len_frac))
    for d in range(cfg.n_docs):
        length = rng.integers(min_len, cfg.doc_len + 1)
        theta = rng.dirichlet(np.full(k, cfg.theta_conc))
        zs = rng.choice(k, size=length, p=theta)
        for t in np.unique(zs):
            idx = np.nonzero(zs == t)[0]
            prob, alias = tables[t]
            tokens[d, idx] = _np_alias_sample(prob, alias, idx.size, rng)
        mask[d, :length] = True
    return tokens, mask, phi


def shard_corpus(tokens, mask, n_shards: int):
    """Split documents into per-client shards (paper §5.2 data layout)."""
    d = tokens.shape[0]
    per = d // n_shards
    return [(tokens[i * per:(i + 1) * per], mask[i * per:(i + 1) * per])
            for i in range(n_shards)]


# ---------------------------------------------------------------------------
# LM token stream (for the assigned-architecture trainer)
# ---------------------------------------------------------------------------

def lm_batches(vocab_size: int, batch: int, seq_len: int, n_batches: int,
               seed: int = 0, kind: str = "markov", noise: float = 0.1):
    """Synthetic language streams without external data.

    kind="affine": next = (3·cur + 1) mod V with ``noise`` random tokens —
      near-deterministic, learnable to ~1-2 nats within tens of steps (used
      by convergence tests / examples).
    kind="markov": sparse random 2nd-order Markov chain — harder, used for
      longer training runs.
    """
    rng = np.random.default_rng(seed)
    if kind == "affine":
        for _ in range(n_batches):
            out = np.zeros((batch, seq_len), np.int64)
            out[:, 0] = rng.integers(0, vocab_size, size=batch)
            flip = rng.random((batch, seq_len)) < noise
            rnd = rng.integers(0, vocab_size, size=(batch, seq_len))
            for t in range(1, seq_len):
                nxt = (out[:, t - 1] * 3 + 1) % vocab_size
                out[:, t] = np.where(flip[:, t], rnd[:, t], nxt)
            yield {"tokens": out.astype(np.int32)}
        return
    branch = 8
    # successor table: each (context hash) -> `branch` candidate tokens.
    # Context count scales with vocab so small test vocabularies stay
    # learnable within tens of steps.
    n_ctx = min(1 << 16, 4 * vocab_size)
    succ = rng.integers(0, vocab_size, size=(n_ctx, branch), dtype=np.int64)

    def hash_ctx(a, b):
        return ((a * 1000003) ^ b) % n_ctx

    for i in range(n_batches):
        out = np.zeros((batch, seq_len), np.int64)
        out[:, 0] = rng.integers(0, vocab_size, size=batch)
        out[:, 1] = rng.integers(0, vocab_size, size=batch)
        choice = rng.integers(0, branch, size=(batch, seq_len))
        for t in range(2, seq_len):
            ctx = hash_ctx(out[:, t - 2], out[:, t - 1])
            out[:, t] = succ[ctx, choice[:, t]]
        yield {"tokens": out.astype(np.int32)}
