"""Token-type segmentation: the word-major sorted layout (paper §5.1).

The paper's sampler touches each word-topic row once per sweep by walking
the corpus *word-major*: all draws of token-type ``w`` are resolved while
``n_wk[w]`` (and its alias table row) is hot.  On TPU the same idea becomes
a **sorted layout**: flatten a shard's (D, L) token grid, sort the flat
stream by token-type once per sweep, and hand the kernels a per-batch-tile
*vocab-tile window* so every (vocab-tile, batch-tile) grid program whose
tile holds zero resident draws is skipped via scalar prefetch
(DESIGN.md §5).

Because the sort key is the token-type, the vocab tiles touched by any one
batch tile of the sorted stream form a contiguous range — ``vstart[bi]`` to
``vstart[bi] + vcount[bi] - 1`` — so the skip metadata is two small int32
vectors, not a (nb, nv) occupancy matrix.  Padding (masked) positions get
the sentinel row ``vocab_size`` which sorts to the end of the stream and
falls outside every vocab tile, so the kernels never touch them.

The layout depends only on (tokens, mask): drivers should build it once per
shard and reuse it across sweeps (tokens never change between sweeps).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class SortedLayout(NamedTuple):
    """Sorted token stream + tile-skip metadata for one shard.

    With B = D·L flat positions padded up to Bp (a multiple of ``tile_b``):

    Attributes:
      order:   (B,)  int32 — flat position of the i-th sorted draw
               (``flat[order]`` sorts any per-position array; scattering
               with ``.at[order].set`` unsorts the first B sorted entries).
      rows:    (Bp,) int32 — token-type per sorted draw; ``vocab_size``
               marks padding (masked positions + Bp-B fill).
      docs:    (Bp,) int32 — document id per sorted draw (0 for padding).
      real:    (Bp,) bool  — True for genuine (unmasked) tokens.
      vstart:  (nb,) int32 — first vocab tile resident for batch tile bi.
      vcount:  (nb,) int32 — number of vocab tiles resident for batch tile
               bi (0 for all-padding tiles: the whole tile row is skipped).
      hist:    (nv,) int32 — draws per vocab tile (diagnostics/tests).
      offsets: (nv+1,) int32 — CSR-style exclusive prefix sum of ``hist``:
               draws of vocab tile t occupy sorted positions
               [offsets[t], offsets[t+1]) of the real-token prefix.
    """

    order: Array
    rows: Array
    docs: Array
    real: Array
    vstart: Array
    vcount: Array
    hist: Array
    offsets: Array


def chunk_bounds(l: int, n_chunks: int) -> tuple[int, ...]:
    """Position-chunk boundaries for a chunked sorted sweep (static per
    shape): chunk c covers positions [bounds[c], bounds[c+1])."""
    return tuple(round(i * l / n_chunks) for i in range(n_chunks + 1))


def pick_tile(n: int, target: int) -> int:
    """Largest divisor of ``n`` that is ≤ ``target`` (tile-size helper)."""
    for t in range(min(target, n), 0, -1):
        if n % t == 0:
            return t
    return 1


def pick_tile_vmem(v: int, k: int, budget_elems: int = 65536,
                   tile_k: int | None = None) -> int:
    """Vocab tile size from a VMEM budget: the largest divisor of ``v``
    whose (tile_v, K) tile stays within ``budget_elems`` elements per
    resident array (~256 KB fp32 at the default).

    With ``tile_k`` set (the K-tiled kernels), table residency is
    (tile_v, tile_k), so the budget divides by ``tile_k`` instead of K —
    tile_v no longer collapses as K grows, which is what makes the
    (V, K) scale axis usable.

    Small models fit entirely in one tile (minimal grid, no skipping
    needed); production vocabularies tile down and rely on the
    scalar-prefetch skip to keep work ~O(B).
    """
    cols = k if tile_k is None else min(tile_k, k)
    return pick_tile(v, max(1, budget_elems // max(cols, 1)))


@partial(jax.jit, static_argnames=("vocab_size", "tile_v", "tile_b"))
def build_layout(tokens: Array, mask: Array, vocab_size: int, *,
                 tile_v: int, tile_b: int) -> SortedLayout:
    """Sort a shard's token stream by token-type and derive tile-skip data.

    tokens: (D, L) int32 in [0, vocab_size); mask: (D, L) bool.
    Requires ``vocab_size % tile_v == 0``.
    """
    assert vocab_size % tile_v == 0, (vocab_size, tile_v)
    d, l = tokens.shape
    b = d * l
    bp = -(-b // tile_b) * tile_b
    nv = vocab_size // tile_v

    w = tokens.reshape(-1).astype(jnp.int32)
    m = mask.reshape(-1)
    key_rows = jnp.where(m, w, vocab_size)          # sentinel sorts last
    order = jnp.argsort(key_rows, stable=True).astype(jnp.int32)

    rows = key_rows[order]
    docs = (order // l).astype(jnp.int32)
    pad = bp - b
    if pad:
        rows = jnp.concatenate([rows, jnp.full((pad,), vocab_size, jnp.int32)])
        docs = jnp.concatenate([docs, jnp.zeros((pad,), jnp.int32)])
    real = rows < vocab_size

    # Per-batch-tile vocab-tile window.  Sorted ⇒ the touched tiles are the
    # contiguous range [first_row // tile_v, last_real_row // tile_v].
    rs = rows.reshape(bp // tile_b, tile_b)
    has_real = rs[:, 0] < vocab_size                # sorted: first is min
    last_real = jnp.max(jnp.where(rs < vocab_size, rs, -1), axis=1)
    vstart = jnp.where(has_real, rs[:, 0] // tile_v, 0).astype(jnp.int32)
    vend = jnp.where(has_real, last_real // tile_v, -1)
    vcount = (vend - vstart + 1).astype(jnp.int32)

    tile_of = jnp.where(real, rows // tile_v, nv)
    hist = jnp.bincount(tile_of, length=nv + 1)[:nv].astype(jnp.int32)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(hist).astype(jnp.int32)])

    return SortedLayout(order=order, rows=rows, docs=docs, real=real,
                        vstart=vstart, vcount=vcount, hist=hist,
                        offsets=offsets)


def build_chunked_layouts(tokens: Array, mask: Array, vocab_size: int, *,
                          bounds: tuple[int, ...], tile_v: int,
                          tile_b: int) -> tuple[SortedLayout, ...]:
    """Per-position-chunk layouts for ``lda.sweep(layout="sorted")``.

    ``bounds`` are the chunk boundaries over the position axis (see
    :func:`chunk_bounds`); chunk c covers positions [bounds[c], bounds[c+1]).
    Build once per shard and reuse across sweeps.
    """
    d = tokens.shape[0]
    outs = []
    for c in range(len(bounds) - 1):
        s, e = bounds[c], bounds[c + 1]
        outs.append(build_layout(
            tokens[:, s:e], mask[:, s:e], vocab_size, tile_v=tile_v,
            tile_b=min(tile_b, d * (e - s))))
    return tuple(outs)


def sort_values(layout: SortedLayout, flat: Array, fill=0) -> Array:
    """Arrange a flat (B,) per-position array into sorted-stream order (Bp,)."""
    sorted_b = flat[layout.order]
    pad = layout.rows.shape[0] - sorted_b.shape[0]
    if pad:
        fill_arr = jnp.full((pad,), fill, sorted_b.dtype)
        sorted_b = jnp.concatenate([sorted_b, fill_arr])
    return sorted_b


def unsort_values(layout: SortedLayout, sorted_vals: Array, like: Array) -> Array:
    """Invert :func:`sort_values`: scatter sorted-stream values (Bp,) back to
    flat position order, shaped like ``like`` (flat (B,) template)."""
    b = layout.order.shape[0]
    return like.at[layout.order].set(sorted_vals[:b])
