"""Model assembly: init / forward / prefill / decode for every architecture
family (dense, moe, ssm, hybrid, audio enc-dec, vlm).

Design choices that matter at scale:
- **Scan over layers** with stacked (L, ...) parameter leaves: keeps the HLO
  size O(1) in depth (an 80-layer model compiles as fast as a 2-layer one)
  and is what makes the 512-chip dry-run tractable.
- **Remat per layer** (``jax.checkpoint`` around the block body) so the
  backward pass stores only layer inputs.
- Forward returns *hidden states*, not logits: the cross-entropy loss
  computes vocab-sharded logits in sequence chunks (``repro.train.loss``) so
  the (B, S, V) tensor never materializes.
- Decode caches are ring buffers when the config has a sliding window
  (mixtral natively; zamba2's shared attention at the long_500k shape).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (attention_block, cast, cross_attention_block,
                                 embed, init_attention, init_embed, init_mlp,
                                 init_rms_norm, mlp_block, qkv_project,
                                 rms_norm, sdpa, unembed)

Array = jax.Array
Params = dict[str, Any]


# Activation sharding constraint (zero_seq mode): the holder lives in
# layers.py (sdpa adapts its chunking to it); re-exported here for the
# launch layer.
from repro.models.layers import (constrain as _constrain,  # noqa: E402
                                 get_activation_spec, get_block_specs,
                                 set_activation_spec)


def _maybe_cast_blocks(tree: Params, key: str = "blocks") -> Params:
    """zero modes: convert block weights to bf16 BEFORE the layer scan so
    the per-layer ZeRO all-gather moves bf16, not f32 — halves the dominant
    collective (measured in §Perf).  The bf16 copy is pinned to the same
    storage sharding (otherwise XLA sinks the convert back inside the loop
    and gathers f32 — measured).  Master f32 weights are untouched; grads
    flow back through the cast."""
    if get_activation_spec() is None:
        return tree
    specs = (get_block_specs() or {}).get(key)

    def one(x, spec=None):
        if x.dtype != jnp.float32:
            return x
        x = x.astype(jnp.bfloat16)
        if spec is not None:
            x = jax.lax.with_sharding_constraint(x, spec)
        return x

    if specs is None:
        return jax.tree.map(one, tree)
    return jax.tree.map(one, tree, specs)


# ===========================================================================
# Init
# ===========================================================================

def _stack_init(init_fn, key: Array, n: int) -> Params:
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _init_dense_block(cfg: ModelConfig, key: Array) -> Params:
    k1, k2 = jax.random.split(key)
    block = {
        "ln1": init_rms_norm(cfg.d_model),
        "attn": init_attention(cfg, k1),
        "ln2": init_rms_norm(cfg.d_model),
    }
    if cfg.family == "moe":
        block["moe"] = moe_mod.init_moe(cfg, k2)
    else:
        block["mlp"] = init_mlp(cfg, k2)
    return block


def _init_rwkv_block(cfg: ModelConfig, key: Array) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rms_norm(cfg.d_model),
        "tmix": ssm_mod.init_rwkv6_time_mix(cfg, k1),
        "ln2": init_rms_norm(cfg.d_model),
        "cmix": ssm_mod.init_rwkv6_channel_mix(cfg, k2),
    }


def _init_mamba_block(cfg: ModelConfig, key: Array) -> Params:
    return {"ln": init_rms_norm(cfg.d_model),
            "mamba": ssm_mod.init_mamba2(cfg, key)}


def _init_encdec_block(cfg: ModelConfig, key: Array, *, cross: bool) -> Params:
    ks = jax.random.split(key, 3)
    block = {
        "ln1": init_rms_norm(cfg.d_model),
        "attn": init_attention(cfg, ks[0]),
        "ln2": init_rms_norm(cfg.d_model),
        "mlp": init_mlp(cfg, ks[1], kind="gelu"),
    }
    if cross:
        block["ln_x"] = init_rms_norm(cfg.d_model)
        block["xattn"] = init_attention(cfg, ks[2])
    return block


def init_params(cfg: ModelConfig, key: Array) -> Params:
    k_embed, k_blocks, k_extra, k_head = jax.random.split(key, 4)
    params: Params = {"embed": init_embed(cfg, k_embed),
                      "final_norm": init_rms_norm(cfg.d_model)}
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embed(cfg, k_head)

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        params["blocks"] = _stack_init(
            lambda k: _init_dense_block(cfg, k), k_blocks, cfg.n_layers)
        if fam == "vlm":
            kp1, kp2 = jax.random.split(k_extra)
            params["projector"] = {
                "w1": jax.random.normal(kp1, (cfg.vision_dim, cfg.d_model),
                                        jnp.float32) / math.sqrt(cfg.vision_dim),
                "w2": jax.random.normal(kp2, (cfg.d_model, cfg.d_model),
                                        jnp.float32) / math.sqrt(cfg.d_model),
            }
    elif fam == "ssm":
        params["blocks"] = _stack_init(
            lambda k: _init_rwkv_block(cfg, k), k_blocks, cfg.n_layers)
    elif fam == "hybrid":
        params["blocks"] = _stack_init(
            lambda k: _init_mamba_block(cfg, k), k_blocks, cfg.n_layers)
        params["shared_attn"] = _init_dense_block(
            cfg.replace(family="dense"), k_extra)
    elif fam == "audio":
        params["blocks"] = _stack_init(
            lambda k: _init_encdec_block(cfg, k, cross=True), k_blocks,
            cfg.n_layers)
        ke1, ke2 = jax.random.split(k_extra)
        params["encoder"] = {
            "blocks": _stack_init(
                lambda k: _init_encdec_block(cfg, k, cross=False), ke1,
                cfg.encoder_layers),
            "norm": init_rms_norm(cfg.d_model),
            "in_proj": jax.random.normal(
                ke2, (1280 if cfg.d_model == 1280 else cfg.d_model,
                      cfg.d_model), jnp.float32) / math.sqrt(cfg.d_model),
        }
    else:
        raise ValueError(f"unknown family {fam!r}")
    return params


def count_params_analytic(cfg: ModelConfig, active_only: bool = False) -> int:
    """Closed-form parameter count (used for MODEL_FLOPS = 6·N·D)."""
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.head_dim_
    h, kv = cfg.n_heads, cfg.n_kv_heads
    attn = d * hd * (h + 2 * kv) + h * hd * d
    mlp = 3 * d * f
    if cfg.family == "moe":
        e = cfg.top_k if active_only else cfg.n_experts
        mlp = e * 3 * d * f + d * cfg.n_experts
    per_layer = attn + mlp + 2 * d
    if cfg.family == "ssm":
        lora = max(32, d // 16)
        tmix = 5 * d * d + 2 * d * lora + 3 * d
        cmix = 2 * d * f
        per_layer = tmix + cmix + 2 * d
    if cfg.family == "hybrid":
        d_inner, hs, _ = ssm_mod.mamba2_dims(cfg)
        n = cfg.ssm_state
        per_layer = (d * (2 * d_inner + 2 * n + hs) + d_inner * d
                     + cfg.ssm_conv * d_inner + 3 * hs + 2 * d_inner + d)
    total = cfg.n_layers * per_layer
    if cfg.family == "hybrid":
        total += attn + 3 * d * f + 2 * d      # one shared block
    if cfg.family == "audio":
        # decoder blocks use a 2-matrix gelu MLP (not swiglu) and carry an
        # extra cross-attention + its norm.
        total -= cfg.n_layers * (d * f)        # swiglu → gelu correction
        total += cfg.n_layers * (attn + d)     # cross attention + ln_x
        total += cfg.encoder_layers * (attn + 2 * d * f + 2 * d)
        total += d * d + d                     # encoder in_proj + final norm
    if cfg.family == "vlm":
        total += cfg.vision_dim * d + d * d
    total += cfg.padded_vocab * d * (1 if cfg.tie_embeddings else 2)
    return int(total)


# ===========================================================================
# Forward (train / prefill)
# ===========================================================================

def _sinusoidal(positions: Array, d: int) -> Array:
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / half)
    ang = positions[:, None].astype(jnp.float32) * freqs[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)


def _dense_block_fn(cfg: ModelConfig, bp: Params, x: Array, positions: Array
                    ) -> tuple[Array, Array]:
    h = attention_block(cfg, bp["attn"], rms_norm(x, bp["ln1"], cfg.norm_eps),
                        positions)
    x = x + h
    inner = rms_norm(x, bp["ln2"], cfg.norm_eps)
    if "moe" in bp:
        m, aux = moe_mod.moe_block(cfg, bp["moe"], inner)
    else:
        m, aux = mlp_block(bp["mlp"], inner), jnp.zeros((), jnp.float32)
    return x + m, aux


def _rwkv_block_fn(cfg, bp, x):
    h, _, _ = ssm_mod.rwkv6_time_mix(cfg, bp["tmix"],
                                     rms_norm(x, bp["ln1"], cfg.norm_eps))
    x = x + h
    c, _ = ssm_mod.rwkv6_channel_mix(cfg, bp["cmix"],
                                     rms_norm(x, bp["ln2"], cfg.norm_eps))
    return x + c


def _mamba_block_fn(cfg, bp, x):
    h, _, _ = ssm_mod.mamba2_block(cfg, bp["mamba"],
                                   rms_norm(x, bp["ln"], cfg.norm_eps))
    return x + h


def _scan_blocks(body, params_stacked: Params, x: Array, remat: bool):
    if remat:
        body = jax.checkpoint(body)

    def step(carry, bp):
        x, aux = carry
        x2, aux2 = body(bp, _constrain(x))
        return (_constrain(x2), aux + aux2), None

    (x, aux), _ = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)),
                               params_stacked)
    return x, aux


def forward(cfg: ModelConfig, params: Params, batch: dict[str, Array], *,
            remat: bool = True) -> tuple[Array, Array]:
    """Returns (hidden (B, S, D), aux_loss).  ``batch`` needs "tokens" plus
    "patch_embeds" (vlm) or "frames" (audio)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.arange(s)
    x = _constrain(embed(params["embed"], tokens))

    fam = cfg.family
    if fam == "vlm":
        pe = batch["patch_embeds"]                       # (B, P, vision_dim)
        proj = jnp.einsum("bpv,vd->bpd", cast(pe), cast(params["projector"]["w1"]))
        proj = jax.nn.gelu(proj)
        proj = jnp.einsum("bpd,de->bpe", proj, cast(params["projector"]["w2"]))
        x = jax.lax.dynamic_update_slice(x, proj.astype(x.dtype), (0, 0, 0))

    blocks = _maybe_cast_blocks(params["blocks"])
    if fam in ("dense", "moe", "vlm"):
        x, aux = _scan_blocks(
            lambda bp, h: _dense_block_fn(cfg, bp, h, positions),
            blocks, x, remat)
    elif fam == "ssm":
        x, aux = _scan_blocks(
            lambda bp, h: (_rwkv_block_fn(cfg, bp, h), jnp.zeros((), jnp.float32)),
            blocks, x, remat)
    elif fam == "hybrid":
        x, aux = _hybrid_forward(cfg, dict(params, blocks=blocks,
                                           shared_attn=_maybe_cast_blocks(
                                               params["shared_attn"],
                                               "shared_attn")),
                                 x, positions, remat)
    elif fam == "audio":
        x, aux = _audio_forward(cfg, dict(params,
                                          blocks=blocks,
                                          encoder=_maybe_cast_blocks(
                                              params["encoder"],
                                              "encoder")),
                                x, batch["frames"], positions, remat)
    else:
        raise ValueError(fam)

    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def _hybrid_forward(cfg, params, x, positions, remat):
    """Zamba2: groups of ``attn_every`` mamba layers, each followed by the
    SHARED attention block (same weights every application)."""
    g = cfg.attn_every
    n_groups = cfg.n_layers // g
    grouped = jax.tree.map(
        lambda a: a.reshape((n_groups, g) + a.shape[1:]), params["blocks"])
    shared = params["shared_attn"]

    def group_body(bp_group, h):
        def inner(carry, bp):
            return _mamba_block_fn(cfg, bp, carry), None
        h, _ = jax.lax.scan(inner, h, bp_group)
        h2, _ = _dense_block_fn(cfg, shared, h, positions)
        return h2, jnp.zeros((), jnp.float32)

    return _scan_blocks(group_body, grouped, x, remat)


def _audio_forward(cfg, params, x, frames, positions, remat):
    """Whisper: encode stub frame embeddings, then causal decoder with
    cross-attention.  Sinusoidal positions on both sides (DESIGN.md notes
    the learned-table deviation)."""
    enc = params["encoder"]
    fpos = jnp.arange(frames.shape[1])
    mem = cast(frames) @ cast(enc["in_proj"])
    mem = mem + _sinusoidal(fpos, cfg.d_model)[None].astype(mem.dtype)

    def enc_body(bp, h):
        a = attention_block(cfg, bp["attn"], rms_norm(h, bp["ln1"], cfg.norm_eps),
                            fpos, causal=False, rope=False)
        h = h + a
        m = mlp_block(bp["mlp"], rms_norm(h, bp["ln2"], cfg.norm_eps))
        return h + m, jnp.zeros((), jnp.float32)

    mem, _ = _scan_blocks(enc_body, enc["blocks"], mem, remat)
    mem = rms_norm(mem, enc["norm"], cfg.norm_eps)

    x = x + _sinusoidal(positions, cfg.d_model)[None].astype(x.dtype)

    def dec_body(bp, h):
        a = attention_block(cfg, bp["attn"], rms_norm(h, bp["ln1"], cfg.norm_eps),
                            positions, causal=True, rope=False)
        h = h + a
        mk = jnp.einsum("bfd,dhk->bfhk", mem, cast(bp["xattn"]["wk"]))
        mv = jnp.einsum("bfd,dhk->bfhk", mem, cast(bp["xattn"]["wv"]))
        c = cross_attention_block(cfg, bp["xattn"],
                                  rms_norm(h, bp["ln_x"], cfg.norm_eps),
                                  mk.astype(h.dtype), mv.astype(h.dtype))
        h = h + c
        m = mlp_block(bp["mlp"], rms_norm(h, bp["ln2"], cfg.norm_eps))
        return h + m, jnp.zeros((), jnp.float32)

    return _scan_blocks(dec_body, params["blocks"], x, remat)


def logits_fn(cfg: ModelConfig, params: Params, hidden: Array) -> Array:
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return unembed(table, hidden)


# ===========================================================================
# Decode (serve_step): one token against a preallocated cache
# ===========================================================================

def cache_len(cfg: ModelConfig, max_len: int) -> int:
    if cfg.sliding_window and cfg.sliding_window < max_len:
        return cfg.sliding_window
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Params:
    """Zeros/empty cache pytree for ``decode_step`` (also the ShapeDtypeStruct
    template for the dry run)."""
    hd, kv = cfg.head_dim_, cfg.n_kv_heads
    s = cache_len(cfg, max_len)
    fam = cfg.family
    cache: Params = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.sliding_window and cfg.sliding_window < max_len:
        cache["key_pos"] = jnp.full((s,), -1, jnp.int32)

    def attn_cache(n, seq):
        return {"k": jnp.zeros((n, batch, seq, kv, hd), dtype),
                "v": jnp.zeros((n, batch, seq, kv, hd), dtype)}

    if fam in ("dense", "moe", "vlm"):
        cache["layers"] = attn_cache(cfg.n_layers, s)
    elif fam == "ssm":
        h, p = ssm_mod.rwkv_dims(cfg)
        cache["layers"] = {
            "state": jnp.zeros((cfg.n_layers, batch, h, p, p), jnp.float32),
            "shift1": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model),
                                jnp.float32),
            "shift2": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model),
                                jnp.float32),
        }
    elif fam == "hybrid":
        d_inner, h, p = ssm_mod.mamba2_dims(cfg)
        n_groups = cfg.n_layers // cfg.attn_every
        cache["layers"] = {
            "state": jnp.zeros((cfg.n_layers, batch, h, cfg.ssm_state, p),
                               jnp.float32),
            "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, d_inner),
                              jnp.float32),
        }
        cache["shared_attn"] = attn_cache(n_groups, s)
    elif fam == "audio":
        cache["layers"] = attn_cache(cfg.n_layers, s)
        cache["cross"] = attn_cache(cfg.n_layers, cfg.n_frames)
    return cache


# ===========================================================================
# Prefill: full-sequence forward that also materializes the decode cache
# ===========================================================================

def prefill(cfg: ModelConfig, params: Params, batch: dict[str, Array],
            max_len: int) -> tuple[Array, Params]:
    """Run the prompt through the model and build the decode cache.

    Returns (last-token logits (B, 1, Vp), cache with pos = S).  For
    sliding-window configs only the last ``window`` keys are retained
    (ring-buffer layout, aligned so subsequent decode writes continue it).
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    positions = jnp.arange(s)
    x = _constrain(embed(params["embed"], tokens))
    fam = cfg.family
    s_cache = cache_len(cfg, max_len)
    cache: Params = {"pos": jnp.asarray(s, jnp.int32)}

    def clip_kv(k):  # keep the last s_cache positions, ring-aligned
        if s <= s_cache:
            pad = s_cache - s
            return jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        tail = k[:, s - s_cache:]
        shift = s % s_cache
        return jnp.roll(tail, shift, axis=1)

    if cfg.sliding_window and cfg.sliding_window < max_len:
        # Position stored in ring slot i is the largest p < s with
        # p % s_cache == i (or -1 if that slot is still empty).
        i = jnp.arange(s_cache)
        last = s - 1 - ((s - 1 - i) % s_cache)
        cache["key_pos"] = jnp.where((last >= 0) & (last >= s - s_cache),
                                     last, -1).astype(jnp.int32)

    if fam in ("dense", "moe", "vlm"):
        if fam == "vlm":
            pe = batch["patch_embeds"]
            proj = jnp.einsum("bpv,vd->bpd", cast(pe),
                              cast(params["projector"]["w1"]))
            proj = jax.nn.gelu(proj)
            proj = jnp.einsum("bpd,de->bpe", proj,
                              cast(params["projector"]["w2"]))
            x = jax.lax.dynamic_update_slice(x, proj.astype(x.dtype), (0, 0, 0))

        def body(carry, bp):
            h = carry
            xn = rms_norm(h, bp["ln1"], cfg.norm_eps)
            q, k, v = qkv_project(cfg, bp["attn"], xn, positions)
            o = sdpa(q, k, v, causal=True, window=cfg.sliding_window)
            h = h + jnp.einsum("bshk,hkd->bsd", o, cast(bp["attn"]["wo"]),
                               preferred_element_type=jnp.float32
                               ).astype(h.dtype)
            inner = rms_norm(h, bp["ln2"], cfg.norm_eps)
            if "moe" in bp:
                m, _ = moe_mod.moe_block(cfg, bp["moe"], inner)
            else:
                m = mlp_block(bp["mlp"], inner)
            return _constrain(h + m), (clip_kv(k), clip_kv(v))

        x, (ks, vs) = jax.lax.scan(body, x, _maybe_cast_blocks(params["blocks"]))
        cache["layers"] = {"k": ks.astype(jnp.bfloat16),
                           "v": vs.astype(jnp.bfloat16)}

    elif fam == "ssm":
        def body(carry, bp):
            h = carry
            xn = rms_norm(h, bp["ln1"], cfg.norm_eps)
            o, sh1, st = ssm_mod.rwkv6_time_mix(cfg, bp["tmix"], xn)
            h = h + o
            xn2 = rms_norm(h, bp["ln2"], cfg.norm_eps)
            c, sh2 = ssm_mod.rwkv6_channel_mix(cfg, bp["cmix"], xn2)
            return _constrain(h + c), (st, sh1, xn2[:, -1:])

        x, (st, s1, s2) = jax.lax.scan(body, x, _maybe_cast_blocks(params["blocks"]))
        cache["layers"] = {"state": st,
                           "shift1": s1.astype(jnp.float32),
                           "shift2": s2.astype(jnp.float32)}

    elif fam == "hybrid":
        g = cfg.attn_every
        n_groups = cfg.n_layers // g
        grouped = jax.tree.map(
            lambda a: a.reshape((n_groups, g) + a.shape[1:]), params["blocks"])
        shared = params["shared_attn"]

        def group_body(carry, bp_g):
            h = carry

            def inner(c2, bp):
                xn = rms_norm(c2, bp["ln"], cfg.norm_eps)
                o, conv, st = ssm_mod.mamba2_block(cfg, bp["mamba"], xn)
                return c2 + o, (conv, st)

            h, (conv, st) = jax.lax.scan(inner, h, bp_g)
            xn = rms_norm(h, shared["ln1"], cfg.norm_eps)
            q, k, v = qkv_project(cfg, shared["attn"], xn, positions)
            o = sdpa(q, k, v, causal=True, window=cfg.sliding_window)
            h = h + jnp.einsum("bshk,hkd->bsd", o, cast(shared["attn"]["wo"]),
                               preferred_element_type=jnp.float32
                               ).astype(h.dtype)
            m = mlp_block(shared["mlp"], rms_norm(h, shared["ln2"], cfg.norm_eps))
            return _constrain(h + m), (conv, st, clip_kv(k), clip_kv(v))

        x, (conv, st, ks, vs) = jax.lax.scan(group_body, x, grouped)
        cache["layers"] = {
            "conv": conv.reshape((cfg.n_layers,) + conv.shape[2:]).astype(jnp.float32),
            "state": st.reshape((cfg.n_layers,) + st.shape[2:])}
        cache["shared_attn"] = {"k": ks.astype(jnp.bfloat16),
                                "v": vs.astype(jnp.bfloat16)}

    elif fam == "audio":
        enc = params["encoder"]
        frames = batch["frames"]
        fpos = jnp.arange(frames.shape[1])
        mem = cast(frames) @ cast(enc["in_proj"])
        mem = mem + _sinusoidal(fpos, cfg.d_model)[None].astype(mem.dtype)

        def enc_body(carry, bp):
            h = carry
            a = attention_block(cfg, bp["attn"],
                                rms_norm(h, bp["ln1"], cfg.norm_eps),
                                fpos, causal=False, rope=False)
            h = h + a
            m = mlp_block(bp["mlp"], rms_norm(h, bp["ln2"], cfg.norm_eps))
            return h + m, None

        mem, _ = jax.lax.scan(enc_body, mem, enc["blocks"])
        mem = rms_norm(mem, enc["norm"], cfg.norm_eps)

        x = x + _sinusoidal(positions, cfg.d_model)[None].astype(x.dtype)

        def dec_body(carry, bp):
            h = carry
            xn = rms_norm(h, bp["ln1"], cfg.norm_eps)
            q, k, v = qkv_project(cfg, bp["attn"], xn, positions, rope=False)
            o = sdpa(q, k, v, causal=True)
            h = h + jnp.einsum("bshk,hkd->bsd", o, cast(bp["attn"]["wo"]),
                               preferred_element_type=jnp.float32
                               ).astype(h.dtype)
            mk = jnp.einsum("bfd,dhk->bfhk", mem, cast(bp["xattn"]["wk"]))
            mv = jnp.einsum("bfd,dhk->bfhk", mem, cast(bp["xattn"]["wv"]))
            c = cross_attention_block(cfg, bp["xattn"],
                                      rms_norm(h, bp["ln_x"], cfg.norm_eps),
                                      mk.astype(h.dtype), mv.astype(h.dtype))
            h = h + c
            m = mlp_block(bp["mlp"], rms_norm(h, bp["ln2"], cfg.norm_eps))
            return _constrain(h + m), (clip_kv(k), clip_kv(v), mk, mv)

        x, (ks, vs, xks, xvs) = jax.lax.scan(dec_body, x, params["blocks"])
        cache["layers"] = {"k": ks.astype(jnp.bfloat16),
                           "v": vs.astype(jnp.bfloat16)}
        cache["cross"] = {"k": xks.astype(jnp.bfloat16),
                          "v": xvs.astype(jnp.bfloat16)}
    else:
        raise ValueError(fam)

    h = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return logits_fn(cfg, params, h), cache


def _attn_step(cfg, bp, x, k_cache, v_cache, pos, key_pos, rope=True):
    """One-token attention against a cache layer; returns (out, k', v')."""
    s_cache = k_cache.shape[1]
    windowed = key_pos is not None
    write_at = (pos % s_cache) if windowed else pos
    q, k, v = qkv_project(cfg, bp, x, pos[None][None], rope=rope)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype),
                                           (0, write_at, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype),
                                           (0, write_at, 0, 0))
    if windowed:
        # ring buffer: mask by key_pos validity instead of a prefix length
        out = _ring_sdpa(cfg, q, k_cache, v_cache, key_pos, pos, write_at)
    else:
        out = sdpa(q, k_cache, v_cache, causal=False, kv_len=pos + 1)
    o = jnp.einsum("bshk,hkd->bsd", out, cast(bp["wo"]),
                    preferred_element_type=jnp.float32)
    return o.astype(x.dtype), k_cache, v_cache


def _ring_sdpa(cfg, q, k_cache, v_cache, key_pos, pos, write_at):
    import math as _math
    b, _, h, hd = q.shape
    kv = k_cache.shape[2]
    rep = h // kv
    qg = q.reshape(b, 1, kv, rep, hd)
    scores = jnp.einsum("bqgrh,bkgh->bgrqk", qg, k_cache,
                        preferred_element_type=jnp.float32)
    scores = scores / _math.sqrt(hd)
    valid = (key_pos >= 0) | (jnp.arange(k_cache.shape[1]) == write_at)
    scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, -1)
    out = jnp.einsum("bgrqk,bkgh->bqgrh", probs.astype(q.dtype), v_cache)
    return out.reshape(b, 1, h, hd)


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                tokens: Array) -> tuple[Array, Params]:
    """One decode step for a (B, 1) token batch.  Returns (logits, cache')."""
    pos = cache["pos"]
    x = embed(params["embed"], tokens)
    fam = cfg.family
    key_pos = cache.get("key_pos")

    if fam in ("dense", "moe", "vlm"):
        def body(x, bp_and_cache):
            bp, kc, vc = bp_and_cache
            h, kc2, vc2 = _attn_step(cfg, bp["attn"],
                                     rms_norm(x, bp["ln1"], cfg.norm_eps),
                                     kc, vc, pos, key_pos)
            x = x + h
            inner = rms_norm(x, bp["ln2"], cfg.norm_eps)
            if "moe" in bp:
                m, _ = moe_mod.moe_block(cfg, bp["moe"], inner)
            else:
                m = mlp_block(bp["mlp"], inner)
            return x + m, (kc2, vc2)

        def scan_fn(carry, xs):
            bp, kc, vc = xs
            x2, (kc2, vc2) = body(carry, (bp, kc, vc))
            return x2, (kc2, vc2)

        x, (k_new, v_new) = jax.lax.scan(
            scan_fn, x, (params["blocks"], cache["layers"]["k"],
                         cache["layers"]["v"]))
        new_layers = {"k": k_new, "v": v_new}

    elif fam == "ssm":
        def scan_fn(carry, xs):
            bp, state, sh1, sh2 = xs
            x = carry
            h, sh1b, state2 = ssm_mod.rwkv6_time_mix_step(
                cfg, bp["tmix"], rms_norm(x, bp["ln1"], cfg.norm_eps), sh1, state)
            x = x + h
            xn = rms_norm(x, bp["ln2"], cfg.norm_eps)
            c, sh2b = ssm_mod.rwkv6_channel_mix(cfg, bp["cmix"], xn,
                                                shift_prev=sh2)
            # channel-mix shift carry must be the *normalized* input
            return x + c, (state2, sh1b, xn[:, -1:])

        x, (st, s1, s2) = jax.lax.scan(
            scan_fn, x, (params["blocks"], cache["layers"]["state"],
                         cache["layers"]["shift1"], cache["layers"]["shift2"]))
        new_layers = {"state": st, "shift1": s1, "shift2": s2}
        # NOTE: rwkv token-shift operates on the *normalized* stream; we store
        # the normalized x for both mixes (see test_ssm_decode_consistency).

    elif fam == "hybrid":
        g = cfg.attn_every
        n_groups = cfg.n_layers // g
        grouped = jax.tree.map(
            lambda a: a.reshape((n_groups, g) + a.shape[1:]), params["blocks"])
        conv_g = cache["layers"]["conv"].reshape(
            (n_groups, g) + cache["layers"]["conv"].shape[1:])
        state_g = cache["layers"]["state"].reshape(
            (n_groups, g) + cache["layers"]["state"].shape[1:])
        shared = params["shared_attn"]

        def group_fn(carry, xs):
            bp_g, conv_gg, state_gg, kc, vc = xs
            x = carry

            def inner(c2, xs2):
                bp, conv, st = xs2
                h, conv2, st2 = ssm_mod.mamba2_step(
                    cfg, bp["mamba"], rms_norm(c2, bp["ln"], cfg.norm_eps),
                    conv, st)
                return c2 + h, (conv2, st2)

            x, (conv2, st2) = jax.lax.scan(inner, x, (bp_g, conv_gg, state_gg))
            h, kc2, vc2 = _attn_step(cfg, shared["attn"],
                                     rms_norm(x, shared["ln1"], cfg.norm_eps),
                                     kc, vc, pos, key_pos)
            x = x + h
            m = mlp_block(shared["mlp"], rms_norm(x, shared["ln2"], cfg.norm_eps))
            return x + m, (conv2, st2, kc2, vc2)

        x, (conv_n, state_n, k_n, v_n) = jax.lax.scan(
            group_fn, x, (grouped, conv_g, state_g,
                          cache["shared_attn"]["k"], cache["shared_attn"]["v"]))
        new_layers = {"conv": conv_n.reshape(cache["layers"]["conv"].shape),
                      "state": state_n.reshape(cache["layers"]["state"].shape)}

    elif fam == "audio":
        x = x + _sinusoidal(pos[None], cfg.d_model)[None].astype(x.dtype)

        def scan_fn(carry, xs):
            bp, kc, vc, xk, xv = xs
            x = carry
            h, kc2, vc2 = _attn_step(cfg, bp["attn"],
                                     rms_norm(x, bp["ln1"], cfg.norm_eps),
                                     kc, vc, pos, key_pos, rope=False)
            x = x + h
            c = cross_attention_block(cfg, bp["xattn"],
                                      rms_norm(x, bp["ln_x"], cfg.norm_eps),
                                      xk, xv)
            x = x + c
            m = mlp_block(bp["mlp"], rms_norm(x, bp["ln2"], cfg.norm_eps))
            return x + m, (kc2, vc2)

        x, (k_n, v_n) = jax.lax.scan(
            scan_fn, x, (params["blocks"], cache["layers"]["k"],
                         cache["layers"]["v"], cache["cross"]["k"],
                         cache["cross"]["v"]))
        new_layers = {"k": k_n, "v": v_n}
    else:
        raise ValueError(fam)

    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_fn(cfg, params, h)

    new_cache = dict(cache)
    new_cache["pos"] = pos + 1
    if fam == "hybrid":
        new_cache["shared_attn"] = {"k": k_n, "v": v_n}
        new_cache["layers"] = new_layers
    else:
        new_cache["layers"] = new_layers
    if key_pos is not None:
        s_cache = key_pos.shape[0]
        new_cache["key_pos"] = key_pos.at[pos % s_cache].set(pos)
    return logits, new_cache
