"""Mixture-of-Experts layer: top-k routing with capacity-bounded, sort-based
dispatch (TPU-native; no dense (T, E, C) one-hot dispatch tensors).

Two sharding regimes, chosen by the config (see ``repro.train.sharding``):

* expert-parallel (phi3.5-moe: E=16 divides the model axis) — expert weights
  sharded on the expert dim; the (E, C, D) dispatch buffer crosses from
  token-sharding (data) to expert-sharding (model), which XLA lowers to an
  all-to-all — the communication pattern the paper's parameter-server
  analysis stresses for sparse models.
* tensor-parallel experts (mixtral: E=8 does not divide 16) — every expert's
  d_ff is Megatron-sharded over the model axis; no all-to-all, one psum.

Dispatch algorithm (static shapes throughout):
  1. router logits → top-k experts + weights per token;
  2. flatten (token, slot) pairs, sort by expert id;
  3. position-in-expert via sorted-order cumsum; tokens beyond the per-expert
     capacity C = ceil(T·k/E · capacity_factor) are *dropped* (standard
     Switch/GShard semantics; the router aux loss keeps loads balanced);
  4. scatter into the (E, C, D) buffer, batched expert matmuls, scatter back
     weighted by router gates.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import cast, get_activation_spec, get_mesh

Array = jax.Array
Params = dict[str, Any]


def init_moe(cfg: ModelConfig, key: Array) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    return {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * s_in,
        "w_gate": jax.random.normal(ks[1], (e, d, f), jnp.float32) * s_in,
        "w_up": jax.random.normal(ks[2], (e, d, f), jnp.float32) * s_in,
        "w_down": jax.random.normal(ks[3], (e, f, d), jnp.float32) * s_out,
    }


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = int(math.ceil(n_tokens * cfg.top_k / cfg.n_experts
                      * cfg.capacity_factor))
    return max(8, ((c + 7) // 8) * 8)  # pad to an 8-multiple for tiling


def _dispatch(cfg: ModelConfig, xt: Array, gate_vals: Array,
              expert_ids: Array, c: int) -> tuple[Array, Array, Array, Array]:
    """Sort-based dispatch of ONE token group: (T', D) → (E, C, D) buffer
    plus (slot, keep, sorted_token/gate) combine metadata."""
    t, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    flat_expert = expert_ids.reshape(-1)                      # (T'*k,)
    flat_token = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_gate = gate_vals.reshape(-1)

    order = jnp.argsort(flat_expert)                          # stable
    sorted_expert = flat_expert[order]
    sorted_token = flat_token[order]
    sorted_gate = flat_gate[order]

    # position of each (token, slot) within its expert's queue
    counts = jnp.zeros((e,), jnp.int32).at[sorted_expert].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1]])
    pos_in_expert = jnp.arange(t * k, dtype=jnp.int32) - offsets[sorted_expert]
    keep = pos_in_expert < c

    # scatter tokens into the (E, C, D) buffer
    slot = jnp.where(keep, sorted_expert * c + pos_in_expert, e * c)
    buf = jnp.zeros((e * c + 1, d), xt.dtype).at[slot].set(xt[sorted_token])
    return buf[:-1].reshape(e, c, d), slot, keep, (sorted_token, sorted_gate)


def _combine(out_buf: Array, slot: Array, keep: Array, meta, t: int,
             dtype) -> Array:
    """Scatter expert outputs of one group back to (T', D) token order."""
    sorted_token, sorted_gate = meta
    e, c, d = out_buf.shape
    gathered = out_buf.reshape(e * c, d)[jnp.where(keep, slot, 0)]
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    return jnp.zeros((t, d), dtype).at[sorted_token].add(
        gathered * sorted_gate[:, None].astype(dtype))




def _constrain_dispatch(buf: Array) -> Array:
    """(G, E, C, D): pin G over the FULL device grid (scatter stays local).

    Without this, consumer propagation pushes expert-sharding into the
    dispatch scatter whose indices are data-dependent — XLA then replicates
    the scattered operand (measured 32 GiB/layer all-gathers).  Pinning the
    buffer local leaves exactly one reshard (G releases the model axis, E
    acquires it) at the einsum below.  Constraining E over model here
    instead triggers SPMD full-rematerialization — measured 3.4× worse."""
    act = get_activation_spec()
    if act is None:
        return buf
    ax = act[0] if isinstance(act[0], tuple) else (act[0],)
    g_ax = ax if "model" in ax else ax + ("model",)
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(buf, P(g_ax, None, None, None))


def _moe_a2a(cfg: ModelConfig, p: Params, xg: Array, gateg: Array,
             idsg: Array, c: int, mesh, dtype) -> Array:
    """Expert-parallel MoE with explicit all-to-all (shard_map).

    One token group per device (G = mesh size, group g on device g):
      1. device-local sort-based dispatch → (E, C, D);
      2. ``all_to_all`` over the model axis: each model-rank keeps its
         E/m experts and receives their tokens from all peers →
         (E/m, m·C, D);
      3. expert MLPs at jit level — buf (E@model, ·@rest, D) is already
         aligned with the expert-sharded weights, zero collectives;
      4. reverse all_to_all + device-local combine.
    This is the paper's client→server key routing made physical: tokens
    (updates) travel to the shard that owns their expert (parameter row),
    compute happens there, results return — two all-to-alls of exactly
    the dispatched bytes, nothing replicated.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    g, tg, d = xg.shape
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes["model"]
    e = cfg.n_experts
    rest = tuple(a for a in mesh.axis_names if a != "model")
    all_ax = tuple(mesh.axis_names)
    g_spec = P(all_ax, None, None)
    meta_spec = P(all_ax, None)

    def dispatch(xx, gg, ii):
        buf, slot, keep, (st, sg) = _dispatch(cfg, xx[0], gg[0], ii[0], c)
        buf2 = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                  tiled=True)              # (E/m, m·C, D)
        return buf2, slot[None], keep[None], st[None], sg[None]

    buf2, slot, keep, st, sg = shard_map(
        dispatch, mesh=mesh,
        in_specs=(g_spec, g_spec, g_spec),
        out_specs=(P("model", rest, None), meta_spec, meta_spec, meta_spec,
                   meta_spec),
        check_rep=False,
    )(xg, gateg, idsg)

    # Expert MLPs: buf2 (E@model, CC@rest, D) × weights (E@model, ·, ·) —
    # expert dims aligned, no collectives.
    gate_h = jnp.einsum("ecd,edf->ecf", buf2, cast(p["w_gate"]),
                        preferred_element_type=jnp.float32)
    up_h = jnp.einsum("ecd,edf->ecf", buf2, cast(p["w_up"]),
                      preferred_element_type=jnp.float32)
    h = (jax.nn.silu(gate_h) * up_h).astype(dtype)
    out_buf = jnp.einsum("ecf,efd->ecd", h, cast(p["w_down"]),
                         preferred_element_type=jnp.float32).astype(dtype)

    def combine(ob, sl, kp, stt, sgg):
        back = jax.lax.all_to_all(ob, "model", split_axis=1, concat_axis=0,
                                  tiled=True)               # (E, C, D)
        out = _combine(back, sl[0], kp[0], (stt[0], sgg[0]), tg, dtype)
        return out[None]

    out = shard_map(
        combine, mesh=mesh,
        in_specs=(P("model", rest, None), meta_spec, meta_spec, meta_spec,
                  meta_spec),
        out_specs=g_spec,
        check_rep=False,
    )(out_buf, slot, keep, st, sg)
    return out


def moe_block(cfg: ModelConfig, p: Params, x: Array
              ) -> tuple[Array, Array]:
    """x: (B, S, D) → (out, aux_loss).

    Dispatch is GROUPED (``cfg.moe_groups`` token groups, vmapped): each
    group sorts/paks its own tokens with a per-group capacity.  With groups
    aligned to the device grid (zero modes set G = mesh size) the argsort,
    scatter and combine are all device-LOCAL and the only cross-device
    movement is the (G, E, C, D) → expert-sharded buffer reshard — the
    all-to-all that expert parallelism actually requires.  A single global
    sort (G=1) makes the token permutation span all devices and XLA falls
    back to replicate+all-reduce of (T·k, D) dispatch tensors — measured
    64 GiB/layer on phi3.5-moe (§Perf).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    g = cfg.moe_groups or 1
    if t % g:
        g = 1
    tg = t // g
    c = capacity(cfg, tg)
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt, cast(p["router"]),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)           # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # Load-balance auxiliary loss (Switch-style): E * Σ_e f_e · P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / (t * k))
    aux = e * jnp.sum(me * ce) * cfg.router_aux_weight

    # ---- grouped local dispatch ----------------------------------------
    xg = xt.reshape(g, tg, d)
    gateg = gate_vals.reshape(g, tg, k)
    idsg = expert_ids.reshape(g, tg, k)

    mesh = get_mesh()
    act = get_activation_spec()
    batch_covers_model = (act is not None and isinstance(act[0], tuple)
                          and "model" in act[0])
    if (mesh is not None and "model" in mesh.axis_names
            and batch_covers_model     # zero_batch: groups align 1:1 devices
            and g == int(mesh.devices.size)
            and e % dict(zip(mesh.axis_names, mesh.devices.shape))["model"] == 0):
        # shard_map path: device-local dispatch + explicit all_to_all.
        # Plain-jit alternatives all fail (measured, §Perf): XLA either
        # replicates the data-dependent scatter (32 GiB/layer all-gathers)
        # or full-remats the constrained reshard.
        out = _moe_a2a(cfg, p, xg, gateg, idsg, c, mesh, x.dtype)
        return out.reshape(b, s, d), aux

    buf, slot, keep, meta = jax.vmap(
        lambda xx, gg, ii: _dispatch(cfg, xx, gg, ii, c))(xg, gateg, idsg)
    # buf: (G, E, C, D) — G sharded over the device grid, E to be
    # expert-sharded by the einsum below (the all-to-all boundary).
    buf = _constrain_dispatch(buf)

    # ---- batched expert MLPs (E-sharded weights) ------------------------
    if g == 1:
        # 3-D form: XLA:CPU's DotThunk executes this (tests/examples); the
        # 4-D grouped form below is compile-only on CPU (dry-run).
        b3 = buf[0]
        gate_h = jnp.einsum("ecd,edf->ecf", b3, cast(p["w_gate"]),
                            preferred_element_type=jnp.float32)
        up_h = jnp.einsum("ecd,edf->ecf", b3, cast(p["w_up"]),
                          preferred_element_type=jnp.float32)
        h = (jax.nn.silu(gate_h) * up_h).astype(x.dtype)
        out_buf = jnp.einsum("ecf,efd->ecd", h, cast(p["w_down"]),
                             preferred_element_type=jnp.float32
                             ).astype(x.dtype)[None]
    else:
        gate_h = jnp.einsum("gecd,edf->gecf", buf, cast(p["w_gate"]),
                            preferred_element_type=jnp.float32)
        up_h = jnp.einsum("gecd,edf->gecf", buf, cast(p["w_up"]),
                          preferred_element_type=jnp.float32)
        h = (jax.nn.silu(gate_h) * up_h).astype(x.dtype)
        out_buf = jnp.einsum("gecf,efd->gecd", h, cast(p["w_down"]),
                             preferred_element_type=jnp.float32
                             ).astype(x.dtype)

    # ---- combine back (group-local) -------------------------------------
    out = jax.vmap(
        lambda ob, sl, kp, mt: _combine(ob, sl, kp, mt, tg, x.dtype))(
        out_buf, slot, keep, meta)
    return out.reshape(b, s, d), aux


def moe_block_dense_ref(cfg: ModelConfig, p: Params, x: Array) -> Array:
    """Oracle: evaluate every expert on every token and mix by gates
    (no capacity drops).  Used by tests on small shapes."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xt, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, cfg.top_k)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    gate_h = jnp.einsum("td,edf->etf", xt, p["w_gate"].astype(x.dtype))
    up_h = jnp.einsum("td,edf->etf", xt, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(gate_h) * up_h
    all_out = jnp.einsum("etf,efd->etd", h, p["w_down"].astype(x.dtype))

    mask = jax.nn.one_hot(expert_ids, cfg.n_experts, dtype=jnp.float32)
    weights = jnp.einsum("tk,tke->te", gate_vals, mask)       # (T, E)
    out = jnp.einsum("te,etd->td", weights.astype(x.dtype), all_out)
    return out.reshape(b, s, d)
