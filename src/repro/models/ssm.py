"""SSM / linear-recurrence blocks: RWKV-6 (Finch) and Mamba-2 (SSD).

Both ride on ``repro.models.linear_attn``; the block code handles the
projections, data-dependent decay, token shift / short conv, and gating.

RWKV-6 [arXiv:2404.05892]: the headline Finch feature — *data-dependent
decay* w_t = exp(-exp(w0 + tanh(x̃ Wa) Wb)) — is implemented exactly; the
r/k/v/g token-shift interpolation uses static learned mixes (the paper's
LoRA-ified mixes change capacity, not structure).

Mamba-2 [arXiv:2405.21060-style SSD as used by Zamba2]: scalar-per-head
decay exp(-softplus(dt)·exp(A_log)), depthwise causal conv front, RMSNorm
gate, D skip.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import linear_attn as la
from repro.models.layers import cast, init_rms_norm, rms_norm

Array = jax.Array
Params = dict[str, Any]


# ---------------------------------------------------------------------------
# RWKV-6 time mix + channel mix
# ---------------------------------------------------------------------------

def rwkv_dims(cfg: ModelConfig) -> tuple[int, int]:
    h = cfg.ssm_heads or cfg.d_model // 64
    return h, cfg.d_model // h  # (heads, head_dim)


def init_rwkv6_time_mix(cfg: ModelConfig, key: Array) -> Params:
    d = cfg.d_model
    h, hd = rwkv_dims(cfg)
    lora = max(32, d // 16)
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d)
    return {
        "mix_r": jnp.full((d,), 0.5, jnp.float32),
        "mix_k": jnp.full((d,), 0.5, jnp.float32),
        "mix_v": jnp.full((d,), 0.5, jnp.float32),
        "mix_g": jnp.full((d,), 0.5, jnp.float32),
        "mix_w": jnp.full((d,), 0.5, jnp.float32),
        "wr": jax.random.normal(ks[0], (d, d), jnp.float32) * s,
        "wk": jax.random.normal(ks[1], (d, d), jnp.float32) * s,
        "wv": jax.random.normal(ks[2], (d, d), jnp.float32) * s,
        "wg": jax.random.normal(ks[3], (d, d), jnp.float32) * s,
        "wo": jax.random.normal(ks[4], (d, d), jnp.float32) * s,
        # data-dependent decay LoRA: w = exp(-exp(w0 + tanh(x Wa) Wb))
        "w0": jnp.full((d,), -2.0, jnp.float32),
        "wa": jax.random.normal(ks[5], (d, lora), jnp.float32) * s,
        "wb": jax.random.normal(ks[6], (lora, d), jnp.float32) * (1.0 / math.sqrt(lora)),
        "u": jax.random.normal(ks[7], (h, hd), jnp.float32) * 0.1,  # bonus
        "ln_out": init_rms_norm(d),
    }


def _token_shift(x: Array, prev: Array | None) -> Array:
    """x_{t-1} with x_{-1} = prev (decode carry) or 0."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def rwkv6_time_mix(cfg: ModelConfig, p: Params, x: Array, *,
                   shift_prev: Array | None = None,
                   state: Array | None = None, chunk: int = 64
                   ) -> tuple[Array, Array, Array]:
    """Returns (out, last_x (B,1,D) shift carry, final state (B,H,K,P))."""
    b, s, d = x.shape
    h, hd = rwkv_dims(cfg)
    xp = _token_shift(x, shift_prev)

    def mixed(name):
        m = cast(p["mix_" + name])
        return x * m + xp * (1.0 - m)

    r = jnp.einsum("bsd,de->bse", mixed("r"), cast(p["wr"]),
                   preferred_element_type=jnp.float32)
    k = jnp.einsum("bsd,de->bse", mixed("k"), cast(p["wk"]),
                   preferred_element_type=jnp.float32)
    v = jnp.einsum("bsd,de->bse", mixed("v"), cast(p["wv"]),
                   preferred_element_type=jnp.float32)
    g = jnp.einsum("bsd,de->bse", mixed("g"), cast(p["wg"]),
                   preferred_element_type=jnp.float32)
    # data-dependent decay (per channel = per (head, key-dim))
    wx = mixed("w")
    lw = (p["w0"].astype(jnp.float32)
          + jnp.tanh(jnp.einsum("bsd,dl->bsl", wx, cast(p["wa"]),
                   preferred_element_type=jnp.float32).astype(jnp.float32))
          @ p["wb"].astype(jnp.float32))
    log_w = -jnp.exp(lw)                               # (B,S,D), ≤ 0

    rh = r.reshape(b, s, h, hd)
    kh = k.reshape(b, s, h, hd)
    vh = v.reshape(b, s, h, hd)
    lwh = log_w.reshape(b, s, h, hd)

    out, new_state = la.linear_attention(
        rh, kh, vh, lwh, chunk=min(chunk, s), inclusive=False,
        u=p["u"].astype(jnp.float32), initial_state=state)
    out = out.reshape(b, s, d).astype(x.dtype)
    out = rms_norm(out, p["ln_out"], cfg.norm_eps) * jax.nn.silu(g)
    out = jnp.einsum("bsd,de->bse", out, cast(p["wo"]),
                     preferred_element_type=jnp.float32)
    return out.astype(x.dtype), x[:, -1:], new_state


def rwkv6_time_mix_step(cfg: ModelConfig, p: Params, x_t: Array,
                        shift_prev: Array, state: Array
                        ) -> tuple[Array, Array, Array]:
    """Decode step: x_t (B,1,D).  Returns (out, new shift carry, new state)."""
    b, _, d = x_t.shape
    h, hd = rwkv_dims(cfg)

    def mixed(name):
        m = cast(p["mix_" + name])
        return x_t * m + shift_prev * (1.0 - m)

    r = jnp.einsum("bsd,de->bse", mixed("r"), cast(p["wr"]),
                   preferred_element_type=jnp.float32)[:, 0]
    k = jnp.einsum("bsd,de->bse", mixed("k"), cast(p["wk"]),
                   preferred_element_type=jnp.float32)[:, 0]
    v = jnp.einsum("bsd,de->bse", mixed("v"), cast(p["wv"]),
                   preferred_element_type=jnp.float32)[:, 0]
    g = jnp.einsum("bsd,de->bse", mixed("g"), cast(p["wg"]),
                   preferred_element_type=jnp.float32)[:, 0]
    wx = mixed("w")
    lw = (p["w0"].astype(jnp.float32)
          + jnp.tanh(jnp.einsum("bsd,dl->bsl", wx, cast(p["wa"]),
                   preferred_element_type=jnp.float32).astype(jnp.float32))
          @ p["wb"].astype(jnp.float32))[:, 0]
    log_w = -jnp.exp(lw)

    out, new_state = la.linear_attention_step(
        r.reshape(b, h, hd).astype(jnp.float32),
        k.reshape(b, h, hd).astype(jnp.float32),
        v.reshape(b, h, hd).astype(jnp.float32),
        log_w.reshape(b, h, hd), state, inclusive=False,
        u=p["u"].astype(jnp.float32))
    out = out.reshape(b, 1, d).astype(x_t.dtype)
    out = rms_norm(out, p["ln_out"], cfg.norm_eps) * jax.nn.silu(g)[:, None]
    out = jnp.einsum("bsd,de->bse", out, cast(p["wo"]),
                     preferred_element_type=jnp.float32)
    return out.astype(x_t.dtype), x_t, new_state


def init_rwkv6_channel_mix(cfg: ModelConfig, key: Array) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2 = jax.random.split(key)
    return {
        "mix_k": jnp.full((d,), 0.5, jnp.float32),
        "wk": jax.random.normal(k1, (d, f), jnp.float32) / math.sqrt(d),
        "wv": jax.random.normal(k2, (f, d), jnp.float32) / math.sqrt(f),
    }


def rwkv6_channel_mix(cfg: ModelConfig, p: Params, x: Array, *,
                      shift_prev: Array | None = None) -> tuple[Array, Array]:
    xp = _token_shift(x, shift_prev)
    m = cast(p["mix_k"])
    xk = x * m + xp * (1.0 - m)
    h = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, cast(p["wk"]),
                   preferred_element_type=jnp.float32)))
    return jnp.einsum("bsf,fd->bsd", h, cast(p["wv"]),
                      preferred_element_type=jnp.float32).astype(x.dtype), x[:, -1:]


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) block
# ---------------------------------------------------------------------------

def mamba2_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    """(d_inner, n_heads, head_dim)."""
    d_inner = 2 * cfg.d_model
    heads = cfg.ssm_heads or d_inner // 64
    return d_inner, heads, d_inner // heads


def init_mamba2(cfg: ModelConfig, key: Array) -> Params:
    d = cfg.d_model
    d_inner, h, hd = mamba2_dims(cfg)
    n = cfg.ssm_state
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    # in_proj emits [z (d_inner), x (d_inner), B (n), C (n), dt (h)]
    d_proj = 2 * d_inner + 2 * n + h
    return {
        "w_in": jax.random.normal(ks[0], (d, d_proj), jnp.float32) * s,
        "conv": jax.random.normal(ks[1], (cfg.ssm_conv, d_inner), jnp.float32)
                * (1.0 / math.sqrt(cfg.ssm_conv)),
        "conv_b": jnp.zeros((d_inner,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h).astype(jnp.float32)),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm": init_rms_norm(d_inner),
        "w_out": jax.random.normal(ks[2], (d_inner, d), jnp.float32)
                 * (1.0 / math.sqrt(d_inner)),
    }


def _causal_conv(x: Array, w: Array, b: Array, prev: Array | None) -> tuple[Array, Array]:
    """Depthwise causal conv1d.  x (B,S,C), w (W,C).  ``prev`` is the (B,W-1,C)
    carry for decode.  Returns (out, new carry)."""
    width = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * cast(w[i]) for i in range(width))
    return out + cast(b), xp[:, -(width - 1):]


def _mamba2_core(cfg, p, x):
    """Shared projections: returns (z, xc_preconv, B, C, dt) split."""
    d_inner, h, hd = mamba2_dims(cfg)
    n = cfg.ssm_state
    proj = jnp.einsum("bsd,de->bse", x, cast(p["w_in"]),
                      preferred_element_type=jnp.float32)
    z, xc, bmat, cmat, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n],
        axis=-1)
    return z, xc, bmat, cmat, dt


def mamba2_block(cfg: ModelConfig, p: Params, x: Array, *,
                 conv_prev: Array | None = None, state: Array | None = None,
                 chunk: int = 64) -> tuple[Array, Array, Array]:
    """Returns (out, conv carry, ssm state)."""
    b, s, _ = x.shape
    d_inner, h, hd = mamba2_dims(cfg)
    n = cfg.ssm_state

    z, xc, bmat, cmat, dt = _mamba2_core(cfg, p, x)
    xc, conv_carry = _causal_conv(xc, p["conv"], p["conv_b"], conv_prev)
    xc = jax.nn.silu(xc)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])     # (B,S,H)
    log_w = (-jnp.exp(p["a_log"])[None, None] * dt)[..., None]      # (B,S,H,1)
    v = xc.reshape(b, s, h, hd).astype(jnp.float32)
    # B/C shared across heads (ngroups=1): k_t = dt·B_t, r_t = C_t
    k = (dt[..., None] * bmat[:, :, None, :].astype(jnp.float32))   # (B,S,H,N)
    r = jnp.broadcast_to(cmat[:, :, None, :].astype(jnp.float32), (b, s, h, n))

    out, new_state = la.linear_attention(
        r, k, v, log_w, chunk=min(chunk, s), inclusive=True,
        initial_state=state)
    out = out + p["d_skip"][None, None, :, None] * v
    out = out.reshape(b, s, d_inner).astype(x.dtype)
    out = rms_norm(out * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return (jnp.einsum("bse,ed->bsd", out, cast(p["w_out"]),
                       preferred_element_type=jnp.float32).astype(x.dtype),
            conv_carry, new_state)


def mamba2_step(cfg: ModelConfig, p: Params, x_t: Array, conv_prev: Array,
                state: Array) -> tuple[Array, Array, Array]:
    """Decode step, x_t (B,1,D)."""
    b = x_t.shape[0]
    d_inner, h, hd = mamba2_dims(cfg)
    n = cfg.ssm_state

    z, xc, bmat, cmat, dt = _mamba2_core(cfg, p, x_t)
    xc, conv_carry = _causal_conv(xc, p["conv"], p["conv_b"], conv_prev)
    xc = jax.nn.silu(xc)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    log_w = (-jnp.exp(p["a_log"])[None] * dt)[..., None]               # (B,H,1)
    v = xc.reshape(b, h, hd).astype(jnp.float32)
    k = dt[..., None] * bmat[:, 0, None, :].astype(jnp.float32)
    r = jnp.broadcast_to(cmat[:, 0, None, :].astype(jnp.float32), (b, h, n))

    out, new_state = la.linear_attention_step(r, k, v, log_w, state,
                                              inclusive=True)
    out = out + p["d_skip"][None, :, None] * v
    out = out.reshape(b, 1, d_inner).astype(x_t.dtype)
    out = rms_norm(out * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return (jnp.einsum("bse,ed->bsd", out, cast(p["w_out"]),
                       preferred_element_type=jnp.float32).astype(x_t.dtype),
            conv_carry, new_state)
