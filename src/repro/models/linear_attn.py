"""Chunked linear attention with (data-dependent) decay.

Shared engine for RWKV-6 (vector decay per key channel, exclusive recurrence
with a current-token bonus ``u``) and Mamba-2 / SSD (scalar decay per head,
inclusive recurrence):

    S_t = diag(w_t) · S_{t-1} + k_t v_tᵀ              (state: K×P per head)
    RWKV-6:  out_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ)
    Mamba-2: out_t = r_t · S_t

A naive scan over time is O(S) sequential steps; the chunked form processes
``chunk`` tokens per step with dense contractions — the standard TPU-native
formulation (intra-chunk masked attention with decay ratios + inter-chunk
state carry).

Numerical-stability design: the textbook separable form
``(r_t e^{L_t})·(k_s e^{-L_s})`` overflows once cumulative decay within a
chunk exceeds ~88 nats (Mamba-2 decays routinely reach hundreds).  Instead
the intra-chunk term uses the *direct pairwise* ratio exp(L_t − L_s), whose
exponent is ≤ 0 for every causal (t, s) pair because L is non-increasing —
unconditionally overflow-free.  The pairwise tensor is blocked over the key
dimension (``K_BLOCK``) to bound the transient to (B,H,C,C,K_BLOCK).  The
inter-chunk factors are all ≤ 1 by the same monotonicity.  (A Pallas kernel
could recover MXU matmuls with sub-block rebasing; the roofline for SSM
archs is HBM-bound, so the VPU form does not move the bottleneck.)

``linear_attention_ref`` is the step-by-step oracle used by tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

MIN_LOG_W = -60.0   # per-step floor: e^-60 is already an exact-zero carry in f32
K_BLOCK = 32        # key-dim blocking for the pairwise intra-chunk tensor


def linear_attention_ref(r, k, v, log_w, *, inclusive: bool,
                         u: Array | None = None, initial_state=None):
    """Oracle: sequential scan.  r/k: (B,S,H,K), v: (B,S,H,P),
    log_w: (B,S,H,K) or (B,S,H,1).  Returns (out (B,S,H,P), state (B,H,K,P))."""
    b, s, h, kd = k.shape
    p = v.shape[-1]
    log_w = jnp.broadcast_to(jnp.clip(log_w, MIN_LOG_W, 0.0), (b, s, h, kd))
    state0 = (jnp.zeros((b, h, kd, p), jnp.float32) if initial_state is None
              else initial_state.astype(jnp.float32))

    def step(state, inp):
        r_t, k_t, v_t, lw_t = inp  # (B,H,K), (B,H,K), (B,H,P), (B,H,K)
        outer = k_t[..., :, None] * v_t[..., None, :]       # (B,H,K,P)
        new_state = jnp.exp(lw_t)[..., None] * state + outer
        if inclusive:
            out = jnp.einsum("bhk,bhkp->bhp", r_t, new_state)
        else:
            base = state + (u[None, :, :, None] * outer if u is not None else 0.0)
            out = jnp.einsum("bhk,bhkp->bhp", r_t, base)
        return new_state, out

    xs = (r.transpose(1, 0, 2, 3).astype(jnp.float32),
          k.transpose(1, 0, 2, 3).astype(jnp.float32),
          v.transpose(1, 0, 2, 3).astype(jnp.float32),
          log_w.transpose(1, 0, 2, 3).astype(jnp.float32))
    state, outs = jax.lax.scan(step, state0, xs)
    return outs.transpose(1, 0, 2, 3), state


def linear_attention(r, k, v, log_w, *, chunk: int = 64, inclusive: bool,
                     u: Array | None = None, initial_state=None):
    """Chunked evaluation; same contract as ``linear_attention_ref``."""
    b, s, h, kd = k.shape
    p = v.shape[-1]
    if s % chunk:
        raise ValueError(f"seq {s} must be a multiple of chunk {chunk}")
    n = s // chunk
    log_w = jnp.broadcast_to(jnp.clip(log_w, MIN_LOG_W, 0.0),
                             (b, s, h, kd)).astype(jnp.float32)

    rc = r.reshape(b, n, chunk, h, kd).astype(jnp.float32)
    kc = k.reshape(b, n, chunk, h, kd).astype(jnp.float32)
    vc = v.reshape(b, n, chunk, h, p).astype(jnp.float32)
    lwc = log_w.reshape(b, n, chunk, h, kd)

    state0 = (jnp.zeros((b, h, kd, p), jnp.float32) if initial_state is None
              else initial_state.astype(jnp.float32))

    t_idx = jnp.arange(chunk)
    if inclusive:
        pair_mask = t_idx[:, None] >= t_idx[None, :]   # s ≤ t
    else:
        pair_mask = t_idx[:, None] > t_idx[None, :]    # s < t

    n_kb = max(1, kd // K_BLOCK)
    while kd % n_kb:
        n_kb -= 1
    kb = kd // n_kb

    def chunk_step(state, inp):
        r_i, k_i, v_i, lw_i = inp      # (B,C,H,K) / (B,C,H,P)
        lw_cum = jnp.cumsum(lw_i, axis=1)              # inclusive cumsum L_t
        lw_tot = lw_cum[:, -1]                         # (B,H,K)

        # Inter-chunk: carry-in state contribution.
        #   exclusive: out_t += (r_t ⊙ P_{t-1}) S_prev  with P_{t-1}=exp(L_t - lw_t)
        #   inclusive: out_t += (r_t ⊙ P_t) S_prev
        l_q = lw_cum if inclusive else lw_cum - lw_i   # ≤ 0 everywhere
        q_tilde = r_i * jnp.exp(l_q)
        out = jnp.einsum("bchk,bhkp->bchp", q_tilde, state)

        # Intra-chunk, direct pairwise (overflow-free: exponent ≤ 0 on the
        # causal mask), blocked over the key dim.
        def k_block(i, att):
            sl = jax.lax.dynamic_slice_in_dim
            r_b = sl(r_i, i * kb, kb, axis=3)
            k_b = sl(k_i, i * kb, kb, axis=3)
            lq_b = sl(l_q, i * kb, kb, axis=3)
            lk_b = sl(lw_cum, i * kb, kb, axis=3)
            d = lq_b[:, :, None] - lk_b[:, None, :, :]   # (B,C,C,H,kb), ≤0 causal
            term = jnp.einsum("bchk,bdhk,bcdhk->bhcd", r_b, k_b,
                              jnp.exp(jnp.minimum(d, 0.0)))
            return att + term

        att = jax.lax.fori_loop(0, n_kb, k_block,
                                jnp.zeros((b, h, chunk, chunk), jnp.float32))
        att = jnp.where(pair_mask[None, None], att, 0.0)
        out = out + jnp.einsum("bhcd,bdhp->bchp", att, v_i)

        if not inclusive and u is not None:
            # current-token bonus (RWKV-6 ``u``)
            bonus = jnp.einsum("bchk,bchk->bch", r_i * u[None, None], k_i)
            out = out + bonus[..., None] * v_i

        # State carry: S' = diag(exp(L_C)) S + Σ_s exp(L_C - L_s) k_s v_sᵀ
        k_carry = k_i * jnp.exp(lw_tot[:, None] - lw_cum)
        new_state = (jnp.exp(lw_tot)[..., None] * state
                     + jnp.einsum("bchk,bchp->bhkp", k_carry, v_i))
        return new_state, out

    xs = (rc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4), lwc.transpose(1, 0, 2, 3, 4))
    state, outs = jax.lax.scan(chunk_step, state0, xs)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return out, state


def linear_attention_step(r_t, k_t, v_t, log_w_t, state, *, inclusive: bool,
                          u: Array | None = None):
    """Single decode step.  r_t/k_t: (B,H,K), v_t: (B,H,P), state (B,H,K,P).
    Returns (out (B,H,P), new_state)."""
    log_w_t = jnp.clip(log_w_t, MIN_LOG_W, 0.0)
    lw = jnp.broadcast_to(log_w_t, k_t.shape).astype(jnp.float32)
    outer = k_t[..., :, None] * v_t[..., None, :]
    new_state = jnp.exp(lw)[..., None] * state.astype(jnp.float32) + outer
    if inclusive:
        out = jnp.einsum("bhk,bhkp->bhp", r_t, new_state)
    else:
        base = state.astype(jnp.float32)
        if u is not None:
            base = base + u[None, :, :, None] * outer
        out = jnp.einsum("bhk,bhkp->bhp", r_t, base)
    return out, new_state
