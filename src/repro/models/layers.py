"""Shared neural building blocks (pure functional JAX).

Weight layout notes:
- Attention projections are stored 4-D as (d_model, n_heads, head_dim) so
  sharding can target either the head axis (when divisible by the mesh) or
  the head_dim axis (GQA KV heads rarely divide a 16-way axis; head_dim
  does) — see ``repro.train.sharding``.
- All matmuls run in bf16 with f32 accumulation (``preferred_element_type``);
  master weights stay f32 and are cast at use.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Array = jax.Array
Params = dict[str, Any]

COMPUTE_DTYPE = jnp.bfloat16


def cast(x: Array) -> Array:
    return x.astype(COMPUTE_DTYPE)


# ---------------------------------------------------------------------------
# Activation sharding constraint (zero_seq mode; see train/sharding.py).
# Lives here (not model.py) so sdpa can adapt its q-chunking: with the
# sequence dim sharded, slicing q into chunks would re-shard every chunk —
# the per-device q is already S/16 long, so chunking is disabled instead.
# ---------------------------------------------------------------------------

_ACT_SPEC = None
_BLOCK_SPECS = None   # storage PartitionSpecs for params["blocks"] etc.
_MESH = None          # the mesh being lowered against (shard_map dispatch)


def set_activation_spec(spec, block_specs=None, mesh=None) -> None:
    global _ACT_SPEC, _BLOCK_SPECS, _MESH
    _ACT_SPEC = spec
    _BLOCK_SPECS = block_specs
    _MESH = mesh


def get_activation_spec():
    return _ACT_SPEC


def get_block_specs():
    return _BLOCK_SPECS


def get_mesh():
    return _MESH


def constrain(x: Array) -> Array:
    if _ACT_SPEC is not None and x.ndim == 3:
        return jax.lax.with_sharding_constraint(x, _ACT_SPEC)
    return x


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def init_rms_norm(d: int) -> Array:
    return jnp.ones((d,), jnp.float32)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window / qk-norm / bias), q-chunked
# ---------------------------------------------------------------------------

def init_attention(cfg: ModelConfig, key: Array) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(h * hd)
    p = {
        "wq": jax.random.normal(k1, (d, h, hd), jnp.float32) * s_in,
        "wk": jax.random.normal(k2, (d, kv, hd), jnp.float32) * s_in,
        "wv": jax.random.normal(k3, (d, kv, hd), jnp.float32) * s_in,
        "wo": jax.random.normal(k4, (h, hd, d), jnp.float32) * s_out,
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((h, hd), jnp.float32)
        p["bk"] = jnp.zeros((kv, hd), jnp.float32)
        p["bv"] = jnp.zeros((kv, hd), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = init_rms_norm(hd)
        p["k_norm"] = init_rms_norm(hd)
    return p


def qkv_project(cfg: ModelConfig, p: Params, x: Array, positions: Array,
                rope: bool = True) -> tuple[Array, Array, Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, cast(p["wq"]),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", x, cast(p["wk"]),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, cast(p["wv"]),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    if cfg.attn_bias:
        q = q + cast(p["bq"])
        k = k + cast(p["bk"])
        v = v + cast(p["bv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def sdpa(q: Array, k: Array, v: Array, *, causal: bool, window: int = 0,
         q_offset: Array | int = 0, kv_len: Array | None = None,
         q_chunk: int = 1024) -> Array:
    """Grouped-query scaled dot-product attention, chunked over queries.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd).  ``q_offset`` is the absolute
    position of q[0] (decode: cache length so far).  ``kv_len`` optionally
    masks the valid prefix of the KV buffers (decode with preallocated
    caches).  Chunking over Sq bounds the transient score buffer to
    (B, KV, rep, q_chunk, Sk) — the TPU VMEM-friendly shape — instead of the
    full Sq×Sk matrix.
    """
    b, sq, h, hd = q.shape
    _, sk, kv, _ = k.shape
    rep = h // kv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(b, sq, kv, rep, hd)
    kpos = jnp.arange(sk)

    def attend(q_blk: Array, blk_offset: Array) -> Array:
        c = q_blk.shape[1]
        scores = jnp.einsum("bqgrh,bkgh->bgrqk", q_blk, k,
                            preferred_element_type=jnp.float32) * scale
        qpos = blk_offset + jnp.arange(c) + q_offset
        mask = jnp.ones((c, sk), bool)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window:
            mask &= kpos[None, :] > qpos[:, None] - window
        if kv_len is not None:
            mask &= kpos[None, :] < kv_len
        scores = jnp.where(mask[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bgrqk,bkgh->bqgrh", probs.astype(q.dtype), v,
                         preferred_element_type=jnp.float32)
        return out.astype(q.dtype).reshape(b, c, h, hd)

    seq_sharded = _ACT_SPEC is not None and len(_ACT_SPEC) > 1 \
        and _ACT_SPEC[1] is not None
    if sq <= q_chunk or seq_sharded:
        # zero_seq: q's sequence dim is model-sharded; chunking would
        # re-shard every chunk (measured: ×4 trip over every layer's K/V
        # gather).  The per-device transient is (B/d, KV, rep, S/m, S) —
        # already 1/(d·m) of the global score tensor.
        return attend(qg, jnp.asarray(0))

    # Largest divisor of Sq not exceeding q_chunk (Sq=1500 → 750): keeps the
    # scan uniform without padding (whisper's 1500 encoder frames, etc.).
    while sq % q_chunk:
        q_chunk -= 1
    n_chunks = sq // q_chunk
    # Scan over q chunks with a rematerialized body: the backward pass
    # recomputes each chunk's scores/probs instead of storing the stacked
    # (B, KV, rep, q_chunk, Sk) residuals — the flash-attention memory
    # profile, structurally (kernels/ carries the Pallas version).
    qg_chunks = qg.reshape(b, n_chunks, q_chunk, kv, rep, hd).transpose(
        1, 0, 2, 3, 4, 5)
    attend_ckpt = jax.checkpoint(attend)

    def body(_, xs):
        q_blk, i = xs
        return None, attend_ckpt(q_blk, i * q_chunk)

    _, out = jax.lax.scan(body, None, (qg_chunks, jnp.arange(n_chunks)))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


def attention_block(cfg: ModelConfig, p: Params, x: Array, positions: Array,
                    *, causal: bool = True, rope: bool = True,
                    window: int | None = None) -> Array:
    q, k, v = qkv_project(cfg, p, x, positions, rope=rope)
    w = cfg.sliding_window if window is None else window
    out = sdpa(q, k, v, causal=causal, window=w)
    return jnp.einsum("bshk,hkd->bsd", out, cast(p["wo"]),
                      preferred_element_type=jnp.float32).astype(x.dtype)


def cross_attention_block(cfg: ModelConfig, p: Params, x: Array,
                          mem_k: Array, mem_v: Array) -> Array:
    """Decoder cross-attention over precomputed encoder K/V (no rope)."""
    positions = jnp.arange(x.shape[1])
    q = jnp.einsum("bsd,dhk->bshk", x, cast(p["wq"]),
                   preferred_element_type=jnp.float32).astype(x.dtype)
    out = sdpa(q, mem_k, mem_v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, cast(p["wo"]),
                      preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, key: Array, kind: str = "swiglu") -> Params:
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    if kind == "swiglu":
        return {
            "w_gate": jax.random.normal(k1, (d, f), jnp.float32) * s_in,
            "w_up": jax.random.normal(k2, (d, f), jnp.float32) * s_in,
            "w_down": jax.random.normal(k3, (f, d), jnp.float32) * s_out,
        }
    return {  # gelu (whisper)
        "w_up": jax.random.normal(k1, (d, f), jnp.float32) * s_in,
        "w_down": jax.random.normal(k2, (f, d), jnp.float32) * s_out,
    }


def mlp_block(p: Params, x: Array) -> Array:
    if "w_gate" in p:
        gate = jnp.einsum("bsd,df->bsf", x, cast(p["w_gate"]),
                          preferred_element_type=jnp.float32)
        up = jnp.einsum("bsd,df->bsf", x, cast(p["w_up"]),
                        preferred_element_type=jnp.float32)
        h = (jax.nn.silu(gate) * up).astype(x.dtype)
    else:
        up = jnp.einsum("bsd,df->bsf", x, cast(p["w_up"]),
                        preferred_element_type=jnp.float32)
        h = jax.nn.gelu(up).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, cast(p["w_down"]),
                      preferred_element_type=jnp.float32).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(cfg: ModelConfig, key: Array) -> Array:
    return (jax.random.normal(key, (cfg.padded_vocab, cfg.d_model), jnp.float32)
            * (1.0 / math.sqrt(cfg.d_model)))


def embed(table: Array, tokens: Array) -> Array:
    return cast(table)[tokens]


def unembed(table: Array, h: Array) -> Array:
    """Logits against the (possibly tied) embedding table: (B, S, Vp)."""
    return jnp.einsum("bsd,vd->bsv", h, cast(table),
                      preferred_element_type=jnp.float32)
