"""AdamW + LR schedules, hand-rolled (optax is not in the environment).

Optimizer state mirrors the parameter pytree (m, v per leaf) and inherits
its sharding, which is what makes ZeRO-style sharded optimizer state free:
the state shards wherever the parameter shards.
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamWState(NamedTuple):
    step: Array
    m: Any
    v: Any


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def update(params, grads, state: AdamWState, *, lr: Array | float,
           b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
           weight_decay: float = 0.1,
           grad_clip: float = 1.0) -> tuple[Any, AdamWState]:
    """One AdamW step with global-norm gradient clipping."""
    if grad_clip:
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                         state.v, grads)

    def leaf_update(p, m, v):
        mhat = m / b1c
        vhat = v / b2c
        upd = mhat / (jnp.sqrt(vhat) + eps)
        # decoupled weight decay on matrices only (norms/biases excluded by
        # dimensionality — the standard heuristic)
        wd = weight_decay if p.ndim >= 2 else 0.0
        return p - lr * (upd + wd * p)

    new_params = jax.tree.map(leaf_update, params, new_m, new_v)
    return new_params, AdamWState(step=step, m=new_m, v=new_v)


def global_norm(tree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def cosine_schedule(step: Array, *, peak_lr: float, warmup: int,
                    total: int, min_ratio: float = 0.1) -> Array:
    """Linear warmup + cosine decay to ``min_ratio``·peak."""
    stepf = step.astype(jnp.float32)
    warm = stepf / max(warmup, 1)
    prog = jnp.clip((stepf - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(math.pi * prog))
    return peak_lr * jnp.where(stepf < warmup, warm, cos)
