"""Sharding-aware checkpointing (the paper's §5.4 snapshot mechanism).

Snapshots are the fault-tolerance substrate: clients and servers write their
state every N minutes without a global barrier; recovery re-reads the latest
snapshot and re-pulls fresh parameters.  On the JAX side a snapshot is a
flattened pytree written with numpy (no orbax in the environment); restore
re-places leaves onto their shardings.

Layout: ``<dir>/<name>-<step>.npz`` + a ``<name>.MANIFEST`` file recording
the latest complete snapshot (write-then-rename, so a preempted writer
never corrupts the recovery point — the asynchronous-snapshot property of
§5.4).  The manifest stores snapshot **basenames**, never joined paths, so
a snapshot directory can be relocated (moved between machines, remounted
under a different root) and still recovers — paths are re-joined against
the manifest's own directory at read time.  It also keeps the history of
written steps, so :func:`restore_latest` can fall back to an earlier
snapshot when the newest file turns out truncated or corrupt
(:class:`CorruptSnapshotError`) — a half-written ``.npz`` must never lose
the run when an older complete one exists.
"""

from __future__ import annotations

import json
import os
import tempfile
import zipfile
import zlib
from typing import Any

import jax
import numpy as np

SEP = "/"

# Errors that a truncated / bit-rotted npz raises anywhere between open
# and member decompression.
_NPZ_READ_ERRORS = (OSError, EOFError, ValueError, KeyError,
                    zipfile.BadZipFile, zlib.error)


def _dtype_kind(dt) -> str:
    """numpy's dtype kind, with extension float dtypes (bfloat16 and
    friends register as kind 'V') normalized to 'f' — bf16 is saved
    widened to f32, so the narrowing back must count as same-kind."""
    dt = np.dtype(dt)
    if dt.kind == "V" and jax.numpy.issubdtype(dt, np.floating):
        return "f"
    return dt.kind


class CorruptSnapshotError(RuntimeError):
    """The snapshot file exists but cannot be read back (truncated write,
    bit rot, missing npz member).  Distinct from a template mismatch
    (``ValueError``): a corrupt snapshot is recoverable by falling back to
    an earlier manifest entry (:func:`restore_latest`); a template
    mismatch means the caller is restoring into the wrong structure."""


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":   # npz has no native bf16: widen;
            arr = arr.astype(np.float32)   # restore() re-narrows via template
        flat[key] = arr
    return flat


def _read_manifest(directory: str, name: str) -> dict | None:
    manifest = os.path.join(directory, f"{name}.MANIFEST")
    if not os.path.exists(manifest):
        return None
    with open(manifest) as f:
        return json.load(f)


def save(directory: str, name: str, step: int, tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    fname = f"{name}-{step}.npz"
    path = os.path.join(directory, fname)
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)
    prev = _read_manifest(directory, name) or {}
    # History of completed steps (newest last); legacy manifests carried
    # only "step".
    steps = list(prev.get("steps", []))
    if not steps and "step" in prev:
        steps = [prev["step"]]
    if step not in steps:
        steps.append(step)
    manifest = os.path.join(directory, f"{name}.MANIFEST")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "w") as f:
        # Basename only: a relocated snapshot directory must stay
        # recoverable, so the path is re-joined against the manifest's
        # directory at read time.
        json.dump({"latest": fname, "step": step,
                   "steps": sorted(set(steps))}, f)
    os.replace(tmp, manifest)
    return path


def latest_step(directory: str, name: str) -> int | None:
    m = _read_manifest(directory, name)
    return None if m is None else m["step"]


def _snapshot_path(directory: str, name: str, step: int,
                   manifest: dict | None) -> str:
    if manifest is not None and manifest.get("step") == step \
            and "latest" in manifest:
        # os.path.basename tolerates legacy manifests that recorded the
        # full joined path.
        return os.path.join(directory, os.path.basename(manifest["latest"]))
    return os.path.join(directory, f"{name}-{step}.npz")


def restore(directory: str, name: str, template: Any,
            shardings: Any | None = None, step: int | None = None) -> Any:
    """Restore into the structure of ``template``; leaves are device_put to
    ``shardings`` when given (recovered clients re-shard transparently).

    Raises :class:`CorruptSnapshotError` when the file is unreadable
    (truncated/partial write) and ``ValueError`` with the offending leaf
    path when the snapshot does not match the template's structure,
    shapes, or dtype families — instead of an opaque numpy broadcast
    failure at first use."""
    manifest = _read_manifest(directory, name)
    if step is None:
        if manifest is None:
            raise FileNotFoundError(f"no snapshot for {name} in {directory}")
        step = manifest["step"]
    path = _snapshot_path(directory, name, step, manifest)
    try:
        data = np.load(path)
        available = set(data.files)
    except _NPZ_READ_ERRORS as e:
        raise CorruptSnapshotError(
            f"snapshot {path} is unreadable ({type(e).__name__}: {e}); "
            "it was likely truncated by a preempted writer") from e
    flat_template = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat_template[0]:
        key = SEP.join(str(getattr(q, "key", getattr(q, "idx", getattr(q, "name", q))))
                       for q in p)
        if key not in available:
            raise ValueError(
                f"snapshot {path} has no leaf {key!r} required by the "
                f"restore template — the template's pytree structure does "
                f"not match the snapshot (saved leaves: "
                f"{sorted(available)[:8]}…)")
        try:
            arr = data[key]
        except _NPZ_READ_ERRORS as e:
            raise CorruptSnapshotError(
                f"snapshot {path} leaf {key!r} is unreadable "
                f"({type(e).__name__}: {e})") from e
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"snapshot {path} leaf {key!r} has shape {arr.shape} but "
                f"the restore template expects {tuple(leaf.shape)} — "
                "restoring a snapshot into a differently-configured state "
                "(vocab/topics/clients/shards changed?)")
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            # Intentional narrowing cast (bf16 is saved widened to f32);
            # crossing dtype kinds means the wrong template.
            if _dtype_kind(arr.dtype) != _dtype_kind(leaf.dtype):
                raise ValueError(
                    f"snapshot {path} leaf {key!r} has dtype {arr.dtype} "
                    f"but the restore template expects {leaf.dtype}")
            arr = np.asarray(jax.numpy.asarray(arr).astype(leaf.dtype))
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(flat_template[1], leaves)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree


def load_raw(directory: str, name: str,
             step: int | None = None) -> tuple[int, dict[str, np.ndarray]]:
    """Template-free restore: the newest readable snapshot as a flat
    ``{key: array}`` dict plus its step.

    The shard-server restore path (DESIGN.md §13) uses this: a restarted
    shard process does not yet know its row count or which aux/pending
    buffers were live, so there is no template to validate against — the
    server rebuilds its state from whatever keys were saved and validates
    semantically (row-range, family) afterwards.  Walks the manifest's
    step history past corrupt files exactly like :func:`restore_latest`;
    an explicit ``step`` disables the fallback."""
    manifest = _read_manifest(directory, name)
    if manifest is None:
        raise FileNotFoundError(f"no snapshot for {name} in {directory}")
    steps = [step] if step is not None else \
        sorted(set(manifest.get("steps", []) or [manifest["step"]]),
               reverse=True)
    errors: list[str] = []
    for s in steps:
        path = _snapshot_path(directory, name, s, manifest)
        try:
            with np.load(path, allow_pickle=False) as data:
                return s, {k: data[k] for k in data.files}
        except _NPZ_READ_ERRORS as e:
            if step is not None:
                raise CorruptSnapshotError(
                    f"snapshot {path} is unreadable "
                    f"({type(e).__name__}: {e})") from e
            errors.append(f"step {s}: {type(e).__name__}: {e}")
    raise CorruptSnapshotError(
        f"no readable snapshot for {name} in {directory}; tried steps "
        f"{steps}: {errors}")


def restore_latest(directory: str, name: str, template: Any,
                   shardings: Any | None = None,
                   step: int | None = None) -> Any:
    """Restore the newest *readable* snapshot.

    Tries the manifest's latest entry first and walks the recorded step
    history newest→oldest past any :class:`CorruptSnapshotError` — the
    §5.4 recovery property: a truncated newest snapshot is rejected in
    favor of the previous manifest entry instead of losing the run.  An
    explicit ``step`` disables the fallback (that file or nothing).
    Template mismatches (``ValueError``) are never skipped — every
    snapshot in the history would mismatch the same way."""
    if step is not None:
        return restore(directory, name, template, shardings, step=step)
    manifest = _read_manifest(directory, name)
    if manifest is None:
        raise FileNotFoundError(f"no snapshot for {name} in {directory}")
    steps = list(manifest.get("steps", [])) or [manifest["step"]]
    errors: list[str] = []
    for s in sorted(set(steps), reverse=True):
        try:
            return restore(directory, name, template, shardings, step=s)
        except CorruptSnapshotError as e:
            errors.append(str(e))
    raise CorruptSnapshotError(
        f"no readable snapshot for {name} in {directory}; tried steps "
        f"{sorted(set(steps), reverse=True)}: {errors}")
