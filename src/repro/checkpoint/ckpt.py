"""Sharding-aware checkpointing (the paper's §5.4 snapshot mechanism).

Snapshots are the fault-tolerance substrate: clients and servers write their
state every N minutes without a global barrier; recovery re-reads the latest
snapshot and re-pulls fresh parameters.  On the JAX side a snapshot is a
flattened pytree written with numpy (no orbax in the environment); restore
re-places leaves onto their shardings.

Layout: <dir>/<name>-<step>.npz + a MANIFEST file recording the latest
complete snapshot (write-then-rename, so a preempted writer never corrupts
the recovery point — the asynchronous-snapshot property of §5.4).
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import numpy as np

SEP = "/"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":   # npz has no native bf16: widen;
            arr = arr.astype(np.float32)   # restore() re-narrows via template
        flat[key] = arr
    return flat


def save(directory: str, name: str, step: int, tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(directory, f"{name}-{step}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **flat)
    os.replace(tmp, path)
    manifest = os.path.join(directory, f"{name}.MANIFEST")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "w") as f:
        json.dump({"latest": path, "step": step}, f)
    os.replace(tmp, manifest)
    return path


def latest_step(directory: str, name: str) -> int | None:
    manifest = os.path.join(directory, f"{name}.MANIFEST")
    if not os.path.exists(manifest):
        return None
    with open(manifest) as f:
        return json.load(f)["step"]


def restore(directory: str, name: str, template: Any,
            shardings: Any | None = None, step: int | None = None) -> Any:
    """Restore into the structure of ``template``; leaves are device_put to
    ``shardings`` when given (recovered clients re-shard transparently)."""
    if step is None:
        step = latest_step(directory, name)
        if step is None:
            raise FileNotFoundError(f"no snapshot for {name} in {directory}")
    path = os.path.join(directory, f"{name}-{step}.npz")
    data = np.load(path)
    flat_template = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, leaf in flat_template[0]:
        key = SEP.join(str(getattr(q, "key", getattr(q, "idx", getattr(q, "name", q))))
                       for q in p)
        arr = data[key]
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = np.asarray(jax.numpy.asarray(arr).astype(leaf.dtype))
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(flat_template[1], leaves)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree
