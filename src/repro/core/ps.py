"""Parameter-server abstraction mapped to JAX SPMD (paper §4, §5.3).

The paper's parameter server holds (key,value) sufficient statistics sharded
over server nodes (Chord-style consistent hashing); clients pull stale
copies, sample, and push batched deltas with user-defined communication
filters under an eventual-consistency model.

On a TPU mesh the same roles map to sharding (DESIGN.md §2):

  server group  →  the ``model`` mesh axis: canonical statistics arrays are
                   sharded row-wise over it (`P('model', None)` for (V, K)
                   matrices — row-hashing becomes row-sharding).
  client group  →  the ``data`` mesh axis: each data shard holds a document
                   shard plus a *stale replica* of the shared statistics.
  push/pull     →  `psum` of (filtered) deltas / all-gather of fresh rows.
  consistency   →  bounded staleness: clients run ``tau`` Gibbs sweeps
                   against a frozen snapshot between sync rounds.

Communication filters (paper §5.3 "Communication filters") are implemented
as *delta compression*: the magnitude-priority filter keeps the top-k rows
by L1 delta mass, and the uniform-sampling anti-starvation term keeps a
random subset of the remainder.  The compressed representation (indices,
values) is what crosses the interconnect — visible as smaller collectives
in the lowered HLO (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclass(frozen=True)
class FilterSpec:
    """Communication filter configuration.

    kind:
      "dense"     — no filtering; push the full delta matrix.
      "topk"      — keep ``k_rows`` rows with the largest L1 delta magnitude
                    plus ``random_rows`` uniformly sampled rows (paper §5.3:
                    priority ∝ magnitude + uniform sampling to avoid
                    starvation of small-update parameters).
      "threshold" — zero rows whose L1 delta magnitude is below ``threshold``
                    (KKT-style significance filter).
    """

    kind: str = "dense"
    k_rows: int = 0
    random_rows: int = 0
    threshold: float = 0.0


class CompressedDelta(NamedTuple):
    """Sparse (row-indices, row-values) delta representation."""

    indices: Array  # (k,) int32 row ids
    values: Array   # (k, K) rows


def compress_delta(delta: Array, spec: FilterSpec, key: Array) -> CompressedDelta:
    """Apply the communication filter to a (V, K) row-delta matrix."""
    if spec.kind != "topk":
        raise ValueError("compress_delta only applies to the topk filter")
    v = delta.shape[0]
    k_rows = min(spec.k_rows, v)   # small leaves pass through whole
    mag = jnp.abs(delta).sum(-1)  # (V,) L1 per row
    _, top_idx = jax.lax.top_k(mag, k_rows)
    if spec.random_rows > 0 and k_rows < v:
        # Uniform anti-starvation rows: sampled from the whole vocabulary;
        # collisions with top rows are harmless (delta rows add idempotently
        # because we zero them after selection — see below).
        rand_idx = jax.random.randint(key, (spec.random_rows,), 0, v, jnp.int32)
        idx = jnp.concatenate([top_idx.astype(jnp.int32), rand_idx])
    else:
        idx = top_idx.astype(jnp.int32)
    # De-duplicate by construction: gather rows, then mark first occurrence.
    # (A duplicated index would double-apply the delta; we zero repeats.)
    sorted_idx = jnp.sort(idx)
    dup = jnp.concatenate([jnp.array([False]), sorted_idx[1:] == sorted_idx[:-1]])
    order = jnp.argsort(idx)
    dup_unsorted = jnp.zeros_like(dup).at[order].set(dup)
    rows = delta[idx] * (~dup_unsorted)[:, None]
    return CompressedDelta(indices=idx, values=rows)


def decompress_delta(comp: CompressedDelta, vocab_size: int, n_cols: int) -> Array:
    """Scatter a compressed delta back to a dense (V, K) matrix."""
    dense = jnp.zeros((vocab_size, n_cols), comp.values.dtype)
    return dense.at[comp.indices].add(comp.values)


def filter_delta(delta: Array, spec: FilterSpec, key: Array) -> Array:
    """Dense-in/dense-out filtering (used when the transport is a psum).

    For "topk" this returns the dense matrix with only the selected rows
    non-zero — semantically identical to compress+decompress, and the form
    the distributed driver psums.  The *compressed* transport (all-gather of
    (indices, values)) lives in ``repro.core.distributed.sync_compressed``.
    """
    if spec.kind == "dense":
        return delta
    if spec.kind == "threshold":
        mag = jnp.abs(delta).sum(-1)
        return jnp.where((mag >= spec.threshold)[:, None], delta, 0.0)
    if spec.kind == "topk":
        comp = compress_delta(delta, spec, key)
        return decompress_delta(comp, delta.shape[0], delta.shape[1])
    raise ValueError(spec.kind)


def changed_rows(row_mass: Array, k_rows: int, threshold: float
                 ) -> tuple[Array, Array]:
    """Select the rows an incremental alias rebuild should touch.

    The same magnitude-priority machinery as the top-k communication filter
    (:func:`compress_delta`): the ``k_rows`` rows with the largest
    accumulated L1 delta mass, plus a validity mask ``mass > threshold`` so
    below-threshold rows inside the fixed-size selection are left untouched
    (shapes must be static under jit; masked rows cost a no-op scatter).

    ``row_mass`` is the (V,) per-row accumulated L1 push mass.  The
    accounting that feeds it lives behind the parameter server's push path
    (``repro.core.server.ParameterServer``: per-shard accumulators folded
    on every tracked push, consumed + reset by ``consume_changed_rows``) —
    with a top-k communication filter at most ``k_rows + random_rows`` rows
    are non-zero per push, so size the rebuild budget accordingly.
    """
    k_rows = min(k_rows, row_mass.shape[0])
    mass, idx = jax.lax.top_k(row_mass, k_rows)
    return idx.astype(jnp.int32), mass > threshold


class SparseDelta(NamedTuple):
    """Row-sliced COO pytree delta: one shared row-index vector plus the
    packed rows of every delta statistic at those indices.

    The dense↔sparse boundary contract (DESIGN.md §12): ``to_sparse_delta``
    keeps every row that is non-zero in *any* statistic, so
    ``from_sparse_delta`` reconstructs the dense pytree bit-for-bit — the
    selected rows carry their exact float values and the dropped rows were
    exactly 0.0 in every statistic.  No arithmetic is re-ordered, which is
    why a sparse push under BSP is bit-exact with the dense push.
    """

    rows: Array                  # (R,) int32, strictly increasing, unique
    values: dict[str, Array]     # name -> (R, K) packed rows


def to_sparse_delta(deltas: dict[str, Array]) -> SparseDelta:
    """Dense delta pytree → :class:`SparseDelta` of its non-zero rows.

    Host-side (data-dependent shape — the wire path and the Python
    reference loop use it; the compiled round keeps dense deltas).  Rows
    are the ascending union of non-zero rows across statistics.
    """
    mats = {n: np.asarray(v) for n, v in deltas.items()}
    nz: np.ndarray | None = None
    for v in mats.values():
        row_any = np.any(v != 0, axis=tuple(range(1, v.ndim)))
        nz = row_any if nz is None else (nz | row_any)
    rows = np.flatnonzero(nz).astype(np.int32)
    return SparseDelta(rows=rows,
                       values={n: v[rows] for n, v in mats.items()})


def from_sparse_delta(sp: SparseDelta, n_rows: int) -> dict[str, Array]:
    """:class:`SparseDelta` → dense delta pytree (exact inverse of
    :func:`to_sparse_delta` given the dense row count)."""
    out: dict[str, Array] = {}
    rows = jnp.asarray(sp.rows, jnp.int32)
    for n, v in sp.values.items():
        v = jnp.asarray(v)
        dense = jnp.zeros((n_rows,) + v.shape[1:], v.dtype)
        # Unique indices by construction: the scatter-add writes each
        # selected row's exact value (0 + x == x bit-for-bit).
        out[n] = dense.at[rows].add(v)
    return out


def residual_update(residual: Array, delta: Array, sent: Array) -> Array:
    """Error-feedback accumulator: what a filter withholds is carried to the
    next round instead of dropped, so every update is eventually applied —
    this *is* the eventual-consistency guarantee, kept exactly."""
    return residual + delta - sent
