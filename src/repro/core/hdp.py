"""HDP-LDA: Hierarchical Dirichlet Process topic model (paper §2.3).

Document-side hierarchy: θ_d ~ DP(b1, θ0), θ0 ~ DP(b0, H).  We use the
truncated direct-assignment sampler of Teh et al. [20] with auxiliary table
counts, which is the scheme the paper's shared-statistics list corresponds
to (root counts + per-document table counts + word-topic counts):

  p(z_di = t | rest) ∝ (n_dt^{-di} + b1·θ0_t) · (n_wt + β)/(n_t + β̄)

  m_dk ~ CRT(n_dk, b1·θ0_k)          (Antoniak / Chinese-restaurant-table)
  θ0   ~ Dir(m_·1 + b0/K, …, m_·K + b0/K)

The conditional again splits into a document-sparse term (n_dt) and a dense
term (b1·θ0_t · LM), so MHW applies unchanged.  Shared statistics: n_wk,
n_k, m_k (aggregated table counts) and θ0; local: z, n_dk, m_dk.

Constraints under relaxed consistency: 1 ≤ m_dk ≤ n_dk whenever n_dk > 0
and m_dk = 0 otherwise — maintained by ``repro.core.projection.HDP_RULES``.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import alias as alias_mod
from repro.core import mhw

Array = jax.Array


@dataclass(frozen=True)
class HDPConfig:
    n_topics: int           # truncation level K
    vocab_size: int
    b0: float = 1.0         # root DP concentration
    b1: float = 1.0         # document DP concentration
    beta: float = 0.01      # topic-word Dirichlet
    mh_steps: int = 2
    crt_max: int = 128      # max count for exact CRT sampling
    # Driver-side cadence + sorted-layout tile geometry (see LDAConfig for
    # the knob semantics).
    alias_refresh_every: int = 1
    tile_v: int | None = None
    tile_b: int = 1024
    tile_k: int | None = None
    sorted_chunks: int = 4


class SharedStats(NamedTuple):
    n_wk: Array   # (V, K)
    n_k: Array    # (K,)
    m_k: Array    # (K,) aggregated table counts
    theta0: Array # (K,) root topic distribution


class LocalState(NamedTuple):
    z: Array      # (D, L)
    n_dk: Array   # (D, K)
    m_dk: Array   # (D, K) per-document table counts


def init_state(cfg: HDPConfig, tokens: Array, mask: Array, key: Array
               ) -> tuple[LocalState, SharedStats]:
    d, l = tokens.shape
    kz, kt = jax.random.split(key)
    z = jnp.where(mask, jax.random.randint(kz, (d, l), 0, cfg.n_topics, jnp.int32), 0)
    onehot = jax.nn.one_hot(z, cfg.n_topics, dtype=jnp.float32)
    n_dk = jnp.einsum("dl,dlk->dk", mask.astype(jnp.float32), onehot)
    w = tokens.reshape(-1)
    n_wk = (jnp.zeros((cfg.vocab_size, cfg.n_topics), jnp.float32)
            .at[w, z.reshape(-1)].add(mask.reshape(-1).astype(jnp.float32)))
    m_dk = jnp.minimum(n_dk, 1.0)  # one table per occupied (d, k) to start
    m_k = m_dk.sum(0)
    theta0 = (m_k + cfg.b0 / cfg.n_topics) / (m_k.sum() + cfg.b0)
    return (LocalState(z=z, n_dk=n_dk, m_dk=m_dk),
            SharedStats(n_wk=n_wk, n_k=n_wk.sum(0), m_k=m_k, theta0=theta0))


def language_model(cfg: HDPConfig, shared: SharedStats) -> Array:
    beta_bar = cfg.beta * cfg.vocab_size
    return (shared.n_wk + cfg.beta) / (shared.n_k[None, :] + beta_bar)


def dense_probs(cfg: HDPConfig, shared: SharedStats) -> Array:
    """Dense term b1·θ0_t · (n_wt+β)/(n_t+β̄): (V, K) rows per token-type."""
    return cfg.b1 * shared.theta0[None, :] * language_model(cfg, shared)


def build_alias(cfg: HDPConfig, shared: SharedStats):
    dp = dense_probs(cfg, shared)
    return alias_mod.build(dp), dp


@partial(jax.jit, static_argnames=("cfg", "method", "layout"))
def sweep(
    cfg: HDPConfig,
    local: LocalState,
    shared: SharedStats,
    tables: alias_mod.AliasTable,
    stale_dense: Array,
    tokens: Array,
    mask: Array,
    key: Array,
    method: str = "mhw",
    layout: str = "scan",
    sorted_layouts: tuple | None = None,
) -> tuple[LocalState, Array, Array]:
    """One Gibbs sweep over z. Returns (local', delta_wk, delta_k).

    ``layout="sorted"`` (mhw only) runs the generic token-sorted
    tile-skipping pipeline with the HDP dense term b1·θ0_t as the
    per-topic prior vector (``repro.core.family``); pass prebuilt
    ``sorted_layouts`` from ``family.get("hdp").build_sorted_layouts``
    to hoist the per-shard sorts out of the sweep.
    """
    if layout == "sorted":
        if method != "mhw":
            raise ValueError("layout='sorted' requires method='mhw'")
        from repro.core import family as family_mod
        local2, deltas = family_mod.get("hdp").sweep_sorted(
            cfg, local, shared, tables, stale_dense, tokens, mask, key,
            sorted_layouts)
        return local2, deltas["n_wk"], deltas["n_wk"].sum(0)
    if layout != "scan":
        raise ValueError(f"unknown layout {layout!r}")
    d, l = tokens.shape
    beta_bar = cfg.beta * cfg.vocab_size
    n_wk, n_k, theta0 = shared.n_wk, shared.n_k, shared.theta0

    def position_step(carry, inputs):
        n_dk = carry
        w, z_old, m, k = inputs
        docs = jnp.arange(d)
        mf = m.astype(jnp.float32)

        n_dk_m = n_dk.at[docs, z_old].add(-mf)
        own = jax.nn.one_hot(z_old, cfg.n_topics) * mf[:, None]
        lm_fresh = (n_wk[w] - own + cfg.beta) / (n_k[None, :] - own + beta_bar)

        if method == "exact":
            logits = (jnp.log(n_dk_m + cfg.b1 * theta0[None, :])
                      + jnp.log(lm_fresh + 1e-30))
            z_new = jax.random.categorical(k, logits, axis=-1).astype(jnp.int32)
        elif method == "mhw":
            sparse_w = n_dk_m * lm_fresh
            prop = mhw.MixtureProposal(
                sparse_weights=sparse_w, dense_tables=tables, dense_rows=w)

            def log_p(t):
                return (jnp.log(n_dk_m[docs, t] + cfg.b1 * theta0[t] + 1e-30)
                        + jnp.log(lm_fresh[docs, t] + 1e-30))

            z_new = mhw.mh_chain(k, z_old, prop, stale_dense, log_p, cfg.mh_steps)
        else:
            raise ValueError(method)

        z_new = jnp.where(m, z_new, z_old)
        return n_dk_m.at[docs, z_new].add(mf), z_new

    keys = jax.random.split(key, l)
    n_dk_final, z_t = jax.lax.scan(position_step, local.n_dk,
                                   (tokens.T, local.z.T, mask.T, keys))
    z_new = z_t.T

    w_flat = tokens.reshape(-1)
    mf = mask.reshape(-1).astype(jnp.float32)
    delta_wk = (
        jnp.zeros((cfg.vocab_size, cfg.n_topics), jnp.float32)
        .at[w_flat, z_new.reshape(-1)].add(mf)
        .at[w_flat, local.z.reshape(-1)].add(-mf)
    )
    return (LocalState(z=z_new, n_dk=n_dk_final, m_dk=local.m_dk),
            delta_wk, delta_wk.sum(0))


@partial(jax.jit, static_argnames=("cfg",))
def resample_tables(cfg: HDPConfig, local: LocalState, shared: SharedStats,
                    key: Array) -> tuple[LocalState, Array]:
    """Antoniak step: m_dk ~ CRT(n_dk, b1 θ0_k); returns new local + m_k.

    CRT(n, c) = Σ_{j=0}^{n-1} Bernoulli(c / (c + j)); exact for n ≤ crt_max,
    clamped above (error O(1) tables on O(100+) counts — below sampler noise).
    """
    d = local.n_dk.shape[0]
    c = cfg.b1 * shared.theta0  # (K,)
    j = jnp.arange(cfg.crt_max, dtype=jnp.float32)  # (J,)
    p = c[None, :, None] / (c[None, :, None] + j[None, None, :])  # (1, K, J)
    u = jax.random.uniform(key, (d, cfg.n_topics, cfg.crt_max))
    n = jnp.clip(local.n_dk, 0, cfg.crt_max)
    active = j[None, None, :] < n[:, :, None]
    m_dk = jnp.sum((u < p) & active, axis=-1).astype(jnp.float32)
    # CRT(n,c) >= 1 whenever n >= 1 (the j=0 Bernoulli has p=1).
    m_dk = jnp.where(local.n_dk > 0, jnp.maximum(m_dk, 1.0), 0.0)
    return LocalState(z=local.z, n_dk=local.n_dk, m_dk=m_dk), m_dk.sum(0)


@partial(jax.jit, static_argnames=("cfg",))
def resample_theta0(cfg: HDPConfig, m_k: Array, key: Array) -> Array:
    """θ0 ~ Dir(m_k + b0/K)."""
    conc = m_k + cfg.b0 / cfg.n_topics
    g = jax.random.gamma(key, conc)
    return g / g.sum()


def apply_delta(cfg: HDPConfig, shared: SharedStats, delta_wk: Array,
                delta_k: Array, m_k: Array | None = None,
                theta0: Array | None = None) -> SharedStats:
    return SharedStats(
        n_wk=shared.n_wk + delta_wk,
        n_k=shared.n_k + delta_k,
        m_k=shared.m_k if m_k is None else m_k,
        theta0=shared.theta0 if theta0 is None else theta0,
    )


@partial(jax.jit, static_argnames=("cfg", "n_fold_sweeps"))
def perplexity(cfg: HDPConfig, shared: SharedStats, tokens: Array, mask: Array,
               key: Array, n_fold_sweeps: int = 10) -> Array:
    phi = language_model(cfg, shared)
    d, l = tokens.shape
    k_init, k_sweeps = jax.random.split(key)
    z = jax.random.randint(k_init, (d, l), 0, cfg.n_topics, jnp.int32)
    onehot = jax.nn.one_hot(jnp.where(mask, z, 0), cfg.n_topics, dtype=jnp.float32)
    n_dk = jnp.einsum("dl,dlk->dk", mask.astype(jnp.float32), onehot)
    prior = cfg.b1 * shared.theta0

    def fold_sweep(carry, k):
        z, n_dk = carry

        def pos(c, inp):
            n_dk = c
            w, z_old, m, kk = inp
            docs = jnp.arange(d)
            mf = m.astype(jnp.float32)
            n_dk_m = n_dk.at[docs, z_old].add(-mf)
            logits = jnp.log(n_dk_m + prior[None, :]) + jnp.log(phi[w] + 1e-30)
            z_new = jax.random.categorical(kk, logits, axis=-1).astype(jnp.int32)
            z_new = jnp.where(m, z_new, z_old)
            return n_dk_m.at[docs, z_new].add(mf), z_new

        keys = jax.random.split(k, l)
        n_dk2, z_t = jax.lax.scan(pos, n_dk, (tokens.T, z.T, mask.T, keys))
        return (z_t.T, n_dk2), None

    (z, n_dk), _ = jax.lax.scan(fold_sweep, (z, n_dk),
                                jax.random.split(k_sweeps, n_fold_sweeps))
    theta = (n_dk + prior[None, :]) / (n_dk.sum(-1, keepdims=True) + prior.sum())
    pw = jnp.einsum("dk,dlk->dl", theta, phi[tokens])
    logp = jnp.where(mask, jnp.log(pw + 1e-30), 0.0)
    return jnp.exp(-logp.sum() / jnp.maximum(mask.sum(), 1))
