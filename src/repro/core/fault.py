"""Fault-plan injection (paper §5.4): scripted and seeded-random failures.

The paper's production claim rests on failure being *routine*: on a shared
cluster of tens of thousands of cores, clients are pre-empted, pushes are
dropped by the transport, pulls time out, and stragglers stall barriers —
and the system's answer is bounded staleness, asynchronous snapshots and
re-pulling fresh parameters, not global restart.  Until this module the
only injectable failure was a single static ``drop_client=(id, from, to)``
tuple with no recovery path.

A :class:`FaultPlan` is a scripted (or seeded-random, see
:meth:`FaultPlan.random`) schedule of :class:`FaultEvent`\\ s over clients
and rounds.  The plan is resolved **host-side**, once per round, into a
:class:`RoundFaults` record — plain boolean masks and flags that enter the
compiled round as *traced* scalars, so fault injection never retraces the
round program.  Four event kinds:

``crash``
    The client is gone for ``[start, stop)``: it neither samples nor
    pushes, its local state / residuals / read-my-writes lag are frozen,
    and its server clock stops (exactly the protection SSP's staleness
    bound watches for).  At round ``stop`` the client **rejoins**: the
    Trainer restores its locals from the latest snapshot when snapshots
    are enabled (``TrainerConfig.snapshot_dir``), clears its
    read-my-writes lag, and forces a fresh pull — under SSP a rejoining
    client is just a maximally-stale client taking its blocking refresh,
    which is what makes recovery cheap (Yuan et al. 2014; Zheng et al.).
    Restoring from a snapshot older than the crash loses the client's
    un-snapshotted assignment movement: the server keeps the pushes the
    restored local state no longer accounts for, so
    ``Trainer.consistency_error()`` is expected to be nonzero after a
    lossy rejoin — the sampler re-absorbs the drift (the counts are an
    MH proposal's statistics, not an invariant the chain needs exactly).

``straggle``
    A slow client: within ``[start, stop)`` it completes a round of work
    only every ``period``-th round (its round spans ``period`` lock-step
    rounds).  On the skipped rounds it is masked exactly like a dead
    client — frozen state, no push, frozen clock — but no recovery is
    needed on exit because its state was never lost, and no count mass is
    lost (``consistency_error`` stays 0 under the dense filter).

``lost_push``
    The client samples and updates its local replica, but its filtered
    delta never reaches the server (a dropped message, not a dead
    client).  The mass is *lost*, not residual-carried — the maintained
    statistics drift from the assignments by exactly the dropped delta,
    which is the fault being modeled.  The client's server clock does not
    advance (clocks tick when a push is applied).

``failed_pull``
    The shared cache refresh fails for rounds in ``[start, stop)``.  Only
    meaningful under a caching policy (SSP): the clients degrade
    gracefully — they continue sampling the stale cache past the
    staleness bound while the Trainer retries the refresh each round,
    and after ``TrainerConfig.pull_retry_limit`` consecutive failed
    attempts the refresh forces through anyway (modeling failover to a
    healthy server replica).  Under BSP/async there is no refreshable
    cache — the pull *is* the barrier read — so the event is a no-op.

Network fault kinds (``NET_KINDS``, DESIGN.md §13) schedule *transport*
misbehavior for the chaos proxy (:mod:`repro.net.chaos`) rather than
client liveness; :meth:`FaultPlan.resolve` ignores them — they never
enter the traced round masks.  For these kinds ``client`` is a
**connection ordinal** at the proxy (-1 = every connection) and
``[start, stop)`` is a window of client→server **frame ordinals** on
that connection; ``period`` repeats the action every period-th frame of
the window (the field defaults to 2 — pass ``period=1`` to fire on
every frame; single-frame windows are unaffected); ``magnitude`` is
the action's size:

``conn_drop``
    Sever the proxied connection before forwarding the scheduled frame —
    the client sees a mid-RPC connection loss and must retry through the
    idempotent-replay path (DESIGN.md §13).

``frame_truncate``
    Forward the frame header plus only ``magnitude`` (fraction, default
    0.5) of the payload bytes, then sever — the receiver gets a
    mid-read EOF (`ProtocolError`), never a silently corrupt frame.

``delay``
    Sleep ``magnitude`` seconds (default 0.05) before forwarding the
    frame — latency injection without loss.

Determinism: a plan is a frozen value.  :meth:`FaultPlan.random`
materializes its events eagerly from ``numpy.random.default_rng(seed)``
at construction, so resolution is a pure function of (plan, round) and a
seeded chaos run is exactly reproducible.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

ROUND_KINDS = ("crash", "straggle", "lost_push", "failed_pull")
NET_KINDS = ("conn_drop", "frame_truncate", "delay")
KINDS = ROUND_KINDS + NET_KINDS

_NET_MAGNITUDE_DEFAULT = {"conn_drop": 0.0, "frame_truncate": 0.5,
                          "delay": 0.05}


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: ``kind`` applied to ``client`` for rounds in
    ``[start, stop)``.  ``client`` is ignored for ``failed_pull`` (the
    cache refresh is shared).  ``period`` applies to ``straggle`` only
    (the client completes work every ``period``-th round of the window)
    and to the network kinds (the action fires every ``period``-th frame
    of the window; the default of 2 means every other frame — pass
    ``period=1`` for every frame).  For the network kinds
    (``NET_KINDS``) ``client`` is a proxy connection ordinal (-1 = all),
    ``[start, stop)`` is a frame-ordinal window, and ``magnitude`` sizes
    the action (truncate fraction / delay seconds)."""

    kind: str
    client: int = 0
    start: int = 0
    stop: int = 0
    period: int = 2
    magnitude: float = 0.0

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {KINDS}")
        if self.stop < self.start:
            raise ValueError(f"fault window [{self.start}, {self.stop}) "
                             "is reversed")
        if self.kind in NET_KINDS:
            if self.client < -1:
                raise ValueError("network fault connection ordinal must "
                                 f"be >= -1 (-1 = all), got {self.client}")
            if self.period < 1:
                raise ValueError("network fault period must be >= 1, "
                                 f"got {self.period}")
            if self.magnitude == 0.0 and self.kind != "conn_drop":
                object.__setattr__(self, "magnitude",
                                   _NET_MAGNITUDE_DEFAULT[self.kind])
            if self.kind == "frame_truncate" and not (
                    0.0 <= self.magnitude < 1.0):
                raise ValueError("frame_truncate magnitude is the kept "
                                 "payload fraction and must be in "
                                 f"[0, 1), got {self.magnitude}")
            if self.magnitude < 0.0:
                raise ValueError(f"magnitude must be >= 0, "
                                 f"got {self.magnitude}")
            return
        if self.kind != "failed_pull" and self.client < 0:
            raise ValueError(f"client must be >= 0, got {self.client}")
        if self.kind == "straggle" and self.period < 2:
            raise ValueError("straggle period must be >= 2 (period 1 is "
                             "a healthy client)")

    def active(self, round_idx: int) -> bool:
        return self.start <= round_idx < self.stop


@dataclass(frozen=True)
class RoundFaults:
    """Host-side resolution of a :class:`FaultPlan` for one round — the
    flags the Trainer feeds the compiled round as traced scalars.

    alive        per-client: samples and updates its local state this
                 round (False while crashed or mid-straggle).
    push_ok      per-client: its produced delta lands on the server
                 (False additionally under ``lost_push``).  A client's
                 server clock advances iff ``alive & push_ok``.
    pull_failed  the shared cache refresh fails this round (SSP only).
    rejoining    clients whose crash window ends at exactly this round —
                 the Trainer runs the rejoin protocol for them before
                 dispatching the round.
    """

    alive: tuple[bool, ...]
    push_ok: tuple[bool, ...]
    pull_failed: bool = False
    rejoining: tuple[int, ...] = ()

    @property
    def alive_mask(self) -> np.ndarray:
        return np.asarray(self.alive, bool)

    @property
    def push_mask(self) -> np.ndarray:
        return np.asarray(self.push_ok, bool)


_HEALTHY_CACHE: dict[int, RoundFaults] = {}


def healthy(n_clients: int) -> RoundFaults:
    """The no-fault resolution (cached — it is the steady-state value)."""
    rf = _HEALTHY_CACHE.get(n_clients)
    if rf is None:
        rf = _HEALTHY_CACHE[n_clients] = RoundFaults(
            alive=(True,) * n_clients, push_ok=(True,) * n_clients)
    return rf


@dataclass(frozen=True)
class FaultPlan:
    """A schedule of :class:`FaultEvent`\\ s, resolved per round.

    Frozen and hashable (it rides on ``TrainerConfig``); the empty plan
    is the healthy run.  Construct scripted plans directly or via the
    :meth:`crash` / :meth:`scripted` helpers, random chaos plans via
    :meth:`random`, and the legacy ``drop_client`` tuple via
    :meth:`from_drop_client`.
    """

    events: tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))
        for e in self.events:
            if not isinstance(e, FaultEvent):
                raise TypeError(f"FaultPlan events must be FaultEvent, "
                                f"got {type(e).__name__}")

    # ------------------------------------------------------------ builders
    @classmethod
    def none(cls) -> "FaultPlan":
        return cls()

    @classmethod
    def scripted(cls, *events: FaultEvent) -> "FaultPlan":
        return cls(events=tuple(events))

    @classmethod
    def crash(cls, client: int, start: int, stop: int) -> "FaultPlan":
        """One client crashed for ``[start, stop)``, rejoining at
        ``stop`` — the kill-and-rejoin scenario."""
        return cls(events=(FaultEvent("crash", client, start, stop),))

    @classmethod
    def from_drop_client(cls, drop: tuple[int, int, int]) -> "FaultPlan":
        """The legacy ``TrainerConfig.drop_client=(id, from, to)`` tuple
        as a one-event plan (same semantics: crash for ``[from, to)``)."""
        client, start, stop = drop
        return cls.crash(int(client), int(start), int(stop))

    @classmethod
    def random(cls, seed: int, n_clients: int, n_rounds: int, *,
               p_crash: float = 0.02, p_straggle: float = 0.02,
               p_lost_push: float = 0.02, p_failed_pull: float = 0.01,
               mean_window: float = 3.0) -> "FaultPlan":
        """A seeded-random chaos schedule, deterministic under ``seed``.

        Per client and round, each per-client hazard fires independently
        with its probability and opens a window of geometric mean length
        ``mean_window`` (at most one concurrent event per client — a
        crashed client cannot also straggle).  ``p_failed_pull`` is the
        per-round hazard of a shared refresh outage.  Events are
        materialized eagerly here, so two plans with equal arguments are
        equal values.
        """
        rng = np.random.default_rng(seed)
        p_stop = 1.0 / max(mean_window, 1.0)
        events: list[FaultEvent] = []
        hazards = (("crash", p_crash), ("straggle", p_straggle),
                   ("lost_push", p_lost_push))
        for c in range(n_clients):
            busy_until = 0
            for r in range(n_rounds):
                if r < busy_until:
                    continue
                for kind, p in hazards:
                    if rng.random() < p:
                        length = 1 + int(rng.geometric(p_stop))
                        stop = min(r + length, n_rounds)
                        events.append(FaultEvent(kind, c, r, stop))
                        busy_until = stop
                        break
        outage_until = 0
        for r in range(n_rounds):
            if r >= outage_until and rng.random() < p_failed_pull:
                length = 1 + int(rng.geometric(p_stop))
                stop = min(r + length, n_rounds)
                events.append(FaultEvent("failed_pull", 0, r, stop))
                outage_until = stop
        return cls(events=tuple(events))

    # ----------------------------------------------------------- resolution
    @property
    def max_client(self) -> int:
        """Largest client id any per-client *round* event names (-1 if
        none) — validated against ``n_clients`` by the Trainer.  Network
        events name connection ordinals, not clients, and are skipped."""
        ids = [e.client for e in self.events
               if e.kind not in NET_KINDS and e.kind != "failed_pull"]
        return max(ids) if ids else -1

    @property
    def last_round(self) -> int:
        """First round from which the plan is permanently healthy (the
        frame-ordinal windows of network events do not count)."""
        return max((e.stop for e in self.events
                    if e.kind not in NET_KINDS), default=0)

    @property
    def net_events(self) -> tuple[FaultEvent, ...]:
        """The transport-level events, for the chaos proxy."""
        return tuple(e for e in self.events if e.kind in NET_KINDS)

    def resolve(self, round_idx: int, n_clients: int) -> RoundFaults:
        """The per-round fault flags — a pure host-side function of
        (plan, round): see :class:`RoundFaults` for field semantics."""
        if not self.events or round_idx > self.last_round:
            return healthy(n_clients)
        alive = [True] * n_clients
        push_ok = [True] * n_clients
        pull_failed = False
        rejoining: set[int] = set()
        for e in self.events:
            if e.kind in NET_KINDS:
                continue  # transport-level: resolved by the chaos proxy
            if e.kind == "failed_pull":
                pull_failed = pull_failed or e.active(round_idx)
                continue
            c = e.client
            if c >= n_clients:
                raise ValueError(
                    f"fault event {e} names client {c} but the run has "
                    f"only {n_clients} clients")
            if e.kind == "crash":
                if e.active(round_idx):
                    alive[c] = False
                    push_ok[c] = False
                elif e.stop == round_idx and e.start < e.stop:
                    rejoining.add(c)
            elif e.kind == "straggle":
                if e.active(round_idx) and (round_idx - e.start) % e.period:
                    alive[c] = False
                    push_ok[c] = False
            elif e.kind == "lost_push":
                if e.active(round_idx):
                    push_ok[c] = False
        # A client crashed by an overlapping event does not rejoin yet.
        rejoin = tuple(sorted(c for c in rejoining if alive[c]))
        return RoundFaults(alive=tuple(alive), push_ok=tuple(push_ok),
                           pull_failed=pull_failed, rejoining=rejoin)
