"""Collapsed Gibbs sampling for Latent Dirichlet Allocation (paper §2.1).

Two samplers are provided, matching the paper's own experimental comparison:

* ``method="exact"`` — the full-conditional collapsed Gibbs sampler (the
  "YahooLDA" baseline of the paper: SparseLDA-style sampling; on TPU the
  sparse bucket walk becomes a dense K-lane categorical, see DESIGN.md §2).
* ``method="mhw"`` — AliasLDA: the Metropolis-Hastings-Walker sampler of
  paper §3.  The conditional is split per eq. (4) into a document-sparse
  term (kept exact) and a corpus-dense term `α_t · (n_wt+β)/(n_t+β̄)`
  approximated by a *stale* alias table, corrected by MH accept/reject.

Layout conventions
------------------
Documents are padded to a fixed length L with ``mask`` marking real tokens.
Two sweep layouts are provided (DESIGN.md §5):

* ``layout="scan"`` — the token sweep scans positions (so the per-document
  counts ``n_dk`` stay exact, as in a sequential Gibbs sweep) and
  vectorizes across documents — the TPU analogue of the paper's per-client
  multithreaded sampler, which is likewise relaxed *between* documents.
  This is the correctness oracle.
* ``layout="sorted"`` (``method="mhw"`` only) — the paper's word-major
  order: the flat token stream is sorted by token-type
  (``repro.data.segment``) and the whole shard runs as one fused
  tile-skipping Pallas chain (``repro.kernels.mhw_fused``), each token
  proposing against the sweep-start counts minus its own contribution
  (Jacobi-style within the sweep, like the paper's per-word relaxation).
  Each ``n_wk`` row is touched once per resident tile pair instead of once
  per scan position.

Sufficient statistics:
  n_dk (D, K) — document-topic counts, client-local (paper §5.2).
  n_wk (V, K) — word-topic counts, shared via the parameter server.
  n_k  (K,)   — topic totals, shared (aggregation parameter).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import alias as alias_mod
from repro.core import mhw
from repro.data import segment

Array = jax.Array


@dataclass(frozen=True)
class LDAConfig:
    n_topics: int
    vocab_size: int
    alpha: float = 0.1
    beta: float = 0.01
    mh_steps: int = 2
    # How many Gibbs sweeps an alias table is reused for before rebuild
    # (the l/n refresh of paper §3.3); used by the driver, not the sweep.
    alias_refresh_every: int = 1
    # Tile sizes for the sorted-layout kernels; tile_v=None sizes vocab
    # tiles from a VMEM budget (segment.pick_tile_vmem) — small models fit
    # in one tile, production vocabularies tile down and skip.  tile_b
    # trades skip granularity against grid size: smaller batch tiles span
    # fewer vocab tiles (more programs skipped) but launch more programs.
    tile_v: int | None = None
    tile_b: int = 1024
    # K-tile size for the staging axis of the fused kernels (None = full
    # K, the untiled path).  Must divide K.  With it set, table VMEM
    # residency is (tile_v, tile_k) and the budget-derived tile_v stops
    # shrinking as K grows (segment.pick_tile_vmem).
    tile_k: int | None = None
    # Sequential position-chunks per sorted sweep: each chunk is one fused
    # word-major kernel launch, with n_dk refreshed between chunks so the
    # within-document Gauss-Seidel effect of the scan layout is mostly
    # retained (1 = fully parallel Jacobi sweep).
    sorted_chunks: int = 4
    # Full-table build path.  The fused kernel (dense term computed
    # in-register, kernels/alias_build.py) measures ~2× slower than
    # materialize-then-build at current (V, K) — BENCH_throughput.json
    # shows 39.7ms fused vs 20.0ms unfused per build — so unfused stays
    # the default until the roofline item validates fused at production
    # sizes.  (The *partial* gather-fused rebuild is unaffected: it wins
    # by scaling with changed rows, not V.)
    fused_alias_build: bool = False


class SharedStats(NamedTuple):
    """Statistics synchronized through the parameter server."""

    n_wk: Array  # (V, K) float32
    n_k: Array   # (K,)  float32


class LocalState(NamedTuple):
    """Client-local sampler state."""

    z: Array     # (D, L) int32 topic assignments (padded)
    n_dk: Array  # (D, K) float32 doc-topic counts


def init_state(cfg: LDAConfig, tokens: Array, mask: Array, key: Array
               ) -> tuple[LocalState, SharedStats]:
    """Random topic init + consistent sufficient statistics."""
    d, l = tokens.shape
    z = jax.random.randint(key, (d, l), 0, cfg.n_topics, dtype=jnp.int32)
    z = jnp.where(mask, z, 0)
    n_dk = count_dk(cfg, z, mask)
    n_wk = count_wk(cfg, tokens, z, mask)
    return LocalState(z=z, n_dk=n_dk), SharedStats(n_wk=n_wk, n_k=n_wk.sum(0))


def count_dk(cfg: LDAConfig, z: Array, mask: Array) -> Array:
    onehot = jax.nn.one_hot(z, cfg.n_topics, dtype=jnp.float32)
    return jnp.einsum("dl,dlk->dk", mask.astype(jnp.float32), onehot)


def count_wk(cfg: LDAConfig, tokens: Array, z: Array, mask: Array) -> Array:
    w = tokens.reshape(-1)
    t = z.reshape(-1)
    m = mask.reshape(-1).astype(jnp.float32)
    return jnp.zeros((cfg.vocab_size, cfg.n_topics), jnp.float32).at[w, t].add(m)


def language_model(cfg: LDAConfig, shared: SharedStats) -> Array:
    """p(w|t) rows: (V, K) = (n_wk + β) / (n_k + β̄)."""
    beta_bar = cfg.beta * cfg.vocab_size
    return (shared.n_wk + cfg.beta) / (shared.n_k[None, :] + beta_bar)


def dense_probs(cfg: LDAConfig, shared: SharedStats) -> Array:
    """The dense proposal term α_t · (n_wt+β)/(n_t+β̄), per token-type row."""
    return cfg.alpha * language_model(cfg, shared)


def build_alias(cfg: LDAConfig, shared: SharedStats) -> tuple[alias_mod.AliasTable, Array]:
    """Build per-token-type alias tables over the (stale) dense term."""
    if cfg.fused_alias_build:
        from repro.kernels import ops
        tile_r = max(t for t in (8, 4, 2, 1) if cfg.vocab_size % t == 0)
        return ops.build_tables_fused_lda(
            shared.n_wk, shared.n_k, alpha=cfg.alpha, beta=cfg.beta,
            vocab_size=cfg.vocab_size, tile_r=tile_r)
    dp = dense_probs(cfg, shared)
    return alias_mod.build(dp), dp


@partial(jax.jit, static_argnames=("cfg", "method", "layout"))
def sweep(
    cfg: LDAConfig,
    local: LocalState,
    shared: SharedStats,
    tables: alias_mod.AliasTable,
    stale_dense: Array,
    tokens: Array,
    mask: Array,
    key: Array,
    method: str = "mhw",
    layout: str = "scan",
    sorted_layouts: tuple[segment.SortedLayout, ...] | None = None,
) -> tuple[LocalState, Array, Array]:
    """One Gibbs sweep over a client's shard.

    ``shared`` is the client's frozen snapshot for this sweep; ``tables`` /
    ``stale_dense`` may be *staler* (alias refresh cadence).  Returns the new
    local state plus the (V, K) and (K,) deltas to push to the server.

    ``layout="sorted"`` (mhw only) runs the fused token-sorted pipeline;
    pass prebuilt per-chunk ``sorted_layouts``
    (``segment.build_chunked_layouts``) to hoist the per-shard sorts out of
    the sweep — tokens never change between sweeps, so drivers should sort
    once and reuse.
    """
    if layout == "sorted":
        if method != "mhw":
            raise ValueError("layout='sorted' requires method='mhw'")
        return _sweep_sorted(cfg, local, shared, tables, stale_dense,
                             tokens, mask, key, sorted_layouts)
    if layout != "scan":
        raise ValueError(f"unknown layout {layout!r}")
    d, l = tokens.shape
    beta_bar = cfg.beta * cfg.vocab_size
    n_wk, n_k = shared.n_wk, shared.n_k

    def position_step(carry, inputs):
        n_dk = carry
        w, z_old, m, k = inputs  # (D,), (D,), (D,), key
        docs = jnp.arange(d)

        # Remove the token's own contribution (the ^{-di} correction) from
        # the local doc counts and the gathered word rows.
        n_dk_m = n_dk.at[docs, z_old].add(-mask_f(m))
        row_wk = n_wk[w]                                    # (D, K)
        own = jax.nn.one_hot(z_old, cfg.n_topics) * mask_f(m)[:, None]
        row_wk_m = row_wk - own
        n_k_m = n_k[None, :] - own
        lm_fresh = (row_wk_m + cfg.beta) / (n_k_m + beta_bar)  # (D, K)

        if method == "exact":
            logits = jnp.log(n_dk_m + cfg.alpha) + jnp.log(lm_fresh + 1e-30)
            z_new = jax.random.categorical(k, logits, axis=-1).astype(jnp.int32)
        elif method == "mhw":
            sparse_w = n_dk_m * lm_fresh                    # exact sparse term
            prop = mhw.MixtureProposal(
                sparse_weights=sparse_w, dense_tables=tables, dense_rows=w)

            def log_p(t):
                return (jnp.log(n_dk_m[docs, t] + cfg.alpha)
                        + jnp.log(lm_fresh[docs, t] + 1e-30))

            z_new = mhw.mh_chain(k, z_old, prop, stale_dense, log_p, cfg.mh_steps)
        else:
            raise ValueError(f"unknown method {method!r}")

        z_new = jnp.where(m, z_new, z_old)
        n_dk_out = n_dk_m.at[docs, z_new].add(mask_f(m))
        return n_dk_out, z_new

    keys = jax.random.split(key, l)
    inputs = (tokens.T, local.z.T, mask.T, keys)
    n_dk_final, z_new_t = jax.lax.scan(position_step, local.n_dk, inputs)
    z_new = z_new_t.T

    # Batched delta push (paper §5.3: whole rows of the word-topic matrix).
    w_flat = tokens.reshape(-1)
    m_flat = mask.reshape(-1).astype(jnp.float32)
    delta_wk = (
        jnp.zeros((cfg.vocab_size, cfg.n_topics), jnp.float32)
        .at[w_flat, z_new.reshape(-1)].add(m_flat)
        .at[w_flat, local.z.reshape(-1)].add(-m_flat)
    )
    delta_k = delta_wk.sum(0)
    return LocalState(z=z_new, n_dk=n_dk_final), delta_wk, delta_k


def _sweep_sorted(
    cfg: LDAConfig,
    local: LocalState,
    shared: SharedStats,
    tables: alias_mod.AliasTable,
    stale_dense: Array,
    tokens: Array,
    mask: Array,
    key: Array,
    layouts: tuple[segment.SortedLayout, ...] | None,
) -> tuple[LocalState, Array, Array]:
    """Token-sorted MHW sweep: the generic tile-skipping pipeline of
    ``repro.core.family`` instantiated for LDA (prior = α·1, fresh factor =
    the LM row).  See ``family.ModelFamily.sweep_sorted`` for the chunked
    Jacobi/Gauss-Seidel relaxation semantics."""
    from repro.core import family as family_mod
    local2, deltas = family_mod.get("lda").sweep_sorted(
        cfg, local, shared, tables, stale_dense, tokens, mask, key, layouts)
    return local2, deltas["n_wk"], deltas["n_wk"].sum(0)


def chunk_bounds(l: int, n_chunks: int) -> tuple[int, ...]:
    """Position-chunk boundaries for the sorted sweep (static per shape)."""
    return segment.chunk_bounds(l, n_chunks)


def sorted_tile_v(cfg: LDAConfig) -> int:
    """The vocab tile size the sorted sweep will use for ``cfg``.

    Hoisted layouts (``segment.build_chunked_layouts``) MUST be built with
    this exact tile size — the layout's vstart/vcount are in vocab-tile
    units and are consumed by kernels tiled with it.  Delegates to the
    family registry so the geometry cannot drift from the sweep's.
    """
    from repro.core import family as family_mod
    return family_mod.get("lda").sorted_tile_v(cfg)


def build_sorted_layouts(cfg: LDAConfig, tokens: Array, mask: Array
                         ) -> tuple[segment.SortedLayout, ...]:
    """Prebuild the per-chunk sorted layouts ``sweep(layout="sorted")``
    expects — delegates to the family registry so tile/chunk geometry
    cannot drift from what the sweep derives internally.  Build once per
    shard and reuse across sweeps (the layout depends only on tokens/mask).
    """
    from repro.core import family as family_mod
    return family_mod.get("lda").build_sorted_layouts(cfg, tokens, mask)


def mask_f(m: Array) -> Array:
    return m.astype(jnp.float32)


def apply_delta(shared: SharedStats, delta_wk: Array, delta_k: Array) -> SharedStats:
    return SharedStats(n_wk=shared.n_wk + delta_wk, n_k=shared.n_k + delta_k)


# ---------------------------------------------------------------------------
# Evaluation (paper §6, "Evaluation criteria")
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg", "n_fold_sweeps"))
def perplexity(
    cfg: LDAConfig,
    shared: SharedStats,
    tokens: Array,
    mask: Array,
    key: Array,
    n_fold_sweeps: int = 10,
) -> Array:
    """Held-out perplexity with fold-in estimation of θ_d.

    The language model φ is frozen from the trained statistics; θ_d is
    estimated by ``n_fold_sweeps`` Gibbs sweeps on the held-out documents,
    then π = exp(-Σ log p(w_d)/Σ N_d) with
    p(w) = Σ_t θ_dt φ_wt  (paper §6 evaluation criteria).
    """
    phi = language_model(cfg, shared)  # (V, K) — columns are p(w|t)
    d, l = tokens.shape

    k_init, k_sweeps = jax.random.split(key)
    z = jax.random.randint(k_init, (d, l), 0, cfg.n_topics, dtype=jnp.int32)
    n_dk = count_dk(cfg, jnp.where(mask, z, 0), mask)

    def fold_sweep(carry, k):
        z, n_dk = carry

        def pos(carry_in, inputs):
            n_dk = carry_in
            w, z_old, m, kk = inputs
            docs = jnp.arange(d)
            n_dk_m = n_dk.at[docs, z_old].add(-mask_f(m))
            logits = jnp.log(n_dk_m + cfg.alpha) + jnp.log(phi[w] + 1e-30)
            z_new = jax.random.categorical(kk, logits, axis=-1).astype(jnp.int32)
            z_new = jnp.where(m, z_new, z_old)
            return n_dk_m.at[docs, z_new].add(mask_f(m)), z_new

        keys = jax.random.split(k, l)
        n_dk2, z_new_t = jax.lax.scan(pos, n_dk, (tokens.T, z.T, mask.T, keys))
        return (z_new_t.T, n_dk2), None

    (z, n_dk), _ = jax.lax.scan(fold_sweep, (z, n_dk), jax.random.split(k_sweeps, n_fold_sweeps))

    theta = (n_dk + cfg.alpha) / (n_dk.sum(-1, keepdims=True) + cfg.alpha * cfg.n_topics)
    # log p(w_di) = log Σ_t θ_dt φ_w t
    pw = jnp.einsum("dk,dlk->dl", theta, phi[tokens])
    logp = jnp.where(mask, jnp.log(pw + 1e-30), 0.0)
    return jnp.exp(-logp.sum() / jnp.maximum(mask.sum(), 1))


def topics_per_word(shared: SharedStats, threshold: float = 0.5) -> Array:
    """Average number of non-zero topics across token-types (paper §6)."""
    nz = (shared.n_wk > threshold).sum(-1).astype(jnp.float32)
    seen = shared.n_wk.sum(-1) > threshold
    return jnp.where(seen, nz, 0.0).sum() / jnp.maximum(seen.sum(), 1)
