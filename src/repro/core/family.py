"""The ``ModelFamily`` protocol + registry: one model API for LDA/PDP/HDP.

The paper's central systems claim is that one inference stack — MHW
sampling (§3), the relaxed-consistency parameter server (§5.2-5.3) and
constraint projection (§5.5) — serves every latent-variable model family
uniformly.  This module is that claim as code: each family registers

* its **shared/local statistics** as named dicts (what the parameter
  server replicates vs. what stays client-local),
* its **projection rules and aggregates** — sourced verbatim from
  ``repro.core.projection.*_RULES`` / ``*_AGGREGATES`` and split by operand
  locality into ``shared_rules`` (applied by the distributed projection)
  and ``local_rules`` (applied to client state, e.g. HDP's
  1 ≤ m_dk ≤ n_dk table-count polytope) so no rule is silently dropped,
* its **dense-proposal factorization** (paper eq. 4): the conditional
  p(e) ∝ (doc_e + prior_e) · f_e over E outcomes, exposed through
  ``language_model`` / ``dense_probs`` / ``sparse_prior`` /
  ``doc_sparse_logp`` / ``accept_ratio`` — the hooks that let the generic
  MHW machinery (``core.mhw``, ``kernels.mhw_fused``,
  ``kernels.alias_sample``) and the token-sorted tile-skipping layout
  (``data.segment``) drive any family through one code path.

``ModelFamily.sweep_sorted`` is that one code path: the chunked
Jacobi/Gauss-Seidel sorted sweep (DESIGN.md §5.1) generic over families —
LDA and HDP share the lm kernel (per-topic prior vector), PDP runs the 2K
joint-outcome kernel.  Every family's fused kernel is validated bit-exact
against its pure-jnp oracle (tests/test_sorted_sweep.py).

Drivers — ``engine.Trainer``, ``core.distributed.make_round_fn``, the
benchmarks — consume only this protocol; they never import the model
modules directly.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import alias as alias_mod
from repro.core import hdp, lda, pdp, projection
from repro.core import mhw as mhw_mod
from repro.core import stirling
from repro.data import segment
from repro.kernels import ops

Array = jax.Array


def _rule_names(rule: projection.Rule) -> tuple[str, ...]:
    return (rule.a,) if rule.b is None else (rule.a, rule.b)


class ModelFamily:
    """Protocol base: per-family declarations + the generic machinery.

    Subclasses declare the class attributes and the abstract hooks; the
    base class owns everything that is genuinely family-independent (rule
    splitting, projection application, the chunked sorted sweep, layout
    geometry).  All methods take the family's config dataclass explicitly —
    a family singleton is stateless and shareable.
    """

    name: str = ""
    config_cls: type = object
    shared_cls: type = object
    local_cls: type = object
    shared_stats: tuple[str, ...] = ()
    local_stats: tuple[str, ...] = ()
    # Stats replicated (not summed) when merging per-shard initializations.
    replicated_stats: tuple[str, ...] = ()
    # Stats whose count mass is conserved by sweeps: recomputing them from
    # the assignments must reproduce the maintained values bit-exactly
    # (the sorted-vs-scan sufficient-statistics parity contract).
    conserved_stats: tuple[str, ...] = ()
    delta_names: tuple[str, ...] = ()
    rules: tuple[projection.Rule, ...] = ()
    aggregates: tuple[projection.Aggregate, ...] = ()

    # ---------------------------------------------------------------- rules
    @property
    def shared_rules(self) -> tuple[projection.Rule, ...]:
        return tuple(r for r in self.rules
                     if set(_rule_names(r)) <= set(self.shared_stats))

    @property
    def local_rules(self) -> tuple[projection.Rule, ...]:
        return tuple(r for r in self.rules
                     if set(_rule_names(r)) <= set(self.local_stats))

    # ---------------------------------------------------------------- state
    def init_state(self, cfg, tokens: Array, mask: Array, key: Array):
        raise NotImplementedError

    def stats_dict(self, shared) -> dict[str, Array]:
        return dict(shared._asdict())

    def shared_from_dict(self, d: dict[str, Array]):
        return self.shared_cls(**{n: d[n] for n in self.shared_stats})

    def local_dict(self, local) -> dict[str, Array]:
        return dict(local._asdict())

    def local_from_dict(self, d: dict[str, Array]):
        return self.local_cls(**{n: d[n] for n in self.local_stats})

    # ------------------------------------- dense-proposal factorization
    def n_outcomes(self, cfg) -> int:
        """E: the size of the per-token outcome space (K, or 2K for PDP)."""
        return cfg.n_topics

    def language_model(self, cfg, shared) -> Array:
        raise NotImplementedError

    def dense_probs(self, cfg, shared) -> Array:
        """(V, E) stale dense proposal term prior_e · f_e per token-type."""
        raise NotImplementedError

    def build_alias(self, cfg, shared):
        """(alias tables, stale dense matrix) over :meth:`dense_probs`."""
        raise NotImplementedError

    def sparse_prior(self, cfg, shared) -> Array:
        """(E,) per-outcome prior mass added to the document-sparse counts
        in the target: α·1 for LDA/PDP, b1·θ0 for HDP."""
        raise NotImplementedError

    # --------------------------------------- incremental alias maintenance
    @property
    def alias_delta_stats(self) -> tuple[str, ...]:
        """Shared statistics whose per-row drift stales the alias rows —
        what the delta-driven producer watches (n_wk for the LM families;
        both m_wk and s_wk for PDP, whose dense rows depend on both)."""
        return self.delta_names

    def dense_probs_rows(self, cfg, shared, rows: Array) -> Array:
        """Gathered (R, E) dense-proposal rows for token-types ``rows`` —
        must match ``dense_probs(cfg, shared)[rows]`` bit-for-bit.  The
        default materializes the full dense term; families override with
        O(R·E) gathered math so incremental rebuild cost scales with the
        changed rows, not V."""
        return self.dense_probs(cfg, shared)[rows]

    def rebuild_alias_rows(self, cfg, shared, tables: alias_mod.AliasTable,
                           stale: Array, rows: Array, valid: Array
                           ) -> tuple[alias_mod.AliasTable, Array]:
        """Incremental alias producer (paper §5.1 / §3.3): rebuild only the
        token-type ``rows`` (gather → build-from-stats kernel → scatter into
        the resident table + stale snapshot).  Rows with ``valid=False``
        keep their current entries.  Generic path: gathered dense rows +
        the compacted-rows build kernel; the LM families override with the
        fully fused gather kernel."""
        p_rows = self.dense_probs_rows(cfg, shared, rows)
        sub = ops.build_tables_rows(p_rows)
        return alias_mod.update_rows(tables, stale, rows, valid, sub, p_rows)

    def doc_sparse_logp(self, cfg, shared, doc_rows: Array, outcome: Array
                        ) -> Array:
        """log of the document-sparse target factor at ``outcome``:
        log(doc_e + prior_e).  doc_rows: (B, E); outcome: (B,) → (B,).

        SEALED accessor, not an injection point: it resolves to
        ``mhw.doc_sparse_logp`` — the same module-level function
        ``mhw.mix_chain`` (and through it every oracle and fused kernel)
        evaluates directly, because the bit-exactness contract between
        kernels and oracles forbids virtual dispatch inside the chain.
        A family customizes its target through ``sparse_prior`` and the
        fresh-factor computation of its ``sorted_chunk``/scan sweep, never
        by overriding this method (an override would not reach the chain).
        """
        return mhw_mod.doc_sparse_logp(doc_rows,
                                       self.sparse_prior(cfg, shared),
                                       outcome)

    def accept_ratio(self, log_p_cand: Array, log_p_cur: Array,
                     log_q_cur: Array, log_q_cand: Array) -> Array:
        """MH acceptance log-ratio (paper eq. 7) — identical for every
        family; SEALED like :meth:`doc_sparse_logp`, resolving to
        ``mhw.accept_log_ratio`` (which the chain calls directly)."""
        return mhw_mod.accept_log_ratio(log_p_cand, log_p_cur,
                                        log_q_cur, log_q_cand)

    # ---------------------------------------------------------------- sweeps
    def sweep(self, cfg, local, shared, tables, stale: Array, tokens: Array,
              mask: Array, key: Array, *, method: str = "mhw",
              layout: str = "scan", sorted_layouts: tuple | None = None
              ) -> tuple[Any, dict[str, Array]]:
        """One Gibbs sweep; returns (local', {delta_name: (V, K) delta})."""
        raise NotImplementedError

    def apply_delta(self, shared, deltas: dict[str, Array]):
        """Apply pushed deltas and re-derive aggregates (the C2 rule)."""
        raise NotImplementedError

    def count_stats(self, cfg, tokens: Array, mask: Array, local
                    ) -> dict[str, Array]:
        """Recompute the shard's contribution to each conserved shared
        statistic directly from the assignments (consistency oracle)."""
        raise NotImplementedError

    # ----------------------------------------------------------- projection
    def project(self, shared):
        """Algorithm 1 on the shared statistics (rules + C2 aggregates)."""
        stats = projection.project(self.stats_dict(shared),
                                   self.shared_rules, self.aggregates)
        return self.shared_from_dict(stats)

    def count_violations(self, shared) -> Array:
        return projection.count_violations(self.stats_dict(shared),
                                           self.shared_rules)

    def local_project(self, local):
        """Apply the family's client-local constraint rules (e.g. HDP's
        1 ≤ m_dk ≤ n_dk) to the local state.  Identity when none exist."""
        if not self.local_rules:
            return local
        d = projection.project(self.local_dict(local), self.local_rules)
        return self.local_from_dict(d)

    def count_local_violations(self, local) -> Array:
        return projection.count_violations(self.local_dict(local),
                                           self.local_rules)

    # ------------------------------------------------------------ lifecycle
    def post_round(self, cfg, locals_: list, shared, key: Array):
        """Per-round auxiliary resampling hook (HDP's CRT tables + θ0).
        Default: no-op."""
        return locals_, shared

    def perplexity(self, cfg, shared, tokens: Array, mask: Array, key: Array
                   ) -> Array:
        raise NotImplementedError

    def topics_per_word(self, shared) -> Array:
        raise NotImplementedError

    # ---------------------------------------------- token-sorted fast path
    def sorted_tile_v(self, cfg) -> int:
        """The vocab tile size the sorted sweep will use for ``cfg`` —
        hoisted layouts MUST be built with this exact size.  The VMEM
        budget is taken over the (tile_v, E) joint-outcome tiles —
        (tile_v, tile_k) when ``cfg.tile_k`` turns on the K-tiled
        staging, which is what keeps tile_v usable at K=1024+."""
        return cfg.tile_v or segment.pick_tile_vmem(
            cfg.vocab_size, self.n_outcomes(cfg),
            tile_k=self.sorted_tile_k(cfg))

    def sorted_tile_k(self, cfg) -> int | None:
        """K-tile size for the fused kernels' staging axis (None = full
        K).  Layout geometry does not depend on it, only kernel VMEM."""
        return getattr(cfg, "tile_k", None)

    def build_sorted_layouts(self, cfg, tokens: Array, mask: Array
                             ) -> tuple[segment.SortedLayout, ...]:
        """Prebuild the per-chunk sorted layouts ``sweep_sorted`` expects —
        the one sanctioned recipe, so tile/chunk geometry cannot drift from
        what the sweep derives internally.  Build once per shard and reuse
        across sweeps (the layout depends only on tokens/mask)."""
        l = tokens.shape[1]
        n_chunks = max(1, min(cfg.sorted_chunks, l))
        return segment.build_chunked_layouts(
            tokens, mask, cfg.vocab_size,
            bounds=segment.chunk_bounds(l, n_chunks),
            tile_v=self.sorted_tile_v(cfg), tile_b=cfg.tile_b)

    # per-family hooks for the generic chunked sweep ----------------------
    def encode(self, cfg, local) -> Array:
        """(D, L) int32 chain state per position (joint outcome for PDP)."""
        raise NotImplementedError

    def topic_of(self, cfg, e: Array) -> Array:
        """Map encoded outcomes to topic ids (identity for lm families)."""
        return e

    def sorted_chunk(self, cfg, shared, tables, stale: Array,
                     lay: segment.SortedLayout, e_sorted: Array,
                     ndk_rows: Array, key: Array, tile_v: int, tile_b: int,
                     uniforms: tuple[Array, ...] | None = None) -> Array:
        """Run the family's fused kernel over one sorted chunk.

        ``uniforms`` (optional) overrides the chain's internal uniform
        draw with caller-supplied ``(slot, coin, u_mix, u_sparse, u_acc)``
        streams in sorted-stream order — see ``ops.mhw_sweep_sorted``.
        """
        raise NotImplementedError

    def finalize_sorted(self, cfg, local, e_grid: Array, n_dk: Array,
                        tokens: Array, mask: Array
                        ) -> tuple[Any, dict[str, Array]]:
        """Decode the final outcome grid into (local', deltas)."""
        raise NotImplementedError

    def sweep_sorted(self, cfg, local, shared, tables, stale: Array,
                     tokens: Array, mask: Array, key: Array,
                     layouts: tuple[segment.SortedLayout, ...] | None,
                     chunk_uniforms=None) -> tuple[Any, dict[str, Array]]:
        """Token-sorted MHW sweep: fused tile-skipping chains per shard.

        The sweep runs as ``cfg.sorted_chunks`` sequential position-chunks.
        Within a chunk every token proposes word-major against the current
        statistics minus its own contribution (the ^{-di} correction) —
        fully parallel, one fused kernel launch; between chunks ``n_dk`` is
        refreshed so each document's counts advance ``sorted_chunks`` times
        per sweep (the scan layout's Gauss-Seidel recurrence, coarsened).
        The shared statistics stay the sweep-start snapshot throughout,
        exactly as in the scan layout.

        ``chunk_uniforms`` (optional) is a callback ``(c, lay, tile_b) ->
        uniforms | None`` giving the per-chunk uniform streams for
        :meth:`sorted_chunk`; the serving engine supplies per-request
        streams here so each document's chain is independent of its
        batch-mates (DESIGN.md §14).
        """
        d, l = tokens.shape
        tile_v = self.sorted_tile_v(cfg)
        n_chunks = max(1, min(cfg.sorted_chunks, l))
        bounds = segment.chunk_bounds(l, n_chunks)
        if layouts is not None and len(layouts) != n_chunks:
            raise ValueError(
                f"sorted_layouts has {len(layouts)} chunks, cfg wants "
                f"{n_chunks}; rebuild with "
                f"family.get({self.name!r}).build_sorted_layouts(cfg, ...)")

        e_grid = self.encode(cfg, local)
        n_dk = local.n_dk
        for c in range(n_chunks):
            s, e = bounds[c], bounds[c + 1]
            tok_c, mask_c = tokens[:, s:e], mask[:, s:e]
            bc = d * (e - s)
            tile_b = min(cfg.tile_b, bc)
            lay = layouts[c] if layouts is not None else segment.build_layout(
                tok_c, mask_c, cfg.vocab_size, tile_v=tile_v, tile_b=tile_b)

            # Geometry guard for hoisted layouts: vstart/vcount are in
            # vocab-tile units and rows are padded to tile_b — a layout
            # built with different tiles would sample silently wrong.
            if lay.hist.shape[0] * tile_v != cfg.vocab_size:
                raise ValueError(
                    f"sorted_layouts[{c}] was built with tile_v="
                    f"{cfg.vocab_size // lay.hist.shape[0]}, sweep uses "
                    f"{tile_v}; rebuild with "
                    f"family.get({self.name!r}).build_sorted_layouts")
            if (lay.rows.shape[0] % tile_b != 0
                    or lay.vstart.shape[0] != lay.rows.shape[0] // tile_b):
                raise ValueError(
                    f"sorted_layouts[{c}] batch tiling "
                    f"({lay.vstart.shape[0]} tiles over "
                    f"{lay.rows.shape[0]} draws) does not match "
                    f"tile_b={tile_b}")

            e_c = e_grid[:, s:e]
            e_flat = e_c.reshape(-1)
            e_s = segment.sort_values(lay, e_flat, fill=0)
            ndk = n_dk[lay.docs]   # raw rows; the kernel applies the ^{-di}

            uniforms = (chunk_uniforms(c, lay, tile_b)
                        if chunk_uniforms is not None else None)
            e_new_s = self.sorted_chunk(cfg, shared, tables, stale, lay,
                                        e_s, ndk, jax.random.fold_in(key, c),
                                        tile_v, tile_b, uniforms=uniforms)

            e_new_flat = segment.unsort_values(lay, e_new_s, e_flat)
            e_new_c = jnp.where(mask_c, e_new_flat.reshape(d, e - s), e_c)

            docs_c = jnp.arange(bc, dtype=jnp.int32) // (e - s)
            m_c = mask_c.reshape(-1).astype(jnp.float32)
            n_dk = (n_dk
                    .at[docs_c, self.topic_of(cfg, e_new_c.reshape(-1))]
                    .add(m_c)
                    .at[docs_c, self.topic_of(cfg, e_flat)].add(-m_c))
            e_grid = e_grid.at[:, s:e].set(e_new_c)

        return self.finalize_sorted(cfg, local, e_grid, n_dk, tokens, mask)


class _LMFamilyBase(ModelFamily):
    """Shared machinery for the families whose fresh factor is the LM row
    (n_wk − own + β)/(n_k − own + β̄): LDA and HDP-LDA.  They differ only
    in the per-topic prior vector and their extra shared statistics."""

    def language_model(self, cfg, shared) -> Array:
        beta_bar = cfg.beta * cfg.vocab_size
        return (shared.n_wk + cfg.beta) / (shared.n_k[None, :] + beta_bar)

    def dense_probs_rows(self, cfg, shared, rows: Array) -> Array:
        # prior · (LM row) with the division grouped first — the exact
        # operation order of dense_probs, so partial and full rebuilds of
        # the same statistics agree bit-for-bit.
        beta_bar = cfg.beta * cfg.vocab_size
        return (self.sparse_prior(cfg, shared)[None, :]
                * ((shared.n_wk[rows] + cfg.beta)
                   / (shared.n_k[None, :] + beta_bar)))

    def rebuild_alias_rows(self, cfg, shared, tables, stale, rows, valid):
        """LM-dense fast path: the scalar-prefetched gather kernel computes
        prior_e·(n_wk+β)/(n_k+β̄) in-register from the gathered rows and
        builds the sub-table in one fused launch."""
        sub, p_rows = ops.build_tables_gather_fused(
            shared.n_wk, shared.n_k, self.sparse_prior(cfg, shared), rows,
            beta=cfg.beta, beta_bar=cfg.beta * cfg.vocab_size)
        return alias_mod.update_rows(tables, stale, rows, valid, sub, p_rows)

    def encode(self, cfg, local) -> Array:
        return local.z

    def sorted_chunk(self, cfg, shared, tables, stale, lay, e_sorted,
                     ndk_rows, key, tile_v, tile_b, uniforms=None) -> Array:
        return ops.mhw_sweep_sorted(
            tables, stale, shared.n_wk, shared.n_k,
            self.sparse_prior(cfg, shared), lay.rows, e_sorted, ndk_rows,
            lay.vstart, lay.vcount, key, mh_steps=cfg.mh_steps,
            beta=cfg.beta, beta_bar=cfg.beta * cfg.vocab_size,
            tile_v=tile_v, tile_b=tile_b, tile_k=self.sorted_tile_k(cfg),
            uniforms=uniforms)

    def _delta_wk(self, cfg, tokens, mask, z_old, z_new) -> Array:
        w_flat = tokens.reshape(-1)
        m_flat = mask.reshape(-1).astype(jnp.float32)
        return (jnp.zeros((cfg.vocab_size, cfg.n_topics), jnp.float32)
                .at[w_flat, z_new.reshape(-1)].add(m_flat)
                .at[w_flat, z_old.reshape(-1)].add(-m_flat))

    def count_stats(self, cfg, tokens, mask, local) -> dict[str, Array]:
        w = tokens.reshape(-1)
        t = local.z.reshape(-1)
        m = mask.reshape(-1).astype(jnp.float32)
        n_wk = (jnp.zeros((cfg.vocab_size, cfg.n_topics), jnp.float32)
                .at[w, t].add(m))
        return {"n_wk": n_wk}

    def topics_per_word(self, shared) -> Array:
        return lda.topics_per_word(
            lda.SharedStats(n_wk=shared.n_wk, n_k=shared.n_k))


class LDAFamily(_LMFamilyBase):
    name = "lda"
    config_cls = lda.LDAConfig
    shared_cls = lda.SharedStats
    local_cls = lda.LocalState
    shared_stats = ("n_wk", "n_k")
    local_stats = ("z", "n_dk")
    conserved_stats = ("n_wk",)
    delta_names = ("n_wk",)
    rules = projection.LDA_RULES
    aggregates = projection.LDA_AGGREGATES

    def init_state(self, cfg, tokens, mask, key):
        return lda.init_state(cfg, tokens, mask, key)

    def dense_probs(self, cfg, shared) -> Array:
        return lda.dense_probs(cfg, shared)

    def build_alias(self, cfg, shared):
        return lda.build_alias(cfg, shared)

    def sparse_prior(self, cfg, shared) -> Array:
        return jnp.full((cfg.n_topics,), cfg.alpha, jnp.float32)

    def sweep(self, cfg, local, shared, tables, stale, tokens, mask, key, *,
              method="mhw", layout="scan", sorted_layouts=None):
        local2, dwk, _ = lda.sweep(cfg, local, shared, tables, stale, tokens,
                                   mask, key, method=method, layout=layout,
                                   sorted_layouts=sorted_layouts)
        return local2, {"n_wk": dwk}

    def apply_delta(self, shared, deltas):
        n_wk = shared.n_wk + deltas["n_wk"]
        return lda.SharedStats(n_wk=n_wk, n_k=n_wk.sum(0))

    def finalize_sorted(self, cfg, local, e_grid, n_dk, tokens, mask):
        dwk = self._delta_wk(cfg, tokens, mask, local.z, e_grid)
        return lda.LocalState(z=e_grid, n_dk=n_dk), {"n_wk": dwk}

    def perplexity(self, cfg, shared, tokens, mask, key) -> Array:
        return lda.perplexity(cfg, shared, tokens, mask, key)


class HDPFamily(_LMFamilyBase):
    name = "hdp"
    config_cls = hdp.HDPConfig
    shared_cls = hdp.SharedStats
    local_cls = hdp.LocalState
    shared_stats = ("n_wk", "n_k", "m_k", "theta0")
    local_stats = ("z", "n_dk", "m_dk")
    replicated_stats = ("theta0",)
    conserved_stats = ("n_wk",)
    delta_names = ("n_wk",)
    rules = projection.HDP_RULES
    aggregates = projection.HDP_AGGREGATES

    def init_state(self, cfg, tokens, mask, key):
        return hdp.init_state(cfg, tokens, mask, key)

    def dense_probs(self, cfg, shared) -> Array:
        return hdp.dense_probs(cfg, shared)

    def build_alias(self, cfg, shared):
        return hdp.build_alias(cfg, shared)

    def sparse_prior(self, cfg, shared) -> Array:
        return cfg.b1 * shared.theta0

    def sweep(self, cfg, local, shared, tables, stale, tokens, mask, key, *,
              method="mhw", layout="scan", sorted_layouts=None):
        local2, dwk, _ = hdp.sweep(cfg, local, shared, tables, stale, tokens,
                                   mask, key, method=method, layout=layout,
                                   sorted_layouts=sorted_layouts)
        return local2, {"n_wk": dwk}

    def apply_delta(self, shared, deltas):
        n_wk = shared.n_wk + deltas["n_wk"]
        return hdp.SharedStats(n_wk=n_wk, n_k=n_wk.sum(0),
                               m_k=shared.m_k, theta0=shared.theta0)

    def finalize_sorted(self, cfg, local, e_grid, n_dk, tokens, mask):
        dwk = self._delta_wk(cfg, tokens, mask, local.z, e_grid)
        return (hdp.LocalState(z=e_grid, n_dk=n_dk, m_dk=local.m_dk),
                {"n_wk": dwk})

    def post_round(self, cfg, locals_, shared, key):
        """CRT table resampling per client; m_k sums across clients (it is
        a shared aggregation parameter, paper §5.2), then θ0 | m_k."""
        m_k_total = None
        locals_ = list(locals_)
        for c in range(len(locals_)):
            locals_[c], m_k = hdp.resample_tables(
                cfg, locals_[c], shared, jax.random.fold_in(key, c))
            m_k_total = m_k if m_k_total is None else m_k_total + m_k
        theta0 = hdp.resample_theta0(cfg, m_k_total,
                                     jax.random.fold_in(key, 101))
        shared = hdp.SharedStats(n_wk=shared.n_wk, n_k=shared.n_k,
                                 m_k=m_k_total, theta0=theta0)
        return locals_, shared

    def perplexity(self, cfg, shared, tokens, mask, key) -> Array:
        return hdp.perplexity(cfg, shared, tokens, mask, key)


class PDPFamily(ModelFamily):
    name = "pdp"
    config_cls = pdp.PDPConfig
    shared_cls = pdp.SharedStats
    local_cls = pdp.LocalState
    shared_stats = ("m_wk", "s_wk", "m_k", "s_k")
    local_stats = ("z", "r", "n_dk")
    # s_wk is NOT count-conserved: init_state's polytope repair (and the
    # projection) adjusts table counts without rewriting per-token r
    # indicators — s_wk is governed by the constraint rules instead.
    conserved_stats = ("m_wk",)
    delta_names = ("m_wk", "s_wk")
    rules = projection.PDP_RULES
    aggregates = projection.PDP_AGGREGATES

    def init_state(self, cfg, tokens, mask, key):
        return pdp.init_state(cfg, tokens, mask, key)

    def n_outcomes(self, cfg) -> int:
        return 2 * cfg.n_topics

    def language_model(self, cfg, shared) -> Array:
        return pdp.language_model(cfg, shared)

    def dense_probs(self, cfg, shared) -> Array:
        return pdp.dense_probs(cfg, shared)

    def build_alias(self, cfg, shared):
        return pdp.build_alias(cfg, shared)

    def sparse_prior(self, cfg, shared) -> Array:
        return jnp.full((2 * cfg.n_topics,), cfg.alpha, jnp.float32)

    def dense_probs_rows(self, cfg, shared, rows: Array) -> Array:
        # The (m, s)-dependent joint rows: both table and customer counts
        # of the gathered token-types feed the 2K outcome columns — which
        # is why alias_delta_stats tracks m_wk AND s_wk drift for PDP.
        table = stirling.as_jax(cfg.stirling_n_max, cfg.discount)
        log_f0, log_f1 = pdp._log_factors(
            cfg, table, shared.m_wk[rows], shared.s_wk[rows],
            shared.m_k[None, :], shared.s_k[None, :])
        return cfg.alpha * jnp.concatenate(
            [jnp.exp(log_f0), jnp.exp(log_f1)], axis=-1)

    def sweep(self, cfg, local, shared, tables, stale, tokens, mask, key, *,
              method="mhw", layout="scan", sorted_layouts=None):
        local2, dm, ds = pdp.sweep(cfg, local, shared, tables, stale, tokens,
                                   mask, key, method=method, layout=layout,
                                   sorted_layouts=sorted_layouts)
        return local2, {"m_wk": dm, "s_wk": ds}

    def apply_delta(self, shared, deltas):
        m_wk = shared.m_wk + deltas["m_wk"]
        s_wk = shared.s_wk + deltas["s_wk"]
        return pdp.SharedStats(m_wk=m_wk, s_wk=s_wk,
                               m_k=m_wk.sum(0), s_k=s_wk.sum(0))

    def count_stats(self, cfg, tokens, mask, local) -> dict[str, Array]:
        m_wk = pdp._count(cfg, tokens, local.z, mask,
                          jnp.ones_like(local.r))
        s_wk = pdp._count(cfg, tokens, local.z, mask, local.r)
        return {"m_wk": m_wk, "s_wk": s_wk}

    def topics_per_word(self, shared) -> Array:
        return lda.topics_per_word(
            lda.SharedStats(n_wk=shared.m_wk, n_k=shared.m_k))

    def encode(self, cfg, local) -> Array:
        return local.z + cfg.n_topics * local.r

    def topic_of(self, cfg, e: Array) -> Array:
        return e % cfg.n_topics

    def sorted_chunk(self, cfg, shared, tables, stale, lay, e_sorted,
                     ndk_rows, key, tile_v, tile_b, uniforms=None) -> Array:
        stirl = stirling.as_jax(cfg.stirling_n_max, cfg.discount)
        return ops.pdp_sweep_sorted(
            tables, stale, shared.m_wk, shared.s_wk, shared.m_k, shared.s_k,
            stirl, self.sparse_prior(cfg, shared), lay.rows, e_sorted,
            ndk_rows, lay.vstart,
            lay.vcount, key, mh_steps=cfg.mh_steps,
            concentration=cfg.concentration, discount=cfg.discount,
            gamma=cfg.gamma, gamma_bar=cfg.gamma * cfg.vocab_size,
            tile_v=tile_v, tile_b=tile_b, tile_k=self.sorted_tile_k(cfg),
            uniforms=uniforms)

    def finalize_sorted(self, cfg, local, e_grid, n_dk, tokens, mask):
        z_new = e_grid % cfg.n_topics
        r_new = e_grid // cfg.n_topics
        dm, ds = pdp.deltas_from(cfg, tokens, mask, local.z, local.r,
                                 z_new, r_new)
        return (pdp.LocalState(z=z_new, r=r_new, n_dk=n_dk),
                {"m_wk": dm, "s_wk": ds})

    def perplexity(self, cfg, shared, tokens, mask, key) -> Array:
        return pdp.perplexity(cfg, shared, tokens, mask, key)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

FAMILIES: dict[str, ModelFamily] = {}


def register(family: ModelFamily) -> ModelFamily:
    """Register a family singleton under its name (last wins).

    Rejects a family whose shared/local rule split does not cover its full
    rule set — a rule mixing shared and local operands would otherwise be
    silently dropped from BOTH projection paths (the exact bug class the
    registry exists to prevent).
    """
    dropped = set(family.rules) - set(family.shared_rules) \
        - set(family.local_rules)
    if dropped:
        raise ValueError(
            f"family {family.name!r}: rules {sorted(r.a for r in dropped)} "
            "span shared and local statistics — neither projection path "
            "would apply them; split the rule or fix the stat declarations")
    FAMILIES[family.name] = family
    return family


register(LDAFamily())
register(PDPFamily())
register(HDPFamily())


def get(name: str) -> ModelFamily:
    try:
        return FAMILIES[name]
    except KeyError:
        raise KeyError(f"unknown model family {name!r}; registered: "
                       f"{sorted(FAMILIES)}") from None


def family_of(cfg: Any) -> ModelFamily:
    """Resolve the registered family for a model config instance."""
    for fam in FAMILIES.values():
        if isinstance(cfg, fam.config_cls):
            return fam
    raise TypeError(f"no registered ModelFamily for config {type(cfg)!r}")


def names() -> Sequence[str]:
    return sorted(FAMILIES)
