"""Walker's alias method (Vose variant) in pure JAX.

The alias method preprocesses a categorical distribution ``p`` over ``K``
outcomes into a table of ``K`` (prob, alias) pairs so that subsequent draws
cost O(1) each.  This is the "Walker" half of the paper's
Metropolis-Hastings-Walker sampler (paper §3.1).

The construction here is the functional, fixed-shape analogue of the
classical two-stack algorithm so it can be ``vmap``-ed across rows (one table
per token-type) and lowered to TPU.  ``repro.kernels.alias_build`` contains
the Pallas kernel version; this module is the reference implementation and
the public API.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AliasTable(NamedTuple):
    """Alias table for one (or a batch of) categorical distribution(s).

    Attributes:
      prob:  (..., K) float32 — acceptance threshold per slot, in [0, 1].
      alias: (..., K) int32   — alternative outcome per slot.
      mass:  (...,)   float32 — total unnormalized mass of the distribution
             (kept so callers can form mixture weights between several
             alias tables without renormalizing, as the sparse+dense split
             in the paper requires).
    """

    prob: jax.Array
    alias: jax.Array
    mass: jax.Array


def _build_one(p: jax.Array) -> AliasTable:
    """Build an alias table for a single unnormalized distribution ``p``.

    Functional two-stack (small/large) construction with a fixed iteration
    count of K so it is jit/vmap friendly.  Matches Vose's algorithm; any
    numerically-leftover slots default to (prob=1, alias=self), which is the
    standard robust finish.
    """
    k = p.shape[0]
    mass = jnp.sum(p)
    # Guard against all-zero rows: fall back to uniform.
    safe = mass > 0
    pn = jnp.where(safe, p / jnp.where(safe, mass, 1.0), jnp.full_like(p, 1.0 / k))
    scaled = pn * k  # mean 1.0

    idx = jnp.arange(k, dtype=jnp.int32)
    is_small = scaled < 1.0
    # Stable partition of indices into a "small" stack and a "large" stack.
    order = jnp.argsort(is_small)  # larges first, smalls last
    stack = idx[order].astype(jnp.int32)
    n_small = jnp.sum(is_small).astype(jnp.int32)
    n_large = (k - n_small).astype(jnp.int32)
    # Layout: stack[0 : n_large] are larges; stack[k - n_small :] are smalls.
    # Treat them as two stacks growing toward each other via pointers.
    large_top = n_large - 1          # index of current top of large stack
    small_top = k - n_small          # index of current top of small stack

    prob = jnp.ones((k,), jnp.float32)
    alias = idx.copy()
    assigned = jnp.zeros((k,), jnp.bool_)

    def body(_, carry):
        prob, alias, assigned, scaled, stack, large_top, small_top, n_small, n_large = carry
        active = (n_small > 0) & (n_large > 0)

        i = stack[jnp.clip(small_top, 0, k - 1)]     # a small entry
        j = stack[jnp.clip(large_top, 0, k - 1)]     # a large entry

        new_prob = jnp.where(active, prob.at[i].set(scaled[i]), prob)
        new_alias = jnp.where(active, alias.at[i].set(j), alias)
        new_assigned = jnp.where(active, assigned.at[i].set(True), assigned)
        # Large donor absorbs the slack of the small slot.
        sj = scaled[j] - (1.0 - scaled[i])
        new_scaled = jnp.where(active, scaled.at[j].set(sj), scaled)

        # Pop the small; pop the large; re-push the large onto whichever
        # stack it now belongs to.
        j_is_small = sj < 1.0
        # Popping small: small_top moves up (stack grows downward from k-1).
        small_top2 = small_top + 1
        n_small2 = n_small - 1
        large_top2 = large_top - 1
        n_large2 = n_large - 1
        # Re-push j.
        #   if j is now small: place at small_top2 - 1.
        #   else:              place back at large_top2 + 1.
        push_small_pos = small_top2 - 1
        push_large_pos = large_top2 + 1
        pos = jnp.where(j_is_small, push_small_pos, push_large_pos)
        new_stack = jnp.where(active, stack.at[jnp.clip(pos, 0, k - 1)].set(j), stack)
        small_top3 = jnp.where(j_is_small, push_small_pos, small_top2)
        n_small3 = jnp.where(j_is_small, n_small2 + 1, n_small2)
        large_top3 = jnp.where(j_is_small, large_top2, push_large_pos)
        n_large3 = jnp.where(j_is_small, n_large2, n_large2 + 1)

        small_top3 = jnp.where(active, small_top3, small_top)
        n_small3 = jnp.where(active, n_small3, n_small)
        large_top3 = jnp.where(active, large_top3, large_top)
        n_large3 = jnp.where(active, n_large3, n_large)

        return (new_prob, new_alias, new_assigned, new_scaled, new_stack,
                large_top3, small_top3, n_small3, n_large3)

    init = (prob, alias, assigned, scaled, stack, large_top, small_top, n_small, n_large)
    prob, alias, assigned, scaled, *_ = jax.lax.fori_loop(0, k, body, init)

    # Anything never assigned (leftover larges, numerical leftovers) keeps
    # prob=1 / alias=self.
    prob = jnp.where(assigned, prob, 1.0)
    alias = jnp.where(assigned, alias, idx)
    return AliasTable(prob=prob.astype(jnp.float32), alias=alias.astype(jnp.int32),
                      mass=mass.astype(jnp.float32))


@partial(jax.jit, static_argnames=())
def build(p: jax.Array) -> AliasTable:
    """Build alias table(s) for ``p`` with shape (..., K) (unnormalized)."""
    flat = p.reshape((-1, p.shape[-1]))
    tables = jax.vmap(_build_one)(flat.astype(jnp.float32))
    batch = p.shape[:-1]
    return AliasTable(
        prob=tables.prob.reshape(batch + (p.shape[-1],)),
        alias=tables.alias.reshape(batch + (p.shape[-1],)),
        mass=tables.mass.reshape(batch),
    )


def sample(table: AliasTable, key: jax.Array, shape: tuple[int, ...] = ()) -> jax.Array:
    """Draw samples from a single alias table in O(1) per draw.

    ``table`` has K slots; returns int32 array of ``shape``.
    """
    if table.prob.ndim != 1:
        raise ValueError("sample() expects a single table; use sample_rows for batches")
    k = table.prob.shape[-1]
    k_slot, k_coin = jax.random.split(key)
    slot = jax.random.randint(k_slot, shape, 0, k, dtype=jnp.int32)
    coin = jax.random.uniform(k_coin, shape)
    take_slot = coin < table.prob[slot]
    return jnp.where(take_slot, slot, table.alias[slot]).astype(jnp.int32)


def sample_rows(tables: AliasTable, rows: jax.Array, key: jax.Array) -> jax.Array:
    """Draw one sample per entry of ``rows`` from per-row alias tables.

    ``tables`` holds (R, K) tables; ``rows`` is an int32 array of row indices
    (one draw per element).  This is the access pattern of the sampler: each
    token draws from the alias table of *its own* token-type.
    """
    k = tables.prob.shape[-1]
    k_slot, k_coin = jax.random.split(key)
    slot = jax.random.randint(k_slot, rows.shape, 0, k, dtype=jnp.int32)
    coin = jax.random.uniform(k_coin, rows.shape)
    prob = tables.prob[rows, slot]
    alias = tables.alias[rows, slot]
    return jnp.where(coin < prob, slot, alias).astype(jnp.int32)


def update_rows(tables: AliasTable, stale: jax.Array, rows: jax.Array,
                valid: jax.Array, sub: AliasTable, p_rows: jax.Array
                ) -> tuple[AliasTable, jax.Array]:
    """Scatter freshly built rows into a resident table + stale snapshot.

    The consumer half of the incremental alias producer (paper §5.1): rows
    of ``sub``/``p_rows`` (built over the gathered, drifted token-types
    ``rows``) replace the resident entries; rows with ``valid=False`` keep
    their current entries, so a fixed-size top-k selection can carry
    below-threshold padding without touching the table.  ``rows`` must be
    duplicate-free for valid entries (``lax.top_k`` indices are).
    """
    keep = ~valid
    sel = lambda old_rows, new_rows: jnp.where(  # noqa: E731 — local select
        keep.reshape(keep.shape + (1,) * (new_rows.ndim - 1)),
        old_rows, new_rows)
    return AliasTable(
        prob=tables.prob.at[rows].set(sel(tables.prob[rows], sub.prob)),
        alias=tables.alias.at[rows].set(sel(tables.alias[rows], sub.alias)),
        mass=tables.mass.at[rows].set(sel(tables.mass[rows], sub.mass)),
    ), stale.at[rows].set(sel(stale[rows], p_rows))


def logpdf_rows(p_rows: jax.Array, rows: jax.Array, outcome: jax.Array) -> jax.Array:
    """Unnormalized log-density of ``outcome`` under the *exact* distribution
    rows ``p_rows[rows]`` — used by MH acceptance when the alias table acts as
    a stale proposal (paper §3.2)."""
    return jnp.log(p_rows[rows, outcome] + 1e-30)
