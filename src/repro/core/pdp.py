"""Pitman-Yor Topic Model / Poisson-Dirichlet Process sampler (paper §2.2).

The language model of each topic is drawn from PDP(b, a, ψ0) with a shared
base distribution ψ0 ~ Dir(γ); the power-law discount ``a`` gives natural-
language word frequencies.  The collapsed sampler tracks, per (word w,
topic t):

  m_wk — number of times dish w served in restaurant t  (customer counts)
  s_wk — number of tables serving dish w in restaurant t (table counts)

and per token an auxiliary indicator r_di ∈ {0,1} (did this token open a new
table).  The joint conditional over (t, r) is given by paper eqs. (5)-(6)
with generalized-Stirling-number ratios; like LDA it splits into a sparse
(n_dt) and a dense (α_t) part, so the same MHW machinery applies with a
state space of 2K outcomes (paper: "a twice as large space of state
variables").  Outcomes are encoded e = t + K·r throughout.

Constraints between the shared statistics (0 ≤ s_wk ≤ m_wk, m_wk > 0 ⇒
s_wk ≥ 1, aggregates m_k = Σ_w m_wk) are exactly the polytope the paper's
projection step (§5.5, our ``repro.core.projection``) maintains under
relaxed consistency.

Two sweep layouts (DESIGN.md §5): ``layout="scan"`` is the sequential
position scan (correctness oracle); ``layout="sorted"`` routes the shard
through the generic token-sorted tile-skipping pipeline of
``repro.core.family`` / ``repro.kernels.mhw_fused`` over the 2K outcome
space, with :func:`sorted_chain_pdp` as the kernel's bit-exact pure-jnp
oracle.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import alias as alias_mod
from repro.core import mhw, stirling

Array = jax.Array


@dataclass(frozen=True)
class PDPConfig:
    n_topics: int
    vocab_size: int
    alpha: float = 0.1      # document Dirichlet
    discount: float = 0.1   # a — power-law discount
    concentration: float = 10.0  # b
    gamma: float = 0.5      # base-distribution Dirichlet ψ0 ~ Dir(γ)
    mh_steps: int = 2
    stirling_n_max: int = 512
    # Driver-side cadence + sorted-layout tile geometry (see LDAConfig for
    # the knob semantics; tiles here cover the 2K joint outcome space).
    alias_refresh_every: int = 1
    tile_v: int | None = None
    tile_b: int = 1024
    tile_k: int | None = None
    sorted_chunks: int = 4


class SharedStats(NamedTuple):
    m_wk: Array  # (V, K) customer counts
    s_wk: Array  # (V, K) table counts
    m_k: Array   # (K,) aggregates (C2 rule: derived)
    s_k: Array   # (K,)


class LocalState(NamedTuple):
    z: Array     # (D, L) topic assignment
    r: Array     # (D, L) table-open indicator
    n_dk: Array  # (D, K) doc-topic counts


def init_state(cfg: PDPConfig, tokens: Array, mask: Array, key: Array
               ) -> tuple[LocalState, SharedStats]:
    d, l = tokens.shape
    kz, kr = jax.random.split(key)
    z = jnp.where(mask, jax.random.randint(kz, (d, l), 0, cfg.n_topics, jnp.int32), 0)
    # Initialize every first occurrence as a table opener; statistically any
    # consistent init works.  Simplest consistent choice: each token opens a
    # table with prob 0.5, then repair s<=m / m>0=>s>=1 via projection logic.
    r = jnp.where(mask, jax.random.bernoulli(kr, 0.5, (d, l)).astype(jnp.int32), 0)
    m_wk = _count(cfg, tokens, z, mask, jnp.ones_like(r))
    s_wk = _count(cfg, tokens, z, mask, r)
    s_wk = jnp.where(m_wk > 0, jnp.maximum(s_wk, 1.0), 0.0)
    s_wk = jnp.minimum(s_wk, m_wk)
    n_dk = jnp.einsum("dl,dlk->dk", mask.astype(jnp.float32),
                      jax.nn.one_hot(z, cfg.n_topics, dtype=jnp.float32))
    return (LocalState(z=z, r=r, n_dk=n_dk),
            SharedStats(m_wk=m_wk, s_wk=s_wk, m_k=m_wk.sum(0), s_k=s_wk.sum(0)))


def _count(cfg, tokens, z, mask, weight):
    w = tokens.reshape(-1)
    t = z.reshape(-1)
    val = (mask.reshape(-1) * weight.reshape(-1)).astype(jnp.float32)
    return jnp.zeros((cfg.vocab_size, cfg.n_topics), jnp.float32).at[w, t].add(val)


def log_factors(table: Array, m_wk_row: Array, s_wk_row: Array,
                m_k: Array, s_k: Array, *, b: float, a: float, gamma: float,
                gamma_bar: float) -> tuple[Array, Array]:
    """Per-token log factors f(t, r) excluding the (α_t + n_dt) factor.

    Implements paper eqs. (5) and (6) for every topic t, given the gathered
    (-di corrected) rows for the token's word.  Shapes: (..., K).
    Returns (log_f_r0, log_f_r1).

    Module-level with scalar hyperparameters so the fused sorted kernel
    (``kernels.mhw_fused``) and the oracle (:func:`sorted_chain_pdp`) call
    the *same* function on tile values — bit-exactness by construction.
    """
    log_denom = jnp.log(b + m_k)
    # r = 0: existing table
    #   (m_tw + 1 - s_tw)/(m_tw + 1) * S^{m+1}_{s} / S^{m}_{s} / (b + m_t)
    occ = jnp.maximum(m_wk_row + 1.0 - s_wk_row, 0.0)
    log_f0 = (jnp.log(occ + 1e-30) - jnp.log(m_wk_row + 1.0)
              + stirling.log_ratio_same(table, m_wk_row, s_wk_row) - log_denom)
    # r = 1: open a new table
    #   (b + a s_t)/(b + m_t) * (s_tw+1)/(m_tw+1) * (γ + s_tw)/(γ̄ + s_t)
    #   * S^{m+1}_{s+1} / S^{m}_{s}
    log_f1 = (jnp.log(b + a * s_k) - log_denom
              + jnp.log(s_wk_row + 1.0) - jnp.log(m_wk_row + 1.0)
              + jnp.log(gamma + s_wk_row) - jnp.log(gamma_bar + s_k)
              + stirling.log_ratio_incr(table, m_wk_row, s_wk_row))
    return log_f0, log_f1


def _log_factors(cfg: PDPConfig, table: Array, m_wk_row: Array,
                 s_wk_row: Array, m_k: Array, s_k: Array
                 ) -> tuple[Array, Array]:
    """Config-bound wrapper around :func:`log_factors`."""
    return log_factors(table, m_wk_row, s_wk_row, m_k, s_k,
                       b=cfg.concentration, a=cfg.discount, gamma=cfg.gamma,
                       gamma_bar=cfg.gamma * cfg.vocab_size)


def own_contrib(k_topics: int, e0: Array, real: Array
                ) -> tuple[Array, Array]:
    """^{-di} one-hot contributions for joint outcomes e = t + K·r.

    Returns (own_t, own_r): (B, K) float32 — the token's own customer and
    table contribution, zeroed for padding lanes.  Shared by the fused
    kernel and the oracle (same ops, same bits).
    """
    z0 = e0 % k_topics
    r0 = e0 // k_topics
    karange = jax.lax.broadcasted_iota(jnp.int32, (1, k_topics), 1)
    own_t = ((karange == z0[:, None]) & real[:, None]).astype(jnp.float32)
    own_r = own_t * (r0[:, None] > 0).astype(jnp.float32)
    return own_t, own_r


def corrected_rows(m_row_raw: Array, s_row_raw: Array, own_t: Array,
                   own_r: Array) -> tuple[Array, Array]:
    """Apply the ^{-di} removal + CRP bookkeeping repair to gathered rows:
    a removed non-opener cannot leave a table-less dish; a removed opener of
    an empty dish removes its table."""
    m_row = m_row_raw - own_t
    s_row = s_row_raw - own_r
    s_row = jnp.where(m_row > 0, jnp.maximum(s_row, 1.0), 0.0)
    s_row = jnp.minimum(s_row, m_row)
    return m_row, s_row


def dense_probs(cfg: PDPConfig, shared: SharedStats) -> Array:
    """Dense proposal term over the joint (t, r) space: (V, 2K).

    α_t · f(t, r) for every token-type; columns [0:K] are r=0, [K:2K] r=1.
    """
    table = stirling.as_jax(cfg.stirling_n_max, cfg.discount)
    log_f0, log_f1 = _log_factors(cfg, table, shared.m_wk, shared.s_wk,
                                  shared.m_k[None, :], shared.s_k[None, :])
    return cfg.alpha * jnp.concatenate([jnp.exp(log_f0), jnp.exp(log_f1)], axis=-1)


def build_alias(cfg: PDPConfig, shared: SharedStats) -> tuple[alias_mod.AliasTable, Array]:
    dp = dense_probs(cfg, shared)
    return alias_mod.build(dp), dp


@partial(jax.jit, static_argnames=("cfg", "method", "layout"))
def sweep(
    cfg: PDPConfig,
    local: LocalState,
    shared: SharedStats,
    tables: alias_mod.AliasTable,
    stale_dense: Array,
    tokens: Array,
    mask: Array,
    key: Array,
    method: str = "mhw",
    layout: str = "scan",
    sorted_layouts: tuple | None = None,
) -> tuple[LocalState, Array, Array]:
    """One Gibbs sweep; returns new local state + (V,K) deltas for m and s.

    ``layout="sorted"`` (mhw only) runs the generic token-sorted
    tile-skipping pipeline over the 2K joint outcomes (see
    ``repro.core.family``); pass prebuilt ``sorted_layouts`` from
    ``family.get("pdp").build_sorted_layouts`` to hoist the per-shard sorts.
    """
    if layout == "sorted":
        if method != "mhw":
            raise ValueError("layout='sorted' requires method='mhw'")
        from repro.core import family as family_mod
        local2, deltas = family_mod.get("pdp").sweep_sorted(
            cfg, local, shared, tables, stale_dense, tokens, mask, key,
            sorted_layouts)
        return local2, deltas["m_wk"], deltas["s_wk"]
    if layout != "scan":
        raise ValueError(f"unknown layout {layout!r}")
    d, l = tokens.shape
    k_topics = cfg.n_topics
    table = stirling.as_jax(cfg.stirling_n_max, cfg.discount)
    m_wk, s_wk = shared.m_wk, shared.s_wk
    m_k, s_k = shared.m_k, shared.s_k

    def position_step(carry, inputs):
        n_dk = carry
        w, z_old, r_old, m, k = inputs
        docs = jnp.arange(d)
        mf = m.astype(jnp.float32)

        # --- remove own contribution (the ^{-di} correction) -------------
        n_dk_m = n_dk.at[docs, z_old].add(-mf)
        own_t = jax.nn.one_hot(z_old, k_topics) * mf[:, None]
        own_r = own_t * r_old.astype(jnp.float32)[:, None]
        m_row, s_row = corrected_rows(m_wk[w], s_wk[w], own_t, own_r)
        m_k_m = m_k[None, :] - own_t
        s_k_m = s_k[None, :] - own_r

        log_f0, log_f1 = _log_factors(cfg, table, m_row, s_row, m_k_m, s_k_m)
        log_f = jnp.concatenate([log_f0, log_f1], axis=-1)       # (D, 2K)
        # joint target over e = t + K*r:  (n_dt + α) * f(t, r)
        n_dk_ext = jnp.concatenate([n_dk_m, n_dk_m], axis=-1)

        if method == "exact":
            logits = jnp.log(n_dk_ext + cfg.alpha) + log_f
            e_new = jax.random.categorical(k, logits, axis=-1).astype(jnp.int32)
        elif method == "mhw":
            sparse_w = n_dk_ext * jnp.exp(log_f)
            prop = mhw.MixtureProposal(
                sparse_weights=sparse_w, dense_tables=tables, dense_rows=w)

            def log_p(e):
                return (jnp.log(n_dk_ext[docs, e] + cfg.alpha) + log_f[docs, e])

            e_old = z_old + k_topics * r_old
            e_new = mhw.mh_chain(k, e_old, prop, stale_dense, log_p, cfg.mh_steps)
        else:
            raise ValueError(method)

        z_new = jnp.where(m, e_new % k_topics, z_old)
        r_new = jnp.where(m, e_new // k_topics, r_old)
        n_dk_out = n_dk_m.at[docs, z_new].add(mf)
        return n_dk_out, (z_new, r_new)

    keys = jax.random.split(key, l)
    inputs = (tokens.T, local.z.T, local.r.T, mask.T, keys)
    n_dk_final, (z_t, r_t) = jax.lax.scan(position_step, local.n_dk, inputs)
    z_new, r_new = z_t.T, r_t.T

    delta_m, delta_s = deltas_from(cfg, tokens, mask, local.z, local.r,
                                   z_new, r_new)
    return (LocalState(z=z_new, r=r_new, n_dk=n_dk_final), delta_m, delta_s)


def deltas_from(cfg: PDPConfig, tokens: Array, mask: Array, z_old: Array,
                r_old: Array, z_new: Array, r_new: Array
                ) -> tuple[Array, Array]:
    """(V, K) customer/table count deltas between two assignment states."""
    w_flat = tokens.reshape(-1)
    mf = mask.reshape(-1).astype(jnp.float32)
    delta_m = (
        jnp.zeros((cfg.vocab_size, cfg.n_topics), jnp.float32)
        .at[w_flat, z_new.reshape(-1)].add(mf)
        .at[w_flat, z_old.reshape(-1)].add(-mf)
    )
    delta_s = (
        jnp.zeros((cfg.vocab_size, cfg.n_topics), jnp.float32)
        .at[w_flat, z_new.reshape(-1)].add(mf * r_new.reshape(-1))
        .at[w_flat, z_old.reshape(-1)].add(-mf * r_old.reshape(-1))
    )
    return delta_m, delta_s


def sorted_chain_pdp(prob: Array, alias: Array, mass: Array, stale: Array,
                     m_wk: Array, s_wk: Array, m_k: Array, s_k: Array,
                     stirl: Array, prior: Array, rows: Array, e0: Array,
                     ndk: Array, slot: Array, coin: Array, u_mix: Array,
                     u_sparse: Array, u_acc: Array, *, b: float, a: float,
                     gamma: float, gamma_bar: float) -> Array:
    """Whole-shard MH chain over the token-sorted stream — PDP's 2K space.

    Pure-jnp reference semantics of ``kernels.mhw_fused.pdp_sweep_fused``:
    the fresh Stirling-ratio factors, the ^{-di} correction + CRP repair and
    the chain itself (via ``mhw.mix_chain``) use the exact functions the
    kernel uses, so outputs are bit-identical given the same uniforms.

    prob/alias/stale: (V, 2K); mass: (V,); m_wk/s_wk: (V, K); m_k/s_k: (K,);
    stirl: the log-Stirling table; prior: (2K,); rows/e0: (B,) sorted
    token-types (≥V ⇒ padding, kept at e0) and joint-outcome chain init;
    ndk: (B, K) *raw* gathered doc rows; uniforms: (S, B), slot in [0, 2K).
    Returns (B,) int32 final joint outcomes.
    """
    v, k_topics = m_wk.shape
    real = rows < v
    r = jnp.clip(rows, 0, v - 1)

    own_t, own_r = own_contrib(k_topics, e0, real)
    m_row, s_row = corrected_rows(m_wk[r], s_wk[r], own_t, own_r)
    m_k_m = m_k[None, :] - own_t
    s_k_m = s_k[None, :] - own_r

    log_f0, log_f1 = log_factors(stirl, m_row, s_row, m_k_m, s_k_m,
                                 b=b, a=a, gamma=gamma, gamma_bar=gamma_bar)
    log_f = jnp.concatenate([log_f0, log_f1], axis=-1)         # (B, 2K)
    ndk_m = ndk - own_t
    ndk_ext = jnp.concatenate([ndk_m, ndk_m], axis=-1)
    sparse_w = ndk_ext * jnp.exp(log_f)

    e = mhw.mix_chain(e0, doc=ndk_ext, prior=prior, logf=log_f,
                      sparse_w=sparse_w, stale_rows=stale[r],
                      prob_rows=prob[r], alias_rows=alias[r],
                      dense_mass=mass[r], slot=slot, coin=coin, u_mix=u_mix,
                      u_sparse=u_sparse, u_acc=u_acc)
    return jnp.where(real, e, e0).astype(jnp.int32)


def apply_delta(shared: SharedStats, delta_m: Array, delta_s: Array) -> SharedStats:
    m_wk = shared.m_wk + delta_m
    s_wk = shared.s_wk + delta_s
    # C2 aggregation rule (paper Alg. 1): aggregates derived from counterparts.
    return SharedStats(m_wk=m_wk, s_wk=s_wk, m_k=m_wk.sum(0), s_k=s_wk.sum(0))


def language_model(cfg: PDPConfig, shared: SharedStats) -> Array:
    """Posterior-mean p(w|t): hierarchical CRP smoothing with base ψ0."""
    b, a = cfg.concentration, cfg.discount
    gamma_bar = cfg.gamma * cfg.vocab_size
    s_w = shared.s_wk.sum(-1)  # (V,)
    p0 = (cfg.gamma + s_w) / (gamma_bar + s_w.sum())
    direct = jnp.maximum(shared.m_wk - a * shared.s_wk, 0.0)
    back = (b + a * shared.s_k)[None, :] * p0[:, None]
    return (direct + back) / (b + shared.m_k)[None, :]


@partial(jax.jit, static_argnames=("cfg", "n_fold_sweeps"))
def perplexity(cfg: PDPConfig, shared: SharedStats, tokens: Array, mask: Array,
               key: Array, n_fold_sweeps: int = 10) -> Array:
    phi = language_model(cfg, shared)  # (V, K)
    d, l = tokens.shape
    k_init, k_sweeps = jax.random.split(key)
    z = jax.random.randint(k_init, (d, l), 0, cfg.n_topics, jnp.int32)
    onehot = jax.nn.one_hot(jnp.where(mask, z, 0), cfg.n_topics, dtype=jnp.float32)
    n_dk = jnp.einsum("dl,dlk->dk", mask.astype(jnp.float32), onehot)

    def fold_sweep(carry, k):
        z, n_dk = carry

        def pos(c, inp):
            n_dk = c
            w, z_old, m, kk = inp
            docs = jnp.arange(d)
            mf = m.astype(jnp.float32)
            n_dk_m = n_dk.at[docs, z_old].add(-mf)
            logits = jnp.log(n_dk_m + cfg.alpha) + jnp.log(phi[w] + 1e-30)
            z_new = jax.random.categorical(kk, logits, axis=-1).astype(jnp.int32)
            z_new = jnp.where(m, z_new, z_old)
            return n_dk_m.at[docs, z_new].add(mf), z_new

        keys = jax.random.split(k, l)
        n_dk2, z_t = jax.lax.scan(pos, n_dk, (tokens.T, z.T, mask.T, keys))
        return (z_t.T, n_dk2), None

    (z, n_dk), _ = jax.lax.scan(fold_sweep, (z, n_dk),
                                jax.random.split(k_sweeps, n_fold_sweeps))
    theta = (n_dk + cfg.alpha) / (n_dk.sum(-1, keepdims=True) + cfg.alpha * cfg.n_topics)
    pw = jnp.einsum("dk,dlk->dl", theta, phi[tokens])
    logp = jnp.where(mask, jnp.log(pw + 1e-30), 0.0)
    return jnp.exp(-logp.sum() / jnp.maximum(mask.sum(), 1))
