"""The explicit parameter-server API (paper §4, §5.2-§5.3).

The paper's server holds (key, value) sufficient statistics sharded over
server nodes; clients *pull* stale copies, sample, and *push* batched
deltas under a relaxed consistency model.  Until this module, "the server"
was an implicit dense pytree threaded through every round signature and
hard-wired to one consistency behavior (bulk-synchronous ``tau`` sweeps).
This module makes both halves first-class:

* :class:`ParameterServer` — ``pull / push / project / snapshot`` over
  **vocabulary-sharded** shared statistics: every named shared stat whose
  leading dimension is the vocabulary is split into ``n_shards``
  contiguous row-ranges (:class:`ShardSpec` owns the row→shard map), so
  pulls and pushes address shard-local slices instead of the full (V, K)
  array.  Aggregates (n_k, m_k, θ0, …) stay unsharded ``aux`` state and
  are re-derived from the assembled view, so the sharded store is
  bit-exact with the historical dense pytree (assembly is pure
  concatenation of exact slices; all arithmetic runs on the assembled
  view in the same operation order as before).
* :class:`Consistency` — the pluggable pull/push policy:

  - :class:`BSP`: every pull returns the canonical state as of the end of
    the previous round (today's behavior, bit-exact with the PR-3 round);
  - :class:`SSP`: clients may run up to ``bound`` rounds ahead of a
    *versioned stale cache*; the server tracks per-client clocks and the
    compiled round's pull blocks — realized in the lock-step simulation
    as a forced synchronous refresh — once ``clock − cache_version``
    would exceed the bound (Yuan et al. 2014's bounded staleness).  SSP's
    read-my-writes guarantee is kept: each client's pull is the cache
    plus its *own* accumulated deltas since the cache version (the
    per-client ``client_lag`` accumulator), so only *other* clients'
    updates are stale;
  - :class:`Async`: pushes apply to the canonical statistics immediately
    (client c+1's pull in the same round already sees client c's push —
    Gauss-Seidel across clients instead of BSP's Jacobi barrier), the
    communication filter's error-feedback residuals carry withheld mass,
    and pulls never block (they always return the freshest state).

This module is the *in-process* backend; ``repro.net`` (DESIGN.md §11)
maps the same surface onto a framed TCP protocol — ``ShardServer``
processes host the vocabulary row-ranges and ``RemoteParameterServer``
presents this class's pull/push/project/snapshot API to the Trainer, with
pull as a versioned cache refresh (``Consistency.needs_refresh`` answered
as NOT_MODIFIED on the wire) and push as a delta frame at the round
barrier.  The sharding predicate, row-range math (:class:`ShardSpec`) and
policy objects here are shared verbatim by both transports.

The server also owns the **per-shard changed-row accounting** that drives
the PR-3 incremental alias rebuild: every tracked push accumulates per-row
L1 delta mass into per-shard accumulators, and
:meth:`ParameterServer.consume_changed_rows` runs the top-k
magnitude-priority selection (``ps.changed_rows``) over the concatenated
shard masses and resets them — so the rebuild budget reflects drift since
the *last rebuild*, not just the last round, once policies stop
rebuilding every round.

Everything here is functional: the :class:`ParameterServer` object is a
frozen (hashable) configuration — family, shard spec, policy — suitable
as a ``jax.jit`` static argument, and all methods are pure functions over
:class:`ServerState` pytrees, so a whole sync round (pull → sample →
push → project) stays one compiled program (``repro.engine.round``).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ps

Array = jax.Array


# ---------------------------------------------------------------------------
# Vocabulary sharding
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShardSpec:
    """Row-range sharding of the vocabulary dimension.

    ``n_rows`` vocabulary rows are split into ``n_shards`` contiguous,
    balanced ranges — the paper's key-hashing over server nodes becomes
    row-range sharding (DESIGN.md §2: row-hashing ≡ row-sharding).
    """

    n_rows: int
    n_shards: int = 1

    def __post_init__(self):
        if not 1 <= self.n_shards <= self.n_rows:
            raise ValueError(
                f"n_shards={self.n_shards} must be in [1, {self.n_rows}]")

    @property
    def bounds(self) -> tuple[int, ...]:
        """The ``n_shards + 1`` row boundaries (balanced contiguous ranges)."""
        return tuple(i * self.n_rows // self.n_shards
                     for i in range(self.n_shards + 1))

    def rows_of(self, shard: int) -> tuple[int, int]:
        """[start, stop) row range owned by ``shard``."""
        b = self.bounds
        return b[shard], b[shard + 1]

    def shard_of(self, row: int) -> int:
        """The row→shard map for a single row id."""
        if not 0 <= row < self.n_rows:
            raise IndexError(row)
        return int(np.searchsorted(np.asarray(self.bounds), row, "right")) - 1

    def row_to_shard(self) -> np.ndarray:
        """(n_rows,) int32 row→shard map (the Chord finger table analogue)."""
        out = np.zeros((self.n_rows,), np.int32)
        for s in range(self.n_shards):
            lo, hi = self.rows_of(s)
            out[lo:hi] = s
        return out

    def split(self, x: Array) -> tuple[Array, ...]:
        """Split a (n_rows, ...) array into its per-shard row slices."""
        return tuple(x[lo:hi] for lo, hi in
                     (self.rows_of(s) for s in range(self.n_shards)))


# ---------------------------------------------------------------------------
# Consistency policies
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Consistency:
    """Base pull/push policy.  Frozen + hashable: policies ride along the
    :class:`ParameterServer` as jit statics, so each policy gets its own
    compiled round (one trace per (family, layout, policy))."""

    kind = "bsp"

    @property
    def key(self) -> str:
        """Stable identifier used for trace-count bookkeeping and parsing."""
        return self.kind

    # Does this policy maintain a versioned stale cache in ServerState?
    caches = False
    # Do pushes apply immediately (within-round, client-sequential)?
    immediate = False
    # Staleness bound: a client at clock r may sample a snapshot of
    # version v only while r - v <= bound.
    bound = 0

    def needs_refresh(self, round_idx: int, version: int | None) -> bool:
        """Host-side pull schedule: must the cached snapshot be refreshed
        before round ``round_idx``?  Mirrors the traced predicate inside
        the compiled round (lock-step clients ⇒ deterministic)."""
        return True


@dataclass(frozen=True)
class BSP(Consistency):
    """Bulk-synchronous: pull always returns the canonical state as of the
    end of the previous round.  Bit-exact with the pre-server round."""


@dataclass(frozen=True)
class SSP(Consistency):
    """Stale-synchronous parallel with staleness bound ``s``: clients run
    up to ``s`` rounds ahead of the versioned cache; the pull blocks (in
    the lock-step simulation: synchronously refreshes, after all pushes
    through the previous round have been applied) once the bound would be
    exceeded.  ``SSP(0)`` degenerates to BSP's refresh-every-round."""

    bound: int = 1
    kind = "ssp"
    caches = True

    def __post_init__(self):
        if self.bound < 0:
            raise ValueError(f"SSP bound must be >= 0, got {self.bound}")

    @property
    def key(self) -> str:
        return f"ssp({self.bound})"

    def needs_refresh(self, round_idx: int, version: int | None) -> bool:
        return version is None or round_idx - version > self.bound


@dataclass(frozen=True)
class Async(Consistency):
    """Fully asynchronous: pushes apply to the canonical statistics the
    moment a client produces them (error-feedback residuals carry what the
    communication filter withholds), and pulls never block — they return
    whatever is freshest.  Unbounded staleness across clients; within the
    lock-step simulation this surfaces as Gauss-Seidel client ordering."""

    kind = "async"
    immediate = True


def make_consistency(spec: str | Consistency) -> Consistency:
    """Parse a :class:`TrainerConfig.consistency` string: ``"bsp"``,
    ``"async"``, or ``"ssp:<bound>"`` (also accepts ``ssp(<bound>)`` /
    bare ``ssp`` for bound=1).  A negative bound reaches the
    :class:`SSP` validator and raises."""
    if isinstance(spec, Consistency):
        return spec
    s = spec.strip().lower()
    if s == "bsp":
        return BSP()
    if s == "async":
        return Async()
    if s.startswith("ssp"):
        rest = s[3:].strip("(): \t")
        try:
            return SSP(bound=int(rest)) if rest else SSP()
        except ValueError as e:
            if "bound" in str(e):     # invalid bound, not unparseable text
                raise
    raise ValueError(
        f"unknown consistency {spec!r}; expected 'bsp', 'ssp:<bound>' "
        "or 'async'")


# ---------------------------------------------------------------------------
# Server state
# ---------------------------------------------------------------------------

class ServerState(NamedTuple):
    """The server's round state — one donated pytree per compiled round.

    shards        per-shard dict of row-slices of every vocabulary-sharded
                  statistic (the canonical store).
    aux           unsharded statistics: aggregates re-derived on push
                  (n_k, m_k, s_k) and replicated parameters (θ0).
    cache         the versioned stale snapshot SSP clients pull (a dense
                  shared pytree); ``None`` for policies that pull live
                  state (BSP / async).
    cache_version the round index at which ``cache`` was last refreshed.
    client_lag    SSP's read-my-writes accumulator: per delta-stat, the
                  (n_clients, …)-stacked deltas each client has applied
                  locally since the cache version — a client's pull is
                  ``cache + client_lag[c]``, so its own writes are never
                  stale; reset on refresh.  ``None`` for BSP / async.
    clocks        (n_clients,) int32 per-client round clocks; a client's
                  clock advances when its push is applied (failed clients
                  freeze, which is what SSP's bound guards against).
    row_mass      per-shard accumulated L1 row mass of tracked pushes —
                  the changed-row accounting behind the incremental alias
                  rebuild; reset by :meth:`consume_changed_rows`.
    tables/stale  the alias proposal resident next to the server (the
                  pulled proposal cache): alias tables + the stale dense
                  proposal matrix they encode.
    """

    shards: tuple[dict[str, Array], ...]
    aux: dict[str, Array]
    cache: Any
    cache_version: Array
    client_lag: Any
    clocks: Array
    row_mass: tuple[Array, ...]
    tables: Any
    stale: Any


# ---------------------------------------------------------------------------
# The server
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParameterServer:
    """Vocabulary-sharded parameter server with a pluggable consistency
    policy.  Pure-functional: hashable configuration here, all mutable
    state in :class:`ServerState` (see module docstring)."""

    family: Any                 # ModelFamily singleton (identity-hashed)
    spec: ShardSpec
    policy: Consistency = BSP()

    # ------------------------------------------------------------ structure
    def _is_sharded(self, name: str, x: Array) -> bool:
        return x.ndim == 2 and x.shape[0] == self.spec.n_rows

    def split(self, shared) -> tuple[tuple[dict[str, Array], ...],
                                     dict[str, Array]]:
        """Dense shared pytree → (per-shard slice dicts, aux dict)."""
        stats = self.family.stats_dict(shared)
        sharded = {n: v for n, v in stats.items() if self._is_sharded(n, v)}
        aux = {n: v for n, v in stats.items() if n not in sharded}
        shards = tuple(
            {n: sharded[n][lo:hi] for n in sharded}
            for lo, hi in (self.spec.rows_of(s)
                           for s in range(self.spec.n_shards)))
        return shards, aux

    def assemble(self, state: ServerState):
        """Canonical dense view: concatenate the shard slices (exact — no
        arithmetic) and merge the aux stats back into the family pytree."""
        stats = dict(state.aux)
        for n in state.shards[0]:
            stats[n] = jnp.concatenate([sh[n] for sh in state.shards], 0) \
                if len(state.shards) > 1 else state.shards[0][n]
        return self.family.shared_from_dict(stats)

    def load_dense(self, state: ServerState, shared) -> ServerState:
        """Store a dense shared pytree back into the sharded canonical
        store (pure re-slicing — bit-exact round trip with assemble)."""
        shards, aux = self.split(shared)
        return state._replace(shards=shards, aux=aux)

    # ------------------------------------------------------------ lifecycle
    def init_state(self, shared, n_clients: int) -> ServerState:
        shards, aux = self.split(shared)
        cache, client_lag = None, None
        if self.policy.caches:
            # Materialized copy, not an alias: the cache and the canonical
            # shards live in one donated ServerState, and donating the
            # same buffer twice is a runtime error on donating backends.
            cache = jax.tree.map(jnp.copy, shared)
            stats = self.family.stats_dict(shared)
            client_lag = {
                n: jnp.zeros((n_clients,) + stats[n].shape, stats[n].dtype)
                for n in self.family.delta_names}
        return ServerState(
            shards=shards, aux=aux, cache=cache, client_lag=client_lag,
            cache_version=jnp.zeros((), jnp.int32),
            clocks=jnp.zeros((n_clients,), jnp.int32),
            row_mass=tuple(jnp.zeros((hi - lo,), jnp.float32)
                           for lo, hi in (self.spec.rows_of(s)
                                          for s in range(self.spec.n_shards))),
            tables=None, stale=None)

    # ------------------------------------------------------------- protocol
    def snapshot(self, state: ServerState):
        """The canonical current statistics (admin/eval view — always
        fresh, regardless of the pull policy)."""
        return self.assemble(state)

    def pull(self, state: ServerState, keys: Sequence[tuple[str, int]]
             | None = None):
        """Client pull.

        ``keys=None`` → the policy view: SSP clients get the versioned
        stale cache, BSP/async clients the live canonical state.
        ``keys=[(stat, shard), ...]`` → the addressed shard-local row
        slices from the canonical store (what crosses the wire when a
        client only holds part of the vocabulary)."""
        if keys is None:
            return state.cache if self.policy.caches else self.assemble(state)
        return [state.shards[shard][name] for name, shard in keys]

    def reset_lag(self, client_lag, do_refresh):
        """Zero the read-my-writes accumulators when the pull refreshes
        (the fresh cache already contains every applied push)."""
        if client_lag is None:
            return None
        return {n: jnp.where(do_refresh, jnp.zeros_like(v), v)
                for n, v in client_lag.items()}

    def rejoin_client(self, state: ServerState, c: int) -> ServerState:
        """Elastic rejoin (paper §5.4): reset client ``c``'s
        read-my-writes lag row before it re-enters the round.

        A rejoining client restored its locals from a snapshot that
        predates its crash, so none of the in-flight writes its lag row
        accumulated survive in its local replica — serving them back
        through ``client_view`` would hand it phantom deltas.  The caller
        (``Trainer``) additionally forces a fresh pull on the rejoin
        round, so the rejoining client is simply a maximally stale client
        taking its blocking refresh — the SSP machinery makes recovery a
        cache refresh, not a new code path.  Its clock stays frozen until
        its first post-rejoin push is applied.  No-op for policies
        without a lag accumulator (BSP / async)."""
        if state.client_lag is None:
            return state
        return state._replace(client_lag={
            n: v.at[c].set(jnp.zeros_like(v[c]))
            for n, v in state.client_lag.items()})

    def client_view(self, snapshot, client_lag, c: int):
        """Client ``c``'s pull under read-my-writes SSP: the versioned
        cache plus the client's own deltas since the cache version (its
        writes are never stale — only other clients' are).  Identity for
        policies without a cache."""
        if client_lag is None:
            return snapshot
        return self.family.apply_delta(
            snapshot, {n: v[c] for n, v in client_lag.items()})

    def pull_round(self, state: ServerState, round_idx, do_refresh):
        """The compiled round's pull: returns (snapshot, cache', version').

        BSP/async: snapshot is the live canonical state; no cache.
        SSP: the versioned cache, refreshed to the canonical state when
        ``do_refresh`` (the traced staleness-bound predicate — the
        simulation's realization of the blocking pull) is set.
        """
        canonical = self.assemble(state)
        version = jnp.asarray(round_idx, jnp.int32)
        if not self.policy.caches:
            return canonical, None, version
        cache = jax.tree.map(
            lambda fresh, old: jnp.where(do_refresh, fresh, old),
            canonical, state.cache)
        version = jnp.where(do_refresh, version, state.cache_version)
        return cache, cache, version

    def push(self, state: ServerState, deltas: dict[str, Array],
             clock_inc: Array | None = None, *, track_mass: bool = False
             ) -> ServerState:
        """Apply summed client deltas to the canonical statistics.

        Runs the family's ``apply_delta`` on the assembled view (same
        operation order as the historical dense push — aggregates like
        n_k re-derived there), re-slices into the shard store, advances
        the pushing clients' clocks, and — when ``track_mass`` — folds
        the per-row L1 delta mass into the per-shard changed-row
        accounting (consumed by :meth:`consume_changed_rows`)."""
        dense = self.family.apply_delta(self.assemble(state), deltas)
        state = self.load_dense(state, dense)
        if track_mass:
            state = self.accumulate_mass(state, deltas)
        if clock_inc is not None:
            state = state._replace(
                clocks=state.clocks + clock_inc.astype(jnp.int32))
        return state

    def push_sparse(self, state: ServerState, sparse: ps.SparseDelta,
                    clock_inc: Array | None = None, *,
                    track_mass: bool = False) -> ServerState:
        """Apply a :class:`~repro.core.ps.SparseDelta` push.

        The sparse→dense conversion happens here, at the pytree boundary
        (``ps.from_sparse_delta``), and the densified delta goes through
        the exact :meth:`push` path — same ``apply_delta`` op order, same
        clock/ mass accounting — so a sparse push under BSP is bit-exact
        with the dense push of the same delta (DESIGN.md §12).  The win is
        what *crosses a transport*: callers ship (rows, packed values)
        instead of (V, K) matrices and convert at either edge.
        """
        dense = ps.from_sparse_delta(sparse, self.spec.n_rows)
        return self.push(state, dense, clock_inc, track_mass=track_mass)

    def accumulate_mass(self, state: ServerState, deltas: dict[str, Array]
                        ) -> ServerState:
        """Fold a push's per-row L1 mass into the per-shard accounting.
        Watches the family's ``alias_delta_stats`` (the statistics whose
        drift stales the alias proposal rows)."""
        mass = functools.reduce(
            jnp.add, (jnp.abs(deltas[n]).sum(-1)
                      for n in self.family.alias_delta_stats))
        return state._replace(row_mass=tuple(
            m + mass[lo:hi] for m, (lo, hi) in
            zip(state.row_mass, (self.spec.rows_of(s)
                                 for s in range(self.spec.n_shards)))))

    def project(self, state: ServerState, do_project=True) -> ServerState:
        """Constraint projection (Algorithm 1) on the shared polytope,
        under ``lax.cond`` so the cadence flag stays traced."""
        dense = self.assemble(state)
        dense = jax.lax.cond(do_project, self.family.project,
                             lambda s: s, dense)
        return self.load_dense(state, dense)

    # ----------------------------------------------- changed-row accounting
    def shard_row_mass(self, state: ServerState) -> tuple[Array, ...]:
        """Per-shard accumulated row mass (observability / tests)."""
        return state.row_mass

    def consume_changed_rows(self, state: ServerState, k_rows: int,
                             threshold: float
                             ) -> tuple[Array, Array, ServerState]:
        """Select the rows an incremental alias rebuild should touch and
        reset the accounting: the global top-``k_rows`` by accumulated L1
        mass over the concatenated shard accumulators (``ps.changed_rows``
        — the communication filter's magnitude-priority machinery), with
        the validity mask dropping below-threshold rows.  Returned row ids
        are global (the row→shard map recovers the owning shard)."""
        mass = jnp.concatenate(state.row_mass) if len(state.row_mass) > 1 \
            else state.row_mass[0]
        rows, valid = ps.changed_rows(mass, k_rows, threshold)
        state = state._replace(row_mass=tuple(
            jnp.zeros_like(m) for m in state.row_mass))
        return rows, valid, state

    # ------------------------------------------------------- alias proposal
    def refresh_proposal(self, model_cfg, state: ServerState) -> ServerState:
        """Full alias rebuild against the canonical statistics — the
        producer half of §5.1, run on the pull-refresh schedule."""
        tables, stale = self.family.build_alias(model_cfg,
                                                self.assemble(state))
        return state._replace(tables=tables, stale=stale)


def make_server(family, vocab_size: int, *, n_shards: int = 1,
                consistency: str | Consistency = "bsp") -> ParameterServer:
    """Convenience constructor used by the Trainer and the mesh round."""
    return ParameterServer(family=family,
                           spec=ShardSpec(vocab_size, n_shards),
                           policy=make_consistency(consistency))
