"""Distributed collapsed Gibbs sampling on a device mesh (paper §5.2-§5.3).

Clients = shards of the ``data`` mesh axis, each holding a document shard
and a stale replica of the shared statistics.  The canonical statistics
live behind the explicit parameter server (``repro.core.server``):
vocabulary-sharded :class:`~repro.core.server.ServerState` under a
pluggable consistency policy (BSP / SSP / async).  A *round* is:

  1. pull   — the policy's snapshot of the shared statistics (BSP: frozen
              fresh copy; SSP: the versioned stale cache; async: live),
  2. sample — ``tau`` local Gibbs sweeps against the snapshot, applying own
              deltas locally (bounded-staleness eventual consistency),
  3. filter — communication filter on the accumulated delta (paper §5.3),
  4. push   — psum of filtered deltas across clients (or the compressed
              all-gather transport), applied to the canonical statistics,
  5. project— distributed constraint projection (paper §5.5, Algorithm 2)
              on the shared polytope, plus each family's client-local rules
              (e.g. HDP's 1 ≤ m_dk ≤ n_dk table-count constraints) applied
              shard-locally inside the round.

Model specifics enter only through the ``repro.core.family`` registry —
there is exactly one round implementation for LDA / PDP / HDP, and a
family's projection rules are sourced verbatim from
``repro.core.projection.*_RULES`` (split by operand locality, never
hand-copied here).  The per-client round body (:func:`tau_sweeps` — the
staleness loop as a ``lax.scan`` — and :func:`filter_push`) is defined
here and consumed verbatim by the single-device ``engine.Trainer``'s
compiled whole-round program (``repro.engine.round``), so the mesh round
and the client-iterated round cannot drift apart.

Failure injection (paper §5.4): a boolean per-client ``alive`` mask zeroes a
failed client's contribution for the round — the recovery path (reload from
snapshot, re-pull, continue) is exercised in tests/benchmarks.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import family as family_mod
from repro.core import projection, ps
from repro.core import server as server_mod

Array = jax.Array


# --------------------------------------------------------------------------
# The per-client round body — shared with engine.round's compiled round
# --------------------------------------------------------------------------

def tau_sweeps(model_cfg, fam: family_mod.ModelFamily, local, snapshot,
               tables, stale_dense, tokens, mask, sweep_keys, *,
               method: str = "mhw", layout: str = "scan",
               sorted_layouts: tuple | None = None):
    """One client's work for a sync round: ``tau`` sweeps against the frozen
    snapshot, applying its own deltas locally between sweeps (the paper's
    clients update their replica immediately and push asynchronously), then
    the family's client-local constraint rules.

    ``sweep_keys`` is the (tau, ...) stacked per-sweep key array — the
    caller owns the keying so the mesh round and the Trainer each preserve
    their historical RNG streams.  The staleness loop is a ``lax.scan`` so
    ``tau`` does not multiply the trace.

    Returns (local', accumulated_deltas).
    """
    zero = {n: jnp.zeros_like(fam.stats_dict(snapshot)[n])
            for n in fam.delta_names}

    def one_sweep(carry, key):
        local, shared_local, acc = carry
        local, deltas = fam.sweep(model_cfg, local, shared_local, tables,
                                  stale_dense, tokens, mask, key,
                                  method=method, layout=layout,
                                  sorted_layouts=sorted_layouts)
        shared_local = fam.apply_delta(shared_local, deltas)
        acc = {n: acc[n] + deltas[n] for n in acc}
        return (local, shared_local, acc), None

    (local, _, acc), _ = jax.lax.scan(one_sweep, (local, snapshot, zero),
                                      sweep_keys)
    # Local projection: the rules whose operands live in client state
    # (HDP's m_dk polytope) — shard-local and embarrassingly parallel.
    local = fam.local_project(local)
    return local, acc


def filter_push(fam: family_mod.ModelFamily, deltas: dict[str, Array],
                spec: ps.FilterSpec, key: Array,
                residual: dict[str, Array] | None = None):
    """Communication filter + error feedback on a client's accumulated
    delta (§5.3).  What the filter withholds is carried in ``residual`` to
    the next round, never dropped — count mass must be conserved or the
    statistics drift negative.

    Returns (sent, residual').  With the dense filter both pass through
    unchanged (and ``residual`` may stay ``None``).
    """
    if spec.kind == "dense":
        return deltas, residual
    if residual is not None:
        deltas = {n: deltas[n] + residual[n] for n in deltas}
    sent = {n: ps.filter_delta(v, spec, jax.random.fold_in(key, i))
            for i, (n, v) in enumerate(deltas.items())}
    return sent, {n: deltas[n] - sent[n] for n in deltas}


def filter_push_sparse(fam: family_mod.ModelFamily,
                       deltas: dict[str, Array], spec: ps.FilterSpec,
                       key: Array,
                       residual: dict[str, Array] | None = None
                       ) -> tuple[ps.SparseDelta, dict[str, Array] | None]:
    """:func:`filter_push` with a COO row-sliced result (DESIGN.md §12).

    The filter runs dense (identical arithmetic — same residual as the
    dense path), then the sent delta crosses the pytree boundary through
    ``ps.to_sparse_delta``: the non-zero-row union across delta stats,
    packed as (rows, values).  ``ps.from_sparse_delta`` reconstructs the
    sent delta bit-for-bit, so a transport shipping the sparse form is
    bit-exact with one shipping the dense form — while moving only the
    rows the filter (or the corpus' power-law row access) actually
    touched.  Host-side: the result shape is data-dependent.
    """
    sent, residual = filter_push(fam, deltas, spec, key, residual)
    return ps.to_sparse_delta(sent), residual


@dataclass(frozen=True)
class DistConfig:
    model: str = "lda"                 # any name in family.FAMILIES
    tau: int = 1                       # sweeps per sync round (staleness)
    alias_refresh_every: int = 1       # rounds between alias-table rebuilds
    filter: ps.FilterSpec = field(default_factory=ps.FilterSpec)
    project_every: int = 1             # rounds between projections (0 = never)
    # Parameter-server policy + vocabulary sharding (core.server): "bsp" |
    # "ssp:<bound>" | "async".  Under SPMD lock-step, async's immediate
    # per-client application degenerates to the same psum barrier as BSP
    # (the transport is a reduce); its distinguishing behavior here is the
    # non-blocking pull (always the live state, never a versioned cache).
    consistency: str = "bsp"
    n_server_shards: int = 1
    # "scan" | "sorted" (mhw only).  Note: under shard_map the sorted
    # layouts are rebuilt inside each sweep (per-shard token streams only
    # exist inside the mesh program, so they cannot be hoisted from here);
    # engine.Trainer's client-iterated driver hoists them once per shard.
    layout: str = "scan"


# --------------------------------------------------------------------------
# The distributed round
# --------------------------------------------------------------------------

def client_round(model_cfg, fam: family_mod.ModelFamily,
                 dist_cfg: DistConfig, local, snapshot, tables, stale_dense,
                 tokens, mask, key, method="mhw"):
    """One client's work for a sync round: ``tau`` sweeps against the frozen
    snapshot, applying its own deltas locally between sweeps (the paper's
    clients update their local replica immediately and push asynchronously),
    then the family's client-local constraint rules.

    Returns (local', accumulated_deltas).

    Thin wrapper over the shared round body (:func:`tau_sweeps`)
    preserving this module's historical per-sweep keying
    ``fold_in(key, s)``."""
    sweep_keys = jax.vmap(lambda s: jax.random.fold_in(key, s))(
        jnp.arange(dist_cfg.tau))
    return tau_sweeps(
        model_cfg, fam, local, snapshot, tables, stale_dense, tokens, mask,
        sweep_keys, method=method, layout=dist_cfg.layout)


def make_server(model_cfg, dist_cfg: DistConfig) -> server_mod.ParameterServer:
    """The round's :class:`~repro.core.server.ParameterServer` — family,
    vocabulary shard spec and consistency policy resolved from configs."""
    return server_mod.make_server(
        family_mod.get(dist_cfg.model), model_cfg.vocab_size,
        n_shards=dist_cfg.n_server_shards,
        consistency=dist_cfg.consistency)


def make_round_fn(model_cfg, dist_cfg: DistConfig, mesh: Mesh,
                  method: str = "mhw", data_axis: str = "data",
                  model_axis: str = "model",
                  server: server_mod.ParameterServer | None = None):
    """Build the jitted distributed round over an explicit parameter
    server.

    The round consumes a :class:`~repro.core.server.ParameterServer`
    (built from ``dist_cfg`` when not given) instead of raw
    ``shared``/``stale_dense`` pytrees: the returned function takes the
    server's :class:`~repro.core.server.ServerState` — canonical
    vocabulary-sharded statistics, versioned SSP cache, per-client
    clocks, changed-row accounting, and the resident alias proposal
    (host-refreshed via ``server.refresh_proposal``).

    Sharding contract (see module docstring):
      tokens/mask/local state — sharded over ``data`` on the document dim.
      shared stats            — canonical copy sharded over ``model`` rows
                                (the server's vocabulary row-ranges laid
                                over the physical row sharding).
    The round returns (local', server_state').

    Consistency: SSP's refresh predicate is evaluated in-trace from the
    server clocks (``max(clocks) − cache_version > bound``; ``max`` so a
    dead client cannot freeze the schedule — its protection is the zeroed
    push, §5.4); the blocking pull degenerates to a forced synchronous
    refresh under SPMD lock-step, as in the Trainer.
    """
    fam = family_mod.get(dist_cfg.model)
    if server is None:
        server = make_server(model_cfg, dist_cfg)
    n_clients = mesh.shape[data_axis]

    row_sharding = NamedSharding(mesh, P(model_axis, None))
    vec_sharding = NamedSharding(mesh, P())
    doc_sharding = NamedSharding(mesh, P(data_axis, None))

    def round_fn(local, state, tokens, mask, key, alive):
        """alive: (n_clients,) bool — failure-injection mask (paper §5.4)."""
        # 1. pull: the policy view made available to every client —
        #    expressed as a replication constraint (all-gather).  BSP and
        #    async pull the live canonical state; SSP the versioned cache.
        #    The replication constraint is applied to the assembled view
        #    *immediately*: letting the partitioner propagate the
        #    model-axis row sharding into the shard-concatenation corrupts
        #    values on multi-axis host meshes (observed on jax 0.4.37 —
        #    the concat operands get strided over the data axis); pinning
        #    the concat replicated sidesteps it, and every derived tensor
        #    (including the row-constrained canonical store below) is then
        #    partitioned correctly.
        canonical = jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(x, vec_sharding),
            server.assemble(state))
        if server.policy.caches:
            clock_now = state.clocks.max()
            do_refresh = clock_now - state.cache_version > server.policy.bound
            cache = jax.tree.map(
                lambda fresh, old: jnp.where(do_refresh, fresh, old),
                canonical, state.cache)
            version = jnp.where(do_refresh, clock_now, state.cache_version)
            snapshot = cache
            lag = server.reset_lag(state.client_lag, do_refresh)
        else:
            cache, version = state.cache, state.clocks.max()
            snapshot = canonical
            lag = None

        # 2-3. sample + filter, client-parallel over the data axis.
        from jax.experimental.shard_map import shard_map

        def one_client(local_shard, tokens_shard, mask_shard, key_shard,
                       alive_shard, snapshot_rep, tables_rep, stale_rep,
                       lag_shard):
            # Read-my-writes SSP: each client samples the stale cache plus
            # its own deltas since the cache version (its lag shard).
            view = snapshot_rep if lag_shard is None else fam.apply_delta(
                snapshot_rep, {n: v[0] for n, v in lag_shard.items()})
            local2, deltas = client_round(
                model_cfg, fam, dist_cfg, local_shard, view,
                tables_rep, stale_rep, tokens_shard, mask_shard,
                key_shard[0], method)
            a = alive_shard[0].astype(jnp.float32)
            sent, _ = filter_push(
                fam, deltas, dist_cfg.filter,
                jax.random.fold_in(key_shard[0], 7))
            # 4. push: eventual-consistency reduce across clients.
            out = {name: jax.lax.psum(sent[name] * a, data_axis)
                   for name in fam.delta_names}
            lag2 = None if lag_shard is None else {
                n: v + deltas[n][None] * a for n, v in lag_shard.items()}
            return local2, out, lag2

        spec_local = jax.tree.map(lambda _: P(data_axis), local)
        lag_spec = None if lag is None else {n: P(data_axis) for n in lag}
        fn = shard_map(
            one_client, mesh=mesh,
            in_specs=(spec_local, P(data_axis, None), P(data_axis, None),
                      P(data_axis), P(data_axis), P(), P(), P(), lag_spec),
            out_specs=(spec_local, P(), lag_spec),
            check_rep=False,
        )
        keys = jax.random.split(key, n_clients)
        local2, summed, lag = fn(local, tokens, mask, keys, alive, snapshot,
                                 state.tables, state.stale, lag)

        # Pushes always land on the canonical statistics (SSP relaxes
        # what clients *see*, never what the server *applies*).
        shared2 = fam.apply_delta(canonical, summed)

        # 5. distributed projection (Algorithm 2) over the model axis rows.
        #    The shard_mapped row-partitioned form is used for the
        #    single-slice server state (the historical layout); for
        #    multi-shard states the same rules+aggregates run replicated —
        #    mathematically identical (Algorithm 2 *distributes* this very
        #    computation), avoiding the partitioner defect noted at the
        #    pull: resharding the concat-derived statistics onto model-axis
        #    rows mid-program strides them over the wrong mesh axis
        #    (jax 0.4.37).
        stats = fam.stats_dict(shared2)
        if dist_cfg.project_every and server.spec.n_shards == 1:
            row_specs = {n: P(model_axis, None)
                         for n in stats if stats[n].ndim == 2}
            for n in stats:
                if stats[n].ndim != 2:
                    row_specs[n] = P()
            projectable = {n: v for n, v in stats.items()}
            # Only the rules whose every operand is a shared statistic run
            # here; local-operand rules were applied inside client_round.
            elem_rules = [r for r in fam.shared_rules
                          if projectable.get(r.a) is not None
                          and (r.b is None
                               or projectable.get(r.b) is not None)]
            stats = _project_alg2(projectable, elem_rules, fam.aggregates,
                                  mesh, model_axis, row_specs)
        elif dist_cfg.project_every:
            stats = projection.project(stats, fam.shared_rules,
                                       fam.aggregates)
        shared3 = fam.shared_from_dict(stats)

        # Canonical storage: keep the server copy sharded over model rows.
        # Only safe as a constraint when the server state is one dense
        # slice per stat (n_shards == 1): re-slicing a row-constrained
        # tensor into the per-shard outputs mis-lowers on multi-axis host
        # meshes (XLA strides the rows over the wrong axis — observed on
        # jax 0.4.37; same partitioner defect worked around at the pull
        # above), so multi-shard slices stay replicated and GSPMD places
        # them.
        if server.spec.n_shards == 1:
            shared3 = jax.tree.map(
                lambda x: jax.lax.with_sharding_constraint(
                    x, row_sharding if x.ndim == 2 else vec_sharding),
                shared3)
        state2 = server.load_dense(state, shared3)
        state2 = server.accumulate_mass(state2, summed)
        state2 = state2._replace(
            cache=cache, cache_version=version.astype(jnp.int32),
            client_lag=lag,
            clocks=state.clocks + alive.astype(jnp.int32))
        return local2, state2

    return jax.jit(round_fn)


def _project_alg2(stats, rules, aggregates, mesh, model_axis, row_specs):
    """Algorithm 2: rows partitioned over the model axis, projected locally,
    aggregates re-derived with a psum."""
    from jax.experimental.shard_map import shard_map

    agg_outs = {a.out for a in aggregates}
    elem = {n: v for n, v in stats.items() if n not in agg_outs}

    in_specs = ({n: row_specs[n] for n in elem},)
    out_specs = {n: row_specs[n] for n in elem}
    for a in aggregates:
        out_specs[a.out] = P()

    def local_fn(e):
        out = dict(e)
        for rule in rules:
            if rule.a in out and (rule.b is None or rule.b in out):
                out = projection._apply_rule(out, rule)
        for a in aggregates:
            out[a.out] = jax.lax.psum(out[a.src].sum(a.axis), model_axis)
        return out

    fn = shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_rep=False)
    result = fn(elem)
    # Preserve non-projected passthrough stats (e.g. theta0).
    for n, v in stats.items():
        if n not in result:
            result[n] = v
    return result


# --------------------------------------------------------------------------
# Compressed transport (paper §5.3 filter as an actual smaller collective)
# --------------------------------------------------------------------------

def sync_compressed(delta: Array, spec: ps.FilterSpec, key: Array,
                    data_axis: str = "data") -> Array:
    """Inside shard_map: compress this client's delta to (indices, values),
    all-gather the compressed representation, and scatter-add — the wire
    carries n_clients·k·K floats instead of V·K.  Returns the dense summed
    delta on every client."""
    comp = ps.compress_delta(delta, spec, key)
    all_idx = jax.lax.all_gather(comp.indices, data_axis)   # (C, k)
    all_val = jax.lax.all_gather(comp.values, data_axis)    # (C, k, K)
    dense = jnp.zeros_like(delta)
    return dense.at[all_idx.reshape(-1)].add(
        all_val.reshape(-1, delta.shape[1]))
