"""Generalized Stirling numbers for Pitman-Yor / Poisson-Dirichlet samplers.

The paper's PDP conditional (eqs. 5-6) uses ratios of generalized Stirling
numbers S^N_{M,a} with the recurrence

    S^{N+1}_{M,a} = S^N_{M-1,a} + (N - M a) S^N_{M,a},
    S^N_{M,a} = 0 for M > N,   S^0_{0,a} = 1.

They grow super-exponentially, so we precompute a log-space table on the
host (float64) once per discount value and look up ratios with cheap gathers
inside the jitted sampler.  Counts are clamped to the table size; at the
scales where the clamp binds the ratio is within O(1/N) of its asymptote,
which is far below sampler noise (the paper's own implementation uses a
bounded cache as well, cf. [5]).
"""

from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


@functools.lru_cache(maxsize=8)
def log_stirling_table(n_max: int, a: float) -> "np.ndarray":
    """Return logS with shape (n_max+1, n_max+1): logS[N, M] = log S^N_{M,a}."""
    logS = np.full((n_max + 1, n_max + 1), NEG_INF, dtype=np.float64)
    logS[0, 0] = 0.0
    for n in range(0, n_max):
        m = np.arange(0, n + 2)
        # term1: S^n_{m-1}
        t1 = np.full(n + 2, NEG_INF)
        t1[1:] = logS[n, 0 : n + 1]
        # term2: (n - m a) S^n_m
        coef = n - m * a
        t2 = np.where(coef > 0, np.log(np.maximum(coef, 1e-300)) + logS[n, 0 : n + 2], NEG_INF)
        logS[n + 1, 0 : n + 2] = np.logaddexp(t1, t2)
    return logS


def as_jax(n_max: int, a: float) -> jnp.ndarray:
    return jnp.asarray(log_stirling_table(n_max, a), dtype=jnp.float32)


def log_ratio_same(table: jnp.ndarray, n: jnp.ndarray, m: jnp.ndarray) -> jnp.ndarray:
    """log S^{n+1}_{m} - log S^{n}_{m}  (paper eq. 5 ratio), clamped to table."""
    hi = table.shape[0] - 2
    n_c = jnp.clip(n, 0, hi).astype(jnp.int32)
    m_c = jnp.clip(m, 0, hi + 1).astype(jnp.int32)
    return table[n_c + 1, m_c] - table[n_c, m_c]


def log_ratio_incr(table: jnp.ndarray, n: jnp.ndarray, m: jnp.ndarray) -> jnp.ndarray:
    """log S^{n+1}_{m+1} - log S^{n}_{m}  (paper eq. 6 ratio), clamped."""
    hi = table.shape[0] - 2
    n_c = jnp.clip(n, 0, hi).astype(jnp.int32)
    m_c = jnp.clip(m, 0, hi).astype(jnp.int32)
    return table[n_c + 1, m_c + 1] - table[n_c, m_c]
