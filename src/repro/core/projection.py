"""Parameter projection for constraint-violation resolution (paper §5.5).

Under the relaxed (eventual) consistency model, concurrently-pushed deltas
can leave the shared sufficient statistics outside their feasible polytope —
e.g. in PDP the table counts must satisfy 0 ≤ s_wk ≤ m_wk and
m_wk > 0 ⇒ s_wk ≥ 1; aggregates must satisfy m_k = Σ_w m_wk.  Sampling from
inconsistent statistics produces NaN/negative probabilities and divergence
(paper Fig. 8).  The fix is a proximal projection: round every parameter to
the nearest point of the constraint set.

The paper gives three deployment schedules for the same projection:

  Algorithm 1 — single-machine batch pass at the end of an iteration.
  Algorithm 2 — distributed batch pass: parameter IDs are partitioned over
                clients, each projects its slice (the variant the paper
                reports results with).
  Algorithm 3 — on-demand, server-side, applied to every read.

All three share the rule language below.  A ``Rule`` constrains an ordered
pair of arrays elementwise; an ``Aggregate`` re-derives a sum statistic from
its counterpart (the paper's C2 tuples).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

Array = jax.Array
Stats = dict[str, Array]


@dataclass(frozen=True)
class Rule:
    """Elementwise constraint c(A, B) between stats ``a`` and ``b``.

    kind:
      "le"        — A ≤ B            (projection: A ← min(A, B))
      "ge"        — A ≥ B            (projection: A ← max(A, B))
      "nonneg"    — A ≥ 0            (b ignored)
      "pos_link"  — B > 0 ⇒ A ≥ 1 and B = 0 ⇒ A = 0
                    (PDP: m_wk > 0 ⇒ s_wk ≥ 1; m_wk = 0 ⇒ s_wk = 0)
    Projections move each violating entry to the nearest feasible value
    (L1-proximal, matching Algorithm 1's argmin |A' - A|).
    """

    kind: str
    a: str
    b: str | None = None


@dataclass(frozen=True)
class Aggregate:
    """C2 tuple: stats[out] must equal stats[src].sum(axis)."""

    src: str
    out: str
    axis: int | tuple[int, ...] = 0


def _apply_rule(stats: Stats, rule: Rule) -> Stats:
    a = stats[rule.a]
    if rule.kind == "nonneg":
        stats = dict(stats)
        stats[rule.a] = jnp.maximum(a, 0.0)
        return stats
    b = stats[rule.b]
    if rule.kind == "le":
        a2 = jnp.minimum(a, b)
    elif rule.kind == "ge":
        a2 = jnp.maximum(a, b)
    elif rule.kind == "pos_link":
        a2 = jnp.where(b > 0, jnp.maximum(a, 1.0), 0.0)
    else:
        raise ValueError(rule.kind)
    out = dict(stats)
    out[rule.a] = a2
    return out


def count_violations(stats: Stats, rules: Sequence[Rule]) -> Array:
    """Total number of elementwise constraint violations (diagnostics)."""
    total = jnp.zeros((), jnp.float32)
    for rule in rules:
        a = stats[rule.a]
        if rule.kind == "nonneg":
            total += jnp.sum((a < 0).astype(jnp.float32))
            continue
        b = stats[rule.b]
        if rule.kind == "le":
            total += jnp.sum((a > b).astype(jnp.float32))
        elif rule.kind == "ge":
            total += jnp.sum((a < b).astype(jnp.float32))
        elif rule.kind == "pos_link":
            total += jnp.sum(((b > 0) & (a < 1)).astype(jnp.float32))
            total += jnp.sum(((b <= 0) & (a != 0)).astype(jnp.float32))
    return total


def project(stats: Stats, rules: Sequence[Rule],
            aggregates: Sequence[Aggregate] = ()) -> Stats:
    """Algorithm 1 — batch projection on the full statistics.

    Rules are applied in order (the paper sorts so the most-frequent
    parameter types come first; callers pass them pre-sorted) followed by
    aggregate re-derivation.
    """
    for rule in rules:
        stats = _apply_rule(stats, rule)
    stats = dict(stats)
    for agg in aggregates:
        stats[agg.out] = stats[agg.src].sum(agg.axis)
    return stats


def project_distributed(
    stats: Stats,
    rules: Sequence[Rule],
    aggregates: Sequence[Aggregate],
    mesh: jax.sharding.Mesh,
    shard_axis: str = "model",
    row_specs: dict[str, P] | None = None,
) -> Stats:
    """Algorithm 2 — distributed projection.

    Parameter IDs (rows of the (V, K) matrices) are partitioned across
    devices of ``shard_axis``; each shard projects its slice independently
    (the elementwise rules are embarrassingly row-parallel — the paper's
    random allocation of correction tasks by parameter ID).  Aggregates are
    re-derived with a ``psum`` over the shards, which is the SendUpdate of
    Algorithm 1 expressed as a collective.
    """
    from jax.experimental.shard_map import shard_map

    elementwise = {k: v for k, v in stats.items()
                   if not any(a.out == k for a in aggregates)}
    agg_names = [a.out for a in aggregates]

    in_specs = {k: (row_specs or {}).get(k, P(shard_axis)) for k in elementwise}
    out_specs = dict(in_specs)
    for a in aggregates:
        out_specs[a.out] = P()  # replicated after psum

    def local_project(shard_stats):
        out = dict(shard_stats)
        for rule in rules:
            out = _apply_rule(out, rule)
        for agg in aggregates:
            partial_sum = out[agg.src].sum(agg.axis)
            out[agg.out] = jax.lax.psum(partial_sum, shard_axis)
        return out

    fn = shard_map(local_project, mesh=mesh,
                   in_specs=(in_specs,), out_specs=out_specs, check_rep=False)
    result = fn(elementwise)
    return result


def make_on_demand(rules: Sequence[Rule]) -> Callable[[Stats], Stats]:
    """Algorithm 3 — server-side on-demand correction.

    Returns a pull-path filter: every time a client pulls parameters the
    returned callable rounds them to the feasible set.  Aggregates are NOT
    re-derived here (that requires a global pass); the read is merely made
    safe, exactly as the paper's server-side variant."""

    def on_pull(stats: Stats) -> Stats:
        out = stats
        for rule in rules:
            out = _apply_rule(out, rule)
        return out

    return on_pull


# Canonical rule sets ------------------------------------------------------

PDP_RULES = (
    Rule("nonneg", "m_wk"),
    Rule("nonneg", "s_wk"),
    Rule("pos_link", "s_wk", "m_wk"),   # m>0 => s>=1 ; m=0 => s=0
    Rule("le", "s_wk", "m_wk"),         # s <= m
)
PDP_AGGREGATES = (
    Aggregate("m_wk", "m_k", 0),
    Aggregate("s_wk", "s_k", 0),
)

LDA_RULES = (Rule("nonneg", "n_wk"),)
LDA_AGGREGATES = (Aggregate("n_wk", "n_k", 0),)

HDP_RULES = (
    Rule("nonneg", "n_wk"),
    Rule("nonneg", "m_dk"),
    Rule("pos_link", "m_dk", "n_dk"),   # n_dk>0 => m_dk>=1 ; n_dk=0 => m_dk=0
    Rule("le", "m_dk", "n_dk"),         # tables <= customers
)
HDP_AGGREGATES = (Aggregate("n_wk", "n_k", 0),)
