"""Metropolis-Hastings-Walker sampling (paper §3).

The MHW sampler draws from a slowly-changing categorical distribution ``p``
in amortized O(1) by treating a *stale* snapshot ``q`` of ``p`` (stored as an
alias table) as a stationary MH proposal and correcting with accept/reject:

    Pr{move i -> j} = min(1, q(i) p(j) / (q(j) p(i)))          (paper eq. 7)

For topic models the proposal is the paper's sparse+dense mixture (eq. 4):
a document-sparse term sampled exactly and a corpus-dense term sampled from
the stale alias table; acceptance only needs *point* evaluations of p and q,
which cost O(1) gathers.

This module is generic over the point-evaluation callables so the same chain
drives LDA, PDP and HDP.

Two layouts are supported (DESIGN.md §5):

* position-scan — :func:`mh_chain` runs inside ``lda.sweep``'s sequential
  position scan (one chain per document per position);
* token-sorted — :func:`sorted_chain` is the pure-jnp semantics of one
  whole-shard chain over the sorted stream of ``repro.data.segment``; the
  production path is the fused Pallas kernel
  ``repro.kernels.mhw_fused.mhw_sweep_fused``, which must match it
  bit-for-bit given the same uniforms.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import alias as alias_mod

Array = jax.Array


class MixtureProposal(NamedTuple):
    """The paper's sparse+dense proposal for one batch of tokens.

    sparse_weights: (B, K) unnormalized sparse-term weights (e.g. n_dk rows).
      Zero rows are fine (coin then always selects dense).
    dense_tables: per-row alias tables, (R, K).
    dense_rows:  (B,) row index (token-type) into ``dense_tables`` per token.
    """

    sparse_weights: Array
    dense_tables: alias_mod.AliasTable
    dense_rows: Array

    def sample(self, key: Array) -> Array:
        """Draw one proposal per token: (B,) int32."""
        b = self.sparse_weights.shape[0]
        k_coin, k_sparse, k_dense = jax.random.split(key, 3)
        sparse_mass = jnp.sum(self.sparse_weights, axis=-1)
        dense_mass = self.dense_tables.mass[self.dense_rows]
        total = sparse_mass + dense_mass
        pick_sparse = jax.random.uniform(k_coin, (b,)) * total < sparse_mass
        # Sparse draw: vectorized categorical over K lanes (TPU analogue of
        # the O(k_d) sparse walk; see DESIGN.md §2).
        gumbel = jax.random.gumbel(k_sparse, self.sparse_weights.shape)
        logw = jnp.log(self.sparse_weights + 1e-30)
        sparse_draw = jnp.argmax(logw + gumbel, axis=-1).astype(jnp.int32)
        dense_draw = alias_mod.sample_rows(self.dense_tables, self.dense_rows, k_dense)
        return jnp.where(pick_sparse, sparse_draw, dense_draw)

    def log_q(self, outcome: Array, dense_probs: Array) -> Array:
        """Unnormalized log proposal density at ``outcome`` (B,).

        ``dense_probs`` is the (R, K) *stale* unnormalized dense distribution
        the alias tables were built from (needed for point evaluation — the
        table itself only supports sampling).
        """
        b = jnp.arange(outcome.shape[0])
        sparse_val = self.sparse_weights[b, outcome]
        dense_val = dense_probs[self.dense_rows, outcome]
        return jnp.log(sparse_val + dense_val + 1e-30)


def mh_chain(
    key: Array,
    init: Array,
    proposal: MixtureProposal,
    dense_probs: Array,
    log_p: Callable[[Array], Array],
    n_steps: int,
) -> Array:
    """Run ``n_steps`` of stationary-proposal MH for a batch of tokens.

    init: (B,) current states (e.g. current topic assignments).
    log_p: maps (B,) outcomes -> (B,) unnormalized log target density.
    Returns the final (B,) states.
    """

    def step(carry, k):
        z = carry
        k_prop, k_acc = jax.random.split(k)
        cand = proposal.sample(k_prop)
        log_ratio = (
            log_p(cand) - log_p(z)
            + proposal.log_q(z, dense_probs) - proposal.log_q(cand, dense_probs)
        )
        accept = jnp.log(jax.random.uniform(k_acc, z.shape) + 1e-30) < log_ratio
        return jnp.where(accept, cand, z), accept

    keys = jax.random.split(key, n_steps)
    z, accepts = jax.lax.scan(step, init, keys)
    return z


def mh_chain_with_stats(key, init, proposal, dense_probs, log_p, n_steps):
    """Like mh_chain but also returns the mean acceptance rate (diagnostics)."""

    def step(carry, k):
        z = carry
        k_prop, k_acc = jax.random.split(k)
        cand = proposal.sample(k_prop)
        log_ratio = (
            log_p(cand) - log_p(z)
            + proposal.log_q(z, dense_probs) - proposal.log_q(cand, dense_probs)
        )
        accept = jnp.log(jax.random.uniform(k_acc, z.shape) + 1e-30) < log_ratio
        return jnp.where(accept, cand, z), jnp.mean(accept.astype(jnp.float32))

    keys = jax.random.split(key, n_steps)
    z, rates = jax.lax.scan(step, init, keys)
    return z, jnp.mean(rates)


# ---------------------------------------------------------------------------
# Token-sorted layout (DESIGN.md §5) — oracle for the fused kernel
# ---------------------------------------------------------------------------

_EPS = 1e-30


def _gather_k(mat: Array, idx: Array) -> Array:
    """mat: (B, K), idx: (B,) int → (B,) mat[b, idx[b]]."""
    return jnp.take_along_axis(mat, idx[:, None].astype(jnp.int32),
                               axis=1)[:, 0]


def sorted_chain(prob: Array, alias: Array, mass: Array, stale: Array,
                 n_wk: Array, n_k: Array, rows: Array, z0: Array, ndk: Array,
                 slot: Array, coin: Array, u_mix: Array, u_sparse: Array,
                 u_acc: Array, *, alpha: float, beta: float,
                 beta_bar: float) -> Array:
    """Whole-shard MH chain over the token-sorted stream, given uniforms.

    Pure-jnp reference semantics of ``kernels.mhw_fused.mhw_sweep_fused``:
    the fresh LM row, the sparse inverse-CDF draw, the dense alias draw and
    the acceptance test use the exact formulas of the kernel so outputs are
    bit-identical.  ``rows`` entries ≥ V are padding and keep ``z0``.

    prob/alias/stale/n_wk: (V, K); mass: (V,); n_k: (K,); rows/z0: (B,);
    ndk: (B, K) *raw* gathered doc rows (the ^{-di} own-token removal
    happens here, as in the kernel); slot/coin/u_mix/u_sparse/u_acc:
    (S, B) per-step uniforms.  Returns (B,) int32.
    """
    v, k_topics = prob.shape
    real = rows < v
    r = jnp.clip(rows, 0, v - 1)

    karange = jax.lax.broadcasted_iota(jnp.int32, (1, k_topics), 1)
    own = ((karange == z0[:, None]) & real[:, None]).astype(jnp.float32)
    ndk = ndk - own
    rows_wk = n_wk[r]
    lm = (rows_wk - own + beta) / (n_k[None, :] - own + beta_bar)

    sparse_w = ndk * lm
    cdf = jnp.cumsum(sparse_w, axis=-1)
    sparse_mass = cdf[:, -1]
    dense_mass = mass[r]
    stale_rows = stale[r]

    def log_p(t):
        return (jnp.log(_gather_k(ndk, t) + alpha)
                + jnp.log(_gather_k(lm, t) + _EPS))

    def log_q(t):
        return jnp.log(_gather_k(sparse_w, t) + _gather_k(stale_rows, t)
                       + _EPS)

    z = z0
    lp_z = log_p(z)
    lq_z = log_q(z)
    for s in range(slot.shape[0]):
        slot_s = slot[s]
        dense_draw = jnp.where(coin[s] < prob[r, slot_s], slot_s,
                               alias[r, slot_s])
        target = u_sparse[s] * sparse_mass
        sparse_draw = jnp.clip(
            jnp.sum((cdf <= target[:, None]).astype(jnp.int32), axis=-1),
            0, k_topics - 1)
        pick_sparse = u_mix[s] * (sparse_mass + dense_mass) < sparse_mass
        cand = jnp.where(pick_sparse, sparse_draw, dense_draw).astype(jnp.int32)
        lp_c = log_p(cand)
        lq_c = log_q(cand)
        accept = jnp.log(u_acc[s] + _EPS) < lp_c - lp_z + lq_z - lq_c
        z = jnp.where(accept, cand, z)
        lp_z = jnp.where(accept, lp_c, lp_z)
        lq_z = jnp.where(accept, lq_c, lq_z)
    return jnp.where(real, z, z0).astype(jnp.int32)
