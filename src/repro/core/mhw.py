"""Metropolis-Hastings-Walker sampling (paper §3).

The MHW sampler draws from a slowly-changing categorical distribution ``p``
in amortized O(1) by treating a *stale* snapshot ``q`` of ``p`` (stored as an
alias table) as a stationary MH proposal and correcting with accept/reject:

    Pr{move i -> j} = min(1, q(i) p(j) / (q(j) p(i)))          (paper eq. 7)

For topic models the proposal is the paper's sparse+dense mixture (eq. 4):
a document-sparse term sampled exactly and a corpus-dense term sampled from
the stale alias table; acceptance only needs *point* evaluations of p and q,
which cost O(1) gathers.

This module is generic over the model family.  Every family factors its
conditional as

    p(e) ∝ (doc_e + prior_e) · f_e          (the ``ModelFamily`` protocol's
                                             dense-proposal factorization)

with ``doc`` the document-sparse counts over E outcomes (E = K topics for
LDA/HDP, 2K joint (topic, table-indicator) outcomes for PDP), ``prior`` the
per-outcome prior mass (α for LDA, b1·θ0_t for HDP) and ``f`` the fresh
corpus factor (the LM row for LDA/HDP, the Stirling-ratio factor for PDP).
The stale dense term ``prior·f_stale`` lives in the alias table.

Two layouts are supported (DESIGN.md §5):

* position-scan — :func:`mh_chain` runs inside each family's sequential
  position scan (one chain per document per position);
* token-sorted — :func:`mix_chain` is the single pure-jnp chain semantics
  over the sorted stream of ``repro.data.segment``, shared bit-for-bit by
  the per-family oracles (:func:`sorted_chain`, ``pdp.sorted_chain_pdp``)
  and the fused Pallas kernels (``repro.kernels.mhw_fused``), which must
  match them bit-exactly given the same uniforms.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import alias as alias_mod

Array = jax.Array


class MixtureProposal(NamedTuple):
    """The paper's sparse+dense proposal for one batch of tokens.

    sparse_weights: (B, K) unnormalized sparse-term weights (e.g. n_dk rows).
      Zero rows are fine (coin then always selects dense).
    dense_tables: per-row alias tables, (R, K).
    dense_rows:  (B,) row index (token-type) into ``dense_tables`` per token.
    """

    sparse_weights: Array
    dense_tables: alias_mod.AliasTable
    dense_rows: Array

    def sample(self, key: Array) -> Array:
        """Draw one proposal per token: (B,) int32."""
        b = self.sparse_weights.shape[0]
        k_coin, k_sparse, k_dense = jax.random.split(key, 3)
        sparse_mass = jnp.sum(self.sparse_weights, axis=-1)
        dense_mass = self.dense_tables.mass[self.dense_rows]
        total = sparse_mass + dense_mass
        pick_sparse = jax.random.uniform(k_coin, (b,)) * total < sparse_mass
        # Sparse draw: vectorized categorical over K lanes (TPU analogue of
        # the O(k_d) sparse walk; see DESIGN.md §2).
        gumbel = jax.random.gumbel(k_sparse, self.sparse_weights.shape)
        logw = jnp.log(self.sparse_weights + 1e-30)
        sparse_draw = jnp.argmax(logw + gumbel, axis=-1).astype(jnp.int32)
        dense_draw = alias_mod.sample_rows(self.dense_tables, self.dense_rows, k_dense)
        return jnp.where(pick_sparse, sparse_draw, dense_draw)

    def log_q(self, outcome: Array, dense_probs: Array) -> Array:
        """Unnormalized log proposal density at ``outcome`` (B,).

        ``dense_probs`` is the (R, K) *stale* unnormalized dense distribution
        the alias tables were built from (needed for point evaluation — the
        table itself only supports sampling).
        """
        b = jnp.arange(outcome.shape[0])
        sparse_val = self.sparse_weights[b, outcome]
        dense_val = dense_probs[self.dense_rows, outcome]
        return jnp.log(sparse_val + dense_val + 1e-30)


def accept_log_ratio(log_p_cand: Array, log_p_cur: Array,
                     log_q_cur: Array, log_q_cand: Array) -> Array:
    """Paper eq. 7 in log space: log [p(j) q(i)] − log [p(i) q(j)].

    The single acceptance rule every family and every layout uses — the
    ``ModelFamily.accept_ratio`` protocol hook resolves here.
    """
    return log_p_cand - log_p_cur + log_q_cur - log_q_cand


def mh_chain(
    key: Array,
    init: Array,
    proposal: MixtureProposal,
    dense_probs: Array,
    log_p: Callable[[Array], Array],
    n_steps: int,
) -> Array:
    """Run ``n_steps`` of stationary-proposal MH for a batch of tokens.

    init: (B,) current states (e.g. current topic assignments).
    log_p: maps (B,) outcomes -> (B,) unnormalized log target density.
    Returns the final (B,) states.
    """

    def step(carry, k):
        z = carry
        k_prop, k_acc = jax.random.split(k)
        cand = proposal.sample(k_prop)
        log_ratio = accept_log_ratio(
            log_p(cand), log_p(z),
            proposal.log_q(z, dense_probs), proposal.log_q(cand, dense_probs))
        accept = jnp.log(jax.random.uniform(k_acc, z.shape) + 1e-30) < log_ratio
        return jnp.where(accept, cand, z), accept

    keys = jax.random.split(key, n_steps)
    z, accepts = jax.lax.scan(step, init, keys)
    return z


def mh_chain_with_stats(key, init, proposal, dense_probs, log_p, n_steps):
    """Like mh_chain but also returns the mean acceptance rate (diagnostics)."""

    def step(carry, k):
        z = carry
        k_prop, k_acc = jax.random.split(k)
        cand = proposal.sample(k_prop)
        log_ratio = accept_log_ratio(
            log_p(cand), log_p(z),
            proposal.log_q(z, dense_probs), proposal.log_q(cand, dense_probs))
        accept = jnp.log(jax.random.uniform(k_acc, z.shape) + 1e-30) < log_ratio
        return jnp.where(accept, cand, z), jnp.mean(accept.astype(jnp.float32))

    keys = jax.random.split(key, n_steps)
    z, rates = jax.lax.scan(step, init, keys)
    return z, jnp.mean(rates)


# ---------------------------------------------------------------------------
# Token-sorted layout (DESIGN.md §5) — oracle semantics for the fused kernels
# ---------------------------------------------------------------------------

_EPS = 1e-30


def _gather_k(mat: Array, idx: Array) -> Array:
    """mat: (B, E), idx: (B,) int → (B,) mat[b, idx[b]]."""
    return jnp.take_along_axis(mat, idx[:, None].astype(jnp.int32),
                               axis=1)[:, 0]


def doc_sparse_logp(doc: Array, prior: Array, outcome: Array) -> Array:
    """log of the document-sparse target factor log(doc_e + prior_e) at
    ``outcome``: doc (B, E), prior (E,), outcome (B,) → (B,).

    THE single implementation — :func:`mix_chain` (and through it every
    oracle and fused kernel) and the ``ModelFamily.doc_sparse_logp``
    protocol hook all resolve here, so the target math cannot fork.
    """
    return jnp.log(_gather_k(doc, outcome) + prior[outcome] + _EPS)


def mix_chain(z0: Array, *, doc: Array, prior: Array, logf: Array,
              sparse_w: Array, stale_rows: Array, prob_rows: Array,
              alias_rows: Array, dense_mass: Array, slot: Array, coin: Array,
              u_mix: Array, u_sparse: Array, u_acc: Array) -> Array:
    """The single whole-stream MH chain over E outcomes, given uniforms.

    The bit-exactness contract of the sorted pipeline: every family's
    pure-jnp oracle AND every fused Pallas kernel call this function on the
    same values, so kernel and oracle cannot drift.

    Target (eq. 4 factorization): p(e) ∝ (doc_e + prior_e) · f_e with
    log f supplied as ``logf``; proposal q(e) ∝ sparse_w_e + stale_e.

    z0: (B,) chain init over outcomes.
    doc/logf/sparse_w/stale_rows/prob_rows/alias_rows: (B, E) per-token rows
      (own-token ^{-di} removal already applied by the caller).
    prior: (E,) per-outcome prior mass (α·1 for LDA/PDP, b1·θ0 for HDP).
    dense_mass: (B,) stale dense-term mass per token's row.
    slot/coin/u_mix/u_sparse/u_acc: (S, B) per-step uniforms (slot int32 in
      [0, E)).  Returns (B,) int32 final states.
    """
    e_outcomes = doc.shape[-1]
    cdf = jnp.cumsum(sparse_w, axis=-1)
    sparse_mass = cdf[:, -1]

    def log_p(t):
        return doc_sparse_logp(doc, prior, t) + _gather_k(logf, t)

    def log_q(t):
        return jnp.log(_gather_k(sparse_w, t) + _gather_k(stale_rows, t)
                       + _EPS)

    z = z0
    lp_z = log_p(z)
    lq_z = log_q(z)
    for s in range(slot.shape[0]):
        slot_s = slot[s]
        dense_draw = jnp.where(coin[s] < _gather_k(prob_rows, slot_s), slot_s,
                               _gather_k(alias_rows, slot_s))
        target = u_sparse[s] * sparse_mass
        sparse_draw = jnp.clip(
            jnp.sum((cdf <= target[:, None]).astype(jnp.int32), axis=-1),
            0, e_outcomes - 1)
        pick_sparse = u_mix[s] * (sparse_mass + dense_mass) < sparse_mass
        cand = jnp.where(pick_sparse, sparse_draw, dense_draw).astype(jnp.int32)
        lp_c = log_p(cand)
        lq_c = log_q(cand)
        accept = (jnp.log(u_acc[s] + _EPS)
                  < accept_log_ratio(lp_c, lp_z, lq_z, lq_c))
        z = jnp.where(accept, cand, z)
        lp_z = jnp.where(accept, lp_c, lp_z)
        lq_z = jnp.where(accept, lq_c, lq_z)
    return z.astype(jnp.int32)


def sorted_chain(prob: Array, alias: Array, mass: Array, stale: Array,
                 n_wk: Array, n_k: Array, prior: Array, rows: Array,
                 z0: Array, ndk: Array, slot: Array, coin: Array,
                 u_mix: Array, u_sparse: Array, u_acc: Array, *, beta: float,
                 beta_bar: float) -> Array:
    """Whole-shard MH chain over the token-sorted stream — lm families.

    Pure-jnp reference semantics of ``kernels.mhw_fused.mhw_sweep_fused``
    for the families whose fresh factor is the language-model row
    (n_wk − own + β)/(n_k − own + β̄): LDA (prior = α·1) and HDP-LDA
    (prior = b1·θ0).  Delegates the chain itself to :func:`mix_chain`, which
    the kernel also calls — bit-identical outputs given the same uniforms.
    ``rows`` entries ≥ V are padding and keep ``z0``.

    prob/alias/stale/n_wk: (V, K); mass: (V,); n_k/prior: (K,); rows/z0:
    (B,); ndk: (B, K) *raw* gathered doc rows (the ^{-di} own-token removal
    happens here, as in the kernel); slot/coin/u_mix/u_sparse/u_acc:
    (S, B) per-step uniforms.  Returns (B,) int32.
    """
    v, k_topics = prob.shape
    real = rows < v
    r = jnp.clip(rows, 0, v - 1)

    karange = jax.lax.broadcasted_iota(jnp.int32, (1, k_topics), 1)
    own = ((karange == z0[:, None]) & real[:, None]).astype(jnp.float32)
    ndk = ndk - own
    rows_wk = n_wk[r]
    lm = (rows_wk - own + beta) / (n_k[None, :] - own + beta_bar)

    z = mix_chain(z0, doc=ndk, prior=prior, logf=jnp.log(lm + _EPS),
                  sparse_w=ndk * lm, stale_rows=stale[r], prob_rows=prob[r],
                  alias_rows=alias[r], dense_mass=mass[r], slot=slot,
                  coin=coin, u_mix=u_mix, u_sparse=u_sparse, u_acc=u_acc)
    return jnp.where(real, z, z0).astype(jnp.int32)
