"""Seeded TCP chaos proxy for the framed wire protocol (DESIGN.md §13).

A :class:`ChaosProxy` is a transparent relay interposed between clients
and one shard server: it listens on its own port, dials the upstream
per accepted connection, and forwards *whole frames* in both directions
— except where a :class:`repro.core.fault.FaultPlan`'s network events
(``conn_drop`` / ``frame_truncate`` / ``delay``) schedule misbehavior.

Determinism is the design constraint: every action is a pure function of
``(plan, connection ordinal, client→server frame ordinal)``.  The proxy
assigns connections ordinals in accept order and counts the frames a
connection sends toward the server; an event fires when its ``client``
field matches the connection ordinal (-1 = every connection) and the
frame ordinal falls in ``[start, stop)`` on the event's ``period``.  Two
runs of the same seeded schedule therefore corrupt exactly the same
frames — which is what lets tests assert byte-identical server stores
and identical client retry counts across replays.

Frame-ordinal map for a BSP train/stress client (how to aim an event):
HELLO is frame 0, INIT frame 1, then round ``r`` contributes PULL at
``2 + 2r`` and PUSH at ``3 + 2r`` — so ``FaultEvent("conn_drop",
client=0, start=5, stop=6)`` severs connection 0's round-1 push.

Actions (all counted per connection in :attr:`ChaosProxy.actions`):

``conn_drop``
    Close both sockets *instead of* forwarding the scheduled frame: the
    sender sees a reset/EOF mid-RPC and retries through the idempotent
    replay path; the server sees a dead connection and starts the
    liveness clock for its clients.

``frame_truncate``
    Forward the frame header plus only ``magnitude`` (fraction) of the
    payload, then close: the receiver gets a mid-read EOF — a
    :class:`~repro.net.protocol.TransportError`, never a silently
    corrupt frame (the exact-read discipline turns byte loss into frame
    loss).

``delay``
    Sleep ``magnitude`` seconds, then forward intact — latency without
    loss (barrier and timeout code paths under slow links).

The proxy only ever cuts the stream at boundaries it chose; it never
rewrites bytes, so any corruption the peers observe is the protocol
layer's own truncation handling — fuzzing *placement*, not encoding.
"""

from __future__ import annotations

import socket
import threading
import time
from typing import Any

from repro.core.fault import NET_KINDS, FaultEvent, FaultPlan
from repro.net import protocol


class ChaosProxy:
    """A frame-aware TCP relay that misbehaves on schedule.

    One proxy fronts one upstream shard address.  Accepted connections
    get ordinals in accept order; the scheduled events from
    ``plan.net_events`` fire on the client→server frame stream (the
    mutation direction — where idempotency matters).  Server→client
    frames are relayed verbatim (reply loss still manifests client-side
    as a severed connection when an event kills the link first).
    """

    def __init__(self, upstream: str, plan: FaultPlan | None = None, *,
                 host: str = "127.0.0.1", port: int = 0,
                 dial_timeout: float = 10.0):
        up_host, _, up_port = upstream.rpartition(":")
        self.upstream = (up_host, int(up_port))
        self.events: tuple[FaultEvent, ...] = tuple(
            plan.net_events) if plan is not None else ()
        for e in self.events:
            if e.kind not in NET_KINDS:
                raise ValueError(f"not a network fault kind: {e.kind!r}")
        self.dial_timeout = dial_timeout
        self._lock = threading.Lock()
        self._conn_seq = 0
        self._stop = False
        self._threads: list[threading.Thread] = []
        # Observability: per-kind counts of fired actions, plus relayed
        # frame totals — the determinism tests compare these across runs.
        self.actions: dict[str, int] = {k: 0 for k in NET_KINDS}
        self.frames_forwarded = 0
        self.connections = 0

        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(32)
        self.address: tuple[str, int] = self._listener.getsockname()[:2]
        self._accept_thread: threading.Thread | None = None

    @property
    def addr(self) -> str:
        return f"{self.address[0]}:{self.address[1]}"

    # ------------------------------------------------------------ schedule
    def _action(self, conn_ord: int, frame_ord: int
                ) -> tuple[str, float] | None:
        """The scheduled action for this (connection, frame), or None.
        First matching event wins — a pure function of the plan and the
        two ordinals, so replays are exact."""
        for e in self.events:
            if e.client not in (-1, conn_ord):
                continue
            if not e.start <= frame_ord < e.stop:
                continue
            if (frame_ord - e.start) % e.period:
                continue
            return e.kind, e.magnitude
        return None

    # ----------------------------------------------------------- lifecycle
    def start(self) -> "ChaosProxy":
        t = threading.Thread(target=self._accept_loop,
                             name=f"chaos-accept-{self.address[1]}",
                             daemon=True)
        t.start()
        self._accept_thread = t
        return self

    def close(self) -> None:
        self._stop = True
        try:
            self._listener.close()
        except OSError:
            pass
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)

    def __enter__(self) -> "ChaosProxy":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def stats(self) -> dict[str, Any]:
        with self._lock:
            return {"upstream": f"{self.upstream[0]}:{self.upstream[1]}",
                    "connections": self.connections,
                    "frames_forwarded": self.frames_forwarded,
                    "actions": dict(self.actions)}

    # ------------------------------------------------------------- relay
    def _accept_loop(self) -> None:
        self._listener.settimeout(0.2)
        while not self._stop:
            try:
                downstream, _addr = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            with self._lock:
                conn_ord = self._conn_seq
                self._conn_seq += 1
                self.connections += 1
            t = threading.Thread(target=self._relay_conn,
                                 args=(downstream, conn_ord), daemon=True)
            t.start()
            self._threads.append(t)

    def _relay_conn(self, downstream: socket.socket, conn_ord: int) -> None:
        try:
            upstream = socket.create_connection(
                self.upstream, timeout=self.dial_timeout)
        except OSError:
            downstream.close()
            return
        for s in (downstream, upstream):
            try:
                s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            except OSError:
                pass
            s.settimeout(0.5)
        dead = threading.Event()

        def kill() -> None:
            dead.set()
            for s in (downstream, upstream):
                try:
                    s.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass
                try:
                    s.close()
                except OSError:
                    pass

        # Server→client direction: verbatim whole-frame relay.
        t = threading.Thread(
            target=self._pump_verbatim, args=(upstream, downstream,
                                              dead, kill), daemon=True)
        t.start()

        # Client→server direction: the scheduled one.
        frame_ord = 0
        try:
            while not (self._stop or dead.is_set()):
                try:
                    frame = self._read_frame(downstream)
                except (protocol.ProtocolError, OSError):
                    break
                if frame is None:
                    continue  # idle tick
                header, payload = frame
                act = self._action(conn_ord, frame_ord)
                frame_ord += 1
                if act is not None:
                    kind, magnitude = act
                    with self._lock:
                        self.actions[kind] += 1
                    if kind == "conn_drop":
                        break
                    if kind == "frame_truncate":
                        keep = int(len(payload) * magnitude)
                        try:
                            upstream.sendall(header + payload[:keep])
                        except OSError:
                            pass
                        break
                    if kind == "delay":
                        time.sleep(magnitude)
                try:
                    upstream.sendall(header + payload)
                except OSError:
                    break
                with self._lock:
                    self.frames_forwarded += 1
        finally:
            kill()
            t.join(timeout=2.0)

    def _pump_verbatim(self, src: socket.socket, dst: socket.socket,
                       dead: threading.Event, kill) -> None:
        while not (self._stop or dead.is_set()):
            try:
                frame = self._read_frame(src)
            except (protocol.ProtocolError, OSError):
                break
            if frame is None:
                continue
            try:
                dst.sendall(frame[0] + frame[1])
            except OSError:
                break
            with self._lock:
                self.frames_forwarded += 1
        kill()

    @staticmethod
    def _read_frame(sock: socket.socket
                    ) -> tuple[bytes, bytes] | None:
        """One whole frame off ``sock`` as (header, payload) bytes, or
        None on an idle boundary tick.  Validates the header (so a
        corrupt length can't make the proxy buffer gigabytes) but leaves
        payload contents untouched."""
        try:
            header = protocol.recv_all(sock, protocol.HEADER_SIZE,
                                       at_boundary=True)
        except protocol.IdleTimeout:
            return None
        _mt, length = protocol._validate_header(header)
        payload = protocol.recv_all(sock, length) if length else b""
        return header, payload


def interpose(addrs: list[str], plan: FaultPlan | None,
              *, host: str = "127.0.0.1") -> tuple[list[str],
                                                   list[ChaosProxy]]:
    """Stand one started proxy in front of each shard address; returns
    (proxied addresses in the same order, the proxies).  With no network
    events in the plan the proxies still relay — a pass-through run
    through the proxy is the control arm of the chaos tests."""
    proxies = [ChaosProxy(a, plan, host=host).start() for a in addrs]
    return [p.addr for p in proxies], proxies
