"""The parameter-server process role (paper §5.2 over a real transport).

A :class:`ShardServer` hosts one contiguous vocabulary row-range
``[row_lo, row_hi)`` of the shared sufficient statistics over TCP,
speaking :mod:`repro.net.protocol`.  ``serve_shards`` stands up the
``n_shards`` row-range servers of a :class:`repro.core.server.ShardSpec`
partition in one process (one listener + one handler thread per
connection each).

Bit-exactness with the in-process :class:`~repro.core.server.ParameterServer`
is the design constraint, not an afterthought; the store mirrors the
in-process arithmetic exactly:

* the canonical store is the plain dict of row-sliced sharded statistics
  (``n_wk[lo:hi]``, …); every mutation is elementwise, and elementwise
  ops on a row slice equal the same ops on the dense array restricted to
  those rows — so any shard count is bit-exact with the dense pytree,
  the same argument as DESIGN.md §9's sharded store;
* INIT merges per-client initial statistics in **ascending client id**
  (fold-left), the exact order of ``Trainer._merge_shared``;
* pushes buffer per ``(round, client)`` and a round finalizes only when
  all ``n_clients`` deltas are present (the BSP barrier); the round
  total is summed in ascending client order — the op order of the
  reference loop's ``total_delta`` accumulation — then applied once;
* projection applies the family's elementwise shared rules
  (``repro.core.projection``) to the row slices on the ``project_every``
  cadence, right after the round's push — aggregates (n_k, m_k, s_k) are
  **never** stored here; clients re-derive them from the assembled rows,
  which is exactly where the in-process server's ``apply_delta`` /
  ``project`` get them from.

Consistency policies map onto the wire as the ISSUE specifies: a PULL
carries the client's cached version and the server answers NOT_MODIFIED
when ``policy.needs_refresh(round, version)`` is False (SSP's versioned
stale cache — the client keeps sampling its cache, up to ``bound``
rounds ahead); a refreshing PULL blocks until the barrier has finalized
every earlier round; async pushes apply immediately in arrival order
(Gauss-Seidel, no parity guarantee across process interleavings) and
async pulls never block.  Per-client clocks live server-side; the
read-my-writes lag rides at the client edge (``RemoteParameterServer``
holds each local client's own lag row — the server only ever sees
post-filter deltas, so the pre-filter lag *cannot* be reconstructed
here).

Failure containment: a malformed frame (bad magic, bad version,
oversized/negative length, truncated payload, undecodable npz) raises
:class:`~repro.net.protocol.ProtocolError` inside that connection's
handler thread, which sends a best-effort ERROR frame and closes *that
connection only* — shard state is mutated only after a frame fully
decodes, and only under the server lock, so a fuzzed connection can
never corrupt the store or wedge the barrier for healthy clients.
Blocking waits (barrier pulls, SNAPSHOT/CLOCK with ``min_round``) are
bounded by ``barrier_timeout`` and answer ERROR instead of hanging.
"""

from __future__ import annotations

import argparse
import json
import os
import socket
import threading
import time
from typing import Any

import numpy as np

from repro.core import family as family_mod
from repro.core import projection
from repro.core import server as server_mod
from repro.net import protocol
from repro.net.protocol import MsgType, ProtocolError


def sharded_stat_names(family, stats: dict[str, Any],
                       vocab_size: int) -> tuple[str, ...]:
    """The statistics the wire row-shards: 2-D with a leading vocabulary
    dimension — the same predicate as ``ParameterServer._is_sharded``, so
    both transports partition identically."""
    return tuple(n for n, v in stats.items()
                 if np.ndim(v) == 2 and np.shape(v)[0] == vocab_size)


class _BarrierTimeout(RuntimeError):
    """A bounded server-side wait expired (slow/dead peer)."""


class ShardServer:
    """One row-range shard of the parameter server, served over TCP.

    The server is model-light: it needs the family only for its stat
    *names*, merge rules, and elementwise projection rules — never for
    sampling, alias tables, or evaluation (those are client-side), so a
    server process is cheap and stateless beyond the store.
    """

    def __init__(self, family_name: str, *, vocab_size: int,
                 n_clients: int, rows: tuple[int, int] | None = None,
                 consistency: str = "bsp", project_every: int = 1,
                 host: str = "127.0.0.1", port: int = 0,
                 barrier_timeout: float = 60.0):
        self.family = family_mod.get(family_name)
        if type(self.family).post_round is not family_mod.ModelFamily.post_round:
            raise NotImplementedError(
                f"family {family_name!r} overrides post_round (cross-client "
                "auxiliary resampling needs every client's locals at the "
                "barrier) — not servable over the wire yet; use the "
                "in-process transport")
        self.family_name = family_name
        self.vocab_size = vocab_size
        self.n_clients = n_clients
        self.rows = (0, vocab_size) if rows is None else (int(rows[0]),
                                                          int(rows[1]))
        if not 0 <= self.rows[0] < self.rows[1] <= vocab_size:
            raise ValueError(f"bad row range {self.rows} for V={vocab_size}")
        self.policy = server_mod.make_consistency(consistency)
        self.project_every = project_every
        self.barrier_timeout = barrier_timeout

        self._cond = threading.Condition()
        # Canonical row-sliced store + unsharded aux (merged at INIT,
        # served verbatim — clients re-derive the aggregate entries).
        self._store: dict[str, np.ndarray] | None = None
        self._aux: dict[str, np.ndarray] = {}
        self._sharded: tuple[str, ...] = ()
        self._init_parts: dict[int, tuple[dict, dict]] = {}
        self._pending: dict[int, dict[int, dict[str, np.ndarray]]] = {}
        self._round = 0
        self._clocks = np.zeros((n_clients,), np.int64)
        # Elementwise shared rules whose operands are all row-sharded —
        # the only rules a row-range can apply locally (aggregates are
        # client-side); resolved once the stat names are known.
        self._rules: tuple[projection.Rule, ...] = ()
        self._stop = False
        self._protocol_errors = 0
        self._latency_s: list[float] = []
        self._conn_counters: list[dict[str, Any]] = []
        self._threads: list[threading.Thread] = []

        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(max(16, 2 * n_clients))
        self.address: tuple[str, int] = self._listener.getsockname()[:2]
        self._accept_thread: threading.Thread | None = None

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "ShardServer":
        t = threading.Thread(target=self._accept_loop,
                             name=f"shard-accept-{self.address[1]}",
                             daemon=True)
        t.start()
        self._accept_thread = t
        return self

    def close(self) -> None:
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        try:
            self._listener.close()
        except OSError:
            pass
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)

    def __enter__(self) -> "ShardServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # ----------------------------------------------------------- accept/IO
    def _accept_loop(self) -> None:
        self._listener.settimeout(0.2)
        while not self._stop:
            try:
                sock, _addr = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            t = threading.Thread(target=self._serve_conn, args=(sock,),
                                 daemon=True)
            t.start()
            self._threads.append(t)

    def _serve_conn(self, sock: socket.socket) -> None:
        conn = protocol.FramedConnection(sock)
        try:
            while not self._stop:
                try:
                    mt, meta, arrays = conn.recv()
                except protocol.ConnectionClosed:
                    break
                t0 = time.perf_counter()
                try:
                    reply = self._dispatch(mt, meta, arrays)
                except _BarrierTimeout as e:
                    conn.send(MsgType.ERROR, {"error": str(e)})
                    continue
                except (KeyError, ValueError, TypeError,
                        NotImplementedError) as e:
                    # Well-framed but semantically bad request: tell the
                    # peer why, then drop it — its state machine is off.
                    conn.send(MsgType.ERROR,
                              {"error": f"{type(e).__name__}: {e}"})
                    break
                conn.send(*reply)
                with self._cond:
                    self._latency_s.append(time.perf_counter() - t0)
                if mt is MsgType.SHUTDOWN:
                    with self._cond:
                        self._stop = True
                        self._cond.notify_all()
                    break
        except ProtocolError as e:
            # Malformed frame: the stream can no longer be trusted.  The
            # store was never touched (mutation happens only after a full
            # decode), so only this connection dies.
            with self._cond:
                self._protocol_errors += 1
            try:
                conn.send(MsgType.ERROR, {"error": str(e)})
            except OSError:
                pass
        finally:
            with self._cond:
                self._conn_counters.append(conn.counters())
            conn.close()

    # ------------------------------------------------------------ dispatch
    def _dispatch(self, mt: MsgType, meta: dict, arrays: dict):
        if mt is MsgType.HELLO:
            return self._on_hello(meta)
        if mt is MsgType.INIT:
            return self._on_init(meta, arrays)
        if mt is MsgType.PULL:
            return self._on_pull(meta)
        if mt is MsgType.PULL_KEYS:
            return self._on_pull_keys(meta)
        if mt is MsgType.PUSH:
            return self._on_push(meta, arrays)
        if mt is MsgType.PUSH_SPARSE:
            return self._on_push_sparse(meta, arrays)
        if mt is MsgType.PROJECT:
            with self._cond:
                self._require_store()
                self._project_locked()
            return MsgType.OK, {"server_round": self._round}, None
        if mt is MsgType.SNAPSHOT:
            return self._on_snapshot(meta)
        if mt is MsgType.CLOCK:
            return self._on_clock(meta)
        if mt is MsgType.REJOIN:
            return self._on_rejoin(meta)
        if mt is MsgType.STATS:
            return MsgType.OK, self.stats(), None
        if mt is MsgType.SHUTDOWN:
            return MsgType.OK, {"server_round": self._round}, None
        raise ValueError(f"message type {mt.name} is not a request")

    def _on_hello(self, meta: dict):
        for field, mine in (("family", self.family_name),
                            ("vocab_size", self.vocab_size),
                            ("n_clients", self.n_clients),
                            ("consistency", self.policy.key)):
            theirs = meta.get(field)
            if theirs != mine:
                raise ValueError(
                    f"handshake mismatch on {field}: client says "
                    f"{theirs!r}, server has {mine!r}")
        return MsgType.WELCOME, {
            "rows": list(self.rows),
            "vocab_size": self.vocab_size,
            "n_clients": self.n_clients,
            "consistency": self.policy.key,
            "project_every": self.project_every,
            "server_round": self._round,
        }, None

    def _on_init(self, meta: dict, arrays: dict):
        c = int(meta["client"])
        if not 0 <= c < self.n_clients:
            raise ValueError(f"client id {c} out of range")
        sharded = tuple(meta["sharded"])
        lo, hi = self.rows
        part = {n: arrays[n] for n in sharded}
        for n, v in part.items():
            if v.ndim != 2 or v.shape[0] != hi - lo:
                raise ValueError(
                    f"INIT stat {n!r} has shape {v.shape}; this server "
                    f"owns rows [{lo}, {hi}) and expects ({hi - lo}, K)")
        aux = {n: arrays[n] for n in arrays if n not in sharded}
        with self._cond:
            if self._store is not None:
                raise ValueError("INIT after the store was sealed")
            if self._sharded and self._sharded != sharded:
                raise ValueError(f"INIT sharded-name mismatch: {sharded} "
                                 f"vs {self._sharded}")
            self._sharded = sharded
            self._init_parts[c] = (part, aux)
            if len(self._init_parts) == self.n_clients:
                self._seal_store_locked()
                self._cond.notify_all()
        return MsgType.OK, {"server_round": self._round,
                            "initialized": self._store is not None}, None

    def _seal_store_locked(self) -> None:
        """Merge the per-client initial statistics in ascending client id
        — fold-left, replicated stats from the lowest id — the exact op
        order of ``Trainer._merge_shared``."""
        cids = sorted(self._init_parts)
        part0, aux0 = self._init_parts[cids[0]]
        store = {n: np.array(v) for n, v in part0.items()}
        aux = {n: np.array(v) for n, v in aux0.items()}
        for c in cids[1:]:
            part, auxc = self._init_parts[c]
            for n in store:
                store[n] = store[n] + part[n]
            for n in aux:
                if n in self.family.replicated_stats or aux[n].shape == ():
                    continue
                aux[n] = aux[n] + auxc[n]
        self._store, self._aux = store, aux
        self._init_parts.clear()
        names = set(self._sharded)
        self._rules = tuple(
            r for r in self.family.shared_rules
            if {r.a} | ({r.b} if r.b else set()) <= names)

    def _require_store(self) -> None:
        if self._store is None:
            self._wait_locked(lambda: self._store is not None,
                              "store initialization (INIT barrier)")

    def _wait_locked(self, pred, what: str) -> None:
        deadline = time.monotonic() + self.barrier_timeout
        while not pred():
            if self._stop:
                raise _BarrierTimeout("server is shutting down")
            remaining = deadline - time.monotonic()
            if remaining <= 0 or not self._cond.wait(timeout=remaining):
                raise _BarrierTimeout(
                    f"timed out after {self.barrier_timeout:.1f}s waiting "
                    f"for {what} (server at round {self._round})")

    def _on_pull(self, meta: dict):
        r = int(meta["round"])
        version = meta.get("cached_version")
        with self._cond:
            if self.policy.caches and version is not None \
                    and not self.policy.needs_refresh(r, int(version)):
                # The client's cached version is within the staleness
                # bound: no wait, no payload — the SSP fast path.
                return MsgType.NOT_MODIFIED, {
                    "version": int(version), "server_round": self._round}, None
            self._require_store()
            if not self.policy.immediate:
                # Barrier: a refreshing pull for round r sees the state
                # with every round < r applied.  (A client pulls r before
                # pushing r, so this can never deadlock the barrier.)
                self._wait_locked(lambda: self._round >= r,
                                  f"round barrier {r}")
            arrays = {n: v for n, v in self._store.items()}
            arrays.update(self._aux)
            return MsgType.STATE, {
                "version": r, "server_round": self._round,
                "sharded": list(self._sharded), "rows": list(self.rows),
            }, arrays

    def _on_pull_keys(self, meta: dict):
        with self._cond:
            self._require_store()
            names = meta.get("names") or list(self._sharded)
            lo, hi = self.rows
            glo = int(meta.get("lo", lo))
            ghi = int(meta.get("hi", hi))
            clo, chi = max(glo, lo), min(ghi, hi)
            if clo >= chi:
                arrays = {}
            else:
                arrays = {n: self._store[n][clo - lo:chi - lo]
                          for n in names}
            return MsgType.STATE, {
                "version": self._round, "server_round": self._round,
                "rows": [clo, chi], "sharded": list(names)}, arrays

    def _on_push(self, meta: dict, arrays: dict):
        r, c = int(meta["round"]), int(meta["client"])
        if not 0 <= c < self.n_clients:
            raise ValueError(f"client id {c} out of range")
        lo, hi = self.rows
        with self._cond:
            self._require_store()
            deltas = {}
            for n in self._sharded:
                v = arrays[n]
                if v.shape != self._store[n].shape:
                    raise ValueError(
                        f"PUSH delta {n!r} has shape {v.shape}, store has "
                        f"{self._store[n].shape} (rows [{lo}, {hi}))")
                deltas[n] = v
            return self._apply_push_locked(r, c, deltas)

    def _on_push_sparse(self, meta: dict, arrays: dict):
        """The COO row-sliced push frame (DESIGN.md §12): ``rows`` carries
        shard-local row ids, each delta stat a packed (R, K) value block.

        Every index is validated — integer dtype, 1-D, in-range for this
        shard's row slice, strictly increasing (which implies unique and
        non-negative), value blocks exactly (R, K) — *before* the store is
        touched, under the lock, so a malformed sparse frame answers a
        clean ERROR and leaves the store byte-identical.  The densified
        delta then rides the exact dense-push barrier path: scatter of
        disjoint rows into zeros reconstructs the sender's dense delta
        bit-for-bit, so sparse BSP stays bit-exact with dense BSP.
        """
        r, c = int(meta["round"]), int(meta["client"])
        if not 0 <= c < self.n_clients:
            raise ValueError(f"client id {c} out of range")
        lo, hi = self.rows
        if "rows" not in arrays:
            raise ValueError("PUSH_SPARSE frame is missing the 'rows' array")
        rows = arrays["rows"]
        if rows.ndim != 1 or not np.issubdtype(rows.dtype, np.integer):
            raise ValueError(
                f"PUSH_SPARSE rows must be a 1-D integer array, got "
                f"shape {rows.shape} dtype {rows.dtype}")
        rows = rows.astype(np.int64)
        n_local = hi - lo
        if int(meta.get("n_rows", n_local)) != n_local:
            raise ValueError(
                f"PUSH_SPARSE n_rows={meta.get('n_rows')} does not match "
                f"this shard's row slice [{lo}, {hi})")
        if rows.size and (rows[0] < 0 or rows[-1] >= n_local
                          or np.any(rows < 0)
                          or np.any(rows >= n_local)):
            raise ValueError(
                f"PUSH_SPARSE row index out of range [0, {n_local}) "
                f"(rows [{lo}, {hi}))")
        if rows.size and np.any(np.diff(rows) <= 0):
            raise ValueError(
                "PUSH_SPARSE rows must be strictly increasing (duplicate "
                "or unsorted row indices would mis-apply the scatter-add)")
        with self._cond:
            self._require_store()
            deltas = {}
            for n in self._sharded:
                if n not in arrays:
                    raise ValueError(f"PUSH_SPARSE frame is missing packed "
                                     f"rows for stat {n!r}")
                v = arrays[n]
                want = (rows.size,) + self._store[n].shape[1:]
                if v.shape != want:
                    raise ValueError(
                        f"PUSH_SPARSE values {n!r} have shape {v.shape}; "
                        f"{len(rows)} row indices over store "
                        f"{self._store[n].shape} require {want}")
                dense = np.zeros(self._store[n].shape, v.dtype)
                dense[rows] = v
                deltas[n] = dense
            return self._apply_push_locked(r, c, deltas)

    def _apply_push_locked(self, r: int, c: int,
                           deltas: dict[str, np.ndarray]):
        """Shared tail of the dense and sparse push paths — the policy
        split (async immediate vs barrier buffering) and the ack."""
        if self.policy.immediate:
            # Async: apply on arrival (Gauss-Seidel in arrival order).
            for n in deltas:
                self._store[n] = self._store[n] + deltas[n]
            self._clocks[c] += 1
            done = int(self._clocks.min())
            if self.project_every and done > self._round:
                for m in range(self._round, done):
                    if m % self.project_every == 0:
                        self._project_locked()
                self._round = done
            elif done > self._round:
                self._round = done
            self._cond.notify_all()
        else:
            if r < self._round:
                raise ValueError(
                    f"PUSH for already-finalized round {r} "
                    f"(server at {self._round})")
            slot = self._pending.setdefault(r, {})
            if c in slot:
                raise ValueError(f"duplicate PUSH (round {r}, "
                                 f"client {c})")
            slot[c] = deltas
            self._advance_locked()
        return MsgType.OK, {"server_round": self._round,
                            "round": r, "client": c}, None

    def _advance_locked(self) -> None:
        """Finalize every consecutive complete round: sum the pending
        deltas in ascending client order, apply once, advance clocks,
        project on cadence — the reference loop's barrier, verbatim."""
        while len(self._pending.get(self._round, {})) == self.n_clients:
            r = self._round
            slot = self._pending.pop(r)
            total: dict[str, np.ndarray] | None = None
            for c in sorted(slot):
                d = slot[c]
                total = ({n: np.array(v) for n, v in d.items()}
                         if total is None
                         else {n: total[n] + d[n] for n in total})
            for n in total:
                self._store[n] = self._store[n] + total[n]
            self._clocks += 1
            if self.project_every and r % self.project_every == 0:
                self._project_locked()
            self._round = r + 1
            self._cond.notify_all()

    def _project_locked(self) -> None:
        """The family's elementwise shared rules on the row slices
        (aggregate re-derivation is the client's assembly step)."""
        if not self._rules:
            return
        stats = projection.project(dict(self._store), self._rules)
        self._store = {n: np.asarray(stats[n]) for n in self._store}

    def _on_snapshot(self, meta: dict):
        min_round = int(meta.get("min_round", 0))
        with self._cond:
            self._require_store()
            self._wait_locked(lambda: self._round >= min_round,
                              f"snapshot barrier {min_round}")
            arrays = {n: v for n, v in self._store.items()}
            arrays.update(self._aux)
            return MsgType.STATE, {
                "version": self._round, "server_round": self._round,
                "sharded": list(self._sharded), "rows": list(self.rows),
                "clocks": [int(x) for x in self._clocks]}, arrays

    def _on_clock(self, meta: dict):
        min_round = meta.get("min_round")
        with self._cond:
            if min_round is not None:
                self._wait_locked(lambda: self._round >= int(min_round),
                                  f"clock barrier {min_round}")
            return MsgType.OK, {
                "server_round": self._round,
                "clocks": [int(x) for x in self._clocks]}, None

    def _on_rejoin(self, meta: dict):
        c = int(meta["client"])
        if not 0 <= c < self.n_clients:
            raise ValueError(f"client id {c} out of range")
        with self._cond:
            # Read-my-writes lag lives at the client edge; server-side the
            # rejoin clears any stale pending push the crashed incarnation
            # left in unfinalized rounds (it will re-push after re-pulling).
            for slot in self._pending.values():
                slot.pop(c, None)
            return MsgType.OK, {"server_round": self._round,
                                "client": c}, None

    # -------------------------------------------------------------- admin
    def stats(self) -> dict[str, Any]:
        with self._cond:
            live = [dict(c) for c in self._conn_counters]
            lat = sorted(self._latency_s)

            def pct(p: float) -> float:
                if not lat:
                    return 0.0
                return lat[min(len(lat) - 1,
                               int(round(p * (len(lat) - 1))))] * 1e3

            return {
                "server_round": self._round,
                "rows": list(self.rows),
                "clocks": [int(x) for x in self._clocks],
                "protocol_errors": self._protocol_errors,
                "rpc_count": len(self._latency_s),
                "rpc_p50_ms": pct(0.50),
                "rpc_p99_ms": pct(0.99),
                "bytes_in": sum(c["bytes_in"] for c in live),
                "bytes_out": sum(c["bytes_out"] for c in live),
                "closed_connections": live,
            }


def serve_shards(family_name: str, *, vocab_size: int, n_clients: int,
                 n_shards: int = 1, consistency: str = "bsp",
                 project_every: int = 1, host: str = "127.0.0.1",
                 ports: tuple[int, ...] | None = None,
                 barrier_timeout: float = 60.0) -> list[ShardServer]:
    """Start the ``n_shards`` row-range servers of a balanced
    :class:`~repro.core.server.ShardSpec` partition (one listener each,
    all in this process) and return them running.  Row ranges match the
    in-process ``ShardSpec.rows_of`` exactly, so either transport shards
    the vocabulary identically."""
    spec = server_mod.ShardSpec(vocab_size, n_shards)
    servers = []
    for s in range(n_shards):
        srv = ShardServer(
            family_name, vocab_size=vocab_size, n_clients=n_clients,
            rows=spec.rows_of(s), consistency=consistency,
            project_every=project_every, host=host,
            port=0 if ports is None else ports[s],
            barrier_timeout=barrier_timeout)
        servers.append(srv.start())
    return servers


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="parameter-server shard process (repro.net)")
    ap.add_argument("--family", default="lda")
    ap.add_argument("--vocab-size", type=int, required=True)
    ap.add_argument("--n-clients", type=int, required=True)
    ap.add_argument("--n-shards", type=int, default=1)
    ap.add_argument("--consistency", default="bsp")
    ap.add_argument("--project-every", type=int, default=1)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--barrier-timeout", type=float, default=60.0)
    ap.add_argument("--address-file", default=None,
                    help="write the bound addresses as JSON (the launcher "
                         "polls this instead of parsing stdout)")
    args = ap.parse_args(argv)

    servers = serve_shards(
        args.family, vocab_size=args.vocab_size, n_clients=args.n_clients,
        n_shards=args.n_shards, consistency=args.consistency,
        project_every=args.project_every, host=args.host,
        barrier_timeout=args.barrier_timeout)
    addrs = [f"{h}:{p}" for h, p in (s.address for s in servers)]
    if args.address_file:
        tmp = args.address_file + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"addresses": addrs}, f)
        os.replace(tmp, args.address_file)
    for a in addrs:
        print(f"READY {a}", flush=True)
    try:
        while any(not s._stop for s in servers):
            time.sleep(0.1)
    except KeyboardInterrupt:
        pass
    finally:
        for s in servers:
            s.close()
    for s in servers:
        print(f"STATS {json.dumps({k: v for k, v in s.stats().items() if k != 'closed_connections'})}",
              flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
