"""The parameter-server process role (paper §5.2 over a real transport).

A :class:`ShardServer` hosts one contiguous vocabulary row-range
``[row_lo, row_hi)`` of the shared sufficient statistics over TCP,
speaking :mod:`repro.net.protocol`.  ``serve_shards`` stands up the
``n_shards`` row-range servers of a :class:`repro.core.server.ShardSpec`
partition in one process (one listener + one handler thread per
connection each).

Bit-exactness with the in-process :class:`~repro.core.server.ParameterServer`
is the design constraint, not an afterthought; the store mirrors the
in-process arithmetic exactly:

* the canonical store is the plain dict of row-sliced sharded statistics
  (``n_wk[lo:hi]``, …); every mutation is elementwise, and elementwise
  ops on a row slice equal the same ops on the dense array restricted to
  those rows — so any shard count is bit-exact with the dense pytree,
  the same argument as DESIGN.md §9's sharded store;
* INIT merges per-client initial statistics in **ascending client id**
  (fold-left), the exact order of ``Trainer._merge_shared``;
* pushes buffer per ``(round, client)`` and a round finalizes only when
  all ``n_clients`` deltas are present (the BSP barrier); the round
  total is summed in ascending client order — the op order of the
  reference loop's ``total_delta`` accumulation — then applied once;
* projection applies the family's elementwise shared rules
  (``repro.core.projection``) to the row slices on the ``project_every``
  cadence, right after the round's push — aggregates (n_k, m_k, s_k) are
  **never** stored here; clients re-derive them from the assembled rows,
  which is exactly where the in-process server's ``apply_delta`` /
  ``project`` get them from.

Consistency policies map onto the wire as the ISSUE specifies: a PULL
carries the client's cached version and the server answers NOT_MODIFIED
when ``policy.needs_refresh(round, version)`` is False (SSP's versioned
stale cache — the client keeps sampling its cache, up to ``bound``
rounds ahead); a refreshing PULL blocks until the barrier has finalized
every earlier round; async pushes apply immediately in arrival order
(Gauss-Seidel, no parity guarantee across process interleavings) and
async pulls never block.  Per-client clocks live server-side; the
read-my-writes lag rides at the client edge (``RemoteParameterServer``
holds each local client's own lag row — the server only ever sees
post-filter deltas, so the pre-filter lag *cannot* be reconstructed
here).

Failure containment: a malformed frame (bad magic, bad version,
oversized/negative length, truncated payload, undecodable npz) raises
:class:`~repro.net.protocol.ProtocolError` inside that connection's
handler thread, which sends a best-effort ERROR frame and closes *that
connection only* — shard state is mutated only after a frame fully
decodes, and only under the server lock, so a fuzzed connection can
never corrupt the store or wedge the barrier for healthy clients.
Blocking waits (barrier pulls, SNAPSHOT/CLOCK with ``min_round``) are
bounded by ``barrier_timeout`` and answer ERROR instead of hanging.

Fault tolerance across the wire (DESIGN.md §13) adds three mechanisms:

* **idempotent mutation replay** — every PUSH/PUSH_SPARSE/INIT is a
  *sequenced mutation*: key ``(client, seq)`` with ``seq = round`` for
  pushes and ``-1`` for INIT.  The server keeps a bounded mutation log
  of ``(content digest, recorded reply)`` per key under the store lock;
  a replayed frame whose digest matches returns the recorded ack
  without touching the store (exactly-once application under
  at-least-once delivery), a same-key frame with *different* content is
  a hard error, and a replay-flagged frame for a pruned/finalized round
  acks ``{"ignored": true}``.  This is what makes client-side
  retry-after-reconnect safe on the mutation path — BSP stays bit-exact
  because a retried delta can never double-apply;
* **shard snapshot/restore** — the full barrier state (store, aux,
  pending per-round deltas, ghost markers, clocks, round, eviction set,
  mutation log) persists through :mod:`repro.checkpoint.ckpt` on a
  round cadence and on SNAPSHOT_WRITE; a restarted shard process
  restores it (SNAPSHOT_RESTORE or ``--restore``) and resumes mid-run —
  clients replay their unacked/windowed mutations on reconnect, so
  rounds past the snapshot re-finalize in the identical ascending
  client order;
* **barrier eviction** — handler sockets carry timeouts + SO_KEEPALIVE,
  so a dead peer surfaces as a transport error naming the shard's rows
  and the client ids the connection served.  A client whose every
  connection is gone becomes *suspect*; past the liveness deadline it
  is evicted from the round barrier: rounds finalize from the remaining
  contributors (same ascending-id fold — bit-exact with the in-process
  crash mask) and its SSP clock freezes.  Any later frame from the
  client (HELLO/INIT/PUSH/REJOIN) un-evicts it.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import socket
import threading
import time
from typing import Any

import numpy as np

from repro.checkpoint import ckpt
from repro.core import family as family_mod
from repro.core import projection
from repro.core import server as server_mod
from repro.net import protocol
from repro.net.protocol import MsgType, ProtocolError


def sharded_stat_names(family, stats: dict[str, Any],
                       vocab_size: int) -> tuple[str, ...]:
    """The statistics the wire row-shards: 2-D with a leading vocabulary
    dimension — the same predicate as ``ParameterServer._is_sharded``, so
    both transports partition identically."""
    return tuple(n for n, v in stats.items()
                 if np.ndim(v) == 2 and np.shape(v)[0] == vocab_size)


class _BarrierTimeout(RuntimeError):
    """A bounded server-side wait expired (slow/dead peer)."""


# Finalized rounds whose mutation-log entries are kept for replay dedup;
# older entries answer ``ignored`` to replay-flagged frames.  Must cover
# the client replay window (client.REPLAY_WINDOW) with slack.
MUTLOG_WINDOW = 64

_GHOST_DIGEST = "__ghost__"


def mutation_digest(deltas: dict[str, np.ndarray] | None) -> str:
    """Content digest of a mutation's arrays — the idempotency check.
    Covers names, shapes, dtypes, and raw bytes, so a replayed frame is
    accepted iff it is byte-identical to the recorded application."""
    if deltas is None:
        return _GHOST_DIGEST
    h = hashlib.sha256()
    for n in sorted(deltas):
        v = np.ascontiguousarray(deltas[n])
        h.update(n.encode())
        h.update(str(v.shape).encode())
        h.update(v.dtype.str.encode())
        h.update(v.tobytes())
    return h.hexdigest()


class ShardServer:
    """One row-range shard of the parameter server, served over TCP.

    The server is model-light: it needs the family only for its stat
    *names*, merge rules, and elementwise projection rules — never for
    sampling, alias tables, or evaluation (those are client-side), so a
    server process is cheap and stateless beyond the store.
    """

    def __init__(self, family_name: str, *, vocab_size: int,
                 n_clients: int, rows: tuple[int, int] | None = None,
                 consistency: str = "bsp", project_every: int = 1,
                 host: str = "127.0.0.1", port: int = 0,
                 barrier_timeout: float = 60.0,
                 liveness_timeout: float = 15.0,
                 snapshot_dir: str | None = None,
                 snapshot_every: int = 0,
                 snapshot_name: str = "shard"):
        self.family = family_mod.get(family_name)
        if type(self.family).post_round is not family_mod.ModelFamily.post_round:
            raise NotImplementedError(
                f"family {family_name!r} overrides post_round (cross-client "
                "auxiliary resampling needs every client's locals at the "
                "barrier) — not servable over the wire yet; use the "
                "in-process transport")
        self.family_name = family_name
        self.vocab_size = vocab_size
        self.n_clients = n_clients
        self.rows = (0, vocab_size) if rows is None else (int(rows[0]),
                                                          int(rows[1]))
        if not 0 <= self.rows[0] < self.rows[1] <= vocab_size:
            raise ValueError(f"bad row range {self.rows} for V={vocab_size}")
        self.policy = server_mod.make_consistency(consistency)
        self.project_every = project_every
        self.barrier_timeout = barrier_timeout
        self.liveness_timeout = liveness_timeout
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = int(snapshot_every)
        # Stable per-shard snapshot name: a restarted process serving the
        # same row range finds its own files.
        self._snap_name = f"{snapshot_name}-{self.rows[0]}-{self.rows[1]}"

        self._cond = threading.Condition()
        # Canonical row-sliced store + unsharded aux (merged at INIT,
        # served verbatim — clients re-derive the aggregate entries).
        self._store: dict[str, np.ndarray] | None = None
        self._aux: dict[str, np.ndarray] = {}
        self._sharded: tuple[str, ...] = ()
        self._init_parts: dict[int, tuple[dict, dict]] = {}
        self._pending: dict[int, dict[int, dict[str, np.ndarray]]] = {}
        self._round = 0
        self._clocks = np.zeros((n_clients,), np.int64)
        # Elementwise shared rules whose operands are all row-sharded —
        # the only rules a row-range can apply locally (aggregates are
        # client-side); resolved once the stat names are known.
        self._rules: tuple[projection.Rule, ...] = ()
        # Idempotency: (client, seq) -> (content digest, recorded reply
        # meta).  seq = round for pushes, -1 for INIT; pruned past
        # MUTLOG_WINDOW finalized rounds.  Pending slots may hold None —
        # a ghost push (simulated-fault barrier filler, no delta/clock).
        self._mutlog: dict[tuple[int, int], tuple[str, dict]] = {}
        # Liveness: client -> eviction deadline while every connection
        # that served it is gone; past the deadline the client moves to
        # _evicted and the barrier stops requiring it.
        self._suspects: dict[int, float] = {}
        self._evicted: set[int] = set()
        self._evictions = 0
        self._live_conns: dict[int, set[int]] = {}
        self._conn_seq = 0
        self._snapshots_written = 0
        self._stop = False
        self._protocol_errors = 0
        self._latency_s: list[float] = []
        self._conn_counters: list[dict[str, Any]] = []
        self._threads: list[threading.Thread] = []

        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(max(16, 2 * n_clients))
        self.address: tuple[str, int] = self._listener.getsockname()[:2]
        self._accept_thread: threading.Thread | None = None

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "ShardServer":
        t = threading.Thread(target=self._accept_loop,
                             name=f"shard-accept-{self.address[1]}",
                             daemon=True)
        t.start()
        self._accept_thread = t
        return self

    def close(self) -> None:
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        try:
            self._listener.close()
        except OSError:
            pass
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)

    def __enter__(self) -> "ShardServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # ----------------------------------------------------------- accept/IO
    def _accept_loop(self) -> None:
        self._listener.settimeout(0.2)
        while not self._stop:
            try:
                sock, _addr = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            t = threading.Thread(target=self._serve_conn, args=(sock,),
                                 daemon=True)
            t.start()
            self._threads.append(t)

    def _serve_conn(self, sock: socket.socket) -> None:
        # Per-socket timeout + keepalive: a dead or half-open peer can no
        # longer park this thread in recv_all forever while the barrier
        # waits — it surfaces as a transport error within the liveness
        # deadline, naming this shard and the clients it served.
        try:
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_KEEPALIVE, 1)
        except OSError:
            pass
        sock.settimeout(min(1.0, max(self.liveness_timeout, 0.05)))
        conn = protocol.FramedConnection(sock)
        clients: set[int] = set()
        with self._cond:
            self._conn_seq += 1
            conn_id = self._conn_seq
            self._live_conns[conn_id] = clients
        try:
            while not self._stop:
                try:
                    mt, meta, arrays = conn.recv()
                except protocol.IdleTimeout:
                    # Idle peer is normal; use the tick to run the
                    # liveness sweep for everyone else's dead clients.
                    with self._cond:
                        self._sweep_liveness_locked()
                    continue
                except protocol.ConnectionClosed:
                    break
                except protocol.TransportError as e:
                    raise ProtocolError(
                        f"shard rows {list(self.rows)} lost the connection "
                        f"serving clients {sorted(clients)}: {e}") from e
                self._note_clients(clients, meta)
                t0 = time.perf_counter()
                try:
                    reply = self._dispatch(mt, meta, arrays)
                except _BarrierTimeout as e:
                    conn.send(MsgType.ERROR, {"error": str(e)})
                    continue
                except (KeyError, ValueError, TypeError,
                        NotImplementedError) as e:
                    # Well-framed but semantically bad request: tell the
                    # peer why, then drop it — its state machine is off.
                    conn.send(MsgType.ERROR,
                              {"error": f"{type(e).__name__}: {e}"})
                    break
                conn.send(*reply)
                with self._cond:
                    self._latency_s.append(time.perf_counter() - t0)
                if mt is MsgType.SHUTDOWN:
                    with self._cond:
                        self._stop = True
                        self._cond.notify_all()
                    break
        except ProtocolError as e:
            # Malformed frame or dead transport: the stream can no longer
            # be trusted.  The store was never touched (mutation happens
            # only after a full decode), so only this connection dies.
            with self._cond:
                self._protocol_errors += 1
            try:
                conn.send(MsgType.ERROR, {"error": str(e)})
            except OSError:
                pass
        finally:
            with self._cond:
                self._live_conns.pop(conn_id, None)
                self._mark_suspects_locked(clients)
                self._conn_counters.append(conn.counters())
            conn.close()

    def _note_clients(self, clients: set[int], meta: dict) -> None:
        """Record which client ids this connection serves (HELLO sends
        the full list, mutations name one) and clear their suspect /
        evicted status — any frame from a client proves it is alive."""
        fresh: set[int] = set()
        announced = meta.get("clients")
        if isinstance(announced, (list, tuple)):
            for x in announced:
                try:
                    fresh.add(int(x))
                except (TypeError, ValueError):
                    pass
        if "client" in meta:
            try:
                fresh.add(int(meta["client"]))
            except (TypeError, ValueError):
                pass
        if not fresh:
            return
        clients.update(fresh)
        with self._cond:
            revived = False
            for c in fresh:
                self._suspects.pop(c, None)
                if c in self._evicted:
                    self._evicted.discard(c)
                    revived = True
            if revived:
                self._cond.notify_all()

    # ----------------------------------------------------------- liveness
    def _mark_suspects_locked(self, clients: set[int]) -> None:
        """A connection died: its clients become eviction suspects unless
        another live connection still serves them."""
        still: set[int] = set()
        for s in self._live_conns.values():
            still |= s
        now = time.monotonic()
        for c in clients:
            if c in still or c in self._evicted:
                continue
            self._suspects.setdefault(c, now + self.liveness_timeout)

    def _sweep_liveness_locked(self) -> None:
        """Evict suspects past their deadline: the barrier stops
        requiring them (rounds finalize from the survivors) and their
        clocks freeze — the wire analogue of the in-process crash mask."""
        if not self._suspects:
            return
        now = time.monotonic()
        expired = [c for c, dl in self._suspects.items() if now >= dl]
        if not expired:
            return
        for c in expired:
            del self._suspects[c]
            self._evicted.add(c)
            self._evictions += 1
        if self._store is not None:
            self._advance_locked()
        self._cond.notify_all()

    # ------------------------------------------------------------ dispatch
    def _dispatch(self, mt: MsgType, meta: dict, arrays: dict):
        if mt is MsgType.HELLO:
            return self._on_hello(meta)
        if mt is MsgType.INIT:
            return self._on_init(meta, arrays)
        if mt is MsgType.PULL:
            return self._on_pull(meta)
        if mt is MsgType.PULL_KEYS:
            return self._on_pull_keys(meta)
        if mt is MsgType.PUSH:
            return self._on_push(meta, arrays)
        if mt is MsgType.PUSH_SPARSE:
            return self._on_push_sparse(meta, arrays)
        if mt is MsgType.PROJECT:
            with self._cond:
                self._require_store()
                self._project_locked()
            return MsgType.OK, {"server_round": self._round}, None
        if mt is MsgType.SNAPSHOT:
            return self._on_snapshot(meta)
        if mt is MsgType.SNAPSHOT_WRITE:
            return self._on_snapshot_write(meta)
        if mt is MsgType.SNAPSHOT_RESTORE:
            return self._on_snapshot_restore(meta)
        if mt is MsgType.CLOCK:
            return self._on_clock(meta)
        if mt is MsgType.REJOIN:
            return self._on_rejoin(meta)
        if mt is MsgType.STATS:
            return MsgType.OK, self.stats(), None
        if mt is MsgType.SHUTDOWN:
            return MsgType.OK, {"server_round": self._round}, None
        raise ValueError(f"message type {mt.name} is not a request")

    def _on_hello(self, meta: dict):
        for field, mine in (("family", self.family_name),
                            ("vocab_size", self.vocab_size),
                            ("n_clients", self.n_clients),
                            ("consistency", self.policy.key)):
            theirs = meta.get(field)
            if theirs != mine:
                raise ValueError(
                    f"handshake mismatch on {field}: client says "
                    f"{theirs!r}, server has {mine!r}")
        return MsgType.WELCOME, {
            "rows": list(self.rows),
            "vocab_size": self.vocab_size,
            "n_clients": self.n_clients,
            "consistency": self.policy.key,
            "project_every": self.project_every,
            "server_round": self._round,
        }, None

    def _on_init(self, meta: dict, arrays: dict):
        c = int(meta["client"])
        if not 0 <= c < self.n_clients:
            raise ValueError(f"client id {c} out of range")
        sharded = tuple(meta["sharded"])
        lo, hi = self.rows
        part = {n: arrays[n] for n in sharded}
        for n, v in part.items():
            if v.ndim != 2 or v.shape[0] != hi - lo:
                raise ValueError(
                    f"INIT stat {n!r} has shape {v.shape}; this server "
                    f"owns rows [{lo}, {hi}) and expects ({hi - lo}, K)")
        aux = {n: arrays[n] for n in arrays if n not in sharded}
        digest = mutation_digest(dict(arrays))
        with self._cond:
            rec = self._mutlog.get((c, -1))
            if rec is not None:
                if rec[0] == digest:
                    # Idempotent replay of an already-applied INIT (the
                    # ack was lost, or the client re-replays its buffer
                    # after a reconnect): recorded reply, no mutation.
                    return MsgType.OK, dict(rec[1]), None
                raise ValueError(
                    f"conflicting INIT replay for client {c}: same "
                    "sequence, different content digest")
            if self._store is not None:
                if meta.get("replay"):
                    # Sealed via snapshot restore and the log entry was
                    # not carried (or pruned): the INIT is already folded
                    # into the restored store — acknowledge and ignore.
                    return MsgType.OK, {"server_round": self._round,
                                        "client": c, "ignored": True}, None
                raise ValueError("INIT after the store was sealed")
            if self._sharded and self._sharded != sharded:
                raise ValueError(f"INIT sharded-name mismatch: {sharded} "
                                 f"vs {self._sharded}")
            self._sharded = sharded
            self._init_parts[c] = (part, aux)
            if len(self._init_parts) == self.n_clients:
                self._seal_store_locked()
                self._cond.notify_all()
            reply = {"server_round": self._round,
                     "initialized": self._store is not None, "client": c}
            self._mutlog[(c, -1)] = (digest, reply)
        return MsgType.OK, dict(reply), None

    def _seal_store_locked(self) -> None:
        """Merge the per-client initial statistics in ascending client id
        — fold-left, replicated stats from the lowest id — the exact op
        order of ``Trainer._merge_shared``."""
        cids = sorted(self._init_parts)
        part0, aux0 = self._init_parts[cids[0]]
        store = {n: np.array(v) for n, v in part0.items()}
        aux = {n: np.array(v) for n, v in aux0.items()}
        for c in cids[1:]:
            part, auxc = self._init_parts[c]
            for n in store:
                store[n] = store[n] + part[n]
            for n in aux:
                if n in self.family.replicated_stats or aux[n].shape == ():
                    continue
                aux[n] = aux[n] + auxc[n]
        self._store, self._aux = store, aux
        self._init_parts.clear()
        names = set(self._sharded)
        self._rules = tuple(
            r for r in self.family.shared_rules
            if {r.a} | ({r.b} if r.b else set()) <= names)

    def _require_store(self) -> None:
        if self._store is None:
            self._wait_locked(lambda: self._store is not None,
                              "store initialization (INIT barrier)")

    def _wait_locked(self, pred, what: str) -> None:
        deadline = time.monotonic() + self.barrier_timeout
        while not pred():
            if self._stop:
                raise _BarrierTimeout("server is shutting down")
            # Wake on a short tick so a waiter also runs the liveness
            # sweep — a barrier stalled by a dead client resolves at the
            # eviction deadline, not at barrier_timeout.
            self._sweep_liveness_locked()
            if pred():
                return
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise _BarrierTimeout(
                    f"timed out after {self.barrier_timeout:.1f}s waiting "
                    f"for {what} (server at round {self._round})")
            self._cond.wait(timeout=min(remaining, 0.25))

    def _on_pull(self, meta: dict):
        r = int(meta["round"])
        version = meta.get("cached_version")
        with self._cond:
            if self.policy.caches and version is not None \
                    and not self.policy.needs_refresh(r, int(version)):
                # The client's cached version is within the staleness
                # bound: no wait, no payload — the SSP fast path.
                return MsgType.NOT_MODIFIED, {
                    "version": int(version), "server_round": self._round}, None
            self._require_store()
            if not self.policy.immediate:
                # Barrier: a refreshing pull for round r sees the state
                # with every round < r applied.  (A client pulls r before
                # pushing r, so this can never deadlock the barrier.)
                self._wait_locked(lambda: self._round >= r,
                                  f"round barrier {r}")
            arrays = {n: v for n, v in self._store.items()}
            arrays.update(self._aux)
            return MsgType.STATE, {
                "version": r, "server_round": self._round,
                "sharded": list(self._sharded), "rows": list(self.rows),
            }, arrays

    def _on_pull_keys(self, meta: dict):
        with self._cond:
            self._require_store()
            names = meta.get("names") or list(self._sharded)
            lo, hi = self.rows
            glo = int(meta.get("lo", lo))
            ghi = int(meta.get("hi", hi))
            clo, chi = max(glo, lo), min(ghi, hi)
            if clo >= chi:
                arrays = {}
            else:
                arrays = {n: self._store[n][clo - lo:chi - lo]
                          for n in names}
            return MsgType.STATE, {
                "version": self._round, "server_round": self._round,
                "rows": [clo, chi], "sharded": list(names)}, arrays

    def _on_push(self, meta: dict, arrays: dict):
        r, c = int(meta["round"]), int(meta["client"])
        if not 0 <= c < self.n_clients:
            raise ValueError(f"client id {c} out of range")
        lo, hi = self.rows
        with self._cond:
            self._require_store()
            if meta.get("ghost"):
                # Simulated-fault barrier filler (DESIGN.md §13): fills
                # the client's slot so the round finalizes, but carries
                # no delta and ticks no clock — the wire analogue of the
                # in-process push mask.
                return self._apply_push_locked(
                    r, c, None, replay=bool(meta.get("replay")))
            deltas = {}
            for n in self._sharded:
                v = arrays[n]
                if v.shape != self._store[n].shape:
                    raise ValueError(
                        f"PUSH delta {n!r} has shape {v.shape}, store has "
                        f"{self._store[n].shape} (rows [{lo}, {hi}))")
                deltas[n] = v
            return self._apply_push_locked(
                r, c, deltas, replay=bool(meta.get("replay")))

    def _on_push_sparse(self, meta: dict, arrays: dict):
        """The COO row-sliced push frame (DESIGN.md §12): ``rows`` carries
        shard-local row ids, each delta stat a packed (R, K) value block.

        Every index is validated — integer dtype, 1-D, in-range for this
        shard's row slice, strictly increasing (which implies unique and
        non-negative), value blocks exactly (R, K) — *before* the store is
        touched, under the lock, so a malformed sparse frame answers a
        clean ERROR and leaves the store byte-identical.  The densified
        delta then rides the exact dense-push barrier path: scatter of
        disjoint rows into zeros reconstructs the sender's dense delta
        bit-for-bit, so sparse BSP stays bit-exact with dense BSP.
        """
        r, c = int(meta["round"]), int(meta["client"])
        if not 0 <= c < self.n_clients:
            raise ValueError(f"client id {c} out of range")
        lo, hi = self.rows
        if "rows" not in arrays:
            raise ValueError("PUSH_SPARSE frame is missing the 'rows' array")
        rows = arrays["rows"]
        if rows.ndim != 1 or not np.issubdtype(rows.dtype, np.integer):
            raise ValueError(
                f"PUSH_SPARSE rows must be a 1-D integer array, got "
                f"shape {rows.shape} dtype {rows.dtype}")
        rows = rows.astype(np.int64)
        n_local = hi - lo
        if int(meta.get("n_rows", n_local)) != n_local:
            raise ValueError(
                f"PUSH_SPARSE n_rows={meta.get('n_rows')} does not match "
                f"this shard's row slice [{lo}, {hi})")
        if rows.size and (rows[0] < 0 or rows[-1] >= n_local
                          or np.any(rows < 0)
                          or np.any(rows >= n_local)):
            raise ValueError(
                f"PUSH_SPARSE row index out of range [0, {n_local}) "
                f"(rows [{lo}, {hi}))")
        if rows.size and np.any(np.diff(rows) <= 0):
            raise ValueError(
                "PUSH_SPARSE rows must be strictly increasing (duplicate "
                "or unsorted row indices would mis-apply the scatter-add)")
        with self._cond:
            self._require_store()
            deltas = {}
            for n in self._sharded:
                if n not in arrays:
                    raise ValueError(f"PUSH_SPARSE frame is missing packed "
                                     f"rows for stat {n!r}")
                v = arrays[n]
                want = (rows.size,) + self._store[n].shape[1:]
                if v.shape != want:
                    raise ValueError(
                        f"PUSH_SPARSE values {n!r} have shape {v.shape}; "
                        f"{len(rows)} row indices over store "
                        f"{self._store[n].shape} require {want}")
                dense = np.zeros(self._store[n].shape, v.dtype)
                dense[rows] = v
                deltas[n] = dense
            return self._apply_push_locked(
                r, c, deltas, replay=bool(meta.get("replay")))

    def _apply_push_locked(self, r: int, c: int,
                           deltas: dict[str, np.ndarray] | None, *,
                           replay: bool = False):
        """Shared tail of the dense, sparse, and ghost push paths — the
        idempotency check (mutation log), the policy split (async
        immediate vs barrier buffering), and the ack.

        Dedup rule (DESIGN.md §13): the sequence number of a push *is*
        its round, so the log key is (client, round).  A key hit with a
        matching content digest returns the recorded ack — the frame was
        already applied and the retry is the lost ack coming back; a hit
        with a different digest is a protocol violation (two different
        deltas claiming one sequence slot); a miss for an
        already-finalized round is stale — rejected, unless the client
        flagged it as a buffered replay (reconnect catch-up), which acks
        ``ignored`` because the finalized store already contains it.
        """
        digest = mutation_digest(deltas)
        rec = self._mutlog.get((c, r))
        if rec is not None:
            if rec[0] == digest:
                return MsgType.OK, dict(rec[1]), None
            raise ValueError(
                f"conflicting PUSH replay (round {r}, client {c}): same "
                "sequence number, different delta digest")
        if self.policy.immediate:
            # Async: apply on arrival (Gauss-Seidel in arrival order).
            if deltas is not None:
                for n in deltas:
                    self._store[n] = self._store[n] + deltas[n]
                self._clocks[c] += 1
            mask = self._clock_mask_locked()
            done = int(self._clocks[mask].min()) if mask.any() \
                else self._round
            if self.project_every and done > self._round:
                for m in range(self._round, done):
                    if m % self.project_every == 0:
                        self._project_locked()
                self._round = done
            elif done > self._round:
                self._round = done
            reply = {"server_round": self._round, "round": r, "client": c}
            self._mutlog[(c, r)] = (digest, reply)
            self._prune_mutlog_locked()
            self._cond.notify_all()
            return MsgType.OK, dict(reply), None
        if r < self._round:
            if replay:
                return MsgType.OK, {"server_round": self._round,
                                    "round": r, "client": c,
                                    "ignored": True}, None
            raise ValueError(
                f"PUSH for already-finalized round {r} "
                f"(server at {self._round})")
        slot = self._pending.setdefault(r, {})
        if c in slot:
            # Unreachable while the mutation log covers pending rounds
            # (it is pruned only below the finalized horizon) — keep the
            # old invariant as a backstop.
            raise ValueError(f"duplicate PUSH (round {r}, client {c})")
        slot[c] = deltas
        reply = {"server_round": self._round, "round": r, "client": c}
        self._mutlog[(c, r)] = (digest, reply)
        self._advance_locked()
        reply["server_round"] = self._round
        return MsgType.OK, dict(reply), None

    def _clock_mask_locked(self) -> np.ndarray:
        """Clients whose clocks still gate round advancement — everyone
        not evicted (an evicted client's frozen clock must not hold the
        async round back forever)."""
        mask = np.ones((self.n_clients,), bool)
        for c in self._evicted:
            mask[c] = False
        return mask

    def _required_locked(self) -> list[int]:
        """The barrier's required contributor set: every non-evicted
        client."""
        return [c for c in range(self.n_clients)
                if c not in self._evicted]

    def _advance_locked(self) -> None:
        """Finalize every consecutive complete round: sum the pending
        deltas in ascending client order, apply once, advance the
        contributors' clocks, project on cadence — the reference loop's
        barrier, verbatim.  A round is complete when every *required*
        (non-evicted) client has a slot; ghost slots (None) count for
        completeness but contribute no delta and tick no clock, exactly
        the in-process push mask."""
        while True:
            required = self._required_locked()
            slot = self._pending.get(self._round)
            if not required or slot is None \
                    or not all(c in slot for c in required):
                break
            r = self._round
            slot = self._pending.pop(r)
            contributors = [c for c in sorted(slot) if slot[c] is not None]
            total: dict[str, np.ndarray] | None = None
            for c in contributors:
                d = slot[c]
                total = ({n: np.array(v) for n, v in d.items()}
                         if total is None
                         else {n: total[n] + d[n] for n in total})
            if total is not None:
                for n in total:
                    self._store[n] = self._store[n] + total[n]
            for c in contributors:
                self._clocks[c] += 1
            if self.project_every and r % self.project_every == 0:
                self._project_locked()
            self._round = r + 1
            self._prune_mutlog_locked()
            if self.snapshot_dir and self.snapshot_every \
                    and self._round % self.snapshot_every == 0:
                self._snapshot_locked(self.snapshot_dir, self._round)
            self._cond.notify_all()

    def _prune_mutlog_locked(self) -> None:
        horizon = self._round - MUTLOG_WINDOW
        if horizon <= 0:
            return
        stale = [k for k in self._mutlog if 0 <= k[1] < horizon]
        for k in stale:
            del self._mutlog[k]

    def _project_locked(self) -> None:
        """The family's elementwise shared rules on the row slices
        (aggregate re-derivation is the client's assembly step)."""
        if not self._rules:
            return
        stats = projection.project(dict(self._store), self._rules)
        self._store = {n: np.asarray(stats[n]) for n in self._store}

    def _on_snapshot(self, meta: dict):
        min_round = int(meta.get("min_round", 0))
        with self._cond:
            self._require_store()
            self._wait_locked(lambda: self._round >= min_round,
                              f"snapshot barrier {min_round}")
            arrays = {n: v for n, v in self._store.items()}
            arrays.update(self._aux)
            return MsgType.STATE, {
                "version": self._round, "server_round": self._round,
                "sharded": list(self._sharded), "rows": list(self.rows),
                "clocks": [int(x) for x in self._clocks]}, arrays

    def _on_clock(self, meta: dict):
        min_round = meta.get("min_round")
        with self._cond:
            if min_round is not None:
                self._wait_locked(lambda: self._round >= int(min_round),
                                  f"clock barrier {min_round}")
            return MsgType.OK, {
                "server_round": self._round,
                "clocks": [int(x) for x in self._clocks]}, None

    def _on_rejoin(self, meta: dict):
        c = int(meta["client"])
        if not 0 <= c < self.n_clients:
            raise ValueError(f"client id {c} out of range")
        action = meta.get("action", "join")
        with self._cond:
            if action == "leave":
                # Voluntary elastic leave: same effect as liveness
                # eviction, but immediate — the barrier stops requiring
                # the client and its clock freezes until it rejoins.
                self._suspects.pop(c, None)
                if c not in self._evicted:
                    self._evicted.add(c)
                    self._evictions += 1
                if self._store is not None:
                    self._advance_locked()
                self._cond.notify_all()
                return MsgType.OK, {"server_round": self._round,
                                    "client": c, "evicted": True}, None
            # Read-my-writes lag lives at the client edge; server-side the
            # rejoin clears any stale pending push the crashed incarnation
            # left in unfinalized rounds (it will re-push after re-pulling)
            # and drops the matching mutation-log entries so the fresh
            # incarnation's different delta is not a digest conflict.
            self._suspects.pop(c, None)
            if c in self._evicted:
                self._evicted.discard(c)
            for slot in self._pending.values():
                slot.pop(c, None)
            for k in [k for k in self._mutlog
                      if k[0] == c and k[1] >= self._round]:
                del self._mutlog[k]
            self._cond.notify_all()
            return MsgType.OK, {"server_round": self._round,
                                "client": c}, None

    # ----------------------------------------------------- snapshot/restore
    def _snapshot_locked(self, directory: str, step: int) -> str:
        """Persist the full barrier state as one flat npz through
        :mod:`repro.checkpoint.ckpt` (write-then-rename, step history).
        Arrays carry the heavy state (store, aux, pending deltas); one
        JSON blob carries everything else (round, clocks, eviction set,
        ghost markers, mutation log) so a restarted shard resumes with
        replay dedup intact."""
        flat: dict[str, np.ndarray] = {}
        for n, v in self._store.items():
            flat[f"store/{n}"] = v
        for n, v in self._aux.items():
            flat[f"aux/{n}"] = v
        ghosts: list[list[int]] = []
        for r, slot in self._pending.items():
            for c, d in slot.items():
                if d is None:
                    ghosts.append([int(r), int(c)])
                else:
                    for n, v in d.items():
                        flat[f"pending/{r}/{c}/{n}"] = v
        blob = {
            "family": self.family_name,
            "vocab_size": self.vocab_size,
            "n_clients": self.n_clients,
            "consistency": self.policy.key,
            "rows": list(self.rows),
            "round": int(self._round),
            "clocks": [int(x) for x in self._clocks],
            "sharded": list(self._sharded),
            "evicted": sorted(int(c) for c in self._evicted),
            "ghosts": ghosts,
            "mutlog": [[int(c), int(s), dg, dict(rm)]
                       for (c, s), (dg, rm) in self._mutlog.items()],
        }
        flat["__meta__"] = np.frombuffer(
            json.dumps(blob).encode("utf-8"), np.uint8).copy()
        path = ckpt.save(directory, self._snap_name, step, flat)
        self._snapshots_written += 1
        return path

    def snapshot_to(self, directory: str | None = None,
                    step: int | None = None) -> str:
        directory = directory or self.snapshot_dir
        if not directory:
            raise ValueError("no snapshot directory configured")
        with self._cond:
            self._require_store()
            return self._snapshot_locked(
                directory, self._round if step is None else int(step))

    def restore_from(self, directory: str | None = None,
                     step: int | None = None) -> int:
        """Reload the shard's state from the newest readable snapshot and
        resume serving mid-run.  Validates identity (family, vocab,
        n_clients, consistency, row range) semantically — the snapshot is
        read template-free because a fresh process has no sealed store to
        validate against."""
        directory = directory or self.snapshot_dir
        if not directory:
            raise ValueError("no snapshot directory configured")
        step, flat = ckpt.load_raw(directory, self._snap_name, step)
        raw = flat.pop("__meta__", None)
        if raw is None:
            raise ValueError(
                f"snapshot {self._snap_name} step {step} has no __meta__ "
                "blob — not a shard-server snapshot")
        blob = json.loads(bytes(raw.tobytes()).decode("utf-8"))
        for field, mine in (("family", self.family_name),
                            ("vocab_size", self.vocab_size),
                            ("n_clients", self.n_clients),
                            ("consistency", self.policy.key),
                            ("rows", list(self.rows))):
            theirs = blob.get(field)
            if theirs != mine:
                raise ValueError(
                    f"snapshot identity mismatch on {field}: snapshot "
                    f"has {theirs!r}, server has {mine!r}")
        store: dict[str, np.ndarray] = {}
        aux: dict[str, np.ndarray] = {}
        pending: dict[int, dict[int, dict[str, np.ndarray] | None]] = {}
        for key, v in flat.items():
            if key.startswith("store/"):
                store[key[len("store/"):]] = np.array(v)
            elif key.startswith("aux/"):
                aux[key[len("aux/"):]] = np.array(v)
            elif key.startswith("pending/"):
                _, r, c, n = key.split("/", 3)
                pending.setdefault(int(r), {}).setdefault(
                    int(c), {})[n] = np.array(v)
            else:
                raise ValueError(f"unknown snapshot leaf {key!r}")
        for r, c in blob.get("ghosts", []):
            pending.setdefault(int(r), {})[int(c)] = None
        with self._cond:
            self._store = store
            self._aux = aux
            self._sharded = tuple(blob["sharded"])
            self._pending = pending
            self._round = int(blob["round"])
            self._clocks = np.asarray(blob["clocks"], np.int64)
            self._evicted = set(int(c) for c in blob.get("evicted", []))
            self._suspects.clear()
            self._mutlog = {(int(c), int(s)): (dg, dict(rm))
                            for c, s, dg, rm in blob.get("mutlog", [])}
            self._init_parts.clear()
            names = set(self._sharded)
            self._rules = tuple(
                r for r in self.family.shared_rules
                if {r.a} | ({r.b} if r.b else set()) <= names)
            self._cond.notify_all()
            return self._round

    def _on_snapshot_write(self, meta: dict):
        directory = meta.get("directory") or self.snapshot_dir
        if not directory:
            raise ValueError(
                "SNAPSHOT_WRITE needs meta['directory'] (the server has "
                "no --snapshot-dir configured)")
        with self._cond:
            self._require_store()
            step = self._round if meta.get("step") is None \
                else int(meta["step"])
            path = self._snapshot_locked(directory, step)
        return MsgType.OK, {"server_round": self._round, "step": step,
                            "name": self._snap_name,
                            "path": os.path.basename(path)}, None

    def _on_snapshot_restore(self, meta: dict):
        directory = meta.get("directory") or self.snapshot_dir
        if not directory:
            raise ValueError(
                "SNAPSHOT_RESTORE needs meta['directory'] (the server "
                "has no --snapshot-dir configured)")
        step = None if meta.get("step") is None else int(meta["step"])
        try:
            restored = self.restore_from(directory, step)
        except (FileNotFoundError, ckpt.CorruptSnapshotError) as e:
            raise ValueError(f"restore failed: {e}") from e
        return MsgType.OK, {"server_round": restored,
                            "name": self._snap_name}, None

    def round_reached(self, n: int) -> bool:
        with self._cond:
            return self._round >= n

    # -------------------------------------------------------------- admin
    def stats(self) -> dict[str, Any]:
        with self._cond:
            live = [dict(c) for c in self._conn_counters]
            lat = sorted(self._latency_s)

            def pct(p: float) -> float:
                if not lat:
                    return 0.0
                return lat[min(len(lat) - 1,
                               int(round(p * (len(lat) - 1))))] * 1e3

            return {
                "server_round": self._round,
                "rows": list(self.rows),
                "clocks": [int(x) for x in self._clocks],
                "evicted": sorted(int(c) for c in self._evicted),
                "suspects": sorted(int(c) for c in self._suspects),
                "evictions": self._evictions,
                "mutlog_entries": len(self._mutlog),
                "snapshots_written": self._snapshots_written,
                "protocol_errors": self._protocol_errors,
                "rpc_count": len(self._latency_s),
                "rpc_p50_ms": pct(0.50),
                "rpc_p99_ms": pct(0.99),
                "bytes_in": sum(c["bytes_in"] for c in live),
                "bytes_out": sum(c["bytes_out"] for c in live),
                "closed_connections": live,
            }


def serve_shards(family_name: str, *, vocab_size: int, n_clients: int,
                 n_shards: int = 1, consistency: str = "bsp",
                 project_every: int = 1, host: str = "127.0.0.1",
                 ports: tuple[int, ...] | None = None,
                 barrier_timeout: float = 60.0,
                 liveness_timeout: float = 15.0,
                 snapshot_dir: str | None = None,
                 snapshot_every: int = 0,
                 restore: bool = False) -> list[ShardServer]:
    """Start the ``n_shards`` row-range servers of a balanced
    :class:`~repro.core.server.ShardSpec` partition (one listener each,
    all in this process) and return them running.  Row ranges match the
    in-process ``ShardSpec.rows_of`` exactly, so either transport shards
    the vocabulary identically.  With ``restore`` each shard reloads its
    latest snapshot from ``snapshot_dir`` before serving (the restarted
    shard-process path)."""
    spec = server_mod.ShardSpec(vocab_size, n_shards)
    servers = []
    for s in range(n_shards):
        srv = ShardServer(
            family_name, vocab_size=vocab_size, n_clients=n_clients,
            rows=spec.rows_of(s), consistency=consistency,
            project_every=project_every, host=host,
            port=0 if ports is None else ports[s],
            barrier_timeout=barrier_timeout,
            liveness_timeout=liveness_timeout,
            snapshot_dir=snapshot_dir, snapshot_every=snapshot_every)
        if restore:
            srv.restore_from(snapshot_dir)
        servers.append(srv.start())
    return servers


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="parameter-server shard process (repro.net)")
    ap.add_argument("--family", default="lda")
    ap.add_argument("--vocab-size", type=int, required=True)
    ap.add_argument("--n-clients", type=int, required=True)
    ap.add_argument("--n-shards", type=int, default=1)
    ap.add_argument("--consistency", default="bsp")
    ap.add_argument("--project-every", type=int, default=1)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--barrier-timeout", type=float, default=60.0)
    ap.add_argument("--liveness-timeout", type=float, default=15.0,
                    help="evict a client from the round barrier this many "
                         "seconds after its last connection died")
    ap.add_argument("--snapshot-dir", default=None)
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="persist shard state every N finalized rounds "
                         "(0 = only on SNAPSHOT_WRITE)")
    ap.add_argument("--restore", action="store_true",
                    help="reload the latest snapshot from --snapshot-dir "
                         "before serving (shard-process restart)")
    ap.add_argument("--ports", default=None,
                    help="comma-separated listen ports, one per shard — a "
                         "restarted process must rebind its published "
                         "addresses")
    ap.add_argument("--die-after-round", type=int, default=None,
                    help="exit(42) once every shard reaches this round "
                         "(deterministic kill point for failover tests)")
    ap.add_argument("--address-file", default=None,
                    help="write the bound addresses as JSON (the launcher "
                         "polls this instead of parsing stdout)")
    args = ap.parse_args(argv)

    ports = None
    if args.ports:
        ports = tuple(int(p) for p in args.ports.split(","))
        if len(ports) != args.n_shards:
            ap.error(f"--ports names {len(ports)} ports for "
                     f"{args.n_shards} shards")
    servers = serve_shards(
        args.family, vocab_size=args.vocab_size, n_clients=args.n_clients,
        n_shards=args.n_shards, consistency=args.consistency,
        project_every=args.project_every, host=args.host, ports=ports,
        barrier_timeout=args.barrier_timeout,
        liveness_timeout=args.liveness_timeout,
        snapshot_dir=args.snapshot_dir,
        snapshot_every=args.snapshot_every, restore=args.restore)
    addrs = [f"{h}:{p}" for h, p in (s.address for s in servers)]
    if args.address_file:
        tmp = args.address_file + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"addresses": addrs}, f)
        os.replace(tmp, args.address_file)
    for a in addrs:
        print(f"READY {a}", flush=True)
    try:
        while any(not s._stop for s in servers):
            if args.die_after_round is not None and all(
                    s.round_reached(args.die_after_round)
                    for s in servers):
                # round_reached takes the store lock, so the round-N
                # snapshot (written under the same lock) is complete
                # before the kill fires.
                print(f"DYING round {args.die_after_round}", flush=True)
                os._exit(42)
            time.sleep(0.1)
    except KeyboardInterrupt:
        pass
    finally:
        for s in servers:
            s.close()
    for s in servers:
        print(f"STATS {json.dumps({k: v for k, v in s.stats().items() if k != 'closed_connections'})}",
              flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
