"""Framed binary wire protocol for the out-of-process parameter server.

Frame layout (DESIGN.md §11) — a fixed 16-byte header followed by a
length-prefixed payload:

    offset  size  field
    ------  ----  -----------------------------------------------
       0      4   magic cookie ``b"LVPS"``
       4      1   protocol version (u8, currently 1)
       5      1   message type (u8, :class:`MsgType`)
       6      2   flags (u16 big-endian, reserved — must be 0)
       8      8   payload length (i64 big-endian, signed on purpose:
                  a negative length must be *representable* so it can
                  be rejected, not wrap into a huge read)
      16      n   payload

The payload of an array-carrying message is itself framed:

    u32 meta_len | meta (UTF-8 JSON) | npz bytes (``numpy.savez``)

so every message carries a small JSON metadata dict (round indices,
client ids, versions, error text) plus zero or more named numpy arrays.
JSON for control fields keeps the protocol debuggable on the wire; npz
for bulk keeps the (V, K) count matrices binary and exact (bit-exactness
across the socket is an acceptance criterion — no text round-trips of
floats).

Error contract: every malformed input — truncated header, bad magic,
unsupported version, oversized or negative length, mid-payload
disconnect, undecodable payload — raises :class:`ProtocolError` (or its
subclass :class:`ConnectionClosed` for a clean EOF *between* frames).
Peers catch it, optionally emit a best-effort :data:`MsgType.ERROR`
frame, and close the connection.  Nothing here blocks forever on a bad
frame and nothing mutates shard state before a frame fully decodes.
"""

from __future__ import annotations

import enum
import io
import json
import socket
import struct
import time
from typing import Any

import numpy as np

MAGIC = b"LVPS"
PROTOCOL_VERSION = 1

# magic(4s) version(B) msg_type(B) flags(H) length(q) — network byte order.
HEADER = struct.Struct("!4sBBHq")
HEADER_SIZE = HEADER.size  # 16

# Hard payload ceiling: generous for (V, K) count matrices at any size this
# repo runs, small enough that a corrupt length field can't trigger a
# multi-GiB allocation before being rejected.
MAX_PAYLOAD = 1 << 30

_META_LEN = struct.Struct("!I")


class ProtocolError(RuntimeError):
    """A malformed frame or protocol violation.  The connection that
    raised it must be considered dead: close it.  Server shard state is
    never touched before a frame fully decodes, so a ProtocolError on one
    connection cannot corrupt the store."""


class ConnectionClosed(ProtocolError):
    """The peer closed the socket at a frame boundary (clean EOF).
    Subclass of :class:`ProtocolError` so generic handlers close the
    connection either way, but distinguishable: EOF *inside* a frame is a
    plain ProtocolError (truncation)."""


class TransportError(ProtocolError):
    """The *network* failed (reset, timeout mid-read, EOF inside a
    frame), as opposed to a malformed frame or a semantic refusal.  The
    distinction drives the client's retry policy (DESIGN.md §13): a
    TransportError is safely retryable through the idempotent-replay
    path, a peer ERROR frame or a corrupt frame is not."""


class IdleTimeout(TransportError):
    """The socket timed out at a frame boundary with zero bytes read —
    the peer may be healthy but silent.  Servers use this as the liveness
    sweep tick; clients treat it like any other TransportError."""


class MsgType(enum.IntEnum):
    """Message-type registry (DESIGN.md §11).  Values are wire-stable:
    append only, never renumber."""

    HELLO = 1          # client → server: handshake (family, n_clients, …)
    WELCOME = 2        # server → client: handshake accept + server config
    INIT = 3           # client → server: per-client initial local stats
    PULL = 4           # client → server: versioned cache refresh request
    STATE = 5          # server → client: fresh snapshot (version, arrays)
    NOT_MODIFIED = 6   # server → client: cached version within bound
    PUSH = 7           # client → server: delta frame for a round
    OK = 8             # server → client: generic ack
    PROJECT = 9        # client → server: request constraint projection
    SNAPSHOT = 10      # client → server: admin/eval canonical state
    CLOCK = 11         # client → server: per-client clocks / barrier wait
    REJOIN = 12        # client → server: elastic rejoin (reset lag row)
    STATS = 13         # client → server: per-connection counters
    SHUTDOWN = 14      # client → server: stop serving after reply
    ERROR = 15         # server → client: request failed (meta["error"])
    PULL_KEYS = 16     # client → server: addressed shard-local row slices
    PUSH_SPARSE = 17   # client → server: COO row-sliced delta frame —
    #                    arrays carry "rows" (u32/i32 shard-local row ids,
    #                    strictly increasing, unique) plus one packed
    #                    (R, K) value matrix per delta statistic; meta
    #                    carries round/client plus "sparse" (stat names)
    #                    and "n_rows" (the shard's dense row count, so the
    #                    server can cross-check before scatter-adding).
    SNAPSHOT_WRITE = 18    # client → server: persist shard state to disk
    #                        (meta: directory, optional step) → OK with
    #                        the written step; admin path, DESIGN.md §13.
    SNAPSHOT_RESTORE = 19  # client → server: reload shard state from a
    #                        snapshot (meta: directory, optional step) →
    #                        OK with the restored round; also taken by a
    #                        restarted shard process before serving.
    INFER = 20         # client → inference server: fold one document in —
    #                    meta {"uid": int, "seed": int}, arrays
    #                    {"tokens": (L,) int32}; answered by INFER_RESULT
    #                    (or ERROR: bad doc / queue overflow load-shed).
    #                    DESIGN.md §14.
    INFER_RESULT = 21  # inference server → client: meta {"uid",
    #                    "n_sweeps"}, arrays {"theta": (K,) float32,
    #                    "assignments": (doc_len,) int32}.


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise ProtocolError(msg)


def pack_payload(meta: dict[str, Any],
                 arrays: dict[str, np.ndarray] | None = None) -> bytes:
    """``meta`` JSON dict + named numpy arrays → payload bytes
    (``u32 meta_len | JSON | npz``)."""
    meta_bytes = json.dumps(meta, separators=(",", ":")).encode("utf-8")
    buf = io.BytesIO()
    np.savez(buf, **{k: np.asarray(v) for k, v in (arrays or {}).items()})
    return _META_LEN.pack(len(meta_bytes)) + meta_bytes + buf.getvalue()


def unpack_payload(payload: bytes) -> tuple[dict[str, Any],
                                            dict[str, np.ndarray]]:
    """Payload bytes → (meta dict, arrays dict).  Raises
    :class:`ProtocolError` on any undecodable byte."""
    _require(len(payload) >= _META_LEN.size,
             f"payload too short for meta length ({len(payload)} bytes)")
    (meta_len,) = _META_LEN.unpack_from(payload, 0)
    _require(_META_LEN.size + meta_len <= len(payload),
             f"meta length {meta_len} exceeds payload ({len(payload)} bytes)")
    try:
        meta = json.loads(payload[_META_LEN.size:_META_LEN.size + meta_len]
                          .decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ProtocolError(f"undecodable meta JSON: {e}") from e
    _require(isinstance(meta, dict), "meta must be a JSON object")
    npz_bytes = payload[_META_LEN.size + meta_len:]
    arrays: dict[str, np.ndarray] = {}
    if npz_bytes:
        try:
            with np.load(io.BytesIO(npz_bytes), allow_pickle=False) as data:
                arrays = {k: data[k] for k in data.files}
        except Exception as e:  # zipfile/zlib/ValueError zoo — see ckpt.py
            raise ProtocolError(f"undecodable npz section: "
                                f"{type(e).__name__}: {e}") from e
    return meta, arrays


def pack_frame(msg_type: MsgType, meta: dict[str, Any],
               arrays: dict[str, np.ndarray] | None = None) -> bytes:
    payload = pack_payload(meta, arrays)
    return HEADER.pack(MAGIC, PROTOCOL_VERSION, int(msg_type), 0,
                       len(payload)) + payload


def recv_all(sock: socket.socket, n: int, *,
             at_boundary: bool = False) -> bytes:
    """Read exactly ``n`` bytes or raise.

    EOF before the first byte of a frame is a clean close
    (:class:`ConnectionClosed`, when ``at_boundary``); a socket timeout
    there with zero bytes is :class:`IdleTimeout` (the liveness-sweep
    tick); EOF or a socket error anywhere else is a
    :class:`TransportError` (truncation — retryable by clients).
    ``recv`` may return short reads at any time — this loop is the
    exact-read discipline the whole protocol rests on."""
    chunks: list[bytes] = []
    got = 0
    while got < n:
        try:
            chunk = sock.recv(min(n - got, 1 << 20))
        except (socket.timeout, TimeoutError) as e:
            if at_boundary and got == 0:
                raise IdleTimeout("idle at frame boundary") from e
            raise TransportError(f"socket timeout after {got}/{n} bytes"
                                 ) from e
        except (ConnectionResetError, BrokenPipeError, OSError) as e:
            raise TransportError(f"socket error after {got}/{n} bytes: "
                                 f"{type(e).__name__}") from e
        if not chunk:
            if at_boundary and got == 0:
                raise ConnectionClosed("peer closed connection")
            raise TransportError(
                f"connection closed mid-read ({got}/{n} bytes)")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def _validate_header(header: bytes) -> tuple[MsgType, int]:
    """Header bytes → (message type, payload length).  Every field is
    validated before a single payload byte is read."""
    magic, version, msg_type, flags, length = HEADER.unpack(header)
    _require(magic == MAGIC,
             f"bad magic cookie {magic!r} (expected {MAGIC!r})")
    _require(version == PROTOCOL_VERSION,
             f"unsupported protocol version {version} "
             f"(speaking {PROTOCOL_VERSION})")
    _require(flags == 0, f"nonzero reserved flags 0x{flags:04x}")
    try:
        mt = MsgType(msg_type)
    except ValueError:
        raise ProtocolError(f"unknown message type {msg_type}") from None
    _require(length >= 0, f"negative payload length {length}")
    _require(length <= MAX_PAYLOAD,
             f"payload length {length} exceeds MAX_PAYLOAD {MAX_PAYLOAD}")
    return mt, length


def read_frame(sock: socket.socket) -> tuple[MsgType, dict[str, Any],
                                             dict[str, np.ndarray]]:
    """Read one complete frame: validates magic, version, type, and
    length before a single payload byte is interpreted."""
    mt, length = _validate_header(recv_all(sock, HEADER_SIZE,
                                           at_boundary=True))
    meta, arrays = unpack_payload(recv_all(sock, length))
    return mt, meta, arrays


class FramedConnection:
    """A socket speaking the framed protocol, with per-connection
    counters (bytes in/out, RPC count, per-RPC latency) — the
    observability surface the bench artifact reports."""

    def __init__(self, sock: socket.socket):
        self.sock = sock
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass
        # encoded bytes: everything on the wire, headers included.
        self.bytes_in = 0
        self.bytes_out = 0
        # payload bytes: the framed data sections only (u32 meta_len +
        # JSON meta + npz) — what a different encoding could shrink; the
        # encoded−payload gap is fixed per-frame header overhead.
        self.payload_in = 0
        self.payload_out = 0
        self.rpc_count = 0
        self.rpc_latency_s: list[float] = []

    def send(self, msg_type: MsgType, meta: dict[str, Any],
             arrays: dict[str, np.ndarray] | None = None) -> None:
        frame = pack_frame(msg_type, meta, arrays)
        self.sock.sendall(frame)
        self.bytes_out += len(frame)
        self.payload_out += len(frame) - HEADER_SIZE

    def recv(self, *, expect: tuple[MsgType, ...] | None = None
             ) -> tuple[MsgType, dict[str, Any], dict[str, np.ndarray]]:
        header = recv_all(self.sock, HEADER_SIZE, at_boundary=True)
        self.bytes_in += HEADER_SIZE
        mt, length = _validate_header(header)
        payload = recv_all(self.sock, length)
        self.bytes_in += length
        self.payload_in += length
        meta, arrays = unpack_payload(payload)
        if mt is MsgType.ERROR:
            raise ProtocolError(f"peer error: {meta.get('error', '?')}")
        if expect is not None and mt not in expect:
            raise ProtocolError(
                f"unexpected {mt.name} (expected "
                f"{'/'.join(e.name for e in expect)})")
        return mt, meta, arrays

    def request(self, msg_type: MsgType, meta: dict[str, Any],
                arrays: dict[str, np.ndarray] | None = None, *,
                expect: tuple[MsgType, ...] | None = None
                ) -> tuple[MsgType, dict[str, Any], dict[str, np.ndarray]]:
        """One RPC: send a frame, read the reply, record latency."""
        t0 = time.perf_counter()
        self.send(msg_type, meta, arrays)
        out = self.recv(expect=expect)
        self.rpc_count += 1
        self.rpc_latency_s.append(time.perf_counter() - t0)
        return out

    def counters(self) -> dict[str, Any]:
        lat = sorted(self.rpc_latency_s)
        def pct(p: float) -> float:
            if not lat:
                return 0.0
            return lat[min(len(lat) - 1, int(round(p * (len(lat) - 1))))]
        return {
            "bytes_in": self.bytes_in,
            "bytes_out": self.bytes_out,
            "payload_in": self.payload_in,
            "payload_out": self.payload_out,
            "rpc_count": self.rpc_count,
            "rpc_p50_ms": pct(0.50) * 1e3,
            "rpc_p99_ms": pct(0.99) * 1e3,
        }

    def close(self) -> None:
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self.sock.close()
