"""The sampler-machine side of the wire: :class:`RemoteParameterServer`.

Implements the pull/push/project/snapshot surface of
:class:`repro.core.server.ParameterServer` over one or more
:class:`repro.net.server.ShardServer` processes, so ``engine.Trainer``
drives either backend through ``TrainerConfig(transport="inproc"|"tcp")``
without touching the round semantics.

Assembly is the client's half of the bit-exactness argument: sharded
statistics arrive as exact row slices and are concatenated in row order
(pure concat, no arithmetic — the same argument as
``ParameterServer.assemble``); the aggregate statistics (n_k, m_k, s_k)
are then re-derived from the assembled rows with the family's
``Aggregate`` tuples via ``jnp.sum`` — the identical op the in-process
``apply_delta`` / ``projection.project`` use — so a pulled snapshot is
bit-for-bit the dense pytree the in-process server would have handed
over.  Remaining unsharded stats (replicated parameters) come from the
row-0 server's merged aux.

The SSP read-my-writes lag rides here, not on the server: each local
client holds its *own* lag row (the pre-filter deltas it applied since
the last refresh — the server only ever sees post-filter pushes, so the
pre-filter lag cannot be reconstructed server-side), reset on every
refreshing pull.  The server keeps the clocks and answers NOT_MODIFIED.

Fault tolerance (DESIGN.md §13): *every* RPC — mutations included —
retries through a bounded reconnect-with-backoff loop.  That is safe
because mutations are idempotent server-side (per-(client, round)
sequence dedup): a retried PUSH whose first copy landed returns the
recorded ack instead of double-applying, so BSP stays bit-exact under
connection loss.  The client additionally keeps a bounded *replay
buffer* of its acked mutation frames (INIT plus the last
``REPLAY_WINDOW`` rounds of pushes, per server) and replays it —
``replay``-flagged — after every re-handshake: a shard server restarted
from a snapshot a few rounds back re-finalizes the missing rounds from
the replayed deltas in the identical ascending-client order, which is
what makes shard restart lossless.  Retryable failures are transport
errors only (:class:`~repro.net.protocol.TransportError`,
:class:`~repro.net.protocol.ConnectionClosed`, ``OSError``); a peer
ERROR frame is a semantic refusal and propagates immediately.

The module is also the client *process* entrypoint
(``python -m repro.net.client``) used by ``repro.launch.loopback``:

* ``--mode train``  — regenerate the deterministic synthetic corpus,
  run a ``Trainer(transport="tcp")`` over the given servers for the
  given subset of global client ids, and write a result JSON (checksums
  of the final shared statistics, throughput, wire counters);
* ``--mode stress`` — no trainer: hammer the servers with deterministic
  integer delta pushes and versioned pulls for N rounds (the
  concurrency stress harness; the launcher verifies the final state is
  exactly init + Σ deltas).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import socket
import time
from typing import Any, Sequence

import numpy as np

from repro.core import family as family_mod
from repro.core import server as server_mod
from repro.net import protocol
from repro.net import server as net_server
from repro.net.protocol import MsgType, ProtocolError


class RemoteError(ProtocolError):
    """The server answered ERROR (application-level failure)."""


# Rounds of acked push frames kept for replay after a reconnect (INIT is
# kept unconditionally).  Must stay below the server's MUTLOG_WINDOW so
# every replayed frame either digest-matches the log or is fresh.
REPLAY_WINDOW = 8

# What a bounded retry may swallow: the transport failed, not the peer's
# semantics.  A peer ERROR frame surfaces as a plain ProtocolError from
# conn.recv and is never retried.
_RETRYABLE = (protocol.TransportError, protocol.ConnectionClosed, OSError)

_BACKOFF_BASE_S = 0.05
_BACKOFF_CAP_S = 2.0


def _connect(addr: str, timeout: float) -> protocol.FramedConnection:
    host, _, port = addr.rpartition(":")
    sock = socket.create_connection((host, int(port)), timeout=timeout)
    sock.settimeout(timeout)
    return protocol.FramedConnection(sock)


class RemoteParameterServer:
    """Client-side handle on a set of shard servers (one TCP connection
    per server), presenting the in-process server's API surface."""

    def __init__(self, addrs: Sequence[str], *, family, n_clients: int,
                 vocab_size: int, consistency: str = "bsp",
                 timeout: float = 60.0, sparse_push: bool = False,
                 reconnect_limit: int = 3,
                 local_clients: Sequence[int] | None = None):
        self.family = (family_mod.get(family) if isinstance(family, str)
                       else family)
        self.n_clients = n_clients
        self.vocab_size = vocab_size
        self.policy = server_mod.make_consistency(consistency)
        self.timeout = timeout
        # Encode pushes as COO row-sliced PUSH_SPARSE frames (DESIGN.md
        # §12).  Off by default: dense PUSH is the reference encoding.
        self.sparse_push = sparse_push
        # Bounded re-dial budget for dropped connections on any RPC
        # (mutations are retry-safe — the server dedups them by
        # (client, round) sequence).
        self.reconnect_limit = reconnect_limit
        self.retries = 0
        self.reconnects = 0
        self._conns: list[protocol.FramedConnection] = []
        self._rows: list[tuple[int, int]] = []
        self._addrs: list[str] = []
        # Acked mutation frames per server, replayed after a reconnect
        # so a shard restored from a snapshot catches up losslessly:
        # (msg_type, meta, arrays, seq) with seq = round (-1 for INIT).
        self._replay: list[list[tuple]] = []
        self.project_every: int | None = None
        self._hello = {"family": self.family.name, "vocab_size": vocab_size,
                       "n_clients": n_clients,
                       "consistency": self.policy.key}
        if local_clients is not None:
            # Announced on HELLO: the server tracks which client ids a
            # connection serves, for barrier-eviction liveness.
            self._hello["clients"] = [int(c) for c in local_clients]
        pairs = []
        for addr in addrs:
            conn = _connect(addr, timeout)
            try:
                _, meta, _ = conn.request(MsgType.HELLO, self._hello,
                                          expect=(MsgType.WELCOME,))
            except ProtocolError as e:
                conn.close()
                for _a, c, _r in pairs:
                    c.close()
                raise RemoteError(f"handshake with {addr} failed: {e}") \
                    from e
            pairs.append((addr, conn, tuple(meta["rows"])))
            self.project_every = meta.get("project_every",
                                          self.project_every)
        # Servers sorted by row range; together they must tile [0, V).
        pairs.sort(key=lambda p: p[2][0])
        cursor = 0
        for addr, conn, (lo, hi) in pairs:
            if lo != cursor:
                for _a, c, _r in pairs:
                    c.close()
                raise RemoteError(
                    f"server row ranges do not tile the vocabulary: "
                    f"gap/overlap at row {cursor} (next range [{lo}, {hi}))")
            cursor = hi
            self._conns.append(conn)
            self._rows.append((lo, hi))
            self._addrs.append(addr)
        if cursor != vocab_size:
            self.close()
            raise RemoteError(f"server row ranges cover [0, {cursor}) "
                              f"but vocab_size={vocab_size}")
        self._replay = [[] for _ in self._conns]
        self._sharded: tuple[str, ...] = ()

    @property
    def n_servers(self) -> int:
        return len(self._conns)

    # ----------------------------------------------------------- plumbing
    def _split_rows(self, stats: dict[str, np.ndarray],
                    names: Sequence[str]) -> list[dict[str, np.ndarray]]:
        return [{n: np.asarray(stats[n])[lo:hi] for n in names}
                for lo, hi in self._rows]

    def _assemble(self, metas: list[dict], parts: list[dict]):
        """Concat row slices per sharded stat (exact), take unsharded aux
        from the row-0 server, re-derive the aggregates with the family's
        C2 tuples — the in-process op order."""
        import jax.numpy as jnp  # deferred: the stress path never needs jax

        sharded = tuple(metas[0]["sharded"])
        stats: dict[str, Any] = {}
        for n in sharded:
            vs = [p[n] for p in parts]
            stats[n] = np.concatenate(vs, 0) if len(vs) > 1 else vs[0]
        for n, v in parts[0].items():
            if n not in sharded:
                stats[n] = v
        agg_outs = set()
        for agg in self.family.aggregates:
            stats[agg.out] = jnp.asarray(stats[agg.src]).sum(agg.axis)
            agg_outs.add(agg.out)
        stats = {n: (jnp.asarray(v) if n not in agg_outs else v)
                 for n, v in stats.items()}
        return self.family.shared_from_dict(stats)

    def _rpc(self, i: int, msg_type: MsgType, meta: dict,
             arrays: dict | None = None, *,
             expect: tuple[MsgType, ...]):
        """One RPC to server ``i`` with bounded retry-with-backoff.

        A transport failure (dropped/reset/timed-out connection) burns
        one unit of the ``reconnect_limit`` budget, sleeps an
        exponential backoff, re-dials + re-handshakes + replays the
        mutation buffer, and resends.  Safe for mutations because the
        server dedups by (client, round) sequence: the retried frame
        either applies (first copy never arrived) or returns the
        recorded ack (the ack was lost).  Semantic refusals (peer ERROR
        frames) propagate immediately — retrying them cannot help."""
        failures = 0
        while True:
            try:
                return self._conns[i].request(msg_type, meta, arrays,
                                              expect=expect)
            except _RETRYABLE as e:
                failures += 1
                self.retries += 1
                if failures > self.reconnect_limit:
                    raise RemoteError(
                        f"{msg_type.name} to {self._addrs[i]} failed "
                        f"after {self.reconnect_limit} reconnect "
                        f"attempts: {e}") from e
                time.sleep(min(_BACKOFF_BASE_S * (2 ** (failures - 1)),
                               _BACKOFF_CAP_S))
                try:
                    self._reconnect(i)
                except _RETRYABLE:
                    # Dial/handshake/replay failure (server still down):
                    # the next loop iteration fails fast on the dead
                    # connection and burns the same bounded budget.
                    pass

    def _request_all(self, msg_type: MsgType, metas: list[dict],
                     arrays_list: list[dict] | None = None, *,
                     expect: tuple[MsgType, ...]):
        out = []
        for i in range(len(self._conns)):
            arrays = None if arrays_list is None else arrays_list[i]
            out.append(self._rpc(i, msg_type, metas[i], arrays,
                                 expect=expect))
        return out

    def _buffer_mutation(self, i: int, msg_type: MsgType, meta: dict,
                         arrays: dict | None, seq: int) -> None:
        """Record an acked mutation for post-reconnect replay; prune
        pushes older than the replay window (INIT, seq -1, is kept)."""
        buf = self._replay[i]
        buf.append((msg_type, meta, arrays, seq))
        if seq >= 0:
            horizon = seq - REPLAY_WINDOW
            self._replay[i] = [e for e in buf
                               if e[3] < 0 or e[3] >= horizon]

    def _reconnect(self, i: int) -> None:
        """Re-dial server ``i`` after a dropped connection: fresh socket,
        fresh HELLO handshake, a check that the server still serves the
        same row range it did at construction (a restarted server with a
        different partition is a config error, not a blip), then replay
        of the buffered mutation window — so a shard restored from a
        snapshot a few rounds back re-finalizes the gap from our acked
        frames.  Wire counters carry over so bench totals survive."""
        old = self._conns[i]
        try:
            old.close()
        except OSError:
            pass
        conn = _connect(self._addrs[i], self.timeout)
        try:
            _, meta, _ = conn.request(MsgType.HELLO, self._hello,
                                      expect=(MsgType.WELCOME,))
        except (protocol.TransportError, protocol.ConnectionClosed):
            # A reset *or* clean close mid-handshake is the restart
            # window (a chaos proxy whose upstream dial failed closes
            # cleanly) — retryable, not a semantic refusal.
            conn.close()
            raise
        except ProtocolError as e:
            conn.close()
            raise RemoteError(
                f"re-handshake with {self._addrs[i]} failed: {e}") from e
        if tuple(meta["rows"]) != self._rows[i]:
            conn.close()
            raise RemoteError(
                f"server {self._addrs[i]} came back with row range "
                f"{tuple(meta['rows'])} (was {self._rows[i]})")
        conn.bytes_in += old.bytes_in
        conn.bytes_out += old.bytes_out
        conn.payload_in += old.payload_in
        conn.payload_out += old.payload_out
        conn.rpc_count += old.rpc_count
        conn.rpc_latency_s = old.rpc_latency_s + conn.rpc_latency_s
        self._conns[i] = conn
        self.reconnects += 1
        for mt, m, arrays, _seq in list(self._replay[i]):
            # replay-flagged: an already-applied frame digest-matches the
            # server's mutation log (recorded ack), a pruned/finalized one
            # acks {"ignored": true}, and a missing one applies — the
            # catch-up that makes shard restart lossless.
            conn.request(mt, {**m, "replay": True}, arrays,
                         expect=(MsgType.OK,))

    # ------------------------------------------------------------- protocol
    def init_push(self, client_id: int, shared) -> None:
        """Send one client's initial statistics (the server merges all
        ``n_clients`` in ascending client id before serving any pull)."""
        stats = {n: np.asarray(v)
                 for n, v in self.family.stats_dict(shared).items()}
        sharded = net_server.sharded_stat_names(self.family, stats,
                                                self.vocab_size)
        self._sharded = sharded
        aux = {n: stats[n] for n in stats if n not in sharded}
        arrays_list = []
        for part in self._split_rows(stats, sharded):
            part = dict(part)
            part.update(aux)
            arrays_list.append(part)
        meta = {"client": int(client_id), "sharded": list(sharded)}
        for i in range(self.n_servers):
            self._rpc(i, MsgType.INIT, meta, arrays_list[i],
                      expect=(MsgType.OK,))
            self._buffer_mutation(i, MsgType.INIT, meta,
                                  arrays_list[i], -1)

    def pull(self, round_idx: int, cached_version: int | None = None
             ) -> tuple[Any, int, bool]:
        """Versioned cache refresh for ``round_idx``.

        Returns ``(shared, version, refreshed)``; ``shared`` is None when
        every server answered NOT_MODIFIED (keep sampling the cache).  A
        split decision (some servers refresh, some not) is a protocol
        violation — the policy predicate is deterministic."""
        meta = {"round": int(round_idx)}
        if cached_version is not None:
            meta["cached_version"] = int(cached_version)
        replies = [self._rpc(i, MsgType.PULL, meta,
                             expect=(MsgType.STATE, MsgType.NOT_MODIFIED))
                   for i in range(self.n_servers)]
        kinds = {mt for mt, _, _ in replies}
        if kinds == {MsgType.NOT_MODIFIED}:
            return None, int(cached_version), False
        if len(kinds) != 1:
            raise RemoteError("servers split on NOT_MODIFIED — "
                              "inconsistent staleness policies")
        metas = [m for _, m, _ in replies]
        parts = [a for _, _, a in replies]
        return self._assemble(metas, parts), int(metas[0]["version"]), True

    def pull_keys(self, names: Sequence[str] | None = None,
                  lo: int = 0, hi: int | None = None
                  ) -> dict[str, np.ndarray]:
        """Addressed row-range pull from the canonical store (what crosses
        the wire when a client only holds part of the vocabulary)."""
        hi = self.vocab_size if hi is None else hi
        meta = {"lo": int(lo), "hi": int(hi)}
        if names is not None:
            meta["names"] = list(names)
        replies = self._request_all(MsgType.PULL_KEYS,
                                    [meta] * self.n_servers,
                                    expect=(MsgType.STATE,))
        out: dict[str, list[np.ndarray]] = {}
        for _, m, arrays in replies:
            if m["rows"][0] >= m["rows"][1]:
                continue
            for n, v in arrays.items():
                out.setdefault(n, []).append(v)
        return {n: (np.concatenate(vs, 0) if len(vs) > 1 else vs[0])
                for n, vs in out.items()}

    def push(self, round_idx: int, client_id: int,
             deltas: dict[str, Any]) -> None:
        """One client's delta frame for ``round_idx`` (row-sliced per
        server; the server finalizes the round at the barrier).

        With ``sparse_push`` the row slice is COO-encoded before it hits
        the wire: the rows that are non-zero in *any* statistic (the
        union keeps one shared index vector per frame) plus the packed
        (R, K) values per statistic.  The server scatters the packed rows
        into zeros and rides the exact dense barrier path, so the round
        total is bit-for-bit the dense PUSH total (dropped rows are
        exactly 0.0, and 0 + x == x in IEEE 754)."""
        nps = {n: np.asarray(v) for n, v in deltas.items()}
        names = tuple(nps)
        meta = {"round": int(round_idx), "client": int(client_id)}
        parts = self._split_rows(nps, names)
        if not self.sparse_push:
            for i in range(self.n_servers):
                self._rpc(i, MsgType.PUSH, meta, parts[i],
                          expect=(MsgType.OK,))
                self._buffer_mutation(i, MsgType.PUSH, meta, parts[i],
                                      int(round_idx))
            return
        metas: list[dict] = []
        arrays_list: list[dict[str, np.ndarray]] = []
        for (lo, hi), part in zip(self._rows, parts):
            nz: np.ndarray | None = None
            for v in part.values():
                row_any = np.any(v != 0, axis=tuple(range(1, v.ndim)))
                nz = row_any if nz is None else (nz | row_any)
            rows = np.flatnonzero(nz).astype(np.uint32)
            arrays = {"rows": rows}
            arrays.update({n: np.ascontiguousarray(part[n][rows])
                           for n in names})
            metas.append({**meta, "n_rows": int(hi - lo),
                          "sparse": list(names)})
            arrays_list.append(arrays)
        for i in range(self.n_servers):
            self._rpc(i, MsgType.PUSH_SPARSE, metas[i], arrays_list[i],
                      expect=(MsgType.OK,))
            self._buffer_mutation(i, MsgType.PUSH_SPARSE, metas[i],
                                  arrays_list[i], int(round_idx))

    def push_ghost(self, round_idx: int, client_id: int) -> None:
        """Fill the client's barrier slot for ``round_idx`` without a
        delta or clock tick — how a trainer-level simulated fault
        (crash/straggle/lost_push mask) rides the wire while keeping the
        round finalization flowing, bit-exact with the in-process mask
        (DESIGN.md §13)."""
        meta = {"round": int(round_idx), "client": int(client_id),
                "ghost": True}
        for i in range(self.n_servers):
            self._rpc(i, MsgType.PUSH, meta, None, expect=(MsgType.OK,))
            self._buffer_mutation(i, MsgType.PUSH, meta, None,
                                  int(round_idx))

    def project(self) -> None:
        self._request_all(MsgType.PROJECT, [{}] * self.n_servers,
                          expect=(MsgType.OK,))

    def snapshot(self, min_round: int = 0):
        """The canonical assembled statistics once every round below
        ``min_round`` has been finalized (admin/eval view)."""
        meta = {"min_round": int(min_round)}
        replies = self._request_all(MsgType.SNAPSHOT,
                                    [meta] * self.n_servers,
                                    expect=(MsgType.STATE,))
        return self._assemble([m for _, m, _ in replies],
                              [a for _, _, a in replies])

    def clock(self, min_round: int | None = None
              ) -> tuple[int, np.ndarray]:
        """(min server round across shards, per-client clocks).  With
        ``min_round``, blocks until every shard has finalized it."""
        meta = {} if min_round is None else {"min_round": int(min_round)}
        replies = self._request_all(MsgType.CLOCK, [meta] * self.n_servers,
                                    expect=(MsgType.OK,))
        rounds = [m["server_round"] for _, m, _ in replies]
        return min(rounds), np.asarray(replies[0][1]["clocks"])

    def rejoin(self, client_id: int) -> None:
        """Elastic rejoin: clear the client's pending pushes and open
        mutation-log entries server-side, and lift any eviction."""
        self._request_all(MsgType.REJOIN,
                          [{"client": int(client_id)}] * self.n_servers,
                          expect=(MsgType.OK,))
        # Frames from the dead incarnation must not resurface on the next
        # reconnect and digest-conflict with the fresh ones.
        for buf in self._replay:
            buf[:] = [e for e in buf
                      if e[3] < 0 or int(e[1].get("client", -2))
                      != int(client_id)]

    def leave(self, client_id: int) -> None:
        """Voluntary elastic leave: the barrier stops requiring the
        client immediately (no liveness deadline) and its clock freezes
        until a rejoin."""
        self._request_all(
            MsgType.REJOIN,
            [{"client": int(client_id), "action": "leave"}]
            * self.n_servers, expect=(MsgType.OK,))

    def snapshot_write(self, directory: str,
                       step: int | None = None) -> list[dict[str, Any]]:
        """Ask every shard to persist its state (SNAPSHOT_WRITE) —
        returns the per-shard {step, name, path} acks."""
        meta: dict[str, Any] = {"directory": directory}
        if step is not None:
            meta["step"] = int(step)
        return [m for _, m, _ in self._request_all(
            MsgType.SNAPSHOT_WRITE, [meta] * self.n_servers,
            expect=(MsgType.OK,))]

    def snapshot_restore(self, directory: str,
                         step: int | None = None) -> list[int]:
        """Ask every shard to reload from its snapshot (SNAPSHOT_RESTORE)
        — returns the per-shard restored rounds."""
        meta: dict[str, Any] = {"directory": directory}
        if step is not None:
            meta["step"] = int(step)
        return [int(m["server_round"]) for _, m, _ in self._request_all(
            MsgType.SNAPSHOT_RESTORE, [meta] * self.n_servers,
            expect=(MsgType.OK,))]

    def server_stats(self) -> list[dict[str, Any]]:
        return [m for _, m, _ in self._request_all(
            MsgType.STATS, [{}] * self.n_servers, expect=(MsgType.OK,))]

    def shutdown_servers(self) -> None:
        for conn in self._conns:
            try:
                conn.request(MsgType.SHUTDOWN, {}, expect=(MsgType.OK,))
            except (ProtocolError, OSError):
                pass

    # ----------------------------------------------------------- counters
    def counters(self) -> dict[str, Any]:
        """Aggregated per-connection wire counters (bytes in/out, RPC
        count, p50/p99 RPC latency) — the bench artifact surface."""
        per = [c.counters() for c in self._conns]
        lat = sorted(x for c in self._conns for x in c.rpc_latency_s)

        def pct(p: float) -> float:
            if not lat:
                return 0.0
            return lat[min(len(lat) - 1,
                           int(round(p * (len(lat) - 1))))] * 1e3

        return {
            "bytes_in": sum(c["bytes_in"] for c in per),
            "bytes_out": sum(c["bytes_out"] for c in per),
            "payload_in": sum(c["payload_in"] for c in per),
            "payload_out": sum(c["payload_out"] for c in per),
            "rpc_count": sum(c["rpc_count"] for c in per),
            "rpc_p50_ms": pct(0.50),
            "rpc_p99_ms": pct(0.99),
            "retries": self.retries,
            "reconnects": self.reconnects,
            "per_connection": per,
        }

    def close(self) -> None:
        for conn in self._conns:
            conn.close()
        self._conns = []

    def __enter__(self) -> "RemoteParameterServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# Process entrypoint (repro.launch.loopback workers)
# ---------------------------------------------------------------------------

def _checksum(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def stress_delta(round_idx: int, client_id: int, shape: tuple[int, int]
                 ) -> np.ndarray:
    """Deterministic integer-valued delta for the stress harness: the
    launcher recomputes Σ over (round, client) and asserts the final
    store equals init + Σ exactly."""
    v, k = shape
    base = (round_idx * 131 + client_id * 17) % 7 + 1
    col = (np.arange(v, dtype=np.float32)[:, None]
           + np.arange(k, dtype=np.float32)[None, :])
    return np.float32(base) + (col % 3)


def _run_train(args) -> dict[str, Any]:
    from repro.core import lda, pdp
    from repro.data.synthetic import CorpusConfig, make_topic_corpus
    from repro.engine.trainer import Trainer, TrainerConfig
    import jax

    tokens, mask, _ = make_topic_corpus(CorpusConfig(
        n_topics=args.n_topics, vocab_size=args.vocab_size,
        n_docs=args.n_docs, doc_len=args.doc_len, seed=args.corpus_seed))
    if args.family == "lda":
        cfg = lda.LDAConfig(n_topics=args.n_topics,
                            vocab_size=args.vocab_size)
    elif args.family == "pdp":
        cfg = pdp.PDPConfig(n_topics=args.n_topics,
                            vocab_size=args.vocab_size)
    else:
        raise SystemExit(f"unsupported family for the wire: {args.family}")
    clients = tuple(int(c) for c in args.clients.split(","))
    tcfg = TrainerConfig(
        n_clients=args.n_clients, tau=args.tau, layout=args.layout,
        consistency=args.consistency, project_every=args.project_every,
        transport="tcp", server_addrs=tuple(args.addrs.split(",")),
        local_clients=clients, reconnect_limit=args.reconnect_limit,
        snapshot_every=args.snapshot_every,
        snapshot_dir=args.snapshot_dir)
    key = jax.random.PRNGKey(args.seed)
    if args.restore:
        # Worker restart: rebuild from the latest local snapshot and
        # resume at the recorded round — the servers' barrier has been
        # waiting for this client's missing pushes.
        trainer = Trainer.restore(cfg, tokens, mask, config=tcfg, key=key)
    else:
        trainer = Trainer(cfg, tokens, mask, config=tcfg, key=key)
    t0 = time.perf_counter()
    rounds_done = 0
    while trainer.round_idx < args.n_rounds:
        trainer.step()
        rounds_done += 1
        if args.die_after_round is not None \
                and trainer.round_idx >= args.die_after_round:
            # Deterministic kill point (failover tests): the round-N
            # snapshot was written by step() before we get here, so the
            # relaunched --restore incarnation resumes at exactly N.
            print(f"DYING round {trainer.round_idx}", flush=True)
            os._exit(42)
    trainer._sync()
    dt = time.perf_counter() - t0
    shared = trainer.shared
    stats = {n: np.asarray(v)
             for n, v in trainer.family.stats_dict(shared).items()}
    result = {
        "mode": "train",
        "clients": list(clients),
        "rounds": args.n_rounds,
        "rounds_done": rounds_done,
        "restored": bool(args.restore),
        "rounds_per_s": rounds_done / max(dt, 1e-9),
        "checksums": {n: _checksum(v) for n, v in stats.items()},
        "sums": {n: float(v.sum()) for n, v in stats.items()},
        "perplexity": trainer.perplexity(),
        "counters": trainer.remote.counters(),
    }
    trainer.close()
    return result


def _run_stress(args) -> dict[str, Any]:
    clients = tuple(int(c) for c in args.clients.split(","))
    fam = family_mod.get(args.family)
    remote = RemoteParameterServer(
        args.addrs.split(","), family=fam, n_clients=args.n_clients,
        vocab_size=args.vocab_size, consistency=args.consistency,
        timeout=args.timeout)
    shape = (args.vocab_size, args.n_topics)
    zero = {n: np.zeros(shape, np.float32) for n in fam.delta_names}
    aggs = {a.out for a in fam.aggregates}
    init_stats = dict(zero)
    for n in fam.shared_stats:
        if n not in init_stats and n in aggs:
            init_stats[n] = np.zeros((args.n_topics,), np.float32)
    for c in clients:
        remote.init_push(c, fam.shared_from_dict(init_stats))
    version: int | None = None
    for r in range(args.n_rounds):
        _shared, v, refreshed = remote.pull(r, version)
        if refreshed:
            version = v
        for c in clients:
            d = stress_delta(r, c, shape)
            remote.push(r, c, {n: d for n in fam.delta_names})
    sr, _clocks = remote.clock(min_round=args.n_rounds)
    final = remote.pull_keys(list(fam.delta_names))
    result = {
        "mode": "stress",
        "clients": list(clients),
        "rounds": args.n_rounds,
        "server_round": sr,
        "checksums": {n: _checksum(v) for n, v in final.items()},
        "sums": {n: float(v.sum()) for n, v in final.items()},
        "counters": remote.counters(),
    }
    remote.close()
    return result


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="parameter-server client process (repro.net)")
    ap.add_argument("--mode", choices=("train", "stress"), default="train")
    ap.add_argument("--addrs", required=True,
                    help="comma-separated host:port shard servers")
    ap.add_argument("--clients", required=True,
                    help="comma-separated global client ids this process "
                         "owns")
    ap.add_argument("--family", default="lda")
    ap.add_argument("--vocab-size", type=int, default=64)
    ap.add_argument("--n-topics", type=int, default=4)
    ap.add_argument("--n-clients", type=int, default=2)
    ap.add_argument("--n-rounds", type=int, default=4)
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--layout", default="scan")
    ap.add_argument("--consistency", default="bsp")
    ap.add_argument("--project-every", type=int, default=1)
    ap.add_argument("--n-docs", type=int, default=16)
    ap.add_argument("--doc-len", type=int, default=12)
    ap.add_argument("--corpus-seed", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--timeout", type=float, default=60.0)
    ap.add_argument("--reconnect-limit", type=int, default=3,
                    help="bounded retry budget per RPC (each unit is one "
                         "reconnect attempt with exponential backoff)")
    ap.add_argument("--snapshot-dir", default=None)
    ap.add_argument("--snapshot-every", type=int, default=0)
    ap.add_argument("--restore", action="store_true",
                    help="resume from the latest snapshot in "
                         "--snapshot-dir (worker restart)")
    ap.add_argument("--die-after-round", type=int, default=None,
                    help="exit(42) after completing this round "
                         "(deterministic kill point for failover tests)")
    ap.add_argument("--out", default=None, help="result JSON path")
    args = ap.parse_args(argv)

    result = _run_train(args) if args.mode == "train" else _run_stress(args)
    payload = json.dumps(result, indent=2, sort_keys=True)
    if args.out:
        with open(args.out, "w") as f:
            f.write(payload)
    print(payload, flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
