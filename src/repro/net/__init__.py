"""Out-of-process parameter server: the wire layer (DESIGN.md §11).

The paper's system is parameter-server *processes* serving sampler
*machines* over a network.  This package is that split as code:

* :mod:`repro.net.protocol` — the framed binary wire protocol: magic
  cookie + protocol version + message type + length-prefixed payload,
  exact-read ``recv_all``, npz-style array payloads, and the
  :class:`~repro.net.protocol.ProtocolError` contract (malformed frames
  fail loudly and close the connection — they never hang a peer or
  corrupt shard state).
* :mod:`repro.net.server` — :class:`~repro.net.server.ShardServer` /
  :func:`~repro.net.server.serve_shards`: a process hosting one or more
  vocabulary shards of the canonical ``ServerState`` over TCP, applying
  pushes at deterministic round barriers (bit-exact with the in-process
  BSP path) and answering SSP pulls with ``NOT_MODIFIED`` when the
  client's cached version is within the staleness bound.
* :mod:`repro.net.client` — :class:`~repro.net.client.RemoteParameterServer`:
  the client half, implementing the pull/push/project/snapshot surface of
  :class:`repro.core.server.ParameterServer` over one or more shard
  servers, so ``engine.Trainer`` runs unchanged over either backend via
  ``TrainerConfig(transport="inproc" | "tcp")``.

* :mod:`repro.net.chaos` — :class:`~repro.net.chaos.ChaosProxy`: a
  seeded, frame-aware TCP relay that drops, delays, and truncates frames
  per a :class:`~repro.core.fault.FaultPlan`'s network events —
  deterministic transport misbehavior for the fault-tolerance tests
  (DESIGN.md §13).

The in-process path survives as the zero-copy fast path behind the same
interface; the multi-process loopback launcher lives in
``repro.launch.loopback``.
"""

from repro.net.chaos import ChaosProxy, interpose
from repro.net.client import RemoteParameterServer, RemoteError
from repro.net.protocol import (ConnectionClosed, IdleTimeout, MsgType,
                                ProtocolError, PROTOCOL_VERSION,
                                TransportError)
from repro.net.server import ShardServer, serve_shards

__all__ = [
    "ChaosProxy",
    "ConnectionClosed",
    "IdleTimeout",
    "MsgType",
    "PROTOCOL_VERSION",
    "ProtocolError",
    "RemoteError",
    "RemoteParameterServer",
    "ShardServer",
    "TransportError",
    "interpose",
    "serve_shards",
]
