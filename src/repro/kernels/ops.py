"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True so the kernels validate on CPU; on a real TPU
runtime set ``repro.kernels.ops.INTERPRET = False`` (or pass explicitly) and
the same BlockSpecs lower to Mosaic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.alias import AliasTable
from repro.kernels import alias_build as _build
from repro.kernels import alias_sample as _sample
from repro.kernels import mh_accept as _accept
from repro.kernels import mhw_fused as _fused

INTERPRET = True


def build_tables(p: jax.Array, *, tile_r: int = 8,
                 interpret: bool | None = None) -> AliasTable:
    """Kernel-backed replacement for ``repro.core.alias.build`` (2-D input)."""
    prob, alias, mass = _build.alias_build(
        p, tile_r=tile_r,
        interpret=INTERPRET if interpret is None else interpret)
    return AliasTable(prob=prob, alias=alias, mass=mass)


def build_tables_fused_lda(n_wk: jax.Array, n_k: jax.Array, *, alpha: float,
                           beta: float, vocab_size: int, tile_r: int = 8,
                           interpret: bool | None = None
                           ) -> tuple[AliasTable, jax.Array]:
    """Fused dense-term + alias build; also returns the dense term mass-
    consistent stale matrix (recomputed cheaply for MH point evaluation)."""
    prob, alias, mass = _build.alias_build_fused(
        n_wk, n_k, alpha=alpha, beta=beta, vocab_size=vocab_size,
        tile_r=tile_r, interpret=INTERPRET if interpret is None else interpret)
    stale_dense = alpha * (n_wk + beta) / (n_k[None, :] + beta * vocab_size)
    return AliasTable(prob=prob, alias=alias, mass=mass), stale_dense


def sample_rows(tables: AliasTable, rows: jax.Array, key: jax.Array, *,
                tile_v: int = 64, tile_b: int = 1024,
                interpret: bool | None = None) -> jax.Array:
    """Kernel-backed replacement for ``repro.core.alias.sample_rows``."""
    k = tables.prob.shape[-1]
    k_slot, k_coin = jax.random.split(key)
    slot = jax.random.randint(k_slot, rows.shape, 0, k, dtype=jnp.int32)
    coin = jax.random.uniform(k_coin, rows.shape)
    return _sample.alias_sample(
        tables.prob, tables.alias, rows, slot, coin, tile_v=tile_v,
        tile_b=tile_b, interpret=INTERPRET if interpret is None else interpret)


def sample_rows_sorted(tables: AliasTable, rows: jax.Array,
                       vstart: jax.Array, vcount: jax.Array, key: jax.Array,
                       *, tile_v: int = _sample.DEFAULT_TILE_V,
                       tile_b: int = _sample.DEFAULT_TILE_B,
                       interpret: bool | None = None) -> jax.Array:
    """Tile-skipping draws over a token-sorted stream (``segment`` layout).

    ``rows`` must be ascending with padding sentinels ≥ V at the end;
    ``vstart``/``vcount`` come from ``segment.build_layout``.  Padding
    positions return 0.
    """
    k = tables.prob.shape[-1]
    k_slot, k_coin = jax.random.split(key)
    slot = jax.random.randint(k_slot, rows.shape, 0, k, dtype=jnp.int32)
    coin = jax.random.uniform(k_coin, rows.shape)
    return _sample.alias_sample_sorted(
        tables.prob, tables.alias, rows, slot, coin, vstart, vcount,
        tile_v=tile_v, tile_b=tile_b,
        interpret=INTERPRET if interpret is None else interpret)


def mhw_sweep_sorted(tables: AliasTable, stale: jax.Array, n_wk: jax.Array,
                     n_k: jax.Array, rows: jax.Array, z0: jax.Array,
                     ndk: jax.Array, vstart: jax.Array, vcount: jax.Array,
                     key: jax.Array, *, mh_steps: int, alpha: float,
                     beta: float, beta_bar: float,
                     tile_v: int = _sample.DEFAULT_TILE_V,
                     tile_b: int = _sample.DEFAULT_TILE_B,
                     interpret: bool | None = None) -> jax.Array:
    """Fused sorted-layout MHW chain: draws the per-step uniforms and runs
    ``kernels.mhw_fused.mhw_sweep_fused`` (see that module's docstring)."""
    k = tables.prob.shape[-1]
    b = rows.shape[0]
    ks = jax.random.split(key, 5)
    slot = jax.random.randint(ks[0], (mh_steps, b), 0, k, dtype=jnp.int32)
    coin = jax.random.uniform(ks[1], (mh_steps, b))
    u_mix = jax.random.uniform(ks[2], (mh_steps, b))
    u_sparse = jax.random.uniform(ks[3], (mh_steps, b))
    u_acc = jax.random.uniform(ks[4], (mh_steps, b))
    return _fused.mhw_sweep_fused(
        tables.prob, tables.alias, tables.mass, stale, n_wk, n_k, rows, z0,
        ndk, slot, coin, u_mix, u_sparse, u_acc, vstart, vcount,
        tile_v=tile_v, tile_b=tile_b, n_steps=mh_steps, alpha=alpha,
        beta=beta, beta_bar=beta_bar,
        interpret=INTERPRET if interpret is None else interpret)


def mh_accept(z, cand, log_p_z, log_p_cand, log_q_z, log_q_cand, key, *,
              tile_b: int = 4096, interpret: bool | None = None):
    """Kernel-backed fused MH accept step."""
    u = jax.random.uniform(key, z.shape)
    return _accept.mh_accept(
        z, cand, log_p_z, log_p_cand, log_q_z, log_q_cand, u,
        tile_b=tile_b, interpret=INTERPRET if interpret is None else interpret)
