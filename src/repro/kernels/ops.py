"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True so the kernels validate on CPU; on a real TPU
runtime set ``repro.kernels.ops.INTERPRET = False`` (or pass explicitly) and
the same BlockSpecs lower to Mosaic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.alias import AliasTable
from repro.kernels import alias_build as _build
from repro.kernels import alias_sample as _sample
from repro.kernels import mh_accept as _accept
from repro.kernels import mhw_fused as _fused

INTERPRET = True


def build_tables(p: jax.Array, *, tile_r: int = 8,
                 interpret: bool | None = None) -> AliasTable:
    """Kernel-backed replacement for ``repro.core.alias.build`` (2-D input)."""
    prob, alias, mass = _build.alias_build(
        p, tile_r=tile_r,
        interpret=INTERPRET if interpret is None else interpret)
    return AliasTable(prob=prob, alias=alias, mass=mass)


def build_tables_fused_lda(n_wk: jax.Array, n_k: jax.Array, *, alpha: float,
                           beta: float, vocab_size: int, tile_r: int = 8,
                           interpret: bool | None = None
                           ) -> tuple[AliasTable, jax.Array]:
    """Fused dense-term + alias build; also returns the dense term mass-
    consistent stale matrix (recomputed cheaply for MH point evaluation)."""
    prob, alias, mass = _build.alias_build_fused(
        n_wk, n_k, alpha=alpha, beta=beta, vocab_size=vocab_size,
        tile_r=tile_r, interpret=INTERPRET if interpret is None else interpret)
    stale_dense = alpha * (n_wk + beta) / (n_k[None, :] + beta * vocab_size)
    return AliasTable(prob=prob, alias=alias, mass=mass), stale_dense


def build_tables_rows(p_rows: jax.Array, *, tile_r: int = 8,
                      interpret: bool | None = None) -> AliasTable:
    """Alias build over a compacted (R, K) block of gathered changed rows
    (the incremental producer's generic path; see alias_build_rows)."""
    prob, alias, mass = _build.alias_build_rows(
        p_rows, tile_r=tile_r,
        interpret=INTERPRET if interpret is None else interpret)
    return AliasTable(prob=prob, alias=alias, mass=mass)


def build_tables_gather_fused(n_wk: jax.Array, n_k: jax.Array,
                              prior: jax.Array, rows: jax.Array, *,
                              beta: float, beta_bar: float,
                              interpret: bool | None = None
                              ) -> tuple[AliasTable, jax.Array]:
    """Gather → fused dense-term + alias build over changed rows only, for
    the LM-dense families (prior_e · (n_wk+β)/(n_k+β̄)).  Returns the
    compacted sub-table plus the matching dense rows for the stale-snapshot
    scatter (``repro.core.alias.update_rows``)."""
    prob, alias, mass, dense = _build.alias_build_gather_fused(
        n_wk, n_k, prior, rows, beta=beta, beta_bar=beta_bar,
        interpret=INTERPRET if interpret is None else interpret)
    return AliasTable(prob=prob, alias=alias, mass=mass), dense


def sample_rows(tables: AliasTable, rows: jax.Array, key: jax.Array, *,
                tile_v: int = 64, tile_b: int = 1024,
                interpret: bool | None = None) -> jax.Array:
    """Kernel-backed replacement for ``repro.core.alias.sample_rows``."""
    k = tables.prob.shape[-1]
    k_slot, k_coin = jax.random.split(key)
    slot = jax.random.randint(k_slot, rows.shape, 0, k, dtype=jnp.int32)
    coin = jax.random.uniform(k_coin, rows.shape)
    return _sample.alias_sample(
        tables.prob, tables.alias, rows, slot, coin, tile_v=tile_v,
        tile_b=tile_b, interpret=INTERPRET if interpret is None else interpret)


def sample_rows_sorted(tables: AliasTable, rows: jax.Array,
                       vstart: jax.Array, vcount: jax.Array, key: jax.Array,
                       *, tile_v: int = _sample.DEFAULT_TILE_V,
                       tile_b: int = _sample.DEFAULT_TILE_B,
                       interpret: bool | None = None) -> jax.Array:
    """Tile-skipping draws over a token-sorted stream (``segment`` layout).

    ``rows`` must be ascending with padding sentinels ≥ V at the end;
    ``vstart``/``vcount`` come from ``segment.build_layout``.  Padding
    positions return 0.
    """
    k = tables.prob.shape[-1]
    k_slot, k_coin = jax.random.split(key)
    slot = jax.random.randint(k_slot, rows.shape, 0, k, dtype=jnp.int32)
    coin = jax.random.uniform(k_coin, rows.shape)
    return _sample.alias_sample_sorted(
        tables.prob, tables.alias, rows, slot, coin, vstart, vcount,
        tile_v=tile_v, tile_b=tile_b,
        interpret=INTERPRET if interpret is None else interpret)


def _step_uniforms(key: jax.Array, n_outcomes: int, mh_steps: int, b: int):
    """The five per-MH-step uniform streams every fused sorted chain uses."""
    ks = jax.random.split(key, 5)
    slot = jax.random.randint(ks[0], (mh_steps, b), 0, n_outcomes,
                              dtype=jnp.int32)
    return (slot,) + tuple(jax.random.uniform(ks[i], (mh_steps, b))
                           for i in range(1, 5))


def mhw_sweep_sorted(tables: AliasTable, stale: jax.Array, n_wk: jax.Array,
                     n_k: jax.Array, prior: jax.Array, rows: jax.Array,
                     z0: jax.Array, ndk: jax.Array, vstart: jax.Array,
                     vcount: jax.Array, key: jax.Array, *, mh_steps: int,
                     beta: float, beta_bar: float,
                     tile_v: int = _sample.DEFAULT_TILE_V,
                     tile_b: int = _sample.DEFAULT_TILE_B,
                     tile_k: int | None = None,
                     uniforms: tuple[jax.Array, ...] | None = None,
                     interpret: bool | None = None) -> jax.Array:
    """Fused sorted-layout MHW chain for the lm families (LDA: prior = α·1,
    HDP: prior = b1·θ0): draws the per-step uniforms and runs
    ``kernels.mhw_fused.mhw_sweep_fused`` (see that module's docstring).

    ``uniforms`` overrides the ``_step_uniforms`` draw with caller-supplied
    ``(slot, coin, u_mix, u_sparse, u_acc)`` streams, each ``(mh_steps, b)``
    in sorted-stream order; ``key`` is then unused.  The serving engine uses
    this to keep each document's chain a pure function of its own request
    seed regardless of which slots it shares a batch with.
    """
    k = tables.prob.shape[-1]
    b = rows.shape[0]
    if uniforms is None:
        uniforms = _step_uniforms(key, k, mh_steps, b)
    slot, coin, u_mix, u_sparse, u_acc = uniforms
    return _fused.mhw_sweep_fused(
        tables.prob, tables.alias, tables.mass, stale, n_wk, n_k, prior,
        rows, z0, ndk, slot, coin, u_mix, u_sparse, u_acc, vstart, vcount,
        tile_v=tile_v, tile_b=tile_b, tile_k=tile_k, n_steps=mh_steps,
        beta=beta, beta_bar=beta_bar,
        interpret=INTERPRET if interpret is None else interpret)


def pdp_sweep_sorted(tables: AliasTable, stale: jax.Array, m_wk: jax.Array,
                     s_wk: jax.Array, m_k: jax.Array, s_k: jax.Array,
                     stirl: jax.Array, prior: jax.Array, rows: jax.Array,
                     e0: jax.Array, ndk: jax.Array, vstart: jax.Array,
                     vcount: jax.Array, key: jax.Array, *, mh_steps: int,
                     concentration: float, discount: float, gamma: float,
                     gamma_bar: float,
                     tile_v: int = _sample.DEFAULT_TILE_V,
                     tile_b: int = _sample.DEFAULT_TILE_B,
                     tile_k: int | None = None,
                     uniforms: tuple[jax.Array, ...] | None = None,
                     interpret: bool | None = None) -> jax.Array:
    """Fused sorted-layout MHW chain for PDP's joint 2K outcome space:
    draws the per-step uniforms (slot over [0, 2K)) and runs
    ``kernels.mhw_fused.pdp_sweep_fused``.  ``uniforms`` overrides the
    draw exactly as in :func:`mhw_sweep_sorted`."""
    e_out = tables.prob.shape[-1]
    b = rows.shape[0]
    if uniforms is None:
        uniforms = _step_uniforms(key, e_out, mh_steps, b)
    slot, coin, u_mix, u_sparse, u_acc = uniforms
    return _fused.pdp_sweep_fused(
        tables.prob, tables.alias, tables.mass, stale, m_wk, s_wk, m_k, s_k,
        stirl, prior, rows, e0, ndk, slot, coin, u_mix, u_sparse, u_acc,
        vstart, vcount, tile_v=tile_v, tile_b=tile_b, tile_k=tile_k,
        n_steps=mh_steps, b_conc=concentration, a_disc=discount,
        gamma=gamma, gamma_bar=gamma_bar,
        interpret=INTERPRET if interpret is None else interpret)


def mh_accept(z, cand, log_p_z, log_p_cand, log_q_z, log_q_cand, key, *,
              tile_b: int = 4096, interpret: bool | None = None):
    """Kernel-backed fused MH accept step."""
    u = jax.random.uniform(key, z.shape)
    return _accept.mh_accept(
        z, cand, log_p_z, log_p_cand, log_q_z, log_q_cand, u,
        tile_b=tile_b, interpret=INTERPRET if interpret is None else interpret)
