"""Pallas TPU kernel: blocked Walker/Vose alias-table construction.

The alias build is the producer half of the paper's multi-thread sampler
(§5.1): tables over the dense proposal term are (re)built every refresh
cadence for every token-type row.  On TPU the thread pool dissolves into a
*blocked, row-vectorized* kernel:

  grid          = vocabulary tiles (one program per TILE_R rows)
  VMEM working  = a (TILE_R, K) tile of the dense term + the table state
  inner loop    = the classical two-stack pairing loop, run in lockstep
                  across the TILE_R rows of the tile (rows are VPU lanes;
                  every loop step retires one "small" slot per row)

The fused variant computes the dense LDA term α(n_wk+β)/(n_t+β̄) from the
raw sufficient statistics *inside* the kernel, saving one V×K HBM round
trip versus materializing the dense matrix and then building tables
(measured in the ``alias_build`` section of benchmarks/bench_throughput.py,
fused vs. materialize-then-build).

Incremental rebuilds (the delta-driven producer of the paper's §5.1
producer/consumer design) use the *rows* variants: only the token-type rows
whose pushed delta mass drifted are rebuilt — :func:`alias_build_rows` over
a compacted (R, E) block, and :func:`alias_build_gather_fused`, whose
scalar-prefetched row indices drive the input index map so the gather, the
dense-term computation and the table build fuse into one kernel (cost
scales with R changed rows, not V).

Validated against ``repro.kernels.ref`` in interpret mode (CPU); the block
shapes keep the working set ≤ a few MB of VMEM for production sizes
(TILE_R=8, K≤4096 → ~1.5 MB including table state).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TILE_R = 8


def _build_tile(scaled: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Two-stack alias pairing for a (R, K) tile, rows in lockstep.

    ``scaled`` is the K-normalized distribution × K (mean 1.0 per row).
    Returns (prob, alias) of shapes (R, K) float32 / int32.
    """
    r, k = scaled.shape
    idx = jnp.arange(k, dtype=jnp.int32)
    rows = jnp.arange(r)

    is_small = scaled < 1.0
    order = jnp.argsort(is_small, axis=-1)            # larges first
    stack = jnp.broadcast_to(idx, (r, k))
    stack = jnp.take_along_axis(stack, order, axis=-1).astype(jnp.int32)
    n_small = jnp.sum(is_small, axis=-1).astype(jnp.int32)   # (R,)
    n_large = (k - n_small).astype(jnp.int32)
    large_top = n_large - 1
    small_top = k - n_small

    prob = jnp.ones((r, k), jnp.float32)
    alias = jnp.broadcast_to(idx, (r, k)).astype(jnp.int32)
    assigned = jnp.zeros((r, k), jnp.bool_)

    def body(_, carry):
        prob, alias, assigned, scaled, stack, large_top, small_top, n_small, n_large = carry
        active = (n_small > 0) & (n_large > 0)        # (R,)

        i = stack[rows, jnp.clip(small_top, 0, k - 1)]
        j = stack[rows, jnp.clip(large_top, 0, k - 1)]

        si = scaled[rows, i]
        prob = jnp.where(active[:, None],
                         prob.at[rows, i].set(si), prob)
        alias = jnp.where(active[:, None],
                          alias.at[rows, i].set(j), alias)
        assigned = jnp.where(active[:, None],
                             assigned.at[rows, i].set(True), assigned)
        sj = scaled[rows, j] - (1.0 - si)
        scaled = jnp.where(active[:, None],
                           scaled.at[rows, j].set(sj), scaled)

        j_is_small = sj < 1.0
        small_top2 = small_top + 1
        large_top2 = large_top - 1
        pos = jnp.where(j_is_small, small_top2 - 1, large_top2 + 1)
        stack = jnp.where(active[:, None],
                          stack.at[rows, jnp.clip(pos, 0, k - 1)].set(j), stack)
        small_top3 = jnp.where(active,
                               jnp.where(j_is_small, small_top2 - 1, small_top2),
                               small_top)
        n_small3 = jnp.where(active,
                             jnp.where(j_is_small, n_small, n_small - 1),
                             n_small)
        large_top3 = jnp.where(active,
                               jnp.where(j_is_small, large_top2, large_top2 + 1),
                               large_top)
        n_large3 = jnp.where(active,
                             jnp.where(j_is_small, n_large - 1, n_large),
                             n_large)
        return (prob, alias, assigned, scaled, stack,
                large_top3, small_top3, n_small3, n_large3)

    init = (prob, alias, assigned, scaled, stack, large_top, small_top,
            n_small, n_large)
    prob, alias, assigned, *_ = jax.lax.fori_loop(0, k, body, init)
    prob = jnp.where(assigned, prob, 1.0)
    alias = jnp.where(assigned, alias, idx[None, :])
    return prob, alias


def _alias_build_kernel(p_ref, prob_ref, alias_ref, mass_ref):
    p = p_ref[...].astype(jnp.float32)                 # (TILE_R, K)
    k = p.shape[-1]
    mass = jnp.sum(p, axis=-1)                         # (TILE_R,)
    safe = mass > 0
    pn = jnp.where(safe[:, None], p / jnp.where(safe, mass, 1.0)[:, None],
                   jnp.full_like(p, 1.0 / k))
    prob, alias = _build_tile(pn * k)
    prob_ref[...] = prob
    alias_ref[...] = alias
    mass_ref[...] = mass.astype(jnp.float32)


def _alias_build_fused_kernel(n_wk_ref, n_k_ref, prob_ref, alias_ref,
                              mass_ref, *, alpha, beta, beta_bar):
    """Fused: dense term α(n_wk+β)/(n_k+β̄) computed in-register."""
    n_wk = n_wk_ref[...].astype(jnp.float32)           # (TILE_R, K)
    n_k = n_k_ref[...].astype(jnp.float32)             # (1, K) broadcast row
    p = alpha * (n_wk + beta) / (n_k + beta_bar)
    k = p.shape[-1]
    mass = jnp.sum(p, axis=-1)
    pn = p / mass[:, None]
    prob, alias = _build_tile(pn * k)
    prob_ref[...] = prob
    alias_ref[...] = alias
    mass_ref[...] = mass.astype(jnp.float32)


def _alias_build_tiled_kernel(p_ref, prob_ref, alias_ref, mass_ref,
                              p_s, prob_s, alias_s, *, tile_k: int):
    """Two-phase K-streamed build (grid (nr, 2, nk), lexicographic order):
    phase 0 stages the row tile's input k-tiles into full-K scratch;
    phase 1 runs the pairing once (at its first k-tile step) on the
    staged rows and flushes the result back out one k-tile per step.
    Walker pairing moves probability mass between arbitrary outcome
    columns, so the *build state* is irreducibly full-K per row — the
    streaming bounds the in/out block residency, not the scratch.

    Output blocks written during phase 0 hold garbage; the grid revisits
    every (row, k-tile) output block in phase 1 after all of that row's
    phase-0 steps (the phase axis is major to the k axis), so the
    phase-1 flush is the one that lands."""
    pi = pl.program_id(1)
    ki = pl.program_id(2)
    ksl = pl.ds(ki * tile_k, tile_k)

    @pl.when(pi == 0)
    def _stage():
        p_s[:, ksl] = p_ref[...].astype(jnp.float32)

    @pl.when((pi == 1) & (ki == 0))
    def _build():
        p = p_s[...]
        k = p.shape[-1]
        mass = jnp.sum(p, axis=-1)
        safe = mass > 0
        pn = jnp.where(safe[:, None],
                       p / jnp.where(safe, mass, 1.0)[:, None],
                       jnp.full_like(p, 1.0 / k))
        prob, alias = _build_tile(pn * k)
        prob_s[...] = prob
        alias_s[...] = alias
        mass_ref[...] = mass.astype(jnp.float32)

    @pl.when(pi == 1)
    def _flush():
        prob_ref[...] = prob_s[:, ksl]
        alias_ref[...] = alias_s[:, ksl]


@functools.partial(jax.jit, static_argnames=("tile_r", "tile_k", "interpret"))
def alias_build(p: jax.Array, *, tile_r: int = DEFAULT_TILE_R,
                tile_k: int | None = None, interpret: bool = True):
    """Build alias tables for (V, K) rows. Returns (prob, alias, mass).

    ``tile_k`` (None ⇒ K) streams the input and output K dimension in
    (tile_r, tile_k) blocks through the two-phase kernel; the build math
    is identical either way (the pairing always sees the full row), so
    tiled and untiled tables are bit-identical."""
    v, k = p.shape
    assert v % tile_r == 0, f"V={v} must be a multiple of tile_r={tile_r}"
    out_shape = [
        jax.ShapeDtypeStruct((v, k), jnp.float32),
        jax.ShapeDtypeStruct((v, k), jnp.int32),
        jax.ShapeDtypeStruct((v,), jnp.float32),
    ]
    if tile_k is None or tile_k >= k:
        return pl.pallas_call(
            _alias_build_kernel,
            grid=(v // tile_r,),
            in_specs=[pl.BlockSpec((tile_r, k), lambda i: (i, 0))],
            out_specs=[
                pl.BlockSpec((tile_r, k), lambda i: (i, 0)),
                pl.BlockSpec((tile_r, k), lambda i: (i, 0)),
                pl.BlockSpec((tile_r,), lambda i: (i,)),
            ],
            out_shape=out_shape,
            interpret=interpret,
        )(p)
    assert k % tile_k == 0, f"K={k} must be a multiple of tile_k={tile_k}"
    nk = k // tile_k
    kernel = functools.partial(_alias_build_tiled_kernel, tile_k=tile_k)
    return pl.pallas_call(
        kernel,
        grid=(v // tile_r, 2, nk),
        in_specs=[pl.BlockSpec((tile_r, tile_k), lambda i, pi, ki: (i, ki))],
        out_specs=[
            pl.BlockSpec((tile_r, tile_k), lambda i, pi, ki: (i, ki)),
            pl.BlockSpec((tile_r, tile_k), lambda i, pi, ki: (i, ki)),
            pl.BlockSpec((tile_r,), lambda i, pi, ki: (i,)),
        ],
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((tile_r, k), jnp.float32),   # staged input rows
            pltpu.VMEM((tile_r, k), jnp.float32),   # built prob rows
            pltpu.VMEM((tile_r, k), jnp.int32),     # built alias rows
        ],
        interpret=interpret,
    )(p)


def _alias_build_fused_tiled_kernel(n_wk_ref, n_k_ref, prob_ref, alias_ref,
                                    mass_ref, nwk_s, nk_s, prob_s, alias_s,
                                    *, tile_k: int, alpha, beta, beta_bar):
    """K-streamed fused build: phase 0 stages the *raw* statistics
    k-tiles; phase 1 computes the dense term on the full-K staged rows —
    the exact expression and shapes of :func:`_alias_build_fused_kernel`,
    so XLA emits the same rounding and tiled == untiled bit-for-bit —
    then runs the pairing and flushes one k-tile per step."""
    pi = pl.program_id(1)
    ki = pl.program_id(2)
    ksl = pl.ds(ki * tile_k, tile_k)

    @pl.when(pi == 0)
    def _stage():
        nwk_s[:, ksl] = n_wk_ref[...].astype(jnp.float32)
        nk_s[:, ksl] = n_k_ref[...].astype(jnp.float32)

    @pl.when((pi == 1) & (ki == 0))
    def _build():
        p = alpha * (nwk_s[...] + beta) / (nk_s[...] + beta_bar)
        k = p.shape[-1]
        mass = jnp.sum(p, axis=-1)
        pn = p / mass[:, None]
        prob, alias = _build_tile(pn * k)
        prob_s[...] = prob
        alias_s[...] = alias
        mass_ref[...] = mass.astype(jnp.float32)

    @pl.when(pi == 1)
    def _flush():
        prob_ref[...] = prob_s[:, ksl]
        alias_ref[...] = alias_s[:, ksl]


@functools.partial(jax.jit,
                   static_argnames=("alpha", "beta", "vocab_size", "tile_r",
                                    "tile_k", "interpret"))
def alias_build_fused(n_wk: jax.Array, n_k: jax.Array, *, alpha: float,
                      beta: float, vocab_size: int,
                      tile_r: int = DEFAULT_TILE_R,
                      tile_k: int | None = None, interpret: bool = True):
    """Fused dense-term + alias build from raw LDA statistics.

    ``tile_k`` (None ⇒ K) streams inputs and outputs in k-tiles as in
    :func:`alias_build`; the dense term and the pairing see identical
    values either way, so the tables are bit-identical."""
    v, k = n_wk.shape
    assert v % tile_r == 0
    out_shape = [
        jax.ShapeDtypeStruct((v, k), jnp.float32),
        jax.ShapeDtypeStruct((v, k), jnp.int32),
        jax.ShapeDtypeStruct((v,), jnp.float32),
    ]
    if tile_k is None or tile_k >= k:
        kernel = functools.partial(_alias_build_fused_kernel, alpha=alpha,
                                   beta=beta, beta_bar=beta * vocab_size)
        return pl.pallas_call(
            kernel,
            grid=(v // tile_r,),
            in_specs=[
                pl.BlockSpec((tile_r, k), lambda i: (i, 0)),
                pl.BlockSpec((1, k), lambda i: (0, 0)),
            ],
            out_specs=[
                pl.BlockSpec((tile_r, k), lambda i: (i, 0)),
                pl.BlockSpec((tile_r, k), lambda i: (i, 0)),
                pl.BlockSpec((tile_r,), lambda i: (i,)),
            ],
            out_shape=out_shape,
            interpret=interpret,
        )(n_wk, n_k.reshape(1, -1))
    assert k % tile_k == 0, f"K={k} must be a multiple of tile_k={tile_k}"
    nk = k // tile_k
    kernel = functools.partial(_alias_build_fused_tiled_kernel,
                               tile_k=tile_k, alpha=alpha, beta=beta,
                               beta_bar=beta * vocab_size)
    return pl.pallas_call(
        kernel,
        grid=(v // tile_r, 2, nk),
        in_specs=[
            pl.BlockSpec((tile_r, tile_k), lambda i, pi, ki: (i, ki)),
            pl.BlockSpec((1, tile_k), lambda i, pi, ki: (0, ki)),
        ],
        out_specs=[
            pl.BlockSpec((tile_r, tile_k), lambda i, pi, ki: (i, ki)),
            pl.BlockSpec((tile_r, tile_k), lambda i, pi, ki: (i, ki)),
            pl.BlockSpec((tile_r,), lambda i, pi, ki: (i,)),
        ],
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((tile_r, k), jnp.float32),   # staged n_wk rows
            pltpu.VMEM((1, k), jnp.float32),        # staged n_k row
            pltpu.VMEM((tile_r, k), jnp.float32),   # built prob rows
            pltpu.VMEM((tile_r, k), jnp.int32),     # built alias rows
        ],
        interpret=interpret,
    )(n_wk, n_k.reshape(1, -1))


@functools.partial(jax.jit, static_argnames=("tile_r", "tile_k", "interpret"))
def alias_build_rows(p: jax.Array, *, tile_r: int = DEFAULT_TILE_R,
                     tile_k: int | None = None, interpret: bool = True):
    """Alias build over a compacted (R, K) row block — the gathered changed
    rows of an incremental rebuild.  R need not be a tile_r multiple (rows
    are padded with zero mass, which the kernel's uniform fallback absorbs,
    and trimmed from the outputs)."""
    r, k = p.shape
    pad = (-r) % tile_r
    p_pad = jnp.pad(p, ((0, pad), (0, 0))) if pad else p
    prob, alias, mass = alias_build(p_pad, tile_r=min(tile_r, r + pad),
                                    tile_k=tile_k, interpret=interpret)
    return prob[:r], alias[:r], mass[:r]


def _alias_build_gather_kernel(rows_ref, n_wk_ref, n_k_ref, prior_ref,
                               prob_ref, alias_ref, mass_ref, stale_ref,
                               *, beta, beta_bar):
    """One gathered row per program: the scalar-prefetched row index drives
    the n_wk index map (the gather *is* the DMA), the dense term
    prior_e·(n_wk+β)/(n_k+β̄) is computed in-register, and the freshly built
    table row plus the dense row (the stale-snapshot update) are written to
    the compacted outputs."""
    del rows_ref  # consumed by the index maps
    n_wk = n_wk_ref[...].astype(jnp.float32)           # (1, K) gathered row
    n_k = n_k_ref[...].astype(jnp.float32)             # (1, K)
    # prior · (LM row), division grouped first — the exact operation order
    # of the families' dense_probs, so partial rebuilds are bit-identical
    # to a full rebuild of the same statistics.
    p = prior_ref[...] * ((n_wk + beta) / (n_k + beta_bar))
    k = p.shape[-1]
    mass = jnp.sum(p, axis=-1)                         # (1,)
    safe = mass > 0
    pn = jnp.where(safe[:, None], p / jnp.where(safe, mass, 1.0)[:, None],
                   jnp.full_like(p, 1.0 / k))
    prob, alias = _build_tile(pn * k)
    prob_ref[...] = prob
    alias_ref[...] = alias
    mass_ref[...] = mass.astype(jnp.float32)
    stale_ref[...] = p


@functools.partial(jax.jit,
                   static_argnames=("beta", "beta_bar", "interpret"))
def alias_build_gather_fused(n_wk: jax.Array, n_k: jax.Array,
                             prior: jax.Array, rows: jax.Array, *,
                             beta: float, beta_bar: float,
                             interpret: bool = True):
    """Gather → fused dense-term + alias build over changed rows only.

    ``prior`` is the (K,) per-topic prior-mass vector of the dense proposal
    (α·1 for LDA, b1·θ0 for HDP), so one kernel serves every family whose
    dense term factorizes as prior_e · LM row.  ``rows`` is the (R,) int32
    changed-row selection.  Returns compacted (prob, alias, mass, dense)
    rows of shapes (R, K)/(R, K)/(R,)/(R, K) for the caller to scatter
    (``repro.core.alias.update_rows``).
    """
    v, k = n_wk.shape
    r = rows.shape[0]
    kernel = functools.partial(_alias_build_gather_kernel, beta=beta,
                               beta_bar=beta_bar)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i, rows: (rows[i], 0)),
            pl.BlockSpec((1, k), lambda i, rows: (0, 0)),
            pl.BlockSpec((1, k), lambda i, rows: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i, rows: (i, 0)),
            pl.BlockSpec((1, k), lambda i, rows: (i, 0)),
            pl.BlockSpec((1,), lambda i, rows: (i,)),
            pl.BlockSpec((1, k), lambda i, rows: (i, 0)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((r, k), jnp.float32),
            jax.ShapeDtypeStruct((r, k), jnp.int32),
            jax.ShapeDtypeStruct((r,), jnp.float32),
            jax.ShapeDtypeStruct((r, k), jnp.float32),
        ],
        interpret=interpret,
    )(rows.astype(jnp.int32), n_wk, n_k.reshape(1, -1),
      prior.reshape(1, -1))
