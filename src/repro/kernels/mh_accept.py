"""Pallas TPU kernel: fused Metropolis-Hastings acceptance (paper eq. 7).

One MH step for a batch of tokens: given the point log-densities of the
target and proposal at the current state and the candidate, accept with
probability min(1, q(z)p(c) / (q(c)p(z))).  Elementwise and trivially
parallel — the value of the kernel is *fusion*: acceptance, the ratio, the
log of the uniform and the select retire in one VMEM pass instead of five
HBM-roundtrip ops.

This standalone step remains for callers that compute their own point
densities; the sorted sampling pipeline goes further and fuses the whole
chain — proposal draw, density gathers and acceptance — with the
table-tile residency in ``repro.kernels.mhw_fused`` (DESIGN.md §5.1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_B = 4096


def _mh_accept_kernel(z_ref, cand_ref, lp_z_ref, lp_c_ref, lq_z_ref,
                      lq_c_ref, u_ref, out_ref):
    log_ratio = (lp_c_ref[...] - lp_z_ref[...]
                 + lq_z_ref[...] - lq_c_ref[...])
    accept = jnp.log(u_ref[...] + 1e-30) < log_ratio
    out_ref[...] = jnp.where(accept, cand_ref[...], z_ref[...]).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def mh_accept(z, cand, log_p_z, log_p_cand, log_q_z, log_q_cand, u, *,
              tile_b: int = DEFAULT_TILE_B, interpret: bool = True):
    """Fused accept/reject: all inputs (B,); returns (B,) int32 new states."""
    b = z.shape[0]
    tile_b = min(tile_b, b)
    assert b % tile_b == 0
    grid = (b // tile_b,)
    spec = pl.BlockSpec((tile_b,), lambda i: (i,))
    return pl.pallas_call(
        _mh_accept_kernel,
        grid=grid,
        in_specs=[spec] * 7,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=interpret,
    )(z, cand, log_p_z, log_p_cand, log_q_z, log_q_cand, u)
