"""Pallas TPU kernel: blocked O(1) alias-table draws.

Consumer half of the paper's §5.1 producer/consumer sampler: given prebuilt
(prob, alias) tables, each token draws from the table of its own token-type
row using two uniforms — slot choice and the biased coin.

TPU adaptation: a flat gather ``prob[rows[b], slot[b]]`` would need the
whole (V, K) table resident, which does not fit VMEM at production sizes
(2M types × 2K topics).  Instead the kernel runs a 2-D grid over
(vocab tiles × batch tiles): each program holds one (TILE_V, K) table tile
in VMEM and resolves exactly the draws whose row falls inside its tile,
accumulating into the output block with a mask.  The batch-tile output
block is revisited across vocab tiles (same index map), which Pallas
supports as an accumulation pattern.

Work is O(B · V/TILE_V) predicate evaluations — VPU-trivial — while HBM
traffic stays one pass over the table + one pass over the draws, which is
what the roofline cares about.  In production the driver sorts draws by
token-type (documents arrive word-major after the shard build) so most
(vocab, batch) tile pairs are empty; a future refinement can skip them with
a scalar-prefetch row histogram.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE_V = 64
DEFAULT_TILE_B = 1024


def _alias_sample_kernel(rows_ref, slot_ref, coin_ref, prob_ref, alias_ref,
                         out_ref, *, tile_v: int):
    vi = pl.program_id(0)
    row_lo = vi * tile_v

    rows = rows_ref[...]                          # (TILE_B,)
    slot = slot_ref[...]
    coin = coin_ref[...]
    prob = prob_ref[...]                          # (TILE_V, K)
    alias = alias_ref[...]

    local = rows - row_lo
    in_tile = (local >= 0) & (local < tile_v)
    safe_local = jnp.clip(local, 0, tile_v - 1)

    p = prob[safe_local, slot]
    a = alias[safe_local, slot]
    draw = jnp.where(coin < p, slot, a).astype(jnp.int32)

    @pl.when(vi == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] = jnp.where(in_tile, draw, out_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("tile_v", "tile_b", "interpret"))
def alias_sample(prob: jax.Array, alias: jax.Array, rows: jax.Array,
                 slot: jax.Array, coin: jax.Array, *,
                 tile_v: int = DEFAULT_TILE_V,
                 tile_b: int = DEFAULT_TILE_B,
                 interpret: bool = True) -> jax.Array:
    """Blocked alias draws.

    prob/alias: (V, K) tables; rows/slot/coin: (B,) per-draw row id, slot
    uniform (int in [0,K)) and coin uniform (float in [0,1)).  Returns (B,)
    int32 draws.  RNG stays outside the kernel so the kernel is a pure
    function of its inputs (exactly comparable to the oracle).
    """
    v, k = prob.shape
    b = rows.shape[0]
    tile_v = min(tile_v, v)
    tile_b = min(tile_b, b)
    assert v % tile_v == 0 and b % tile_b == 0
    grid = (v // tile_v, b // tile_b)
    kernel = functools.partial(_alias_sample_kernel, tile_v=tile_v)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b,), lambda vi, bi: (bi,)),
            pl.BlockSpec((tile_b,), lambda vi, bi: (bi,)),
            pl.BlockSpec((tile_b,), lambda vi, bi: (bi,)),
            pl.BlockSpec((tile_v, k), lambda vi, bi: (vi, 0)),
            pl.BlockSpec((tile_v, k), lambda vi, bi: (vi, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b,), lambda vi, bi: (bi,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=interpret,
    )(rows, slot, coin, prob, alias)
