"""Pallas TPU kernels: blocked O(1) alias-table draws.

Consumer half of the paper's §5.1 producer/consumer sampler: given prebuilt
(prob, alias) tables, each token draws from the table of its own token-type
row using two uniforms — slot choice and the biased coin.

TPU adaptation: a flat gather ``prob[rows[b], slot[b]]`` would need the
whole (V, K) table resident, which does not fit VMEM at production sizes
(2M types × 2K topics).  Instead the kernels run a 2-D grid over
(vocab tiles × batch tiles): each program holds one (TILE_V, K) table tile
in VMEM and resolves exactly the draws whose row falls inside its tile,
accumulating into the output block with a mask.  The batch-tile output
block is revisited across vocab tiles (same index map), which Pallas
supports as an accumulation pattern.

Two variants:

* :func:`alias_sample` — layout-oblivious scan: every (vocab, batch) tile
  pair is visited, O(B · V/TILE_V) predicate work.  Kept as the oracle and
  for unsorted draw streams.
* :func:`alias_sample_sorted` — consumes the token-sorted layout of
  ``repro.data.segment`` (DESIGN.md §5): a scalar-prefetched per-batch-tile
  vocab-tile window (``vstart``/``vcount``) drives the table-tile index map,
  so programs whose tile holds zero resident draws neither DMA a fresh tile
  (the index map re-points at the previous tile) nor run the body
  (``pl.when``).  Tile-predicate work drops to ~O(B): each batch tile only
  really visits the few vocab tiles its contiguous sorted row-range spans.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TILE_V = 64
DEFAULT_TILE_B = 1024


def _alias_sample_kernel(rows_ref, slot_ref, coin_ref, prob_ref, alias_ref,
                         out_ref, *, tile_v: int):
    vi = pl.program_id(0)
    row_lo = vi * tile_v

    rows = rows_ref[...]                          # (TILE_B,)
    slot = slot_ref[...]
    coin = coin_ref[...]
    prob = prob_ref[...]                          # (TILE_V, K)
    alias = alias_ref[...]

    local = rows - row_lo
    in_tile = (local >= 0) & (local < tile_v)
    safe_local = jnp.clip(local, 0, tile_v - 1)

    p = prob[safe_local, slot]
    a = alias[safe_local, slot]
    draw = jnp.where(coin < p, slot, a).astype(jnp.int32)

    @pl.when(vi == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] = jnp.where(in_tile, draw, out_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("tile_v", "tile_b", "interpret"))
def alias_sample(prob: jax.Array, alias: jax.Array, rows: jax.Array,
                 slot: jax.Array, coin: jax.Array, *,
                 tile_v: int = DEFAULT_TILE_V,
                 tile_b: int = DEFAULT_TILE_B,
                 interpret: bool = True) -> jax.Array:
    """Blocked alias draws (full tile scan).

    prob/alias: (V, K) tables; rows/slot/coin: (B,) per-draw row id, slot
    uniform (int in [0,K)) and coin uniform (float in [0,1)).  Returns (B,)
    int32 draws.  RNG stays outside the kernel so the kernel is a pure
    function of its inputs (exactly comparable to the oracle).
    """
    v, k = prob.shape
    b = rows.shape[0]
    tile_v = min(tile_v, v)
    tile_b = min(tile_b, b)
    assert v % tile_v == 0 and b % tile_b == 0
    grid = (v // tile_v, b // tile_b)
    kernel = functools.partial(_alias_sample_kernel, tile_v=tile_v)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b,), lambda vi, bi: (bi,)),
            pl.BlockSpec((tile_b,), lambda vi, bi: (bi,)),
            pl.BlockSpec((tile_b,), lambda vi, bi: (bi,)),
            pl.BlockSpec((tile_v, k), lambda vi, bi: (vi, 0)),
            pl.BlockSpec((tile_v, k), lambda vi, bi: (vi, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b,), lambda vi, bi: (bi,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=interpret,
    )(rows, slot, coin, prob, alias)


# ---------------------------------------------------------------------------
# Token-sorted, tile-skipping variant (scalar prefetch)
# ---------------------------------------------------------------------------

def _alias_sample_sorted_kernel(vstart_ref, vcount_ref, rows_ref, slot_ref,
                                coin_ref, prob_ref, alias_ref, out_ref, *,
                                tile_v: int, n_vtiles: int):
    bi = pl.program_id(0)
    vi = pl.program_id(1)
    tid = jnp.clip(vstart_ref[bi] + jnp.minimum(vi, vcount_ref[bi] - 1),
                   0, n_vtiles - 1)
    row_lo = tid * tile_v

    @pl.when(vi == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(vi < vcount_ref[bi])
    def _body():
        rows = rows_ref[...]
        local = rows - row_lo
        in_tile = (local >= 0) & (local < tile_v)
        safe_local = jnp.clip(local, 0, tile_v - 1)
        p = prob_ref[...][safe_local, slot_ref[...]]
        a = alias_ref[...][safe_local, slot_ref[...]]
        draw = jnp.where(coin_ref[...] < p, slot_ref[...], a).astype(jnp.int32)
        out_ref[...] = jnp.where(in_tile, draw, out_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("tile_v", "tile_b", "interpret"))
def alias_sample_sorted(prob: jax.Array, alias: jax.Array, rows: jax.Array,
                        slot: jax.Array, coin: jax.Array, vstart: jax.Array,
                        vcount: jax.Array, *,
                        tile_v: int = DEFAULT_TILE_V,
                        tile_b: int = DEFAULT_TILE_B,
                        interpret: bool = True) -> jax.Array:
    """Tile-skipping alias draws over a token-sorted stream.

    rows must be sorted ascending (``segment.build_layout``); entries ≥ V
    are padding sentinels and return 0.  ``vstart``/``vcount``
    (B/tile_b,) give the contiguous vocab-tile window of each batch tile;
    programs outside the window are skipped (no DMA, no body) so the work
    is proportional to the number of *occupied* tile pairs, not the grid.
    """
    v, k = prob.shape
    b = rows.shape[0]
    tile_v = min(tile_v, v)
    tile_b = min(tile_b, b)
    assert v % tile_v == 0 and b % tile_b == 0
    nb, nv = b // tile_b, v // tile_v
    assert vstart.shape == (nb,) and vcount.shape == (nb,)

    kernel = functools.partial(_alias_sample_sorted_kernel, tile_v=tile_v,
                               n_vtiles=nv)

    def table_map(bi, vi, vs, vc):
        return (jnp.clip(vs[bi] + jnp.minimum(vi, vc[bi] - 1), 0, nv - 1), 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nb, nv),
        in_specs=[
            pl.BlockSpec((tile_b,), lambda bi, vi, vs, vc: (bi,)),
            pl.BlockSpec((tile_b,), lambda bi, vi, vs, vc: (bi,)),
            pl.BlockSpec((tile_b,), lambda bi, vi, vs, vc: (bi,)),
            pl.BlockSpec((tile_v, k), table_map),
            pl.BlockSpec((tile_v, k), table_map),
        ],
        out_specs=pl.BlockSpec((tile_b,), lambda bi, vi, vs, vc: (bi,)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=interpret,
    )(vstart, vcount, rows, slot, coin, prob, alias)
