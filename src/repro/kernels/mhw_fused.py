"""Pallas TPU kernels: fused MHW sweep steps over the token-sorted layout.

One program = one (batch-tile, resident-vocab-tile) pair of the sorted
stream (``repro.data.segment``).  With the (TILE_V, E) table tile — alias
``prob``/``alias``/``mass`` rows, the stale dense matrix and the *fresh*
shared-statistic rows — resident in VMEM, the whole per-token MH chain of
paper §3 retires in a single residency:

  1. the fresh per-outcome factor f is computed from the resident tile —
     each word-topic row is touched once per (batch-tile, vocab-tile) pair
     instead of once per scan position;
  2. the sparse+dense mixture proposal (paper eq. 4): document-sparse term
     via an inverse-CDF draw over the E lanes, corpus-dense term via the
     alias-table slot/coin draw;
  3. the stale-q point gathers and the MH acceptance coin (paper eq. 7).

Unfused, steps 2–3 are five HBM round trips per MH step (proposal draw,
two q gathers, two p gathers) plus a fresh statistics gather per token;
fused they are VMEM reads.  Grid programs outside a batch tile's vocab
window are skipped via scalar prefetch exactly as in ``alias_sample_sorted``.

Two kernels instantiate the ``ModelFamily`` dense-proposal factorization
(p(e) ∝ (doc_e + prior_e)·f_e, see ``repro.core.mhw``):

* :func:`mhw_sweep_fused` — lm families (LDA, HDP-LDA): E = K outcomes,
  f = (n_wk − own + β)/(n_k − own + β̄), per-topic ``prior`` vector
  (α·1 for LDA, b1·θ0 for HDP).  Oracle: ``mhw.sorted_chain``.
* :func:`pdp_sweep_fused` — PDP: E = 2K joint (topic, table-indicator)
  outcomes, f = the generalized-Stirling-ratio factors of paper eqs. (5)-(6)
  computed from resident (m_wk, s_wk) tiles plus the VMEM-resident
  log-Stirling table.  Oracle: ``pdp.sorted_chain_pdp``.

Both kernels delegate the chain itself to ``mhw.mix_chain`` — the same
function their oracles call — so kernel and oracle are bit-identical given
the same uniforms (tests/test_sorted_sweep.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Shared with the oracles: the bit-exactness contract requires kernels and
# oracles to run the identical chain math on identical factor values.
from repro.core.mhw import _EPS, mix_chain
from repro.core.pdp import corrected_rows, log_factors, own_contrib
from repro.kernels.alias_sample import DEFAULT_TILE_B, DEFAULT_TILE_V


def _index_maps(nv: int, nk: int):
    """BlockSpec index maps shared by both sorted-layout kernels: per-batch
    blocks, per-step uniform blocks, whole-array residents, the
    scalar-prefetched vocab-tile-window maps (the tile-skip re-point), and
    the K-tile maps of the ``tile_k`` staging axis (grid axis 2, minor)."""
    def vtile(bi, vi, vs, vc):
        return jnp.clip(vs[bi] + jnp.minimum(vi, vc[bi] - 1), 0, nv - 1)

    def bmap(bi, vi, ki, vs, vc):
        return (bi,)

    def bmap2(bi, vi, ki, vs, vc):
        return (bi, 0)

    def smap(bi, vi, ki, vs, vc):
        return (0, bi)

    def fullmap(bi, vi, ki, vs, vc):
        return (0, 0)

    def vmapk(bi, vi, ki, vs, vc):
        # (vocab-tile, k-tile) table block — the (tile_v, tile_k) residency
        # that replaces the (tile_v, K) one.
        return (vtile(bi, vi, vs, vc), ki)

    def vmapk_clip(bi, vi, ki, vs, vc):
        # (V, K) statistics under a 2K-outcome e-tile axis: k-tiles exist
        # only for the first nk e-tiles; later steps re-fetch the last one
        # (the kernel guards the stage, the map just has to stay in range).
        return (vtile(bi, vi, vs, vc), jnp.minimum(ki, nk - 1))

    def vmap1(bi, vi, ki, vs, vc):
        return (vtile(bi, vi, vs, vc),)

    return bmap, bmap2, smap, fullmap, vmapk, vmapk_clip, vmap1


def _mhw_fused_kernel(vstart_ref, vcount_ref, rows_ref, z_ref, ndk_ref,
                      slot_ref, coin_ref, umix_ref, usp_ref, uacc_ref,
                      prob_ref, alias_ref, mass_ref, stale_ref, nwk_ref,
                      nk_ref, prior_ref, out_ref, nwk_s, stale_s, prob_s,
                      alias_s, *, tile_v: int, n_vtiles: int, tile_k: int,
                      n_ktiles: int, beta: float, beta_bar: float):
    bi = pl.program_id(0)
    vi = pl.program_id(1)
    ki = pl.program_id(2)
    tid = jnp.clip(vstart_ref[bi] + jnp.minimum(vi, vcount_ref[bi] - 1),
                   0, n_vtiles - 1)
    row_lo = tid * tile_v

    rows = rows_ref[...]                           # (TILE_B,) sorted rows
    local = rows - row_lo
    in_tile = (local >= 0) & (local < tile_v)
    lidx = jnp.clip(local, 0, tile_v - 1)

    @pl.when((vi == 0) & (ki == 0))
    def _init():
        out_ref[...] = z_ref[...]

    @pl.when(vi < vcount_ref[bi])
    def _stage():
        # Stage this (tile_v, tile_k) table block's per-token gathers into
        # the full-K VMEM scratch.  Pure data movement: column tiles of
        # the same gathered rows concatenate to exactly the rows the
        # untiled kernel gathers, so tiling cannot perturb the chain.
        ksl = pl.ds(ki * tile_k, tile_k)
        nwk_s[:, ksl] = nwk_ref[...][lidx]
        stale_s[:, ksl] = stale_ref[...][lidx]
        prob_s[:, ksl] = prob_ref[...][lidx]
        alias_s[:, ksl] = alias_ref[...][lidx]

    @pl.when((vi < vcount_ref[bi]) & (ki == n_ktiles - 1))
    def _body():
        z0 = z_ref[...]                            # (TILE_B,) chain init
        k_topics = ndk_ref.shape[-1]

        # ^{-di} correction in-kernel: remove the token's own contribution
        # from its doc row, its n_wk row and the topic totals (as in the
        # scan path) — callers pass *raw* gathered n_dk rows.
        karange = jax.lax.broadcasted_iota(jnp.int32, (1, k_topics), 1)
        own = ((karange == z0[:, None]) & in_tile[:, None]).astype(jnp.float32)
        ndk = ndk_ref[...] - own                   # (TILE_B, K)
        rows_wk = nwk_s[...]                       # (TILE_B, K) staged
        lm = (rows_wk - own + beta) / (nk_ref[...] - own + beta_bar)

        z = mix_chain(
            z0, doc=ndk, prior=prior_ref[...][0], logf=jnp.log(lm + _EPS),
            sparse_w=ndk * lm, stale_rows=stale_s[...],
            prob_rows=prob_s[...], alias_rows=alias_s[...],
            dense_mass=mass_ref[...][lidx], slot=slot_ref[...],
            coin=coin_ref[...], u_mix=umix_ref[...], u_sparse=usp_ref[...],
            u_acc=uacc_ref[...])

        out_ref[...] = jnp.where(in_tile, z.astype(jnp.int32), out_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("tile_v", "tile_b", "tile_k", "n_steps",
                                    "beta", "beta_bar", "interpret"))
def mhw_sweep_fused(prob: jax.Array, alias: jax.Array, mass: jax.Array,
                    stale: jax.Array, n_wk: jax.Array, n_k: jax.Array,
                    prior: jax.Array, rows: jax.Array, z0: jax.Array,
                    ndk: jax.Array, slot: jax.Array, coin: jax.Array,
                    u_mix: jax.Array, u_sparse: jax.Array, u_acc: jax.Array,
                    vstart: jax.Array, vcount: jax.Array, *,
                    tile_v: int = DEFAULT_TILE_V,
                    tile_b: int = DEFAULT_TILE_B,
                    tile_k: int | None = None,
                    n_steps: int = 2, beta: float = 0.01,
                    beta_bar: float | None = None,
                    interpret: bool = True) -> jax.Array:
    """Fused sorted-layout MHW chain for one sweep — lm families (LDA/HDP).

    prob/alias/stale/n_wk: (V, K); mass: (V,); n_k: (K,); prior: (K,)
    per-topic prior mass (α·1 for LDA, b1·θ0 for HDP).
    rows/z0: (B,) sorted token-types (≥V ⇒ padding, left at z0) and chain
    init; ndk: (B, K) *raw* gathered doc-topic rows per sorted draw (the
    ^{-di} removal happens in-kernel).  slot/coin/u_mix/u_sparse/u_acc:
    (n_steps, B) per-MH-step uniforms (slot is int32 in [0, K)).
    vstart/vcount: (B/tile_b,) vocab-tile windows from
    ``segment.build_layout``.  Returns (B,) int32 final states.

    ``tile_k`` (None ⇒ K) adds the K-tile *staging* axis: the (V, K)
    tables stream through VMEM in (tile_v, tile_k) blocks whose per-token
    gathers accumulate into full-K scratch; the chain itself — which
    needs the full K row per token (cumsum proposal CDF, arbitrary-index
    gathers) — runs once per (batch, vocab) tile on the staged scratch,
    bit-identical to the untiled kernel.  Table VMEM residency drops from
    (tile_v, K) to (tile_v, tile_k); the (tile_b, K) per-token state is
    the floor, so shrink ``tile_b`` as K grows (``segment.pick_tile_vmem``).
    """
    v, k = prob.shape
    b = rows.shape[0]
    tile_v = min(tile_v, v)
    tile_b = min(tile_b, b)
    tile_k = k if tile_k is None else min(tile_k, k)
    assert v % tile_v == 0 and b % tile_b == 0
    assert k % tile_k == 0, f"K={k} must be a multiple of tile_k={tile_k}"
    nb, nv, nk = b // tile_b, v // tile_v, k // tile_k
    assert vstart.shape == (nb,) and vcount.shape == (nb,)
    if beta_bar is None:
        beta_bar = beta * v

    kernel = functools.partial(_mhw_fused_kernel, tile_v=tile_v, n_vtiles=nv,
                               tile_k=tile_k, n_ktiles=nk,
                               beta=beta, beta_bar=beta_bar)
    bmap, bmap2, smap, fullmap, vmapk, _, vmap1 = _index_maps(nv, nk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nb, nv, nk),
        in_specs=[
            pl.BlockSpec((tile_b,), bmap),           # rows
            pl.BlockSpec((tile_b,), bmap),           # z0
            pl.BlockSpec((tile_b, k), bmap2),        # ndk
            pl.BlockSpec((n_steps, tile_b), smap),   # slot
            pl.BlockSpec((n_steps, tile_b), smap),   # coin
            pl.BlockSpec((n_steps, tile_b), smap),   # u_mix
            pl.BlockSpec((n_steps, tile_b), smap),   # u_sparse
            pl.BlockSpec((n_steps, tile_b), smap),   # u_acc
            pl.BlockSpec((tile_v, tile_k), vmapk),   # prob
            pl.BlockSpec((tile_v, tile_k), vmapk),   # alias
            pl.BlockSpec((tile_v,), vmap1),          # mass
            pl.BlockSpec((tile_v, tile_k), vmapk),   # stale
            pl.BlockSpec((tile_v, tile_k), vmapk),   # n_wk
            pl.BlockSpec((1, k), fullmap),           # n_k
            pl.BlockSpec((1, k), fullmap),           # prior
        ],
        out_specs=pl.BlockSpec((tile_b,), bmap),
        scratch_shapes=[
            pltpu.VMEM((tile_b, k), jnp.float32),    # staged n_wk gathers
            pltpu.VMEM((tile_b, k), jnp.float32),    # staged stale gathers
            pltpu.VMEM((tile_b, k), jnp.float32),    # staged prob gathers
            pltpu.VMEM((tile_b, k), jnp.int32),      # staged alias gathers
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=interpret,
    )(vstart, vcount, rows, z0, ndk, slot, coin, u_mix, u_sparse, u_acc,
      prob, alias, mass, stale, n_wk, n_k.reshape(1, -1),
      prior.reshape(1, -1))


# ---------------------------------------------------------------------------
# PDP: joint (topic, table-indicator) outcomes e = t + K·r  (paper §2.2)
# ---------------------------------------------------------------------------


def _pdp_fused_kernel(vstart_ref, vcount_ref, rows_ref, e_ref, ndk_ref,
                      slot_ref, coin_ref, umix_ref, usp_ref, uacc_ref,
                      prob_ref, alias_ref, mass_ref, stale_ref, mwk_ref,
                      swk_ref, mk_ref, sk_ref, prior_ref, stirl_ref, out_ref,
                      mwk_s, swk_s, stale_s, prob_s, alias_s,
                      *, tile_v: int, n_vtiles: int, tile_k: int,
                      n_ktiles: int, b: float, a: float,
                      gamma: float, gamma_bar: float):
    bi = pl.program_id(0)
    vi = pl.program_id(1)
    ei = pl.program_id(2)          # e-tile over the 2K joint outcomes
    n_etiles = 2 * n_ktiles
    tid = jnp.clip(vstart_ref[bi] + jnp.minimum(vi, vcount_ref[bi] - 1),
                   0, n_vtiles - 1)
    row_lo = tid * tile_v

    rows = rows_ref[...]
    local = rows - row_lo
    in_tile = (local >= 0) & (local < tile_v)
    lidx = jnp.clip(local, 0, tile_v - 1)

    @pl.when((vi == 0) & (ei == 0))
    def _init():
        out_ref[...] = e_ref[...]

    @pl.when(vi < vcount_ref[bi])
    def _stage_e():
        # The (V, 2K) joint-outcome tables stream one e-tile per step.
        esl = pl.ds(ei * tile_k, tile_k)
        stale_s[:, esl] = stale_ref[...][lidx]
        prob_s[:, esl] = prob_ref[...][lidx]
        alias_s[:, esl] = alias_ref[...][lidx]

    @pl.when((vi < vcount_ref[bi]) & (ei < n_ktiles))
    def _stage_k():
        # The (V, K) customer/table counts only have k-tiles for the
        # first half of the e axis (their index map clips past it).
        ksl = pl.ds(ei * tile_k, tile_k)
        mwk_s[:, ksl] = mwk_ref[...][lidx]
        swk_s[:, ksl] = swk_ref[...][lidx]

    @pl.when((vi < vcount_ref[bi]) & (ei == n_etiles - 1))
    def _body():
        e0 = e_ref[...]                            # (TILE_B,) joint outcome
        k_topics = ndk_ref.shape[-1]

        # ^{-di}: remove the token's own customer/table contribution from
        # the gathered rows, the aggregates and its doc row, with the CRP
        # bookkeeping repair — same functions as the oracle.
        own_t, own_r = own_contrib(k_topics, e0, in_tile)
        m_row, s_row = corrected_rows(mwk_s[...], swk_s[...],
                                      own_t, own_r)
        m_k_m = mk_ref[...] - own_t                # (TILE_B, K) via broadcast
        s_k_m = sk_ref[...] - own_r

        log_f0, log_f1 = log_factors(stirl_ref[...], m_row, s_row, m_k_m,
                                     s_k_m, b=b, a=a, gamma=gamma,
                                     gamma_bar=gamma_bar)
        log_f = jnp.concatenate([log_f0, log_f1], axis=-1)   # (TILE_B, 2K)
        ndk_m = ndk_ref[...] - own_t
        ndk_ext = jnp.concatenate([ndk_m, ndk_m], axis=-1)

        e = mix_chain(
            e0, doc=ndk_ext, prior=prior_ref[...][0], logf=log_f,
            sparse_w=ndk_ext * jnp.exp(log_f),
            stale_rows=stale_s[...], prob_rows=prob_s[...],
            alias_rows=alias_s[...], dense_mass=mass_ref[...][lidx],
            slot=slot_ref[...], coin=coin_ref[...], u_mix=umix_ref[...],
            u_sparse=usp_ref[...], u_acc=uacc_ref[...])

        out_ref[...] = jnp.where(in_tile, e.astype(jnp.int32), out_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("tile_v", "tile_b", "tile_k", "n_steps",
                                    "b_conc", "a_disc", "gamma", "gamma_bar",
                                    "interpret"))
def pdp_sweep_fused(prob: jax.Array, alias: jax.Array, mass: jax.Array,
                    stale: jax.Array, m_wk: jax.Array, s_wk: jax.Array,
                    m_k: jax.Array, s_k: jax.Array, stirl: jax.Array,
                    prior: jax.Array, rows: jax.Array, e0: jax.Array,
                    ndk: jax.Array, slot: jax.Array, coin: jax.Array,
                    u_mix: jax.Array, u_sparse: jax.Array, u_acc: jax.Array,
                    vstart: jax.Array, vcount: jax.Array, *,
                    tile_v: int = DEFAULT_TILE_V,
                    tile_b: int = DEFAULT_TILE_B,
                    tile_k: int | None = None, n_steps: int = 2,
                    b_conc: float = 10.0, a_disc: float = 0.1,
                    gamma: float = 0.5, gamma_bar: float | None = None,
                    interpret: bool = True) -> jax.Array:
    """Fused sorted-layout MHW chain for one PDP sweep (2K outcomes).

    prob/alias/stale: (V, 2K) joint-outcome tables; mass: (V,);
    m_wk/s_wk: (V, K) customer/table counts; m_k/s_k: (K,); stirl: the
    log-Stirling table (resident in VMEM — ≤ (513, 513) fp32 ≈ 1 MB);
    prior: (2K,) = α·1.  rows/e0: (B,) sorted token-types and joint-outcome
    chain init; ndk: (B, K) raw gathered doc rows; uniforms (n_steps, B),
    slot int32 in [0, 2K).  Returns (B,) int32 final joint outcomes.

    ``tile_k`` (None ⇒ K) adds the staging axis as in
    :func:`mhw_sweep_fused`, here over ``2K/tile_k`` e-tiles: the (V, 2K)
    joint tables stage one (tile_v, tile_k) block per step, the (V, K)
    customer/table counts only during the first K/tile_k steps; the chain
    runs on the staged full-width scratch at the last e-tile, bit-exact
    with the untiled kernel.
    """
    v, e_out = prob.shape
    k = m_wk.shape[1]
    assert e_out == 2 * k
    bsz = rows.shape[0]
    tile_v = min(tile_v, v)
    tile_b = min(tile_b, bsz)
    tile_k = k if tile_k is None else min(tile_k, k)
    assert v % tile_v == 0 and bsz % tile_b == 0
    assert k % tile_k == 0, f"K={k} must be a multiple of tile_k={tile_k}"
    nb, nv, nk = bsz // tile_b, v // tile_v, k // tile_k
    assert vstart.shape == (nb,) and vcount.shape == (nb,)
    if gamma_bar is None:
        gamma_bar = gamma * v

    kernel = functools.partial(_pdp_fused_kernel, tile_v=tile_v, n_vtiles=nv,
                               tile_k=tile_k, n_ktiles=nk,
                               b=b_conc, a=a_disc, gamma=gamma,
                               gamma_bar=gamma_bar)
    bmap, bmap2, smap, fullmap, vmapk, vmapk_clip, vmap1 = _index_maps(nv, nk)

    s_dim = stirl.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nb, nv, 2 * nk),
        in_specs=[
            pl.BlockSpec((tile_b,), bmap),            # rows
            pl.BlockSpec((tile_b,), bmap),            # e0
            pl.BlockSpec((tile_b, k), bmap2),         # ndk
            pl.BlockSpec((n_steps, tile_b), smap),    # slot
            pl.BlockSpec((n_steps, tile_b), smap),    # coin
            pl.BlockSpec((n_steps, tile_b), smap),    # u_mix
            pl.BlockSpec((n_steps, tile_b), smap),    # u_sparse
            pl.BlockSpec((n_steps, tile_b), smap),    # u_acc
            pl.BlockSpec((tile_v, tile_k), vmapk),    # prob (e-tiles)
            pl.BlockSpec((tile_v, tile_k), vmapk),    # alias (e-tiles)
            pl.BlockSpec((tile_v,), vmap1),           # mass
            pl.BlockSpec((tile_v, tile_k), vmapk),    # stale (e-tiles)
            pl.BlockSpec((tile_v, tile_k), vmapk_clip),  # m_wk (k-tiles)
            pl.BlockSpec((tile_v, tile_k), vmapk_clip),  # s_wk (k-tiles)
            pl.BlockSpec((1, k), fullmap),            # m_k
            pl.BlockSpec((1, k), fullmap),            # s_k
            pl.BlockSpec((1, e_out), fullmap),        # prior
            pl.BlockSpec((s_dim, s_dim), fullmap),    # stirling table
        ],
        out_specs=pl.BlockSpec((tile_b,), bmap),
        scratch_shapes=[
            pltpu.VMEM((tile_b, k), jnp.float32),     # staged m_wk gathers
            pltpu.VMEM((tile_b, k), jnp.float32),     # staged s_wk gathers
            pltpu.VMEM((tile_b, e_out), jnp.float32),  # staged stale
            pltpu.VMEM((tile_b, e_out), jnp.float32),  # staged prob
            pltpu.VMEM((tile_b, e_out), jnp.int32),   # staged alias
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz,), jnp.int32),
        interpret=interpret,
    )(vstart, vcount, rows, e0, ndk, slot, coin, u_mix, u_sparse, u_acc,
      prob, alias, mass, stale, m_wk, s_wk, m_k.reshape(1, -1),
      s_k.reshape(1, -1), prior.reshape(1, -1), stirl)
