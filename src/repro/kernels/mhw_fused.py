"""Pallas TPU kernel: fused MHW sweep step over the token-sorted layout.

One program = one (batch-tile, resident-vocab-tile) pair of the sorted
stream (``repro.data.segment``).  With the (TILE_V, K) table tile — alias
``prob``/``alias``/``mass`` rows, the stale dense matrix and the *fresh*
``n_wk`` rows — resident in VMEM, the whole per-token MH chain of paper §3
retires in a single residency:

  1. fresh language-model rows  lm = (n_wk[w] − own + β)/(n_k − own + β̄)
     read from the resident tile — each word-topic row is touched once per
     (batch-tile, vocab-tile) pair instead of once per scan position;
  2. the sparse+dense mixture proposal (paper eq. 4): document-sparse term
     via an inverse-CDF draw over the K lanes, corpus-dense term via the
     alias-table slot/coin draw;
  3. the stale-q point gathers and the MH acceptance coin (paper eq. 7).

Unfused, steps 2–3 are five HBM round trips per MH step (proposal draw,
two q gathers, two p gathers) plus a fresh ``n_wk`` gather per token; fused
they are VMEM reads.  Grid programs outside a batch tile's vocab window are
skipped via scalar prefetch exactly as in ``alias_sample_sorted``.

``repro.core.mhw.sorted_chain`` is the pure-jnp oracle: identical formulas,
identical uniforms, bit-identical outputs (tests/test_sorted_sweep.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Shared with the oracle: the bit-exactness contract requires the kernel
# and mhw.sorted_chain to use the identical guard constant and gather.
from repro.core.mhw import _EPS, _gather_k
from repro.kernels.alias_sample import DEFAULT_TILE_B, DEFAULT_TILE_V


def _mhw_fused_kernel(vstart_ref, vcount_ref, rows_ref, z_ref, ndk_ref,
                      slot_ref, coin_ref, umix_ref, usp_ref, uacc_ref,
                      prob_ref, alias_ref, mass_ref, stale_ref, nwk_ref,
                      nk_ref, out_ref, *, tile_v: int, n_vtiles: int,
                      n_steps: int, alpha: float, beta: float,
                      beta_bar: float):
    bi = pl.program_id(0)
    vi = pl.program_id(1)
    tid = jnp.clip(vstart_ref[bi] + jnp.minimum(vi, vcount_ref[bi] - 1),
                   0, n_vtiles - 1)
    row_lo = tid * tile_v

    @pl.when(vi == 0)
    def _init():
        out_ref[...] = z_ref[...]

    @pl.when(vi < vcount_ref[bi])
    def _body():
        rows = rows_ref[...]                       # (TILE_B,) sorted rows
        local = rows - row_lo
        in_tile = (local >= 0) & (local < tile_v)
        lidx = jnp.clip(local, 0, tile_v - 1)

        z0 = z_ref[...]                            # (TILE_B,) chain init
        k_topics = ndk_ref.shape[-1]

        # ^{-di} correction in-kernel: remove the token's own contribution
        # from its doc row, its n_wk row and the topic totals (as in the
        # scan path) — callers pass *raw* gathered n_dk rows.
        karange = jax.lax.broadcasted_iota(jnp.int32, (1, k_topics), 1)
        own = ((karange == z0[:, None]) & in_tile[:, None]).astype(jnp.float32)
        ndk = ndk_ref[...] - own                   # (TILE_B, K)
        rows_wk = nwk_ref[...][lidx]               # (TILE_B, K)
        lm = (rows_wk - own + beta) / (nk_ref[...] - own + beta_bar)

        sparse_w = ndk * lm                        # exact sparse term
        cdf = jnp.cumsum(sparse_w, axis=-1)
        sparse_mass = cdf[:, -1]
        dense_mass = mass_ref[...][lidx]
        stale = stale_ref[...]                     # (TILE_V, K)
        ptile = prob_ref[...]
        atile = alias_ref[...]

        def log_p(t):
            return (jnp.log(_gather_k(ndk, t) + alpha)
                    + jnp.log(_gather_k(lm, t) + _EPS))

        def log_q(t):
            return jnp.log(_gather_k(sparse_w, t) + stale[lidx, t] + _EPS)

        z = z0
        lp_z = log_p(z)
        lq_z = log_q(z)
        for s in range(n_steps):
            slot = slot_ref[...][s]
            dense_draw = jnp.where(coin_ref[...][s] < ptile[lidx, slot],
                                   slot, atile[lidx, slot])
            target = usp_ref[...][s] * sparse_mass
            sparse_draw = jnp.clip(
                jnp.sum((cdf <= target[:, None]).astype(jnp.int32), axis=-1),
                0, k_topics - 1)
            pick_sparse = (umix_ref[...][s] * (sparse_mass + dense_mass)
                           < sparse_mass)
            cand = jnp.where(pick_sparse, sparse_draw,
                             dense_draw).astype(jnp.int32)
            lp_c = log_p(cand)
            lq_c = log_q(cand)
            accept = (jnp.log(uacc_ref[...][s] + _EPS)
                      < lp_c - lp_z + lq_z - lq_c)
            z = jnp.where(accept, cand, z)
            lp_z = jnp.where(accept, lp_c, lp_z)
            lq_z = jnp.where(accept, lq_c, lq_z)

        out_ref[...] = jnp.where(in_tile, z.astype(jnp.int32), out_ref[...])


@functools.partial(jax.jit,
                   static_argnames=("tile_v", "tile_b", "n_steps", "alpha",
                                    "beta", "beta_bar", "interpret"))
def mhw_sweep_fused(prob: jax.Array, alias: jax.Array, mass: jax.Array,
                    stale: jax.Array, n_wk: jax.Array, n_k: jax.Array,
                    rows: jax.Array, z0: jax.Array, ndk: jax.Array,
                    slot: jax.Array, coin: jax.Array, u_mix: jax.Array,
                    u_sparse: jax.Array, u_acc: jax.Array,
                    vstart: jax.Array, vcount: jax.Array, *,
                    tile_v: int = DEFAULT_TILE_V,
                    tile_b: int = DEFAULT_TILE_B,
                    n_steps: int = 2, alpha: float = 0.1, beta: float = 0.01,
                    beta_bar: float | None = None,
                    interpret: bool = True) -> jax.Array:
    """Fused sorted-layout MHW chain for one sweep.

    prob/alias/stale/n_wk: (V, K); mass: (V,); n_k: (K,).
    rows/z0: (B,) sorted token-types (≥V ⇒ padding, left at z0) and chain
    init; ndk: (B, K) own-token-removed doc-topic rows per sorted draw.
    slot/coin/u_mix/u_sparse/u_acc: (n_steps, B) per-MH-step uniforms
    (slot is int32 in [0, K)).  vstart/vcount: (B/tile_b,) vocab-tile
    windows from ``segment.build_layout``.  Returns (B,) int32 final states.
    """
    v, k = prob.shape
    b = rows.shape[0]
    tile_v = min(tile_v, v)
    tile_b = min(tile_b, b)
    assert v % tile_v == 0 and b % tile_b == 0
    nb, nv = b // tile_b, v // tile_v
    assert vstart.shape == (nb,) and vcount.shape == (nb,)
    if beta_bar is None:
        beta_bar = beta * v

    kernel = functools.partial(_mhw_fused_kernel, tile_v=tile_v, n_vtiles=nv,
                               n_steps=n_steps, alpha=alpha, beta=beta,
                               beta_bar=beta_bar)

    def bmap(bi, vi, vs, vc):
        return (bi,)

    def bmap2(bi, vi, vs, vc):
        return (bi, 0)

    def smap(bi, vi, vs, vc):
        return (0, bi)

    def vmap_(bi, vi, vs, vc):
        return (jnp.clip(vs[bi] + jnp.minimum(vi, vc[bi] - 1), 0, nv - 1), 0)

    def vmap1(bi, vi, vs, vc):
        return (jnp.clip(vs[bi] + jnp.minimum(vi, vc[bi] - 1), 0, nv - 1),)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nb, nv),
        in_specs=[
            pl.BlockSpec((tile_b,), bmap),           # rows
            pl.BlockSpec((tile_b,), bmap),           # z0
            pl.BlockSpec((tile_b, k), bmap2),        # ndk
            pl.BlockSpec((n_steps, tile_b), smap),   # slot
            pl.BlockSpec((n_steps, tile_b), smap),   # coin
            pl.BlockSpec((n_steps, tile_b), smap),   # u_mix
            pl.BlockSpec((n_steps, tile_b), smap),   # u_sparse
            pl.BlockSpec((n_steps, tile_b), smap),   # u_acc
            pl.BlockSpec((tile_v, k), vmap_),        # prob
            pl.BlockSpec((tile_v, k), vmap_),        # alias
            pl.BlockSpec((tile_v,), vmap1),          # mass
            pl.BlockSpec((tile_v, k), vmap_),        # stale
            pl.BlockSpec((tile_v, k), vmap_),        # n_wk
            pl.BlockSpec((1, k), lambda bi, vi, vs, vc: (0, 0)),  # n_k
        ],
        out_specs=pl.BlockSpec((tile_b,), bmap),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=interpret,
    )(vstart, vcount, rows, z0, ndk, slot, coin, u_mix, u_sparse, u_acc,
      prob, alias, mass, stale, n_wk, n_k.reshape(1, -1))
