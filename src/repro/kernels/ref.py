"""Pure-jnp oracles for the Pallas kernels.

Each kernel in this package has a reference implementation here; tests sweep
shapes/dtypes and assert allclose between the kernel (interpret=True on CPU)
and these oracles.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import alias as alias_mod
from repro.core import mhw as mhw_mod


def alias_build_ref(p: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Reference alias-table construction: (prob, alias, mass) per row."""
    t = alias_mod.build(p)
    return t.prob, t.alias, t.mass


def dense_probs_ref(n_wk: jax.Array, n_k: jax.Array, alpha: float,
                    beta: float, vocab_size: int) -> jax.Array:
    """Dense LDA proposal term α(n_wk+β)/(n_k+β̄) — fused into alias_build."""
    beta_bar = beta * vocab_size
    return alpha * (n_wk + beta) / (n_k[None, :] + beta_bar)


def alias_build_fused_ref(n_wk, n_k, alpha, beta, vocab_size):
    """Oracle for the fused dense-term + alias-table build."""
    return alias_build_ref(dense_probs_ref(n_wk, n_k, alpha, beta, vocab_size))


def alias_sample_ref(prob: jax.Array, alias: jax.Array, rows: jax.Array,
                     slot: jax.Array, coin: jax.Array) -> jax.Array:
    """Reference O(1) alias draws with *given* uniforms.

    rows: (B,) table-row per draw; slot: (B,) int in [0,K); coin: (B,) in
    [0,1).  Deterministic given the uniforms, so kernel vs oracle compare
    exactly.
    """
    p = prob[rows, slot]
    a = alias[rows, slot]
    return jnp.where(coin < p, slot, a).astype(jnp.int32)


def alias_sample_sorted_ref(prob: jax.Array, alias: jax.Array,
                            rows: jax.Array, slot: jax.Array,
                            coin: jax.Array) -> jax.Array:
    """Reference for the tile-skipping sorted sampler: same draws as
    :func:`alias_sample_ref` for in-vocab rows, 0 for padding sentinels
    (rows ≥ V), matching the kernel's zero-initialized output blocks."""
    v = prob.shape[0]
    r = jnp.clip(rows, 0, v - 1)
    draw = alias_sample_ref(prob, alias, r, slot, coin)
    return jnp.where(rows < v, draw, 0).astype(jnp.int32)


def mhw_sweep_sorted_ref(prob, alias, mass, stale, n_wk, n_k, prior, rows,
                         z0, ndk, slot, coin, u_mix, u_sparse, u_acc, *,
                         beta, beta_bar):
    """Oracle for ``kernels.mhw_fused.mhw_sweep_fused`` (lm families:
    LDA with prior = α·1, HDP with prior = b1·θ0) — delegates to the
    pure-jnp chain semantics owned by ``repro.core.mhw``."""
    return mhw_mod.sorted_chain(prob, alias, mass, stale, n_wk, n_k, prior,
                                rows, z0, ndk, slot, coin, u_mix, u_sparse,
                                u_acc, beta=beta, beta_bar=beta_bar)


def pdp_sweep_sorted_ref(prob, alias, mass, stale, m_wk, s_wk, m_k, s_k,
                         stirl, prior, rows, e0, ndk, slot, coin, u_mix,
                         u_sparse, u_acc, *, b, a, gamma, gamma_bar):
    """Oracle for ``kernels.mhw_fused.pdp_sweep_fused`` — delegates to the
    pure-jnp chain semantics owned by ``repro.core.pdp``."""
    from repro.core import pdp as pdp_mod
    return pdp_mod.sorted_chain_pdp(prob, alias, mass, stale, m_wk, s_wk,
                                    m_k, s_k, stirl, prior, rows, e0, ndk,
                                    slot, coin, u_mix, u_sparse, u_acc,
                                    b=b, a=a, gamma=gamma,
                                    gamma_bar=gamma_bar)


def mh_accept_ref(z: jax.Array, cand: jax.Array, log_p_z: jax.Array,
                  log_p_cand: jax.Array, log_q_z: jax.Array,
                  log_q_cand: jax.Array, u: jax.Array) -> jax.Array:
    """Reference MH accept step (paper eq. 7) with given uniforms."""
    log_ratio = log_p_cand - log_p_z + log_q_z - log_q_cand
    accept = jnp.log(u + 1e-30) < log_ratio
    return jnp.where(accept, cand, z).astype(jnp.int32)
