"""Training engine: the unified multi-client driver over the ModelFamily
protocol (``repro.engine.trainer``), backed by the explicit parameter
server (``repro.core.server``)."""

from repro.core.fault import FaultEvent, FaultPlan, RoundFaults
from repro.core.server import (Async, BSP, Consistency, ParameterServer,
                               ServerState, ShardSpec, SSP,
                               make_consistency)
from repro.engine.trainer import RunResult, Trainer, TrainerConfig
from repro.net import RemoteParameterServer, serve_shards
from repro.net.protocol import ProtocolError

__all__ = [
    "Async",
    "BSP",
    "Consistency",
    "FaultEvent",
    "FaultPlan",
    "ParameterServer",
    "ProtocolError",
    "RemoteParameterServer",
    "RoundFaults",
    "RunResult",
    "SSP",
    "ServerState",
    "ShardSpec",
    "Trainer",
    "TrainerConfig",
    "make_consistency",
    "serve_shards",
]
