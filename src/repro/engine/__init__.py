"""Training engine: the unified multi-client driver over the ModelFamily
protocol (``repro.engine.trainer``)."""

from repro.engine.trainer import RunResult, Trainer, TrainerConfig

__all__ = ["RunResult", "Trainer", "TrainerConfig"]
