"""The sync-round body, shared by every driver, compiled as one program.

The paper's client loop is *one tight loop*, not a sequence of dispatches:
pull → tau sweeps → filter → push → project → auxiliaries all live inside a
single compiled program per round (§5.1-§5.3).  The per-client round body
(``tau_sweeps`` + ``filter_push``) is defined once in
``repro.core.distributed`` (core owns the round semantics; this engine
module only adds the jit/donation/cadence machinery on top) and is consumed
three ways:

* by ``core.distributed.make_round_fn``'s shard_mapped mesh round
  (clients = data-axis shards),
* by :func:`trainer_round` — the whole-round function ``engine.Trainer``
  jits: clients unrolled inside the trace, the tau staleness loop as
  ``lax.scan``, projection under ``lax.cond`` (so the cadence does not
  retrace), and the incremental alias producer fused at the tail,
* by the Python reference loop ``Trainer._step_python`` — kept un-compiled
  as the dispatch-per-op baseline the benchmarks compare against,

so the three drivers cannot drift apart.  (A fourth consumer,
``Trainer._step_remote`` — the ``transport="tcp"`` loop against
``repro.net`` shard servers — reuses :func:`filter_push` and the same
per-client key schedule, which is what keeps the wire path bit-exact
with the in-process round; see DESIGN.md §11.)

Since the ParameterServer redesign the round no longer threads raw
``shared``/``stale_dense`` pytrees: it takes a static
:class:`repro.core.server.ParameterServer` (family + shard spec +
consistency policy) and its traced, donated
:class:`repro.core.server.ServerState` — the vocabulary-sharded canonical
statistics, the versioned SSP pull cache, per-client clocks, the
per-shard changed-row accounting, and the resident alias proposal
(tables + stale dense matrix).  The pull/push semantics are the policy's:

* **BSP** — pull returns the canonical state as of the end of the
  previous round; pushes are summed at the round barrier.  Bit-exact
  with the PR-3 compiled round (assembly of the sharded store is pure
  concatenation; all arithmetic keeps its historical operation order).
* **SSP(s)** — pull returns the versioned stale cache; the traced
  ``do_refresh`` flag (the staleness-bound predicate, computed by the
  policy on the lock-step schedule) refreshes it from the canonical
  state, which in the simulation realizes SSP's blocking pull.
* **async** — each client's filtered push applies to the canonical view
  immediately, so later clients in the same round sample against it
  (Gauss-Seidel ordering); pulls never block.

Compiled-round invariants:

* **One trace per (family, layout, policy).**  Everything that varies
  between rounds — the round index, the fault-injection ``alive`` /
  ``push_ok`` masks (resolved host-side from a ``core.fault.FaultPlan``),
  the projection cadence, the SSP refresh flag — enters as *traced*
  scalars; and only the trace-relevant slice of the Trainer's config
  (:class:`RoundConfig`) keys the jit cache, so host-only knobs (fault
  plans, snapshot cadence/dirs) cannot force retraces either.  RNG keys
  are derived inside the trace with ``fold_in`` on the
  traced round index, reproducing the reference loop's keying
  bit-for-bit.  ``trace_count`` exposes a trace-time counter per
  (family, layout, policy) as the regression guard.
* **Donated buffers.**  The Trainer donates local states, the server
  state (canonical shards, cache, alias proposal) and residuals, so XLA
  updates the round state in place instead of allocating a second copy
  of the model every round.  Donation is skipped on backends that ignore
  it (CPU) to avoid spurious warnings.
* **Async pipelining.**  The round function never blocks; the Trainer only
  synchronizes at evaluation points, so consecutive rounds overlap with
  host-side Python (the dispatch of round r+1 rides on round r's compute).

Incremental alias maintenance (§3.3 l/n staleness, §5.1 producer/consumer):
after the push, the proposal rows that actually drifted are identified
from the server's per-shard changed-row accounting
(``ParameterServer.consume_changed_rows`` — the same magnitude-priority
machinery as the top-k communication filter, now accumulated across
pushes *since the last rebuild*), and only those rows are rebuilt via the
family's gather → build → scatter path (``ModelFamily.rebuild_alias_rows``)
into the server-resident tables.  Column aggregates (n_k, m_k, θ0) still
drift for untouched rows; that staleness is exactly what the MH
acceptance step corrects for, and a periodic full rebuild
(``alias_full_rebuild_every``) bounds it.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import ps

# Re-exported here for drivers/benchmarks that address the round body
# through the engine namespace.
from repro.core.distributed import (filter_push,  # noqa: F401
                                    filter_push_sparse, tau_sweeps)


@dataclass(frozen=True)
class RoundConfig:
    """The trace-relevant slice of ``TrainerConfig`` — the jit static the
    compiled round is keyed on.

    The Trainer's config also carries host-only knobs (snapshot cadence
    and directory, the fault plan, pull-retry budget, alias schedules,
    ``project_every``, …) that never enter the trace; keying the jit
    cache on the full ``TrainerConfig`` would retrace the identical round
    program whenever one of them changes (e.g. a baseline run vs. the
    same run with fault injection + snapshots — exactly the pairs
    ``bench_failover`` compares).  This reduced static makes those pairs
    share one trace by construction."""

    layout: str
    method: str
    n_clients: int
    tau: int
    filter: ps.FilterSpec
    alias_rebuild_rows: int
    alias_rebuild_threshold: float | None

    @classmethod
    def from_trainer(cls, tcfg) -> "RoundConfig":
        return cls(layout=tcfg.layout, method=tcfg.method,
                   n_clients=tcfg.n_clients, tau=tcfg.tau,
                   filter=tcfg.filter,
                   alias_rebuild_rows=tcfg.alias_rebuild_rows,
                   alias_rebuild_threshold=tcfg.alias_rebuild_threshold)

# Trace-time counters, keyed (family_name, layout, policy): the
# compile-stability regression guard.  Bumped from inside the round body,
# which only executes at trace time — a steady-state Trainer must not grow
# these for its (family, layout, policy) triple.
_TRACE_COUNTS: dict[tuple[str, str, str], int] = {}


def trace_count(family_name: str, layout: str, policy: str = "bsp") -> int:
    """How many times the compiled round has been traced for this
    (family, layout, policy) — across all Trainer instances (the jit cache
    is shared, so a second Trainer with the same signature costs no
    trace)."""
    return _TRACE_COUNTS.get((family_name, layout, policy), 0)


# ---------------------------------------------------------------------------
# The Trainer's whole-round compiled program
# ---------------------------------------------------------------------------

def _round_impl(server, model_cfg, rcfg, incremental, state, locals_,
                residuals, shard_tokens, shard_masks, layouts, key, r,
                alive, push_ok, do_project, do_refresh):
    """One sync round as a single traced program.

    Static: server / model_cfg / rcfg (:class:`RoundConfig`) /
    incremental (hashable configs — the jit cache is shared across
    Trainer instances with equal signatures).  Traced: everything else,
    including the server state, the round index ``r``, the fault masks
    ``alive`` (client samples + keeps its state) and ``push_ok`` (its
    delta lands on the server — ``alive`` minus lost pushes; the two
    coincide except under a ``lost_push`` fault event), the projection
    flag ``do_project`` and the SSP refresh flag ``do_refresh``, so
    per-round cadence and fault injection never retrace.
    """
    fam, pol = server.family, server.policy
    key_ = (fam.name, rcfg.layout, pol.key)
    _TRACE_COUNTS[key_] = _TRACE_COUNTS.get(key_, 0) + 1

    # pull — policy view: BSP the canonical state, SSP the versioned stale
    # cache (refreshed under the traced staleness-bound flag; each client
    # then layers its own read-my-writes lag on top), async the live view
    # that immediate pushes below keep updating.
    snapshot, cache, version = server.pull_round(state, r, do_refresh)
    lag = server.reset_lag(state.client_lag, do_refresh)
    new_lag_rows = []
    zero = {n: jnp.zeros_like(fam.stats_dict(snapshot)[n])
            for n in fam.delta_names}
    total = zero
    new_locals, new_residuals = [], []
    # RNG keying is the historical reference-loop scheme (flat fold_in on
    # r*131 + c*17 + s / 7000+… / 9000+…), preserved so compiled and Python
    # rounds are bit-identical.  Note the flat offsets can collide across
    # phases once r*131 grows past 7000 (r ≳ 53) — a correlation quirk
    # inherited from PR 2, kept until a coordinated re-keying of both paths.
    for c in range(rcfg.n_clients):                         # clients unrolled
        sweep_keys = jax.vmap(
            lambda s, c=c: jax.random.fold_in(key, r * 131 + c * 17 + s)
        )(jnp.arange(rcfg.tau))
        loc, acc = tau_sweeps(
            model_cfg, fam, locals_[c],
            server.client_view(snapshot, lag, c), state.tables, state.stale,
            shard_tokens[c], shard_masks[c], sweep_keys, method=rcfg.method,
            layout=rcfg.layout,
            sorted_layouts=layouts[c] if layouts is not None else None)
        kf = jax.random.fold_in(key, 7000 + r * 131 + c)
        sent, res = filter_push(fam, acc, rcfg.filter, kf, residuals[c])
        # Fault injection (§5.4, core.fault): a dead client (alive=False)
        # is frozen — no state update, no push, identical to skipping it
        # entirely.  A lost push (alive but push_ok=False) keeps the
        # client's local update and residual but drops its delta on the
        # server floor: the mass is lost, not residual-carried — that is
        # the fault being modeled.
        a = alive[c]
        if lag is not None:
            # Read-my-writes: the pre-filter delta the client applied
            # locally rides in its lag row until the next refresh — it
            # reflects what the client *applied locally*, so it follows
            # `alive`, not `push_ok` (a lost push is still in the
            # client's own replica).
            new_lag_rows.append({
                n: jnp.where(a, lag[n][c] + acc[n], lag[n][c])
                for n in lag})
        new_locals.append(jax.tree.map(
            lambda new, old: jnp.where(a, new, old), loc, locals_[c]))
        new_residuals.append(
            res if res is None else jax.tree.map(
                lambda new, old: jnp.where(a, new, old), res, residuals[c]))
        pf = (a & push_ok[c]).astype(jnp.float32)
        total = {n: total[n] + sent[n] * pf for n in total}
        if pol.immediate:
            # async: the push lands now — the next client pulls it.
            snapshot = fam.apply_delta(
                snapshot, {n: sent[n] * pf for n in sent})

    # A client's server clock advances iff its push was applied.
    pushed = alive & push_ok
    if pol.immediate:                                       # push (applied)
        state = server.load_dense(state, snapshot)
        if incremental:
            state = server.accumulate_mass(state, total)
        state = state._replace(clocks=state.clocks + pushed.astype(jnp.int32))
    else:                                                   # push (barrier)
        state = server.push(state, total, pushed, track_mass=incremental)
    state = server.project(state, do_project)               # project
    dense = server.assemble(state)
    new_locals, dense = fam.post_round(                     # auxiliaries
        model_cfg, new_locals, dense, jax.random.fold_in(key, 9000 + r))
    state = server.load_dense(state, dense)
    if lag is not None:
        lag = {n: jnp.stack([row[n] for row in new_lag_rows])
               for n in lag}
    state = state._replace(cache=cache, cache_version=version,
                           client_lag=lag)

    if incremental:
        # Incremental alias producer: rebuild only the token-type rows
        # whose accumulated push mass drifted past the threshold, against
        # the end-of-round statistics (freshest possible proposal).
        rows, valid, state = server.consume_changed_rows(
            state, rcfg.alias_rebuild_rows, rcfg.alias_rebuild_threshold)
        tables, stale = fam.rebuild_alias_rows(
            model_cfg, server.assemble(state), state.tables, state.stale,
            rows, valid)
        state = state._replace(tables=tables, stale=stale)
    return tuple(new_locals), state, tuple(new_residuals)


@functools.lru_cache(maxsize=None)
def _jitted_round(donate: bool):
    """jit wrapper cache: donation covers the round-owned state (server
    state incl. alias proposal, locals, residuals) where the backend
    honors it."""
    donate_argnums = (4, 5, 6) if donate else ()
    return jax.jit(_round_impl, static_argnums=(0, 1, 2, 3),
                   donate_argnums=donate_argnums)


def trainer_round(server, model_cfg, rcfg, incremental, *args):
    """Dispatch one compiled sync round (see :func:`_round_impl` for the
    argument contract).  ``server`` is the static
    :class:`~repro.core.server.ParameterServer` and ``rcfg`` the static
    :class:`RoundConfig` (a full ``TrainerConfig`` is also accepted and
    reduced, so external callers keying on the old signature keep
    working); the first traced argument is the server's donated
    :class:`~repro.core.server.ServerState`.  Buffers are donated only
    where the backend honors donation — CPU ignores it and would warn on
    every compile."""
    if not isinstance(rcfg, RoundConfig):
        rcfg = RoundConfig.from_trainer(rcfg)
    donate = jax.default_backend() != "cpu"
    fn = _jitted_round(donate)
    return fn(server, model_cfg, rcfg, bool(incremental), *args)
