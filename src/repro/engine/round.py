"""The sync-round body, shared by every driver, compiled as one program.

The paper's client loop is *one tight loop*, not a sequence of dispatches:
pull → tau sweeps → filter → push → project → auxiliaries all live inside a
single compiled program per round (§5.1-§5.3).  The per-client round body
(``tau_sweeps`` + ``filter_push``) is defined once in
``repro.core.distributed`` (core owns the round semantics; this engine
module only adds the jit/donation/cadence machinery on top) and is consumed
three ways:

* by ``core.distributed.make_round_fn``'s shard_mapped mesh round
  (clients = data-axis shards),
* by :func:`trainer_round` — the whole-round function ``engine.Trainer``
  jits: clients unrolled inside the trace, the tau staleness loop as
  ``lax.scan``, projection under ``lax.cond`` (so the cadence does not
  retrace), and the incremental alias producer fused at the tail,
* by the Python reference loop ``Trainer._step_python`` — kept un-compiled
  as the dispatch-per-op baseline the benchmarks compare against,

so the three drivers cannot drift apart.

Compiled-round invariants:

* **One trace per (family, layout).**  Everything that varies between
  rounds — the round index, the failure-injection ``alive`` mask, the
  projection cadence — enters as *traced* scalars; RNG keys are derived
  inside the trace with ``fold_in`` on the traced round index, reproducing
  the reference loop's keying bit-for-bit.  ``trace_count`` exposes a
  trace-time counter per (family, layout) as the regression guard.
* **Donated buffers.**  The Trainer donates local states, shared statistics,
  residuals (and, in incremental-alias mode, the resident tables + stale
  snapshot), so XLA updates the round state in place instead of allocating
  a second copy of the model every round.  Donation is skipped on backends
  that ignore it (CPU) to avoid spurious warnings.
* **Async pipelining.**  The round function never blocks; the Trainer only
  synchronizes at evaluation points, so consecutive rounds overlap with
  host-side Python (the dispatch of round r+1 rides on round r's compute).

Incremental alias maintenance (§3.3 l/n staleness, §5.1 producer/consumer):
after the push, the rows of the proposal term that actually drifted are
identified from the summed delta's per-row L1 mass (``ps.changed_rows`` —
the same magnitude-priority machinery as the top-k communication filter),
and only those rows are rebuilt via the family's gather → build → scatter
path (``ModelFamily.rebuild_alias_rows``).  Column aggregates (n_k, m_k,
θ0) still drift for untouched rows; that staleness is exactly what the MH
acceptance step corrects for, and a periodic full rebuild
(``alias_full_rebuild_every``) bounds it.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import ps
# Re-exported here for drivers/benchmarks that address the round body
# through the engine namespace.
from repro.core.distributed import filter_push, tau_sweeps  # noqa: F401

# Trace-time counters, keyed (family_name, layout): the compile-stability
# regression guard.  Bumped from inside the round body, which only executes
# at trace time — a steady-state Trainer must not grow these.
_TRACE_COUNTS: dict[tuple[str, str], int] = {}


def trace_count(family_name: str, layout: str) -> int:
    """How many times the compiled round has been traced for this
    (family, layout) — across all Trainer instances (the jit cache is
    shared, so a second Trainer with the same signature costs no trace)."""
    return _TRACE_COUNTS.get((family_name, layout), 0)


# ---------------------------------------------------------------------------
# The Trainer's whole-round compiled program
# ---------------------------------------------------------------------------

def _round_impl(fam, model_cfg, tcfg, incremental, locals_, shared,
                residuals, tables, stale, shard_tokens, shard_masks,
                layouts, key, r, alive, do_project):
    """One sync round as a single traced program.

    Static: fam / model_cfg / tcfg / incremental (hashable configs — the
    jit cache is shared across Trainer instances with equal signatures).
    Traced: everything else, including the round index ``r``, the failure
    mask ``alive`` and the projection flag ``do_project``, so per-round
    cadence never retraces.
    """
    key_ = (fam.name, tcfg.layout)
    _TRACE_COUNTS[key_] = _TRACE_COUNTS.get(key_, 0) + 1

    snapshot = shared                                       # pull (frozen)
    zero = {n: jnp.zeros_like(fam.stats_dict(snapshot)[n])
            for n in fam.delta_names}
    total = zero
    new_locals, new_residuals = [], []
    # RNG keying is the historical reference-loop scheme (flat fold_in on
    # r*131 + c*17 + s / 7000+… / 9000+…), preserved so compiled and Python
    # rounds are bit-identical.  Note the flat offsets can collide across
    # phases once r*131 grows past 7000 (r ≳ 53) — a correlation quirk
    # inherited from PR 2, kept until a coordinated re-keying of both paths.
    for c in range(tcfg.n_clients):                         # clients unrolled
        sweep_keys = jax.vmap(
            lambda s, c=c: jax.random.fold_in(key, r * 131 + c * 17 + s)
        )(jnp.arange(tcfg.tau))
        loc, acc = tau_sweeps(
            model_cfg, fam, locals_[c], snapshot, tables, stale,
            shard_tokens[c], shard_masks[c], sweep_keys, method=tcfg.method,
            layout=tcfg.layout,
            sorted_layouts=layouts[c] if layouts is not None else None)
        kf = jax.random.fold_in(key, 7000 + r * 131 + c)
        sent, res = filter_push(fam, acc, tcfg.filter, kf, residuals[c])
        # Failure injection (§5.4): a dead client's push is zeroed and its
        # state/residual frozen — identical to skipping it entirely.
        a = alive[c]
        new_locals.append(jax.tree.map(
            lambda new, old: jnp.where(a, new, old), loc, locals_[c]))
        new_residuals.append(
            res if res is None else jax.tree.map(
                lambda new, old: jnp.where(a, new, old), res, residuals[c]))
        af = a.astype(jnp.float32)
        total = {n: total[n] + sent[n] * af for n in total}

    shared = fam.apply_delta(snapshot, total)               # push
    shared = jax.lax.cond(do_project, fam.project,          # project
                          lambda s: s, shared)
    new_locals, shared = fam.post_round(                    # auxiliaries
        model_cfg, new_locals, shared, jax.random.fold_in(key, 9000 + r))

    if not incremental:
        return tuple(new_locals), shared, tuple(new_residuals)

    # Incremental alias producer: rebuild only the token-type rows whose
    # pushed delta mass drifted past the threshold, against the end-of-round
    # statistics (freshest possible proposal for round r+1).
    mass = functools.reduce(
        jnp.add, (jnp.abs(total[n]).sum(-1) for n in fam.alias_delta_stats))
    rows, valid = ps.changed_rows(mass, tcfg.alias_rebuild_rows,
                                  tcfg.alias_rebuild_threshold)
    tables, stale = fam.rebuild_alias_rows(model_cfg, shared, tables, stale,
                                           rows, valid)
    return tuple(new_locals), shared, tuple(new_residuals), tables, stale


@functools.lru_cache(maxsize=None)
def _jitted_round(incremental: bool, donate: bool):
    """jit wrapper cache: donation depends on whether the alias buffers are
    round outputs (incremental mode) and on backend support."""
    donate_argnums = ()
    if donate:
        # locals_, shared, residuals — always owned by the round.
        donate_argnums = (4, 5, 6)
        if incremental:
            donate_argnums += (7, 8)     # tables, stale rebuilt in-round
    return jax.jit(_round_impl, static_argnums=(0, 1, 2, 3),
                   donate_argnums=donate_argnums)


def trainer_round(fam, model_cfg, tcfg, incremental, *args):
    """Dispatch one compiled sync round (see :func:`_round_impl` for the
    argument contract).  Buffers are donated only where the backend honors
    donation — CPU ignores it and would warn on every compile."""
    donate = jax.default_backend() != "cpu"
    fn = _jitted_round(bool(incremental), donate)
    return fn(fam, model_cfg, tcfg, bool(incremental), *args)
