"""``Trainer``: the one driver loop for every ModelFamily (paper §5).

Replaces the hand-rolled per-model driver loops that used to live in
``examples/quickstart.py``, ``examples/distributed_lvm.py`` and
``benchmarks/bench_{lda,pdp,hdp}.py``, and the per-model adapter classes of
``core/distributed.py``: model specifics enter only through the
``repro.core.family`` registry, so LDA / PDP / HDP — and any future family —
run the identical lifecycle:

    pull    — ask the parameter server for a snapshot under the configured
              consistency policy (BSP: fresh every round; SSP: a versioned
              stale cache, refreshed when the staleness bound is hit;
              async: the live, immediately-updated statistics),
    sample  — ``tau`` local Gibbs sweeps per client against the snapshot
              (scan oracle layout or the token-sorted tile-skipping fast
              path, selected by ``TrainerConfig.layout``), each client
              applying its own deltas locally (bounded staleness, §5.2),
    filter  — communication filter + error-feedback residuals on the
              accumulated delta (§5.3),
    push    — filtered deltas applied to the server's vocabulary-sharded
              canonical statistics (at the round barrier, or immediately
              per client under async),
    project — constraint projection on the shared polytope (§5.5) plus the
              family's client-local rules (e.g. HDP's 1 ≤ m_dk ≤ n_dk),
    (post)  — family auxiliary resampling (HDP CRT tables + θ0).

The shared statistics live behind an explicit
:class:`repro.core.server.ParameterServer` (DESIGN.md §9): the Trainer
holds the server's :class:`~repro.core.server.ServerState` — the
vocabulary-sharded canonical store, the SSP versioned pull cache,
per-client clocks, the per-shard changed-row accounting, and the resident
alias proposal — and ``TrainerConfig.consistency`` /
``TrainerConfig.n_server_shards`` select the policy and the sharding.
``Trainer.shared`` remains the assembled dense view for evaluation and
diagnostics.

Since PR 3 the whole round is **one compiled program**
(``repro.engine.round``, DESIGN.md §8): clients are unrolled inside the
trace, the tau loop is a ``lax.scan``, round state (locals / server state /
residuals) is donated so XLA updates it in place, and ``step()`` never
blocks — rounds pipeline asynchronously and the Trainer synchronizes only
at evaluation points.  ``TrainerConfig.compiled=False`` keeps the PR-2
Python reference loop (one dispatch per op, blocking per round) for parity
tests and as the benchmark baseline; it supports every consistency policy
through the same server methods, so it stays the parity oracle for all of
them.

The Trainer also owns the alias-table maintenance (the l/n staleness rule
of §3.3 — the producer half of the paper's §5.1 producer/consumer design),
in three schedules:

* cadence (BSP/async default): tables fully rebuilt every
  ``alias_refresh_every`` rounds and reused in between;
* pull-coupled (SSP): the proposal is part of the pulled cache, so tables
  rebuild exactly when the versioned snapshot refreshes — this skipped
  work is the measured SSP throughput win (benchmarks/bench_consistency);
* incremental (``alias_rebuild_threshold`` set): every compiled round ends
  by rebuilding *only* the token-type rows whose accumulated push mass
  exceeds the threshold (the server's per-shard changed-row accounting,
  consumed by ``ParameterServer.consume_changed_rows``), with a full
  rebuild every ``alias_full_rebuild_every`` rounds to bound the drift of
  the column aggregates that partial rebuilds leave stale.

Fault tolerance (§5.4, DESIGN.md §10): ``TrainerConfig.fault_plan``
injects scripted or seeded-random fault schedules
(``repro.core.fault.FaultPlan`` — crashes, stragglers, lost pushes,
failed pull refreshes), resolved host-side per round into traced masks so
chaos runs never retrace; ``snapshot_every``/``snapshot_dir`` write
periodic barrier-free snapshots of the full training pytree through
``repro.checkpoint.ckpt``, ``Trainer.restore()`` resumes from the latest
manifest (bit-exact under BSP), and a crashed client rejoins mid-run by
restoring its locals from the last snapshot and taking a forced-fresh
pull with its read-my-writes lag reset — under SSP a rejoining client is
just a maximally stale client taking its blocking refresh.

The loop is semantically the single-device simulation of
``core.distributed.make_round_fn`` (clients iterated instead of
shard_mapped) — both drive the same round body in ``engine.round``; RNG
streams are keyed identically to the historical
``benchmarks.common.run_multiclient``.  One deliberate behavior change
from that loop: projection now runs uniformly per ``project_every`` for
*every* family (the old loop never projected LDA) — matching the
distributed round's paper-production default; pass ``project_every=0``
to disable.
"""

from __future__ import annotations

import functools
import time
import warnings
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core import family as family_mod
from repro.core import fault as fault_mod
from repro.core import ps
from repro.core import server as server_mod
from repro.data.synthetic import shard_corpus
from repro.engine import round as round_mod

Array = jax.Array


@dataclass(frozen=True)
class TrainerConfig:
    """Driver-side knobs; model-side knobs live in the family's config."""

    layout: str = "scan"          # "scan" (oracle) | "sorted" (fast path)
    method: str = "mhw"           # "mhw" | "exact" (scan layout only)
    n_clients: int = 1
    tau: int = 1                  # local sweeps per sync round (staleness)
    # --- parameter server (DESIGN.md §9) --------------------------------
    # Consistency policy: "bsp" (bulk-synchronous, bit-exact with the
    # pre-server round) | "ssp:<bound>" (stale-synchronous: clients run up
    # to <bound> rounds ahead of a versioned cache) | "async" (immediate
    # pushes, non-blocking pulls).
    consistency: str = "bsp"
    # Vocabulary shards of the server's canonical statistics (row-range
    # sharding with a row→shard map; 1 = unsharded).
    n_server_shards: int = 1
    # --------------------------------------------------------------------
    # One compiled program per round (donated buffers, async dispatch);
    # False = the PR-2 Python reference loop (blocking, one jit per op).
    compiled: bool = True
    # --- alias maintenance (§3.3 l/n rule, §5.1 producer) ---------------
    # Rounds between full alias-table rebuilds; None → the model config's
    # value.  Cadence mode only (ignored when incremental mode is on, and
    # under SSP, whose proposal rebuilds on the pull-refresh schedule).
    alias_refresh_every: int | None = None
    # Incremental mode (compiled rounds only): when set, each round ends by
    # rebuilding the ≤ alias_rebuild_rows token-type rows whose accumulated
    # push L1 mass exceeds this threshold (0.0 = any changed row), inside
    # the compiled round.  A full rebuild still runs every
    # alias_full_rebuild_every rounds to bound aggregate drift.
    alias_rebuild_threshold: float | None = None
    alias_rebuild_rows: int = 64
    alias_full_rebuild_every: int = 16
    # --------------------------------------------------------------------
    project_every: int = 1        # rounds between projections (0 = never)
    filter: ps.FilterSpec = field(default_factory=ps.FilterSpec)
    # --- fault tolerance (§5.4, core.fault / checkpoint.ckpt) -----------
    # Scripted or seeded-random schedule of fault events (crashes with
    # kill-and-rejoin recovery, stragglers, lost pushes, failed pull
    # refreshes), resolved host-side per round — see core.fault.FaultPlan.
    fault_plan: fault_mod.FaultPlan | None = None
    # DEPRECATED shim: (client_id, from_round, to_round) compiles to the
    # one-event FaultPlan.crash(...) with a DeprecationWarning.  Mutually
    # exclusive with fault_plan.
    drop_client: tuple[int, int, int] | None = None
    # Periodic barrier-free snapshots of the full training pytree (server
    # state, per-client locals, residuals, clocks, RNG key, round index)
    # through checkpoint.ckpt: every `snapshot_every` rounds into
    # `snapshot_dir` (both must be set to enable).  Trainer.restore()
    # resumes from the latest manifest — bit-exact under BSP; crashed
    # clients also restore their locals from here when they rejoin.
    snapshot_every: int = 0
    snapshot_dir: str | None = None
    snapshot_name: str = "trainer"
    # Bounded retry for failed pull refreshes (the `failed_pull` fault):
    # the clients continue on the stale cache while the refresh is
    # retried each round; after this many consecutive failures the
    # refresh forces through (failover to a healthy replica).
    pull_retry_limit: int = 3
    # --- transport (DESIGN.md §11, repro.net) ---------------------------
    # "inproc" (default): the zero-copy in-process ParameterServer path.
    # "tcp": the shared statistics live in out-of-process shard servers
    # (repro.net.server) and every pull/push crosses the framed binary
    # wire protocol through a RemoteParameterServer.  BSP over tcp is
    # bit-exact with inproc BSP; HDP (cross-client post_round) is not
    # servable over the wire and raises.
    transport: str = "inproc"
    # "host:port" shard-server addresses (tcp only); together the servers
    # must tile the vocabulary rows [0, V).
    server_addrs: tuple[str, ...] = ()
    # Which global client ids THIS process runs (tcp only; None = all of
    # them — the single-process loopback case).  Other clients run in
    # other processes against the same servers; RNG streams key on the
    # global client id, so the union of processes reproduces the
    # in-process run exactly under BSP.
    local_clients: tuple[int, ...] | None = None
    # Encode pushes as COO row-sliced PUSH_SPARSE frames (tcp only;
    # DESIGN.md §12).  Bit-exact with dense pushes under BSP — the server
    # densifies and rides the same barrier path — but only the changed
    # rows cross the wire, which is the bytes/round win on zipf corpora.
    sparse_push: bool = False
    # Consecutive re-dial budget per server for dropped connections
    # during PULL (tcp only; the pull_retry_limit idiom on the wire).
    reconnect_limit: int = 3


@dataclass
class RunResult:
    perplexities: list[float] = field(default_factory=list)
    topics_per_word: list[float] = field(default_factory=list)
    iter_times: list[float] = field(default_factory=list)
    violations: list[float] = field(default_factory=list)
    tokens: int = 0

    @property
    def tokens_per_s(self) -> float:
        """Training throughput over the recorded eval segments.

        Returns ``float("nan")`` before any eval segment has been timed
        (``iter_times`` empty — e.g. a fresh ``RunResult`` or a run that
        has not reached its first evaluation point): a benchmark script
        averaging or logging throughput must not silently record 0.0 as
        if it were a measurement — NaN propagates loudly instead.
        """
        if not self.iter_times:
            return float("nan")
        t = float(np.mean(self.iter_times))
        return self.tokens / max(t, 1e-9)


class Trainer:
    """Multi-client trainer for one registered model family.

    >>> cfg = lda.LDAConfig(n_topics=8, vocab_size=400)
    >>> t = Trainer(cfg, tokens, mask,
    ...             config=TrainerConfig(n_clients=4, layout="sorted",
    ...                                  consistency="ssp:2"))
    >>> result = t.run(n_rounds=20, eval_every=5)

    The family is resolved from the model config's type via the registry
    (``family.family_of``).  State lives on the instance: per-client local
    states, the parameter server's :class:`~repro.core.server.ServerState`
    (canonical vocabulary-sharded statistics, SSP pull cache, clocks,
    changed-row accounting, alias proposal), prebuilt sorted layouts (the
    token stream never changes between sweeps, so the per-shard sorts are
    hoisted out of the loop), and the error-feedback residuals of the
    communication filter.
    """

    def __init__(self, model_cfg, tokens: Array, mask: Array, *,
                 config: TrainerConfig = TrainerConfig(),
                 key: Array | None = None):
        if config.layout not in ("scan", "sorted"):
            raise ValueError(f"unknown layout {config.layout!r}")
        if config.layout == "sorted" and config.method != "mhw":
            raise ValueError("layout='sorted' requires method='mhw'")
        if config.alias_rebuild_threshold is not None and not config.compiled:
            raise ValueError("incremental alias rebuilds "
                             "(alias_rebuild_threshold) require compiled "
                             "rounds; the reference loop only supports the "
                             "alias_refresh_every cadence")
        if config.transport not in ("inproc", "tcp"):
            raise ValueError(f"unknown transport {config.transport!r}; "
                             "expected 'inproc' or 'tcp'")
        if config.transport == "inproc" and (
                config.server_addrs or config.local_clients is not None
                or config.sparse_push):
            raise ValueError("server_addrs / local_clients / sparse_push "
                             "are tcp-only knobs; set transport='tcp'")
        self.cfg = model_cfg
        self.tcfg = config
        self.fault_plan = self._resolve_fault_plan(config)
        self.family = family_mod.family_of(model_cfg)
        if config.transport == "tcp":
            self._validate_tcp(config)
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.tokens = jnp.asarray(tokens)
        self.mask = jnp.asarray(mask)
        self.n_tokens = int(np.asarray(mask).sum())
        # Which global client ids this process runs: all of them inproc
        # (and for single-process tcp); a subset when this Trainer is one
        # of several worker processes sharing the wire servers.
        self.local_clients = (tuple(range(config.n_clients))
                              if config.local_clients is None
                              else tuple(sorted(config.local_clients)))
        remote_mode = config.transport == "tcp"
        local_set = set(self.local_clients)

        shards = shard_corpus(np.asarray(tokens), np.asarray(mask),
                              config.n_clients)
        self.shards = [(jnp.asarray(t), jnp.asarray(m)) for t, m in shards]

        # init() builds per-shard stats; the canonical shared state is
        # their sum (replicated stats — e.g. θ0 — taken from shard 0).
        # Over the wire, each process computes only its local clients'
        # contributions and INIT-pushes them — the servers perform the
        # same ascending-client-id merge at the INIT barrier.
        self.locals_: list = [None] * config.n_clients
        shared = None
        init_stats: dict[int, Any] = {}
        for c, (t, m) in enumerate(self.shards):
            if remote_mode and c not in local_set:
                continue
            loc, sh = self.family.init_state(model_cfg, t, m,
                                             jax.random.fold_in(self.key, c))
            self.locals_[c] = loc
            if remote_mode:
                init_stats[c] = sh
            else:
                shared = sh if shared is None else self._merge_shared(shared, sh)

        # The parameter server: vocabulary-sharded canonical statistics
        # under the configured consistency policy (DESIGN.md §9) — held
        # in-process, or behind the framed wire protocol (DESIGN.md §11).
        self.remote = None
        if remote_mode:
            from repro.net import client as net_client
            self.server = None
            self.pstate = None
            self.remote = net_client.RemoteParameterServer(
                config.server_addrs, family=self.family,
                n_clients=config.n_clients,
                vocab_size=model_cfg.vocab_size,
                consistency=config.consistency,
                sparse_push=config.sparse_push,
                reconnect_limit=config.reconnect_limit,
                local_clients=self.local_clients)
            for c in sorted(init_stats):
                self.remote.init_push(c, init_stats[c])
            stats_template = self.family.stats_dict(
                init_stats[self.local_clients[0]])
        else:
            self.server = server_mod.make_server(
                self.family, model_cfg.vocab_size,
                n_shards=config.n_server_shards,
                consistency=config.consistency)
            self.pstate = self.server.init_state(shared, config.n_clients)
            stats_template = None
        # Host mirror of the SSP cache version (the lock-step pull
        # schedule is deterministic, so the host never needs to sync to
        # decide a refresh) and a rebuild counter for tests/benchmarks.
        self._host_version: int | None = None
        self.alias_builds = 0
        # Wire-transport client state: the pulled versioned snapshot (the
        # SSP cache at the client edge), the alias proposal built from it,
        # and each local client's own read-my-writes lag row.
        self._tcp_snapshot = None
        self._tcp_version: int | None = None
        self._tcp_tables = None
        self._tcp_stale = None
        self._lag: dict[int, dict[str, Array]] | None = None
        if remote_mode and self.remote.policy.caches:
            self._lag = {
                c: {n: jnp.zeros_like(stats_template[n])
                    for n in self.family.delta_names}
                for c in self.local_clients}

        # Hoisted sorted layouts: one tuple of per-chunk layouts per shard
        # (local clients only — a worker never sweeps remote shards).
        self.layouts = None
        if config.layout == "sorted":
            self.layouts = tuple(
                self.family.build_sorted_layouts(model_cfg, t, m)
                if c in local_set else None
                for c, (t, m) in enumerate(self.shards))

        self.alias_refresh_every = (
            config.alias_refresh_every
            if config.alias_refresh_every is not None
            else getattr(model_cfg, "alias_refresh_every", 1))
        # Error-feedback residuals (ps.residual_update): what a
        # communication filter withholds is carried to the next round,
        # never dropped — count mass must be conserved or the statistics
        # drift negative (paper §5.3's eventual-consistency contract).
        # Zero-initialized (not None) so the compiled round's pytree
        # structure is stable from the first call.
        if config.filter.kind != "dense":
            stats = (stats_template if remote_mode
                     else self.family.stats_dict(self.shared))
            self.residuals: list = [
                {n: jnp.zeros_like(stats[n]) for n in self.family.delta_names}
                if (not remote_mode or c in local_set) else None
                for c in range(config.n_clients)]
        else:
            self.residuals = [None] * config.n_clients
        self.round_idx = 0
        # Fault-tolerance host state: the reduced jit static (host-only
        # knobs like the fault plan and snapshot cadence must not key the
        # trace cache), the failed-pull retry budget, and observability
        # counters for tests/benchmarks.
        self._rcfg = round_mod.RoundConfig.from_trainer(config)
        self._pull_retries = 0
        self.pull_failures = 0
        self.rejoins = 0

    def _validate_tcp(self, config: TrainerConfig) -> None:
        """Reject TrainerConfig combinations the wire transport cannot
        honor (each names its inproc-only machinery).

        ``fault_plan`` / ``drop_client`` and ``snapshot_every`` used to be
        rejected here too; since the wire grew idempotent replay, ghost
        pushes and worker-side snapshots (DESIGN.md §13) the same fault
        schedules and snapshot cadences run over tcp — simulated faults
        ride the wire as ghost barrier frames, and a killed worker
        process restores from its own snapshot with ``Trainer.restore``.
        """
        if not config.server_addrs:
            raise ValueError("transport='tcp' requires server_addrs "
                             "(host:port shard servers)")
        if config.alias_rebuild_threshold is not None:
            raise ValueError("incremental alias rebuilds are inproc "
                             "compiled-round machinery; tcp rebuilds from "
                             "the pulled snapshot on the refresh schedule")
        if type(self.family).post_round is not family_mod.ModelFamily.post_round:
            raise NotImplementedError(
                f"family {self.family.name!r} overrides post_round "
                "(cross-client auxiliary resampling at the barrier) — not "
                "servable over the wire; use transport='inproc'")
        if config.local_clients is not None:
            lc = tuple(config.local_clients)
            if not lc or len(set(lc)) != len(lc) or \
                    not all(0 <= c < config.n_clients for c in lc):
                raise ValueError(
                    f"local_clients {lc} must be distinct ids in "
                    f"[0, {config.n_clients})")

    @staticmethod
    def _resolve_fault_plan(config: TrainerConfig) -> fault_mod.FaultPlan:
        """The run's fault plan: ``config.fault_plan``, or the deprecated
        ``drop_client`` tuple compiled to a one-event crash plan."""
        if config.drop_client is not None:
            if config.fault_plan is not None:
                raise ValueError(
                    "TrainerConfig.drop_client and TrainerConfig.fault_plan "
                    "are mutually exclusive — drop_client is the deprecated "
                    "shim; express the crash as FaultPlan.crash(...) inside "
                    "the plan instead")
            warnings.warn(
                "TrainerConfig.drop_client is deprecated; use "
                "fault_plan=FaultPlan.crash(client, start, stop) "
                "(repro.core.fault) — drop_client compiles to exactly that "
                "one-event plan", DeprecationWarning, stacklevel=3)
            return fault_mod.FaultPlan.from_drop_client(config.drop_client)
        if config.fault_plan is None:
            return fault_mod.FaultPlan.none()
        if config.fault_plan.max_client >= config.n_clients:
            raise ValueError(
                f"fault plan names client {config.fault_plan.max_client} "
                f"but the run has only {config.n_clients} clients")
        return config.fault_plan

    # ------------------------------------------------------------------
    @property
    def shared(self):
        """The assembled dense shared statistics (the server's canonical
        snapshot — always fresh, regardless of the pull policy).  Over
        tcp this is a SNAPSHOT round-trip that first waits for every
        stepped round to finalize at the servers."""
        if self.remote is not None:
            return self.remote.snapshot(min_round=self.round_idx)
        return self.server.snapshot(self.pstate)

    @shared.setter
    def shared(self, value):
        if self.remote is not None:
            raise ValueError("Trainer.shared is read-only over tcp — the "
                             "shard servers own the canonical state")
        self.pstate = self.server.load_dense(self.pstate, value)

    @property
    def tables(self):
        return self._tcp_tables if self.remote is not None \
            else self.pstate.tables

    @property
    def stale(self):
        return self._tcp_stale if self.remote is not None \
            else self.pstate.stale

    @property
    def clocks(self) -> np.ndarray:
        """Per-client round clocks as tracked by the server."""
        if self.remote is not None:
            return self.remote.clock()[1]
        return np.asarray(self.pstate.clocks)

    @property
    def _incremental(self) -> bool:
        return self.tcfg.alias_rebuild_threshold is not None

    @property
    def round_traces(self) -> int:
        """Trace count of this Trainer's compiled round signature — the
        compile-stability guard (steady-state rounds must not grow it).
        The jit cache is shared, so another Trainer with an equal signature
        reuses the trace."""
        policy = (self.remote.policy if self.remote is not None
                  else self.server.policy)
        return round_mod.trace_count(self.family.name, self.tcfg.layout,
                                     policy.key)

    def _merge_shared(self, acc, sh):
        fam = self.family
        a, b = fam.stats_dict(acc), fam.stats_dict(sh)
        merged = {n: (a[n] if n in fam.replicated_stats or a[n].shape == ()
                      else a[n] + b[n])
                  for n in a}
        return fam.shared_from_dict(merged)

    def _pull_refresh(self, r: int, *, force: bool = False,
                      failed: bool = False) -> bool:
        """The policy's pull schedule for round ``r`` (host mirror of the
        traced predicate; lock-step clients make it deterministic).  Under
        SSP a True here is the blocking pull: the bound r − version would
        be exceeded, so the client waits for a fresh snapshot.

        ``force`` is the rejoin protocol's forced-fresh pull (retried
        until it succeeds, so it overrides a concurrent ``failed``).
        ``failed`` is the ``failed_pull`` fault: a due refresh degrades
        gracefully — the clients continue on the stale cache (past the
        staleness bound; that is the degradation) and the refresh is
        retried next round, bounded by ``TrainerConfig.pull_retry_limit``
        consecutive failures before it forces through anyway."""
        pol = self.server.policy
        if not pol.caches:
            return True
        if not (force or pol.needs_refresh(r, self._host_version)):
            return False
        if failed and not force \
                and self._pull_retries < self.tcfg.pull_retry_limit:
            self._pull_retries += 1
            self.pull_failures += 1
            return False
        self._pull_retries = 0
        self._host_version = r
        return True

    def _refresh_alias(self, do_refresh: bool) -> None:
        srv, r = self.server, self.round_idx
        if self._incremental:
            # Incremental mode: partial rebuilds happen inside the compiled
            # round; the periodic full rebuild re-anchors the rows whose
            # *aggregate* factors (n_k, m_k, θ0) drifted without row pushes.
            if self.pstate.tables is not None and not (
                    self.tcfg.alias_full_rebuild_every
                    and r % self.tcfg.alias_full_rebuild_every == 0):
                return
        elif srv.policy.caches:
            # SSP: the proposal is part of the pulled versioned cache —
            # rebuilt exactly when the pull refreshes.  The skipped
            # rebuilds on stale rounds are the measured throughput win.
            if self.pstate.tables is not None and not do_refresh:
                return
        elif self.pstate.tables is not None and \
                r % self.alias_refresh_every != 0:
            return
        self.pstate = srv.refresh_proposal(self.cfg, self.pstate)
        self.alias_builds += 1

    def _round_faults(self) -> fault_mod.RoundFaults:
        """This round's host-side fault resolution, with the rejoin
        protocol already executed for any client whose crash window ends
        now: restore its locals (and residuals) from the latest snapshot
        when snapshots are enabled — otherwise its frozen in-memory state
        doubles as the implicit snapshot — and clear its read-my-writes
        lag; the caller then forces a fresh pull for the round."""
        rf = self.fault_plan.resolve(self.round_idx, self.tcfg.n_clients)
        if rf.rejoining:
            self._rejoin(rf.rejoining)
        return rf

    def _rejoin(self, clients: tuple[int, ...]) -> None:
        snap = self._load_latest_snapshot()
        for c in clients:
            if snap is not None and (self.remote is None
                                     or c in self.local_clients):
                self.locals_[c] = snap["locals"][c]
                if self.residuals[c] is not None:
                    self.residuals[c] = snap["residuals"][c]
            if self.remote is not None:
                # Over the wire the rejoin protocol is a REJOIN frame
                # (clear pending pushes + open mutation-log entries, lift
                # any eviction) followed by a forced-fresh pull, which
                # the caller triggers via the rejoining mask.
                if c in self.local_clients:
                    self.remote.rejoin(c)
            else:
                self.pstate = self.server.rejoin_client(self.pstate, c)
        self.rejoins += len(clients)

    def _load_latest_snapshot(self) -> dict | None:
        """The newest readable snapshot, or None when snapshotting is off
        or nothing has been written yet (a client crashing before the
        first snapshot recovers from its frozen init-equivalent state)."""
        if not self.tcfg.snapshot_dir:
            return None
        try:
            return ckpt.restore_latest(self.tcfg.snapshot_dir,
                                       self.tcfg.snapshot_name,
                                       self.snapshot_state())
        except FileNotFoundError:
            return None
        except ckpt.CorruptSnapshotError as e:
            # Every written snapshot is unreadable: degrade to the frozen
            # in-memory state rather than aborting the run (§5.4), loudly.
            warnings.warn(f"rejoin falling back to in-memory state: {e}",
                          RuntimeWarning, stacklevel=2)
            return None

    def _sync(self) -> None:
        """Block until every in-flight round has materialized (eval
        points; compiled rounds otherwise pipeline asynchronously).  Over
        tcp: wait for the servers' barrier to finalize every stepped
        round (the CLOCK message with min_round)."""
        if self.remote is not None:
            self.remote.clock(min_round=self.round_idx)
            return
        jax.block_until_ready(jax.tree.leaves(self.pstate.shards[0])[0])

    # ------------------------------------------------------------------
    def step(self) -> None:
        """One sync round: (faults) → pull → sample → filter → push →
        project → (snapshot).

        Compiled mode (default): one jitted program, donated buffers, no
        host sync — the call returns as soon as the round is dispatched.
        Fault events (``TrainerConfig.fault_plan``) resolve host-side
        into traced masks, and the periodic snapshot
        (``snapshot_every``/``snapshot_dir``) is barrier-free: the host
        blocks only to serialize the buffers it writes while further
        rounds keep dispatching.
        """
        if self.remote is not None:
            self._step_remote()
        elif not self.tcfg.compiled:
            self._step_python()
        else:
            self._step_compiled()
        if self.tcfg.snapshot_every and self.tcfg.snapshot_dir \
                and self.round_idx % self.tcfg.snapshot_every == 0:
            self.save_snapshot()

    def _step_compiled(self) -> None:
        tcfg = self.tcfg
        r = self.round_idx
        rf = self._round_faults()
        do_refresh = self._pull_refresh(r, force=bool(rf.rejoining),
                                        failed=rf.pull_failed)
        self._refresh_alias(do_refresh)

        do_project = bool(tcfg.project_every
                          and r % tcfg.project_every == 0)
        locals2, self.pstate, residuals2 = round_mod.trainer_round(
            self.server, self.cfg, self._rcfg, self._incremental,
            self.pstate, tuple(self.locals_), tuple(self.residuals),
            tuple(t for t, _ in self.shards),
            tuple(m for _, m in self.shards),
            self.layouts, self.key, np.int32(r), rf.alive_mask,
            rf.push_mask, np.bool_(do_project), np.bool_(do_refresh))
        self.locals_ = list(locals2)
        self.residuals = list(residuals2)
        self.round_idx += 1

    def _refresh_alias_tcp(self, refreshed: bool) -> None:
        """Alias maintenance at the client edge of the wire: the proposal
        is built from the pulled versioned snapshot — under SSP exactly
        when the pull refreshed (the proposal rides the cache, as
        inproc); under BSP/async on the ``alias_refresh_every`` cadence.
        Bit-exact with the inproc schedule: the pulled snapshot at
        version r carries the same statistics ``refresh_proposal`` reads
        from the canonical store at round r."""
        r = self.round_idx
        if self._tcp_tables is not None:
            if self.remote.policy.caches:
                if not refreshed:
                    return
            elif r % self.alias_refresh_every != 0:
                return
        self._tcp_tables, self._tcp_stale = self.family.build_alias(
            self.cfg, self._tcp_snapshot)
        self.alias_builds += 1

    def _step_remote(self) -> None:
        """One sync round over the wire (DESIGN.md §11): the
        ``_step_python`` loop with the server side of each phase replaced
        by protocol messages — pull is a versioned cache refresh (the
        server answers NOT_MODIFIED within the staleness bound), push is
        a delta frame finalized at the server's round barrier (summed
        there in ascending client id — the reference loop's op order),
        projection runs server-side on the same cadence, and the
        read-my-writes lag is this process's own rows.  RNG streams key
        on the *global* client id, so M worker processes jointly
        reproduce the single-process run — bit-exactly under BSP.

        Fault injection (DESIGN.md §13): the same host-side
        ``fault_plan`` resolution as the inproc loops, with the masks
        expressed as wire frames — a dead or push-losing client fills
        its barrier slot with a *ghost* push (counted for completeness,
        no delta, no clock tick), bit-exact with the inproc alive/push
        masks; a ``failed_pull`` skips the due cache refresh and keeps
        sampling the stale snapshot, bounded by ``pull_retry_limit``;
        a rejoin restores locals from the latest snapshot, REJOINs at
        the servers and takes a forced-fresh pull."""
        fam, cfg, tcfg = self.family, self.cfg, self.tcfg
        r = self.round_idx
        pol = self.remote.policy
        rf = self._round_faults()
        force = bool(rf.rejoining)
        skip_pull = False
        if rf.pull_failed and not force and pol.caches \
                and self._tcp_snapshot is not None \
                and pol.needs_refresh(r, self._host_version) \
                and self._pull_retries < tcfg.pull_retry_limit:
            # The due refresh RPC "fails": continue on the stale cache
            # past the bound (that is the degradation) and retry next
            # round — the inproc failed_pull idiom on the wire.
            self._pull_retries += 1
            self.pull_failures += 1
            skip_pull = True
        refreshed = False
        if not skip_pull:
            snapshot_new, version, refreshed = self.remote.pull(
                r, None if force else (
                    self._tcp_version if pol.caches else None))
            if refreshed:
                self._tcp_snapshot = snapshot_new
                self._tcp_version = version
                self._host_version = version
                self._pull_retries = 0
                if self._lag is not None:
                    # Fresh cache already contains every applied push:
                    # zero the read-my-writes accumulators
                    # (srv.reset_lag).
                    self._lag = {
                        c: {n: jnp.zeros_like(v) for n, v in row.items()}
                        for c, row in self._lag.items()}
        snapshot = self._tcp_snapshot
        self._refresh_alias_tcp(refreshed)

        for c in self.local_clients:
            if not rf.alive[c]:
                # Dead client (§5.4): frozen locals, no contribution —
                # but the servers' round barrier still needs its slot,
                # so a ghost frame rides the wire in its place.
                self.remote.push_ghost(r, c)
                continue
            t, m = self.shards[c]
            lays = self.layouts[c] if self.layouts is not None else None
            local_shared = (fam.apply_delta(snapshot, self._lag[c])
                            if self._lag is not None else snapshot)
            acc = None
            for s in range(tcfg.tau):                # sample (τ sweeps)
                k = jax.random.fold_in(self.key, r * 131 + c * 17 + s)
                self.locals_[c], d = fam.sweep(
                    cfg, self.locals_[c], local_shared, self._tcp_tables,
                    self._tcp_stale, t, m, k, method=tcfg.method,
                    layout=tcfg.layout, sorted_layouts=lays)
                local_shared = fam.apply_delta(local_shared, d)
                acc = d if acc is None else {n: acc[n] + d[n] for n in d}
            self.locals_[c] = fam.local_project(self.locals_[c])
            if self._lag is not None:
                # Pre-filter delta rides in the client's own lag row until
                # the next refresh (read-my-writes) — including when the
                # push below is lost (the delta is in the replica
                # regardless), exactly the reference loop.
                self._lag[c] = {n: self._lag[c][n] + acc[n] for n in acc}
            kf = jax.random.fold_in(self.key, 7000 + r * 131 + c)
            acc, self.residuals[c] = round_mod.filter_push(   # filter
                fam, acc, tcfg.filter, kf, self.residuals[c])
            if not rf.push_ok[c]:
                # Lost push (§5.4): the filtered delta is dropped on the
                # floor; a ghost fills the barrier slot in its place.
                self.remote.push_ghost(r, c)
                continue
            self.remote.push(r, c, acc)              # push (delta frame)
        self.round_idx += 1

    def close(self) -> None:
        """Release the wire connections (tcp transport); no-op inproc."""
        if getattr(self, "remote", None) is not None:
            self.remote.close()

    def _step_python(self) -> None:
        """The PR-2 reference loop: one jitted dispatch per sweep/op and a
        device sync every round.  Semantically identical to the compiled
        round (same RNG keying and server methods — integer count
        statistics match bit-exactly for every consistency policy); kept
        as the parity oracle and the dispatch-overhead baseline measured
        in benchmarks/bench_throughput.py."""
        fam, cfg, tcfg = self.family, self.cfg, self.tcfg
        srv, pol = self.server, self.server.policy
        r = self.round_idx
        rf = self._round_faults()
        do_refresh = self._pull_refresh(r, force=bool(rf.rejoining),
                                        failed=rf.pull_failed)
        self._refresh_alias(do_refresh)
        state = self.pstate
        pushed = rf.alive_mask & rf.push_mask

        snapshot, cache, version = srv.pull_round(state, r, do_refresh)
        lag = srv.reset_lag(state.client_lag, do_refresh)
        total_delta = None
        for c in range(tcfg.n_clients):
            if not rf.alive[c]:
                continue   # dead client: frozen, contributes nothing
            t, m = self.shards[c]
            lays = self.layouts[c] if self.layouts is not None else None
            local_shared = srv.client_view(snapshot, lag, c)
            acc = None
            for s in range(tcfg.tau):                # sample (τ sweeps)
                k = jax.random.fold_in(self.key, r * 131 + c * 17 + s)
                self.locals_[c], d = fam.sweep(
                    cfg, self.locals_[c], local_shared, state.tables,
                    state.stale, t, m, k, method=tcfg.method,
                    layout=tcfg.layout, sorted_layouts=lays)
                local_shared = fam.apply_delta(local_shared, d)
                acc = d if acc is None else {n: acc[n] + d[n] for n in d}
            # Client-local constraint rules (e.g. HDP's table-count
            # polytope 1 ≤ m_dk ≤ n_dk) — applied every round, exactly as
            # the distributed round does.
            self.locals_[c] = fam.local_project(self.locals_[c])
            if lag is not None:
                # Read-my-writes: the pre-filter delta the client applied
                # locally rides in its lag row until the next refresh —
                # including when its push below is lost (the delta is in
                # the client's replica regardless).
                lag = {n: lag[n].at[c].add(acc[n]) for n in lag}
            kf = jax.random.fold_in(self.key, 7000 + r * 131 + c)
            acc, self.residuals[c] = round_mod.filter_push(   # filter (§5.3)
                fam, acc, tcfg.filter, kf, self.residuals[c])
            if not rf.push_ok[c]:
                continue   # lost push (§5.4): the filtered delta is
                           # dropped on the floor, not residual-carried
            total_delta = acc if total_delta is None else {
                n: total_delta[n] + acc[n] for n in acc}
            if pol.immediate:                        # async: push lands now
                snapshot = fam.apply_delta(snapshot, acc)

        if pol.immediate:
            state = srv.load_dense(state, snapshot)
            state = state._replace(
                clocks=state.clocks + jnp.asarray(pushed, jnp.int32))
        elif total_delta is not None:                # push (barrier)
            state = srv.push(state, total_delta, jnp.asarray(pushed))
        do_project = bool(tcfg.project_every
                          and r % tcfg.project_every == 0)
        state = srv.project(state, do_project)       # project
        dense = srv.assemble(state)
        locals2, dense = fam.post_round(             # family auxiliaries
            cfg, self.locals_, dense,
            jax.random.fold_in(self.key, 9000 + r))
        self.locals_ = list(locals2)
        state = srv.load_dense(state, dense)
        self.pstate = state._replace(cache=cache, cache_version=version,
                                     client_lag=lag)
        self._sync()
        self.round_idx += 1

    # ---------------------------------------------------- snapshot/restore
    def snapshot_state(self) -> dict:
        """The full training pytree a snapshot carries (§5.4): the
        server's :class:`~repro.core.server.ServerState` (canonical
        shards, SSP cache + per-client clocks, changed-row accounting,
        resident alias proposal), per-client locals and residuals, the
        run RNG key, and the host-side schedule scalars (round index,
        cache-version mirror, retry/build counters) as int32 leaves —
        everything a bit-exact BSP resume needs.

        Over tcp the shard servers own the canonical statistics (they
        snapshot themselves — SNAPSHOT_WRITE), so the worker snapshot
        carries the *client edge* instead: this process's locals and
        residuals, the pulled versioned snapshot, the alias proposal
        built from it, and the read-my-writes lag rows.  A restored
        worker resumes mid-run against the still-live servers
        (``Trainer.restore``), bit-exactly under BSP with
        ``snapshot_every=1``."""
        hv = -1 if self._host_version is None else self._host_version
        state = {
            "locals": tuple(self.locals_),
            "residuals": tuple(self.residuals),
            "key": self.key,
            "round_idx": np.int32(self.round_idx),
            "host_version": np.int32(hv),
            "alias_builds": np.int32(self.alias_builds),
            "pull_retries": np.int32(self._pull_retries),
        }
        if self.remote is not None:
            if self._tcp_snapshot is None or self._tcp_tables is None:
                raise ValueError(
                    "tcp snapshot before the first pull: the client edge "
                    "(pulled snapshot + alias proposal) is empty — step "
                    "at least one round first")
            tv = -1 if self._tcp_version is None else self._tcp_version
            state.update({
                "tcp_snapshot": self._tcp_snapshot,
                "tcp_version": np.int32(tv),
                "tcp_tables": self._tcp_tables,
                "tcp_stale": self._tcp_stale,
                "tcp_lag": self._lag,
            })
        else:
            state["server"] = self.pstate
        return state

    def save_snapshot(self) -> str:
        """Write a snapshot of :meth:`snapshot_state` at the current
        round through ``checkpoint.ckpt`` (write-then-rename manifest).
        Barrier-free in the §5.4 sense: no ``_sync()`` — the host blocks
        only to serialize the buffers it writes, while already-dispatched
        rounds keep running."""
        if not self.tcfg.snapshot_dir:
            raise ValueError("TrainerConfig.snapshot_dir is not set")
        return ckpt.save(self.tcfg.snapshot_dir, self.tcfg.snapshot_name,
                         self.round_idx, self.snapshot_state())

    @classmethod
    def restore(cls, model_cfg, tokens: Array, mask: Array, *,
                config: TrainerConfig = TrainerConfig(),
                snapshot_dir: str | None = None,
                step: int | None = None,
                key: Array | None = None) -> "Trainer":
        """Resume a run from its latest snapshot manifest.

        Builds a Trainer exactly as ``__init__`` would (same config, same
        corpus — sharding and sorted layouts are re-derived
        deterministically), then overwrites its round state from the
        newest *readable* snapshot in ``snapshot_dir`` (defaulting to
        ``config.snapshot_dir``): a truncated newest file falls back to
        the previous manifest entry (``ckpt.restore_latest``).

        The restored run continues **bit-exactly** under BSP — the
        snapshot carries every round input (state, residuals, clocks,
        RNG key, round index, alias proposal), so rounds ``k, k+1, …``
        replay identically to the uninterrupted run (the oracle property;
        asserted in tests).  Under SSP/async the continuation is
        within-tolerance: the schedule state (cache version, retry
        budget) is restored, but a crash by definition lost whatever
        staleness window was in flight."""
        tcfg = config
        sdir = snapshot_dir if snapshot_dir is not None else tcfg.snapshot_dir
        if not sdir:
            raise ValueError("no snapshot_dir: pass snapshot_dir= or set "
                             "TrainerConfig.snapshot_dir")
        trainer = cls(model_cfg, tokens, mask, config=tcfg, key=key)
        # Materialize the alias proposal so the restore template has the
        # snapshot's pytree structure (snapshots are written after at
        # least one round, whose pull built the tables — a fresh
        # Trainer's `tables=None` placeholder would not unflatten).
        # Note the fresh tcp Trainer's __init__ already re-sent its INIT
        # pushes — the servers' mutation log dedups them (same seed ⇒
        # same digest), so the canonical state is untouched.
        if trainer.remote is not None:
            trainer._materialize_tcp_edge()
        else:
            trainer.pstate = trainer.server.refresh_proposal(
                model_cfg, trainer.pstate)
        snap = ckpt.restore_latest(sdir, tcfg.snapshot_name,
                                   trainer.snapshot_state(), step=step)
        trainer._install_snapshot(snap)
        return trainer

    def _materialize_tcp_edge(self) -> None:
        """Template materialization for a tcp restore: structurally the
        client edge a running worker holds — one pull (any round the
        servers have finalized) plus the alias proposal built from it.
        Values are overwritten by the restored snapshot."""
        if self._tcp_snapshot is None:
            snap, version, _ = self.remote.pull(0, None)
            self._tcp_snapshot = snap
            self._tcp_version = version
        if self._tcp_tables is None:
            self._tcp_tables, self._tcp_stale = self.family.build_alias(
                self.cfg, self._tcp_snapshot)

    def _install_snapshot(self, snap: dict) -> None:
        as_device = functools.partial(jax.tree.map, jnp.asarray)
        self.locals_ = list(as_device(snap["locals"]))
        self.residuals = list(as_device(snap["residuals"]))
        self.key = jnp.asarray(snap["key"])
        self.round_idx = int(snap["round_idx"])
        hv = int(snap["host_version"])
        self._host_version = None if hv < 0 else hv
        self.alias_builds = int(snap["alias_builds"])
        self._pull_retries = int(snap["pull_retries"])
        if self.remote is None:
            self.pstate = as_device(snap["server"])
            return
        self._tcp_snapshot = as_device(snap["tcp_snapshot"])
        tv = int(snap["tcp_version"])
        self._tcp_version = None if tv < 0 else tv
        self._tcp_tables = as_device(snap["tcp_tables"])
        self._tcp_stale = as_device(snap["tcp_stale"])
        self._lag = as_device(snap["tcp_lag"])
        # The rejoin protocol (DESIGN.md §13): clear whatever pending
        # pushes and open mutation-log entries the dead incarnation left
        # at the servers, lift any eviction, and take the next pull
        # fresh.  Replayed pushes for rounds the servers already
        # finalized dedup against the mutation log (bit-exact restore ⇒
        # identical digests), so the resumed rounds apply exactly once.
        for c in self.local_clients:
            self.remote.rejoin(c)
        self._tcp_version = None

    def run(self, n_rounds: int, *, eval_every: int = 5,
            eval_docs: int = 32) -> RunResult:
        """Run ``n_rounds`` sync rounds with periodic held-out evaluation.

        Compiled rounds pipeline asynchronously between evaluation points;
        per-round times are therefore measured per eval segment (wall time
        from the previous sync, amortized over the segment's rounds)."""
        fam, cfg = self.family, self.cfg
        eval_t = self.tokens[:eval_docs]
        eval_m = self.mask[:eval_docs]
        res = RunResult(tokens=self.n_tokens)
        first = self.round_idx
        seg_start = time.perf_counter()
        seg_rounds = 0
        for r in range(first, first + n_rounds):
            self.step()
            seg_rounds += 1
            if (r - first) % eval_every == 0 or r == first + n_rounds - 1:
                self._sync()
                dt = (time.perf_counter() - seg_start) / seg_rounds
                res.iter_times.extend([dt] * seg_rounds)
                res.perplexities.append(float(fam.perplexity(
                    cfg, self.shared, eval_t, eval_m,
                    jax.random.PRNGKey(42))))
                res.topics_per_word.append(
                    float(fam.topics_per_word(self.shared)))
                res.violations.append(
                    float(fam.count_violations(self.shared)))
                seg_start = time.perf_counter()
                seg_rounds = 0
        return res

    # ------------------------------------------------------------ queries
    def perplexity(self, tokens: Array | None = None,
                   mask: Array | None = None,
                   key: Array | None = None) -> float:
        return float(self.family.perplexity(
            self.cfg, self.shared,
            self.tokens if tokens is None else tokens,
            self.mask if mask is None else mask,
            jax.random.PRNGKey(42) if key is None else key))

    def consistency_error(self) -> float:
        """Max |counts-from-assignments − maintained| over the family's
        count-conserved shared statistics, summed across client shards.

        With the dense filter this must be exactly 0.0 in either layout
        AND under every consistency policy — staleness delays what a
        client *sees*, never what the server *applies*: every pushed
        delta lands exactly once (error feedback carries filtered mass),
        so the canonical counts always match the assignments.
        """
        fam, cfg = self.family, self.cfg
        if self.remote is not None and \
                len(self.local_clients) != self.tcfg.n_clients:
            raise RuntimeError(
                "consistency_error needs every client's locals; this "
                "worker only runs clients "
                f"{self.local_clients} of {self.tcfg.n_clients}")
        totals: dict[str, Array] = {}
        for (t, m), loc in zip(self.shards, self.locals_):
            for n, v in fam.count_stats(cfg, t, m, loc).items():
                totals[n] = v if n not in totals else totals[n] + v
        stats = fam.stats_dict(self.shared)
        return max(float(jnp.abs(totals[n] - stats[n]).max())
                   for n in fam.conserved_stats)
