"""High Performance Latent Variable Models — the stable top-level API.

The paper's system in three objects:

* :func:`get_family` — the ModelFamily registry (LDA / PDP / HDP share one
  inference stack; ``repro.core.family``),
* :class:`ParameterServer` / :class:`Consistency` — vocabulary-sharded
  shared statistics under a pluggable consistency policy (BSP / SSP /
  async; ``repro.core.server``),
* :class:`Trainer` — the multi-client driver running compiled sync rounds
  against the server (``repro.engine``).

>>> import repro
>>> fam = repro.get_family("lda")
>>> trainer = repro.Trainer(cfg, tokens, mask,
...                         config=repro.TrainerConfig(consistency="ssp:2"))
"""

from repro.core import family
from repro.core.family import get as get_family
from repro.core.fault import FaultEvent, FaultPlan
from repro.core.ps import FilterSpec
from repro.core.server import (Async, BSP, Consistency, ParameterServer,
                               ServerState, ShardSpec, SSP,
                               make_consistency)
from repro.engine import RunResult, Trainer, TrainerConfig
from repro.net import RemoteParameterServer, serve_shards
from repro.net.protocol import ProtocolError
from repro.serve import (FoldInEngine, InferenceSnapshot, ServeConfig,
                         freeze_snapshot)

__all__ = [
    "Async",
    "BSP",
    "Consistency",
    "FaultEvent",
    "FaultPlan",
    "FilterSpec",
    "FoldInEngine",
    "InferenceSnapshot",
    "ParameterServer",
    "ProtocolError",
    "RemoteParameterServer",
    "RunResult",
    "SSP",
    "ServeConfig",
    "ServerState",
    "ShardSpec",
    "Trainer",
    "TrainerConfig",
    "family",
    "freeze_snapshot",
    "get_family",
    "make_consistency",
    "serve_shards",
]
