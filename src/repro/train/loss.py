"""Sequence-chunked, vocab-sharded cross-entropy.

The (B, S, Vp) logits tensor never materializes: the hidden states are
unembedded in sequence chunks, each chunk's logits stay sharded over the
``model`` axis on the vocab dim, and only the (B, chunk) scalar losses
survive.  Padding vocabulary ids (vocab_size..padded_vocab) are masked to
-inf so they contribute nothing to the partition function.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import cast

Array = jax.Array


def chunked_ce_loss(cfg: ModelConfig, params, hidden: Array, targets: Array,
                    mask: Array, *, chunk: int = 512) -> Array:
    """Mean next-token CE over ``mask``.  hidden: (B, S, D) at positions
    predicting targets (B, S)."""
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    n = s // chunk
    rem = s - n * chunk

    vocab_ids = jnp.arange(cfg.padded_vocab)
    pad_mask = (vocab_ids >= cfg.vocab_size)

    from jax.sharding import PartitionSpec as P
    from repro.models.layers import get_activation_spec
    act = get_activation_spec()

    def chunk_loss(h_c: Array, t_c: Array, m_c: Array) -> tuple[Array, Array]:
        logits = jnp.einsum("bsd,vd->bsv", h_c, cast(table),
                            preferred_element_type=jnp.float32)
        if act is not None:
            # zero modes: batch/seq sharding of the hidden states conflicts
            # with the vocab sharding of the table on the model axis; left
            # alone XLA gathers the (B, chunk, V/16) logits across batch
            # (measured 38 GiB/step).  Constraining logits to the activation
            # sharding makes the loop-invariant TABLE the gathered operand.
            logits = jax.lax.with_sharding_constraint(
                logits, P(act[0], act[1], None))
        logits = jnp.where(pad_mask[None, None], -1e30, logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # Gold logit as a masked reduction over the vocab dim (NOT
        # take_along_axis: gathering along the vocab-SHARDED dim makes XLA
        # all-gather the full (B, chunk, V) logits — measured 78 GiB/step on
        # qwen3 zero_batch; the masked max reduces the sharded dim locally
        # and cross-shard combines only (B, chunk) scalars).
        gold = jnp.max(jnp.where(vocab_ids[None, None] == t_c[..., None],
                                 logits, -jnp.inf), axis=-1)
        nll = (lse - gold) * m_c
        return nll.sum(), m_c.sum()

    if n > 0:
        hc = hidden[:, :n * chunk].reshape(b, n, chunk, d)
        tc = targets[:, :n * chunk].reshape(b, n, chunk)
        mc = mask[:, :n * chunk].reshape(b, n, chunk).astype(jnp.float32)

        def body(carry, xs):
            h_c, t_c, m_c = xs
            l, m = chunk_loss(h_c, t_c, m_c)
            return (carry[0] + l, carry[1] + m), None

        (tot, cnt), _ = jax.lax.scan(
            body, (jnp.zeros(()), jnp.zeros(())),
            (hc.transpose(1, 0, 2, 3), tc.transpose(1, 0, 2),
             mc.transpose(1, 0, 2)))
    else:
        tot = jnp.zeros(())
        cnt = jnp.zeros(())
    if rem:
        l, m = chunk_loss(hidden[:, n * chunk:], targets[:, n * chunk:],
                          mask[:, n * chunk:].astype(jnp.float32))
        tot = tot + l
        cnt = cnt + m
    return tot / jnp.maximum(cnt, 1.0)
