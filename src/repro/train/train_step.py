"""The jittable training step: microbatched gradient accumulation, remat,
mixed precision, AdamW — plus the optional stale-synchronous gradient mode
(the paper's parameter-server communication pattern; see train/sync.py).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.optim import adamw
from repro.train.loss import chunked_ce_loss

Array = jax.Array


@dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    microbatches: int = 1
    loss_chunk: int = 512


def shift_targets(tokens: Array) -> tuple[Array, Array, Array]:
    """Next-token prediction: inputs (B, S), targets (B, S), mask.

    Sequence length is kept at S (targets roll left; the final position is
    masked out) so attention chunking stays aligned to the padded shape.
    """
    inputs = tokens
    targets = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones_like(targets, jnp.float32).at[:, -1].set(0.0)
    return inputs, targets, mask


def loss_fn(cfg: ModelConfig, tcfg: TrainConfig, params, batch
            ) -> tuple[Array, dict[str, Array]]:
    tokens = batch["tokens"]
    inputs, targets, mask = shift_targets(tokens)
    fwd_batch = dict(batch)
    fwd_batch["tokens"] = inputs
    hidden, aux = model_lib.forward(cfg, params, fwd_batch, remat=True)
    ce = chunked_ce_loss(cfg, params, hidden, targets, mask,
                         chunk=tcfg.loss_chunk)
    return ce + aux, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params', opt', metrics).

    The global batch splits into ``tcfg.microbatches`` microbatches scanned
    sequentially with gradient accumulation — the live activation set is one
    microbatch, which is what lets 76B-scale configs fit v5e HBM.
    """

    def train_step(params, opt_state: adamw.AdamWState, batch):
        n_mb = tcfg.microbatches

        if n_mb == 1:
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: loss_fn(cfg, tcfg, p, batch), has_aux=True)(params)
        else:
            def split_mb(x):
                b = x.shape[0]
                return x.reshape((n_mb, b // n_mb) + x.shape[1:])

            mbs = jax.tree.map(split_mb, batch)

            def accum(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(
                    lambda p: loss_fn(cfg, tcfg, p, mb), has_aux=True)(params)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), m

            g0 = jax.tree.map(jnp.zeros_like, params)
            (grads, loss), metrics = jax.lax.scan(accum, (g0, jnp.zeros(())),
                                                  mbs)
            grads = jax.tree.map(lambda g: g / n_mb, grads)
            loss = loss / n_mb
            metrics = jax.tree.map(lambda m: m.mean(), metrics)

        lr = adamw.cosine_schedule(opt_state.step, peak_lr=tcfg.peak_lr,
                                   warmup=tcfg.warmup, total=tcfg.total_steps)
        new_params, new_opt = adamw.update(
            params, grads, opt_state, lr=lr,
            weight_decay=tcfg.weight_decay, grad_clip=tcfg.grad_clip)
        out_metrics = {"loss": loss, "lr": lr,
                       "grad_norm": adamw.global_norm(grads), **metrics}
        return new_params, new_opt, out_metrics

    return train_step
