"""Stale-synchronous, filter-compressed gradient sync — the paper's
parameter-server communication pattern (eventual consistency + magnitude-
priority filters, §5.3) applied to data-parallel SGD.  This is the
*beyond-paper* transfer recorded separately in EXPERIMENTS.md.

Mechanics (per client = data shard, expressed with shard_map):
  - each client keeps a full parameter replica and an error-feedback
    *residual* pytree (what filters withheld so far);
  - every step it computes local gradients and adds them to the residual;
  - every ``sync_every`` steps it pushes the *filtered* residual (top-k rows
    by L1 magnitude + uniformly sampled anti-starvation rows) through a
    psum and applies the synced update; between syncs it applies its own
    local update (bounded staleness — exactly the topic-model driver's τ);
  - nothing is ever dropped: residual_update carries withheld mass forward,
    the eventual-consistency guarantee in exact form.

This trades gradient freshness for a ~V/k reduction in sync bytes; the
convergence benchmark (benchmarks/bench_stale_sync.py) quantifies the
trade on a real LM.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import ps

Array = jax.Array


@dataclass(frozen=True)
class SyncConfig:
    sync_every: int = 1                    # τ: steps between syncs
    filter: ps.FilterSpec = field(default_factory=ps.FilterSpec)


def filter_tree(grads: Any, spec: ps.FilterSpec, key: Array) -> Any:
    """Apply the communication filter leaf-wise.  2-D+ leaves filter by
    row-magnitude on their leading dim; 1-D leaves pass through dense (they
    are negligible traffic)."""
    leaves, treedef = jax.tree.flatten(grads)
    out = []
    for i, g in enumerate(leaves):
        if g.ndim >= 2 and spec.kind != "dense":
            rows = g.reshape(g.shape[0], -1)
            k = jax.random.fold_in(key, i)
            filt = ps.filter_delta(rows, spec, k).reshape(g.shape)
            out.append(filt)
        else:
            out.append(g)
    return jax.tree.unflatten(treedef, out)


def make_sync_fns(mesh: Mesh, scfg: SyncConfig, data_axis: str = "data"):
    """Returns (local_update, synced_update) pieces used by the stale-sync
    trainer loop in ``repro.launch.train`` (driver-level, since the sync
    cadence is a Python-loop decision, matching the paper's round structure).
    """

    def push(residual: Any, key: Array) -> tuple[Any, Any]:
        """Filter the residual, psum across clients, return (synced_grads,
        new_residual).  Runs inside shard_map over the data axis."""
        sent = filter_tree(residual, scfg.filter, key)
        synced = jax.tree.map(lambda s: jax.lax.psum(s, data_axis), sent)
        new_residual = jax.tree.map(lambda r, s: r - s, residual, sent)
        return synced, new_residual

    return push


def sync_bytes_estimate(params: Any, spec: ps.FilterSpec) -> tuple[int, int]:
    """(dense_bytes, filtered_bytes) one sync round would move per client —
    the napkin math for the §Perf collective-term hypothesis."""
    dense = 0
    filtered = 0
    for g in jax.tree.leaves(params):
        nbytes = g.size * 4
        dense += nbytes
        if g.ndim >= 2 and spec.kind == "topk":
            rows = g.shape[0]
            row_bytes = (g.size // rows) * 4
            kept = min(rows, spec.k_rows + spec.random_rows)
            filtered += kept * row_bytes + kept * 4
        else:
            filtered += nbytes
    return dense, filtered
