"""Sharding rules: parameter/activation PartitionSpecs for train and serve.

Two modes (DESIGN.md §5, EXPERIMENTS.md §Perf):

``mode="megatron"`` (paper-faithful baseline — TP over ``model``):
- Tensor parallelism over the ``model`` axis, FSDP over the ``data`` axis
  (training only), pure data parallelism over the ``pod`` axis.
- Attention projections are (D, H, hd): the head axis shards over ``model``
  when divisible, else the head_dim axis (GQA kv heads rarely divide 16),
  else replicate.
- MoE experts shard over ``model`` when E divides it (expert parallelism,
  phi3.5-moe), else d_ff Megatron-sharding inside each expert (mixtral).
- The embedding / lm_head table is (Vp, D) with vocab over ``model`` so the
  chunked cross-entropy keeps logits vocab-sharded.
- 1-D leaves (norms, biases, scalars) replicate.
The generic rule is greedy: prefer ``model`` on the *last* shardable dim
(contraction outputs), ``data`` on the first remaining shardable dim.
Leaves under a scanned "blocks" collection skip their leading layer dim.

``mode="zero_seq"`` (the §Perf optimization): ZeRO-3 + sequence parallelism.
The HLO analysis of the megatron baseline shows two pathologies: (a) when
head counts don't divide the 16-way axis the greedy rule shards head_dim —
a *contraction* dim of the attention-score einsum — so XLA all-reduces full
(B, KV, rep, q, S) score tensors every layer; (b) activations carry no
``model``-axis sharding, so backward re-gathers full (B, S, D)/(B, S, F)
tensors per layer.  zero_seq instead:
- activations shard (B → data, S → model) everywhere (sequence parallel);
  attention queries stay S-sharded, K/V are all-gathered per layer (small);
- weights are *storage*-sharded over both axes on whatever dims divide
  (pure ZeRO-3) and all-gathered per layer at use — for every assigned
  arch the per-layer weight gather ≪ the score/activation all-reduces it
  replaces;
- MoE expert weights keep expert-parallelism over ``model`` when E divides
  it (the all-to-all dispatch is already the cheap pattern);
- embedding/lm_head keep vocab over ``model`` (chunked CE unchanged).
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array


def _greedy_spec(shape: tuple[int, ...], start: int, mesh_sizes: dict[str, int],
                 fsdp_axis: str | None) -> P:
    assign: list[Any] = [None] * len(shape)
    # model on the last shardable dim
    for i in reversed(range(start, len(shape))):
        if shape[i] % mesh_sizes["model"] == 0:
            assign[i] = "model"
            break
    if fsdp_axis:
        for i in range(start, len(shape)):
            if assign[i] is None and shape[i] % mesh_sizes[fsdp_axis] == 0:
                assign[i] = fsdp_axis
                break
    return P(*assign)


def _is_stacked(path: tuple) -> bool:
    keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
    return "blocks" in keys


def _leaf_name(path: tuple) -> str:
    k = path[-1]
    return getattr(k, "key", getattr(k, "name", str(k)))


def param_specs(params_or_shapes: Any, *, mesh: Mesh,
                fsdp: bool = True, mode: str = "megatron") -> Any:
    """PartitionSpec pytree for a parameter pytree (arrays or
    ShapeDtypeStructs)."""
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    fsdp_axis = "data" if (fsdp and "data" in mesh_sizes) else None

    def zero_rule(path, leaf):
        """ZeRO-3 storage sharding: big dims over model/data wherever they
        divide; embeddings keep vocab over model; MoE experts keep expert
        parallelism when E divides the model axis."""
        shape = leaf.shape
        name = _leaf_name(path)
        start = 1 if _is_stacked(path) else 0
        eff = shape[start:]
        if len(eff) <= 1:
            return P()
        if name in ("embed", "lm_head"):
            spec = [None] * len(shape)
            if shape[0] % mesh_sizes["model"] == 0:
                spec[0] = "model"
            if fsdp_axis and shape[1] % mesh_sizes[fsdp_axis] == 0:
                spec[1] = fsdp_axis
            return P(*spec)
        if name in ("w_gate", "w_up", "w_down") and len(eff) == 3 \
                and eff[0] % mesh_sizes["model"] == 0:
            spec = [None] * len(shape)
            spec[start] = "model"                  # expert parallel
            if fsdp_axis and eff[1] % mesh_sizes[fsdp_axis] == 0:
                spec[start + 1] = fsdp_axis
            return P(*spec)
        # generic ZeRO: model on the largest divisible dim, data on the
        # next largest remaining divisible dim
        spec = [None] * len(shape)
        order = sorted(range(start, len(shape)), key=lambda i: -shape[i])
        for i in order:
            if shape[i] % mesh_sizes["model"] == 0:
                spec[i] = "model"
                break
        if fsdp_axis:
            for i in order:
                if spec[i] is None and shape[i] % mesh_sizes[fsdp_axis] == 0:
                    spec[i] = fsdp_axis
                    break
        return P(*spec)

    def rule(path, leaf):
        shape = leaf.shape
        name = _leaf_name(path)
        start = 1 if _is_stacked(path) else 0
        eff = shape[start:]
        if len(eff) <= 1:
            return P()
        if name in ("embed", "lm_head"):
            spec = [None] * len(shape)
            if shape[0] % mesh_sizes["model"] == 0:
                spec[0] = "model"
            if fsdp_axis and shape[1] % mesh_sizes[fsdp_axis] == 0:
                spec[1] = fsdp_axis
            return P(*spec)
        if name == "router":
            # (L, D, E): E is small; shard D over fsdp only
            spec = [None] * len(shape)
            if fsdp_axis and shape[start] % mesh_sizes[fsdp_axis] == 0:
                spec[start] = fsdp_axis
            return P(*spec)
        if name in ("w_gate", "w_up", "w_down") and len(eff) == 3:
            # MoE expert weights (L, E, a, b)
            e = eff[0]
            spec = [None] * len(shape)
            if e % mesh_sizes["model"] == 0:
                spec[start] = "model"          # expert parallel
                if fsdp_axis and eff[1] % mesh_sizes[fsdp_axis] == 0:
                    spec[start + 1] = fsdp_axis
            else:
                # Megatron inside experts: shard the f dim over model
                f_dim = start + (2 if name != "w_down" else 1)
                other = start + (1 if name != "w_down" else 2)
                if shape[f_dim] % mesh_sizes["model"] == 0:
                    spec[f_dim] = "model"
                if fsdp_axis and shape[other] % mesh_sizes[fsdp_axis] == 0:
                    spec[other] = fsdp_axis
            return P(*spec)
        return _greedy_spec(shape, start, mesh_sizes, fsdp_axis)

    return jax.tree_util.tree_map_with_path(
        zero_rule if mode == "zero_seq" else rule, params_or_shapes)


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes that shard the global batch dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_specs(batch_template: Any, mesh: Mesh,
               mode: str = "megatron") -> Any:
    """Batch arrays shard their leading dim over (pod, data); in zero_seq
    mode the sequence dim (dim 1) additionally shards over ``model``; in
    zero_batch mode the batch dim shards over ALL axes (pure ZeRO-DP)."""
    ax = batch_axes(mesh)
    model = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    all_ax = ax + ("model",) if model > 1 else ax

    def rule(leaf):
        spec: list[Any] = [None] * len(leaf.shape)
        if (mode == "zero_batch" and leaf.shape
                and leaf.shape[0] % _prod(mesh, all_ax) == 0):
            spec[0] = all_ax
            return P(*spec)
        if leaf.shape and leaf.shape[0] % _prod(mesh, ax) == 0:
            spec[0] = ax if len(ax) > 1 else ax[0]
        if (mode == "zero_seq" and len(leaf.shape) >= 2
                and leaf.shape[1] % model == 0 and model > 1):
            spec[1] = "model"
        return P(*spec)

    return jax.tree.map(rule, batch_template)


def resolve_mode(mesh: Mesh, mode: str, global_batch: int,
                 seq_len: int = 0) -> str:
    """zero_batch needs B to divide the whole mesh; fall back to zero_seq
    (which needs S to divide the model axis; else megatron)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model = sizes.get("model", 1)
    if mode == "zero_batch":
        full = _prod(mesh, batch_axes(mesh)) * model
        if global_batch % full == 0:
            return "zero_batch"
        mode = "zero_seq"
    if mode == "zero_seq" and seq_len and seq_len % model:
        return "megatron"
    return mode


def activation_spec(mesh: Mesh, mode: str = "megatron") -> P | None:
    """The (B, S, D) hidden-state constraint applied inside the forward
    pass.  zero_seq: batch over (pod, data), sequence over model.
    zero_batch: batch over every axis."""
    ax = batch_axes(mesh)
    if mode == "zero_batch":
        model = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
        all_ax = ax + ("model",) if model > 1 else ax
        return P(all_ax, None, None)
    if mode != "zero_seq":
        return None
    return P(ax if len(ax) > 1 else ax[0], "model", None)


def cache_specs(cache_template: Any, mesh: Mesh) -> Any:
    """Decode caches: batch dim over (pod, data); attention K/V sequence dim
    over ``model`` (flash-decode layout); SSM states shard their trailing
    head_dim over ``model`` when divisible."""
    ax = batch_axes(mesh)
    nbatch = _prod(mesh, ax)
    model = mesh.shape["model"] if "model" in mesh.axis_names else 1
    bspec = ax if len(ax) > 1 else (ax[0] if ax else None)

    def rule(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        if name in ("pos", "key_pos"):
            return P()
        spec: list[Any] = [None] * len(shape)
        if name in ("k", "v"):
            # (n, B, S, KV, hd)
            if shape[1] % nbatch == 0 and nbatch > 1:
                spec[1] = bspec
            if shape[2] % model == 0:
                spec[2] = "model"
            return P(*spec)
        # ssm state (L, B, H, K, P), conv (L, B, W-1, d_inner), shifts
        if len(shape) >= 2 and shape[1] % nbatch == 0 and nbatch > 1:
            spec[1] = bspec
        for i in reversed(range(2, len(shape))):
            if shape[i] % model == 0:
                spec[i] = "model"
                break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, cache_template)


def _prod(mesh: Mesh, axes: tuple[str, ...]) -> int:
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


def named(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
