"""Inference client: INFER round-trips against ``serve.server``
(DESIGN.md §14), plus the client *process* the loopback serve smoke
launches.

The client is deliberately thin — one blocking RPC per document.  Service
concurrency comes from running many client connections (each gets its own
handler thread server-side; the batcher folds their documents into shared
fused sweeps).  A load-shed ERROR ("overloaded: …") is retried with
exponential backoff up to ``retries`` times; any other ERROR propagates.
"""

from __future__ import annotations

import argparse
import json
import socket
import time
from typing import Sequence

import numpy as np

from repro.net import protocol
from repro.net.protocol import MsgType, ProtocolError
from repro.serve.engine import InferRequest, InferResult, result_checksum


def _connect(addr: str, timeout: float) -> protocol.FramedConnection:
    host, port = addr.rsplit(":", 1)
    sock = socket.create_connection((host, int(port)), timeout=timeout)
    sock.settimeout(timeout)
    return protocol.FramedConnection(sock)


class InferenceClient:
    """One connection to an inference server."""

    def __init__(self, addr: str, *, timeout: float = 60.0,
                 retries: int = 5, backoff: float = 0.05):
        self.addr = addr
        self.timeout = timeout
        self.retries = retries
        self.backoff = backoff
        self.shed_retries = 0
        self._conn = _connect(addr, timeout)

    def infer(self, uid: int, tokens: Sequence[int], seed: int = 0
              ) -> InferResult:
        """Fold one document in; blocks until the server's chain mixes."""
        arrays = {"tokens": np.asarray(tokens, np.int32)}
        meta = {"uid": int(uid), "seed": int(seed)}
        delay = self.backoff
        for attempt in range(self.retries + 1):
            try:
                _, rmeta, rarr = self._conn.request(
                    MsgType.INFER, meta, arrays,
                    expect=(MsgType.INFER_RESULT,))
            except ProtocolError as e:
                # recv() folds ERROR frames into ProtocolError; only the
                # load-shed refusal is retryable (the server kept the
                # connection open for exactly this).
                if "overloaded" in str(e) and attempt < self.retries:
                    self.shed_retries += 1
                    time.sleep(delay)
                    delay *= 2
                    continue
                raise
            return InferResult(
                uid=int(rmeta["uid"]),
                theta=np.asarray(rarr["theta"], np.float32),
                assignments=np.asarray(rarr["assignments"], np.int32),
                n_sweeps=int(rmeta["n_sweeps"]))
        raise ProtocolError("unreachable")  # pragma: no cover

    def stats(self) -> dict:
        _, meta, _ = self._conn.request(MsgType.STATS, {},
                                        expect=(MsgType.OK,))
        return meta

    def shutdown(self) -> None:
        self._conn.request(MsgType.SHUTDOWN, {}, expect=(MsgType.OK,))

    def counters(self) -> dict:
        return self._conn.counters()

    def close(self) -> None:
        self._conn.close()

    def __enter__(self) -> "InferenceClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def synthetic_docs(vocab_size: int, n_docs: int, max_len: int, seed: int
                   ) -> list[np.ndarray]:
    """Deterministic request corpus shared by the client CLI, the
    launcher's in-process reference, and the benchmark — same seed, same
    documents, everywhere."""
    rng = np.random.default_rng(seed)
    return [rng.integers(0, vocab_size,
                         size=int(rng.integers(4, max_len + 1))
                         ).astype(np.int32)
            for _ in range(n_docs)]


def requests_for(client_id: int, *, vocab_size: int, n_docs: int,
                 max_len: int, corpus_seed: int, seed_base: int
                 ) -> list[InferRequest]:
    """The exact request list client ``client_id`` sends: uids are
    partitioned per client, request seeds derive from the uid — so the
    in-process reference can regenerate every request bit-for-bit."""
    docs = synthetic_docs(vocab_size, n_docs, max_len,
                          corpus_seed + client_id)
    return [InferRequest(uid=client_id * 10_000 + i, tokens=d,
                         seed=seed_base + client_id * 10_000 + i)
            for i, d in enumerate(docs)]


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="inference client process (repro.serve)")
    ap.add_argument("--addr", required=True)
    ap.add_argument("--client-id", type=int, required=True)
    ap.add_argument("--n-docs", type=int, default=8)
    ap.add_argument("--vocab-size", type=int, required=True)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--corpus-seed", type=int, default=7)
    ap.add_argument("--seed-base", type=int, default=1000)
    ap.add_argument("--timeout", type=float, default=120.0)
    ap.add_argument("--out", required=True,
                    help="write per-uid result checksums + latencies as "
                         "JSON (the launcher compares them)")
    args = ap.parse_args(argv)

    reqs = requests_for(args.client_id, vocab_size=args.vocab_size,
                        n_docs=args.n_docs, max_len=args.max_len,
                        corpus_seed=args.corpus_seed,
                        seed_base=args.seed_base)
    checksums: dict[str, str] = {}
    latencies: list[float] = []
    with InferenceClient(args.addr, timeout=args.timeout) as cli:
        for req in reqs:
            t0 = time.perf_counter()
            res = cli.infer(req.uid, req.tokens, seed=req.seed)
            latencies.append((time.perf_counter() - t0) * 1e3)
            checksums[str(res.uid)] = result_checksum(res)
        shed_retries = cli.shed_retries
    payload = {"client_id": args.client_id, "checksums": checksums,
               "latency_ms": latencies, "shed_retries": shed_retries}
    tmp = args.out + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f)
    import os
    os.replace(tmp, args.out)
    print(f"DONE {len(checksums)} docs", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
