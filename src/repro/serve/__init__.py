"""Online topic-inference serving (DESIGN.md §14): snapshot-frozen
fold-in engine, INFER service, and client."""

from repro.serve.engine import (FoldInEngine, InferRequest, InferResult,
                                ServeConfig, fold_in_perplexity,
                                reference_fold_in, result_checksum)
from repro.serve.snapshot import (InferenceSnapshot, freeze,
                                  from_checkpoint, from_servers,
                                  from_trainer)

# The unambiguous name for top-level re-export (repro.freeze_snapshot).
freeze_snapshot = freeze

__all__ = [
    "freeze_snapshot",
    "FoldInEngine",
    "InferRequest",
    "InferResult",
    "InferenceSnapshot",
    "ServeConfig",
    "fold_in_perplexity",
    "freeze",
    "from_checkpoint",
    "from_servers",
    "from_trainer",
    "reference_fold_in",
    "result_checksum",
]
