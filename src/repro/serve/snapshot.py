"""Inference snapshots: a trained model frozen for serving (DESIGN.md §14).

Serving never mutates the model.  An :class:`InferenceSnapshot` captures
everything the fold-in engine needs — the family name, the model config,
the dense shared statistics, and the alias proposal built over them — as
an immutable value.  Three provenance paths produce one:

* :func:`freeze` — from in-memory shared statistics (tests, notebooks);
* :func:`from_trainer` — from a live :class:`~repro.engine.trainer.Trainer`
  via its canonical ``Trainer.shared`` snapshot (works over both the
  in-process server and tcp);
* :func:`from_checkpoint` — from a ``checkpoint/ckpt.py`` manifest written
  by ``Trainer.save_snapshot`` (restores only the ``server/shards`` and
  ``server/aux`` leaves — the serving process never materializes client
  locals or the SSP cache);
* :func:`from_servers` — the PULL path: assemble the canonical statistics
  from live shard-server processes over the framed wire protocol.

The alias tables are built exactly once, at freeze time, with the same
``family.build_alias`` producer training uses — so the proposal the
serving chain mixes against is bit-identical to a training-time refresh
over the same statistics.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.core import family as family_mod
from repro.core import server as server_mod

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class InferenceSnapshot:
    """A trained model frozen for fold-in serving.

    ``shared`` is the family's dense SharedStats NamedTuple; ``tables`` /
    ``stale`` are the alias proposal built over it (``family.build_alias``).
    The snapshot is read-only by construction: the engine threads it into
    local-only sweeps and never writes any leaf back.
    """

    family_name: str
    cfg: Any
    shared: Any
    tables: Any
    stale: Array

    @property
    def family(self) -> family_mod.ModelFamily:
        return family_mod.get(self.family_name)

    @property
    def vocab_size(self) -> int:
        return self.cfg.vocab_size

    @property
    def n_topics(self) -> int:
        return self.cfg.n_topics

    def topic_prior(self) -> Array:
        """(K,) per-topic prior mass used when normalizing harvested
        proportions — the family's sparse prior, truncated to the first
        K entries for PDP (whose joint outcome space is 2K with the same
        α in both halves)."""
        prior = self.family.sparse_prior(self.cfg, self.shared)
        return prior[: self.cfg.n_topics]

    def language_model(self) -> Array:
        """(V, K) per-topic word distributions φ under the frozen stats."""
        return self.family.language_model(self.cfg, self.shared)


def freeze(cfg: Any, shared: Any) -> InferenceSnapshot:
    """Freeze dense shared statistics into a serving snapshot, building
    the alias proposal over them."""
    fam = family_mod.family_of(cfg)
    tables, stale = fam.build_alias(cfg, shared)
    return InferenceSnapshot(family_name=fam.name, cfg=cfg, shared=shared,
                             tables=tables, stale=stale)


def from_trainer(trainer: Any) -> InferenceSnapshot:
    """Freeze a live Trainer's canonical statistics (``Trainer.shared``
    waits for every stepped round to finalize, so the snapshot is a
    consistent round boundary, not a mid-round torn read)."""
    return freeze(trainer.cfg, trainer.shared)


def _shared_template(fam: family_mod.ModelFamily, cfg: Any, n_shards: int
                     ) -> tuple[dict, tuple[str, ...]]:
    """A ``{"server": {"shards": ..., "aux": ...}}`` template whose flat
    leaf paths match the ``server/shards/<s>/<stat>`` / ``server/aux/<stat>``
    keys a Trainer snapshot records for its ServerState — restore matches
    leaves by flat string key and ignores every other saved leaf, which is
    what lets the serving process skip client locals entirely."""
    dummy_tok = jnp.zeros((1, 1), jnp.int32)
    dummy_mask = jnp.zeros((1, 1), bool)
    _, shared = fam.init_state(cfg, dummy_tok, dummy_mask,
                               jax.random.PRNGKey(0))
    srv = server_mod.make_server(fam, cfg.vocab_size, n_shards=n_shards)
    shards, aux = srv.split(shared)
    sharded = tuple(sorted(shards[0]))
    return {"server": {"shards": tuple(dict(s) for s in shards),
                       "aux": dict(aux)}}, sharded


def _assemble(fam: family_mod.ModelFamily, shards, aux: dict,
              sharded: tuple[str, ...]) -> Any:
    dense = {n: jnp.concatenate([jnp.asarray(s[n]) for s in shards], axis=0)
             for n in sharded}
    dense.update({n: jnp.asarray(v) for n, v in aux.items()})
    return fam.shared_from_dict(dense)


def from_checkpoint(directory: str, cfg: Any, *, n_shards: int = 1,
                    name: str = "trainer",
                    step: int | None = None) -> InferenceSnapshot:
    """Freeze the newest readable Trainer snapshot under ``directory``.

    ``n_shards`` must match the partition the snapshot was written with
    (shape validation catches a mismatch).  Only the shared statistics
    are restored; the snapshot's client locals, SSP cache, clocks and
    alias proposal are ignored and the proposal is rebuilt fresh.
    """
    fam = family_mod.family_of(cfg)
    template, sharded = _shared_template(fam, cfg, n_shards)
    snap = ckpt.restore_latest(directory, name, template, step=step)
    shared = _assemble(fam, snap["server"]["shards"],
                       snap["server"]["aux"], sharded)
    return freeze(cfg, shared)


def from_servers(addrs: Any, cfg: Any, *, n_clients: int,
                 consistency: str = "bsp", timeout: float = 60.0,
                 min_round: int = 0) -> InferenceSnapshot:
    """Freeze the canonical assembled statistics of live shard servers
    (the PULL path): one SNAPSHOT round-trip per shard, after every round
    below ``min_round`` has finalized."""
    from repro.net import client as net_client
    with net_client.RemoteParameterServer(
            tuple(addrs), family=family_mod.family_of(cfg),
            n_clients=n_clients, consistency=consistency,
            vocab_size=cfg.vocab_size, timeout=timeout) as remote:
        shared = remote.snapshot(min_round=min_round)
    return freeze(cfg, shared)
