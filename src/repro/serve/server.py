"""Online inference service: INFER/INFER_RESULT over the framed wire
protocol (DESIGN.md §14).

An :class:`InferenceServer` owns one frozen
:class:`~repro.serve.snapshot.InferenceSnapshot` and one
:class:`~repro.serve.engine.FoldInEngine`, and serves concurrent clients
through the same threaded accept-loop / per-connection handler idiom as
``net.server.ShardServer``.  Connection handlers never touch the engine:
they validate an INFER frame fully, enqueue a ticket on the bounded
admission queue, and block until the batcher thread delivers the result.

Admission policy:

* **batching window** — when the engine is idle, the batcher waits up to
  ``max_batch_delay`` seconds after the first queued request before
  starting to sweep, so a burst of concurrent requests shares one fused
  sweep instead of serializing;
* **continuous admission** — while chains are mixing, newly queued
  requests are admitted at every inter-sweep boundary (a new document
  never waits for its batch-mates to finish);
* **load shed** — a full admission queue answers ERROR
  ("overloaded: …") immediately and keeps the connection; the client
  decides whether to retry.  Shed requests are counted (the benchmark
  artifact reports them).

Because a fold-in chain is a pure function of (snapshot, tokens, seed),
none of this scheduling is observable in the results — only in latency.

CLI (``python -m repro.serve.server``): loads the snapshot from a
Trainer checkpoint manifest, binds, writes ``--address-file``, prints
``READY host:port``.
"""

from __future__ import annotations

import argparse
import collections
import json
import os
import queue as queue_mod
import socket
import threading
import time
from typing import Any

import numpy as np

from repro.net import protocol
from repro.net.protocol import MsgType, ProtocolError
from repro.serve import snapshot as snapshot_mod
from repro.serve.engine import FoldInEngine, InferRequest, ServeConfig


class _Ticket:
    """One in-flight request: the handler blocks on ``event`` while the
    batcher folds the document in."""

    __slots__ = ("uid", "tokens", "seed", "event", "result", "error")

    def __init__(self, uid: int, tokens: np.ndarray, seed: int):
        self.uid = uid
        self.tokens = tokens
        self.seed = seed
        self.event = threading.Event()
        self.result = None
        self.error: str | None = None


class InferenceServer:
    """Serve fold-in requests for one frozen snapshot over TCP."""

    def __init__(self, snap, scfg: ServeConfig | None = None, *,
                 host: str = "127.0.0.1", port: int = 0,
                 max_queue: int = 64, max_batch_delay: float = 0.01,
                 request_timeout: float = 300.0,
                 idle_timeout: float = 1.0):
        self.snap = snap
        self.engine = FoldInEngine(snap, scfg)
        self.max_queue = max_queue
        self.max_batch_delay = max_batch_delay
        self.request_timeout = request_timeout
        self.idle_timeout = idle_timeout

        self._queue: queue_mod.Queue[_Ticket] = queue_mod.Queue(
            maxsize=max_queue)
        self._lock = threading.Lock()
        self._stop = False
        self._ticket_seq = 0
        self._protocol_errors = 0
        self._shed = 0
        self._served = 0
        self._latency_s: list[float] = []
        self._threads: list[threading.Thread] = []

        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(64)
        self.address = self._listener.getsockname()
        self._accept_thread: threading.Thread | None = None
        self._batch_thread: threading.Thread | None = None

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "InferenceServer":
        t = threading.Thread(target=self._accept_loop,
                             name=f"infer-accept-{self.address[1]}",
                             daemon=True)
        t.start()
        self._accept_thread = t
        b = threading.Thread(target=self._batch_loop,
                             name="infer-batcher", daemon=True)
        b.start()
        self._batch_thread = b
        return self

    def close(self) -> None:
        self._stop = True
        try:
            self._listener.close()
        except OSError:
            pass
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)
        if self._batch_thread is not None:
            self._batch_thread.join(timeout=5.0)

    @property
    def stopped(self) -> bool:
        return self._stop

    def stats(self) -> dict[str, Any]:
        with self._lock:
            lat = sorted(self._latency_s)

            def pct(p: float) -> float:
                if not lat:
                    return 0.0
                return lat[min(len(lat) - 1,
                               int(round(p * (len(lat) - 1))))]

            return {
                "served": self._served,
                "shed": self._shed,
                "protocol_errors": self._protocol_errors,
                "latency_p50_ms": pct(0.50) * 1e3,
                "latency_p99_ms": pct(0.99) * 1e3,
                "sweeps_run": self.engine.sweeps_run,
            }

    # --------------------------------------------------------------- batcher
    def _batch_loop(self) -> None:
        """The only thread that touches the engine: admit → step →
        harvest, with the batching window when idle."""
        pending: collections.deque[_Ticket] = collections.deque()
        live: dict[int, _Ticket] = {}
        while not self._stop:
            if not pending and not live:
                try:
                    pending.append(self._queue.get(timeout=0.1))
                except queue_mod.Empty:
                    continue
                # Batching window: give the rest of a concurrent burst a
                # chance to share the first fused sweep.
                deadline = time.monotonic() + self.max_batch_delay
                while True:
                    left = deadline - time.monotonic()
                    if left <= 0:
                        break
                    try:
                        pending.append(self._queue.get(timeout=left))
                    except queue_mod.Empty:
                        break
            # Continuous admission: drain whatever fits right now.
            while self.engine.free_slots() > len(pending):
                try:
                    pending.append(self._queue.get_nowait())
                except queue_mod.Empty:
                    break
            while pending:
                t = pending[0]
                try:
                    ok = self.engine.admit(InferRequest(
                        uid=id(t), tokens=t.tokens, seed=t.seed))
                except ValueError as e:
                    # Backstop — handlers validate before enqueueing.
                    t.error = str(e)
                    t.event.set()
                    pending.popleft()
                    continue
                if not ok:
                    break
                live[id(t)] = t
                pending.popleft()
            if not live:
                continue
            self.engine.step()
            for res in self.engine.harvest():
                t = live.pop(res.uid)
                t.result = res
                t.event.set()
        for t in list(pending) + list(live.values()):
            t.error = "server shutting down"
            t.event.set()

    # ----------------------------------------------------------- connections
    def _accept_loop(self) -> None:
        self._listener.settimeout(0.2)
        while not self._stop:
            try:
                sock, _addr = self._listener.accept()
            except socket.timeout:
                continue
            except OSError:
                break
            t = threading.Thread(target=self._serve_conn, args=(sock,),
                                 daemon=True)
            t.start()
            self._threads.append(t)

    def _validate(self, meta: dict, arrays: dict) -> _Ticket:
        """Full request validation before anything is enqueued — a
        malformed INFER never reaches the engine (the serving analogue of
        'no store mutation before full decode')."""
        uid = meta.get("uid")
        if not isinstance(uid, int) or isinstance(uid, bool):
            raise ValueError(f"INFER meta.uid must be an int, got "
                             f"{type(uid).__name__}")
        seed = meta.get("seed", 0)
        if not isinstance(seed, int) or isinstance(seed, bool):
            raise ValueError("INFER meta.seed must be an int")
        if "tokens" not in arrays:
            raise ValueError("INFER frame has no 'tokens' array")
        toks = np.asarray(arrays["tokens"])
        if toks.ndim != 1:
            raise ValueError(f"tokens must be 1-D, got shape {toks.shape}")
        if toks.dtype.kind not in "iu":
            raise ValueError(f"tokens must be integer, got {toks.dtype}")
        if toks.size == 0:
            raise ValueError("empty document")
        scfg = self.engine.scfg
        if toks.size > scfg.max_len:
            raise ValueError(f"document has {toks.size} tokens, max_len "
                             f"is {scfg.max_len}")
        if int(toks.min()) < 0 or int(toks.max()) >= self.snap.vocab_size:
            raise ValueError("token id out of range for vocab_size "
                             f"{self.snap.vocab_size}")
        return _Ticket(uid, toks.astype(np.int32), seed)

    def _serve_conn(self, sock: socket.socket) -> None:
        try:
            sock.setsockopt(socket.SOL_SOCKET, socket.SO_KEEPALIVE, 1)
        except OSError:
            pass
        sock.settimeout(self.idle_timeout)
        conn = protocol.FramedConnection(sock)
        try:
            while not self._stop:
                try:
                    mt, meta, arrays = conn.recv()
                except protocol.IdleTimeout:
                    continue
                except protocol.ConnectionClosed:
                    break
                except protocol.TransportError as e:
                    raise ProtocolError(
                        f"inference server lost the connection: {e}"
                    ) from e
                if mt is MsgType.SHUTDOWN:
                    conn.send(MsgType.OK, {})
                    self._stop = True
                    break
                if mt is MsgType.STATS:
                    conn.send(MsgType.OK, self.stats())
                    continue
                if mt is not MsgType.INFER:
                    conn.send(MsgType.ERROR,
                              {"error": f"unsupported message {mt.name} "
                                        "on an inference server"})
                    break
                t0 = time.perf_counter()
                try:
                    ticket = self._validate(meta, arrays)
                except ValueError as e:
                    # Well-framed but semantically bad request: tell the
                    # peer why, then drop it — its state machine is off.
                    conn.send(MsgType.ERROR,
                              {"error": f"ValueError: {e}"})
                    break
                try:
                    self._queue.put_nowait(ticket)
                except queue_mod.Full:
                    # Load shed: answer immediately, keep the connection —
                    # overload is the client's retry decision, not a
                    # protocol failure.
                    with self._lock:
                        self._shed += 1
                    conn.send(MsgType.ERROR,
                              {"error": "overloaded: admission queue "
                                        f"full ({self.max_queue})",
                               "shed": True})
                    continue
                if not ticket.event.wait(self.request_timeout):
                    conn.send(MsgType.ERROR,
                              {"error": "inference timed out"})
                    break
                if ticket.error is not None:
                    conn.send(MsgType.ERROR, {"error": ticket.error})
                    break
                res = ticket.result
                conn.send(MsgType.INFER_RESULT,
                          {"uid": ticket.uid, "n_sweeps": res.n_sweeps},
                          {"theta": np.asarray(res.theta, np.float32),
                           "assignments": np.asarray(res.assignments,
                                                     np.int32)})
                with self._lock:
                    self._served += 1
                    self._latency_s.append(time.perf_counter() - t0)
        except ProtocolError as e:
            # Malformed frame or dead transport: the stream can no longer
            # be trusted; only this connection dies, the engine and every
            # other client are untouched.
            with self._lock:
                self._protocol_errors += 1
            try:
                conn.send(MsgType.ERROR, {"error": str(e)})
            except OSError:
                pass
        finally:
            conn.close()


# ---------------------------------------------------------------------------
# CLI — the inference-server process the loopback launcher starts
# ---------------------------------------------------------------------------

def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="online topic-inference server (repro.serve)")
    ap.add_argument("--family", default="lda")
    ap.add_argument("--vocab-size", type=int, required=True)
    ap.add_argument("--n-topics", type=int, required=True)
    ap.add_argument("--snapshot-dir", required=True,
                    help="Trainer checkpoint manifest to freeze")
    ap.add_argument("--snapshot-name", default="trainer")
    ap.add_argument("--n-shards", type=int, default=1)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--n-sweeps", type=int, default=10)
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--max-batch-delay", type=float, default=0.01)
    ap.add_argument("--address-file", default=None,
                    help="write the bound address as JSON (the launcher "
                         "polls this instead of parsing stdout)")
    args = ap.parse_args(argv)

    from repro.core import family as family_mod
    fam = family_mod.get(args.family)
    cfg = fam.config_cls(n_topics=args.n_topics,
                         vocab_size=args.vocab_size)
    snap = snapshot_mod.from_checkpoint(
        args.snapshot_dir, cfg, n_shards=args.n_shards,
        name=args.snapshot_name)
    scfg = ServeConfig(max_slots=args.max_slots, max_len=args.max_len,
                       n_sweeps=args.n_sweeps)
    srv = InferenceServer(snap, scfg, host=args.host, port=args.port,
                          max_queue=args.max_queue,
                          max_batch_delay=args.max_batch_delay).start()
    addr = f"{srv.address[0]}:{srv.address[1]}"
    if args.address_file:
        tmp = args.address_file + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"addresses": [addr]}, f)
        os.replace(tmp, args.address_file)
    print(f"READY {addr}", flush=True)
    try:
        while not srv.stopped:
            time.sleep(0.1)
    except KeyboardInterrupt:
        pass
    finally:
        srv.close()
    print(f"STATS {json.dumps(srv.stats())}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
