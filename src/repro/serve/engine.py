"""Fold-in serving engine: continuous batching of documents (DESIGN.md §14).

Online topic inference folds an unseen document into a *frozen* trained
model: the document gets its own assignment chain ``z`` and doc-topic
counts ``n_dk``, the shared statistics stay read-only, and after a fixed
number of local-only MHW sweeps the document's topic proportions are
harvested from ``n_dk``.  No pushes ⇒ no deltas, no barrier, no
projection conflicts — serving is embarrassingly parallel across
documents and across replicas of the snapshot.

The engine batches live documents into a slot grid ``(max_slots,
max_len)`` and runs ONE fused token-sorted sweep (the exact
``ModelFamily.sweep_sorted`` pipeline training uses — ``mhw.mix_chain``
semantics, tile-skipping sorted kernels) over every live slot per
:meth:`FoldInEngine.step`.  Slots are continuous: a document can be
admitted while its batch-mates are mid-chain, and harvested as soon as
its own chain has mixed ``n_sweeps`` sweeps.

**Determinism contract** (the serving analogue of the sorted-vs-scan
parity contract): a document's chain is a pure function of (snapshot,
tokens, request seed) — independent of which slots it happens to share
batches with.  The fused kernels make this possible because every
per-token MH step consumes explicit uniform streams in sorted-stream
order (``ops._step_uniforms``); the engine draws each slot's streams
under the *single-document* layout geometry with the slot's own
``fold_in(fold_in(PRNGKey(seed), sweep), chunk)`` key and permutes them
into the batched sorted order.  The result is bit-identical to
:func:`reference_fold_in` — the Trainer path (``family.sweep`` with
``layout="sorted"``) run on a one-document shard with its pushes
dropped — which is exactly what tests/test_serve_engine.py asserts per
family.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import segment
from repro.kernels import ops
from repro.serve.snapshot import InferenceSnapshot

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine-side serving knobs (the service layer adds queueing on top).

    ``n_sweeps`` is the fold-in chain length: how many local-only sweeps
    a document mixes before harvest.  Fold-in converges fast — the
    training-time perplexity evaluators use 10 — so the default matches
    the eval convention.
    """

    max_slots: int = 8
    max_len: int = 256
    n_sweeps: int = 10


@dataclasses.dataclass(frozen=True)
class InferRequest:
    """One document to fold in.  ``seed`` fixes the request's chain: the
    same (snapshot, tokens, seed) triple always yields the same result,
    no matter how the request is batched or which replica serves it."""

    uid: int
    tokens: Sequence[int]
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class InferResult:
    uid: int
    theta: np.ndarray        # (K,) topic proportions
    assignments: np.ndarray  # (doc_len,) final topic per token
    n_sweeps: int


@dataclasses.dataclass
class _Slot:
    uid: int
    length: int
    key: Array               # PRNGKey(seed) — the request's chain root
    age: int                 # completed sweeps
    # Per-chunk single-document geometry (order, padded width) — the
    # layout reference_fold_in's sweep derives for a (1, L) shard, under
    # which this slot's uniform streams are drawn.
    orders: tuple[np.ndarray, ...]
    widths: tuple[int, ...]


def _theta(prior: np.ndarray, n_dk_row: np.ndarray, length: int
           ) -> np.ndarray:
    """Posterior-mean topic proportions from a folded-in doc's counts."""
    return (n_dk_row + prior) / (float(length) + float(prior.sum()))


def result_checksum(res: InferResult) -> str:
    """Order-independent digest of one result — what the loopback smoke
    compares across client processes and the in-process reference."""
    h = hashlib.sha256()
    h.update(np.int64(res.uid).tobytes())
    h.update(np.ascontiguousarray(res.assignments, np.int32).tobytes())
    h.update(np.ascontiguousarray(res.theta, np.float32).tobytes())
    return h.hexdigest()


class FoldInEngine:
    """Slot-based continuous batching of fold-in chains over one frozen
    :class:`~repro.serve.snapshot.InferenceSnapshot`."""

    def __init__(self, snap: InferenceSnapshot,
                 scfg: ServeConfig | None = None):
        self.snap = snap
        self.scfg = scfg or ServeConfig()
        self.fam = snap.family
        self.cfg = snap.cfg
        s, l = self.scfg.max_slots, self.scfg.max_len
        self._tokens = jnp.zeros((s, l), jnp.int32)
        self._mask = jnp.zeros((s, l), bool)
        # Slot-grid local state; rows are rewritten wholesale at admit, so
        # the init values never reach a result.
        self._local, _ = self.fam.init_state(
            self.cfg, self._tokens, self._mask, jax.random.PRNGKey(0))
        self._slots: list[_Slot | None] = [None] * s
        self._layouts = None      # batched chunk layouts; rebuilt on change
        self._prior = np.asarray(snap.topic_prior(), np.float32)
        n_chunks = max(1, min(self.cfg.sorted_chunks, l))
        self._bounds = segment.chunk_bounds(l, n_chunks)
        # Counters for the benchmark/service layer.
        self.sweeps_run = 0
        self.docs_admitted = 0
        self.docs_harvested = 0

    # ------------------------------------------------------------ occupancy
    @property
    def live(self) -> int:
        return sum(s is not None for s in self._slots)

    def free_slots(self) -> int:
        return sum(s is None for s in self._slots)

    # --------------------------------------------------------------- admit
    def admit(self, req: InferRequest) -> bool:
        """Pack a request into a free slot; False when the grid is full.

        Raises ``ValueError`` for an empty document, one longer than
        ``max_len``, or out-of-vocabulary token ids (the service layer
        maps this to a semantic ERROR frame, never a truncation)."""
        toks = np.asarray(req.tokens, np.int32).reshape(-1)
        if toks.size == 0:
            raise ValueError("empty document")
        if toks.size > self.scfg.max_len:
            raise ValueError(
                f"document has {toks.size} tokens, max_len is "
                f"{self.scfg.max_len}")
        if toks.min() < 0 or toks.max() >= self.cfg.vocab_size:
            raise ValueError("token id out of range for vocab_size "
                             f"{self.cfg.vocab_size}")
        try:
            j = self._slots.index(None)
        except ValueError:
            return False
        l = self.scfg.max_len
        row_tok = np.zeros((1, l), np.int32)
        row_tok[0, :toks.size] = toks
        row_mask = np.zeros((1, l), bool)
        row_mask[0, :toks.size] = True
        tok1 = jnp.asarray(row_tok)
        mask1 = jnp.asarray(row_mask)
        key = jax.random.PRNGKey(int(req.seed))
        # The slot's chain init IS the oracle's: family init on the
        # single-document shard, keyed by the request.
        local0, _ = self.fam.init_state(self.cfg, tok1, mask1, key)
        ld = self.fam.local_dict(self._local)
        for name, row in self.fam.local_dict(local0).items():
            ld[name] = ld[name].at[j].set(row[0])
        self._local = self.fam.local_from_dict(ld)
        self._tokens = self._tokens.at[j].set(tok1[0])
        self._mask = self._mask.at[j].set(mask1[0])
        # Single-doc sorted geometry per chunk: the inverse of these
        # orders routes the slot's uniform columns to flat positions.
        lays = self.fam.build_sorted_layouts(self.cfg, tok1, mask1)
        self._slots[j] = _Slot(
            uid=req.uid, length=int(toks.size), key=key, age=0,
            orders=tuple(np.asarray(la.order) for la in lays),
            widths=tuple(int(la.rows.shape[0]) for la in lays))
        self._layouts = None
        self.docs_admitted += 1
        return True

    # ------------------------------------------------------------ uniforms
    def _chunk_uniforms(self, c: int, lay: segment.SortedLayout,
                        tile_b: int):
        """Per-request uniform streams for batched chunk ``c``: each live
        slot's streams are drawn under ITS single-doc geometry and key,
        mapped through its single-doc sorted order, then permuted into the
        batched sorted order.  Empty slots get neutral values (their
        outputs are masked away)."""
        s_chunk, e_chunk = self._bounds[c], self._bounds[c + 1]
        clen = e_chunk - s_chunk
        e_out = self.fam.n_outcomes(self.cfg)
        mh = self.cfg.mh_steps
        cols = []
        for slot in self._slots:
            if slot is None:
                cols.append((np.zeros((mh, clen), np.int32),)
                            + tuple(np.full((mh, clen), 0.5, np.float32)
                                    for _ in range(4)))
                continue
            ck = jax.random.fold_in(
                jax.random.fold_in(slot.key, slot.age), c)
            u = ops._step_uniforms(ck, e_out, mh, slot.widths[c])
            order = slot.orders[c]
            inv = np.empty(clen, np.int64)
            inv[order] = np.arange(clen)
            cols.append(tuple(np.asarray(a)[:, inv] for a in u))
        # (mh, max_slots*clen) flat streams, slot-major like the grid.
        flat = [np.concatenate([col[i] for col in cols], axis=1)
                for i in range(5)]
        order_b = np.asarray(lay.order)
        pad = int(lay.rows.shape[0]) - order_b.shape[0]
        out = []
        for i, f in enumerate(flat):
            g = f[:, order_b]
            if pad:
                fill = np.zeros((mh, pad), np.int32) if i == 0 else \
                    np.full((mh, pad), 0.5, np.float32)
                g = np.concatenate([g, fill], axis=1)
            out.append(jnp.asarray(g))
        return tuple(out)

    # ---------------------------------------------------------------- step
    def step(self) -> int:
        """One fused local-only sweep across every live slot.  Shared
        statistics are read-only; the returned deltas are dropped on the
        floor (fold-in never pushes).  Returns the number of live slots
        swept (0 = nothing to do)."""
        if self.live == 0:
            return 0
        if self._layouts is None:
            self._layouts = self.fam.build_sorted_layouts(
                self.cfg, self._tokens, self._mask)
        local2, _deltas = self.fam.sweep_sorted(
            self.cfg, self._local, self.snap.shared, self.snap.tables,
            self.snap.stale, self._tokens, self._mask,
            jax.random.PRNGKey(0),  # unused: every chunk gets uniforms
            self._layouts, chunk_uniforms=self._chunk_uniforms)
        self._local = self.fam.local_project(local2)
        n = 0
        for slot in self._slots:
            if slot is not None:
                slot.age += 1
                n += 1
        self.sweeps_run += 1
        return n

    # ------------------------------------------------------------- harvest
    def harvest(self) -> list[InferResult]:
        """Free every slot whose chain has mixed ``n_sweeps`` sweeps and
        return its topic proportions + final assignments."""
        out = []
        ld = self.fam.local_dict(self._local)
        n_dk = np.asarray(ld["n_dk"])
        z = np.asarray(ld["z"])
        for j, slot in enumerate(self._slots):
            if slot is None or slot.age < self.scfg.n_sweeps:
                continue
            out.append(InferResult(
                uid=slot.uid,
                theta=_theta(self._prior, n_dk[j], slot.length),
                assignments=z[j, :slot.length].copy(),
                n_sweeps=slot.age))
            self._slots[j] = None
            self._mask = self._mask.at[j].set(False)
            self._layouts = None
            self.docs_harvested += 1
        return out

    # ----------------------------------------------------------------- run
    def run(self, requests: Iterable[InferRequest]
            ) -> dict[int, InferResult]:
        """Continuous-batching driver: admit as slots free up, sweep,
        harvest, until every request is served."""
        queue = list(requests)
        results: dict[int, InferResult] = {}
        while queue or self.live:
            while queue and self.admit(queue[0]):
                queue.pop(0)
            self.step()
            for res in self.harvest():
                results[res.uid] = res
        return results


# ---------------------------------------------------------------------------
# The oracle: fold-in through the Trainer path with pushes disabled
# ---------------------------------------------------------------------------

def reference_fold_in(snap: InferenceSnapshot, tokens: Sequence[int],
                      seed: int, *, n_sweeps: int,
                      max_len: int) -> tuple[Any, np.ndarray, np.ndarray]:
    """Fold one document in via the training code path: ``family.sweep``
    (the jitted per-family entry Trainer calls) on a one-document shard
    with ``layout="sorted"``, deltas dropped — i.e. pushes disabled.

    Returns ``(local_state, theta, assignments)``.  ``max_len`` must
    match the engine's slot width: chunk boundaries are derived from the
    padded length, so the geometry is part of the chain's identity.
    """
    fam, cfg = snap.family, snap.cfg
    toks = np.asarray(tokens, np.int32).reshape(-1)
    if toks.size > max_len:
        raise ValueError(f"document has {toks.size} tokens > {max_len}")
    row_tok = np.zeros((1, max_len), np.int32)
    row_tok[0, :toks.size] = toks
    row_mask = np.zeros((1, max_len), bool)
    row_mask[0, :toks.size] = True
    tok1, mask1 = jnp.asarray(row_tok), jnp.asarray(row_mask)
    key = jax.random.PRNGKey(int(seed))
    local, _ = fam.init_state(cfg, tok1, mask1, key)
    layouts = fam.build_sorted_layouts(cfg, tok1, mask1)
    for s in range(n_sweeps):
        local, _deltas = fam.sweep(
            cfg, local, snap.shared, snap.tables, snap.stale, tok1, mask1,
            jax.random.fold_in(key, s), method="mhw", layout="sorted",
            sorted_layouts=layouts)
        local = fam.local_project(local)
    n_dk = np.asarray(local.n_dk[0])
    prior = np.asarray(snap.topic_prior(), np.float32)
    theta = _theta(prior, n_dk, int(toks.size))
    z = np.asarray(local.z[0, :toks.size])
    return local, theta, z


# ---------------------------------------------------------------------------
# Fold-in quality: held-out perplexity of harvested proportions
# ---------------------------------------------------------------------------

def fold_in_perplexity(snap: InferenceSnapshot,
                       thetas: np.ndarray, tokens: np.ndarray,
                       mask: np.ndarray) -> float:
    """Held-out perplexity of documents under their *harvested* topic
    proportions and the frozen per-topic word distributions — the
    serving-side counterpart of ``family.perplexity`` (which folds in
    with its own internal chains).  The benchmark's quality gate compares
    the two."""
    phi = np.asarray(snap.language_model(), np.float32)  # (V, K)
    k = thetas.shape[1]
    pw = np.einsum("dk,dlk->dl", np.asarray(thetas, np.float32),
                   phi[np.asarray(tokens)][..., :k])
    m = np.asarray(mask, bool)
    logs = np.log(np.maximum(pw, 1e-30))[m]
    return float(np.exp(-logs.sum() / max(1, m.sum())))
