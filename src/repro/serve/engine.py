"""Batched serving engine: slot-based continuous batching over the
prefill / decode_step pair from ``repro.models.model``.

The engine owns a fixed pool of ``batch`` decode slots sharing one
preallocated KV cache (the decode_32k / long_500k dry-run shapes are this
engine's two production configurations).  Requests are admitted into free
slots; each engine step runs ONE fused decode_step for the whole pool, so
throughput is batch-amortized exactly as in the paper's multi-client
sampler — many logical streams, one vectorized sweep.

Slot lifecycle:
  admit()   — prefill the prompt (per-request), scatter its KV into the
              pool cache at the slot index, mark the slot live.
  step()    — one decode_step for all live slots; dead slots decode
              garbage that is masked out (the SPMD-friendly analogue of
              dynamic batching — no recompilation when occupancy changes).
  harvest() — collect finished sequences (EOS or max_tokens).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib

Array = jax.Array


@dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 32
    # filled by the engine:
    output: list[int] = field(default_factory=list)
    done: bool = False


@dataclass
class EngineConfig:
    batch: int = 8                # decode slot count
    max_len: int = 512            # KV capacity per slot
    eos_id: int = -1              # -1: never stop on a token
    greedy: bool = True
    temperature: float = 1.0


class Engine:
    """Single-host engine; the distributed version shards the same cache
    pytree with ``repro.train.sharding.cache_specs`` (see launch/serve.py)."""

    def __init__(self, cfg: ModelConfig, params: Any, ecfg: EngineConfig,
                 key: Array | None = None):
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self.cache = model_lib.init_cache(cfg, ecfg.batch, ecfg.max_len)
        # per-slot bookkeeping (host side)
        self.slot_req: list[Request | None] = [None] * ecfg.batch
        self.slot_pos = np.zeros(ecfg.batch, np.int32)   # tokens generated
        self.last_tok = np.zeros(ecfg.batch, np.int32)
        self._decode = jax.jit(
            lambda params, cache, toks: model_lib.decode_step(
                cfg, params, cache, toks))
        self._prefill = jax.jit(
            lambda params, batch: model_lib.prefill(cfg, params, batch,
                                                    ecfg.max_len))

    # ------------------------------------------------------------------
    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    @property
    def live(self) -> int:
        return sum(r is not None for r in self.slot_req)

    def _scatter_cache(self, slot: int, req_cache: Any) -> None:
        """Copy a single-request prefill cache into slot ``slot`` of the
        pool cache.  Batch is dim 1 of every (L, B, ...) leaf."""

        def scatter(pool: Array, one: Array) -> Array:
            if pool.ndim == 0 or pool is one:
                return pool
            return pool.at[:, slot:slot + 1].set(one.astype(pool.dtype))

        pool_layers = jax.tree.map(scatter, self.cache["layers"],
                                   req_cache["layers"])
        self.cache = dict(self.cache)
        self.cache["layers"] = pool_layers
        if "shared_attn" in self.cache:
            self.cache["shared_attn"] = jax.tree.map(
                scatter, self.cache["shared_attn"], req_cache["shared_attn"])
        if "cross" in self.cache:
            self.cache["cross"] = jax.tree.map(
                scatter, self.cache["cross"], req_cache["cross"])

    def admit(self, req: Request, extra_inputs: dict[str, Array] | None = None
              ) -> bool:
        """Prefill ``req`` into a free slot.  Returns False when full.

        NOTE: the pool decodes all slots at one shared position counter, so
        this engine pads/aligns prompts to a common length: the admitted
        prompt must have length == current cache['pos'] (0 for the first
        admit of a generation wave).  launch/serve.py batches a wave of
        same-length prompts, which is the production pattern for benchmark
        serving; ragged admission would use per-slot position tracking.
        """
        free = self.free_slots()
        if not free:
            return False
        slot = free[0]
        batch = {"tokens": jnp.asarray(req.prompt, jnp.int32)[None]}
        if extra_inputs:
            batch.update(extra_inputs)
        logits, req_cache = self._prefill(self.params, batch)
        self._scatter_cache(slot, req_cache)
        self.cache["pos"] = req_cache["pos"]
        if "key_pos" in req_cache:
            self.cache["key_pos"] = req_cache["key_pos"]
        tok = int(jnp.argmax(logits[0, 0, :self.cfg.vocab_size]))
        req.output.append(tok)
        self.slot_req[slot] = req
        self.slot_pos[slot] = 1
        self.last_tok[slot] = tok
        return True

    def step(self) -> None:
        """One fused decode step for every live slot."""
        toks = jnp.asarray(self.last_tok, jnp.int32)[:, None]
        logits, self.cache = self._decode(self.params, self.cache, toks)
        logits = logits[:, 0, :self.cfg.vocab_size]
        if self.ecfg.greedy:
            nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        else:
            self.key, k = jax.random.split(self.key)
            nxt = np.asarray(jax.random.categorical(
                k, logits / self.ecfg.temperature, axis=-1), np.int32)
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            tok = int(nxt[i])
            req.output.append(tok)
            self.slot_pos[i] += 1
            self.last_tok[i] = tok
            if (tok == self.ecfg.eos_id
                    or self.slot_pos[i] >= req.max_new_tokens):
                req.done = True

    def harvest(self) -> list[Request]:
        done = []
        for i, req in enumerate(self.slot_req):
            if req is not None and req.done:
                done.append(req)
                self.slot_req[i] = None
        return done

    # ------------------------------------------------------------------
    def run(self, requests: list[Request],
            extra_inputs: Callable[[Request], dict[str, Array]] | None = None,
            ) -> list[Request]:
        """Drive a full wave of same-length-prompt requests to completion."""
        pending = list(requests)
        finished: list[Request] = []
        # Admit as many as fit (same prompt length ⇒ shared cache pos).
        while pending and self.free_slots():
            r = pending.pop(0)
            self.admit(r, extra_inputs(r) if extra_inputs else None)
        while self.live:
            self.step()
            finished.extend(self.harvest())
            # same-wave refill only when cache positions still align
            if not self.live and pending:
                self.cache = model_lib.init_cache(
                    self.cfg, self.ecfg.batch, self.ecfg.max_len)
                while pending and self.free_slots():
                    r = pending.pop(0)
                    self.admit(r, extra_inputs(r) if extra_inputs else None)
        return finished
