"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 100 --batch 8 --seq 128 [--mesh data=1,model=1]

Builds the mesh, applies the sharding rules from ``repro.train.sharding``
to parameters / optimizer state / batches, jits the training step with
those shardings, and runs the loop with periodic checkpointing.  On the CPU
container the mesh is 1x1 and the same code path exercises the full
sharded program; on a real pod the ``--mesh`` flag selects the production
layout that the dry-run validated.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs.base import reduced
from repro.configs.registry import ARCHITECTURES
from repro.data.synthetic import lm_batches
from repro.launch.mesh import make_host_mesh
from repro.models import model as model_lib
from repro.optim import adamw
from repro.train import sharding as sh
from repro.train.train_step import TrainConfig, make_train_step


def parse_mesh(spec: str) -> dict[str, int]:
    out = {}
    for part in spec.split(","):
        k, v = part.split("=")
        out[k.strip()] = int(v)
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m",
                    choices=sorted(ARCHITECTURES))
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", default="data=1,model=1")
    ap.add_argument("--sharding", default="megatron",
                    choices=["megatron", "zero_seq", "zero_batch"],
                    help="layout (train/sharding.py); zero_* are the §Perf-"
                         "optimized modes")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = ARCHITECTURES[args.arch]
    if args.reduced:
        cfg = reduced(cfg).replace(vocab_size=min(512, cfg.vocab_size))
    m = parse_mesh(args.mesh)
    mesh = make_host_mesh(data=m.get("data", 1), model=m.get("model", 1))
    tcfg = TrainConfig(peak_lr=args.lr, warmup=min(10, args.steps // 5),
                       total_steps=args.steps,
                       microbatches=args.microbatches,
                       loss_chunk=min(512, args.seq))

    with mesh:
        mode = sh.resolve_mode(mesh, args.sharding,
                               args.batch, args.seq)
        param_mode = "zero_seq" if mode == "zero_batch" else mode
        model_lib.set_activation_spec(
            sh.activation_spec(mesh, mode),
            mesh=mesh if mode != "megatron" else None)
        params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw.init(params)
        pspecs = sh.param_specs(params, mesh=mesh, fsdp=True,
                                mode=param_mode)
        pshard = sh.named(pspecs, mesh)
        oshard = type(opt)(step=sh.named(jax.sharding.PartitionSpec(), mesh),
                           m=pshard, v=pshard)
        params = jax.tree.map(jax.device_put, params, pshard)
        opt = adamw.AdamWState(
            step=opt.step,
            m=jax.tree.map(jax.device_put, opt.m, pshard),
            v=jax.tree.map(jax.device_put, opt.v, pshard))

        start = 0
        if args.resume and args.ckpt_dir:
            step0 = ckpt.latest_step(args.ckpt_dir, cfg.name)
            if step0 is not None:
                state = ckpt.restore(args.ckpt_dir, cfg.name,
                                     {"params": params,
                                      "opt": opt._asdict()})
                params = state["params"]
                opt = adamw.AdamWState(**state["opt"])
                start = step0
                print(f"resumed from step {start}")

        step_fn = jax.jit(make_train_step(cfg, tcfg),
                          in_shardings=(pshard, oshard, None),
                          out_shardings=(pshard, oshard, None),
                          donate_argnums=(0, 1))
        data = lm_batches(cfg.vocab_size, args.batch, args.seq,
                          args.steps - start, seed=1, kind="affine")
        t0 = time.time()
        for i, batch in enumerate(data):
            step = start + i
            batch = {"tokens": jnp.asarray(batch["tokens"])}
            params, opt, metrics = step_fn(params, opt, batch)
            if step % 10 == 0 or step == args.steps - 1:
                tok_s = ((i + 1) * args.batch * args.seq
                         / max(time.time() - t0, 1e-9))
                print(f"step {step:5d}  loss={float(metrics['loss']):8.4f}  "
                      f"gnorm={float(metrics['grad_norm']):7.3f}  "
                      f"{tok_s:9.0f} tok/s", flush=True)
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                path = ckpt.save(args.ckpt_dir, cfg.name, step + 1,
                                 {"params": params, "opt": opt._asdict()})
                print(f"checkpoint: {path}", flush=True)
    print("training complete")


if __name__ == "__main__":
    main()
