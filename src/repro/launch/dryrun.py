import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before any jax-importing module: jax locks
# the device count on first init, and the production meshes below need 512
# placeholder host devices (16x16 single pod, 2x16x16 multi-pod).

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
workload on the production meshes, without allocating a single real array.

For each combination this prints/records:
  - compiled.memory_analysis()  — per-device HBM footprint (proves it fits)
  - compiled.cost_analysis()    — HLO FLOPs / bytes (feeds §Roofline)
  - collective byte totals parsed from the optimized HLO

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b
  PYTHONPATH=src python -m repro.launch.dryrun --shape train_4k --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --json out.json

A failure to lower/compile any (arch × shape × mesh) is a bug in the
sharding rules, not an acceptable skip — the only skips are the documented
long_500k full-attention exclusions (DESIGN.md §4).
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ARCHITECTURES
from repro.launch import roofline as rl
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_production_mesh


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            verbose: bool = True, sharding: str = "megatron") -> dict:
    """Lower + compile one workload on one production mesh; returns the
    record for EXPERIMENTS.md §Dry-run / §Roofline."""
    cfg = ARCHITECTURES[arch]
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"

    reason = specs_lib.skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skip", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    try:
        with mesh:
            spec = specs_lib.make_lowering_spec(cfg, shape, mesh,
                                                mode=sharding)
            lowered = specs_lib.lower(spec)
            lowered_text = lowered.as_text()
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        n_mb = (specs_lib.default_microbatches(cfg)
                if shape.kind == "train" else 1)
        roof = rl.analyze(compiled, compiled.as_text(), cfg=cfg, shape=shape,
                          mesh_name=mesh_name, chips=chips,
                          n_microbatches=n_mb)
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "ok", "kind": spec.kind, "sharding": sharding,
               "compile_s": round(time.time() - t0, 1),
               "memory_analysis": {
                   "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
                   "output_bytes": getattr(mem, "output_size_in_bytes", 0),
                   "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
                   "generated_code_bytes": getattr(
                       mem, "generated_code_size_in_bytes", 0),
               },
               **roof.row()}
        if verbose:
            hbm = (rec["memory_analysis"]["argument_bytes"]
                   + rec["memory_analysis"]["output_bytes"]
                   + rec["memory_analysis"]["temp_bytes"]) / 2**30
            print(f"[ok]   {arch:22s} {shape_name:12s} {mesh_name:10s} "
                  f"kind={spec.kind:7s} compile={rec['compile_s']:6.1f}s "
                  f"hbm/dev={hbm:7.2f}GiB "
                  f"t_comp={roof.t_compute:.3e}s t_mem={roof.t_memory:.3e}s "
                  f"t_coll={roof.t_collective:.3e}s "
                  f"bottleneck={roof.bottleneck}", flush=True)
        return rec
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        if verbose:
            print(f"[FAIL] {arch:22s} {shape_name:12s} {mesh_name}\n"
                  f"{traceback.format_exc()}", flush=True)
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "fail", "error": f"{type(e).__name__}: {e}"}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one architecture id")
    ap.add_argument("--shape", default=None, help="one input-shape name")
    ap.add_argument("--multi-pod", action="store_true",
                    help="only the 2x16x16 multi-pod mesh")
    ap.add_argument("--single-pod", action="store_true",
                    help="only the 16x16 single-pod mesh")
    ap.add_argument("--json", default=None, help="write records to this file")
    ap.add_argument("--sharding", default="megatron",
                    choices=["megatron", "zero_seq", "zero_batch"],
                    help="megatron = paper-faithful baseline; zero_seq = "
                         "ZeRO-3 + sequence-parallel (§Perf optimization)")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else sorted(ARCHITECTURES)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    if args.multi_pod:
        meshes = [True]
    elif args.single_pod:
        meshes = [False]
    else:
        meshes = [False, True]

    assert len(jax.devices()) == 512, (
        "dryrun needs the 512 forced host devices; do not import jax before "
        "this module sets XLA_FLAGS")

    records = []
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                records.append(run_one(arch, shape, multi_pod=multi_pod,
                                       sharding=args.sharding))

    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skip" for r in records)
    n_fail = sum(r["status"] == "fail" for r in records)
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} documented skips, "
          f"{n_fail} failures")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {args.json}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
