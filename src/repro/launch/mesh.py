"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing never touches jax
device state.  Single pod: (data=16, model=16) = 256 chips (v5e pod).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is an
outer data-parallel axis whose collectives cross DCN.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (host/forced) devices exist — used by
    tests and the CPU examples."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


# TPU v5e hardware constants (roofline denominators).
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (≈ per-chip effective)
