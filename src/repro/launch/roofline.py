"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds:

    compute    = FLOPs      / (chips × 197e12 bf16 FLOP/s)
    memory     = HBM bytes  / (chips × 819e9  B/s)
    collective = coll bytes / 50e9 B/s per-chip ICI

Measurement sources and their caveats (this is a CPU container; the dry-run
compiles for 512 forced host devices, so numbers are *structural*, not
wall-clock):

* ``compiled.cost_analysis()`` counts a ``while`` body ONCE, but our models
  scan over layers and microbatches — HLO FLOPs/bytes undercount by up to
  L × n_mb.  We therefore compute the FLOPs and HBM-traffic terms from the
  *analytic* workload model (parameter matmuls + attention/SSM terms +
  optimizer/cache traffic) and report the raw HLO numbers alongside.
* Collective bytes are parsed from the optimized HLO.  Collectives inside
  ``while`` bodies execute trip-count times; we recover the trip count from
  each while's condition region (the loop-bound constant) and multiply —
  the ``xN`` correction recorded per record as ``coll_loop_corrected``.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape like 'bf16[16,2048,512]' or a tuple thereof."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# ---------------------------------------------------------------------------
# HLO parsing: collectives with while-loop trip-count correction
# ---------------------------------------------------------------------------

_COMP_RE = re.compile(r"^(?:ENTRY )?%?([\w.\-]+) \(.*\) -> .+ \{$")
_INSTR_RE = re.compile(r"%?[\w.\-]+ = (.+?) ([\w\-]+)\(")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"[su]\d+\[\]\{?\}? constant\((\d+)\)")


def _parse_computations(hlo: str) -> dict[str, list[str]]:
    """computation name -> its instruction lines."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo.splitlines():
        stripped = line.strip()
        m = _COMP_RE.match(stripped)
        if m and stripped.endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    """Loop bound heuristic: the largest integer constant in the condition
    region (the canonical `i < N` bound).  Clamped to a sane range."""
    best = 1
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return min(best, 1_000_000)


@dataclass
class CollectiveStats:
    bytes_by_kind: dict[str, int] = field(default_factory=dict)
    count_by_kind: dict[str, int] = field(default_factory=dict)
    loop_corrected: bool = False

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum *output* shape sizes of every collective op, scaled by the while
    trip counts of the regions containing it."""
    comps = _parse_computations(hlo_text)

    # Map body-computation -> trip count, and find each computation's parent
    # multiplier by walking while nests from the leaves of call sites.
    body_trip: dict[str, int] = {}
    called_by: dict[str, str] = {}
    for name, lines in comps.items():
        for line in lines:
            m = _WHILE_RE.search(line)
            if m:
                cond, body = m.groups()
                body_trip[body] = _trip_count(comps.get(cond, []))
                called_by[body] = name
                called_by[cond] = name
            # calls into fusions/regions don't multiply

    def multiplier(comp: str) -> int:
        mult, seen = 1, set()
        while comp in called_by and comp not in seen:
            seen.add(comp)
            if comp in body_trip:
                mult *= body_trip[comp]
            comp = called_by[comp]
        return min(mult, 10_000_000)

    stats = CollectiveStats()
    for name, lines in comps.items():
        mult = multiplier(name)
        if mult > 1:
            stats.loop_corrected = True
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            shape_str, op = m.groups()
            for kind in _COLLECTIVES:
                if op == kind or op == kind + "-start":
                    stats.bytes_by_kind[kind] = (
                        stats.bytes_by_kind.get(kind, 0)
                        + _shape_bytes(shape_str) * mult)
                    stats.count_by_kind[kind] = (
                        stats.count_by_kind.get(kind, 0) + mult)
                    break
    return stats


# ---------------------------------------------------------------------------
# Analytic workload model (FLOPs + HBM bytes)
# ---------------------------------------------------------------------------

def _attn_layers(cfg) -> float:
    if cfg.family in ("dense", "moe", "vlm"):
        return cfg.n_layers
    if cfg.family == "hybrid":
        return cfg.n_layers / max(cfg.attn_every, 1)
    if cfg.family == "audio":
        return cfg.n_layers            # decoder self-attn (cross added apart)
    return 0.0


def analytic_flops(cfg, shape) -> dict[str, float]:
    """Per-step global FLOPs: parameter matmuls + attention + SSM scan.

    Multipliers: forward = 1 pass; train = fwd + per-layer remat re-fwd +
    bwd = 4× forward matmul traffic (2·N → 8·N per token).
    """
    b, s = shape.global_batch, shape.seq_len
    n_act = cfg.active_param_count()
    h, hd = cfg.n_heads, cfg.head_dim_
    la = _attn_layers(cfg)

    def attn_fwd(sq, kv_len, causal=True):
        eff = (kv_len + 1) / 2 if (causal and kv_len == sq) else kv_len
        if cfg.sliding_window and kv_len > cfg.sliding_window:
            eff = min(eff, cfg.sliding_window)
        return 4.0 * b * sq * eff * h * hd * la

    ssm_fwd = 0.0
    if cfg.family == "ssm":
        ssm_fwd = 6.0 * cfg.n_layers * b * s * cfg.d_model * hd if hd else \
            6.0 * cfg.n_layers * b * s * cfg.d_model * 64
    if cfg.family == "hybrid":
        ssm_fwd = 6.0 * cfg.n_layers * b * s * cfg.d_model * cfg.ssm_state

    if shape.kind == "train":
        tokens = b * s
        mat = 8.0 * n_act * tokens                   # 2 fwd + 2 remat + 4 bwd
        attn = 4.0 * attn_fwd(s, s)
        extra = 4.0 * ssm_fwd
        if cfg.family == "audio":
            f = cfg.n_frames or 1500
            attn += 4.0 * (4.0 * b * s * f * h * hd * cfg.n_layers      # cross
                           + 4.0 * b * f * f * h * hd * cfg.encoder_layers)
        return {"flops": mat + attn + extra, "matmul": mat, "attn": attn}
    if shape.kind == "prefill":
        tokens = b * s
        mat = 2.0 * n_act * tokens
        attn = attn_fwd(s, s)
        if cfg.family == "audio":
            f = cfg.n_frames or 1500
            attn += (4.0 * b * s * f * h * hd * cfg.n_layers
                     + 4.0 * b * f * f * h * hd * cfg.encoder_layers)
        return {"flops": mat + attn + ssm_fwd, "matmul": mat, "attn": attn}
    # decode: one token per sequence
    mat = 2.0 * n_act * b
    kv_len = min(s, cfg.sliding_window) if cfg.sliding_window else s
    if cfg.family == "ssm":
        attn = 0.0
    else:
        attn = 4.0 * b * kv_len * h * hd * la
    return {"flops": mat + attn + ssm_fwd / max(s, 1), "matmul": mat,
            "attn": attn}


def analytic_hbm_bytes(cfg, shape, chips: int, n_microbatches: int = 1
                       ) -> float:
    """Per-device HBM traffic per step (floor estimate)."""
    b, s = shape.global_batch, shape.seq_len
    p = cfg.param_count()
    d, l = cfg.d_model, cfg.n_layers
    if shape.kind == "train":
        # f32 master weights re-read per microbatch (fwd+bwd), optimizer
        # update ~6 passes (read g,m,v + write p,m,v), activations ~2 r/w of
        # one (tokens, d) tensor per layer in bf16 with remat.
        weights = p * 4.0 * (2.0 * n_microbatches + 6.0) / chips
        acts = 4.0 * l * b * s * d / chips
        return weights + acts
    if shape.kind == "prefill":
        weights = p * 2.0 / chips                    # bf16 serving weights
        acts = 4.0 * l * b * s * d / chips
        kv = 4.0 * l * b * s * cfg.n_kv_heads * cfg.head_dim_ / chips
        return weights + acts + kv
    # decode
    kv_len = min(s, cfg.sliding_window) if cfg.sliding_window else s
    weights = p * 2.0 / chips
    if cfg.family == "ssm":
        hd = cfg.head_dim_ or 64
        state = 4.0 * l * b * (d // max(hd, 1)) * hd * hd / chips
    elif cfg.family == "hybrid":
        state = 4.0 * l * b * d * cfg.ssm_state / chips \
            + 4.0 * (l / max(cfg.attn_every, 1)) * b * kv_len \
            * cfg.n_kv_heads * cfg.head_dim_ / chips
    else:
        state = 4.0 * l * b * kv_len * cfg.n_kv_heads * cfg.head_dim_ / chips
    return weights + state


# ---------------------------------------------------------------------------
# Roofline record
# ---------------------------------------------------------------------------

@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    model_flops: float
    analytic_flops_total: float = 0.0
    analytic_hbm: float = 0.0
    coll_by_kind: dict[str, int] = field(default_factory=dict)
    coll_counts: dict[str, int] = field(default_factory=dict)
    coll_loop_corrected: bool = False
    per_device_hbm_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        """Analytic FLOPs (HLO undercounts while bodies)."""
        return self.analytic_flops_total / (self.chips * PEAK_FLOPS_BF16)

    @property
    def t_compute_hlo(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.analytic_hbm / HBM_BW

    @property
    def t_memory_hlo(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        # collective bytes here are per-device (HLO shapes are per-shard)
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / analytic compiled FLOPs — remat/attention overhead."""
        return (self.model_flops / self.analytic_flops_total
                if self.analytic_flops_total else 0.0)

    @property
    def step_time(self) -> float:
        """Roofline step-time lower bound: max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline bound."""
        denom = self.step_time * self.chips * PEAK_FLOPS_BF16
        return self.model_flops / denom if denom else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "analytic_flops": self.analytic_flops_total,
            "analytic_hbm_bytes_per_dev": self.analytic_hbm,
            "coll_bytes": self.coll_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "t_compute_hlo_s": self.t_compute_hlo,
            "t_memory_hlo_s": self.t_memory_hlo,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "roofline_step_s": self.step_time,
            "mfu_bound": self.mfu,
            "coll_by_kind": self.coll_by_kind,
            "coll_counts": self.coll_counts,
            "coll_loop_corrected": self.coll_loop_corrected,
            "per_device_hbm_bytes": self.per_device_hbm_bytes,
        }


def model_flops(cfg, shape, kind: str) -> float:
    """MODEL_FLOPS = 6·N·D for training, 2·N·D for forward-only (prefill)
    and 2·N per token for decode; N = active params."""
    n = cfg.active_param_count()
    if kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token


def analyze(compiled, lowered_text: str, *, cfg, shape, mesh_name: str,
            chips: int, n_microbatches: int = 1) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byt = float(cost.get("bytes accessed", 0.0))
    stats = collective_bytes(lowered_text)
    mem = compiled.memory_analysis()
    per_dev = 0.0
    if mem is not None:
        per_dev = (getattr(mem, "argument_size_in_bytes", 0)
                   + getattr(mem, "output_size_in_bytes", 0)
                   + getattr(mem, "temp_size_in_bytes", 0))
    af = analytic_flops(cfg, shape)
    ah = analytic_hbm_bytes(cfg, shape, chips, n_microbatches)
    return Roofline(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byt,
        coll_bytes=float(stats.total_bytes),
        model_flops=model_flops(cfg, shape, shape.kind),
        analytic_flops_total=af["flops"],
        analytic_hbm=ah,
        coll_by_kind=stats.bytes_by_kind,
        coll_counts=stats.count_by_kind,
        coll_loop_corrected=stats.loop_corrected,
        per_device_hbm_bytes=per_dev,
    )
