"""Multi-process loopback launcher for the out-of-process parameter
server (DESIGN.md §11).

Spawns N shard-server processes (``python -m repro.net.server``) and M
client processes (``python -m repro.net.client``) on 127.0.0.1, waits
for the servers to publish their addresses, timeout-guards the whole
run, and collects exit codes, logs, and per-client result JSONs.  This
is the paper's deployment shape in miniature: parameter-server
*processes* serving sampler *processes* over a real network stack (the
loopback interface), with the same frames a cross-machine deployment
would use.

``--smoke`` runs the CI end-to-end check: 1 shard server + 2 train
client processes (one global client each), then an in-process reference
``Trainer`` on the identical corpus/key, and asserts the BSP result is
bit-exact (checksum equality across the socket).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time
from dataclasses import dataclass, field
from typing import Any


@dataclass
class ProcResult:
    """Exit status + captured output of one launched process."""
    name: str
    args: list[str]
    returncode: int
    stdout: str
    stderr: str
    result: dict[str, Any] | None = None  # parsed --out JSON, clients only


@dataclass
class LaunchResult:
    addresses: list[str]
    servers: list[ProcResult] = field(default_factory=list)
    clients: list[ProcResult] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return all(p.returncode == 0 for p in self.servers + self.clients)

    def failures(self) -> list[ProcResult]:
        return [p for p in self.servers + self.clients if p.returncode != 0]


def _python() -> list[str]:
    return [sys.executable]


def _env() -> dict[str, str]:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def _wait_address_file(path: str, proc: subprocess.Popen,
                       timeout: float) -> list[str]:
    """Poll for the server's address file; fail fast if the server died."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            raise RuntimeError(
                f"server process exited early (code {proc.returncode}) "
                f"before publishing addresses")
        if os.path.exists(path):
            try:
                with open(path) as f:
                    data = json.load(f)
                return list(data["addresses"])
            except (json.JSONDecodeError, KeyError):
                pass  # torn read before os.replace — retry
        time.sleep(0.05)
    raise TimeoutError(f"server did not publish {path} "
                       f"within {timeout:.0f}s")


def _send_shutdown(addresses: list[str], timeout: float = 10.0) -> None:
    """Tell each shard server to stop.  Client processes can't do this —
    none of them knows it is the last one out — so the launcher owns
    server lifetime."""
    import socket

    from repro.net import protocol

    for addr in addresses:
        host, port = addr.rsplit(":", 1)
        try:
            sock = socket.create_connection((host, int(port)),
                                            timeout=timeout)
        except OSError:
            continue  # already down
        conn = protocol.FramedConnection(sock)
        try:
            conn.request(protocol.MsgType.SHUTDOWN, {},
                         expect=(protocol.MsgType.OK,))
        except (protocol.ProtocolError, OSError):
            pass
        finally:
            conn.close()


def _finish(proc: subprocess.Popen, name: str, args: list[str],
            timeout: float) -> ProcResult:
    try:
        out, err = proc.communicate(timeout=timeout)
    except subprocess.TimeoutExpired:
        proc.kill()
        out, err = proc.communicate()
        return ProcResult(name, args, returncode=-9,
                          stdout=out or "", stderr=(err or "")
                          + f"\n[launcher] killed after {timeout:.0f}s "
                            "timeout")
    return ProcResult(name, args, proc.returncode, out or "", err or "")


def launch_loopback(*,
                    family: str = "lda",
                    vocab_size: int = 64,
                    n_topics: int = 4,
                    n_shards: int = 1,
                    client_sets: tuple[tuple[int, ...], ...] = ((0,), (1,)),
                    mode: str = "train",
                    n_rounds: int = 3,
                    tau: int = 1,
                    consistency: str = "bsp",
                    n_docs: int = 16,
                    doc_len: int = 12,
                    corpus_seed: int = 3,
                    seed: int = 0,
                    timeout: float = 300.0,
                    workdir: str | None = None,
                    extra_client_args: tuple[str, ...] = (),
                    ) -> LaunchResult:
    """Spawn 1 server process hosting ``n_shards`` shards plus one client
    process per entry of ``client_sets`` and wait for everything.

    Returns a :class:`LaunchResult`; raises nothing on nonzero client
    exits (inspect ``.ok`` / ``.failures()``) but does raise if the
    server never comes up."""
    n_clients = sum(len(cs) for cs in client_sets)
    own_tmp = workdir is None
    tmp = tempfile.mkdtemp(prefix="loopback_") if own_tmp else workdir
    addr_file = os.path.join(tmp, "addresses.json")

    server_args = _python() + [
        "-m", "repro.net.server",
        "--family", family,
        "--vocab-size", str(vocab_size),
        "--n-clients", str(n_clients),
        "--n-shards", str(n_shards),
        "--consistency", consistency,
        "--barrier-timeout", str(timeout),
        "--address-file", addr_file,
    ]
    env = _env()
    server = subprocess.Popen(server_args, stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE, text=True, env=env)
    try:
        addresses = _wait_address_file(addr_file, server, timeout)
    except Exception:
        server.kill()
        out, err = server.communicate()
        sys.stderr.write(f"[launcher] server stdout:\n{out}\n"
                         f"[launcher] server stderr:\n{err}\n")
        raise

    result = LaunchResult(addresses=addresses)
    client_procs: list[tuple[subprocess.Popen, str, list[str], str]] = []
    for i, cs in enumerate(client_sets):
        out_json = os.path.join(tmp, f"client{i}.json")
        cargs = _python() + [
            "-m", "repro.net.client",
            "--mode", mode,
            "--addrs", ",".join(addresses),
            "--clients", ",".join(str(c) for c in cs),
            "--family", family,
            "--vocab-size", str(vocab_size),
            "--n-topics", str(n_topics),
            "--n-clients", str(n_clients),
            "--n-rounds", str(n_rounds),
            "--tau", str(tau),
            "--consistency", consistency,
            "--n-docs", str(n_docs),
            "--doc-len", str(doc_len),
            "--corpus-seed", str(corpus_seed),
            "--seed", str(seed),
            "--timeout", str(timeout),
            "--out", out_json,
        ] + list(extra_client_args)
        proc = subprocess.Popen(cargs, stdout=subprocess.PIPE,
                                stderr=subprocess.PIPE, text=True, env=env)
        client_procs.append((proc, f"client{i}", cargs, out_json))

    deadline = time.monotonic() + timeout
    for proc, name, cargs, out_json in client_procs:
        left = max(1.0, deadline - time.monotonic())
        pr = _finish(proc, name, cargs, left)
        if pr.returncode == 0 and os.path.exists(out_json):
            with open(out_json) as f:
                pr.result = json.load(f)
        result.clients.append(pr)

    _send_shutdown(addresses)
    # A hung server must not hang the launcher: bounded wait, then kill.
    try:
        out, err = server.communicate(timeout=30.0)
        rc = server.returncode
    except subprocess.TimeoutExpired:
        server.kill()
        out, err = server.communicate()
        rc = -9
    result.servers.append(ProcResult("server", server_args, rc,
                                     out or "", err or ""))
    return result


def _smoke() -> int:
    """CI smoke: loopback BSP must be bit-exact with in-process BSP."""
    import numpy as np

    t0 = time.perf_counter()
    res = launch_loopback(client_sets=((0,), (1,)), n_rounds=3,
                          timeout=240.0)
    if not res.ok:
        for p in res.failures():
            sys.stderr.write(f"[smoke] {p.name} exit {p.returncode}\n"
                             f"--- stdout ---\n{p.stdout}\n"
                             f"--- stderr ---\n{p.stderr}\n")
        return 1

    # Both client processes must agree on the final state...
    sums = [p.result["checksums"] for p in res.clients]
    if sums[0] != sums[1]:
        sys.stderr.write(f"[smoke] client checksums disagree: {sums}\n")
        return 1

    # ...and match an in-process reference run exactly.
    import jax
    from repro.core import family as fam_mod
    from repro.core.lda import LDAConfig
    from repro.data.synthetic import CorpusConfig, make_topic_corpus
    from repro.engine.trainer import Trainer, TrainerConfig
    from repro.net.client import _checksum

    tokens, mask, _ = make_topic_corpus(CorpusConfig(
        n_topics=4, vocab_size=64, n_docs=16, doc_len=12, seed=3))
    ref = Trainer(LDAConfig(n_topics=4, vocab_size=64), tokens, mask,
                  config=TrainerConfig(n_clients=2, tau=1),
                  key=jax.random.PRNGKey(0))
    for _ in range(3):
        ref.step()
    ref_sums = {n: _checksum(np.asarray(v)) for n, v in
                fam_mod.get("lda").stats_dict(ref.shared).items()}
    if ref_sums != sums[0]:
        sys.stderr.write(f"[smoke] loopback != in-process: "
                         f"{sums[0]} vs {ref_sums}\n")
        return 1
    dt = time.perf_counter() - t0
    print(f"loopback smoke OK: 1 server + 2 client procs, BSP bit-exact "
          f"with in-process ({dt:.1f}s)")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="loopback multi-process launcher (repro.net)")
    ap.add_argument("--smoke", action="store_true",
                    help="run the CI end-to-end parity smoke and exit")
    ap.add_argument("--family", default="lda")
    ap.add_argument("--vocab-size", type=int, default=64)
    ap.add_argument("--n-topics", type=int, default=4)
    ap.add_argument("--n-shards", type=int, default=1)
    ap.add_argument("--n-client-procs", type=int, default=2)
    ap.add_argument("--clients-per-proc", type=int, default=1)
    ap.add_argument("--mode", choices=("train", "stress"), default="train")
    ap.add_argument("--n-rounds", type=int, default=3)
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--consistency", default="bsp")
    ap.add_argument("--timeout", type=float, default=300.0)
    args = ap.parse_args(argv)

    if args.smoke:
        return _smoke()

    sets = tuple(
        tuple(range(i * args.clients_per_proc,
                    (i + 1) * args.clients_per_proc))
        for i in range(args.n_client_procs))
    res = launch_loopback(
        family=args.family, vocab_size=args.vocab_size,
        n_topics=args.n_topics, n_shards=args.n_shards, client_sets=sets,
        mode=args.mode, n_rounds=args.n_rounds, tau=args.tau,
        consistency=args.consistency, timeout=args.timeout)
    for p in res.servers + res.clients:
        status = "ok" if p.returncode == 0 else f"EXIT {p.returncode}"
        print(f"{p.name}: {status}")
        if p.returncode != 0:
            sys.stderr.write(f"--- {p.name} stdout ---\n{p.stdout}\n"
                             f"--- {p.name} stderr ---\n{p.stderr}\n")
    return 0 if res.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
