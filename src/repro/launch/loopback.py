"""Multi-process loopback launcher for the out-of-process parameter
server (DESIGN.md §11, §13).

Spawns N shard-server processes (``python -m repro.net.server``) and M
client processes (``python -m repro.net.client``) on 127.0.0.1, waits
for the servers to publish their addresses, timeout-guards the whole
run, and collects exit codes, logs, and per-client result JSONs.  This
is the paper's deployment shape in miniature: parameter-server
*processes* serving sampler *processes* over a real network stack (the
loopback interface), with the same frames a cross-machine deployment
would use.

Fault tolerance (DESIGN.md §13) adds two layers on top:

* ``chaos_plan`` — a :class:`repro.core.fault.FaultPlan` whose network
  events are interposed as :class:`repro.net.chaos.ChaosProxy` relays
  between the clients and each shard address: seeded connection drops,
  frame truncations and delays on the wire, with the proxies' action
  counts collected into the result.
* :func:`launch_failover` — the kill-and-rejoin choreography: the shard
  process and/or one worker process carry ``--die-after-round`` and the
  launcher *supervises*, relaunching the killed shard with ``--restore
  --ports`` (same addresses, state reloaded from its own snapshot) and
  the killed worker with ``--restore`` (locals reloaded from its
  trainer snapshot, servers caught up through idempotent replay).

On abnormal exit the launcher dumps diagnostics into the result: the
last stderr lines of every failed process plus each live shard's STATS
frame (per-connection RPC counters) — enough to see *which* connection
died *where* without re-running.

``--smoke`` runs the CI end-to-end check: 1 shard server + 2 train
client processes (one global client each), then an in-process reference
``Trainer`` on the identical corpus/key, and asserts the BSP result is
bit-exact (checksum equality across the socket).  ``--failover-smoke``
runs the same parity check through chaos proxies while killing and
restarting both one shard process and one worker process mid-run.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time
from dataclasses import dataclass, field
from typing import Any


@dataclass
class ProcResult:
    """Exit status + captured output of one launched process."""
    name: str
    args: list[str]
    returncode: int
    stdout: str
    stderr: str
    result: dict[str, Any] | None = None  # parsed --out JSON, clients only
    expected: bool = False  # a scheduled --die-after-round kill (exit 42)


@dataclass
class LaunchResult:
    addresses: list[str]
    servers: list[ProcResult] = field(default_factory=list)
    clients: list[ProcResult] = field(default_factory=list)
    # Chaos-proxy action counts (one dict per interposed shard address).
    proxies: list[dict[str, Any]] = field(default_factory=list)
    # {"server": n, "client": n} relaunches performed by launch_failover.
    restarts: dict[str, int] = field(default_factory=dict)
    # Populated on abnormal exit: stderr tails of failed processes plus
    # the shards' per-connection RPC counters (STATS frames).
    diagnostics: dict[str, Any] = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return all(p.returncode == 0 or (p.expected and p.returncode == 42)
                   for p in self.servers + self.clients)

    def failures(self) -> list[ProcResult]:
        return [p for p in self.servers + self.clients
                if p.returncode != 0 and not (p.expected
                                              and p.returncode == 42)]


def _python() -> list[str]:
    return [sys.executable]


def _env() -> dict[str, str]:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def _tail(text: str, n: int = 15) -> list[str]:
    """The last ``n`` non-empty-ish lines of a captured stream — what a
    failure diagnosis actually needs from a long log."""
    return (text or "").strip().splitlines()[-n:]


def _wait_address_file(path: str, proc: subprocess.Popen,
                       timeout: float) -> list[str]:
    """Poll for the server's address file; fail fast if the server died."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            raise RuntimeError(
                f"server process exited early (code {proc.returncode}) "
                f"before publishing addresses")
        if os.path.exists(path):
            try:
                with open(path) as f:
                    data = json.load(f)
                return list(data["addresses"])
            except (json.JSONDecodeError, KeyError):
                pass  # torn read before os.replace — retry
        time.sleep(0.05)
    raise TimeoutError(f"server did not publish {path} "
                       f"within {timeout:.0f}s")


def _send_shutdown(addresses: list[str], timeout: float = 10.0) -> None:
    """Tell each shard server to stop.  Client processes can't do this —
    none of them knows it is the last one out — so the launcher owns
    server lifetime."""
    import socket

    from repro.net import protocol

    for addr in addresses:
        host, port = addr.rsplit(":", 1)
        try:
            sock = socket.create_connection((host, int(port)),
                                            timeout=timeout)
        except OSError:
            continue  # already down
        conn = protocol.FramedConnection(sock)
        try:
            conn.request(protocol.MsgType.SHUTDOWN, {},
                         expect=(protocol.MsgType.OK,))
        except (protocol.ProtocolError, OSError):
            pass
        finally:
            conn.close()


def _query_server_stats(addresses: list[str],
                        timeout: float = 5.0) -> list[dict[str, Any]]:
    """Each live shard's STATS frame (server round, clocks, evictions,
    per-connection RPC counters) — the server half of the abnormal-exit
    diagnostics.  Unreachable shards report instead of raising."""
    import socket

    from repro.net import protocol

    out: list[dict[str, Any]] = []
    for addr in addresses:
        host, port = addr.rsplit(":", 1)
        try:
            sock = socket.create_connection((host, int(port)),
                                            timeout=timeout)
        except OSError as e:
            out.append({"address": addr, "error": f"unreachable: {e}"})
            continue
        conn = protocol.FramedConnection(sock)
        try:
            _, meta, _ = conn.request(protocol.MsgType.STATS, {},
                                      expect=(protocol.MsgType.OK,))
            out.append({"address": addr, **meta})
        except (protocol.ProtocolError, OSError) as e:
            out.append({"address": addr, "error": str(e)})
        finally:
            conn.close()
    return out


def _diagnose(result: LaunchResult, addresses: list[str],
              server_alive: bool) -> None:
    """Fill ``result.diagnostics`` for an abnormal exit: stderr tails of
    every failed process, plus the shards' per-connection RPC counters
    while they are still answering."""
    if result.ok:
        return
    result.diagnostics = {
        "failures": {
            p.name: {"returncode": p.returncode,
                     "stderr_tail": _tail(p.stderr)}
            for p in result.failures()},
        "server_stats": (_query_server_stats(addresses)
                         if server_alive else []),
    }


def _finish(proc: subprocess.Popen, name: str, args: list[str],
            timeout: float) -> ProcResult:
    try:
        out, err = proc.communicate(timeout=timeout)
    except subprocess.TimeoutExpired:
        proc.kill()
        out, err = proc.communicate()
        return ProcResult(name, args, returncode=-9,
                          stdout=out or "", stderr=(err or "")
                          + f"\n[launcher] killed after {timeout:.0f}s "
                            "timeout")
    return ProcResult(name, args, proc.returncode, out or "", err or "")


def _interpose(addresses: list[str], chaos_plan):
    """Stand chaos proxies in front of ``addresses`` (always, when a
    plan is given — a plan with no net events is the pass-through
    control arm); returns (addresses clients should dial, proxies)."""
    if chaos_plan is None:
        return addresses, []
    from repro.net.chaos import interpose
    return interpose(addresses, chaos_plan)


def launch_loopback(*,
                    family: str = "lda",
                    vocab_size: int = 64,
                    n_topics: int = 4,
                    n_shards: int = 1,
                    client_sets: tuple[tuple[int, ...], ...] = ((0,), (1,)),
                    mode: str = "train",
                    n_rounds: int = 3,
                    tau: int = 1,
                    consistency: str = "bsp",
                    n_docs: int = 16,
                    doc_len: int = 12,
                    corpus_seed: int = 3,
                    seed: int = 0,
                    timeout: float = 300.0,
                    workdir: str | None = None,
                    chaos_plan=None,
                    extra_client_args: tuple[str, ...] = (),
                    ) -> LaunchResult:
    """Spawn 1 server process hosting ``n_shards`` shards plus one client
    process per entry of ``client_sets`` and wait for everything.

    With ``chaos_plan`` (a :class:`repro.core.fault.FaultPlan`) the
    clients dial :class:`~repro.net.chaos.ChaosProxy` relays instead of
    the shards directly; the proxies' action counts land in
    ``result.proxies``.

    Returns a :class:`LaunchResult`; raises nothing on nonzero client
    exits (inspect ``.ok`` / ``.failures()``) but does raise if the
    server never comes up."""
    n_clients = sum(len(cs) for cs in client_sets)
    own_tmp = workdir is None
    tmp = tempfile.mkdtemp(prefix="loopback_") if own_tmp else workdir
    addr_file = os.path.join(tmp, "addresses.json")

    server_args = _python() + [
        "-m", "repro.net.server",
        "--family", family,
        "--vocab-size", str(vocab_size),
        "--n-clients", str(n_clients),
        "--n-shards", str(n_shards),
        "--consistency", consistency,
        "--barrier-timeout", str(timeout),
        "--address-file", addr_file,
    ]
    env = _env()
    server = subprocess.Popen(server_args, stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE, text=True, env=env)
    try:
        addresses = _wait_address_file(addr_file, server, timeout)
    except Exception:
        server.kill()
        out, err = server.communicate()
        sys.stderr.write(f"[launcher] server stdout:\n{out}\n"
                         f"[launcher] server stderr:\n{err}\n")
        raise

    client_addrs, proxies = _interpose(addresses, chaos_plan)
    result = LaunchResult(addresses=addresses)
    client_procs: list[tuple[subprocess.Popen, str, list[str], str]] = []
    for i, cs in enumerate(client_sets):
        out_json = os.path.join(tmp, f"client{i}.json")
        cargs = _python() + [
            "-m", "repro.net.client",
            "--mode", mode,
            "--addrs", ",".join(client_addrs),
            "--clients", ",".join(str(c) for c in cs),
            "--family", family,
            "--vocab-size", str(vocab_size),
            "--n-topics", str(n_topics),
            "--n-clients", str(n_clients),
            "--n-rounds", str(n_rounds),
            "--tau", str(tau),
            "--consistency", consistency,
            "--n-docs", str(n_docs),
            "--doc-len", str(doc_len),
            "--corpus-seed", str(corpus_seed),
            "--seed", str(seed),
            "--timeout", str(timeout),
            "--out", out_json,
        ] + list(extra_client_args)
        proc = subprocess.Popen(cargs, stdout=subprocess.PIPE,
                                stderr=subprocess.PIPE, text=True, env=env)
        client_procs.append((proc, f"client{i}", cargs, out_json))

    deadline = time.monotonic() + timeout
    for proc, name, cargs, out_json in client_procs:
        left = max(1.0, deadline - time.monotonic())
        pr = _finish(proc, name, cargs, left)
        if pr.returncode == 0 and os.path.exists(out_json):
            with open(out_json) as f:
                pr.result = json.load(f)
        result.clients.append(pr)

    # Diagnostics want the shards' counters while they still answer.
    if any(p.returncode != 0 for p in result.clients):
        result.diagnostics["server_stats"] = _query_server_stats(addresses)

    for p in proxies:
        result.proxies.append(p.stats())
        p.close()
    _send_shutdown(addresses)
    # A hung server must not hang the launcher: bounded wait, then kill.
    try:
        out, err = server.communicate(timeout=30.0)
        rc = server.returncode
    except subprocess.TimeoutExpired:
        server.kill()
        out, err = server.communicate()
        rc = -9
    result.servers.append(ProcResult("server", server_args, rc,
                                     out or "", err or ""))
    if not result.ok:
        stats = result.diagnostics.get("server_stats", [])
        _diagnose(result, addresses, server_alive=False)
        result.diagnostics["server_stats"] = stats
    return result


def _strip_flag(args: list[str], flag: str) -> list[str]:
    """``args`` without ``flag`` and its value (two-token options)."""
    out: list[str] = []
    i = 0
    while i < len(args):
        if args[i] == flag:
            i += 2
            continue
        out.append(args[i])
        i += 1
    return out


def launch_failover(*,
                    family: str = "lda",
                    vocab_size: int = 64,
                    n_topics: int = 4,
                    n_shards: int = 1,
                    client_sets: tuple[tuple[int, ...], ...] = ((0,), (1,)),
                    n_rounds: int = 6,
                    tau: int = 1,
                    consistency: str = "bsp",
                    kill_server_round: int | None = None,
                    kill_client: int | None = None,
                    kill_client_round: int | None = None,
                    chaos_plan=None,
                    n_docs: int = 16,
                    doc_len: int = 12,
                    corpus_seed: int = 3,
                    seed: int = 0,
                    timeout: float = 300.0,
                    liveness_timeout: float = 120.0,
                    reconnect_limit: int = 64,
                    workdir: str | None = None,
                    ) -> LaunchResult:
    """The kill-and-rejoin choreography over real processes (§5.4 on the
    wire, DESIGN.md §13).

    The shard process snapshots every finalized round; with
    ``kill_server_round`` it ``exit(42)``\\ s once every shard reaches
    that round, and the launcher relaunches it with ``--restore --ports``
    so it rebinds the *same* addresses and resumes from its snapshot —
    the clients ride it out through bounded RPC retry and replay their
    buffered mutations on reconnect.  With ``kill_client`` (an index
    into ``client_sets``) that worker snapshots every round, dies after
    ``kill_client_round``, and is relaunched with ``--restore`` to
    resume mid-run — the barrier, protected by ``liveness_timeout``,
    waits instead of evicting.  ``chaos_plan`` interposes chaos proxies
    exactly as :func:`launch_loopback`.

    Under BSP the final statistics must be bit-exact with the
    undisturbed in-process run — the acceptance property asserted by
    ``--failover-smoke``, ``tools/ci.sh`` and ``tests/test_failover_tcp``.
    """
    n_clients = sum(len(cs) for cs in client_sets)
    own_tmp = workdir is None
    tmp = tempfile.mkdtemp(prefix="failover_") if own_tmp else workdir
    addr_file = os.path.join(tmp, "addresses.json")
    srv_snap = os.path.join(tmp, "server_snapshots")
    env = _env()

    base_server_args = _python() + [
        "-m", "repro.net.server",
        "--family", family,
        "--vocab-size", str(vocab_size),
        "--n-clients", str(n_clients),
        "--n-shards", str(n_shards),
        "--consistency", consistency,
        "--barrier-timeout", str(timeout),
        "--liveness-timeout", str(liveness_timeout),
        "--snapshot-dir", srv_snap,
        "--snapshot-every", "1",
        "--address-file", addr_file,
    ]
    server_args = list(base_server_args)
    if kill_server_round is not None:
        server_args += ["--die-after-round", str(kill_server_round)]
    server = subprocess.Popen(server_args, stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE, text=True, env=env)
    try:
        addresses = _wait_address_file(addr_file, server, timeout)
    except Exception:
        server.kill()
        out, err = server.communicate()
        sys.stderr.write(f"[launcher] server stdout:\n{out}\n"
                         f"[launcher] server stderr:\n{err}\n")
        raise
    ports = ",".join(a.rsplit(":", 1)[1] for a in addresses)

    client_addrs, proxies = _interpose(addresses, chaos_plan)
    result = LaunchResult(addresses=addresses,
                          restarts={"server": 0, "client": 0})

    running: dict[str, list] = {}  # name -> [proc, args, out_json, victim]
    for i, cs in enumerate(client_sets):
        out_json = os.path.join(tmp, f"client{i}.json")
        cargs = _python() + [
            "-m", "repro.net.client",
            "--mode", "train",
            "--addrs", ",".join(client_addrs),
            "--clients", ",".join(str(c) for c in cs),
            "--family", family,
            "--vocab-size", str(vocab_size),
            "--n-topics", str(n_topics),
            "--n-clients", str(n_clients),
            "--n-rounds", str(n_rounds),
            "--tau", str(tau),
            "--consistency", consistency,
            "--n-docs", str(n_docs),
            "--doc-len", str(doc_len),
            "--corpus-seed", str(corpus_seed),
            "--seed", str(seed),
            "--timeout", str(timeout),
            "--reconnect-limit", str(reconnect_limit),
            "--out", out_json,
        ]
        if i == kill_client:
            if kill_client_round is None:
                raise ValueError("kill_client requires kill_client_round")
            cargs += ["--snapshot-dir",
                      os.path.join(tmp, f"client{i}_snapshots"),
                      "--snapshot-every", "1",
                      "--die-after-round", str(kill_client_round)]
        proc = subprocess.Popen(cargs, stdout=subprocess.PIPE,
                                stderr=subprocess.PIPE, text=True, env=env)
        running[f"client{i}"] = [proc, cargs, out_json, i == kill_client]

    deadline = time.monotonic() + timeout
    server_alive = True
    while running and time.monotonic() < deadline:
        # --- shard-process supervision -------------------------------
        if server_alive and server.poll() is not None:
            out, err = server.communicate()
            expected = server.returncode == 42
            result.servers.append(ProcResult(
                "server#killed" if expected else "server", server_args,
                server.returncode, out or "", err or "",
                expected=expected))
            if not expected:
                server_alive = False  # unexpected death: let clients fail
            else:
                result.restarts["server"] += 1
                # The stale address file must not satisfy the readiness
                # poll before the restarted process has actually bound.
                try:
                    os.remove(addr_file)
                except FileNotFoundError:
                    pass
                server_args = list(base_server_args) + [
                    "--restore", "--ports", ports]
                server = subprocess.Popen(
                    server_args, stdout=subprocess.PIPE,
                    stderr=subprocess.PIPE, text=True, env=env)
                _wait_address_file(addr_file, server,
                                   max(1.0, deadline - time.monotonic()))
        # --- worker-process supervision ------------------------------
        for name in list(running):
            proc, cargs, out_json, victim = running[name]
            rc = proc.poll()
            if rc is None:
                continue
            out, err = proc.communicate()
            if rc == 42 and victim:
                result.clients.append(ProcResult(
                    f"{name}#killed", cargs, rc, out or "", err or "",
                    expected=True))
                result.restarts["client"] += 1
                new_args = _strip_flag(cargs, "--die-after-round") \
                    + ["--restore"]
                proc2 = subprocess.Popen(
                    new_args, stdout=subprocess.PIPE,
                    stderr=subprocess.PIPE, text=True, env=env)
                running[name] = [proc2, new_args, out_json, False]
                continue
            pr = ProcResult(name, cargs, rc, out or "", err or "")
            if rc == 0 and os.path.exists(out_json):
                with open(out_json) as f:
                    pr.result = json.load(f)
            result.clients.append(pr)
            del running[name]
        time.sleep(0.1)

    # Anything still running at the deadline is hung: kill + record.
    for name, (proc, cargs, out_json, _victim) in running.items():
        result.clients.append(_finish(proc, name, cargs, timeout=1.0))

    if any(p.returncode != 0 and not p.expected for p in result.clients):
        result.diagnostics["server_stats"] = _query_server_stats(addresses)

    for p in proxies:
        result.proxies.append(p.stats())
        p.close()
    if server_alive:
        _send_shutdown(addresses)
        try:
            out, err = server.communicate(timeout=30.0)
            rc = server.returncode
        except subprocess.TimeoutExpired:
            server.kill()
            out, err = server.communicate()
            rc = -9
        result.servers.append(ProcResult("server", server_args, rc,
                                         out or "", err or ""))
    if not result.ok:
        stats = result.diagnostics.get("server_stats", [])
        _diagnose(result, addresses, server_alive=False)
        result.diagnostics["server_stats"] = stats
    return result


def _reference_run(n_rounds: int) -> dict[str, Any]:
    """The undisturbed in-process BSP reference for the smoke corpora:
    final per-stat checksums plus held-out perplexity — what a disturbed
    tcp run is compared against bit-for-bit."""
    import jax
    import numpy as np
    from repro.core import family as fam_mod
    from repro.core.lda import LDAConfig
    from repro.data.synthetic import CorpusConfig, make_topic_corpus
    from repro.engine.trainer import Trainer, TrainerConfig
    from repro.net.client import _checksum

    tokens, mask, _ = make_topic_corpus(CorpusConfig(
        n_topics=4, vocab_size=64, n_docs=16, doc_len=12, seed=3))
    ref = Trainer(LDAConfig(n_topics=4, vocab_size=64), tokens, mask,
                  config=TrainerConfig(n_clients=2, tau=1),
                  key=jax.random.PRNGKey(0))
    for _ in range(n_rounds):
        ref.step()
    checksums = {n: _checksum(np.asarray(v)) for n, v in
                 fam_mod.get("lda").stats_dict(ref.shared).items()}
    return {"checksums": checksums, "perplexity": ref.perplexity()}


def _dump_failures(tag: str, res: LaunchResult) -> None:
    for p in res.failures():
        sys.stderr.write(f"[{tag}] {p.name} exit {p.returncode}\n"
                         f"--- stdout ---\n{p.stdout}\n"
                         f"--- stderr ---\n{p.stderr}\n")
    if res.diagnostics:
        sys.stderr.write(f"[{tag}] diagnostics: "
                         f"{json.dumps(res.diagnostics, indent=2)}\n")


def _smoke() -> int:
    """CI smoke: loopback BSP must be bit-exact with in-process BSP."""
    t0 = time.perf_counter()
    res = launch_loopback(client_sets=((0,), (1,)), n_rounds=3,
                          timeout=240.0)
    if not res.ok:
        _dump_failures("smoke", res)
        return 1

    # Both client processes must agree on the final state...
    sums = [p.result["checksums"] for p in res.clients]
    if sums[0] != sums[1]:
        sys.stderr.write(f"[smoke] client checksums disagree: {sums}\n")
        return 1

    # ...and match an in-process reference run exactly.
    ref_sums = _reference_run(3)["checksums"]
    if ref_sums != sums[0]:
        sys.stderr.write(f"[smoke] loopback != in-process: "
                         f"{sums[0]} vs {ref_sums}\n")
        return 1
    dt = time.perf_counter() - t0
    print(f"loopback smoke OK: 1 server + 2 client procs, BSP bit-exact "
          f"with in-process ({dt:.1f}s)")
    return 0


def _failover_smoke() -> int:
    """CI failover smoke (DESIGN.md §13): BSP through chaos proxies with
    a connection drop on the push path, one shard-process restart from
    snapshot and one worker-process kill-and-rejoin — still bit-exact
    with the undisturbed in-process run."""
    from repro.core.fault import FaultEvent, FaultPlan

    t0 = time.perf_counter()
    n_rounds = 6
    # Connection ordinal 0 (the first worker to reach the proxy) loses
    # the connection instead of delivering its round-1 push (frame 5);
    # every connection's round-0 pull (frame 2) is delayed.  Drops aim
    # at a specific ordinal: a reconnected connection gets a fresh
    # ordinal, so the drop fires exactly once.
    plan = FaultPlan.scripted(
        FaultEvent("conn_drop", client=0, start=5, stop=6, period=1),
        FaultEvent("delay", client=-1, start=2, stop=3, period=1,
                   magnitude=0.02))
    res = launch_failover(client_sets=((0,), (1,)), n_rounds=n_rounds,
                          kill_server_round=3,
                          kill_client=1, kill_client_round=2,
                          chaos_plan=plan, timeout=420.0)
    if not res.ok:
        _dump_failures("failover-smoke", res)
        return 1
    if res.restarts != {"server": 1, "client": 1}:
        sys.stderr.write(f"[failover-smoke] expected exactly one shard "
                         f"and one worker restart, got {res.restarts}\n")
        return 1
    drops = sum(p["actions"]["conn_drop"] for p in res.proxies)
    if drops < 1:
        sys.stderr.write("[failover-smoke] the scheduled conn_drop never "
                         f"fired (proxies: {res.proxies})\n")
        return 1

    finals = [p for p in res.clients if p.returncode == 0 and p.result]
    sums = [p.result["checksums"] for p in finals]
    if not sums or any(s != sums[0] for s in sums):
        sys.stderr.write(f"[failover-smoke] client checksums disagree: "
                         f"{sums}\n")
        return 1
    ref_sums = _reference_run(n_rounds)["checksums"]
    if ref_sums != sums[0]:
        sys.stderr.write(f"[failover-smoke] disturbed tcp run != "
                         f"in-process: {sums[0]} vs {ref_sums}\n")
        return 1
    dt = time.perf_counter() - t0
    print(f"failover smoke OK: chaos proxy ({drops} drop), 1 shard "
          f"restart from snapshot, 1 worker kill-and-rejoin, BSP "
          f"bit-exact with in-process ({dt:.1f}s)")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="loopback multi-process launcher (repro.net)")
    ap.add_argument("--smoke", action="store_true",
                    help="run the CI end-to-end parity smoke and exit")
    ap.add_argument("--failover-smoke", action="store_true",
                    help="run the chaos + kill-and-rejoin parity smoke "
                         "and exit")
    ap.add_argument("--family", default="lda")
    ap.add_argument("--vocab-size", type=int, default=64)
    ap.add_argument("--n-topics", type=int, default=4)
    ap.add_argument("--n-shards", type=int, default=1)
    ap.add_argument("--n-client-procs", type=int, default=2)
    ap.add_argument("--clients-per-proc", type=int, default=1)
    ap.add_argument("--mode", choices=("train", "stress"), default="train")
    ap.add_argument("--n-rounds", type=int, default=3)
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--consistency", default="bsp")
    ap.add_argument("--timeout", type=float, default=300.0)
    args = ap.parse_args(argv)

    if args.smoke:
        return _smoke()
    if args.failover_smoke:
        return _failover_smoke()

    sets = tuple(
        tuple(range(i * args.clients_per_proc,
                    (i + 1) * args.clients_per_proc))
        for i in range(args.n_client_procs))
    res = launch_loopback(
        family=args.family, vocab_size=args.vocab_size,
        n_topics=args.n_topics, n_shards=args.n_shards, client_sets=sets,
        mode=args.mode, n_rounds=args.n_rounds, tau=args.tau,
        consistency=args.consistency, timeout=args.timeout)
    for p in res.servers + res.clients:
        status = "ok" if p.returncode == 0 else f"EXIT {p.returncode}"
        print(f"{p.name}: {status}")
    if not res.ok:
        _dump_failures("launch", res)
    return 0 if res.ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
