"""Production serving launcher: batched requests through the slot engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
        --requests 8 --batch 4 --max-new 16

The engine's cache pytree takes the same ``cache_specs`` shardings the
decode dry-run validated; on the CPU container the mesh is 1x1.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import reduced
from repro.configs.registry import ARCHITECTURES
from repro.models import model as model_lib
from repro.serve.engine import Engine, EngineConfig, Request


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b",
                    choices=sorted(ARCHITECTURES))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = reduced(ARCHITECTURES[args.arch]) if args.reduced \
        else ARCHITECTURES[args.arch]
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(cfg, params, EngineConfig(
        batch=args.batch, max_len=args.prompt_len + args.max_new + 8))

    rng = np.random.default_rng(0)
    reqs = [Request(uid=i, prompt=rng.integers(
        0, cfg.vocab_size, args.prompt_len, dtype=np.int32),
        max_new_tokens=args.max_new) for i in range(args.requests)]
    t0 = time.time()
    done = engine.run(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests / {n_tok} tokens in {dt:.1f}s "
          f"({n_tok / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
