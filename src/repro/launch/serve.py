"""Loopback launcher for the online inference service (DESIGN.md §14).

Spawns 1 inference-server process (``python -m repro.serve.server``) and
M concurrent client processes (``python -m repro.serve.client``) on
127.0.0.1, against a snapshot trained in-process and persisted through
``checkpoint.ckpt`` — the serving deployment shape in miniature: a
frozen model behind a socket, folded into by many concurrent users.

``--smoke`` is the CI end-to-end check: train a small LDA model, save
its Trainer snapshot, serve it from a separate process, fold the same
request corpus in from 2 concurrent client processes, and assert every
client's per-document result checksums equal the in-process
``FoldInEngine`` reference over the same snapshot.  Fold-in results are
a pure function of (snapshot, tokens, request seed) — so process
boundaries, request interleaving and batching composition must not move
a single bit.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time
from dataclasses import dataclass, field
from typing import Any


@dataclass
class ProcResult:
    """Exit status + captured output of one launched process."""
    name: str
    args: list[str]
    returncode: int
    stdout: str
    stderr: str
    result: dict[str, Any] | None = None  # parsed --out JSON, clients only


@dataclass
class ServeLaunchResult:
    address: str
    server: ProcResult | None = None
    clients: list[ProcResult] = field(default_factory=list)
    server_stats: dict[str, Any] = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        procs = ([self.server] if self.server else []) + self.clients
        return all(p.returncode == 0 for p in procs)

    def failures(self) -> list[ProcResult]:
        procs = ([self.server] if self.server else []) + self.clients
        return [p for p in procs if p.returncode != 0]


def _python() -> list[str]:
    return [sys.executable]


def _env() -> dict[str, str]:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def _tail(text: str, n: int = 15) -> list[str]:
    return (text or "").strip().splitlines()[-n:]


def _wait_address_file(path: str, proc: subprocess.Popen,
                       timeout: float) -> str:
    """Poll for the server's address file; fail fast if the server died."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if proc.poll() is not None:
            raise RuntimeError(
                f"inference server exited early (code {proc.returncode}) "
                f"before publishing its address")
        if os.path.exists(path):
            try:
                with open(path) as f:
                    return list(json.load(f)["addresses"])[0]
            except (json.JSONDecodeError, KeyError, IndexError):
                pass  # torn read before os.replace — retry
        time.sleep(0.05)
    raise TimeoutError(f"server did not publish {path} within "
                       f"{timeout:.0f}s")


def _finish(proc: subprocess.Popen, name: str, args: list[str],
            timeout: float) -> ProcResult:
    try:
        out, err = proc.communicate(timeout=timeout)
    except subprocess.TimeoutExpired:
        proc.kill()
        out, err = proc.communicate()
        return ProcResult(name, args, returncode=-9,
                          stdout=out or "", stderr=(err or "")
                          + f"\n[launcher] killed after {timeout:.0f}s "
                            "timeout")
    return ProcResult(name, args, proc.returncode, out or "", err or "")


def _shutdown_server(address: str, timeout: float = 10.0
                     ) -> dict[str, Any]:
    """Fetch the server's STATS then tell it to stop — clients can't:
    none of them knows it is the last one out."""
    from repro.serve.client import InferenceClient
    stats: dict[str, Any] = {}
    try:
        with InferenceClient(address, timeout=timeout) as cli:
            try:
                stats = cli.stats()
            except Exception:
                pass
            cli.shutdown()
    except OSError:
        pass  # already down
    return stats


def train_snapshot(workdir: str, *, family: str, vocab_size: int,
                   n_topics: int, n_docs: int = 64, doc_len: int = 48,
                   n_rounds: int = 5, seed: int = 0):
    """Train a small model in-process and persist its Trainer snapshot —
    the model the launched server process will freeze and serve.
    Returns the model config (the serving side rebuilds the same one
    from CLI flags)."""
    import jax

    from repro.core import family as family_mod
    from repro.data.synthetic import CorpusConfig, make_topic_corpus
    from repro.engine.trainer import Trainer, TrainerConfig

    fam = family_mod.get(family)
    cfg = fam.config_cls(n_topics=n_topics, vocab_size=vocab_size)
    tokens, mask, _ = make_topic_corpus(CorpusConfig(
        n_topics=n_topics, vocab_size=vocab_size, n_docs=n_docs,
        doc_len=doc_len, seed=seed))
    tcfg = TrainerConfig(n_clients=1, snapshot_dir=workdir)
    trainer = Trainer(cfg, tokens, mask, config=tcfg,
                      key=jax.random.PRNGKey(seed))
    trainer.run(n_rounds, eval_every=n_rounds + 1)
    trainer.save_snapshot()
    return cfg


def launch_serve(*, family: str = "lda", vocab_size: int = 400,
                 n_topics: int = 8, n_clients: int = 2,
                 n_docs: int = 6, max_len: int = 48, max_slots: int = 8,
                 n_sweeps: int = 10, corpus_seed: int = 7,
                 seed_base: int = 1000, train_rounds: int = 5,
                 timeout: float = 420.0, workdir: str | None = None
                 ) -> tuple[ServeLaunchResult, Any]:
    """Train → snapshot → serve from a separate process → M concurrent
    client processes.  Returns (launch result, model config)."""
    own_dir = workdir is None
    tmp = tempfile.TemporaryDirectory() if own_dir else None
    workdir = tmp.name if own_dir else workdir
    try:
        cfg = train_snapshot(workdir, family=family,
                             vocab_size=vocab_size, n_topics=n_topics,
                             n_rounds=train_rounds, seed=corpus_seed)
        addr_file = os.path.join(workdir, "serve_addr.json")
        srv_args = _python() + ["-m", "repro.serve.server",
                                "--family", family,
                                "--vocab-size", str(vocab_size),
                                "--n-topics", str(n_topics),
                                "--snapshot-dir", workdir,
                                "--max-slots", str(max_slots),
                                "--max-len", str(max_len),
                                "--n-sweeps", str(n_sweeps),
                                "--address-file", addr_file]
        srv = subprocess.Popen(srv_args, stdout=subprocess.PIPE,
                               stderr=subprocess.PIPE, text=True,
                               env=_env())
        result = ServeLaunchResult(address="")
        try:
            result.address = _wait_address_file(addr_file, srv,
                                                timeout=60.0)
        except (RuntimeError, TimeoutError):
            result.server = _finish(srv, "server", srv_args, timeout=5.0)
            return result, cfg

        client_procs = []
        for c in range(n_clients):
            out = os.path.join(workdir, f"client{c}.json")
            cargs = _python() + ["-m", "repro.serve.client",
                                 "--addr", result.address,
                                 "--client-id", str(c),
                                 "--n-docs", str(n_docs),
                                 "--vocab-size", str(vocab_size),
                                 "--max-len", str(max_len),
                                 "--corpus-seed", str(corpus_seed),
                                 "--seed-base", str(seed_base),
                                 "--out", out]
            client_procs.append(
                (subprocess.Popen(cargs, stdout=subprocess.PIPE,
                                  stderr=subprocess.PIPE, text=True,
                                  env=_env()), cargs, out))
        for i, (proc, cargs, out) in enumerate(client_procs):
            pr = _finish(proc, f"client{i}", cargs, timeout)
            if pr.returncode == 0 and os.path.exists(out):
                with open(out) as f:
                    pr.result = json.load(f)
            result.clients.append(pr)
        result.server_stats = _shutdown_server(result.address)
        result.server = _finish(srv, "server", srv_args, timeout=30.0)
        return result, cfg
    finally:
        if tmp is not None:
            tmp.cleanup()


def _smoke(args) -> int:
    """CI serve smoke: 2 concurrent client processes over loopback must
    agree bit-for-bit with the in-process fold-in reference."""
    import tempfile as _tf

    with _tf.TemporaryDirectory() as workdir:
        result, cfg = launch_serve(
            family=args.family, vocab_size=args.vocab_size,
            n_topics=args.n_topics, n_clients=args.n_clients,
            n_docs=args.n_docs, max_len=args.max_len,
            max_slots=args.max_slots, n_sweeps=args.n_sweeps,
            corpus_seed=args.corpus_seed, seed_base=args.seed_base,
            train_rounds=args.train_rounds, timeout=args.timeout,
            workdir=workdir)
        if not result.ok:
            for p in result.failures():
                print(f"FAIL {p.name} rc={p.returncode}",
                      *_tail(p.stderr), sep="\n  ")
            return 1

        # In-process reference: same snapshot (via the same checkpoint
        # manifest), same requests, one engine — must be bit-identical
        # to what crossed the wire, regardless of batching.
        from repro.serve import snapshot as snapshot_mod
        from repro.serve.client import requests_for
        from repro.serve.engine import (FoldInEngine, ServeConfig,
                                        result_checksum)
        snap = snapshot_mod.from_checkpoint(workdir, cfg)
        eng = FoldInEngine(snap, ServeConfig(max_slots=args.max_slots,
                                             max_len=args.max_len,
                                             n_sweeps=args.n_sweeps))
        reqs = []
        for c in range(args.n_clients):
            reqs.extend(requests_for(
                c, vocab_size=args.vocab_size, n_docs=args.n_docs,
                max_len=args.max_len, corpus_seed=args.corpus_seed,
                seed_base=args.seed_base))
        ref = {str(uid): result_checksum(res)
               for uid, res in eng.run(reqs).items()}

        bad = 0
        for pr in result.clients:
            got = pr.result["checksums"]
            for uid, sha in got.items():
                if ref.get(uid) != sha:
                    print(f"MISMATCH {pr.name} uid={uid}: wire {sha[:12]} "
                          f"!= reference {str(ref.get(uid))[:12]}")
                    bad += 1
        total = sum(len(p.result["checksums"]) for p in result.clients)
        if bad or total != args.n_clients * args.n_docs:
            print(f"serve smoke FAILED: {bad} mismatches, "
                  f"{total} results")
            return 1
        stats = result.server_stats
        print(f"serve smoke OK: {total} docs over {args.n_clients} "
              f"concurrent clients bit-exact with in-process fold-in "
              f"(server p50 {stats.get('latency_p50_ms', 0):.1f} ms, "
              f"p99 {stats.get('latency_p99_ms', 0):.1f} ms, "
              f"shed {stats.get('shed', 0)})")
        return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="loopback launcher: 1 inference server x M "
                    "concurrent clients (repro.serve)")
    ap.add_argument("--smoke", action="store_true",
                    help="run the CI checksum-parity smoke and exit")
    # BooleanOptionalAction so --no-reduced actually works (the seed
    # launcher's store_true+default=True flag could never be disabled).
    ap.add_argument("--reduced", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="small smoke geometry (--no-reduced serves a "
                         "larger model)")
    ap.add_argument("--family", default="lda")
    ap.add_argument("--n-clients", type=int, default=2)
    ap.add_argument("--n-docs", type=int, default=6)
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--n-sweeps", type=int, default=10)
    ap.add_argument("--corpus-seed", type=int, default=7)
    ap.add_argument("--seed-base", type=int, default=1000)
    ap.add_argument("--train-rounds", type=int, default=5)
    ap.add_argument("--timeout", type=float, default=420.0)
    args = ap.parse_args(argv)
    if args.reduced:
        args.vocab_size, args.n_topics, args.max_len = 400, 8, 48
    else:
        args.vocab_size, args.n_topics, args.max_len = 4096, 32, 128

    if args.smoke:
        return _smoke(args)

    result, _cfg = launch_serve(
        family=args.family, vocab_size=args.vocab_size,
        n_topics=args.n_topics, n_clients=args.n_clients,
        n_docs=args.n_docs, max_len=args.max_len,
        max_slots=args.max_slots, n_sweeps=args.n_sweeps,
        corpus_seed=args.corpus_seed, seed_base=args.seed_base,
        train_rounds=args.train_rounds, timeout=args.timeout)
    if not result.ok:
        for p in result.failures():
            print(f"FAIL {p.name} rc={p.returncode}",
                  *_tail(p.stderr), sep="\n  ")
        return 1
    lats = [ms for p in result.clients for ms in p.result["latency_ms"]]
    lats.sort()
    total = sum(len(p.result["checksums"]) for p in result.clients)
    p50 = lats[len(lats) // 2] if lats else 0.0
    p99 = lats[min(len(lats) - 1, int(round(0.99 * (len(lats) - 1))))] \
        if lats else 0.0
    print(f"served {total} docs over {len(result.clients)} clients: "
          f"p50 {p50:.1f} ms, p99 {p99:.1f} ms "
          f"(server stats {json.dumps(result.server_stats)})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
