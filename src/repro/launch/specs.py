"""Input specifications for every (architecture × input shape) pair.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation) for every model input of the workload, and
the matching PartitionSpecs.  This is what both the multi-pod dry-run and
the roofline analysis lower against.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.models import model as model_lib
from repro.optim import adamw
from repro.train import sharding as sh
from repro.train.train_step import TrainConfig

Array = jax.Array
SDS = jax.ShapeDtypeStruct


# Per-shape config overrides (DESIGN.md §4): zamba2's shared attention is
# windowed at the long-context shape.
SHAPE_OVERRIDES: dict[tuple[str, str], dict[str, Any]] = {
    ("zamba2-2.7b", "long_500k"): {"sliding_window": 4096},
}

# Microbatch counts for the train shape, keyed by parameter scale — keeps
# the per-device live activation set inside v5e HBM (DESIGN.md §5).
def default_microbatches(cfg: ModelConfig) -> int:
    n = cfg.param_count()
    if n >= 40e9:
        return 16
    if n >= 10e9:
        return 8
    if n >= 2e9:
        return 4
    return 1


def skip_reason(cfg: ModelConfig, shape: InputShape) -> str | None:
    """Returns a reason string when this (arch, shape) pair is skipped."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("pure full-attention architecture: 500k-token decode is not "
                "sub-quadratic/bounded-state (DESIGN.md §4 skip list)")
    return None


def apply_overrides(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    over = SHAPE_OVERRIDES.get((cfg.name, shape.name))
    return cfg.replace(**over) if over else cfg


def batch_template(cfg: ModelConfig, shape: InputShape) -> dict[str, SDS]:
    """ShapeDtypeStructs for the data batch of a train/prefill shape."""
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": SDS((b, s), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = SDS((b, cfg.n_patches, cfg.vision_dim),
                                    jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = SDS((b, cfg.n_frames, cfg.d_model), jnp.bfloat16)
    return batch


@dataclass
class LoweringSpec:
    """Everything needed to ``jit(...).lower(...)`` one workload."""
    kind: str                  # train | prefill | decode
    fn: Any                    # the function to jit
    args: tuple                # ShapeDtypeStruct args
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple = ()


def params_sds(cfg: ModelConfig) -> Any:
    return jax.eval_shape(
        lambda k: model_lib.init_params(cfg, k), jax.random.PRNGKey(0))


def make_lowering_spec(cfg: ModelConfig, shape: InputShape, mesh: Mesh, *,
                       microbatches: int | None = None,
                       tcfg: TrainConfig | None = None,
                       mode: str = "megatron") -> LoweringSpec:
    cfg = apply_overrides(cfg, shape)
    psds = params_sds(cfg)
    # zero_* activation sharding applies to train/prefill tracing only
    # (decode keeps the megatron/flash-decode layout).
    act_mode = mode if shape.kind in ("train", "prefill") else "megatron"
    act_mode = sh.resolve_mode(mesh, act_mode, shape.global_batch,
                               shape.seq_len)
    if act_mode == "zero_batch" and cfg.n_experts:
        # grouped-local MoE dispatch: one token group per device so the
        # argsort/scatter stay local and only the expert all-to-all crosses
        # devices (see models/moe.py docstring + §Perf).
        cfg = cfg.replace(moe_groups=int(mesh.devices.size))
    elif act_mode == "zero_seq" and cfg.n_experts:
        # groups align to (pod, data) batch rows; the sort spans the
        # model-sharded sequence within a row (16 devices, not 256+).
        cfg = cfg.replace(moe_groups=int(shape.global_batch))
    param_mode = "zero_seq" if act_mode == "zero_batch" else act_mode
    pspecs = sh.param_specs(psds, mesh=mesh, fsdp=(shape.kind == "train"),
                            mode=param_mode)
    block_specs = {k: pspecs[k] for k in ("blocks", "shared_attn", "encoder")
                   if isinstance(pspecs, dict) and k in pspecs}
    model_lib.set_activation_spec(sh.activation_spec(mesh, act_mode),
                                  block_specs or None,
                                  mesh if act_mode != "megatron" else None)
    pshard = sh.named(pspecs, mesh)

    if shape.kind == "train":
        from repro.train.train_step import make_train_step
        # zero modes shard activations over the whole mesh — the per-device
        # live set is already tiny, and each microbatch would re-gather
        # every ZeRO-sharded weight (measured ×n_mb collective traffic).
        mb = microbatches or (1 if act_mode != "megatron"
                              else default_microbatches(cfg))
        tcfg = tcfg or TrainConfig(microbatches=mb)
        opt_sds = jax.eval_shape(adamw.init, psds)
        # AdamWState: step is scalar; m/v mirror params
        opt_specs = type(opt_sds)(step=P(), m=pspecs, v=pspecs)
        opt_shard = sh.named(opt_specs, mesh)
        batch = batch_template(cfg, shape)
        bspecs = sh.data_specs(batch, mesh, mode=act_mode)
        bshard = sh.named(bspecs, mesh)
        fn = make_train_step(cfg, tcfg)
        return LoweringSpec(
            kind="train", fn=fn,
            args=(psds, opt_sds, batch),
            in_shardings=(pshard, opt_shard, bshard),
            out_shardings=(pshard, opt_shard, None),
            donate_argnums=(0, 1))

    # Inference: serve-mode parameters are bf16, model-sharded, replicated
    # over the batch axes.
    serve_psds = jax.tree.map(
        lambda x: SDS(x.shape, jnp.bfloat16 if x.dtype == jnp.float32 else x.dtype),
        psds)
    serve_pspecs = sh.param_specs(serve_psds, mesh=mesh, fsdp=False)
    serve_pshard = sh.named(serve_pspecs, mesh)

    if shape.kind == "prefill":
        batch = batch_template(cfg, shape)
        bshard = sh.named(sh.data_specs(batch, mesh, mode=act_mode), mesh)

        def prefill_fn(params, batch):
            return model_lib.prefill(cfg, params, batch, shape.seq_len)

        return LoweringSpec(
            kind="prefill", fn=prefill_fn,
            args=(serve_psds, batch),
            in_shardings=(serve_pshard, bshard),
            out_shardings=None)

    # decode
    cache_sds = jax.eval_shape(
        lambda: model_lib.init_cache(cfg, shape.global_batch, shape.seq_len))
    cshard = sh.named(sh.cache_specs(cache_sds, mesh), mesh)
    tokens = SDS((shape.global_batch, 1), jnp.int32)
    tok_shard = sh.named(sh.data_specs({"t": tokens}, mesh), mesh)["t"]

    def decode_fn(params, cache, tokens):
        return model_lib.decode_step(cfg, params, cache, tokens)

    return LoweringSpec(
        kind="decode", fn=decode_fn,
        args=(serve_psds, cache_sds, tokens),
        in_shardings=(serve_pshard, cshard, tok_shard),
        out_shardings=(None, cshard),
        donate_argnums=(1,))


def lower(spec: LoweringSpec):
    jitted = jax.jit(spec.fn, in_shardings=spec.in_shardings,
                     out_shardings=spec.out_shardings,
                     donate_argnums=spec.donate_argnums)
    return jitted.lower(*spec.args)
