"""Model / workload configuration system.

Every assigned architecture is a ``ModelConfig`` (exact hyper-parameters
from its source paper / model card, cited in the per-arch module).  Configs
are plain frozen dataclasses — hashable, so they can be static jit args —
and carry everything the model builder, trainer, server, dry-run and
roofline need.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads

    # attention options
    rope_theta: float = 1e4
    qk_norm: bool = False            # qwen3
    attn_bias: bool = False          # qwen2 QKV bias
    sliding_window: int = 0          # 0 = full attention; mixtral: 4096

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance loss weight
    moe_groups: int = 0              # grouped-local dispatch (0/1 = global
                                     # sort; zero modes set = mesh size)

    # SSM / linear attention
    ssm_kind: str = ""               # "rwkv6" | "mamba2"
    ssm_state: int = 64              # state dim per head (mamba2 d_state)
    ssm_heads: int = 0               # 0 -> derived
    ssm_conv: int = 4                # mamba short conv width

    # hybrid (zamba2): one SHARED attention block applied every N ssm layers
    attn_every: int = 0

    # encoder-decoder (whisper): n_layers is the decoder depth
    encoder_layers: int = 0
    n_frames: int = 0                # audio stub frames (post-conv)

    # VLM: stub patch embeddings at the vision encoder's output width
    n_patches: int = 0
    vision_dim: int = 0

    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    citation: str = ""

    # ------------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so it shards over any mesh axis
        we use (logits for padding ids are masked to -inf in the loss)."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True iff decode at 500k tokens is sub-quadratic / bounded-state:
        SSM (constant state), hybrid (windowed attention at that shape), or
        native sliding-window attention."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window > 0)

    @property
    def has_decode(self) -> bool:
        """Encoder-only models have no decode step; all assigned archs here
        are decoders or enc-dec, so this is True throughout — kept for the
        config contract."""
        return True

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Parameter count (embedding included once; used for MODEL_FLOPS).
    def param_count(self) -> int:
        from repro.models.model import count_params_analytic
        return count_params_analytic(self)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top_k experts count)."""
        from repro.models.model import count_params_analytic
        return count_params_analytic(self, active_only=True)


@dataclass(frozen=True)
class InputShape:
    """One of the assigned workload shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Reduced variant of the same family for CPU smoke tests:
    2 layers, d_model ≤ 512, ≤ 4 experts — per the assignment contract."""
    d_model = min(cfg.d_model, 256)
    n_heads = min(cfg.n_heads, 4)
    if n_heads:
        n_kv = max(1, min(cfg.n_kv_heads, n_heads))
        while n_heads % n_kv:
            n_kv -= 1
    else:
        n_kv = 0
    kw = dict(
        n_layers=2,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=d_model // n_heads if n_heads else 0,
        d_ff=min(cfg.d_ff, 512),
        vocab_size=min(cfg.vocab_size, 1024),
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
    )
    if cfg.n_experts:
        kw["n_experts"] = min(cfg.n_experts, 4)
        kw["top_k"] = min(cfg.top_k, 2)
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
        kw["n_frames"] = 16
    if cfg.n_patches:
        kw["n_patches"] = 8
        kw["vision_dim"] = min(cfg.vision_dim, 64)
    if cfg.ssm_kind:
        kw["ssm_state"] = min(cfg.ssm_state, 16)
        kw["ssm_heads"] = min(cfg.ssm_heads or 4, 4)
    if cfg.attn_every:
        kw["attn_every"] = 1
    return cfg.replace(**kw)
