"""Qwen2 1.5B — dense GQA with QKV bias [arXiv:2407.10671]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936,
    attn_bias=True, rope_theta=1e6,
    citation="[arXiv:2407.10671]",
)
