"""RWKV-6 (Finch) 3B — attention-free, data-dependent decay
[arXiv:2404.05892]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=0, n_kv_heads=0,
    d_ff=8960, vocab_size=65536,
    ssm_kind="rwkv6", ssm_heads=40, ssm_state=64,  # 40 heads x 64 head_dim
    citation="[arXiv:2404.05892]",
)
