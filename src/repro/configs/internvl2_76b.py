"""InternVL2 76B — InternViT (STUB) + InternLM2-76B language backbone
[arXiv:2404.16821].

The vision encoder is a stub per the assignment carve-out: input_specs()
provides 256 patch embeddings at the ViT output width (3200); the MLP
projector into the LM and the 80-layer language model are real."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256,
    n_patches=256, vision_dim=3200,
    rope_theta=1e6,
    citation="[arXiv:2404.16821]",
)
