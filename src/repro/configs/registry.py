"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from repro.configs import (internvl2_76b, mixtral_8x7b, phi35_moe, qwen2_1_5b,
                           qwen3_14b, rwkv6_3b, smollm_360m, stablelm_1_6b,
                           whisper_large_v3, zamba2_2_7b)
from repro.configs.base import ModelConfig

ARCHITECTURES: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (mixtral_8x7b, phi35_moe, smollm_360m, stablelm_1_6b,
              whisper_large_v3, qwen3_14b, rwkv6_3b, zamba2_2_7b,
              internvl2_76b, qwen2_1_5b)
}


def get(arch: str) -> ModelConfig:
    if arch not in ARCHITECTURES:
        raise KeyError(
            f"unknown arch {arch!r}; available: {sorted(ARCHITECTURES)}")
    return ARCHITECTURES[arch]
