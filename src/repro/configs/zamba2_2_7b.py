"""Zamba2 2.7B — Mamba2 backbone + SHARED attention block applied
periodically [arXiv:2411.15242].

54 Mamba2 layers; one shared (weight-tied) attention+MLP block is applied
every 6 SSM layers.  At the long_500k shape the shared attention uses a
4096 sliding window (DESIGN.md §4)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000,
    ssm_kind="mamba2", ssm_state=64, ssm_heads=40, ssm_conv=4,
    attn_every=6,
    citation="[arXiv:2411.15242]",
)
