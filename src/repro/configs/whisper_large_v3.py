"""Whisper large-v3 — encoder-decoder ASR backbone [arXiv:2212.04356].

The mel-spectrogram + conv feature extractor is a STUB (assignment
carve-out): input_specs() provides post-conv frame embeddings (1500, 1280);
the encoder and decoder transformers are real.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120, vocab_size=51866,
    encoder_layers=32, n_frames=1500,
    citation="[arXiv:2212.04356]",
)
