"""StableLM 2 1.6B — dense, MHA (kv=heads) [hf:stabilityai/stablelm-2-1_6b]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=5632, vocab_size=100352,
    rope_theta=1e4,
    citation="[hf:stabilityai/stablelm-2-1_6b]",
)
