"""Train a language model end-to-end with the full training substrate:
any assigned architecture (``--arch``), microbatched AdamW, remat, chunked
vocab-sharded loss, checkpointing, and the optional stale-synchronous
filtered gradient sync (the paper's PS pattern applied to training).

    # CI-sized run (reduced config, converges visibly in ~60 steps):
    PYTHONPATH=src python examples/train_lm.py --steps 60

    # ~100M-parameter run (the deliverable-scale driver; slow on CPU):
    PYTHONPATH=src python examples/train_lm.py --arch smollm-360m \
        --preset 100m --steps 300 --batch 8 --seq 512

    # paper-pattern sync: 2 simulated clients, top-k filtered, staleness 2:
    PYTHONPATH=src python examples/train_lm.py --stale-sync --clients 2
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import reduced
from repro.configs.registry import ARCHITECTURES
from repro.core import ps
from repro.data.synthetic import lm_batches
from repro.models import model as model_lib
from repro.optim import adamw
from repro.train import sync as sync_lib
from repro.train.train_step import TrainConfig, loss_fn, make_train_step


def pick_config(args):
    cfg = ARCHITECTURES[args.arch]
    if args.preset == "tiny":
        cfg = reduced(cfg).replace(vocab_size=min(512, cfg.vocab_size))
    elif args.preset == "100m":
        # ~100M params of the same family (smollm-360m at 16 layers ≈ 100M
        # non-embedding + embeddings).
        cfg = cfg.replace(n_layers=min(cfg.n_layers, 16),
                          vocab_size=min(cfg.vocab_size, 16384))
    return cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m",
                    choices=sorted(ARCHITECTURES))
    ap.add_argument("--preset", choices=["tiny", "100m", "full"],
                    default="tiny")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--stale-sync", action="store_true",
                    help="PS-pattern gradient sync (filtered, stale)")
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--sync-every", type=int, default=2)
    args = ap.parse_args()

    cfg = pick_config(args)
    n_params = cfg.param_count()
    print(f"arch={cfg.name} preset={args.preset} params≈{n_params / 1e6:.1f}M "
          f"batch={args.batch}x{args.seq}")

    tcfg = TrainConfig(peak_lr=args.lr, warmup=min(10, args.steps // 5),
                       total_steps=args.steps,
                       microbatches=args.microbatches,
                       loss_chunk=min(512, args.seq))
    key = jax.random.PRNGKey(0)
    params = model_lib.init_params(cfg, key)
    opt = adamw.init(params)

    data = lm_batches(cfg.vocab_size, args.batch, args.seq, args.steps,
                      seed=1, kind="affine")

    if not args.stale_sync:
        step_fn = jax.jit(make_train_step(cfg, tcfg))
        t0 = time.time()
        for step, batch in enumerate(data):
            batch = {"tokens": jnp.asarray(batch["tokens"])}
            params, opt, metrics = step_fn(params, opt, batch)
            if step % 10 == 0 or step == args.steps - 1:
                print(f"step {step:4d}  loss={float(metrics['loss']):7.4f}  "
                      f"lr={float(metrics['lr']):.2e}  "
                      f"gnorm={float(metrics['grad_norm']):7.3f}  "
                      f"{(step + 1) * args.batch * args.seq / (time.time() - t0):.0f} tok/s")
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                path = ckpt.save(args.ckpt_dir, cfg.name, step + 1,
                                 {"params": params, "opt": opt._asdict()})
                print(f"  checkpoint: {path}")
        return

    # ---- stale-synchronous PS-pattern training (paper §5.3 on gradients) --
    scfg = sync_lib.SyncConfig(
        sync_every=args.sync_every,
        filter=ps.FilterSpec(kind="topk", k_rows=64, random_rows=16))
    grad_fn = jax.jit(jax.value_and_grad(
        lambda p, b: loss_fn(cfg, tcfg, p, b)[0]))
    residuals = [jax.tree.map(jnp.zeros_like, params)
                 for _ in range(args.clients)]
    t0 = time.time()
    for step, batch in enumerate(data):
        toks = batch["tokens"]
        shard = max(1, toks.shape[0] // args.clients)
        losses, grads_sum = [], None
        for c in range(args.clients):
            b = {"tokens": jnp.asarray(toks[c * shard:(c + 1) * shard])}
            l, g = grad_fn(params, b)
            losses.append(float(l))
            residuals[c] = jax.tree.map(jnp.add, residuals[c], g)
        if (step + 1) % scfg.sync_every == 0:
            for c in range(args.clients):
                kf = jax.random.fold_in(key, step * 31 + c)
                sent = sync_lib.filter_tree(residuals[c], scfg.filter, kf)
                residuals[c] = jax.tree.map(lambda r, s: r - s,
                                            residuals[c], sent)
                grads_sum = sent if grads_sum is None else jax.tree.map(
                    jnp.add, grads_sum, sent)
            grads = jax.tree.map(
                lambda g: g / (args.clients * scfg.sync_every), grads_sum)
            lr = adamw.cosine_schedule(opt.step, peak_lr=tcfg.peak_lr,
                                       warmup=tcfg.warmup,
                                       total=tcfg.total_steps)
            params, opt = adamw.update(params, grads, opt, lr=lr,
                                       weight_decay=tcfg.weight_decay,
                                       grad_clip=tcfg.grad_clip)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d}  loss={np.mean(losses):7.4f}  "
                  f"({time.time() - t0:.1f}s)")
    dense_b, filt_b = sync_lib.sync_bytes_estimate(params, scfg.filter)
    print(f"sync traffic: {filt_b / scfg.sync_every / 1e6:.2f} MB/step "
          f"filtered vs {dense_b / 1e6:.2f} MB/step dense "
          f"({dense_b / (filt_b / scfg.sync_every):.1f}x reduction)")


if __name__ == "__main__":
    main()
