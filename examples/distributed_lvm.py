"""End-to-end driver: the paper's full distributed system — multi-client
parameter-server inference for LDA / PDP / HDP with eventual consistency,
communication filters, constraint projection, snapshots and failover —
through the unified ``engine.Trainer`` / ModelFamily API.

    PYTHONPATH=src python examples/distributed_lvm.py --model pdp --clients 4
    PYTHONPATH=src python examples/distributed_lvm.py --model lda \
        --filter topk --fail-client 1
    PYTHONPATH=src python examples/distributed_lvm.py --model hdp \
        --layout sorted

On a real TPU mesh the same rounds run under shard_map via
``repro.core.distributed.make_round_fn`` (clients = data-axis shards,
server = model-axis row sharding) against the same family registry; this
example drives the identical logic client-by-client so it runs anywhere,
and exercises:

  - τ local sweeps against a frozen snapshot (bounded staleness, §5.2-5.3),
  - the explicit parameter server with a pluggable consistency policy
    (``--consistency bsp|ssp:2|async``) over vocabulary-sharded state
    (``--server-shards``; DESIGN.md §9),
  - scan-oracle or token-sorted tile-skipping layout (``--layout``),
  - magnitude-priority + uniform-sampling delta filters (§5.3),
  - constraint projection on shared AND client-local polytopes (§5.5),
  - fault injection with kill-and-rejoin recovery from periodic
    snapshots (``--fail-client`` builds a ``core.fault.FaultPlan`` crash
    window and enables ``snapshot_every``, so the crashed client rejoins
    mid-run by restoring its locals and taking a forced-fresh pull —
    §5.4; add ``--chaos-seed`` for a seeded-random multi-fault plan).
"""

from __future__ import annotations

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.core import hdp, lda, pdp, ps
from repro.core.fault import FaultPlan
from repro.data.synthetic import CorpusConfig, make_topic_corpus
from repro.engine import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=["lda", "pdp", "hdp"], default="pdp")
    ap.add_argument("--layout", choices=["scan", "sorted"], default="scan")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--tau", type=int, default=2,
                    help="local sweeps per sync round (staleness)")
    ap.add_argument("--consistency", default="bsp",
                    help="server policy: bsp | ssp:<bound> | async")
    ap.add_argument("--server-shards", type=int, default=1,
                    help="vocabulary shards of the server's canonical "
                         "statistics")
    ap.add_argument("--filter", choices=["dense", "topk"], default="dense")
    ap.add_argument("--fail-client", type=int, default=-1,
                    help="client id to crash mid-run and rejoin from its "
                         "snapshot (§5.4 kill-and-rejoin demo)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="seeded-random multi-fault plan (crashes, "
                         "stragglers, lost pushes, failed pulls)")
    ap.add_argument("--snapshot-dir", default=None)
    args = ap.parse_args()

    tokens, mask, _ = make_topic_corpus(CorpusConfig(
        n_topics=8, vocab_size=400, n_docs=256, doc_len=64, seed=0))
    tokens, mask = jnp.asarray(tokens), jnp.asarray(mask)

    if args.model == "lda":
        cfg = lda.LDAConfig(n_topics=8, vocab_size=400, mh_steps=2)
    elif args.model == "pdp":
        cfg = pdp.PDPConfig(n_topics=8, vocab_size=400, alpha=0.1,
                            discount=0.1, concentration=5.0, mh_steps=4,
                            stirling_n_max=256)
    else:
        cfg = hdp.HDPConfig(n_topics=16, vocab_size=400, b0=1.0, b1=2.0,
                            mh_steps=4)

    fspec = (ps.FilterSpec(kind="topk", k_rows=50, random_rows=12)
             if args.filter == "topk" else ps.FilterSpec())
    plan = None
    if args.chaos_seed is not None:
        plan = FaultPlan.random(args.chaos_seed, args.clients, args.rounds,
                                p_crash=0.05, p_straggle=0.05,
                                p_lost_push=0.05, p_failed_pull=0.03)
    elif args.fail_client >= 0:
        plan = FaultPlan.crash(args.fail_client, args.rounds // 3,
                               2 * args.rounds // 3)
    # Periodic snapshots back the rejoin protocol (and Trainer.restore).
    snap_dir = args.snapshot_dir or tempfile.mkdtemp(prefix="lvm_snap_")

    print(f"model={args.model} layout={args.layout} clients={args.clients} "
          f"tau={args.tau} consistency={args.consistency} "
          f"server_shards={args.server_shards} filter={args.filter} "
          f"faults={len(plan.events) if plan else 0} snapshots={snap_dir}")
    t0 = time.time()
    trainer = Trainer(cfg, tokens, mask, config=TrainerConfig(
        layout=args.layout, n_clients=args.clients, tau=args.tau,
        consistency=args.consistency, n_server_shards=args.server_shards,
        filter=fspec, fault_plan=plan,
        snapshot_every=max(2, args.rounds // 4), snapshot_dir=snap_dir))
    res = trainer.run(args.rounds, eval_every=max(1, args.rounds // 6))
    for i, ppl in enumerate(res.perplexities):
        print(f"eval {i}: perplexity={ppl:9.2f}"
              f"  violations={res.violations[i]:.0f}")
    if plan:
        print(f"rejoins={trainer.rejoins} pull_failures="
              f"{trainer.pull_failures}")
    print(f"total {time.time() - t0:.1f}s, "
          f"~{res.tokens_per_s / 1e3:.1f}k tokens/s/round")

    # Record the run's summary curves next to the Trainer's snapshots.
    path = ckpt.save(snap_dir, f"{args.model}_run", args.rounds, {
        "perplexities": np.asarray(res.perplexities),
        "iter_times": np.asarray(res.iter_times),
    })
    print(f"snapshot written: {path}")


if __name__ == "__main__":
    main()
