"""Online topic inference end to end: train → freeze → fold in new
documents through the slot-based continuous-batching engine
(DESIGN.md §14).

    PYTHONPATH=src python examples/serve_topics.py --model lda --docs 8
    PYTHONPATH=src python examples/serve_topics.py --model hdp \
        --docs 12 --sweeps 8 --service

Trains a small model with ``engine.Trainer``, freezes the shared
statistics + alias tables into an immutable
:class:`repro.serve.InferenceSnapshot`, then folds held-out documents in:

  - in-process through :class:`repro.serve.FoldInEngine` (admit → fused
    local-only sweeps across all live slots → harvest θ_d),
  - with ``--service``, additionally over loopback TCP through
    ``repro.serve.server`` + two concurrent ``InferenceClient``
    connections, and checks the served results land bit-identically on
    the in-process ones (the §14 determinism contract: each document's
    chain depends only on (snapshot, tokens, request seed), never on
    batch composition).

One document is also re-derived through :func:`reference_fold_in` — the
training ``family.sweep`` path with pushes dropped — and compared
bit-for-bit.
"""

from __future__ import annotations

import argparse
import threading
import time

import jax
import numpy as np

from repro.core import family as fam_mod
from repro.data.synthetic import CorpusConfig, make_topic_corpus
from repro.engine import Trainer, TrainerConfig
from repro.serve import (FoldInEngine, InferRequest, ServeConfig,
                         fold_in_perplexity, from_trainer,
                         reference_fold_in, result_checksum)
from repro.serve.client import InferenceClient
from repro.serve.engine import InferResult
from repro.serve.server import InferenceServer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="lda",
                    choices=sorted(fam_mod.FAMILIES))
    ap.add_argument("--docs", type=int, default=8,
                    help="held-out documents to fold in")
    ap.add_argument("--sweeps", type=int, default=5,
                    help="local MHW sweeps per document")
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent documents per fused sweep")
    ap.add_argument("--rounds", type=int, default=4,
                    help="training rounds before freezing")
    ap.add_argument("--vocab", type=int, default=400)
    ap.add_argument("--topics", type=int, default=8)
    ap.add_argument("--doc-len", type=int, default=48)
    ap.add_argument("--service", action="store_true",
                    help="also serve over loopback TCP with two "
                         "concurrent clients")
    args = ap.parse_args()

    fam = fam_mod.get(args.model)
    cfg = fam.config_cls(n_topics=args.topics, vocab_size=args.vocab)
    tokens, mask, _ = make_topic_corpus(CorpusConfig(
        n_topics=args.topics, vocab_size=args.vocab,
        n_docs=64 + args.docs, doc_len=args.doc_len, seed=0))

    print(f"training {args.model} (V={args.vocab}, K={args.topics}) "
          f"for {args.rounds} rounds ...")
    trainer = Trainer(cfg, tokens[:64], mask[:64],
                      config=TrainerConfig(n_clients=1),
                      key=jax.random.PRNGKey(0))
    trainer.run(args.rounds, eval_every=args.rounds + 1)
    snap = from_trainer(trainer)
    print(f"frozen snapshot: family={snap.family_name} "
          f"V={snap.vocab_size} K={snap.n_topics}")

    ho_tokens = np.asarray(tokens[64:])
    ho_mask = np.asarray(mask[64:], bool)
    lens = ho_mask.sum(axis=1).astype(int)
    reqs = [InferRequest(uid=i, tokens=ho_tokens[i, :lens[i]],
                        seed=100 + i) for i in range(args.docs)]

    scfg = ServeConfig(max_slots=args.slots, max_len=args.doc_len,
                       n_sweeps=args.sweeps)
    eng = FoldInEngine(snap, scfg)
    t0 = time.time()
    results = eng.run(reqs)
    dt = time.time() - t0
    print(f"folded {len(results)} docs in {dt:.1f}s "
          f"({len(results) / dt:.2f} docs/s, "
          f"{eng.sweeps_run} fused sweeps)")
    for i in range(min(3, args.docs)):
        top = np.argsort(results[i].theta)[::-1][:3]
        print(f"  doc {i}: top topics {top.tolist()} "
              f"theta {np.round(results[i].theta[top], 3).tolist()}")

    ppl = fold_in_perplexity(
        snap, np.stack([results[i].theta for i in range(args.docs)]),
        ho_tokens[:args.docs], ho_mask[:args.docs])
    print(f"fold-in held-out perplexity: {ppl:.2f}")

    # Determinism: the engine's batched chain == the training code path
    # on a single document with pushes dropped.
    _, theta, z = reference_fold_in(snap, reqs[0].tokens, reqs[0].seed,
                                    n_sweeps=args.sweeps,
                                    max_len=args.doc_len)
    ref = InferResult(uid=0, theta=theta, assignments=z,
                      n_sweeps=args.sweeps)
    ok = result_checksum(ref) == result_checksum(results[0])
    print(f"reference_fold_in parity: {'bit-exact' if ok else 'DIVERGED'}")
    assert ok

    if args.service:
        server = InferenceServer(snap, scfg).start()
        addr = "%s:%d" % server.address
        served: dict[int, InferResult] = {}
        lock = threading.Lock()

        def client_main(part: list[InferRequest]) -> None:
            with InferenceClient(addr, timeout=300.0) as cli:
                for r in part:
                    res = cli.infer(r.uid, r.tokens, seed=r.seed)
                    with lock:
                        served[res.uid] = res

        try:
            threads = [threading.Thread(target=client_main, args=(p,))
                       for p in (reqs[0::2], reqs[1::2])]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            stats = server.stats()
        finally:
            server.close()
        agree = all(result_checksum(served[i]) == result_checksum(results[i])
                    for i in range(args.docs))
        print(f"service over loopback: {len(served)} docs via 2 clients, "
              f"p50 {stats['latency_p50_ms']:.1f} ms, "
              f"p99 {stats['latency_p99_ms']:.1f} ms, "
              f"{'bit-exact' if agree else 'DIVERGED'} vs in-process")
        assert agree


if __name__ == "__main__":
    main()
