"""Quickstart: AliasLDA (the paper's Metropolis-Hastings-Walker sampler) on
a synthetic power-law corpus, single client.

    PYTHONPATH=src python examples/quickstart.py

Walks the public API end to end: corpus → init → alias tables → MHW Gibbs
sweeps → perplexity + topics/word, with the alias-table staleness cadence
(`alias_refresh_every`) exposed — the l/n refresh rule of paper §3.3.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import lda
from repro.data.synthetic import CorpusConfig, make_topic_corpus


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--topics", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=500)
    ap.add_argument("--docs", type=int, default=256)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--method", choices=["mhw", "exact"], default="mhw")
    ap.add_argument("--alias-refresh-every", type=int, default=2,
                    help="Gibbs sweeps between alias-table rebuilds (staleness)")
    args = ap.parse_args()

    tokens, mask, _ = make_topic_corpus(CorpusConfig(
        n_topics=args.topics, vocab_size=args.vocab, n_docs=args.docs,
        doc_len=64, seed=0))
    tokens, mask = jnp.asarray(tokens), jnp.asarray(mask)
    n_tokens = int(mask.sum())
    print(f"corpus: {args.docs} docs, {n_tokens} tokens, "
          f"V={args.vocab}, K={args.topics}")

    cfg = lda.LDAConfig(n_topics=args.topics, vocab_size=args.vocab,
                        alpha=0.1, beta=0.01, mh_steps=2)
    key = jax.random.PRNGKey(0)
    local, shared = lda.init_state(cfg, tokens, mask, key)

    tables = stale = None
    for it in range(args.iters):
        t0 = time.perf_counter()
        if tables is None or it % args.alias_refresh_every == 0:
            tables, stale = lda.build_alias(cfg, shared)  # producer side
        local, dwk, dk = lda.sweep(cfg, local, shared, tables, stale, tokens,
                                   mask, jax.random.fold_in(key, it),
                                   method=args.method)
        shared = lda.apply_delta(shared, dwk, dk)
        jax.block_until_ready(shared.n_wk)
        dt = time.perf_counter() - t0
        if it % 5 == 0 or it == args.iters - 1:
            ppl = float(lda.perplexity(cfg, shared, tokens[:32], mask[:32],
                                       jax.random.PRNGKey(42)))
            tpw = float(lda.topics_per_word(shared))
            print(f"iter {it:3d}  perplexity={ppl:8.2f}  topics/word={tpw:5.2f}"
                  f"  {n_tokens / dt / 1e3:8.1f}k tokens/s")

    print("done — consistency check:",
          "OK" if float(jnp.abs(lda.count_wk(cfg, tokens, local.z, mask)
                                - shared.n_wk).max()) == 0 else "VIOLATED")


if __name__ == "__main__":
    main()
