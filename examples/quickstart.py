"""Quickstart: the unified ModelFamily + Trainer API on a synthetic
power-law corpus — the paper's MHW sampler for any registered family.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --model pdp
    PYTHONPATH=src python examples/quickstart.py --model hdp --layout sorted

Walks the public API end to end: corpus → model config → ``engine.Trainer``
(pull → sample → filter → push → project rounds) → perplexity +
topics/word.  The Trainer owns the alias-table staleness cadence
(`alias_refresh_every`, the l/n refresh rule of paper §3.3) and the layout
selection: ``--layout sorted`` runs the token-sorted tile-skipping fused
kernels, ``--layout scan`` the sequential oracle.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.core import hdp, lda, pdp
from repro.data.synthetic import CorpusConfig, make_topic_corpus
from repro.engine import Trainer, TrainerConfig


def model_config(model: str, topics: int, vocab: int):
    """K is taken exactly as given (for HDP it is the truncation level —
    pass a value above the expected topic count, e.g. 2× the corpus's)."""
    if model == "lda":
        return lda.LDAConfig(n_topics=topics, vocab_size=vocab, alpha=0.1,
                             beta=0.01, mh_steps=2)
    if model == "pdp":
        return pdp.PDPConfig(n_topics=topics, vocab_size=vocab, alpha=0.1,
                             discount=0.1, concentration=5.0, mh_steps=4,
                             stirling_n_max=256)
    return hdp.HDPConfig(n_topics=topics, vocab_size=vocab, b0=1.0,
                         b1=2.0, mh_steps=4)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=["lda", "pdp", "hdp"], default="lda")
    ap.add_argument("--layout", choices=["scan", "sorted"], default="scan")
    ap.add_argument("--method", choices=["mhw", "exact"], default="mhw")
    ap.add_argument("--topics", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=500)
    ap.add_argument("--docs", type=int, default=256)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--clients", type=int, default=1)
    ap.add_argument("--alias-refresh-every", type=int, default=2,
                    help="rounds between alias-table rebuilds (staleness)")
    args = ap.parse_args()

    tokens, mask, _ = make_topic_corpus(CorpusConfig(
        n_topics=args.topics, vocab_size=args.vocab, n_docs=args.docs,
        doc_len=64, seed=0))
    tokens, mask = jnp.asarray(tokens), jnp.asarray(mask)
    n_tokens = int(mask.sum())
    cfg = model_config(args.model, args.topics, args.vocab)
    print(f"corpus: {args.docs} docs, {n_tokens} tokens, V={args.vocab}, "
          f"K={cfg.n_topics}, model={args.model}, layout={args.layout}")
    trainer = Trainer(cfg, tokens, mask, config=TrainerConfig(
        layout=args.layout, method=args.method, n_clients=args.clients,
        alias_refresh_every=args.alias_refresh_every),
        key=jax.random.PRNGKey(0))

    eval_every = max(1, args.iters // 4)
    res = trainer.run(args.iters, eval_every=eval_every, eval_docs=32)
    for i, ppl in enumerate(res.perplexities):
        tpw = res.topics_per_word[i]
        print(f"eval {i}: perplexity={ppl:8.2f}  topics/word={tpw:5.2f}")
    print(f"throughput: {res.tokens_per_s / 1e3:8.1f}k tokens/s")

    err = trainer.consistency_error()
    print("done — sufficient-statistics consistency:",
          "OK" if err == 0.0 else f"VIOLATED (max err {err})")


if __name__ == "__main__":
    main()
