"""Serve a small model with batched requests through the slot engine:
prefill → pooled single-token decode with a shared KV cache (the decode
dry-run shapes are this path at production scale).

    PYTHONPATH=src python examples/serve_lm.py --arch qwen2-1.5b \
        --requests 12 --batch 4 --max-new 24
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import reduced
from repro.configs.registry import ARCHITECTURES
from repro.models import model as model_lib
from repro.serve.engine import Engine, EngineConfig, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b",
                    choices=sorted(ARCHITECTURES))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--sample", action="store_true")
    args = ap.parse_args()

    cfg = reduced(ARCHITECTURES[args.arch])
    params = model_lib.init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(batch=args.batch,
                        max_len=args.prompt_len + args.max_new + 8,
                        greedy=not args.sample)
    engine = Engine(cfg, params, ecfg)

    rng = np.random.default_rng(0)
    extra = None
    if cfg.family == "vlm":
        def extra(req):
            return {"patch_embeds": jax.numpy.asarray(
                rng.standard_normal((1, cfg.n_patches, cfg.vision_dim),
                                    np.float32))}
    if cfg.family == "audio":
        def extra(req):
            return {"frames": jax.numpy.asarray(
                rng.standard_normal((1, cfg.n_frames, cfg.d_model),
                                    np.float32))}

    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, args.prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]

    t0 = time.time()
    done = engine.run(reqs, extra_inputs=extra)
    dt = time.time() - t0
    total_new = sum(len(r.output) for r in done)
    print(f"arch={cfg.name} served {len(done)} requests, "
          f"{total_new} tokens in {dt:.1f}s "
          f"({total_new / dt:.1f} tok/s pooled decode)")
    for r in done[:4]:
        print(f"  req {r.uid}: {r.output[:10]}...")
    assert len(done) == args.requests


if __name__ == "__main__":
    main()
