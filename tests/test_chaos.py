"""Chaos-proxy determinism and recovery (DESIGN.md §13).

The proxy's actions are a pure function of (plan, connection ordinal,
frame ordinal), so replaying a scripted schedule twice must corrupt
exactly the same frames — giving byte-identical server stores and
identical client retry counts.  And because every mutation is
idempotent (seq-dedup on the server, bounded retry + replay on the
client), a run through scheduled drops/truncations/delays must still
finish with the exact no-failure sum.

These are the transport-level complements of the placement-fuzz cases
in tests/test_wire_protocol.py: the protocol tests corrupt encodings,
the chaos tests corrupt *delivery*.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import family as fam_mod
from repro.core.fault import FaultEvent, FaultPlan
from repro.net.chaos import ChaosProxy, interpose
from repro.net.client import RemoteParameterServer, stress_delta
from repro.net.server import serve_shards

TIMEOUT = 30.0
SHAPE = (64, 4)


def _zero_shared():
    fam = fam_mod.get("lda")
    n_wk = np.zeros(SHAPE, np.float32)
    return fam.shared_from_dict({"n_wk": n_wk, "n_k": n_wk.sum(0)})


def _want(rounds: int) -> np.ndarray:
    want = np.zeros(SHAPE, np.float32)
    for r in range(rounds):
        want = want + stress_delta(r, 0, SHAPE)
    return want


def _run_through_chaos(plan, rounds: int = 3):
    """One single-client stress run through a proxied shard; returns
    (store bytes, client counters, proxy stats)."""
    servers = serve_shards("lda", vocab_size=64, n_clients=1,
                           barrier_timeout=TIMEOUT)
    addrs = ["%s:%d" % s.address for s in servers]
    proxied, proxies = interpose(addrs, plan)
    rps = RemoteParameterServer(proxied, family="lda", n_clients=1,
                                vocab_size=64, timeout=TIMEOUT,
                                reconnect_limit=10, local_clients=(0,))
    try:
        rps.init_push(0, _zero_shared())
        for r in range(rounds):
            rps.pull(r)
            rps.push(r, 0, {"n_wk": stress_delta(r, 0, SHAPE)})
        rps.clock(min_round=rounds)
        store = rps.pull_keys(["n_wk"])["n_wk"].tobytes()
        counters = rps.counters()
    finally:
        rps.close()
        for p in proxies:
            p.close()
        for s in servers:
            s.close()
    stats = [p.stats() for p in proxies]
    return store, counters, stats


# Frame ordinals (see chaos.py): HELLO=0, INIT=1, PULL(r)=2+2r,
# PUSH(r)=3+2r.  Connection ordinal 0 is the original link; each
# reconnect gets the next ordinal, so a drop aimed at one ordinal fires
# exactly once.
SCHEDULE = FaultPlan.scripted(
    # Every connection's HELLO is delayed — latency without loss.
    FaultEvent("delay", client=-1, start=0, stop=1, period=1,
               magnitude=0.01),
    # The original connection loses its round-0 push on the wire.
    FaultEvent("conn_drop", client=0, start=3, stop=4, period=1),
    # The first reconnect's retried push is cut mid-payload.
    FaultEvent("frame_truncate", client=1, start=2, stop=3, period=1,
               magnitude=0.5),
)


def test_chaos_run_recovers_to_exact_sum():
    """Drops, truncations, and delays on the mutation path change
    nothing about the final store — idempotent replay absorbs them."""
    store, counters, stats = _run_through_chaos(SCHEDULE)
    assert store == _want(3).tobytes()
    # One retry per severed link, one reconnect per retry that re-dialed.
    assert counters["retries"] >= 2
    assert counters["reconnects"] >= 2
    acts = stats[0]["actions"]
    assert acts["conn_drop"] == 1 and acts["frame_truncate"] == 1
    assert acts["delay"] == stats[0]["connections"]


def test_chaos_schedule_replay_is_deterministic():
    """The same scripted schedule replayed against a fresh server:
    byte-identical store, identical retry/reconnect counts, identical
    proxy action counts — the property that makes chaos runs debuggable."""
    store_a, counters_a, stats_a = _run_through_chaos(SCHEDULE)
    store_b, counters_b, stats_b = _run_through_chaos(SCHEDULE)
    assert store_a == store_b
    assert counters_a["retries"] == counters_b["retries"]
    assert counters_a["reconnects"] == counters_b["reconnects"]
    assert [s["actions"] for s in stats_a] == \
           [s["actions"] for s in stats_b]
    assert [s["connections"] for s in stats_a] == \
           [s["connections"] for s in stats_b]


def test_chaos_passthrough_is_invisible():
    """A proxy with no scheduled events is a pure relay: exact sum, no
    retries, no actions."""
    store, counters, stats = _run_through_chaos(FaultPlan.none())
    assert store == _want(3).tobytes()
    assert counters["retries"] == 0 and counters["reconnects"] == 0
    assert all(v == 0 for v in stats[0]["actions"].values())
    assert stats[0]["frames_forwarded"] > 0


@pytest.mark.parametrize("magnitude", [0.0, 0.25, 0.75])
def test_chaos_truncation_fuzz_placement(magnitude):
    """Placement fuzz: cutting the round-0 push at different payload
    fractions (header-only through nearly-whole) always yields a clean
    frame loss — never a corrupt application — and the retry completes
    the exact sum."""
    plan = FaultPlan.scripted(
        FaultEvent("frame_truncate", client=0, start=3, stop=4, period=1,
                   magnitude=magnitude))
    store, counters, stats = _run_through_chaos(plan)
    assert store == _want(3).tobytes()
    assert counters["retries"] >= 1
    assert stats[0]["actions"]["frame_truncate"] == 1


def test_round_kind_events_stay_with_the_trainer():
    """One FaultPlan can mix round-level kinds (the trainer's) with
    network kinds (the proxy's): the proxy takes only its own."""
    plan = FaultPlan.scripted(
        FaultEvent("crash", client=0, start=0, stop=1),
        FaultEvent("delay", client=-1, start=0, stop=1, period=1,
                   magnitude=0.01))
    proxy = ChaosProxy("127.0.0.1:1", plan)
    try:
        assert [e.kind for e in proxy.events] == ["delay"]
    finally:
        proxy.close()
