"""Transport parity: the tcp backend vs the in-process ParameterServer.

BSP over loopback TCP must be *bit-exact* with the in-process reference
(same corpus, same key, same round count) — the acceptance criterion of
DESIGN.md §11.  SSP stays within mass-conservation and perplexity
tolerance.  The stress tests hammer a live server from threads and check
the final store is exactly init + Σ deltas.
"""

from __future__ import annotations

import threading

import jax
import numpy as np
import pytest

from repro.core import family as fam_mod
from repro.engine.trainer import Trainer, TrainerConfig
from repro.net.client import RemoteParameterServer, stress_delta
from repro.net.server import ShardServer, serve_shards
from tests.conftest import make_family_cfg, make_synthetic_corpus

TIMEOUT = 30.0


def _corpus():
    return make_synthetic_corpus(n_topics=4, vocab=64, n_docs=16,
                                 doc_len=12, seed=3)


def _stats(family_name, trainer):
    return {n: np.asarray(v) for n, v in
            fam_mod.get(family_name).stats_dict(trainer.shared).items()}


def _run_ref(cfg, tokens, mask, *, n_clients, rounds, consistency="bsp",
             tau=1):
    t = Trainer(cfg, tokens, mask, key=jax.random.PRNGKey(0),
                config=TrainerConfig(n_clients=n_clients, tau=tau,
                                     consistency=consistency))
    for _ in range(rounds):
        t.step()
    return t


def _servers(family_name, *, n_clients, n_shards=1, consistency="bsp",
             vocab_size=64):
    return serve_shards(family_name, vocab_size=vocab_size,
                        n_clients=n_clients, n_shards=n_shards,
                        consistency=consistency, barrier_timeout=TIMEOUT)


def _addrs(servers):
    return tuple("%s:%d" % s.address for s in servers)


# ---------------------------------------------------------------------------
# Trainer-level parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family_name", ["lda", "pdp"])
def test_bsp_tcp_bitexact_single_worker(family_name):
    """One tcp Trainer hosting every client == in-process, bit for bit."""
    tokens, mask, _ = _corpus()
    cfg = make_family_cfg(family_name, n_topics=4, vocab_size=64)
    ref = _run_ref(cfg, tokens, mask, n_clients=2, rounds=3)
    want = _stats(family_name, ref)

    servers = _servers(family_name, n_clients=2, n_shards=2)
    try:
        t = Trainer(cfg, tokens, mask, key=jax.random.PRNGKey(0),
                    config=TrainerConfig(n_clients=2, tau=1,
                                         transport="tcp",
                                         server_addrs=_addrs(servers)))
        for _ in range(3):
            t.step()
        got = _stats(family_name, t)
        t.close()
    finally:
        for s in servers:
            s.close()
    assert set(want) == set(got)
    for n in want:
        np.testing.assert_array_equal(want[n], got[n], err_msg=n)


def test_bsp_tcp_bitexact_two_workers():
    """Two tcp Trainers (one global client each, stepped concurrently)
    jointly reproduce the single-process run exactly."""
    tokens, mask, _ = _corpus()
    cfg = make_family_cfg("lda", n_topics=4, vocab_size=64)
    ref = _run_ref(cfg, tokens, mask, n_clients=2, rounds=3)
    want = _stats("lda", ref)

    servers = _servers("lda", n_clients=2)
    try:
        mk = lambda cs: Trainer(  # noqa: E731
            cfg, tokens, mask, key=jax.random.PRNGKey(0),
            config=TrainerConfig(n_clients=2, tau=1, transport="tcp",
                                 server_addrs=_addrs(servers),
                                 local_clients=cs))
        t0, t1 = mk((0,)), mk((1,))
        for _ in range(3):
            th = threading.Thread(target=t1.step)
            th.start()
            t0.step()
            th.join(timeout=TIMEOUT)
            assert not th.is_alive()
        got0, got1 = _stats("lda", t0), _stats("lda", t1)
        counters = t0.remote.counters()
        t0.close()
        t1.close()
    finally:
        for s in servers:
            s.close()
    for n in want:
        np.testing.assert_array_equal(want[n], got0[n], err_msg=n)
        np.testing.assert_array_equal(want[n], got1[n], err_msg=n)
    assert counters["rpc_count"] > 0
    assert counters["bytes_out"] > 0


def test_ssp_tcp_runs_within_tolerance():
    """SSP(2) over the wire: NOT_MODIFIED fast path engages, token mass
    is conserved exactly, and model quality lands near the BSP result."""
    tokens, mask, _ = _corpus()
    cfg = make_family_cfg("lda", n_topics=4, vocab_size=64)
    ref = _run_ref(cfg, tokens, mask, n_clients=2, rounds=6)
    ref_ppl = ref.perplexity()
    n_tokens = float(np.asarray(mask).sum())

    servers = _servers("lda", n_clients=2, consistency="ssp:2")
    try:
        t = Trainer(cfg, tokens, mask, key=jax.random.PRNGKey(0),
                    config=TrainerConfig(n_clients=2, tau=1,
                                         consistency="ssp:2",
                                         transport="tcp",
                                         server_addrs=_addrs(servers)))
        for _ in range(6):
            t.step()
        t._sync()
        got = _stats("lda", t)
        ppl = t.perplexity()
        counters = t.remote.counters()
        t.close()
    finally:
        for s in servers:
            s.close()
    # Every token is in exactly one (w, k) cell at all times.
    assert got["n_wk"].sum() == pytest.approx(n_tokens)
    assert np.isfinite(ppl)
    assert abs(ppl - ref_ppl) / ref_ppl < 0.25
    # Staleness bound 2 ⇒ strictly fewer refreshing pulls than rounds ⇒
    # strictly fewer bytes than a BSP run would move.
    assert counters["rpc_count"] > 0


def test_tcp_rejects_unsupported_configs():
    tokens, mask, _ = _corpus()
    cfg = make_family_cfg("hdp", n_topics=4, vocab_size=64)
    with pytest.raises(NotImplementedError):
        Trainer(cfg, tokens, mask, key=jax.random.PRNGKey(0),
                config=TrainerConfig(n_clients=2, transport="tcp",
                                     server_addrs=("127.0.0.1:1",)))
    lcfg = make_family_cfg("lda", n_topics=4, vocab_size=64)
    with pytest.raises(ValueError):
        Trainer(lcfg, tokens, mask, key=jax.random.PRNGKey(0),
                config=TrainerConfig(n_clients=2, transport="tcp"))
    with pytest.raises(ValueError):
        Trainer(lcfg, tokens, mask, key=jax.random.PRNGKey(0),
                config=TrainerConfig(n_clients=2, transport="inproc",
                                     server_addrs=("127.0.0.1:1",)))


# ---------------------------------------------------------------------------
# RemoteParameterServer-level semantics
# ---------------------------------------------------------------------------

def _fresh_remote(servers, n_clients=1, consistency="bsp"):
    return RemoteParameterServer(_addrs(servers), family="lda",
                                 n_clients=n_clients, vocab_size=64,
                                 consistency=consistency, timeout=TIMEOUT)


def _zero_shared():
    fam = fam_mod.get("lda")
    n_wk = np.zeros((64, 4), np.float32)
    return fam.shared_from_dict({"n_wk": n_wk, "n_k": n_wk.sum(0)})


def test_not_modified_and_version_flow():
    servers = _servers("lda", n_clients=1, consistency="ssp:2")
    try:
        with _fresh_remote(servers, consistency="ssp:2") as rps:
            rps.init_push(0, _zero_shared())
            shared, v, refreshed = rps.pull(0, None)
            assert refreshed and v == 0 and shared is not None
            rps.push(0, 0, {"n_wk": np.ones((64, 4), np.float32)})
            # Round 1 with cache at version 0: within bound 2 → cached.
            shared, v, refreshed = rps.pull(1, v)
            assert not refreshed and shared is None and v == 0
            rps.push(1, 0, {"n_wk": np.ones((64, 4), np.float32)})
            rps.push(2, 0, {"n_wk": np.ones((64, 4), np.float32)})
            # Round 3 with the version-0 cache exceeds the bound.
            shared, v, refreshed = rps.pull(3, 0)
            assert refreshed and v == 3
            np.testing.assert_array_equal(
                np.asarray(shared.n_wk), np.full((64, 4), 3, np.float32))
    finally:
        for s in servers:
            s.close()


def test_pull_keys_clock_rejoin_snapshot():
    servers = _servers("lda", n_clients=1, n_shards=2)
    try:
        with _fresh_remote(servers) as rps:
            rps.init_push(0, _zero_shared())
            d = stress_delta(0, 0, (64, 4))
            rps.pull(0)
            rps.push(0, 0, {"n_wk": d})
            sr, clocks = rps.clock(min_round=1)
            assert sr == 1
            np.testing.assert_array_equal(clocks, [1])
            # Addressed row-range read spanning the shard boundary.
            mid = rps.pull_keys(["n_wk"], lo=16, hi=48)["n_wk"]
            np.testing.assert_array_equal(mid, d[16:48])
            rps.rejoin(0)
            snap = rps.snapshot(min_round=1)
            np.testing.assert_array_equal(np.asarray(snap.n_wk), d)
            np.testing.assert_array_equal(np.asarray(snap.n_k), d.sum(0))
    finally:
        for s in servers:
            s.close()


def test_projection_applied_at_barrier():
    """A negative delta pushing a count below zero is clipped by the
    family's nonneg rule at the round barrier, exactly like in-process."""
    servers = _servers("lda", n_clients=1)
    try:
        with _fresh_remote(servers) as rps:
            rps.init_push(0, _zero_shared())
            rps.pull(0)
            neg = np.full((64, 4), -1.0, np.float32)
            rps.push(0, 0, {"n_wk": neg})
            out = rps.pull_keys(["n_wk"])["n_wk"]
            np.testing.assert_array_equal(out, np.zeros((64, 4)))
    finally:
        for s in servers:
            s.close()


def test_concurrent_stress_exact_sum():
    """Many client threads, out-of-order arrivals: the barrier still
    applies rounds deterministically — final state == init + Σ."""
    n_clients, rounds = 4, 8
    servers = _servers("lda", n_clients=n_clients, n_shards=2)
    shape = (64, 4)
    try:
        remotes = [_fresh_remote(servers, n_clients=n_clients)
                   for _ in range(n_clients)]
        for c, rps in enumerate(remotes):
            rps.init_push(c, _zero_shared())

        def worker(c):
            rps = remotes[c]
            version = None
            for r in range(rounds):
                _, v, refreshed = rps.pull(r, version)
                if refreshed:
                    version = v
                rps.push(r, c, {"n_wk": stress_delta(r, c, shape)})

        threads = [threading.Thread(target=worker, args=(c,))
                   for c in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=TIMEOUT * 4)
            assert not t.is_alive(), "stress worker hung"
        remotes[0].clock(min_round=rounds)
        final = remotes[0].pull_keys(["n_wk"])["n_wk"]
        want = np.zeros(shape, np.float32)
        for r in range(rounds):
            for c in range(n_clients):
                want = want + stress_delta(r, c, shape)
        np.testing.assert_array_equal(final, want)
        for rps in remotes:
            rps.close()
    finally:
        for s in servers:
            s.close()


def test_duplicate_push_idempotent_conflict_rejected():
    """The seq-dedup rule (DESIGN.md §13): a byte-identical re-push is
    the lost-ack retry — acked, applied exactly once; different content
    claiming the same (client, round) sequence slot is refused, before
    and after the round finalizes."""
    from repro.net.protocol import ProtocolError
    servers = _servers("lda", n_clients=2)
    try:
        r0 = _fresh_remote(servers, n_clients=2)
        r1 = _fresh_remote(servers, n_clients=2)
        r0.init_push(0, _zero_shared())
        r1.init_push(1, _zero_shared())
        d = np.ones((64, 4), np.float32)
        r0.pull(0)
        r0.push(0, 0, {"n_wk": d})
        # Identical duplicate (even from another connection): recorded
        # ack, no second application.
        r1.push(0, 0, {"n_wk": d})
        # Conflicting content for a recorded sequence slot: refused.
        with pytest.raises(ProtocolError):
            r1.push(0, 0, {"n_wk": 2 * d})
        r1.push(0, 1, {"n_wk": d})      # completes round 0
        r1.clock(min_round=1)
        # After finalization the log still answers: identical → ack,
        # conflicting → refused.
        r1.push(0, 1, {"n_wk": d})
        with pytest.raises(ProtocolError):
            r1.push(0, 1, {"n_wk": 3 * d})
        # Exactly one application per (client, round) despite the dups.
        final = r0.pull_keys(["n_wk"])["n_wk"]
        np.testing.assert_array_equal(final, 2 * d)
        r1.close()
        r0.close()
    finally:
        for s in servers:
            s.close()


def test_stale_push_replay_flag_vs_unflagged():
    """A push for a round below the finalized horizon whose log entry
    has been pruned: a replay-flagged frame (reconnect catch-up) acks
    ``ignored``; an unflagged one is a real protocol violation."""
    from repro.net.protocol import MsgType, ProtocolError
    from repro.net.server import MUTLOG_WINDOW
    servers = _servers("lda", n_clients=1)
    rounds = MUTLOG_WINDOW + 2
    try:
        with _fresh_remote(servers) as rps:
            rps.init_push(0, _zero_shared())
            d = np.ones((64, 4), np.float32)
            for r in range(rounds):
                rps.pull(r)
                rps.push(r, 0, {"n_wk": d})
            # (client 0, round 0) is now below the pruned horizon.
            conn = rps._conns[0]
            _, meta, _ = conn.request(
                MsgType.PUSH, {"round": 0, "client": 0, "replay": True},
                {"n_wk": d}, expect=(MsgType.OK,))
            assert meta.get("ignored") is True
            with pytest.raises(ProtocolError):
                conn.request(MsgType.PUSH, {"round": 0, "client": 0},
                             {"n_wk": d}, expect=(MsgType.OK,))
    finally:
        for s in servers:
            s.close()


# ---------------------------------------------------------------------------
# Process-level launcher
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_launch_loopback_stress_processes(tmp_path):
    """Real processes on loopback: 1 server (2 shards) + 2 stress client
    processes; both report identical checksums of the final store."""
    from repro.launch.loopback import launch_loopback
    res = launch_loopback(mode="stress", n_shards=2,
                          client_sets=((0,), (1,)), n_rounds=4,
                          timeout=180.0, workdir=str(tmp_path))
    assert res.ok, [(p.name, p.returncode, p.stderr[-2000:])
                    for p in res.failures()]
    sums = [p.result["checksums"] for p in res.clients]
    assert sums[0] == sums[1]
    want = np.zeros((64, 4), np.float32)
    for r in range(4):
        for c in range(2):
            want = want + stress_delta(r, c, (64, 4))
    assert res.clients[0].result["sums"]["n_wk"] == pytest.approx(
        float(want.sum()))


# ---------------------------------------------------------------------------
# Sparse delta exchange over the wire (DESIGN.md §12)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family_name", ["lda", "pdp"])
def test_sparse_push_tcp_bitexact(family_name):
    """sparse_push is an encoding, not an algorithm change: the tcp
    Trainer with COO push frames reproduces the in-process run bit for
    bit (incl. the multi-stat pdp delta, whose rows are the non-zero
    union across m_wk/s_wk)."""
    tokens, mask, _ = _corpus()
    cfg = make_family_cfg(family_name, n_topics=4, vocab_size=64)
    ref = _run_ref(cfg, tokens, mask, n_clients=2, rounds=3)
    want = _stats(family_name, ref)

    servers = _servers(family_name, n_clients=2, n_shards=2)
    try:
        t = Trainer(cfg, tokens, mask, key=jax.random.PRNGKey(0),
                    config=TrainerConfig(n_clients=2, tau=1,
                                         transport="tcp",
                                         server_addrs=_addrs(servers),
                                         sparse_push=True))
        for _ in range(3):
            t.step()
        got = _stats(family_name, t)
        t.close()
    finally:
        for s in servers:
            s.close()
    for n in want:
        np.testing.assert_array_equal(want[n], got[n], err_msg=n)


def test_sparse_push_rejected_on_inproc_transport():
    tokens, mask, _ = _corpus()
    cfg = make_family_cfg("lda", n_topics=4, vocab_size=64)
    with pytest.raises(ValueError):
        Trainer(cfg, tokens, mask,
                config=TrainerConfig(n_clients=2, sparse_push=True))


# ---------------------------------------------------------------------------
# Bounded reconnect on pull
# ---------------------------------------------------------------------------

def test_pull_reconnects_after_dropped_connection():
    """A dead socket under a pull: the client re-dials, re-handshakes,
    carries its wire counters over, and the pull succeeds."""
    servers = _servers("lda", n_clients=1, n_shards=2)
    try:
        with _fresh_remote(servers) as rps:
            rps.init_push(0, _zero_shared())
            rps.pull(0)
            before = rps.counters()
            # Kill both connections out from under the client.
            for conn in rps._conns:
                conn.sock.close()
            shared, v, refreshed = rps.pull(0)
            assert refreshed and shared is not None
            after = rps.counters()
            # Counters carried over the reconnect (monotone, not reset).
            assert after["bytes_out"] > before["bytes_out"]
            assert after["rpc_count"] > before["rpc_count"]
    finally:
        for s in servers:
            s.close()


def test_pull_reconnect_budget_exhausts_on_dead_server():
    """Every reconnect attempt fails once the server is gone: the pull
    must surface a RemoteError after reconnect_limit tries, not spin."""
    from repro.net.client import RemoteError
    servers = _servers("lda", n_clients=1)
    rps = RemoteParameterServer(_addrs(servers), family="lda",
                                n_clients=1, vocab_size=64,
                                timeout=TIMEOUT, reconnect_limit=2)
    try:
        rps.init_push(0, _zero_shared())
        rps.pull(0)
        for s in servers:
            s.close()
        for conn in rps._conns:
            conn.sock.close()
        with pytest.raises(RemoteError, match="after 2 reconnect"):
            rps.pull(0)
    finally:
        rps.close()
        for s in servers:
            s.close()


# ---------------------------------------------------------------------------
# Eviction, shard restart, worker restart (DESIGN.md §13)
# ---------------------------------------------------------------------------

def test_dead_client_evicted_from_barrier_then_rejoins():
    """A client whose connections die stops the barrier only until the
    liveness deadline: it is evicted, rounds finalize from the
    survivors, and a later rejoin re-admits it after a forced-fresh
    pull."""
    servers = serve_shards("lda", vocab_size=64, n_clients=2,
                           barrier_timeout=TIMEOUT, liveness_timeout=0.4)
    d = np.ones((64, 4), np.float32)
    try:
        r0 = _fresh_remote(servers, n_clients=2)
        r1 = _fresh_remote(servers, n_clients=2)
        r0.init_push(0, _zero_shared())
        r1.init_push(1, _zero_shared())
        r0.pull(0)
        r0.push(0, 0, {"n_wk": d})
        r1.pull(0)
        r1.push(0, 1, {"n_wk": d})          # round 0 complete
        r1.close()                          # client 1 dies for good
        r0.pull(1)
        r0.push(1, 0, {"n_wk": d})          # round 1 waits on client 1...
        r0.pull(2)                          # ...until the liveness sweep
        st = servers[0].stats()             #    evicts it mid-wait
        assert st["evicted"] == [1] and st["evictions"] == 1
        # Survivor-only round applied exactly its one delta.
        np.testing.assert_array_equal(r0.pull_keys(["n_wk"])["n_wk"], 3 * d)

        # Rejoin: fresh connection, REJOIN, forced-fresh pull, and the
        # barrier requires both clients again.
        r1b = _fresh_remote(servers, n_clients=2)
        r1b.rejoin(1)
        assert servers[0].stats()["evicted"] == []
        r1b.pull(2, None)
        r1b.push(2, 1, {"n_wk": d})
        r0.push(2, 0, {"n_wk": d})          # completes round 2 (both)
        r0.clock(min_round=3)
        np.testing.assert_array_equal(r0.pull_keys(["n_wk"])["n_wk"], 5 * d)
        r1b.close()
        r0.close()
    finally:
        for s in servers:
            s.close()


def test_voluntary_leave_unblocks_barrier_immediately():
    """REJOIN action=leave drops the client from the required set with
    no liveness wait — the elastic scale-down path."""
    servers = serve_shards("lda", vocab_size=64, n_clients=2,
                           barrier_timeout=TIMEOUT, liveness_timeout=60.0)
    d = np.ones((64, 4), np.float32)
    try:
        r0 = _fresh_remote(servers, n_clients=2)
        r0.init_push(0, _zero_shared())
        r0.init_push(1, _zero_shared())
        r0.leave(1)
        r0.pull(0)
        r0.push(0, 0, {"n_wk": d})          # finalizes without client 1
        r0.clock(min_round=1)
        np.testing.assert_array_equal(r0.pull_keys(["n_wk"])["n_wk"], d)
    finally:
        r0.close()
        for s in servers:
            s.close()


def test_shard_restart_from_snapshot_resumes_midrun(tmp_path):
    """Kill the shard servers mid-run and restart them on the same ports
    from their own snapshots: the client reconnects, replays its buffered
    mutations (all dedup against the restored mutation log), and the run
    finishes with the exact no-failure sum."""
    shape = (64, 4)
    kw = dict(vocab_size=64, n_clients=1, n_shards=2,
              barrier_timeout=TIMEOUT, snapshot_dir=str(tmp_path),
              snapshot_every=1)
    servers = serve_shards("lda", **kw)
    ports = [s.address[1] for s in servers]
    rps = RemoteParameterServer(_addrs(servers), family="lda", n_clients=1,
                                vocab_size=64, timeout=TIMEOUT,
                                reconnect_limit=10)
    try:
        rps.init_push(0, _zero_shared())
        for r in range(3):
            rps.pull(r)
            rps.push(r, 0, {"n_wk": stress_delta(r, 0, shape)})
        for s in servers:                   # hard kill, no shutdown
            s.close()
        servers = serve_shards("lda", ports=ports, restore=True, **kw)
        assert all(s.stats()["server_round"] == 3 for s in servers)
        for r in range(3, 6):
            rps.pull(r)
            rps.push(r, 0, {"n_wk": stress_delta(r, 0, shape)})
        rps.clock(min_round=6)
        want = np.zeros(shape, np.float32)
        for r in range(6):
            want = want + stress_delta(r, 0, shape)
        np.testing.assert_array_equal(rps.pull_keys(["n_wk"])["n_wk"], want)
        assert rps.counters()["reconnects"] >= 2  # one per shard
    finally:
        rps.close()
        for s in servers:
            s.close()


def test_snapshot_write_restore_rpcs(tmp_path):
    """The SNAPSHOT_WRITE / SNAPSHOT_RESTORE frames: persist on demand,
    mutate, reload — the store rolls back to the persisted round."""
    servers = _servers("lda", n_clients=1)
    d = np.ones((64, 4), np.float32)
    try:
        with _fresh_remote(servers) as rps:
            rps.init_push(0, _zero_shared())
            rps.pull(0)
            rps.push(0, 0, {"n_wk": d})
            acks = rps.snapshot_write(str(tmp_path))
            assert [a["step"] for a in acks] == [1]
            rps.pull(1)
            rps.push(1, 0, {"n_wk": d})
            np.testing.assert_array_equal(
                rps.pull_keys(["n_wk"])["n_wk"], 2 * d)
            assert rps.snapshot_restore(str(tmp_path)) == [1]
            np.testing.assert_array_equal(
                rps.pull_keys(["n_wk"])["n_wk"], d)
    finally:
        for s in servers:
            s.close()


def test_trainer_tcp_fault_plan_ghost_parity():
    """A scripted crash fault over tcp (ghost pushes riding the wire)
    matches the identical in-process faulted run bit for bit."""
    from repro.core.fault import FaultPlan
    tokens, mask, _ = _corpus()
    cfg = make_family_cfg("lda", n_topics=4, vocab_size=64)
    plan = FaultPlan.crash(1, 1, 3)
    rounds = 5

    def _faulted(transport_kw):
        t = Trainer(cfg, tokens, mask, key=jax.random.PRNGKey(0),
                    config=TrainerConfig(n_clients=2, tau=1,
                                         fault_plan=plan, **transport_kw))
        for _ in range(rounds):
            t.step()
        out = _stats("lda", t)
        rejoins = t.rejoins
        t.close()
        return out, rejoins

    want, ref_rejoins = _faulted({})
    servers = _servers("lda", n_clients=2)
    try:
        got, tcp_rejoins = _faulted(dict(transport="tcp",
                                         server_addrs=_addrs(servers)))
    finally:
        for s in servers:
            s.close()
    assert ref_rejoins == tcp_rejoins == 1
    for n in want:
        np.testing.assert_array_equal(want[n], got[n], err_msg=n)
