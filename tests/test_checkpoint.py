"""Checkpoint/restore tests (paper §5.4 snapshots)."""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt


def tree():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.zeros((3,))},
            "step": jnp.asarray(7, jnp.int32),
            "nested": [jnp.ones((2,)), jnp.full((1,), 2.0)]}


def test_save_restore_roundtrip(tmp_path):
    t = tree()
    ckpt.save(str(tmp_path), "state", 10, t)
    restored = ckpt.restore(str(tmp_path), "state", t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_and_manifest(tmp_path):
    t = tree()
    ckpt.save(str(tmp_path), "state", 5, t)
    ckpt.save(str(tmp_path), "state", 12, t)
    assert ckpt.latest_step(str(tmp_path), "state") == 12
    # restore a specific older step still works
    restored = ckpt.restore(str(tmp_path), "state", t, step=5)
    assert int(restored["step"]) == 7


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path), "nope", tree())


def test_dtype_preserved_via_template(tmp_path):
    t = {"x": jnp.asarray([1, 2], jnp.int32),
         "y": jnp.asarray([1.5], jnp.bfloat16)}
    ckpt.save(str(tmp_path), "s", 1, t)
    r = ckpt.restore(str(tmp_path), "s", t)
    assert r["x"].dtype == np.int32
    assert r["y"].dtype == jnp.bfloat16


def test_atomic_manifest_survives_partial_writer(tmp_path):
    """A crashed writer must never corrupt the recovery point: the manifest
    flips only on os.replace."""
    t = tree()
    ckpt.save(str(tmp_path), "state", 1, t)
    # simulate a partial second write: stray tmp file, manifest untouched
    with open(os.path.join(str(tmp_path), "junk.tmp"), "w") as f:
        f.write("partial")
    assert ckpt.latest_step(str(tmp_path), "state") == 1
    restored = ckpt.restore(str(tmp_path), "state", t)
    assert int(restored["step"]) == 7


def test_relocated_snapshot_dir_restores(tmp_path):
    """The manifest stores basenames, so a moved/remounted snapshot
    directory stays recoverable — paths re-join against the manifest's
    own directory at read time."""
    t = tree()
    src = tmp_path / "orig"
    ckpt.save(str(src), "state", 3, t)
    dst = tmp_path / "relocated"
    os.rename(str(src), str(dst))
    restored = ckpt.restore(str(dst), "state", t)
    assert int(restored["step"]) == 7
    manifest = json.load(open(dst / "state.MANIFEST"))
    assert manifest["latest"] == os.path.basename(manifest["latest"])


def test_legacy_manifest_with_joined_path_restores(tmp_path):
    """Manifests written before the basename convention recorded the full
    joined path; restore must tolerate them (and relocation too)."""
    t = tree()
    src = tmp_path / "orig"
    ckpt.save(str(src), "state", 3, t)
    mpath = src / "state.MANIFEST"
    m = json.load(open(mpath))
    m["latest"] = os.path.join(str(src), m["latest"])   # legacy format
    del m["steps"]                                      # legacy: no history
    json.dump(m, open(mpath, "w"))
    dst = tmp_path / "relocated"
    os.rename(str(src), str(dst))
    restored = ckpt.restore_latest(str(dst), "state", t)
    assert int(restored["step"]) == 7


def test_template_shape_mismatch_clear_error(tmp_path):
    t = tree()
    ckpt.save(str(tmp_path), "state", 1, t)
    bad = jax.tree.map(lambda x: x, t)
    bad["params"]["w"] = jnp.zeros((3, 2))
    with pytest.raises(ValueError, match=r"params/w.*shape"):
        ckpt.restore(str(tmp_path), "state", bad)


def test_template_missing_leaf_clear_error(tmp_path):
    t = tree()
    ckpt.save(str(tmp_path), "state", 1, t)
    bad = dict(t)
    bad["extra"] = jnp.zeros((2,))
    with pytest.raises(ValueError, match="extra"):
        ckpt.restore(str(tmp_path), "state", bad)


def test_template_dtype_kind_mismatch_clear_error(tmp_path):
    t = {"x": jnp.asarray([1.5, 2.5], jnp.float32)}
    ckpt.save(str(tmp_path), "s", 1, t)
    with pytest.raises(ValueError, match="dtype"):
        ckpt.restore(str(tmp_path), "s", {"x": jnp.asarray([1, 2], jnp.int32)})


def test_corrupt_latest_falls_back_to_previous(tmp_path):
    """§5.4 recovery: a truncated newest snapshot is rejected in favor of
    the previous manifest entry instead of losing the run."""
    t = tree()
    ckpt.save(str(tmp_path), "state", 1, t)
    t2 = jax.tree.map(lambda x: x + 1, t)
    path2 = ckpt.save(str(tmp_path), "state", 2, t2)
    with open(path2, "r+b") as f:       # truncate mid-archive
        f.truncate(30)
    restored = ckpt.restore_latest(str(tmp_path), "state", t)
    assert int(restored["step"]) == 7   # step-1 content, not step-2's 8
    # an explicit step disables the fallback: that file or nothing
    with pytest.raises(ckpt.CorruptSnapshotError):
        ckpt.restore_latest(str(tmp_path), "state", t, step=2)
    # all entries corrupt -> CorruptSnapshotError listing the attempts
    path1 = os.path.join(str(tmp_path), "state-1.npz")
    with open(path1, "r+b") as f:
        f.truncate(10)
    with pytest.raises(ckpt.CorruptSnapshotError, match="tried steps"):
        ckpt.restore_latest(str(tmp_path), "state", t)
