"""Checkpoint/restore tests (paper §5.4 snapshots)."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt


def tree():
    return {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.zeros((3,))},
            "step": jnp.asarray(7, jnp.int32),
            "nested": [jnp.ones((2,)), jnp.full((1,), 2.0)]}


def test_save_restore_roundtrip(tmp_path):
    t = tree()
    ckpt.save(str(tmp_path), "state", 10, t)
    restored = ckpt.restore(str(tmp_path), "state", t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_and_manifest(tmp_path):
    t = tree()
    ckpt.save(str(tmp_path), "state", 5, t)
    ckpt.save(str(tmp_path), "state", 12, t)
    assert ckpt.latest_step(str(tmp_path), "state") == 12
    # restore a specific older step still works
    restored = ckpt.restore(str(tmp_path), "state", t, step=5)
    assert int(restored["step"]) == 7


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path), "nope", tree())


def test_dtype_preserved_via_template(tmp_path):
    t = {"x": jnp.asarray([1, 2], jnp.int32),
         "y": jnp.asarray([1.5], jnp.bfloat16)}
    ckpt.save(str(tmp_path), "s", 1, t)
    r = ckpt.restore(str(tmp_path), "s", t)
    assert r["x"].dtype == np.int32
    assert r["y"].dtype == jnp.bfloat16


def test_atomic_manifest_survives_partial_writer(tmp_path):
    """A crashed writer must never corrupt the recovery point: the manifest
    flips only on os.replace."""
    t = tree()
    ckpt.save(str(tmp_path), "state", 1, t)
    # simulate a partial second write: stray tmp file, manifest untouched
    with open(os.path.join(str(tmp_path), "junk.tmp"), "w") as f:
        f.write("partial")
    assert ckpt.latest_step(str(tmp_path), "state") == 1
    restored = ckpt.restore(str(tmp_path), "state", t)
    assert int(restored["step"]) == 7
