"""Elastic fault tolerance (paper §5.4, DESIGN.md §10): FaultPlan
resolution, Trainer snapshot/restore, and kill-and-rejoin recovery.

Contracts:

1. a FaultPlan resolves host-side to per-round masks deterministically
   (seeded-random plans are pure values);
2. the deprecated ``drop_client`` tuple compiles to the equivalent
   one-event plan with a DeprecationWarning;
3. a BSP run interrupted by a crash and resumed via ``Trainer.restore``
   is bit-exact with the uninterrupted run (the snapshot carries every
   round input);
4. an SSP rejoin is just a maximally-stale client taking its blocking
   refresh: the forced pull lands at the rejoin round and the client's
   read-my-writes lag is cleared;
5. a failed pull refresh degrades gracefully (stale cache + bounded
   host-side retry, then force-through), loses no count mass;
6. lost pushes lose exactly their delta (consistency error goes nonzero
   by design, clocks freeze); stragglers lose nothing;
7. all of it holds identically in the compiled round and the Python
   reference loop.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.fault import FaultEvent, FaultPlan, healthy
from repro.engine import Trainer, TrainerConfig
from tests.conftest import make_family_cfg, make_synthetic_corpus

VOCAB = 64


def _cfg(name="lda", k=6):
    return make_family_cfg(name, n_topics=k, vocab_size=VOCAB)


@pytest.fixture(scope="module")
def corpus():
    return make_synthetic_corpus(n_topics=4, vocab=VOCAB, n_docs=24,
                                 doc_len=16, seed=3)


# ---------------------------------------------------------------------------
# FaultPlan resolution (pure host-side)
# ---------------------------------------------------------------------------

def test_fault_event_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultEvent("explode", 0, 0, 1)
    with pytest.raises(ValueError, match="reversed"):
        FaultEvent("crash", 0, 3, 1)
    with pytest.raises(ValueError, match="period"):
        FaultEvent("straggle", 0, 0, 4, period=1)
    with pytest.raises(TypeError):
        FaultPlan(events=("crash",))


def test_plan_resolution_scripted():
    plan = FaultPlan.scripted(
        FaultEvent("crash", client=1, start=2, stop=4),
        FaultEvent("lost_push", client=0, start=3, stop=5),
        FaultEvent("straggle", client=2, start=0, stop=6, period=3),
        FaultEvent("failed_pull", start=4, stop=5),
    )
    n = 4
    # round 0: straggler works ((0-0) % 3 == 0), everyone healthy
    rf = plan.resolve(0, n)
    assert rf.alive == (True, True, True, True)
    assert rf.push_ok == (True, True, True, True)
    assert not rf.pull_failed and rf.rejoining == ()
    # round 1: straggler mid-stall
    rf = plan.resolve(1, n)
    assert rf.alive == (True, True, False, True)
    assert rf.push_ok == (True, True, False, True)
    # round 3: crash active, lost_push active, straggler works
    rf = plan.resolve(3, n)
    assert rf.alive == (True, False, True, True)
    assert rf.push_ok == (False, False, True, True)
    # round 4: crash window ends -> rejoin; shared refresh outage;
    # the period-3 straggler is mid-stall ((4-0) % 3 != 0)
    rf = plan.resolve(4, n)
    assert rf.alive == (True, True, False, True)
    assert rf.rejoining == (1,)
    assert rf.pull_failed
    # past the last window: the cached healthy value
    assert plan.resolve(7, n) is healthy(n)
    assert plan.last_round == 6 and plan.max_client == 2


def test_plan_rejoin_suppressed_by_overlapping_crash():
    plan = FaultPlan.scripted(
        FaultEvent("crash", client=0, start=0, stop=2),
        FaultEvent("crash", client=0, start=2, stop=4),
    )
    rf = plan.resolve(2, 2)
    assert not rf.alive[0] and rf.rejoining == ()
    assert plan.resolve(4, 2).rejoining == (0,)


def test_plan_resolution_rejects_out_of_range_client():
    with pytest.raises(ValueError, match="only 2 clients"):
        FaultPlan.crash(5, 0, 2).resolve(1, 2)


def test_random_plan_deterministic_and_bounded():
    mk = lambda s: FaultPlan.random(s, n_clients=4, n_rounds=32,
                                    p_crash=0.1, p_straggle=0.1,
                                    p_lost_push=0.1, p_failed_pull=0.05)
    assert mk(7).events == mk(7).events
    assert mk(7).events != mk(8).events
    plan = mk(7)
    assert plan.events, "expected events at these hazard rates"
    for e in plan.events:
        assert 0 <= e.start <= e.stop <= 32
        if e.kind != "failed_pull":
            assert e.client < 4
    # at most one concurrent per-client event
    for c in range(4):
        wins = sorted((e.start, e.stop) for e in plan.events
                      if e.kind != "failed_pull" and e.client == c)
        for (_, s0), (s1, _) in zip(wins, wins[1:]):
            assert s0 <= s1


# ---------------------------------------------------------------------------
# drop_client deprecation shim
# ---------------------------------------------------------------------------

def test_drop_client_shim_warns_and_matches(corpus):
    tokens, mask, _ = corpus
    with pytest.warns(DeprecationWarning, match="drop_client"):
        t = Trainer(_cfg(), tokens, mask, config=TrainerConfig(
            n_clients=4, drop_client=(1, 1, 3)))
    assert t.fault_plan == FaultPlan.crash(1, 1, 3)


def test_drop_client_and_fault_plan_mutually_exclusive(corpus):
    tokens, mask, _ = corpus
    with pytest.raises(ValueError, match="mutually exclusive"):
        Trainer(_cfg(), tokens, mask, config=TrainerConfig(
            n_clients=4, drop_client=(1, 1, 3),
            fault_plan=FaultPlan.crash(0, 0, 1)))


def test_trainer_rejects_plan_naming_missing_client(corpus):
    tokens, mask, _ = corpus
    with pytest.raises(ValueError, match="client 3"):
        Trainer(_cfg(), tokens, mask, config=TrainerConfig(
            n_clients=2, fault_plan=FaultPlan.crash(3, 0, 1)))


# ---------------------------------------------------------------------------
# Snapshot / restore / rejoin
# ---------------------------------------------------------------------------

def _stats(t):
    return {n: np.asarray(v)
            for n, v in t.family.stats_dict(t.shared).items()}


def test_bsp_crash_restore_bit_exact(corpus, tmp_path):
    """The oracle property: a run killed after round 4 and resumed from
    the round-4 snapshot replays rounds 4..5 bit-exactly — every shared
    statistic and every client's conserved counts match the
    uninterrupted run."""
    tokens, mask, _ = corpus
    tcfg = TrainerConfig(n_clients=2, snapshot_every=2,
                         snapshot_dir=str(tmp_path))
    ref = Trainer(_cfg(), tokens, mask, config=tcfg)
    for _ in range(6):
        ref.step()
    ref._sync()

    res = Trainer.restore(_cfg(), tokens, mask, config=tcfg, step=4)
    assert res.round_idx == 4
    for _ in range(2):
        res.step()
    res._sync()
    assert res.consistency_error() == 0.0
    a, b = _stats(ref), _stats(res)
    for n in a:
        np.testing.assert_array_equal(a[n], b[n], err_msg=n)


def test_restore_latest_default_and_missing_dir(corpus, tmp_path):
    tokens, mask, _ = corpus
    tcfg = TrainerConfig(n_clients=2, snapshot_every=2,
                         snapshot_dir=str(tmp_path))
    t = Trainer(_cfg(), tokens, mask, config=tcfg)
    for _ in range(5):
        t.step()
    # snapshots at rounds 2 and 4; the manifest's latest wins
    res = Trainer.restore(_cfg(), tokens, mask, config=tcfg)
    assert res.round_idx == 4
    with pytest.raises(ValueError, match="snapshot_dir"):
        Trainer.restore(_cfg(), tokens, mask,
                        config=TrainerConfig(n_clients=2))


def test_ssp_rejoin_forces_refresh_and_resets_lag(corpus, tmp_path):
    """Kill-and-rejoin under SSP(3): the rejoin at round 3 forces a
    fresh pull off-schedule (the natural refresh would wait until round
    4), the rejoined client re-enters with a cleared read-my-writes lag
    (the fresh cache carries every applied push; within the rejoin round
    its row then accumulates exactly its own new delta — which is why
    conservation still holds exactly), and no count mass is lost (the
    crash froze the client, nothing moved)."""
    tokens, mask, _ = corpus
    t = Trainer(_cfg(), tokens, mask, config=TrainerConfig(
        n_clients=2, consistency="ssp:3",
        fault_plan=FaultPlan.crash(1, 1, 3),
        snapshot_every=2, snapshot_dir=str(tmp_path)))
    for _ in range(3):        # rounds 0..2: refresh at 0, crash at 1,2
        t.step()
    assert t._host_version == 0
    t.step()                  # round 3: rejoin -> forced refresh
    t._sync()
    assert t.rejoins == 1
    assert t._host_version == 3
    assert int(np.asarray(t.pstate.cache_version)) == 3
    assert t.consistency_error() == 0.0
    np.testing.assert_array_equal(t.clocks, [4, 2])


def test_server_rejoin_client_clears_one_lag_row(corpus):
    tokens, mask, _ = corpus
    t = Trainer(_cfg(), tokens, mask, config=TrainerConfig(
        n_clients=2, consistency="ssp:3"))
    for _ in range(2):        # rounds past the refresh: lag accumulates
        t.step()
    t._sync()
    assert any(np.abs(np.asarray(v[0])).sum() > 0
               for v in t.pstate.client_lag.values())
    state = t.server.rejoin_client(t.pstate, 0)
    for n, v in state.client_lag.items():
        np.testing.assert_array_equal(np.asarray(v[0]),
                                      np.zeros_like(np.asarray(v[0])))
        np.testing.assert_array_equal(np.asarray(v[1]),
                                      np.asarray(t.pstate.client_lag[n][1]))


def test_failed_pull_bounded_retry_then_force_through(corpus):
    """An SSP(2) refresh outage: the due pull at round 3 fails, clients
    continue on the stale cache (degradation, not derailment) while the
    host retries; after pull_retry_limit consecutive failures the
    refresh forces through.  No count mass is ever lost."""
    tokens, mask, _ = corpus
    plan = FaultPlan.scripted(FaultEvent("failed_pull", start=1, stop=12))
    t = Trainer(_cfg(), tokens, mask, config=TrainerConfig(
        n_clients=2, consistency="ssp:2", fault_plan=plan,
        pull_retry_limit=2))
    for _ in range(6):        # due at 3 -> fail(3), fail(4), force(5)
        t.step()
    t._sync()
    assert t.pull_failures == 2
    assert t._host_version == 5
    assert int(np.asarray(t.pstate.cache_version)) == 5
    assert t.consistency_error() == 0.0


def test_failed_pull_noop_under_bsp(corpus):
    tokens, mask, _ = corpus
    plan = FaultPlan.scripted(FaultEvent("failed_pull", start=0, stop=8))
    t = Trainer(_cfg(), tokens, mask, config=TrainerConfig(
        n_clients=2, consistency="bsp", fault_plan=plan))
    for _ in range(3):
        t.step()
    t._sync()
    assert t.pull_failures == 0
    assert t.consistency_error() == 0.0


@pytest.mark.parametrize("compiled", [True, False])
def test_lost_push_loses_mass_and_freezes_clock(corpus, compiled):
    """A lost push is a *lossy* fault: the client's replica moved but the
    server never saw the delta, so the maintained statistics drift from
    the assignments (nonzero consistency error, by design) and the
    client's clock does not advance for the lost rounds."""
    tokens, mask, _ = corpus
    t = Trainer(_cfg(), tokens, mask, config=TrainerConfig(
        n_clients=2, compiled=compiled,
        fault_plan=FaultPlan.scripted(
            FaultEvent("lost_push", client=1, start=1, stop=3))))
    for _ in range(4):
        t.step()
    t._sync()
    np.testing.assert_array_equal(t.clocks, [4, 2])
    assert t.consistency_error() > 0.0


def test_straggler_conserves_counts(corpus):
    """A straggler with period 2 completes every other round: its clock
    runs at half speed but nothing is lost — the dense-filter
    conservation contract holds exactly."""
    tokens, mask, _ = corpus
    t = Trainer(_cfg(), tokens, mask, config=TrainerConfig(
        n_clients=2, fault_plan=FaultPlan.scripted(
            FaultEvent("straggle", client=1, start=0, stop=6, period=2))))
    for _ in range(6):
        t.step()
    t._sync()
    np.testing.assert_array_equal(t.clocks, [6, 3])
    assert t.consistency_error() == 0.0


def test_compiled_python_parity_under_fault_plan(corpus):
    """The compiled round and the reference loop resolve the same plan to
    identical statistics — the fault masks enter both paths identically
    (bit-exact integer counts, including the lossy lost_push rounds)."""
    tokens, mask, _ = corpus
    plan = FaultPlan.scripted(
        FaultEvent("crash", client=0, start=1, stop=3),
        FaultEvent("lost_push", client=1, start=2, stop=4),
        FaultEvent("straggle", client=2, start=0, stop=5, period=2),
    )
    trainers = {
        compiled: Trainer(_cfg(), tokens, mask, config=TrainerConfig(
            n_clients=3, compiled=compiled, fault_plan=plan))
        for compiled in (True, False)}
    for _ in range(5):
        for t in trainers.values():
            t.step()
    trainers[True]._sync()
    a, b = _stats(trainers[True]), _stats(trainers[False])
    for n in a:
        np.testing.assert_array_equal(a[n], b[n], err_msg=n)
    np.testing.assert_array_equal(trainers[True].clocks,
                                  trainers[False].clocks)


def test_fault_plan_rounds_trace_once(corpus):
    """Chaos must not retrace: a multi-kind plan spanning crashes,
    stragglers, lost pushes and rejoins keeps the one-trace-per-signature
    invariant (the masks are traced inputs)."""
    tokens, mask, _ = corpus
    plan = FaultPlan.random(5, n_clients=3, n_rounds=8, p_crash=0.3,
                            p_straggle=0.3, p_lost_push=0.3,
                            p_failed_pull=0.2)
    t = Trainer(_cfg(), tokens, mask, config=TrainerConfig(
        n_clients=3, consistency="ssp:2", fault_plan=plan))
    t.step()
    traced_once = t.round_traces
    for _ in range(7):
        t.step()
    t._sync()
    assert t.round_traces == traced_once
    assert np.isfinite(t.perplexity(tokens[:16], mask[:16]))
