"""Shared test fixtures.

NOTE: XLA_FLAGS device-count forcing is deliberately NOT set here — smoke
tests and benchmarks must see the single real CPU device.  Multi-device
tests spawn subprocesses (tests/test_distributed.py) or use the dry-run
entry point, which sets the flag before importing jax.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def make_synthetic_corpus(n_topics, vocab, n_docs, doc_len, seed=0,
                          theta_conc=0.2, phi_conc=0.4):
    """Block-structured synthetic corpus with known topics: each true topic
    owns a contiguous vocabulary block (easy to verify recovery)."""
    rng = np.random.default_rng(seed)
    true_phi = np.zeros((n_topics, vocab))
    block = vocab // n_topics
    for k in range(n_topics):
        true_phi[k, k * block:(k + 1) * block] = rng.dirichlet(
            np.ones(block) * phi_conc)
    docs = []
    for _ in range(n_docs):
        theta = rng.dirichlet(np.ones(n_topics) * theta_conc)
        zs = rng.choice(n_topics, size=doc_len, p=theta)
        docs.append(np.array([rng.choice(vocab, p=true_phi[z]) for z in zs]))
    tokens = jnp.asarray(np.stack(docs), dtype=jnp.int32)
    mask = jnp.ones((n_docs, doc_len), dtype=bool)
    return tokens, mask, true_phi


@pytest.fixture(scope="session")
def small_corpus():
    return make_synthetic_corpus(n_topics=6, vocab=120, n_docs=64, doc_len=40,
                                 seed=1)
