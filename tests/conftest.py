"""Shared test fixtures.

NOTE: XLA_FLAGS device-count forcing is deliberately NOT set here — smoke
tests and benchmarks must see the single real CPU device.  Multi-device
tests spawn subprocesses (tests/test_distributed.py) or use the dry-run
entry point, which sets the flag before importing jax.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def make_synthetic_corpus(n_topics, vocab, n_docs, doc_len, seed=0,
                          theta_conc=0.2, phi_conc=0.4):
    """Block-structured synthetic corpus with known topics: each true topic
    owns a contiguous vocabulary block (easy to verify recovery)."""
    rng = np.random.default_rng(seed)
    true_phi = np.zeros((n_topics, vocab))
    block = vocab // n_topics
    for k in range(n_topics):
        true_phi[k, k * block:(k + 1) * block] = rng.dirichlet(
            np.ones(block) * phi_conc)
    docs = []
    for _ in range(n_docs):
        theta = rng.dirichlet(np.ones(n_topics) * theta_conc)
        zs = rng.choice(n_topics, size=doc_len, p=theta)
        docs.append(np.array([rng.choice(vocab, p=true_phi[z]) for z in zs]))
    tokens = jnp.asarray(np.stack(docs), dtype=jnp.int32)
    mask = jnp.ones((n_docs, doc_len), dtype=bool)
    return tokens, mask, true_phi


@pytest.fixture(scope="session")
def small_corpus():
    return make_synthetic_corpus(n_topics=6, vocab=120, n_docs=64, doc_len=40,
                                 seed=1)


def make_family_cfg(name, *, n_topics, vocab_size, mh_steps=2):
    """Test-sized model config for a registered ModelFamily — one factory
    so per-family hyperparameter defaults cannot drift between test files.
    Sizes (K, V) stay per-call-site; family-specific knobs live here."""
    from repro.core import hdp, lda, pdp
    if name == "lda":
        return lda.LDAConfig(n_topics=n_topics, vocab_size=vocab_size,
                             mh_steps=mh_steps)
    if name == "pdp":
        return pdp.PDPConfig(n_topics=n_topics, vocab_size=vocab_size,
                             mh_steps=mh_steps, stirling_n_max=128,
                             concentration=5.0)
    if name == "hdp":
        return hdp.HDPConfig(n_topics=n_topics, vocab_size=vocab_size,
                             b1=2.0, mh_steps=mh_steps)
    raise ValueError(name)
