"""The shard_map distributed Gibbs round (core/distributed.py) on a real
multi-device mesh — run in a subprocess so the forced device count never
leaks into other tests.  Since the ParameterServer redesign the round
consumes a ``core.server.ParameterServer``: the canonical statistics live
in its vocabulary-sharded ``ServerState`` (here also laid over the mesh's
``model`` axis), the alias proposal is server-resident
(``refresh_proposal``), and the consistency policy is pluggable."""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import distributed, lda, ps

    from repro.data.synthetic import CorpusConfig, make_topic_corpus

    assert len(jax.devices()) == 8

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    tokens, mask, _ = make_topic_corpus(CorpusConfig(
        n_topics=8, vocab_size=128, n_docs=64, doc_len=32, seed=0))
    tokens, mask = jnp.asarray(tokens), jnp.asarray(mask)

    cfg = lda.LDAConfig(n_topics=8, vocab_size=128, mh_steps=2)
    # Two vocabulary shards laid over the 2-wide model axis.
    dcfg = distributed.DistConfig(model="lda", tau=1, n_server_shards=2)
    server = distributed.make_server(cfg, dcfg)
    key = jax.random.PRNGKey(0)
    local, shared = lda.init_state(cfg, tokens, mask, key)
    state = server.init_state(shared, n_clients=4)

    with mesh:
        round_fn = distributed.make_round_fn(cfg, dcfg, mesh, server=server)
        p0 = float(lda.perplexity(cfg, shared, tokens[:16], mask[:16],
                                  jax.random.PRNGKey(5)))
        alive = jnp.ones((4,), bool)
        for r in range(8):
            state = server.refresh_proposal(cfg, state)
            local, state = round_fn(local, state, tokens, mask,
                                    jax.random.fold_in(key, r), alive)
        shared = server.snapshot(state)
        p1 = float(lda.perplexity(cfg, shared, tokens[:16], mask[:16],
                                  jax.random.PRNGKey(5)))

    # Convergence across the mesh
    assert p1 < p0 * 0.8, (p0, p1)
    # Per-client clocks advanced with every applied push
    assert np.asarray(state.clocks).tolist() == [8, 8, 8, 8]
    # The server's per-shard changed-row accounting accumulated push mass
    assert all(float(m.sum()) > 0 for m in server.shard_row_mass(state))
    # Shared statistics remain consistent with the summed local assignments
    nwk = lda.count_wk(cfg, tokens, local.z, mask)
    err = float(jnp.abs(nwk - shared.n_wk).max())
    assert err == 0.0, err
    # Failure injection: a dead client contributes nothing (and its clock
    # freezes), system still OK
    with mesh:
        alive = alive.at[1].set(False)
        state2 = server.refresh_proposal(cfg, state)
        local2, state2 = round_fn(local, state2, tokens, mask,
                                  jax.random.fold_in(key, 99), alive)
        shared2 = server.snapshot(state2)
        p2 = float(lda.perplexity(cfg, shared2, tokens[:16], mask[:16],
                                  jax.random.PRNGKey(5)))
    assert np.isfinite(p2) and p2 < p0, (p0, p2)
    assert np.asarray(state2.clocks).tolist() == [9, 8, 9, 9]

    # SSP on the mesh: the versioned cache refreshes from the clocks
    # (bound=1 -> every other round), counts stay exactly consistent.
    scfg = distributed.DistConfig(model="lda", tau=1, consistency="ssp:1")
    sserver = distributed.make_server(cfg, scfg)
    slocal, sshared = lda.init_state(cfg, tokens, mask, key)
    sstate = sserver.init_state(sshared, n_clients=4)
    with mesh:
        sround = distributed.make_round_fn(cfg, scfg, mesh, server=sserver)
        alive = jnp.ones((4,), bool)
        for r in range(4):
            if sserver.policy.needs_refresh(r, int(sstate.cache_version)) \
                    or r == 0:
                sstate = sserver.refresh_proposal(cfg, sstate)
            slocal, sstate = sround(slocal, sstate, tokens, mask,
                                    jax.random.fold_in(key, 500 + r), alive)
    snwk = lda.count_wk(cfg, tokens, slocal.z, mask)
    serr = float(jnp.abs(snwk - sserver.snapshot(sstate).n_wk).max())
    assert serr == 0.0, serr
    assert int(sstate.cache_version) == 2   # refreshed at clock 0 -> 2

    # The token-sorted fast path under shard_map: the same registry round
    # with DistConfig(layout="sorted") must run on the mesh and keep the
    # shared statistics consistent with the summed local assignments.
    with mesh:
        dcfg_sorted = distributed.DistConfig(model="lda", tau=1,
                                             layout="sorted")
        server_s = distributed.make_server(cfg, dcfg_sorted)
        round_fn_sorted = distributed.make_round_fn(cfg, dcfg_sorted, mesh,
                                                    server=server_s)
        alive = jnp.ones((4,), bool)
        state_s = server_s.refresh_proposal(
            cfg, server_s.init_state(shared, n_clients=4))
        local_s, state_s = round_fn_sorted(local, state_s, tokens, mask,
                                           jax.random.fold_in(key, 400),
                                           alive)
        shared_s = server_s.snapshot(state_s)
    ps_ = float(lda.perplexity(cfg, shared_s, tokens[:16], mask[:16],
                               jax.random.PRNGKey(5)))
    assert np.isfinite(ps_), ps_
    nwk_s = lda.count_wk(cfg, tokens, local_s.z, mask)
    assert float(jnp.abs(nwk_s - shared_s.n_wk).max()) == 0.0

    # PDP and HDP through the same registry-driven round: the one round
    # implementation serves every family (no per-model adapters).
    from repro.core import family, hdp, pdp, projection

    pcfg = pdp.PDPConfig(n_topics=8, vocab_size=128, mh_steps=2,
                         stirling_n_max=128, concentration=5.0)
    plocal, pshared = pdp.init_state(pcfg, tokens, mask, key)
    alive = jnp.ones((4,), bool)
    with mesh:
        pdcfg = distributed.DistConfig(model="pdp", tau=1)
        pserver = distributed.make_server(pcfg, pdcfg)
        round_fn = distributed.make_round_fn(pcfg, pdcfg, mesh,
                                             server=pserver)
        pstate = pserver.init_state(pshared, n_clients=4)
        for r in range(2):
            pstate = pserver.refresh_proposal(pcfg, pstate)
            plocal, pstate = round_fn(plocal, pstate, tokens, mask,
                                      jax.random.fold_in(key, 200 + r),
                                      alive)
        pshared = pserver.snapshot(pstate)
    ppdp = float(pdp.perplexity(pcfg, pshared, tokens[:16], mask[:16],
                                jax.random.PRNGKey(5)))
    assert np.isfinite(ppdp)
    # shared projection held the PDP polytope
    fam = family.get("pdp")
    assert float(fam.count_violations(pshared)) == 0.0

    hcfg = hdp.HDPConfig(n_topics=8, vocab_size=128, b1=2.0, mh_steps=2)
    hlocal, hshared = hdp.init_state(hcfg, tokens, mask, key)
    with mesh:
        hdcfg = distributed.DistConfig(model="hdp", tau=1)
        hserver = distributed.make_server(hcfg, hdcfg)
        round_fn = distributed.make_round_fn(hcfg, hdcfg, mesh,
                                             server=hserver)
        hstate = hserver.init_state(hshared, n_clients=4)
        for r in range(2):
            hstate = hserver.refresh_proposal(hcfg, hstate)
            hlocal, hstate = round_fn(hlocal, hstate, tokens, mask,
                                      jax.random.fold_in(key, 300 + r),
                                      alive)
        hshared = hserver.snapshot(hstate)
    phdp = float(hdp.perplexity(hcfg, hshared, tokens[:16], mask[:16],
                                jax.random.PRNGKey(5)))
    assert np.isfinite(phdp)
    # HDP's local table-count polytope (1 <= m_dk <= n_dk) — previously
    # silently dropped by the ad-hoc adapter — is enforced in-round.
    hfam = family.get("hdp")
    lv = float(projection.count_violations(
        {"m_dk": hlocal.m_dk, "n_dk": hlocal.n_dk}, hfam.local_rules))
    assert lv == 0.0, lv
    print("DISTRIBUTED_ROUND_OK", p0, p1, p2, ppdp, phdp)
""")


@pytest.mark.slow
def test_distributed_round_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "DISTRIBUTED_ROUND_OK" in proc.stdout
