"""The shard_map distributed Gibbs round (core/distributed.py) on a real
multi-device mesh — run in a subprocess so the forced device count never
leaks into other tests."""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import distributed, lda, ps
    from repro.data.synthetic import CorpusConfig, make_topic_corpus

    assert len(jax.devices()) == 8

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    tokens, mask, _ = make_topic_corpus(CorpusConfig(
        n_topics=8, vocab_size=128, n_docs=64, doc_len=32, seed=0))
    tokens, mask = jnp.asarray(tokens), jnp.asarray(mask)

    cfg = lda.LDAConfig(n_topics=8, vocab_size=128, mh_steps=2)
    dcfg = distributed.DistConfig(model="lda", tau=1)
    key = jax.random.PRNGKey(0)
    local, shared = lda.init_state(cfg, tokens, mask, key)

    with mesh:
        round_fn = distributed.make_round_fn(cfg, dcfg, mesh)
        p0 = float(lda.perplexity(cfg, shared, tokens[:16], mask[:16],
                                  jax.random.PRNGKey(5)))
        alive = jnp.ones((4,), bool)
        for r in range(8):
            tables, stale = lda.build_alias(cfg, shared)
            local, shared = round_fn(local, shared, tables, stale, tokens,
                                     mask, jax.random.fold_in(key, r), alive)
        p1 = float(lda.perplexity(cfg, shared, tokens[:16], mask[:16],
                                  jax.random.PRNGKey(5)))

    # Convergence across the mesh
    assert p1 < p0 * 0.8, (p0, p1)
    # Shared statistics remain consistent with the summed local assignments
    nwk = lda.count_wk(cfg, tokens, local.z, mask)
    err = float(jnp.abs(nwk - shared.n_wk).max())
    assert err == 0.0, err
    # Failure injection: a dead client contributes nothing, system still OK
    with mesh:
        alive = alive.at[1].set(False)
        tables, stale = lda.build_alias(cfg, shared)
        local2, shared2 = round_fn(local, shared, tables, stale, tokens,
                                   mask, jax.random.fold_in(key, 99), alive)
        p2 = float(lda.perplexity(cfg, shared2, tokens[:16], mask[:16],
                                  jax.random.PRNGKey(5)))
    assert np.isfinite(p2) and p2 < p0, (p0, p2)
    print("DISTRIBUTED_ROUND_OK", p0, p1, p2)
""")


@pytest.mark.slow
def test_distributed_round_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900,
                          cwd=os.path.dirname(os.path.dirname(__file__)))
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "DISTRIBUTED_ROUND_OK" in proc.stdout
